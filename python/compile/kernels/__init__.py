"""Layer-1 Pallas kernels for Kant's scoring hot-spot (build-time only)."""

from . import ref, score  # noqa: F401
