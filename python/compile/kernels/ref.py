"""Pure-jnp reference oracles for the Kant scoring kernels.

These are the ground truth the Pallas kernels in ``score.py`` are tested
against (pytest + hypothesis). They define the *scoring contract* shared with
the Rust native scorer (``rust/src/rsch/score.rs``): same feature layout, same
component definitions, same masking semantics. Keep the three in lockstep.

Feature layout — node features ``[N, NODE_F]`` (f32):

  ==  =====================  ==========================================
  idx  name                   meaning
  ==  =====================  ==========================================
   0  free_gpus              free *healthy* GPUs on the node
   1  total_gpus             GPUs physically on the node
   2  alloc_gpus             GPUs currently allocated
   3  healthy                1.0 if node is schedulable
   4  group_free             free GPUs in the node's NodeNetGroup
   5  group_total            total GPUs in the node's NodeNetGroup
   6  job_pods_on_node       this job's pods already placed on the node
   7  job_pods_in_group      this job's pods already placed in the group
   8  topo_tier              min distance tier to already-placed pods
                             (0 node / 1 leaf / 2 spine / 3 superspine /
                              4 cross-superspine; 4 when the job has no
                              placed pods yet)
   9  in_inference_zone      1.0 if node is in the E-Spread dedicated zone
  10  hbd_free               free GPUs in the node's HBD (scale-up) domain
  11  nvlink_best_clique     size of the largest free NVLink-connected
                             GPU clique on the node
  ==  =====================  ==========================================

Job descriptor ``[JOB_D]`` (f32):

  0 gpus_per_pod, 1 total_gpus, 2 is_gang, 3 is_inference,
  4 wants_whole_node, 5 strategy_id, 6 needs_hbd, 7 (reserved)

Weight vector ``[NUM_COMPONENTS]`` (f32) — chosen by the Rust side per
placement strategy (Binpack / E-Binpack / Spread / E-Spread / native):

  0 w_fill, 1 w_spread, 2 w_group_pack, 3 w_group_empty,
  4 w_topo, 5 w_colocate, 6 w_zone, 7 w_nvlink

Score: ``mask * (components @ w) + (mask - 1) * BIG`` so infeasible nodes sit
at ``-BIG`` and can never win an argmax, while remaining finite (the Rust
side relies on finiteness when sorting).
"""

from __future__ import annotations

import jax.numpy as jnp

NODE_F = 12
GROUP_F = 6
JOB_D = 8
NUM_COMPONENTS = 8
GROUP_COMPONENTS = 6
BIG = 1.0e9
EPS = 1.0e-6


def node_components(feat: jnp.ndarray, job: jnp.ndarray) -> jnp.ndarray:
    """Per-node score components ``[N, NUM_COMPONENTS]`` (pure jnp oracle)."""
    feat = feat.astype(jnp.float32)
    job = job.astype(jnp.float32)
    alloc = feat[:, 2]
    total = jnp.maximum(feat[:, 1], EPS)
    group_free = feat[:, 4]
    group_total = jnp.maximum(feat[:, 5], EPS)
    pods_on_node = feat[:, 6]
    topo_tier = feat[:, 8]
    in_zone = feat[:, 9]
    clique = feat[:, 11]

    gpus_per_pod = job[0]

    # c0: binpack — fill ratio *after* placing one pod, clamped to [0, 1].
    fill_after = jnp.clip((alloc + gpus_per_pod) / total, 0.0, 1.0)
    # c1: spread — prefer emptier nodes.
    spread = 1.0 - jnp.clip(alloc / total, 0.0, 1.0)
    # c2: group consolidation — prefer groups that are already busy.
    group_pack = 1.0 - jnp.clip(group_free / group_total, 0.0, 1.0)
    # c3: group emptiness — prefer empty groups (large gang jobs).
    group_empty = jnp.clip(group_free / group_total, 0.0, 1.0)
    # c4: topology closeness to already-placed pods of the same job.
    #     Truthful 5-tier scale (0 node .. 4 cross-superspine): staying
    #     under the gang's superspine keeps a 0.25 edge over crossing the
    #     core layer. Mirrors rust/src/rsch/score.rs; keep in lockstep.
    topo = 1.0 - jnp.clip(topo_tier, 0.0, 4.0) / 4.0
    # c5: co-location with this job's pods already on the node (E-Binpack
    #     node level), saturating at 8 pods.
    colocate = jnp.clip(pods_on_node, 0.0, 8.0) / 8.0
    # c6: E-Spread dedicated-zone membership.
    zone = in_zone
    # c7: intra-node NVLink fit — largest free clique can hold the pod.
    nvlink = (clique >= gpus_per_pod).astype(jnp.float32)

    return jnp.stack(
        [fill_after, spread, group_pack, group_empty, topo, colocate, zone, nvlink],
        axis=1,
    )


def node_feasible(feat: jnp.ndarray, job: jnp.ndarray) -> jnp.ndarray:
    """Feasibility mask ``[N]``: healthy and enough free GPUs for one pod."""
    feat = feat.astype(jnp.float32)
    healthy = feat[:, 3] > 0.5
    enough = feat[:, 0] >= job[0]
    return jnp.logical_and(healthy, enough).astype(jnp.float32)


def score_nodes_ref(
    feat: jnp.ndarray, job: jnp.ndarray, weights: jnp.ndarray
) -> jnp.ndarray:
    """Reference node scorer: ``[N, NODE_F] x [JOB_D] x [NUM_COMPONENTS] -> [N]``."""
    comps = node_components(feat, job)
    mask = node_feasible(feat, job)
    raw = comps @ weights.astype(jnp.float32)
    return mask * raw + (mask - 1.0) * BIG


def group_components(gfeat: jnp.ndarray, job: jnp.ndarray) -> jnp.ndarray:
    """Per-group components ``[G, GROUP_COMPONENTS]``.

    Group feature layout ``[G, GROUP_F]``:
      0 free_gpus, 1 total_gpus, 2 job_pods_in_group,
      3 zone_frac (fraction of nodes in the inference zone),
      4 healthy_frac, 5 whole_free_nodes (count of fully-idle nodes)
    """
    gfeat = gfeat.astype(jnp.float32)
    job = job.astype(jnp.float32)
    free = gfeat[:, 0]
    total = jnp.maximum(gfeat[:, 1], EPS)
    pods_in_group = gfeat[:, 2]
    zone_frac = gfeat[:, 3]
    healthy_frac = gfeat[:, 4]
    whole_free = gfeat[:, 5]

    pack = 1.0 - jnp.clip(free / total, 0.0, 1.0)
    empty = jnp.clip(free / total, 0.0, 1.0)
    colocate = jnp.clip(pods_in_group, 0.0, 64.0) / 64.0
    zone = zone_frac
    health = healthy_frac
    # Whole-node fit: how well the group's fully-idle nodes cover the job's
    # whole-node demand (8-GPU boards), clamped to [0, 1].
    need_nodes = jnp.ceil(job[1] / 8.0)
    whole_fit = jnp.clip(whole_free / jnp.maximum(need_nodes, 1.0), 0.0, 1.0)
    return jnp.stack([pack, empty, colocate, zone, health, whole_fit], axis=1)


def group_feasible(gfeat: jnp.ndarray, job: jnp.ndarray) -> jnp.ndarray:
    """Group mask: some healthy capacity and enough free GPUs for one pod."""
    gfeat = gfeat.astype(jnp.float32)
    has_capacity = gfeat[:, 0] >= job[0]
    healthy = gfeat[:, 4] > 0.0
    return jnp.logical_and(has_capacity, healthy).astype(jnp.float32)


def score_groups_ref(
    gfeat: jnp.ndarray, job: jnp.ndarray, weights: jnp.ndarray
) -> jnp.ndarray:
    """Reference group scorer: ``[G, GROUP_F] x [JOB_D] x [GROUP_COMPONENTS] -> [G]``."""
    comps = group_components(gfeat, job)
    mask = group_feasible(gfeat, job)
    raw = comps @ weights.astype(jnp.float32)
    return mask * raw + (mask - 1.0) * BIG
