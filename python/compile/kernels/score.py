"""Layer-1 Pallas kernels: vectorized node/group scoring for Kant's RSCH.

The scheduler's per-cycle hot-spot (paper §3.4) is scoring every candidate
node (and, for two-level scheduling, every NodeNetGroup) against the job at
the head of the pipeline. These kernels compute all scores in one pass over a
dense feature matrix, blocked over the node axis so each block fits
comfortably in VMEM on a real TPU:

    grid = (ceil(N / BLOCK_N),)
    features block : [BLOCK_N, NODE_F] f32  ≈ 12 KiB at BLOCK_N=256
    job/weights    : replicated [1, 8] scalars-in-SMEM-shaped rows
    output block   : [BLOCK_N]              ≈ 1 KiB

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that round-trips through
the Rust loader. Numeric behaviour is identical to the ``ref.py`` oracles
(tested by pytest/hypothesis in ``python/tests``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import (
    BIG,
    EPS,
    GROUP_COMPONENTS,
    GROUP_F,
    JOB_D,
    NODE_F,
    NUM_COMPONENTS,
)

# Block size over the node axis. 256 rows x 12 features x 4 B = 12 KiB of
# VMEM per feature block — far under the ~16 MiB budget; chosen to keep the
# last-dim vector lanes full while letting the grid parallelize over blocks.
BLOCK_N = 256
BLOCK_G = 64


def _node_score_block(feat_ref, job_ref, w_ref, out_ref):
    """Score one [BLOCK_N, NODE_F] block of nodes (runs per grid step)."""
    feat = feat_ref[...]
    job = job_ref[...]  # [1, JOB_D]
    w = w_ref[...]  # [1, NUM_COMPONENTS]

    gpus_per_pod = job[0, 0]

    free = feat[:, 0]
    total = jnp.maximum(feat[:, 1], EPS)
    alloc = feat[:, 2]
    healthy = feat[:, 3]
    group_free = feat[:, 4]
    group_total = jnp.maximum(feat[:, 5], EPS)
    pods_on_node = feat[:, 6]
    topo_tier = feat[:, 8]
    in_zone = feat[:, 9]
    clique = feat[:, 11]

    fill_after = jnp.clip((alloc + gpus_per_pod) / total, 0.0, 1.0)
    spread = 1.0 - jnp.clip(alloc / total, 0.0, 1.0)
    group_pack = 1.0 - jnp.clip(group_free / group_total, 0.0, 1.0)
    group_empty = jnp.clip(group_free / group_total, 0.0, 1.0)
    topo = 1.0 - jnp.clip(topo_tier, 0.0, 4.0) / 4.0
    colocate = jnp.clip(pods_on_node, 0.0, 8.0) / 8.0
    zone = in_zone
    nvlink = (clique >= gpus_per_pod).astype(jnp.float32)

    # Weighted sum, kept as explicit FMA chain: one multiply-add per
    # component over the full vector block (VPU-shaped, no MXU involved).
    raw = (
        w[0, 0] * fill_after
        + w[0, 1] * spread
        + w[0, 2] * group_pack
        + w[0, 3] * group_empty
        + w[0, 4] * topo
        + w[0, 5] * colocate
        + w[0, 6] * zone
        + w[0, 7] * nvlink
    )

    mask = jnp.logical_and(healthy > 0.5, free >= gpus_per_pod).astype(jnp.float32)
    out_ref[...] = mask * raw + (mask - 1.0) * BIG


def _group_score_block(gfeat_ref, job_ref, w_ref, out_ref):
    """Score one [BLOCK_G, GROUP_F] block of NodeNetGroups."""
    gfeat = gfeat_ref[...]
    job = job_ref[...]
    w = w_ref[...]

    free = gfeat[:, 0]
    total = jnp.maximum(gfeat[:, 1], EPS)
    pods_in_group = gfeat[:, 2]
    zone_frac = gfeat[:, 3]
    healthy_frac = gfeat[:, 4]
    whole_free = gfeat[:, 5]

    pack = 1.0 - jnp.clip(free / total, 0.0, 1.0)
    empty = jnp.clip(free / total, 0.0, 1.0)
    colocate = jnp.clip(pods_in_group, 0.0, 64.0) / 64.0
    need_nodes = jnp.ceil(job[0, 1] / 8.0)
    whole_fit = jnp.clip(whole_free / jnp.maximum(need_nodes, 1.0), 0.0, 1.0)

    raw = (
        w[0, 0] * pack
        + w[0, 1] * empty
        + w[0, 2] * colocate
        + w[0, 3] * zone_frac
        + w[0, 4] * healthy_frac
        + w[0, 5] * whole_fit
    )
    mask = jnp.logical_and(free >= job[0, 0], healthy_frac > 0.0).astype(jnp.float32)
    out_ref[...] = mask * raw + (mask - 1.0) * BIG


def _pad_rows(n: int, block: int) -> int:
    return ((n + block - 1) // block) * block


@functools.partial(jax.jit, static_argnames=("block_n",))
def score_nodes(
    feat: jnp.ndarray,
    job: jnp.ndarray,
    weights: jnp.ndarray,
    block_n: int = BLOCK_N,
) -> jnp.ndarray:
    """Pallas node scorer. ``feat [N, NODE_F]``, ``job [JOB_D]``,
    ``weights [NUM_COMPONENTS]`` → ``scores [N]``.

    N is padded up to a multiple of ``block_n`` with infeasible (unhealthy)
    rows; padding rows score ``-BIG`` and are sliced off before returning.
    """
    n = feat.shape[0]
    padded = _pad_rows(max(n, 1), block_n)
    feat = jnp.pad(feat.astype(jnp.float32), ((0, padded - n), (0, 0)))
    job2 = job.astype(jnp.float32).reshape(1, JOB_D)
    w2 = weights.astype(jnp.float32).reshape(1, NUM_COMPONENTS)

    grid = (padded // block_n,)
    out = pl.pallas_call(
        _node_score_block,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, NODE_F), lambda i: (i, 0)),
            pl.BlockSpec((1, JOB_D), lambda i: (0, 0)),
            pl.BlockSpec((1, NUM_COMPONENTS), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.float32),
        interpret=True,
    )(feat, job2, w2)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("block_g",))
def score_groups(
    gfeat: jnp.ndarray,
    job: jnp.ndarray,
    weights: jnp.ndarray,
    block_g: int = BLOCK_G,
) -> jnp.ndarray:
    """Pallas group scorer. ``gfeat [G, GROUP_F]`` → ``scores [G]``."""
    g = gfeat.shape[0]
    padded = _pad_rows(max(g, 1), block_g)
    gfeat = jnp.pad(gfeat.astype(jnp.float32), ((0, padded - g), (0, 0)))
    job2 = job.astype(jnp.float32).reshape(1, JOB_D)
    w2 = weights.astype(jnp.float32).reshape(1, GROUP_COMPONENTS)

    grid = (padded // block_g,)
    out = pl.pallas_call(
        _group_score_block,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_g, GROUP_F), lambda i: (i, 0)),
            pl.BlockSpec((1, JOB_D), lambda i: (0, 0)),
            pl.BlockSpec((1, GROUP_COMPONENTS), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_g,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.float32),
        interpret=True,
    )(gfeat, job2, w2)
    return out[:g]
