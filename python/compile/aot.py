"""AOT lowering: JAX scoring pipelines -> HLO *text* artifacts for Rust.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` and NOT
a serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction
ids which the ``xla`` crate's bundled xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids, so text
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (written to ``artifacts/``, consumed by ``rust/src/runtime``):

  node_scorer_<N>.hlo.txt   score_and_rank  over N in NODE_SIZES
  group_scorer_<G>.hlo.txt  score_groups    over G in GROUP_SIZES
  manifest.json             shapes + feature-layout version for the loader

Run once via ``make artifacts``; Python never appears on the request path.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.ref import GROUP_COMPONENTS, GROUP_F, JOB_D, NODE_F, NUM_COMPONENTS

# Pool sizes the Rust loader can pick from; it chooses the smallest artifact
# with capacity >= the live node count and pads with unhealthy rows.
NODE_SIZES = (256, 1024, 4096)
GROUP_SIZES = (128,)

LAYOUT_VERSION = 1


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_node_scorer(n: int) -> str:
    feat = jax.ShapeDtypeStruct((n, NODE_F), jnp.float32)
    job = jax.ShapeDtypeStruct((JOB_D,), jnp.float32)
    w = jax.ShapeDtypeStruct((NUM_COMPONENTS,), jnp.float32)
    return to_hlo_text(jax.jit(model.score_and_rank).lower(feat, job, w))


def lower_group_scorer(g: int) -> str:
    gfeat = jax.ShapeDtypeStruct((g, GROUP_F), jnp.float32)
    job = jax.ShapeDtypeStruct((JOB_D,), jnp.float32)
    w = jax.ShapeDtypeStruct((GROUP_COMPONENTS,), jnp.float32)
    return to_hlo_text(jax.jit(model.score_groups_model).lower(gfeat, job, w))


def fusion_report(hlo_text: str) -> dict:
    """Crude HLO-level cost signals for the perf log (EXPERIMENTS.md §Perf)."""
    lines = hlo_text.splitlines()
    ops = [ln.strip() for ln in lines if "=" in ln and not ln.strip().startswith("//")]
    kinds: dict[str, int] = {}
    import re

    op_re = re.compile(r"([a-z][a-z0-9_-]*)\(")
    for ln in ops:
        rhs = ln.split("=", 1)[1]
        m = op_re.search(rhs)
        if not m:
            continue
        kinds[m.group(1)] = kinds.get(m.group(1), 0) + 1
    return {
        "total_instructions": len(ops),
        "fusions": kinds.get("fusion", 0),
        "sorts": kinds.get("sort", 0),
        "broadcasts": kinds.get("broadcast", 0),
        "kinds": kinds,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="AOT-lower Kant scorers to HLO text")
    ap.add_argument("--out-dir", default=None, help="artifacts directory")
    ap.add_argument("--out", default=None, help="(compat) single-artifact path stem")
    args = ap.parse_args(argv)

    repo_root = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    out_dir = args.out_dir or os.path.join(repo_root, "artifacts")
    if args.out:
        out_dir = os.path.dirname(os.path.abspath(args.out)) or out_dir
    os.makedirs(out_dir, exist_ok=True)

    manifest = {
        "layout_version": LAYOUT_VERSION,
        "node_f": NODE_F,
        "group_f": GROUP_F,
        "job_d": JOB_D,
        "num_components": NUM_COMPONENTS,
        "group_components": GROUP_COMPONENTS,
        "node_scorers": [],
        "group_scorers": [],
        "fusion_reports": {},
    }

    for n in NODE_SIZES:
        text = lower_node_scorer(n)
        name = f"node_scorer_{n}.hlo.txt"
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        manifest["node_scorers"].append({"n": n, "file": name})
        manifest["fusion_reports"][name] = fusion_report(text)
        print(f"wrote {name} ({len(text)} chars)")

    for g in GROUP_SIZES:
        text = lower_group_scorer(g)
        name = f"group_scorer_{g}.hlo.txt"
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        manifest["group_scorers"].append({"g": g, "file": name})
        manifest["fusion_reports"][name] = fusion_report(text)
        print(f"wrote {name} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json to {out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
