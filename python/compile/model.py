"""Layer-2 JAX model: Kant's scoring pipelines, composed from L1 kernels.

The scheduler paper's analogue of a model forward pass is the per-cycle
scoring computation: given the cluster snapshot (dense node/group feature
matrices) and the job at the head of the scheduling pipeline, produce the
score vector the selector consumes. This module is the single source the AOT
path (``aot.py``) lowers to HLO text; it is never imported at runtime.

Entry points (all fixed-shape for AOT):

  - :func:`score_nodes_model`   — [N, NODE_F] x [JOB_D] x [C]  -> [N]
  - :func:`score_groups_model`  — [G, GROUP_F] x [JOB_D] x [Cg] -> [G]
  - :func:`score_nodes_batch`   — vmapped node scorer for a queue of B jobs
  - :func:`score_and_rank`      — fused scores + descending rank permutation,
    saving the Rust side a full sort on the hot path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import score as kernels
from .kernels.ref import GROUP_COMPONENTS, GROUP_F, JOB_D, NODE_F, NUM_COMPONENTS

__all__ = [
    "score_nodes_model",
    "score_groups_model",
    "score_nodes_batch",
    "score_and_rank",
    "NODE_F",
    "GROUP_F",
    "JOB_D",
    "NUM_COMPONENTS",
    "GROUP_COMPONENTS",
]


def score_nodes_model(
    feat: jnp.ndarray, job: jnp.ndarray, weights: jnp.ndarray
) -> jnp.ndarray:
    """Score every node for one job (L1 Pallas kernel under the hood)."""
    return kernels.score_nodes(feat, job, weights)


def score_groups_model(
    gfeat: jnp.ndarray, job: jnp.ndarray, weights: jnp.ndarray
) -> jnp.ndarray:
    """Score every NodeNetGroup for one job (two-level stage 1)."""
    return kernels.score_groups(gfeat, job, weights)


def score_nodes_batch(
    feat: jnp.ndarray, jobs: jnp.ndarray, weights: jnp.ndarray
) -> jnp.ndarray:
    """Score every node for a batch of jobs: ``[B, JOB_D] x [B, C] -> [B, N]``.

    The feature matrix is shared across the batch (one snapshot, many queued
    jobs) — this is RSCH's multi-job cycle in a single XLA launch.
    """
    return jax.vmap(lambda j, w: kernels.score_nodes(feat, j, w))(jobs, weights)


def score_and_rank(
    feat: jnp.ndarray, job: jnp.ndarray, weights: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Scores plus the descending-score permutation (stable, index tiebreak).

    Returns ``(scores [N] f32, order [N] i32)`` where ``order[0]`` is the
    best node index. Sorting inside XLA keeps the Rust hot path allocation-
    free: it walks ``order`` until it finds a node that passes the exact
    (non-vectorizable) device-level checks.
    """
    scores = kernels.score_nodes(feat, job, weights)
    order = jnp.argsort(-scores, stable=True).astype(jnp.int32)
    return scores, order
