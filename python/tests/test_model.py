"""L2 model tests: shapes, batching, and the fused score_and_rank pipeline."""

from __future__ import annotations

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from .test_kernel import (
    WEIGHTS_EBINPACK,
    GROUP_W,
    make_group_features,
    make_job,
    make_node_features,
)

RNG = np.random.default_rng(7)


class TestShapes:
    def test_node_scorer_shape(self):
        feat = make_node_features(333, RNG)
        out = np.asarray(model.score_nodes_model(feat, make_job(2.0), WEIGHTS_EBINPACK))
        assert out.shape == (333,) and out.dtype == np.float32

    def test_group_scorer_shape(self):
        gfeat = make_group_features(50, RNG)
        out = np.asarray(model.score_groups_model(gfeat, make_job(8.0), GROUP_W))
        assert out.shape == (50,) and out.dtype == np.float32

    def test_batch_shape(self):
        feat = make_node_features(256, RNG)
        jobs = np.stack([make_job(g) for g in (1.0, 2.0, 4.0, 8.0)])
        ws = np.tile(WEIGHTS_EBINPACK, (4, 1))
        out = np.asarray(model.score_nodes_batch(feat, jobs, ws))
        assert out.shape == (4, 256)


class TestBatchMatchesSingle:
    def test_batch_rows_equal_single_calls(self):
        feat = make_node_features(200, RNG)
        jobs = np.stack([make_job(g) for g in (1.0, 4.0, 8.0)])
        ws = np.tile(WEIGHTS_EBINPACK, (3, 1))
        batch = np.asarray(model.score_nodes_batch(feat, jobs, ws))
        for i in range(3):
            single = np.asarray(model.score_nodes_model(feat, jobs[i], ws[i]))
            np.testing.assert_allclose(batch[i], single, rtol=1e-5, atol=1e-5)


class TestScoreAndRank:
    def test_order_is_descending_permutation(self):
        feat = make_node_features(512, RNG)
        scores, order = model.score_and_rank(feat, make_job(4.0), WEIGHTS_EBINPACK)
        scores, order = np.asarray(scores), np.asarray(order)
        assert sorted(order.tolist()) == list(range(512))
        ranked = scores[order]
        assert (np.diff(ranked) <= 1e-6).all()

    def test_best_index_matches_ref_argmax(self):
        feat = make_node_features(512, RNG)
        job = make_job(2.0)
        _, order = model.score_and_rank(feat, job, WEIGHTS_EBINPACK)
        want = np.asarray(ref.score_nodes_ref(feat, job, WEIGHTS_EBINPACK))
        best = int(np.asarray(order)[0])
        assert want[best] == want.max()

    def test_stable_tiebreak_by_index(self):
        # Identical nodes -> identical scores -> order must be by index.
        feat = np.tile(make_node_features(1, RNG), (16, 1))
        feat[:, 3] = 1.0
        feat[:, 0] = 8.0
        _, order = model.score_and_rank(feat, make_job(1.0), WEIGHTS_EBINPACK)
        assert np.asarray(order).tolist() == list(range(16))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=300),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
)
def test_hypothesis_rank_consistent_with_scores(n, seed):
    rng = np.random.default_rng(seed)
    feat = make_node_features(n, rng)
    scores, order = model.score_and_rank(feat, make_job(2.0), WEIGHTS_EBINPACK)
    scores, order = np.asarray(scores), np.asarray(order)
    ranked = scores[order]
    assert (np.diff(ranked) <= 1e-5).all()
    assert sorted(order.tolist()) == list(range(n))
