"""AOT path tests: lowering to HLO text, manifest integrity, executability.

The executability check compiles the emitted HLO text back through the local
CPU PJRT client and compares numerics against the oracle — the same
round-trip the Rust runtime performs.
"""

from __future__ import annotations

import json
import os

import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref
from .test_kernel import WEIGHTS_EBINPACK, make_job, make_node_features

RNG = np.random.default_rng(3)


class TestLowering:
    def test_node_scorer_lowers_to_hlo_text(self):
        text = aot.lower_node_scorer(256)
        assert "HloModule" in text
        assert "f32[256,12]" in text  # parameter shape is frozen in the artifact

    def test_group_scorer_lowers_to_hlo_text(self):
        text = aot.lower_group_scorer(128)
        assert "HloModule" in text
        assert "f32[128,6]" in text

    def test_fusion_report_counts_instructions(self):
        text = aot.lower_node_scorer(256)
        rep = aot.fusion_report(text)
        assert rep["total_instructions"] > 0
        assert rep["sorts"] >= 1  # score_and_rank embeds the argsort


class TestManifest:
    def test_main_writes_all_artifacts(self, tmp_path):
        rc = aot.main(["--out-dir", str(tmp_path)])
        assert rc == 0
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert manifest["node_f"] == ref.NODE_F
        assert manifest["job_d"] == ref.JOB_D
        for entry in manifest["node_scorers"]:
            assert (tmp_path / entry["file"]).exists()
        for entry in manifest["group_scorers"]:
            assert (tmp_path / entry["file"]).exists()
        assert {e["n"] for e in manifest["node_scorers"]} == set(aot.NODE_SIZES)


class TestRoundTrip:
    """Compile the HLO text on the CPU PJRT client and check numerics."""

    def _run_hlo(self, text: str, args):
        from jax._src.lib import xla_client as xc

        client = xc.make_cpu_client()
        # Parse text back into an XlaComputation via the HLO parser.
        comp = xc._xla.hlo_module_from_text(text)
        exe = client.compile(
            xc.XlaComputation(comp.as_serialized_hlo_module_proto()).as_serialized_hlo_module_proto()
            if False
            else xc.XlaComputation(comp.as_serialized_hlo_module_proto())
        )
        bufs = [client.buffer_from_pyval(a) for a in args]
        out = exe.execute(bufs)
        return [np.asarray(o) for o in out]

    @pytest.mark.parametrize("n", [256])
    def test_node_scorer_roundtrip_matches_ref(self, n):
        text = aot.lower_node_scorer(n)
        feat = make_node_features(n, RNG)
        job = make_job(4.0)
        try:
            outs = self._run_hlo(text, [feat, job, WEIGHTS_EBINPACK])
        except Exception as exc:  # pragma: no cover - environment-dependent API
            pytest.skip(f"local PJRT round-trip API unavailable: {exc}")
        want_scores = np.asarray(ref.score_nodes_ref(feat, job, WEIGHTS_EBINPACK))
        # return_tuple=True -> flat list [scores, order]
        got_scores = outs[0].reshape(-1)[:n]
        np.testing.assert_allclose(got_scores, want_scores, rtol=1e-4, atol=1e-4)
