"""L1 correctness: Pallas scoring kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (including non-multiple-of-block sizes), dtypes and
feature values; assert_allclose against ref.py is the core signal.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import score as K

RNG = np.random.default_rng(0)


def make_node_features(n: int, rng: np.random.Generator) -> np.ndarray:
    total = rng.choice([1.0, 4.0, 8.0], size=n)
    alloc = np.floor(rng.uniform(0, total + 1))
    alloc = np.minimum(alloc, total)
    free = total - alloc
    healthy = (rng.uniform(size=n) > 0.1).astype(np.float32)
    group_total = np.full(n, 8.0 * 32)
    group_free = np.floor(rng.uniform(0, group_total + 1))
    pods_on_node = np.floor(rng.uniform(0, 9, size=n))
    pods_in_group = pods_on_node + np.floor(rng.uniform(0, 4, size=n))
    topo_tier = rng.choice([0.0, 1.0, 2.0, 3.0, 4.0], size=n)
    in_zone = (rng.uniform(size=n) > 0.7).astype(np.float32)
    hbd_free = np.floor(rng.uniform(0, 64, size=n))
    clique = np.floor(rng.uniform(0, free + 1))
    feat = np.stack(
        [free, total, alloc, healthy, group_free, group_total, pods_on_node,
         pods_in_group, topo_tier, in_zone, hbd_free, clique],
        axis=1,
    ).astype(np.float32)
    assert feat.shape == (n, ref.NODE_F)
    return feat


def make_job(gpus_per_pod: float, pods: float = 4.0, inference: bool = False) -> np.ndarray:
    return np.array(
        [gpus_per_pod, gpus_per_pod * pods, 1.0, float(inference),
         float(gpus_per_pod >= 8), 2.0, 0.0, 0.0],
        dtype=np.float32,
    )


def make_group_features(g: int, rng: np.random.Generator) -> np.ndarray:
    total = np.full(g, 256.0)
    free = np.floor(rng.uniform(0, total + 1))
    pods = np.floor(rng.uniform(0, 16, size=g))
    zone = rng.uniform(size=g).astype(np.float32)
    healthy = rng.uniform(0.5, 1.0, size=g).astype(np.float32)
    whole = np.floor(rng.uniform(0, 33, size=g))
    return np.stack([free, total, pods, zone, healthy, whole], axis=1).astype(np.float32)


WEIGHTS_EBINPACK = np.array([1.0, 0.0, 0.6, 0.0, 0.5, 0.8, -0.3, 0.2], np.float32)
WEIGHTS_SPREAD = np.array([0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.5, 0.1], np.float32)
GROUP_W = np.array([1.0, 0.0, 0.5, -0.2, 0.3, 0.4], np.float32)


class TestNodeScorerVsRef:
    @pytest.mark.parametrize("n", [1, 7, 64, 255, 256, 257, 1024, 1500])
    def test_sizes(self, n):
        feat = make_node_features(n, RNG)
        job = make_job(4.0)
        got = np.asarray(K.score_nodes(feat, job, WEIGHTS_EBINPACK))
        want = np.asarray(ref.score_nodes_ref(feat, job, WEIGHTS_EBINPACK))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("gpp", [1.0, 2.0, 4.0, 8.0])
    def test_gpus_per_pod(self, gpp):
        feat = make_node_features(300, RNG)
        job = make_job(gpp)
        got = np.asarray(K.score_nodes(feat, job, WEIGHTS_SPREAD))
        want = np.asarray(ref.score_nodes_ref(feat, job, WEIGHTS_SPREAD))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_infeasible_nodes_score_below_any_feasible(self):
        feat = make_node_features(512, RNG)
        job = make_job(4.0)
        scores = np.asarray(K.score_nodes(feat, job, WEIGHTS_EBINPACK))
        feas = np.asarray(ref.node_feasible(feat, job)) > 0.5
        if feas.any() and (~feas).any():
            assert scores[~feas].max() < scores[feas].min()

    def test_all_infeasible(self):
        feat = make_node_features(64, RNG)
        feat[:, 3] = 0.0  # all unhealthy
        job = make_job(1.0)
        scores = np.asarray(K.score_nodes(feat, job, WEIGHTS_EBINPACK))
        assert (scores <= -ref.BIG + 1e3).all()

    def test_block_size_invariance(self):
        feat = make_node_features(700, RNG)
        job = make_job(2.0)
        a = np.asarray(K.score_nodes(feat, job, WEIGHTS_EBINPACK, block_n=128))
        b = np.asarray(K.score_nodes(feat, job, WEIGHTS_EBINPACK, block_n=512))
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


class TestGroupScorerVsRef:
    @pytest.mark.parametrize("g", [1, 5, 63, 64, 65, 128])
    def test_sizes(self, g):
        gfeat = make_group_features(g, RNG)
        job = make_job(8.0, pods=32.0)
        got = np.asarray(K.score_groups(gfeat, job, GROUP_W))
        want = np.asarray(ref.score_groups_ref(gfeat, job, GROUP_W))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_empty_group_infeasible_for_big_pod(self):
        gfeat = make_group_features(16, RNG)
        gfeat[:, 0] = 0.0  # no free GPUs anywhere
        job = make_job(8.0)
        scores = np.asarray(K.score_groups(gfeat, job, GROUP_W))
        assert (scores <= -ref.BIG + 1e3).all()


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=600),
    gpp=st.sampled_from([1.0, 2.0, 4.0, 8.0]),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
    wseed=st.integers(min_value=0, max_value=2**32 - 1),
)
def test_hypothesis_node_scorer_matches_ref(n, gpp, seed, wseed):
    rng = np.random.default_rng(seed)
    feat = make_node_features(n, rng)
    job = make_job(gpp)
    w = np.random.default_rng(wseed).uniform(-1, 1, ref.NUM_COMPONENTS).astype(np.float32)
    got = np.asarray(K.score_nodes(feat, job, w))
    want = np.asarray(ref.score_nodes_ref(feat, job, w))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=30, deadline=None)
@given(
    g=st.integers(min_value=1, max_value=200),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
)
def test_hypothesis_group_scorer_matches_ref(g, seed):
    rng = np.random.default_rng(seed)
    gfeat = make_group_features(g, rng)
    job = make_job(4.0, pods=8.0)
    w = rng.uniform(-1, 1, ref.GROUP_COMPONENTS).astype(np.float32)
    got = np.asarray(K.score_groups(gfeat, job, w))
    want = np.asarray(ref.score_groups_ref(gfeat, job, w))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_hypothesis_extreme_values_finite(seed):
    """Scores stay finite even for degenerate features (zero totals etc.)."""
    rng = np.random.default_rng(seed)
    feat = make_node_features(128, rng)
    feat[:, 1] = rng.choice([0.0, 8.0], size=128)  # some zero-GPU nodes
    feat[:, 5] = rng.choice([0.0, 256.0], size=128)
    job = make_job(4.0)
    scores = np.asarray(K.score_nodes(feat, job, WEIGHTS_EBINPACK))
    assert np.isfinite(scores).all()
