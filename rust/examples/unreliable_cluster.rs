//! Reliability: the unreliable cluster.
//!
//! A 256-GPU training cluster (2-node HBD domains) weathers a seeded
//! storm of GPU, node and HBD/switch failures plus maintenance drains.
//! The report compares the fault-free ceiling, naive restart-from-scratch
//! recovery, interval checkpointing with requeue priority aging (swept
//! across checkpoint intervals), and a hardened arm that adds hot spare
//! nodes — on goodput GPU-hours, effective GAR, lost work, and the p99
//! completion inflation restarts cause.
//!
//! Run with: `cargo run --release --example unreliable_cluster [seed [days]]`

use kant::experiments::{fault_tolerance, run_fault_tolerance};
use kant::metrics::report::pct;
use kant::sim::SimOutcome;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let seed: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(42);
    let days: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.0);

    if days > 0.0 {
        // Custom-length run: print the raw arm summaries.
        let c = run_fault_tolerance(seed, days);
        let mut arms: Vec<(String, &SimOutcome)> = vec![
            ("no faults".into(), &c.no_faults),
            ("naive restart".into(), &c.naive),
        ];
        for (i, o) in &c.checkpointed {
            arms.push((format!("ckpt {}m + aging", i / 60_000), o));
        }
        arms.push(("ckpt 15m + aging + spares".into(), &c.hardened));
        for (name, o) in arms {
            let r = &o.metrics.reliability;
            println!(
                "{name:>26}: goodput {:>6.0} GPU-h eff-GAR {} goodput-frac {} \
                 lost {:>5.1} GPU-h evictions {:>3} inflation-p99 {:.2} done/stuck {}/{}",
                r.goodput_gpu_hours(),
                pct(o.metrics.effective_gar()),
                pct(o.metrics.goodput_fraction()),
                r.lost_gpu_hours(),
                r.fault_evictions,
                r.inflation_summary().p99,
                o.metrics.jobs_finished,
                o.unfinished_jobs,
            );
        }
    } else {
        // The standard 2-day figures report.
        println!("{}", fault_tolerance(seed));
    }
}
