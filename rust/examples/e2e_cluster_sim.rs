//! END-TO-END validation driver (DESIGN.md §5, EXPERIMENTS.md): proves the
//! full three-layer stack composes —
//!
//!   L1 Pallas scoring kernel  →  L2 JAX pipeline  →  `make artifacts`
//!   (HLO text)  →  Rust PJRT runtime  →  RSCH hot path  →  QSCH →
//!   discrete-event cluster simulation  →  the paper's metric table.
//!
//! The XLA scorer serves *every* node/group scoring call on the scheduling
//! hot path; the same run is repeated with the native Rust scorer and the
//! two must agree decision-for-decision (bitwise-equal metrics), which is
//! the strongest composition check available.
//!
//! Run with: `cargo run --release --example e2e_cluster_sim`
//! (requires `make artifacts` first)

use kant::config::{training_cluster, Scale};
use kant::experiments::jwtd_buckets;
use kant::job::workload::WorkloadGen;
use kant::metrics::report::{bucket_comparison, fmt_ms, pct, table};
use kant::qsch::Qsch;
use kant::rsch::{Rsch, RschConfig};
use kant::runtime::XlaBackend;
use kant::sim::{run, SimConfig};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let seed = 7;
    // A real workload slice on the small-scale training cluster: 1,024
    // GPUs, ~12 simulated hours at 95% offered load.
    let mut env = training_cluster(Scale::Small, seed, 0.95);
    env.horizon_ms = 12 * 3_600_000;
    let jobs = WorkloadGen::new(env.workload.clone()).generate_until(env.horizon_ms);
    let sim_cfg = SimConfig {
        horizon_ms: env.horizon_ms + 12 * 3_600_000,
        ..SimConfig::default()
    };
    println!(
        "e2e: {} nodes / {} GPUs, {} jobs over {}",
        env.state.nodes.len(),
        env.state.total_gpus(),
        jobs.len(),
        fmt_ms(env.horizon_ms as f64)
    );

    // ---- Arm 1: XLA scorer on the hot path ----
    let mut backend = XlaBackend::new("artifacts")
        .map_err(|e| anyhow::anyhow!("{e:#}\nrun `make artifacts` first"))?;
    backend.warmup()?;
    let mut state = env.state.clone();
    let mut qsch = Qsch::new(kant::qsch::policy::QschConfig::default(), env.ledger.clone());
    let mut rsch = Rsch::with_backend(RschConfig::default(), &state, Box::new(backend));
    let t0 = Instant::now();
    let xla_out = run(&mut state, &mut qsch, &mut rsch, jobs.clone(), &sim_cfg);
    let xla_wall = t0.elapsed();
    println!(
        "xla arm: {} in {:.1}s wall ({} nodes scored, backend={})",
        "done",
        xla_wall.as_secs_f64(),
        xla_out.rsch_stats.nodes_scored,
        rsch.backend_name(),
    );

    // ---- Arm 2: native scorer, identical inputs ----
    let mut state2 = env.state.clone();
    let mut qsch2 = Qsch::new(kant::qsch::policy::QschConfig::default(), env.ledger.clone());
    let mut rsch2 = Rsch::new(RschConfig::default(), &state2);
    let t0 = Instant::now();
    let native_out = run(&mut state2, &mut qsch2, &mut rsch2, jobs, &sim_cfg);
    let native_wall = t0.elapsed();
    println!(
        "native arm: done in {:.1}s wall ({} nodes scored)",
        native_wall.as_secs_f64(),
        native_out.rsch_stats.nodes_scored
    );

    // ---- The paper's headline metric table ----
    let rows = vec![
        vec![
            "xla-scorer".to_string(),
            pct(xla_out.metrics.gar_median(200)),
            pct(xla_out.metrics.sor_final()),
            pct(xla_out.metrics.gfr_avg()),
            xla_out.metrics.jobs_finished.to_string(),
            format!("{:.1}s", xla_wall.as_secs_f64()),
        ],
        vec![
            "native-scorer".to_string(),
            pct(native_out.metrics.gar_median(200)),
            pct(native_out.metrics.sor_final()),
            pct(native_out.metrics.gfr_avg()),
            native_out.metrics.jobs_finished.to_string(),
            format!("{:.1}s", native_wall.as_secs_f64()),
        ],
    ];
    println!(
        "{}",
        table(
            "E2E — full-stack run, XLA vs native scorer (must agree)",
            &["scorer", "GAR", "SOR", "GFR", "finished", "wall"],
            &rows
        )
    );
    let arms = vec![
        (
            "xla",
            jwtd_buckets(&xla_out.store, xla_out.end_ms).summaries(),
        ),
        (
            "native",
            jwtd_buckets(&native_out.store, native_out.end_ms).summaries(),
        ),
    ];
    println!("{}", bucket_comparison("JWTD by size", &arms, fmt_ms));

    // Decision-level agreement: same schedule ⇒ identical metrics.
    let agree = (xla_out.metrics.sor_final() - native_out.metrics.sor_final()).abs() < 1e-9
        && xla_out.metrics.jobs_finished == native_out.metrics.jobs_finished
        && xla_out.end_ms == native_out.end_ms;
    println!("scorer-parity (decision-identical runs): {agree}");
    anyhow::ensure!(agree, "XLA and native scorers diverged");
    println!("E2E OK");
    Ok(())
}
