//! §5.2 multi-tenant inference clusters: quota management on the
//! heterogeneous i2 cluster (Figures 10-12), its GAR/SOR/GFR time series
//! (Figures 13-14), and the GFR-vs-scale comparison (Figure 15).
//!
//! Run with: `cargo run --release --example inference_cluster`

use kant::experiments::{fig10_11_12, fig13_14, fig15};

fn main() {
    let seed = 42;
    println!("{}", fig10_11_12(seed));
    println!("{}", fig13_14(seed));
    println!("{}", fig15(seed));
}
