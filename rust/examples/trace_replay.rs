//! Trace record/replay: generate a workload, save it as JSONL, replay it
//! through two different scheduler configurations on identical inputs —
//! the mechanism every A/B figure in the evaluation relies on.
//!
//! Run with: `cargo run --release --example trace_replay`

use kant::config::{Scale, SimOptions};
use kant::experiments::{run_arm, Arm};
use kant::job::trace::{read_trace, write_trace};
use kant::job::workload::WorkloadGen;
use kant::metrics::report::{pct, table};
use kant::qsch::Qsch;
use kant::rsch::Rsch;
use kant::sim::{run, SimConfig};

fn main() -> anyhow::Result<()> {
    // The builder is the single constructor of environments + configs; the
    // replay below still overrides the horizon for a quick run.
    let setup = SimOptions::for_scale(Scale::Small).seed(11).rho(0.9).build()?;
    let mut env = setup.env;
    env.horizon_ms = 6 * 3_600_000;

    // 1. Generate + persist the trace.
    let jobs = WorkloadGen::new(env.workload.clone()).generate_until(env.horizon_ms);
    let dir = std::env::temp_dir().join("kant_trace_example");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("workload.jsonl");
    write_trace(&path, &jobs)?;
    println!("wrote {} jobs to {}", jobs.len(), path.display());

    // 2. Read it back — byte-faithful.
    let replayed = read_trace(&path)?;
    assert_eq!(replayed, jobs, "trace roundtrip must be lossless");

    // 3. Replay under two arms on the identical input.
    let sim = SimConfig {
        horizon_ms: env.horizon_ms + 12 * 3_600_000,
        ..setup.sim
    };
    let mut rows = Vec::new();
    for arm in [Arm::native_baseline(), Arm::kant_ebinpack()] {
        let mut state = env.state.clone();
        let mut qsch = Qsch::new(arm.qsch.clone(), env.ledger.clone());
        let mut rsch = Rsch::new(arm.rsch.clone(), &state);
        let out = run(&mut state, &mut qsch, &mut rsch, replayed.clone(), &sim);
        rows.push(vec![
            arm.label.to_string(),
            pct(out.metrics.gar_median(200)),
            pct(out.metrics.sor_final()),
            pct(out.metrics.gfr_avg()),
            out.metrics.jobs_finished.to_string(),
        ]);
    }
    println!(
        "{}",
        table(
            "same trace, two schedulers",
            &["arm", "GAR", "SOR", "GFR", "finished"],
            &rows
        )
    );

    // 4. Determinism: replaying the same arm twice is bit-identical.
    let a = run_arm(&env, &Arm::kant_ebinpack(), &sim);
    let b = run_arm(&env, &Arm::kant_ebinpack(), &sim);
    assert_eq!(a.metrics.jobs_finished, b.metrics.jobs_finished);
    assert!((a.metrics.sor_final() - b.metrics.sor_final()).abs() < 1e-15);
    println!("determinism check OK");
    Ok(())
}
