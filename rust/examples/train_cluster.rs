//! §5.1 large-scale training cluster experiment: Kant (Backfill +
//! E-Binpack + two-level + incremental snapshots) vs the native baseline
//! (Strict FIFO + spread-like placement), on the Figure-2 workload.
//!
//! Run with:
//!   cargo run --release --example train_cluster            (small scale)
//!   cargo run --release --example train_cluster -- paper   (8,192 GPUs)

use kant::config::{training_cluster, Scale};
use kant::experiments::{fig3, fig4, fig5, fig6, fig7, fig8, fig9, run_arm, Arm};
use kant::experiments::{EBinpackComparison, PolicyComparison};
use kant::sim::SimConfig;

fn main() {
    let scale = if std::env::args().any(|a| a == "paper") {
        Scale::Paper
    } else {
        Scale::Small
    };
    let seed = 42;

    eprintln!("== policy comparison (Backfill vs Strict vs Best-Effort) ==");
    let env = training_cluster(scale, seed, 0.98);
    let sim = SimConfig::default();
    let policy = PolicyComparison {
        strict: run_arm(&env, &Arm::kant_strict(), &sim),
        backfill: run_arm(&env, &Arm::kant_backfill(), &sim),
        best_effort: run_arm(&env, &Arm::kant_best_effort(), &sim),
    };
    println!("{}", fig3(&policy));
    println!("{}", fig4(&policy));
    println!("{}", fig5(&policy));

    eprintln!("== E-Binpack vs native baseline ==");
    let env = training_cluster(scale, seed, 0.90);
    let ebp = EBinpackComparison {
        baseline: run_arm(&env, &Arm::native_baseline(), &sim),
        ebinpack: run_arm(&env, &Arm::kant_ebinpack(), &sim),
    };
    println!("{}", fig6(&ebp));
    println!("{}", fig7(&ebp));
    println!("{}", fig8(&ebp));
    println!("{}", fig9(&ebp));
}
