//! Quickstart: build a small cluster, submit a handful of jobs through the
//! public API (QSCH → RSCH), and read the paper's metrics.
//!
//! Run with: `cargo run --release --example quickstart`

use kant::cluster::builder::{ClusterBuilder, ClusterSpec};
use kant::cluster::ids::{GpuTypeId, JobId, TenantId};
use kant::cluster::tenant::{QuotaLedger, QuotaMode};
use kant::config::{Scale, SimOptions};
use kant::job::spec::{JobKind, JobSpec, Priority};
use kant::metrics::report::{fmt_ms, headline, pct};
use kant::qsch::Qsch;
use kant::rsch::Rsch;
use kant::sim::run;

fn main() {
    // A 2-spine × 2-group × 8-node cluster of 8-GPU boards = 256 GPUs.
    let mut state = ClusterBuilder::build(&ClusterSpec::homogeneous("quickstart", 2, 2, 8));
    println!(
        "cluster: {} nodes / {} GPUs / {} NodeNetGroups",
        state.nodes.len(),
        state.total_gpus(),
        state.fabric.num_groups()
    );

    // Two tenants with shared quotas.
    let mut ledger = QuotaLedger::new(2, 1, QuotaMode::Shared);
    ledger.set_limit(TenantId(0), GpuTypeId(0), 160);
    ledger.set_limit(TenantId(1), GpuTypeId(0), 96);

    // Kant defaults: Backfill queueing + E-Binpack placement + two-level
    // NodeNetGroup scheduling + incremental snapshots. `SimOptions` is the
    // one constructor for the scheduler configs — the same builder the CLI
    // flags (`--policy`, `--shards`, `--faults`, ...) adapt onto.
    let (qsch_cfg, rsch_cfg, sim_cfg) = SimOptions::for_scale(Scale::Small)
        .configs()
        .expect("default options are always valid");
    let mut qsch = Qsch::new(qsch_cfg, ledger);
    let mut rsch = Rsch::new(rsch_cfg, &state);

    // A mixed workload: one big distributed training gang, a few small
    // training jobs, and an HA inference deployment.
    let mut jobs = vec![
        JobSpec::homogeneous(JobId(1), TenantId(0), JobKind::Training, GpuTypeId(0), 8, 8)
            .with_times(0, 30 * 60_000)
            .with_priority(Priority::HIGH),
        JobSpec::homogeneous(JobId(2), TenantId(0), JobKind::Training, GpuTypeId(0), 1, 4)
            .with_times(10_000, 20 * 60_000),
        JobSpec::homogeneous(JobId(3), TenantId(1), JobKind::Training, GpuTypeId(0), 1, 2)
            .with_times(15_000, 10 * 60_000),
        JobSpec::homogeneous(JobId(4), TenantId(1), JobKind::Inference, GpuTypeId(0), 6, 1)
            .with_times(20_000, 60 * 60_000),
        JobSpec::homogeneous(JobId(5), TenantId(0), JobKind::Training, GpuTypeId(0), 16, 8)
            .with_times(30_000, 45 * 60_000),
    ];
    jobs.sort_by_key(|j| j.submit_ms);

    let out = run(&mut state, &mut qsch, &mut rsch, jobs, &sim_cfg);

    println!("{}", headline("quickstart", &out.metrics));
    for id in 1..=5u64 {
        let j = out.store.expect(JobId(id));
        println!(
            "job {id}: {:?} wait={} preemptions={} nodes={:?}",
            j.phase,
            fmt_ms(j.waiting_ms(out.end_ms) as f64),
            j.preemptions,
            state.nodes_of(JobId(id)).len()
        );
    }
    println!(
        "final: GAR {} SOR {} GFR {} (all jobs drained: {})",
        pct(out.metrics.gar_avg()),
        pct(out.metrics.sor_final()),
        pct(out.metrics.gfr_avg()),
        out.unfinished_jobs == 0
    );
}
