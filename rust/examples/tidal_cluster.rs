//! Elastic inference co-scheduling: the tidal cluster.
//!
//! Twelve diurnal inference services share 256 GPUs with a stream of
//! LOW-priority tidal training gangs. At night the autoscaler shrinks
//! the services to their floors and training backfills the freed
//! capacity; each morning SLO-pressure reclamation evicts the tidal
//! jobs so inference can scale back up. The report compares static
//! provisioning, elastic autoscaling, and elastic+tidal co-scheduling
//! on GAR, SLO violation rate, replica churn, and elastic-capacity
//! utilization.
//!
//! Run with: `cargo run --release --example tidal_cluster [seed [days]]`

use kant::experiments::{elastic_inference, run_elastic_inference};
use kant::metrics::report::pct;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let seed: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(42);
    let days: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.0);

    if days > 0.0 {
        // Custom-length run: print the raw arm summaries.
        let c = run_elastic_inference(seed, days);
        for (name, o) in [
            ("static", &c.static_arm),
            ("elastic", &c.elastic),
            ("elastic+tidal", &c.tidal),
        ] {
            let (a, b) = o.metrics.window();
            println!(
                "{name:>14}: GAR {} SLO-violation {} churn {} elastic-util {} \
                 slo-preempt {} done/cancelled/sub {}/{}/{}",
                pct(o.metrics.gar_avg()),
                pct(o.metrics.elastic.slo_violation_rate()),
                o.metrics.elastic.replica_churn(),
                pct(o.metrics.elastic.elastic_utilization(a, b)),
                o.qsch_stats.slo_pressure_preemptions,
                o.metrics.jobs_finished,
                o.metrics.jobs_cancelled,
                o.metrics.jobs_submitted,
            );
        }
    } else {
        // The standard 4-day figures report.
        println!("{}", elastic_inference(seed));
    }
}
