//! Minimal vendored subset of the `rand_core` 0.6 API: the [`RngCore`]
//! trait and its [`Error`] type. The offline build environment has no
//! crates.io access; kant's own PRNG (`kant::util::rng::Pcg32`) implements
//! this trait so downstream code written against `rand_core` interoperates.
//! Swap for the real crate by replacing the `path` dependency in
//! `rust/Cargo.toml` with a version requirement.

use std::fmt;

/// The core of a random number generator (rand_core 0.6 shape).
pub trait RngCore {
    fn next_u32(&mut self) -> u32;
    fn next_u64(&mut self) -> u64;
    fn fill_bytes(&mut self, dest: &mut [u8]);
    fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), Error>;
}

/// Error type for fallible RNG operations (infallible generators never
/// construct it).
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new<M: fmt::Display>(msg: M) -> Error {
        Error {
            msg: msg.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "RNG error: {}", self.msg)
    }
}

impl std::error::Error for Error {}

#[cfg(test)]
mod tests {
    use super::*;

    struct Counter(u64);

    impl RngCore for Counter {
        fn next_u32(&mut self) -> u32 {
            self.next_u64() as u32
        }
        fn next_u64(&mut self) -> u64 {
            self.0 += 1;
            self.0
        }
        fn fill_bytes(&mut self, dest: &mut [u8]) {
            for b in dest {
                *b = self.next_u32() as u8;
            }
        }
        fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), Error> {
            self.fill_bytes(dest);
            Ok(())
        }
    }

    #[test]
    fn trait_is_object_safe_and_usable() {
        let mut c = Counter(0);
        let rng: &mut dyn RngCore = &mut c;
        assert_eq!(rng.next_u64(), 1);
        let mut buf = [0u8; 3];
        rng.try_fill_bytes(&mut buf).unwrap();
        assert_eq!(buf, [2, 3, 4]);
    }

    #[test]
    fn error_displays() {
        assert_eq!(Error::new("x").to_string(), "RNG error: x");
    }
}
