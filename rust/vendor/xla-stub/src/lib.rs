//! Type-level stub of the `xla` (xla-rs) API surface `kant::runtime` uses.
//!
//! The real crate binds the PJRT C API and needs an XLA toolchain the
//! hermetic build environment does not provide. This stub keeps
//! `--features xla` type-checking (so the AOT-artifact path cannot rot)
//! while every runtime entry point returns a descriptive error. To actually
//! execute artifacts, point the `xla` path dependency in `rust/Cargo.toml`
//! at the real xla-rs crate — the API below mirrors its shapes.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error for every stubbed operation.
#[derive(Debug)]
pub struct Error {
    what: String,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "xla stub: {} unavailable (link the real xla crate; see rust/README.md)",
            self.what
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error {
        what: what.to_string(),
    })
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PJRT CPU client")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compilation")
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Mirrors xla-rs: returns per-device, per-output buffers.
    pub fn execute<L: Borrow<Literal>>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("execution")
    }
}

/// A device buffer produced by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("buffer readback")
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        unavailable("HLO text parsing")
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A host literal (stub holds no data).
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("reshape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("literal readback")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("untupling")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_stub() {
        assert!(PjRtClient::cpu().is_err());
        let e = Literal::vec1(&[1.0f32]).reshape(&[1, 1]).unwrap_err();
        assert!(e.to_string().contains("xla stub"));
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
    }
}
