//! Minimal vendored implementation of the `anyhow` API surface this
//! workspace uses: [`Error`], [`Result`], the [`Context`] extension trait,
//! and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! The offline build environment has no crates.io access, so this shim
//! keeps the crate hermetic. It is drop-in source-compatible for the calls
//! kant makes; to use the real `anyhow`, replace the `path` dependency in
//! `rust/Cargo.toml` with a version requirement.
//!
//! Differences from real anyhow: the error chain is flattened to strings at
//! construction (no downcasting, no backtraces).

use std::error::Error as StdError;
use std::fmt;

/// A flattened error: `chain[0]` is the outermost message, the rest are the
/// `source()` chain (or earlier errors wrapped by `.context(..)`).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message (what `.context(..)` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost to innermost.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The outermost message (what `{}` displays).
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole chain, anyhow-style.
            write!(f, "{}", self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_shows_outermost_and_alternate_shows_chain() {
        let e: Error = Result::<(), _>::Err(io_err())
            .context("opening config")
            .unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: file missing");
    }

    #[test]
    fn option_context_makes_error() {
        let e = None::<u32>.context("missing field 'id'").unwrap_err();
        assert_eq!(e.to_string(), "missing field 'id'");
    }

    #[test]
    fn macros_build_errors() {
        fn fails(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(fails(2).unwrap(), 2);
        assert_eq!(fails(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(fails(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(anyhow!("plain").to_string(), "plain");
    }

    #[test]
    fn with_context_nests() {
        let e: Error = Result::<(), _>::Err(io_err())
            .with_context(|| format!("trace line {}", 7))
            .unwrap_err();
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["trace line 7", "file missing"]);
    }

    #[test]
    fn debug_renders_cause_list() {
        let e: Error = Result::<(), _>::Err(io_err()).context("outer").unwrap_err();
        let d = format!("{e:?}");
        assert!(d.contains("outer"));
        assert!(d.contains("Caused by:"));
        assert!(d.contains("file missing"));
    }
}
