//! End-to-end simulator throughput: simulated days per wall-clock second
//! for each experiment arm on the small-scale training cluster, plus the
//! i2 inference preset. This is the whole-stack hot-path number the §Perf
//! pass optimizes.
//!
//! Run with: `cargo bench --bench e2e`

use kant::config::{inference_cluster, training_cluster, InferencePreset, Scale};
use kant::experiments::{run_arm, Arm};
use kant::sim::SimConfig;
use kant::util::benchkit::Bench;
use std::time::Duration;

fn main() {
    let mut b = Bench::new()
        .warmup(1)
        .min_iters(3)
        .max_iters(10)
        .target_time(Duration::from_secs(6));

    println!("== end-to-end simulation throughput ==");
    for (label, arm) in [
        ("native", Arm::native_baseline()),
        ("kant-backfill-ebinpack", Arm::kant_ebinpack()),
    ] {
        let mut env = training_cluster(Scale::Small, 9, 0.9);
        env.horizon_ms = 24 * 3_600_000; // 1 simulated day of arrivals.
        let sim_days = 2.0; // incl. drain day
        b.run_throughput(
            &format!("sim-train1024/{label}"),
            sim_days,
            || run_arm(&env, &arm, &SimConfig::default()).events_processed,
        );
    }

    let env = inference_cluster(InferencePreset::I2, 9);
    let days = (env.horizon_ms + 24 * 3_600_000) as f64 / 86_400_000.0;
    b.run_throughput("sim-inference-i2/kant", days, || {
        run_arm(&env, &Arm::kant_backfill(), &SimConfig::default()).events_processed
    });
    println!("(items/s = simulated days per wall second)");
}
