//! §3.4.3 ablation bench: deep-copy vs incremental scheduling snapshots.
//!
//! The paper reports >50 % RSCH CPU reduction on a 1,000-node cluster after
//! switching to incremental updates. This bench reproduces the comparison:
//! per scheduling cycle, K nodes mutate and the snapshot refreshes.
//!
//! Run with: `cargo bench --bench snapshot`

use kant::cluster::builder::{ClusterBuilder, ClusterSpec};
use kant::cluster::ids::{JobId, NodeId, PodId};
use kant::cluster::snapshot::{Snapshot, SnapshotMode};
use kant::cluster::state::{ClusterState, PodPlacement};
use kant::util::benchkit::Bench;
use kant::util::rng::Pcg32;
use std::time::Duration;

/// Apply `k` random allocate/release mutations.
fn mutate(
    state: &mut ClusterState,
    rng: &mut Pcg32,
    next_job: &mut u64,
    live: &mut Vec<u64>,
    k: usize,
) {
    for _ in 0..k {
        if !live.is_empty() && rng.chance(0.5) {
            let i = rng.below(live.len() as u64) as usize;
            let j = live.swap_remove(i);
            state.release_job(JobId(j)).unwrap();
        } else {
            let node = NodeId(rng.below(state.nodes.len() as u64) as u32);
            let want = rng.range_inclusive(1, 4) as usize;
            let free = state.node(node).free_gpu_indices();
            if free.len() >= want {
                let id = *next_job;
                *next_job += 1;
                state
                    .commit_placements(
                        JobId(id),
                        vec![PodPlacement {
                            pod: PodId::new(JobId(id), 0),
                            node,
                            devices: free[..want].to_vec(),
                            nic: 0,
                        }],
                    )
                    .unwrap();
                live.push(id);
            }
        }
    }
}

fn bench_mode(b: &mut Bench, nodes_per_group: u32, groups: u32, k: usize, mode: SnapshotMode) {
    // ~1,000-node cluster: 32 groups × 32 nodes.
    let mut state = ClusterBuilder::build(&ClusterSpec::homogeneous(
        "snap",
        8,
        groups / 8,
        nodes_per_group,
    ));
    let mut rng = Pcg32::seed_from_u64(7);
    let mut next_job = 1u64;
    let mut live = Vec::new();
    // Pre-warm to ~50 % allocation.
    mutate(&mut state, &mut rng, &mut next_job, &mut live, 2_000);

    let mut snap = Snapshot::new(mode);
    snap.refresh(&state);
    let n = state.nodes.len();
    let name = format!(
        "snapshot/{:?}/{}nodes/{}mut-per-cycle",
        mode, n, k
    );
    b.run(&name, || {
        mutate(&mut state, &mut rng, &mut next_job, &mut live, k);
        snap.refresh(&state);
        snap.stats.refreshes
    });
}

fn main() {
    println!("== §3.4.3 snapshot ablation: deep copy vs incremental ==");
    let mut b = Bench::new()
        .warmup(3)
        .target_time(Duration::from_secs(2))
        .max_iters(5_000);
    for k in [1usize, 8, 64] {
        bench_mode(&mut b, 32, 32, k, SnapshotMode::DeepCopy);
        bench_mode(&mut b, 32, 32, k, SnapshotMode::Incremental);
    }
    // Report the ratio for the paper claim.
    let r = b.results();
    for pair in r.chunks(2) {
        if let [deep, inc] = pair {
            let speedup = deep.mean_ns / inc.mean_ns.max(1.0);
            let reduction = 100.0 * (1.0 - inc.mean_ns / deep.mean_ns.max(1.0));
            println!(
                "=> {} vs {}: incremental {:.1}x faster ({:.0}% CPU reduction; paper: >50%)",
                deep.name, inc.name, speedup, reduction
            );
        }
    }
}
