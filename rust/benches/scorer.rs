//! Scoring-backend bench: native Rust vs the AOT XLA artifact (PJRT),
//! across candidate-set sizes. The XLA path proves the three-layer
//! composition; the native path is the production default at sim scale —
//! this bench quantifies the crossover.
//!
//! Run with: `cargo bench --bench scorer`
//! (XLA rows need `--features xla`, the real xla crate and `make artifacts`)

use kant::job::spec::PlacementStrategy;
use kant::rsch::features::{JOB_D, NODE_F};
use kant::rsch::score::{node_weights, NativeBackend, Phase, ScoreBackend, NUM_COMPONENTS};
use kant::util::benchkit::Bench;
use kant::util::rng::Pcg32;
use std::time::Duration;

fn random_features(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    let mut feat = vec![0.0f32; n * NODE_F];
    for i in 0..n {
        let row = &mut feat[i * NODE_F..(i + 1) * NODE_F];
        let alloc = rng.below(9) as f32;
        row[0] = 8.0 - alloc;
        row[1] = 8.0;
        row[2] = alloc;
        row[3] = 1.0;
        row[4] = rng.below(257) as f32;
        row[5] = 256.0;
        row[8] = rng.below(5) as f32; // Tier 0..=4 (cross-superspine).
        row[11] = row[0];
    }
    feat
}

#[cfg(feature = "xla")]
fn bench_xla(b: &mut Bench, rng: &mut Pcg32, job: &[f32; JOB_D], w: &[f32; NUM_COMPONENTS]) {
    use kant::runtime::XlaBackend;
    match XlaBackend::new("artifacts") {
        Ok(mut xla) => {
            xla.warmup().expect("artifact warmup");
            for n in [32usize, 256, 1024, 4096] {
                let feat = random_features(rng, n);
                b.run_throughput(&format!("score-nodes/xla/{n}"), n as f64, || {
                    xla.score_nodes(&feat, n, job, w)
                });
            }
        }
        Err(e) => eprintln!("skipping XLA rows (run `make artifacts`): {e}"),
    }
}

#[cfg(not(feature = "xla"))]
fn bench_xla(_b: &mut Bench, _rng: &mut Pcg32, _job: &[f32; JOB_D], _w: &[f32; NUM_COMPONENTS]) {
    eprintln!("skipping XLA rows (built without the `xla` feature)");
}

fn main() {
    let mut b = Bench::new()
        .warmup(3)
        .target_time(Duration::from_secs(2))
        .max_iters(100_000);
    let mut rng = Pcg32::seed_from_u64(1);
    let job = [4.0f32, 64.0, 1.0, 0.0, 0.0, 2.0, 0.0, 0.0];
    let w = node_weights(PlacementStrategy::EBinpack, Phase::Primary, false);

    println!("== scoring hot path: native vs XLA/PJRT ==");
    for n in [32usize, 256, 1024, 4096] {
        let feat = random_features(&mut rng, n);
        let mut native = NativeBackend;
        b.run_throughput(&format!("score-nodes/native/{n}"), n as f64, || {
            native.score_nodes(&feat, n, &job, &w)
        });
    }

    bench_xla(&mut b, &mut rng, &job, &w);
}
