//! §3.4.2 ablation bench: two-level (NodeNetGroup preselect) vs flat
//! scheduling, across cluster sizes — plus the per-placement cost of the
//! full RSCH path. The paper's claim: hierarchical scheduling "significantly
//! reduces the scheduling search scope".
//!
//! Run with: `cargo bench --bench sched_cycle`

// Bench harness configuration comes from the environment by design
// (BENCH_SCALE / BENCH_BASELINE_OUT are CI plumbing, not scheduler state).
#![allow(clippy::disallowed_methods)]

use kant::cluster::builder::{ClusterBuilder, ClusterSpec};
use kant::cluster::gpu::Health;
use kant::cluster::ids::{GpuTypeId, JobId, NodeId, TenantId};
use kant::job::spec::{JobKind, JobSpec};
use kant::qsch::Placer;
use kant::rsch::{GangScoring, Rsch, RschConfig};
use kant::util::benchkit::Bench;
use kant::util::rng::Pcg32;
use std::time::Duration;

fn make_state(groups: u32) -> kant::cluster::state::ClusterState {
    ClusterBuilder::build(&ClusterSpec::homogeneous("bench", 8, groups / 8, 32))
}

/// Place-and-release one small job (the scheduler's common case).
fn bench_placement(b: &mut Bench, groups: u32, two_level: bool) {
    let mut state = make_state(groups);
    let cfg = RschConfig {
        two_level,
        ..RschConfig::default()
    };
    let mut rsch = Rsch::new(cfg, &state);
    // Fragment the cluster a bit so scoring has real work.
    let mut rng = Pcg32::seed_from_u64(3);
    let mut warm = 1_000_000u64;
    for _ in 0..state.nodes.len() / 2 {
        let spec = JobSpec::homogeneous(
            JobId(warm),
            TenantId(0),
            JobKind::Training,
            GpuTypeId(0),
            1,
            rng.range_inclusive(1, 4) as u32,
        );
        let _ = rsch.place(&mut state, &spec);
        warm += 1;
    }
    let mode = if two_level { "two-level" } else { "flat" };
    let n = state.nodes.len();
    let mut id = 1u64;
    b.run_throughput(
        &format!("place-8gpu-job/{mode}/{n}nodes"),
        1.0,
        || {
            let spec = JobSpec::homogeneous(
                JobId(id),
                TenantId(0),
                JobKind::Training,
                GpuTypeId(0),
                1,
                8,
            );
            id += 1;
            if rsch.place(&mut state, &spec).is_ok() {
                state.release_job(JobId(id - 1)).unwrap();
            }
        },
    );
}

/// A 32-node gang placement (256 GPUs) — the large-job path.
fn bench_gang(b: &mut Bench, groups: u32, two_level: bool) {
    let mut state = make_state(groups);
    let cfg = RschConfig {
        two_level,
        ..RschConfig::default()
    };
    let mut rsch = Rsch::new(cfg, &state);
    let mode = if two_level { "two-level" } else { "flat" };
    let n = state.nodes.len();
    let mut id = 1u64;
    b.run_throughput(&format!("place-256gpu-gang/{mode}/{n}nodes"), 32.0, || {
        let spec = JobSpec::homogeneous(
            JobId(id),
            TenantId(0),
            JobKind::Training,
            GpuTypeId(0),
            32,
            8,
        );
        id += 1;
        if rsch.place(&mut state, &spec).is_ok() {
            state.release_job(JobId(id - 1)).unwrap();
        }
    });
}

/// Reliability: placement cost under a steady churn of node
/// cordons/drains/repairs — every health flip dirties the mutation log,
/// so each placement's snapshot refresh re-slots churned nodes in the
/// free-capacity index. This is the health-mutation overhead the fault
/// subsystem adds to the scheduling cycle.
fn bench_fault_storm(b: &mut Bench, groups: u32) {
    let mut state = make_state(groups);
    let mut rsch = Rsch::new(RschConfig::default(), &state);
    let n = state.nodes.len();
    let mut id = 1u64;
    let mut cursor = 0usize;
    b.run_throughput(&format!("place-8gpu-job/fault-storm/{n}nodes"), 1.0, || {
        // Rolling churn: one node cordons, one drains, one returns.
        let cordon = cursor % n;
        let drain = (cursor + n / 3) % n;
        let heal = (cursor + 2 * n / 3) % n;
        state.set_node_health(NodeId(cordon as u32), Health::Cordoned);
        state.set_node_health(NodeId(drain as u32), Health::Draining);
        state.set_node_health(NodeId(heal as u32), Health::Healthy);
        cursor += 1;
        let spec = JobSpec::homogeneous(
            JobId(id),
            TenantId(0),
            JobKind::Training,
            GpuTypeId(0),
            1,
            8,
        );
        id += 1;
        if rsch.place(&mut state, &spec).is_ok() {
            state.release_job(JobId(id - 1)).unwrap();
        }
    });
}

/// Large-gang scoring: a 512-GPU (64-pod) whole-node gang per iteration,
/// across the three gang-scoring modes. `PooledIncremental` (default)
/// must both run faster and rebuild far fewer feature rows than the
/// per-pod paths — the `nodes_scored` counters printed alongside are the
/// work-drop evidence the truthful-tier refactor claims.
fn bench_large_gang(b: &mut Bench, groups: u32, mode: GangScoring, label: &str) {
    let mut state = make_state(groups);
    let cfg = RschConfig {
        gang_scoring: mode,
        ..RschConfig::default()
    };
    let mut rsch = Rsch::new(cfg, &state);
    let n = state.nodes.len();
    let mut id = 1u64;
    b.run_throughput(&format!("place-512gpu-gang/{label}/{n}nodes"), 64.0, || {
        let spec = JobSpec::homogeneous(
            JobId(id),
            TenantId(0),
            JobKind::Training,
            GpuTypeId(0),
            64,
            8,
        );
        id += 1;
        if rsch.place(&mut state, &spec).is_ok() {
            state.release_job(JobId(id - 1)).unwrap();
        }
    });
    eprintln!(
        "   [{label}] nodes_scored={} pods_placed={} (rows/pod {:.1})",
        rsch.stats.nodes_scored,
        rsch.stats.pods_placed,
        rsch.stats.nodes_scored as f64 / rsch.stats.pods_placed.max(1) as f64,
    );
}

/// Superspine-sharded QSCH cycle on the 100,000-GPU preset: one cycle
/// over a 64-job batch (mixed 8-GPU singles, 32-GPU and 128-GPU gangs)
/// with the sharded prefetch planning on `threads` workers across the
/// 10 structural shards. The digest-checked invariant means
/// `nodes_examined` must be identical across the 1/4/8-thread rows —
/// only wall time may move.
fn bench_sharded_cycle(b: &mut Bench, threads: usize) {
    use kant::cluster::tenant::{QuotaLedger, QuotaMode};
    use kant::job::store::JobStore;
    use kant::qsch::policy::QschConfig;
    use kant::qsch::Qsch;

    let mut state = ClusterBuilder::build(&ClusterSpec::train100000());
    let mut ledger = QuotaLedger::new(1, 1, QuotaMode::Shared);
    ledger.set_limit(TenantId(0), GpuTypeId(0), state.total_gpus());
    let cfg = QschConfig {
        batch_shards: threads,
        ..QschConfig::default()
    };
    let mut qsch = Qsch::new(cfg, ledger);
    let mut store = JobStore::new();
    let mut rsch = Rsch::new(RschConfig::default(), &state);
    let n = state.nodes.len();
    let batch = 64usize;
    let mut id = 1u64;
    let mut now = 0u64;
    b.run_throughput(
        &format!("qsch-cycle-batch64/shards{threads}/{n}nodes"),
        batch as f64,
        || {
            for k in 0..batch {
                let replicas = match k % 8 {
                    0 => 16, // 128-GPU gang.
                    1 | 2 => 4,
                    _ => 1,
                };
                let spec = JobSpec::homogeneous(
                    JobId(id),
                    TenantId(0),
                    JobKind::Training,
                    GpuTypeId(0),
                    replicas,
                    8,
                )
                .with_times(now, 3_600_000);
                id += 1;
                qsch.submit(&mut store, spec);
            }
            let r = qsch.cycle(now, &mut store, &mut state, &mut rsch);
            now += 1_000;
            // Empty the cluster again so every iteration plans the same
            // batch against the same free fabric.
            for jid in r.scheduled {
                state.release_job(jid).unwrap();
            }
        },
    );
    eprintln!(
        "   [shards{threads}] nodes_examined={} pods_placed={}",
        rsch.stats.nodes_examined, rsch.stats.pods_placed
    );
}

/// Adaptive-controller overhead at the xlarge (10k-GPU) preset: the same
/// 64-job QSCH cycle with the weight controller disabled (frozen static
/// tables) vs enabled and ticked with oscillating synthetic signals
/// before every cycle — the runner's exact call order. The delta is the
/// per-cycle cost of `--adapt`: one overlay fold plus the blended weight
/// rows on the scoring path.
fn bench_adapt_cycle(b: &mut Bench, adaptive: bool) {
    use kant::cluster::tenant::{QuotaLedger, QuotaMode};
    use kant::job::spec::Priority;
    use kant::job::store::JobStore;
    use kant::qsch::policy::QschConfig;
    use kant::qsch::Qsch;
    use kant::rsch::adapt::{AdaptConfig, AdaptSignals};

    let mut state = ClusterBuilder::build(&ClusterSpec::train10000());
    let mut ledger = QuotaLedger::new(1, 1, QuotaMode::Shared);
    ledger.set_limit(TenantId(0), GpuTypeId(0), state.total_gpus());
    let mut qsch = Qsch::new(QschConfig::default(), ledger);
    let mut store = JobStore::new();
    let rcfg = RschConfig {
        adapt: AdaptConfig {
            enabled: adaptive,
            seed: 7,
            ..AdaptConfig::default()
        },
        ..RschConfig::default()
    };
    let mut rsch = Rsch::new(rcfg, &state);
    let n = state.nodes.len();
    let label = if adaptive { "adapt-adaptive" } else { "adapt-static" };
    let batch = 64usize;
    let mut id = 1u64;
    let mut now = 0u64;
    let mut tick = 0u64;
    b.run_throughput(
        &format!("qsch-cycle-batch64/{label}/{n}nodes"),
        batch as f64,
        || {
            if rsch.wants_adapt() {
                // Oscillate GFR across the dead band so the controller
                // keeps shifting — the worst case, not the settled one.
                let gfr = if tick % 2 == 0 { 0.15 } else { 0.01 };
                tick += 1;
                rsch.adapt_tick(&AdaptSignals {
                    gar: 0.9,
                    gfr,
                    class_p99_wait_ms: [0.0; Priority::NUM_CLASSES],
                });
            }
            for k in 0..batch {
                let replicas = match k % 8 {
                    0 => 16, // 128-GPU gang.
                    1 | 2 => 4,
                    _ => 1,
                };
                let spec = JobSpec::homogeneous(
                    JobId(id),
                    TenantId(0),
                    JobKind::Training,
                    GpuTypeId(0),
                    replicas,
                    8,
                )
                .with_times(now, 3_600_000);
                id += 1;
                qsch.submit(&mut store, spec);
            }
            let r = qsch.cycle(now, &mut store, &mut state, &mut rsch);
            now += 1_000;
            for jid in r.scheduled {
                state.release_job(jid).unwrap();
            }
        },
    );
    eprintln!(
        "   [{label}] adapt_ticks={} adapt_shifts={}",
        rsch.stats.adapt_ticks, rsch.stats.adapt_shifts
    );
}

/// Moldable admission pass: the same 64-job QSCH cycle at the xlarge
/// (10k-GPU) preset with every multi-pod gang declaring a 3-rung shape
/// ladder and `enable_moldable` on — the per-cycle cost of the
/// O(shapes) pool-headroom probes the mold pass runs in front of
/// placement. On an empty fabric every gang keeps its full shape, so
/// the delta vs the adapt-static row is pure probe overhead.
fn bench_moldable_cycle(b: &mut Bench) {
    use kant::cluster::tenant::{QuotaLedger, QuotaMode};
    use kant::job::spec::GangShape;
    use kant::job::store::JobStore;
    use kant::qsch::policy::QschConfig;
    use kant::qsch::Qsch;

    let mut state = ClusterBuilder::build(&ClusterSpec::train10000());
    let mut ledger = QuotaLedger::new(1, 1, QuotaMode::Shared);
    ledger.set_limit(TenantId(0), GpuTypeId(0), state.total_gpus());
    let mut qsch = Qsch::new(
        QschConfig {
            enable_moldable: true,
            enable_shrink: true,
            ..QschConfig::default()
        },
        ledger,
    );
    let mut store = JobStore::new();
    let mut rsch = Rsch::new(RschConfig::default(), &state);
    let n = state.nodes.len();
    let batch = 64usize;
    let mut id = 1u64;
    let mut now = 0u64;
    b.run_throughput(
        &format!("qsch-cycle-batch64/moldable/{n}nodes"),
        batch as f64,
        || {
            for k in 0..batch {
                let replicas = match k % 8 {
                    0 => 16, // 128-GPU gang.
                    1 | 2 => 4,
                    _ => 1,
                };
                let mut spec = JobSpec::homogeneous(
                    JobId(id),
                    TenantId(0),
                    JobKind::Training,
                    GpuTypeId(0),
                    replicas,
                    8,
                )
                .with_times(now, 3_600_000);
                if replicas > 1 {
                    spec = spec.with_shapes(vec![
                        GangShape {
                            replicas,
                            throughput: 1.0,
                        },
                        GangShape {
                            replicas: replicas / 2,
                            throughput: 0.45,
                        },
                        GangShape {
                            replicas: (replicas / 4).max(1),
                            throughput: 0.2,
                        },
                    ]);
                }
                id += 1;
                qsch.submit(&mut store, spec);
            }
            let r = qsch.cycle(now, &mut store, &mut state, &mut rsch);
            now += 1_000;
            for jid in r.scheduled {
                state.release_job(jid).unwrap();
            }
        },
    );
    eprintln!("   [moldable] shape_molds={}", qsch.stats.shape_molds);
}

/// Observability overhead: the same 64-job QSCH cycle at the xlarge
/// (10k-GPU) preset through `cycle()` (disabled recorder, the default
/// path every caller gets) vs `cycle_observed()` with a verbosity-2
/// recorder streaming decision records into a null sink — the full
/// span + trace cost of `--obs-out`. The acceptance target is an
/// obs-on mean within ~5% of obs-off: observability must be cheap
/// enough to leave on.
fn bench_obs_cycle(b: &mut Bench, obs_on: bool) {
    use kant::cluster::tenant::{QuotaLedger, QuotaMode};
    use kant::job::store::JobStore;
    use kant::obs::ObsRecorder;
    use kant::qsch::policy::QschConfig;
    use kant::qsch::Qsch;

    let mut state = ClusterBuilder::build(&ClusterSpec::train10000());
    let mut ledger = QuotaLedger::new(1, 1, QuotaMode::Shared);
    ledger.set_limit(TenantId(0), GpuTypeId(0), state.total_gpus());
    let mut qsch = Qsch::new(QschConfig::default(), ledger);
    let mut store = JobStore::new();
    let mut rsch = Rsch::new(RschConfig::default(), &state);
    let mut obs = if obs_on {
        ObsRecorder::enabled(2).with_sink(Box::new(std::io::sink()))
    } else {
        ObsRecorder::disabled()
    };
    let n = state.nodes.len();
    let label = if obs_on { "obs-on" } else { "obs-off" };
    let batch = 64usize;
    let mut id = 1u64;
    let mut now = 0u64;
    b.run_throughput(
        &format!("qsch-cycle-batch64/{label}/{n}nodes"),
        batch as f64,
        || {
            for k in 0..batch {
                let replicas = match k % 8 {
                    0 => 16, // 128-GPU gang.
                    1 | 2 => 4,
                    _ => 1,
                };
                let spec = JobSpec::homogeneous(
                    JobId(id),
                    TenantId(0),
                    JobKind::Training,
                    GpuTypeId(0),
                    replicas,
                    8,
                )
                .with_times(now, 3_600_000);
                id += 1;
                qsch.submit(&mut store, spec);
            }
            obs.begin_cycle();
            let r = qsch.cycle_observed(now, &mut store, &mut state, &mut rsch, &mut obs);
            obs.end_cycle(
                now,
                qsch.queues.len() as u64,
                r.scheduled.len() as u64,
                r.preempted.len() as u64,
            );
            now += 1_000;
            for jid in r.scheduled {
                state.release_job(jid).unwrap();
            }
        },
    );
    eprintln!(
        "   [{label}] cycles_profiled={} decisions={}",
        obs.profiles().len(),
        obs.decisions()
    );
}

/// §3.1 multi-instance parallel planning throughput.
fn bench_parallel(b: &mut Bench, threads: usize) {
    let mut state = make_state(32);
    let mut rsch = Rsch::new(RschConfig::default(), &state);
    let batch = 64usize;
    let mut id = 1u64;
    b.run_throughput(
        &format!("place-batch64/threads{threads}/1024nodes"),
        batch as f64,
        || {
            let specs: Vec<JobSpec> = (0..batch)
                .map(|k| {
                    JobSpec::homogeneous(
                        JobId(id + k as u64),
                        TenantId(0),
                        JobKind::Training,
                        GpuTypeId(0),
                        1,
                        ((k % 4) + 1) as u32 * 2,
                    )
                })
                .collect();
            id += batch as u64;
            let results = rsch.place_many_parallel(&mut state, &specs, threads);
            for (spec, r) in specs.iter().zip(&results) {
                if r.is_ok() {
                    state.release_job(spec.id).unwrap();
                }
            }
        },
    );
}

fn main() {
    // BENCH_SCALE=small is the CI-artifact preset: one 256-node grid at a
    // short target time so the workflow job finishes in seconds. The
    // default is the full 256/1024/4096-node sweep for real baselines.
    let small = std::env::var("BENCH_SCALE").map(|s| s == "small").unwrap_or(false);
    println!("== §3.4.2 two-level vs flat scheduling ==");
    let mut b = if small {
        Bench::new()
            .warmup(1)
            .target_time(Duration::from_millis(200))
            .max_iters(2_000)
    } else {
        Bench::new()
            .warmup(3)
            .target_time(Duration::from_secs(2))
            .max_iters(20_000)
    };
    let groups_grid: &[u32] = if small { &[8] } else { &[8, 32, 128] };
    for &groups in groups_grid {
        bench_placement(&mut b, groups, false);
        bench_placement(&mut b, groups, true);
    }
    bench_gang(&mut b, if small { 8 } else { 32 }, false);
    bench_gang(&mut b, if small { 8 } else { 32 }, true);

    if !small {
        println!("== §3.1 multi-instance parallel planning ==");
        for threads in [1usize, 2, 4, 8] {
            bench_parallel(&mut b, threads);
        }
    }

    // Summarize two-level speedups (flat/two-level pairs only — the
    // fault-storm scenario below is unpaired).
    let results = b.results().to_vec();
    for pair in results.chunks(2) {
        if let [flat, two] = pair {
            println!(
                "=> {} vs two-level: {:.1}x faster",
                flat.name,
                flat.mean_ns / two.mean_ns.max(1.0)
            );
        }
    }

    // Reliability: health-mutation churn in the placement path (drains /
    // cordons / repairs between placements). Included in the baseline
    // artifact so the bench trajectory covers the fault subsystem.
    println!("== reliability: fault-storm churn ==");
    bench_fault_storm(&mut b, if small { 8 } else { 32 });

    // Large-gang (512-GPU) scoring modes: per-pod rescan vs pooled
    // rebuild vs the default pooled-incremental row cache.
    println!("== large-gang scoring: per-pod vs pooled vs incremental ==");
    let gg = if small { 8 } else { 32 };
    bench_large_gang(&mut b, gg, GangScoring::PerPodRescan, "per-pod-rescan");
    bench_large_gang(&mut b, gg, GangScoring::PooledRebuild, "pooled-rebuild");
    bench_large_gang(&mut b, gg, GangScoring::PooledIncremental, "pooled-incremental");

    // Tentpole scenario: the sharded scheduler core at 100k-GPU scale,
    // 1 vs 4 vs 8 worker threads over the 10 structural superspine
    // shards. Runs in every preset (small keeps iterations low) so the
    // per-commit artifact tracks the sharded cycle's trajectory.
    println!("== superspine-sharded cycle: 100k-GPU preset ==");
    for threads in [1usize, 4, 8] {
        bench_sharded_cycle(&mut b, threads);
    }

    // Adaptive scoring loop: frozen static tables vs controller-on at the
    // xlarge (10k-GPU) preset — the per-cycle overhead of `--adapt`.
    println!("== adaptive weight controller: xlarge preset ==");
    bench_adapt_cycle(&mut b, false);
    bench_adapt_cycle(&mut b, true);

    // Moldable admission pass: O(shapes) headroom probes in front of
    // placement, on laddered versions of the same 64-job batch.
    println!("== moldable shape-selection pass: xlarge preset ==");
    bench_moldable_cycle(&mut b);

    // Observability overhead: disabled recorder (the default path) vs a
    // verbosity-2 recorder streaming into a null sink. The two rows in
    // the committed baseline should stay within a few percent of each
    // other — the digest-inert profiler's "cheap enough to leave on"
    // claim, tracked per commit like every other scenario.
    println!("== observability overhead: xlarge preset ==");
    bench_obs_cycle(&mut b, false);
    bench_obs_cycle(&mut b, true);

    // Seed/refresh a perf baseline when requested. From the package root:
    //   BENCH_BASELINE_OUT=BENCH_baseline.json cargo bench --bench sched_cycle
    // regenerates the committed default-grid baseline; CI additionally
    // publishes a BENCH_SCALE=small run as a workflow artifact on every
    // push (the bench trajectory across PRs).
    if let Ok(path) = std::env::var("BENCH_BASELINE_OUT") {
        let scale_label = if small { "small" } else { "default-grid" };
        let doc = kant::util::benchkit::baseline_json("sched_cycle", scale_label, b.results());
        std::fs::write(&path, doc + "\n").expect("writing bench baseline");
        eprintln!("wrote bench baseline to {path}");
    }
}
