//! Candidate-selection ablation: the incremental free-capacity node index
//! vs the linear scan, up to `xlarge` (1,250 nodes / 10,000 GPUs) — the
//! "tens of thousands of GPUs" scale of the paper's abstract claim. The
//! cluster is warmed to a realistic load first (mostly-full nodes plus a
//! fragmented tail), because that is the regime where pruning the
//! candidate walk pays: on an idle cluster every node is a candidate and
//! no data structure can help.
//!
//! Run with: `cargo bench --bench candidate_index`

// Bench harness configuration comes from the environment by design
// (BENCH_SCALE / BENCH_BASELINE_OUT are CI plumbing, not scheduler state).
#![allow(clippy::disallowed_methods)]

use kant::cluster::builder::{ClusterBuilder, ClusterSpec};
use kant::cluster::ids::{GpuTypeId, JobId, NodeId, PodId, TenantId};
use kant::cluster::state::{ClusterState, PodPlacement};
use kant::job::spec::{JobKind, JobSpec};
use kant::qsch::Placer;
use kant::rsch::{Rsch, RschConfig};
use kant::util::benchkit::Bench;
use std::time::Duration;

/// Deterministically load a fresh cluster: of every 16 nodes, one stays
/// whole-free, one is fragmented to 2 free GPUs, the rest are filled
/// whole. Small pods then fit on ~1/8 of the cluster — the bucket walk —
/// while the linear scan still touches everything.
fn warmed_state(spec: &ClusterSpec) -> ClusterState {
    let mut state = ClusterBuilder::build(spec);
    let mut id = 1_000_000u64;
    for n in 0..state.nodes.len() as u32 {
        let devices: Vec<u8> = match n % 16 {
            0 => continue,           // whole-free
            1 => (0u8..6).collect(), // fragmented: 2 free
            _ => (0u8..8).collect(), // full
        };
        state
            .commit_placements(
                JobId(id),
                vec![PodPlacement {
                    pod: PodId::new(JobId(id), 0),
                    node: NodeId(n),
                    devices,
                    nic: 0,
                }],
            )
            .expect("warm placement");
        id += 1;
    }
    state
}

fn small_job(id: u64, gpus: u32) -> JobSpec {
    JobSpec::homogeneous(JobId(id), TenantId(0), JobKind::Training, GpuTypeId(0), 1, gpus)
}

/// Place-and-release throughput of a 2-GPU pod on the warmed cluster.
fn bench_placement(b: &mut Bench, spec: &ClusterSpec, indexed: bool, two_level: bool) {
    let mut state = warmed_state(spec);
    let n = state.nodes.len();
    let cfg = RschConfig {
        indexed_candidates: indexed,
        two_level,
        ..RschConfig::default()
    };
    let mut rsch = Rsch::new(cfg, &state);
    let mode = match (two_level, indexed) {
        (false, false) => "flat-linear",
        (false, true) => "flat-indexed",
        (true, false) => "two-level-linear",
        (true, true) => "two-level-indexed",
    };
    let mut id = 1u64;
    b.run_throughput(&format!("place-2gpu/{mode}/{n}nodes"), 1.0, || {
        let spec = small_job(id, 2);
        id += 1;
        if rsch.place(&mut state, &spec).is_ok() {
            state.release_job(JobId(id - 1)).unwrap();
        }
    });
}

/// Nodes examined per placed pod over a fixed job batch (the §3.4 work
/// counter the acceptance criterion reads).
fn examined_per_pod(spec: &ClusterSpec, indexed: bool, two_level: bool) -> f64 {
    let mut state = warmed_state(spec);
    let cfg = RschConfig {
        indexed_candidates: indexed,
        two_level,
        ..RschConfig::default()
    };
    let mut rsch = Rsch::new(cfg, &state);
    for k in 0..256u64 {
        let spec = small_job(1 + k, 2);
        if rsch.place(&mut state, &spec).is_ok() {
            state.release_job(spec.id).unwrap();
        }
    }
    rsch.stats.nodes_examined as f64 / rsch.stats.pods_placed.max(1) as f64
}

fn main() {
    let scales: Vec<(&str, ClusterSpec)> = vec![
        ("small-256", ClusterSpec::homogeneous("idx256", 2, 4, 32)),
        ("xlarge-10k", ClusterSpec::train10000()),
    ];

    println!("== candidate selection: free-capacity index vs linear scan ==");
    let mut b = Bench::new()
        .warmup(3)
        .target_time(Duration::from_secs(2))
        .max_iters(50_000);
    for (_, spec) in &scales {
        for two_level in [false, true] {
            bench_placement(&mut b, spec, false, two_level);
            bench_placement(&mut b, spec, true, two_level);
        }
    }

    // Speedup summary per (scale, mode) pair: results interleave
    // linear/indexed in that order.
    let results = b.results().to_vec();
    for pair in results.chunks(2) {
        if let [linear, indexed] = pair {
            println!(
                "=> {} vs {}: {:.1}x faster",
                linear.name,
                indexed.name,
                linear.mean_ns / indexed.mean_ns.max(1.0)
            );
        }
    }

    println!("== nodes examined per placed pod (flat mode isolates the index) ==");
    for (label, spec) in &scales {
        let flat_linear = examined_per_pod(spec, false, false);
        let flat_indexed = examined_per_pod(spec, true, false);
        let tl_linear = examined_per_pod(spec, false, true);
        let tl_indexed = examined_per_pod(spec, true, true);
        println!(
            "{label}: flat {flat_linear:.1} -> indexed {flat_indexed:.1} \
             ({:.1}x fewer); two-level {tl_linear:.1} -> indexed {tl_indexed:.1}",
            flat_linear / flat_indexed.max(1e-9),
        );
        assert!(
            flat_linear >= 5.0 * flat_indexed,
            "{label}: expected >=5x reduction (flat {flat_linear:.1} vs indexed {flat_indexed:.1})"
        );
    }

    if let Ok(path) = std::env::var("BENCH_BASELINE_OUT") {
        let doc =
            kant::util::benchkit::baseline_json("candidate_index", "small+xlarge", b.results());
        std::fs::write(&path, doc + "\n").expect("writing bench baseline");
        eprintln!("wrote bench baseline to {path}");
    }
}
