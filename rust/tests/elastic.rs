//! Integration tests for the elasticity loop: shared-quota borrowing by
//! scale-up replicas and its §3.2.3 quota-reclamation counterpart, plus
//! the per-seed determinism property of the elastic controller.

use kant::cluster::builder::{ClusterBuilder, ClusterSpec};
use kant::cluster::ids::{GpuTypeId, JobId, TenantId};
use kant::cluster::tenant::{QuotaLedger, QuotaMode};
use kant::config::{inference_cluster, InferencePreset};
use kant::job::spec::{ElasticService, JobKind, JobSpec};
use kant::job::store::JobStore;
use kant::job::workload::WorkloadGen;
use kant::metrics::Metrics;
use kant::qsch::policy::QschConfig;
use kant::qsch::Qsch;
use kant::rsch::{Rsch, RschConfig};
use kant::sim::elastic::{ElasticConfig, ElasticController};
use kant::sim::{run, SimConfig};

const G: GpuTypeId = GpuTypeId(0);
const DAY: u64 = ElasticService::DAY_MS;

/// Scale-up beyond the tenant's own quota borrows from the lender
/// (§3.2.1 Shared mode); when the lender needs its quota back,
/// quota-reclamation preemption evicts *exactly* the borrowed replicas —
/// the owned base set and owned children stay untouched.
#[test]
fn scale_up_borrows_quota_and_reclaim_evicts_exactly_borrowed_replicas() {
    // 8 nodes / 64 GPUs. Tenant 0 owns 8 GPUs of quota, tenant 1 the
    // remaining 56 — Shared mode lets tenant 0 burst beyond its slice.
    let state_spec = ClusterSpec::homogeneous("q", 1, 2, 4);
    let mut state = ClusterBuilder::build(&state_spec);
    let mut ledger = QuotaLedger::new(2, 1, QuotaMode::Shared);
    ledger.set_limit(TenantId(0), G, 8);
    ledger.set_limit(TenantId(1), G, 56);
    let mut qsch = Qsch::new(QschConfig::default(), ledger);
    let mut rsch = Rsch::new(RschConfig::default(), &state);
    let mut store = JobStore::new();
    let mut metrics = Metrics::new(&state, 0);

    // One elastic service (floor 2, peak 16, full-amplitude tide).
    let svc = JobSpec::homogeneous(JobId(1), TenantId(0), JobKind::Inference, G, 16, 1)
        .with_times(0, 2 * DAY)
        .with_elastic(ElasticService {
            min_replicas: 2,
            max_replicas: 16,
            phase_ms: 0,
            amplitude: 1.0,
            period_ms: DAY,
        });
    let jobs = vec![svc.clone()];
    let mut ctrl = ElasticController::from_jobs(&ElasticConfig::enabled(), &jobs).unwrap();
    qsch.submit(&mut store, svc);
    qsch.cycle(0, &mut store, &mut state, &mut rsch);
    assert_eq!(state.allocated_gpus(), 2);

    // Noon: demand 16 → 14 scale-up children; 6 fit the tenant's own
    // remaining quota, 8 borrow from tenant 1.
    let noon = DAY / 2;
    let d = ctrl.on_sample(noon, &mut store, &mut state, &mut qsch, &mut metrics);
    assert_eq!(d.submitted, 14);
    qsch.cycle(noon + 1, &mut store, &mut state, &mut rsch);
    assert_eq!(state.allocated_gpus(), 16);
    assert_eq!(qsch.ledger.entry(TenantId(0), G).used_own, 8);
    assert_eq!(qsch.ledger.entry(TenantId(0), G).borrowed, 8);
    assert_eq!(qsch.ledger.entry(TenantId(1), G).lent, 8);
    let borrowed: Vec<JobId> = (2..=15)
        .map(JobId)
        .filter(|&j| qsch.ledger.is_borrowing(j))
        .collect();
    assert_eq!(borrowed.len(), 8, "8 replicas run on borrowed quota");

    // Tenant 1 claims its full quota: 56 GPUs against 48 own-free →
    // quota reclamation must evict the 8 borrowed replicas, exactly.
    let claim = JobSpec::homogeneous(JobId(500), TenantId(1), JobKind::Training, G, 7, 8)
        .with_times(noon + 2, 3_600_000);
    qsch.submit(&mut store, claim);
    let r = qsch.cycle(noon + 10_000, &mut store, &mut state, &mut rsch);
    let mut preempted = r.preempted.clone();
    preempted.sort_unstable();
    let mut expected = borrowed.clone();
    expected.sort_unstable();
    assert_eq!(preempted, expected, "victims are exactly the borrowed replicas");
    assert_eq!(qsch.stats.quota_reclaim_preemptions, 8);
    // The owned base set and owned children keep their resources.
    assert!(store.expect(JobId(1)).holds_resources());
    assert_eq!(qsch.ledger.entry(TenantId(0), G).borrowed, 0);
    assert_eq!(qsch.ledger.entry(TenantId(1), G).lent, 0);
    assert_eq!(qsch.ledger.entry(TenantId(0), G).used_own, 8);
}

/// Reliability × elasticity regression: a node fault that kills an
/// elastic child replica must release its devices, refund its quota, and
/// notify the controller — so the replica books stay consistent and the
/// next load sample re-provisions the dead replica instead of
/// double-counting it. (Previously a fault-evicted child was requeued
/// like a training gang, leaving the controller blind to the loss.)
#[test]
fn fault_evicted_elastic_child_refunds_quota_and_reprovisions() {
    use kant::cluster::ids::NodeId;
    use kant::sim::{run_with_events, Event};

    let build = || {
        let state = ClusterBuilder::build(&ClusterSpec::homogeneous("ef", 1, 2, 4)); // 64 GPUs.
        let mut ledger = QuotaLedger::new(2, 1, QuotaMode::Shared);
        ledger.set_limit(TenantId(0), G, 64);
        ledger.set_limit(TenantId(1), G, 0);
        let qsch = Qsch::new(QschConfig::default(), ledger);
        let rsch = Rsch::new(RschConfig::default(), &state);
        let svc = JobSpec::homogeneous(JobId(1), TenantId(0), JobKind::Inference, G, 16, 1)
            .with_times(0, 2 * DAY)
            .with_elastic(ElasticService {
                min_replicas: 2,
                max_replicas: 16,
                phase_ms: 0,
                amplitude: 1.0,
                period_ms: DAY,
            });
        (state, qsch, rsch, svc)
    };
    let cfg = |horizon: u64| SimConfig {
        horizon_ms: horizon,
        elastic: ElasticConfig::enabled(),
        ..SimConfig::default()
    };

    // Dry-run to the fault instant to learn where child 2 lives (the
    // controller is deterministic, so the replay matches until the fault).
    let fault_at = DAY / 2 + 10 * 60_000;
    let child_node: NodeId = {
        let (mut state, mut qsch, mut rsch, svc) = build();
        run(&mut state, &mut qsch, &mut rsch, vec![svc], &cfg(fault_at));
        *state.nodes_of(JobId(2)).first().expect("child 2 placed at noon")
    };

    let (mut state, mut qsch, mut rsch, svc) = build();
    let events = vec![
        (
            fault_at,
            Event::NodeHealth {
                node: child_node,
                healthy: false,
            },
        ),
        (
            fault_at + 2 * 3_600_000,
            Event::NodeHealth {
                node: child_node,
                healthy: true,
            },
        ),
    ];
    let out = run_with_events(
        &mut state,
        &mut qsch,
        &mut rsch,
        vec![svc],
        events,
        &cfg(2 * DAY + 12 * 3_600_000),
    );

    // The fault really hit replicas, and the books stayed consistent:
    // nothing leaks, every job ends exactly one way, quota fully refunds.
    assert!(out.metrics.reliability.fault_evictions > 0);
    assert_eq!(out.unfinished_jobs, 0);
    assert_eq!(
        out.metrics.jobs_submitted,
        out.metrics.jobs_finished + out.metrics.jobs_cancelled
    );
    assert_eq!(state.allocated_gpus(), 0);
    let e = qsch.ledger.entry(TenantId(0), G);
    assert!(
        e.used_own == 0 && e.borrowed == 0 && e.lent == 0,
        "quota must drain fully: {e:?}"
    );
    // The controller re-provisioned the dead replica(s): more scale-up
    // submissions than the 14 the first morning needed.
    assert!(
        out.metrics.elastic.scale_up_replicas > 14,
        "dead replicas must be re-made (scale-ups {})",
        out.metrics.elastic.scale_up_replicas
    );
    // Post-fault recovery keeps the SLO story intact overall.
    assert!(
        out.metrics.elastic.slo_violation_rate() < 0.2,
        "slo violation rate {}",
        out.metrics.elastic.slo_violation_rate()
    );
}

/// Property: the elastic controller (and everything downstream of it) is
/// deterministic per seed — the full-run digest replays byte-identically
/// for the same seed and diverges across seeds.
#[test]
fn elastic_controller_is_deterministic_per_seed() {
    fn digest_for(seed: u64) -> String {
        let mut env = inference_cluster(InferencePreset::A10, seed);
        env.workload.elastic_frac = 0.7;
        env.horizon_ms = 24 * 3_600_000;
        let mut state = env.state.clone();
        let mut qsch = Qsch::new(QschConfig::default(), env.ledger.clone());
        let mut rsch = Rsch::new(RschConfig::default(), &state);
        let jobs = WorkloadGen::new(env.workload.clone()).generate_until(env.horizon_ms);
        let cfg = SimConfig {
            horizon_ms: env.horizon_ms + 12 * 3_600_000,
            elastic: ElasticConfig::enabled(),
            ..SimConfig::default()
        };
        run(&mut state, &mut qsch, &mut rsch, jobs, &cfg)
            .digest_json()
            .to_string_compact()
    }
    let mut digests = Vec::new();
    for seed in [1u64, 7, 23] {
        let a = digest_for(seed);
        assert_eq!(a, digest_for(seed), "seed {seed} must replay identically");
        digests.push(a);
    }
    digests.dedup();
    assert_eq!(digests.len(), 3, "different seeds must diverge");
}
