//! PR 9's hard invariant: observability never perturbs scheduling. The
//! recorder is write-only for the scheduling core, so a same-seed run
//! must produce a byte-identical `digest_json` with the recorder off,
//! on at full verbosity, and at any `--shards` worker count — including
//! the elastic and fault-storm arms where the preempt / defrag / fault
//! spans all fire. Plus: the `--obs-out` JSONL stream itself must parse
//! back through the same `DecisionRecord` / `SchedulerHealth` readers
//! that `kant obs summarize` and `kant explain` use.

use std::io::Write;
use std::sync::{Arc, Mutex};

use kant::config::{FaultPreset, Scale, SimOptions, SimSetup};
use kant::job::workload::WorkloadGen;
use kant::obs::{DecisionRecord, ObsRecorder, SchedulerHealth};
use kant::qsch::Qsch;
use kant::rsch::Rsch;
use kant::sim::{run_observed, SimOutcome};
use kant::util::json::Json;

const ARRIVAL_MS: u64 = 12 * 3_600_000;

/// In-memory JSONL sink: the recorder owns a `Box<dyn Write>` handle,
/// the test keeps the other one.
#[derive(Clone)]
struct SharedBuf(Arc<Mutex<Vec<u8>>>);

impl SharedBuf {
    fn new() -> SharedBuf {
        SharedBuf(Arc::new(Mutex::new(Vec::new())))
    }

    fn contents(&self) -> String {
        String::from_utf8(self.0.lock().unwrap().clone()).expect("stream is UTF-8")
    }
}

impl Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// One full simulate run through the unified builder (the same path
/// `kant simulate` takes), horizon truncated for test runtime.
fn run_arm(
    seed: u64,
    elastic: bool,
    faults: FaultPreset,
    shards: usize,
    obs: &mut ObsRecorder,
) -> SimOutcome {
    let opts = SimOptions::for_scale(Scale::Small)
        .seed(seed)
        .elastic(elastic)
        .faults(faults)
        .shards(shards);
    let SimSetup {
        mut env,
        qsch,
        rsch,
        mut sim,
    } = opts.build().expect("options are valid");
    env.horizon_ms = ARRIVAL_MS;
    sim.horizon_ms = ARRIVAL_MS + 12 * 3_600_000; // Drain window.
    let mut jobs = WorkloadGen::new(env.workload.clone()).generate_until(env.horizon_ms);
    opts.apply_job_policies(&mut jobs);
    let mut state = env.state;
    let mut qsch = Qsch::new(qsch, env.ledger);
    let mut rsch = Rsch::new(rsch, &state);
    run_observed(&mut state, &mut qsch, &mut rsch, jobs, Vec::new(), &sim, obs)
}

#[test]
fn obs_never_moves_a_digest() {
    // obs off vs verbosity-2 streaming, across the sequential core
    // (shards = 0), the single-worker sharded core and 8 workers, on the
    // plain, elastic and fault-storm arms.
    for (elastic, faults) in [
        (false, FaultPreset::None),
        (true, FaultPreset::None),
        (false, FaultPreset::Storm),
    ] {
        for shards in [0usize, 1, 8] {
            let off = run_arm(7, elastic, faults, shards, &mut ObsRecorder::disabled());
            let buf = SharedBuf::new();
            let mut obs = ObsRecorder::enabled(2).with_sink(Box::new(buf.clone()));
            let on = run_arm(7, elastic, faults, shards, &mut obs);
            assert_eq!(
                off.digest_json().to_string_compact(),
                on.digest_json().to_string_compact(),
                "observability moved the digest: elastic={elastic} \
                 faults={faults:?} shards={shards}"
            );
            // Non-vacuous: the profiled arm actually recorded work.
            assert!(on.health.cycles > 0, "no cycles profiled");
            assert!(
                on.health.decisions > 0,
                "no decisions recorded at verbosity 2"
            );
            // The disabled arm must stay empty — the default path pays
            // no profiling cost and carries no health.
            assert_eq!(off.health, SchedulerHealth::default());
        }
    }
}

#[test]
fn obs_stream_roundtrips_and_ends_with_health() {
    let buf = SharedBuf::new();
    let mut obs = ObsRecorder::enabled(2).with_sink(Box::new(buf.clone()));
    let out = run_arm(3, false, FaultPreset::Storm, 1, &mut obs);

    let text = buf.contents();
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    assert!(!lines.is_empty(), "stream is empty");

    let mut decisions: Vec<DecisionRecord> = Vec::new();
    let mut health: Option<SchedulerHealth> = None;
    for line in &lines {
        let j = Json::parse(line).expect("every stream line is JSON");
        if let Some(rec) = DecisionRecord::from_json(&j) {
            assert!(health.is_none(), "decision after the health trailer");
            decisions.push(rec);
        } else if let Some(h) = SchedulerHealth::from_json(&j) {
            health = Some(h);
        } else {
            panic!("unparseable stream line: {line}");
        }
    }
    let health = health.expect("stream ends with a health trailer");
    assert_eq!(health, out.health, "trailer diverges from SimOutcome.health");
    assert_eq!(
        health.decisions,
        decisions.len() as u64,
        "decision count diverges from the stream"
    );
    assert!(
        decisions.iter().any(|d| d.action == "scheduled"),
        "no scheduled decision in a full run"
    );
    let sched = decisions
        .iter()
        .find(|d| d.action == "scheduled")
        .expect("checked above");
    assert!(!sched.region.is_empty(), "scheduled decision lacks a region");
    assert!(sched.nodes > 0, "scheduled decision lacks node count");
    assert!(!sched.features.is_empty(), "scheduled decision lacks features");

    // Exact JSONL roundtrip for every record, and for the trailer.
    for d in &decisions {
        let j = Json::parse(&d.to_json().to_string_compact()).expect("valid JSON");
        assert_eq!(DecisionRecord::from_json(&j).as_ref(), Some(d));
    }
    let hj = Json::parse(&health.to_json().to_string_compact()).expect("valid JSON");
    assert_eq!(SchedulerHealth::from_json(&hj), Some(health));
}

#[test]
fn verbosity_zero_profiles_without_decisions() {
    let buf = SharedBuf::new();
    let mut obs = ObsRecorder::enabled(0).with_sink(Box::new(buf.clone()));
    let out = run_arm(3, false, FaultPreset::None, 0, &mut obs);
    assert!(out.health.cycles > 0, "phase profiles must still roll up");
    assert_eq!(out.health.decisions, 0, "verbosity 0 must trace nothing");
    // The stream carries exactly the health trailer.
    let text = buf.contents();
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    assert_eq!(lines.len(), 1, "verbosity-0 stream should be trailer-only");
    let j = Json::parse(lines[0]).expect("trailer is JSON");
    assert_eq!(SchedulerHealth::from_json(&j), Some(out.health));
}
