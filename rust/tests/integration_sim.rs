//! Integration: full QSCH+RSCH+simulator runs reproducing the paper's
//! qualitative claims at test-friendly scale. Each test asserts the
//! *shape* of a §5 result (who wins, in which direction).

use kant::cluster::ids::{GpuTypeId, TenantId};
use kant::config::{inference_cluster, training_cluster, InferencePreset, Scale};
use kant::experiments::{jwtd_buckets, run_arm, Arm};
use kant::job::workload::{WorkloadConfig, WorkloadGen};
use kant::sim::SimConfig;

fn sim() -> SimConfig {
    SimConfig::default()
}

/// Shrunk training environment for fast integration runs.
fn quick_training_env(seed: u64, rho: f64) -> kant::config::Environment {
    let mut env = training_cluster(Scale::Small, seed, rho);
    env.horizon_ms = 24 * 3_600_000; // 1 day of arrivals.
    env
}

#[test]
fn backfill_beats_strict_fifo_on_sor() {
    // §5.1.2 / Figure 3: Backfill ≥ Strict FIFO on SOR under contention.
    let env = quick_training_env(42, 0.98);
    let strict = run_arm(&env, &Arm::kant_strict(), &sim());
    let backfill = run_arm(&env, &Arm::kant_backfill(), &sim());
    assert!(
        backfill.metrics.sor_final() >= strict.metrics.sor_final() - 1e-9,
        "backfill {} < strict {}",
        backfill.metrics.sor_final(),
        strict.metrics.sor_final()
    );
    // And it schedules at least as many jobs.
    assert!(backfill.metrics.jobs_finished >= strict.metrics.jobs_finished);
}

#[test]
fn best_effort_starves_large_jobs() {
    // §5.1.2 / Figure 4: without preemption, bypassing inflates the waits
    // of the largest jobs relative to Backfill.
    let env = quick_training_env(43, 1.05); // Overloaded.
    let backfill = run_arm(&env, &Arm::kant_backfill(), &sim());
    let best_effort = run_arm(&env, &Arm::kant_best_effort(), &sim());
    let big = |o: &kant::sim::SimOutcome| {
        let b = jwtd_buckets(&o.store, o.end_ms);
        let s = b.summaries();
        // Mean wait across the two largest non-empty buckets.
        let waits: Vec<f64> = s
            .iter()
            .rev()
            .filter(|(_, sum)| sum.count > 0)
            .take(2)
            .map(|(_, sum)| sum.mean)
            .collect();
        waits.iter().sum::<f64>() / waits.len().max(1) as f64
    };
    assert!(
        big(&best_effort) > big(&backfill),
        "best-effort big-job wait {} must exceed backfill {}",
        big(&best_effort),
        big(&backfill)
    );
}

#[test]
fn ebinpack_cuts_fragmentation_vs_native() {
    // §5.1.3 / Figure 6: E-Binpack collapses GFR vs the spread-like
    // native baseline.
    let env = quick_training_env(44, 0.9);
    let native = run_arm(&env, &Arm::native_baseline(), &sim());
    let ebp = run_arm(&env, &Arm::kant_ebinpack(), &sim());
    assert!(
        ebp.metrics.gfr_avg() < native.metrics.gfr_avg() * 0.6,
        "e-binpack GFR {} not well below native {}",
        ebp.metrics.gfr_avg(),
        native.metrics.gfr_avg()
    );
}

#[test]
fn ebinpack_improves_gar_and_sor() {
    // §5.1.3 / Figure 7.
    let env = quick_training_env(45, 0.95);
    let native = run_arm(&env, &Arm::native_baseline(), &sim());
    let ebp = run_arm(&env, &Arm::kant_ebinpack(), &sim());
    assert!(
        ebp.metrics.sor_final() >= native.metrics.sor_final(),
        "e-binpack SOR {} < native {}",
        ebp.metrics.sor_final(),
        native.metrics.sor_final()
    );
}

#[test]
fn ebinpack_reduces_jtted_group_deviation() {
    // §5.1.3 / Figure 9: placements closer to the optimal topology.
    let env = quick_training_env(46, 0.85);
    let native = run_arm(&env, &Arm::native_baseline(), &sim());
    let ebp = run_arm(&env, &Arm::kant_ebinpack(), &sim());
    let mean_dev = |o: &kant::sim::SimOutcome| {
        let sums = o.metrics.jtted_group_summaries();
        let (mut total, mut n) = (0.0, 0);
        for (_, s) in sums {
            if s.count > 0 {
                total += s.mean * s.count as f64;
                n += s.count;
            }
        }
        total / n.max(1) as f64
    };
    assert!(
        mean_dev(&ebp) <= mean_dev(&native) + 1e-9,
        "e-binpack group deviation {} > native {}",
        mean_dev(&ebp),
        mean_dev(&native)
    );
}

#[test]
fn inference_cluster_runs_hot_and_stable() {
    // §5.2 / Figure 13: near-capacity multi-tenant inference, high GAR.
    let env = inference_cluster(InferencePreset::I2, 47);
    let out = run_arm(&env, &Arm::kant_backfill(), &sim());
    assert!(
        out.metrics.gar_avg() > 0.6,
        "i2 GAR too low: {}",
        out.metrics.gar_avg()
    );
    assert!(out.metrics.gfr_avg() < 0.4);
    // The paper observes "no jobs pending" on i2 — long-lived services may
    // still be *running* at the horizon cut, but none may be stuck queued.
    use kant::job::state::Phase;
    assert_eq!(out.store.count_in_phase(Phase::Queued), 0);
}

#[test]
fn gfr_grows_as_clusters_shrink() {
    // §5.2 / Figure 15: same churn, smaller cluster ⇒ higher GFR.
    let seed = 48;
    let gfr = |p: InferencePreset| {
        let env = inference_cluster(p, seed);
        run_arm(&env, &Arm::kant_backfill(), &sim())
            .metrics
            .gfr_avg()
    };
    let i7 = gfr(InferencePreset::I7);
    let a10 = gfr(InferencePreset::A10);
    assert!(
        a10 > i7,
        "a10 (small) GFR {a10} must exceed i7 (large) {i7}"
    );
}

#[test]
fn quota_isolation_respected_under_load() {
    // §3.2.1: isolated-mode tenants never exceed their limits.
    use kant::cluster::builder::{ClusterBuilder, ClusterSpec};
    use kant::cluster::tenant::{QuotaLedger, QuotaMode};
    use kant::qsch::policy::QschConfig;
    use kant::qsch::Qsch;
    use kant::rsch::{Rsch, RschConfig};
    use kant::sim::run;

    let mut state = ClusterBuilder::build(&ClusterSpec::homogeneous("q", 1, 2, 4));
    let mut ledger = QuotaLedger::new(2, 1, QuotaMode::Isolated);
    ledger.set_limit(TenantId(0), GpuTypeId(0), 16);
    ledger.set_limit(TenantId(1), GpuTypeId(0), 8);
    let mut qsch = Qsch::new(QschConfig::default(), ledger);
    let mut rsch = Rsch::new(RschConfig::default(), &state);

    let mut cfg = WorkloadConfig::paper_training(49);
    cfg.num_tenants = 2;
    cfg.max_gpus = 8;
    cfg.mean_interarrival_ms = 30_000.0;
    let jobs = WorkloadGen::new(cfg).generate(120);
    let out = run(&mut state, &mut qsch, &mut rsch, jobs, &sim());

    // Replay allocation history: at any scheduling instant the per-tenant
    // concurrent GPU usage must respect limits. We verify the end state +
    // ledger consistency (the ledger itself asserts on over-charge).
    assert_eq!(qsch.ledger.entry(TenantId(0), GpuTypeId(0)).used_own, 0);
    assert_eq!(qsch.ledger.entry(TenantId(1), GpuTypeId(0)).used_own, 0);
    assert!(out.metrics.jobs_finished > 0);
}

#[test]
fn full_figure2_distribution_from_env_workload() {
    let env = quick_training_env(50, 0.9);
    let jobs = WorkloadGen::new(env.workload.clone()).generate(5_000);
    let small = jobs.iter().filter(|j| j.total_gpus() <= 8).count() as f64 / jobs.len() as f64;
    assert!(small > 0.9, "small-job share {small}");
}

#[test]
fn deterministic_across_identical_runs() {
    let env = quick_training_env(51, 0.9);
    let a = run_arm(&env, &Arm::kant_backfill(), &sim());
    let b = run_arm(&env, &Arm::kant_backfill(), &sim());
    assert_eq!(a.end_ms, b.end_ms);
    assert_eq!(a.events_processed, b.events_processed);
    assert!((a.metrics.sor_final() - b.metrics.sor_final()).abs() < 1e-15);
    assert!((a.metrics.gfr_avg() - b.metrics.gfr_avg()).abs() < 1e-15);
}
