//! Self-tests for `kant lint`: fixture trees with exact expected
//! `file:line` findings, the allow-annotation round trip, a
//! digest-coverage regression probe over the real sources, and the
//! real-tree gate the CI lint job enforces.

use std::path::{Path, PathBuf};

use kant::lint::{
    self, LintReport, RULE_AMBIENT, RULE_ANNOTATION, RULE_DIGEST, RULE_ORDERED, RULE_WALLCLOCK,
};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/lint_fixtures")
        .join(name)
}

fn src_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("src")
}

fn read_src(rel: &str) -> String {
    std::fs::read_to_string(src_root().join(rel)).expect(rel)
}

/// `(rule, file, line)` triples in report order (already sorted by
/// file, line, rule).
fn triples(r: &LintReport) -> Vec<(&'static str, &str, usize)> {
    r.findings.iter().map(|f| (f.rule, f.file.as_str(), f.line)).collect()
}

#[test]
fn source_rule_fixtures_yield_exact_findings() {
    let r = lint::lint_tree(&fixture("tree")).expect("fixture tree");
    assert_eq!(r.files_scanned, 6);
    assert_eq!(r.digest_fields_checked, 0, "no stats structs in this corpus");
    assert_eq!(
        triples(&r),
        vec![
            (RULE_ANNOTATION, "cluster/allowed.rs", 14), // unused allow
            (RULE_ANNOTATION, "cluster/allowed.rs", 17), // unknown rule
            (RULE_ANNOTATION, "cluster/allowed.rs", 21), // missing reason
            (RULE_ORDERED, "cluster/allowed.rs", 21),    // ...so nothing suppressed
            (RULE_WALLCLOCK, "metrics/wallclock.rs", 5),
            (RULE_WALLCLOCK, "metrics/wallclock.rs", 9),
            (RULE_WALLCLOCK, "metrics/wallclock.rs", 10),
            (RULE_AMBIENT, "qsch/ambient.rs", 4),
            (RULE_AMBIENT, "qsch/ambient.rs", 8),
            (RULE_AMBIENT, "qsch/ambient.rs", 12),
            (RULE_ORDERED, "rsch/ordered_bad.rs", 12),
            (RULE_ORDERED, "rsch/ordered_bad.rs", 16),
            (RULE_ORDERED, "rsch/ordered_bad.rs", 24),
        ],
        "full report:\n{}",
        r.render_text()
    );
    // Spot-check the offending tokens the scanner attributes.
    let what = |file: &str, line: usize| {
        r.findings
            .iter()
            .find(|f| f.file == file && f.line == line)
            .map(|f| f.what.clone())
            .unwrap_or_default()
    };
    assert_eq!(what("rsch/ordered_bad.rs", 12), "self.plans.values()");
    assert_eq!(what("rsch/ordered_bad.rs", 24), "m.drain()");
    assert_eq!(what("qsch/ambient.rs", 4), "thread::current");
    assert_eq!(what("qsch/ambient.rs", 8), "env::var");
    assert_eq!(what("metrics/wallclock.rs", 5), "Instant::now");
}

#[test]
fn allow_annotation_round_trip() {
    let text = std::fs::read_to_string(fixture("tree/cluster/allowed.rs")).unwrap();
    let r = lint::lint_corpus(&[("cluster/allowed.rs".to_string(), text)]);
    // The justified allow on line 7 suppresses the hash iteration on
    // line 8 and is counted as used...
    assert_eq!(r.allows_used, 1);
    assert!(
        !r.findings.iter().any(|f| f.line == 8),
        "suppressed site resurfaced: {}",
        r.render_text()
    );
    // ...while the unused, unknown-rule and reason-less annotations are
    // findings themselves, and the reason-less one suppresses nothing.
    assert_eq!(
        triples(&r),
        vec![
            (RULE_ANNOTATION, "cluster/allowed.rs", 14),
            (RULE_ANNOTATION, "cluster/allowed.rs", 17),
            (RULE_ANNOTATION, "cluster/allowed.rs", 21),
            (RULE_ORDERED, "cluster/allowed.rs", 21),
        ]
    );
}

#[test]
fn digest_coverage_clean_corpus() {
    let r = lint::lint_tree(&fixture("digest_ok")).expect("fixture tree");
    assert!(r.is_clean(), "{}", r.render_text());
    assert_eq!(r.digest_fields_checked, 4);
}

#[test]
fn digest_coverage_flags_drift() {
    let r = lint::lint_tree(&fixture("digest_bad")).expect("fixture tree");
    assert_eq!(r.digest_fields_checked, 5);
    assert_eq!(
        triples(&r),
        vec![
            (RULE_DIGEST, "rsch/mod.rs", 6),   // counter covered by nothing
            (RULE_DIGEST, "sim/runner.rs", 6), // manifest names a ghost counter
            (RULE_DIGEST, "sim/runner.rs", 7), // manifest contradicts digest_json
            (RULE_DIGEST, "sim/runner.rs", 8), // empty reason string
        ],
        "full report:\n{}",
        r.render_text()
    );
    let whats: Vec<&str> = r.findings.iter().map(|f| f.what.as_str()).collect();
    assert_eq!(
        whats,
        vec!["rsch.orphan_counter", "rsch.ghost", "qsch.cycles", "qsch.scheduled"]
    );
}

/// Regression probe: adding a counter to the *real* `QschStats` without
/// covering it must produce exactly one digest-coverage finding. This is
/// the failure a contributor sees if a new stats counter dodges both
/// `digest_json` and `DIGEST_INERT`.
#[test]
fn new_counter_on_real_sources_is_caught() {
    let qsch = read_src("qsch/mod.rs").replace(
        "pub struct QschStats {",
        "pub struct QschStats {\n    pub lint_probe_counter: u64,",
    );
    assert!(qsch.contains("lint_probe_counter"), "surgery target moved");
    let corpus = vec![
        ("qsch/mod.rs".to_string(), qsch),
        ("rsch/mod.rs".to_string(), read_src("rsch/mod.rs")),
        ("sim/runner.rs".to_string(), read_src("sim/runner.rs")),
    ];
    let r = lint::lint_corpus(&corpus);
    assert_eq!(r.findings.len(), 1, "{}", r.render_text());
    assert_eq!(r.findings[0].rule, RULE_DIGEST);
    assert_eq!(r.findings[0].file, "qsch/mod.rs");
    assert_eq!(r.findings[0].what, "qsch.lint_probe_counter");
}

/// The gate itself: the shipped tree is clean, and the digest-coverage
/// rule really engages over all 30 stats counters (16 QSCH + 14 RSCH).
/// If this fails after you add code, run `kant lint` for the findings.
#[test]
fn real_tree_is_clean_and_fully_covered() {
    let r = lint::lint_tree(&src_root()).expect("lint src/");
    assert!(r.is_clean(), "kant lint findings in src/:\n{}", r.render_text());
    assert_eq!(r.digest_fields_checked, 30);
    assert!(r.files_scanned >= 50, "src/ shrank? {} files", r.files_scanned);
}
