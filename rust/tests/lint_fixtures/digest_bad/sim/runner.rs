//! Fixture: manifest drift — stale, contradictory and reason-less
//! entries, each a distinct digest-coverage finding.

pub const DIGEST_INERT: &[(&str, &str)] = &[
    ("rsch.prefetch_batches", "counts fan-out rounds, not outcomes"),
    ("rsch.ghost", "counter that no longer exists"),
    ("qsch.cycles", "claimed inert but the digest reads it"),
    ("qsch.scheduled", ""),
];

pub struct SimOutcome {
    pub qsch_stats: QschStats,
    pub rsch_stats: RschStats,
}

impl SimOutcome {
    pub fn digest_json(&self) -> (u64, u64, u64) {
        (
            self.qsch_stats.cycles,
            self.qsch_stats.scheduled,
            self.rsch_stats.placements,
        )
    }
}
