//! Fixture: RSCH stats mirror with a counter covered by nothing.

pub struct RschStats {
    pub placements: u64,
    pub prefetch_batches: u64,
    pub orphan_counter: u64,
}
