//! Fixture: wall-clock reads — policed by path (this copy is outside
//! the sanctuaries, so every site below is a finding).

pub fn stamp_ms() -> u128 {
    let t = std::time::Instant::now();
    t.elapsed().as_millis()
}

pub fn epoch() -> std::time::SystemTime {
    std::time::SystemTime::now()
}
