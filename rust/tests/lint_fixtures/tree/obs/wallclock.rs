//! Fixture: the same wall-clock reads as metrics/wallclock.rs, but
//! under `obs/` — a sanctioned island, so this copy is clean.

pub fn stamp_ms() -> u128 {
    let t = std::time::Instant::now();
    t.elapsed().as_millis()
}

pub fn epoch() -> std::time::SystemTime {
    std::time::SystemTime::now()
}
