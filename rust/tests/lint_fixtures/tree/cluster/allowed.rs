//! Fixture: the allow-annotation contract, round-tripped.

use std::collections::HashMap;

pub fn merge_counts(m: &HashMap<u64, u64>) -> u64 {
    let mut total = 0;
    // kant-lint: allow(ordered-iteration) — commutative sum over disjoint keys
    for (_k, v) in m {
        total += v;
    }
    total
}

// kant-lint: allow(ordered-iteration) — suppresses nothing below
pub fn noop() {}

// kant-lint: allow(hash-order) — no such rule
pub fn noop2() {}

pub fn peek(m: &HashMap<u64, u64>) -> u64 {
    m.values().copied().next().unwrap_or(0) // kant-lint: allow(ordered-iteration)
}
