//! Fixture: ambient nondeterminism inside the scheduler core.

pub fn worker_tag() -> String {
    format!("{:?}", std::thread::current().id())
}

pub fn tuning() -> Option<String> {
    std::env::var("KANT_TUNING").ok()
}

pub fn hasher() -> impl std::hash::BuildHasher {
    std::collections::hash_map::RandomState::new()
}
