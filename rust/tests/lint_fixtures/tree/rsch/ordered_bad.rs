//! Fixture: hash-container iteration in a digest-affecting module.

use std::collections::{HashMap, HashSet};

pub struct Cache {
    plans: HashMap<u64, Vec<u64>>,
    seen: HashSet<u64>,
}

impl Cache {
    pub fn all_plans(&self) -> Vec<u64> {
        self.plans.values().flatten().copied().collect()
    }

    pub fn first_seen(&self) -> Option<u64> {
        for s in &self.seen {
            return Some(*s);
        }
        None
    }
}

pub fn drain_pairs(m: &mut HashMap<u64, u64>) -> Vec<(u64, u64)> {
    m.drain().collect()
}
