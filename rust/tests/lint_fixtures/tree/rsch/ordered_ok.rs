//! Fixture: stable or provably commutative traversals — all clean.

use std::collections::{BTreeMap, HashMap, HashSet};

pub struct Sorted {
    plans: BTreeMap<u64, Vec<u64>>,
}

pub struct Footprint {
    nodes: HashSet<u64>,
}

pub struct Group {
    nodes: Vec<u64>,
}

impl Sorted {
    pub fn all(&self) -> Vec<u64> {
        self.plans.values().flatten().copied().collect()
    }
}

impl Footprint {
    pub fn contains(&self, n: u64) -> bool {
        self.nodes.contains(&n)
    }

    pub fn width(&self) -> usize {
        self.nodes.iter().count()
    }
}

impl Group {
    pub fn first(&self) -> Option<u64> {
        self.nodes.iter().copied().next()
    }
}

pub fn total(m: &HashMap<u64, u64>) -> u64 {
    m.values().sum()
}
