//! Fixture: digest + inert manifest covering every counter.

pub const DIGEST_INERT: &[(&str, &str)] = &[
    ("rsch.prefetch_batches", "counts fan-out rounds, not outcomes"),
];

pub struct SimOutcome {
    pub qsch_stats: QschStats,
    pub rsch_stats: RschStats,
}

impl SimOutcome {
    pub fn digest_json(&self) -> (u64, u64, u64) {
        (
            self.qsch_stats.cycles,
            self.qsch_stats.scheduled,
            self.rsch_stats.placements,
        )
    }
}
