//! Fixture: minimal RSCH stats mirror.

pub struct RschStats {
    pub placements: u64,
    pub prefetch_batches: u64,
}
