//! Fixture: minimal QSCH stats mirror.

pub struct QschStats {
    pub cycles: u64,
    pub scheduled: u64,
}
