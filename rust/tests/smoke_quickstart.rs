//! Smoke test mirroring `examples/quickstart.rs`'s core path — small
//! cluster, short horizon, the default Kant stack — so example rot is
//! caught by tier-1 (`cargo test`) instead of only by humans running the
//! example. Keep in lockstep with the example's workload.

use kant::cluster::builder::{ClusterBuilder, ClusterSpec};
use kant::cluster::ids::{GpuTypeId, JobId, TenantId};
use kant::cluster::tenant::{QuotaLedger, QuotaMode};
use kant::job::spec::{JobKind, JobSpec, Priority};
use kant::metrics::report::headline;
use kant::qsch::policy::QschConfig;
use kant::qsch::Qsch;
use kant::rsch::{Rsch, RschConfig};
use kant::sim::{run, SimConfig};

/// The quickstart workload: one big gang, small training jobs, an HA
/// inference deployment, then a second large gang.
fn quickstart_jobs() -> Vec<JobSpec> {
    let mut jobs = vec![
        JobSpec::homogeneous(JobId(1), TenantId(0), JobKind::Training, GpuTypeId(0), 8, 8)
            .with_times(0, 30 * 60_000)
            .with_priority(Priority::HIGH),
        JobSpec::homogeneous(JobId(2), TenantId(0), JobKind::Training, GpuTypeId(0), 1, 4)
            .with_times(10_000, 20 * 60_000),
        JobSpec::homogeneous(JobId(3), TenantId(1), JobKind::Training, GpuTypeId(0), 1, 2)
            .with_times(15_000, 10 * 60_000),
        JobSpec::homogeneous(JobId(4), TenantId(1), JobKind::Inference, GpuTypeId(0), 6, 1)
            .with_times(20_000, 60 * 60_000),
        JobSpec::homogeneous(JobId(5), TenantId(0), JobKind::Training, GpuTypeId(0), 16, 8)
            .with_times(30_000, 45 * 60_000),
    ];
    jobs.sort_by_key(|j| j.submit_ms);
    jobs
}

#[test]
fn quickstart_core_path_drains_cleanly() {
    // Same shape as the example: 2 spines × 2 groups × 8 nodes = 256 GPUs.
    let mut state = ClusterBuilder::build(&ClusterSpec::homogeneous("quickstart", 2, 2, 8));
    assert_eq!(state.total_gpus(), 256);

    let mut ledger = QuotaLedger::new(2, 1, QuotaMode::Shared);
    ledger.set_limit(TenantId(0), GpuTypeId(0), 160);
    ledger.set_limit(TenantId(1), GpuTypeId(0), 96);

    let mut qsch = Qsch::new(QschConfig::default(), ledger);
    let mut rsch = Rsch::new(RschConfig::default(), &state);

    let out = run(
        &mut state,
        &mut qsch,
        &mut rsch,
        quickstart_jobs(),
        &SimConfig::default(),
    );

    // Every job must finish and release its resources.
    assert_eq!(out.unfinished_jobs, 0, "quickstart workload must drain");
    assert_eq!(out.metrics.jobs_finished, 5);
    assert_eq!(state.allocated_gpus(), 0);

    // The metrics the example prints must be populated and sane.
    assert!(out.metrics.sor_final() > 0.0);
    assert!(out.metrics.gar_avg() > 0.0 && out.metrics.gar_avg() <= 1.0);
    let report = headline("quickstart", &out.metrics);
    assert!(report.contains("quickstart"));

    // Per-job lifecycle fields the example reads.
    for id in 1..=5u64 {
        let j = out.store.expect(JobId(id));
        assert!(j.is_terminal(), "job {id} not finished: {:?}", j.phase);
        assert!(j.scheduled_ms.is_some(), "job {id} never scheduled");
    }
}

#[test]
fn quickstart_big_gang_gets_whole_nodes() {
    let mut state = ClusterBuilder::build(&ClusterSpec::homogeneous("quickstart", 2, 2, 8));
    let mut ledger = QuotaLedger::new(2, 1, QuotaMode::Shared);
    ledger.set_limit(TenantId(0), GpuTypeId(0), 160);
    ledger.set_limit(TenantId(1), GpuTypeId(0), 96);
    let mut qsch = Qsch::new(QschConfig::default(), ledger);
    let mut rsch = Rsch::new(RschConfig::default(), &state);

    // Only the 64-GPU gang: it must land on exactly 8 whole nodes.
    let jobs = vec![quickstart_jobs().remove(0)];
    let horizon = SimConfig {
        horizon_ms: 5 * 60_000, // Cut before it finishes: still placed.
        ..SimConfig::default()
    };
    run(&mut state, &mut qsch, &mut rsch, jobs, &horizon);
    let nodes = state.nodes_of(JobId(1));
    assert_eq!(nodes.len(), 8);
    for n in &nodes {
        assert_eq!(state.node(*n).free_gpus(), 0, "gang pods take whole boards");
    }
}
