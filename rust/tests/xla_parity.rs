//! Parity: the AOT XLA scorer artifacts must reproduce the native Rust
//! scorer bit-for-bit (within f32 tolerance) on randomized inputs — the
//! contract that makes the two backends interchangeable on the hot path.
//!
//! Requires the `xla` cargo feature (the whole file is compiled out
//! otherwise) and `make artifacts`; tests self-skip when artifacts are
//! missing.

#![cfg(feature = "xla")]

use kant::rsch::features::{GROUP_F, NODE_F};
use kant::rsch::score::{
    group_weights, node_weights, NativeBackend, Phase, ScoreBackend, GROUP_COMPONENTS,
    NUM_COMPONENTS,
};
use kant::job::spec::PlacementStrategy;
use kant::runtime::XlaBackend;
use kant::util::rng::Pcg32;

fn artifacts() -> Option<&'static str> {
    std::path::Path::new("artifacts/manifest.json")
        .exists()
        .then_some("artifacts")
}

fn random_node_features(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    let mut feat = vec![0.0f32; n * NODE_F];
    for i in 0..n {
        let row = &mut feat[i * NODE_F..(i + 1) * NODE_F];
        let total = *rng.choose(&[4.0f32, 8.0]).unwrap();
        let alloc = rng.below(total as u64 + 1) as f32;
        row[0] = total - alloc; // free
        row[1] = total;
        row[2] = alloc;
        row[3] = if rng.chance(0.9) { 1.0 } else { 0.0 };
        row[4] = rng.below(257) as f32; // group_free
        row[5] = 256.0;
        row[6] = rng.below(9) as f32; // pods_on_node
        row[7] = rng.below(17) as f32;
        row[8] = rng.below(5) as f32; // topo tier (0..=4, cross-superspine)
        row[9] = if rng.chance(0.3) { 1.0 } else { 0.0 };
        row[10] = rng.below(65) as f32;
        row[11] = rng.below(row[0] as u64 + 1) as f32;
    }
    feat
}

fn random_group_features(rng: &mut Pcg32, g: usize) -> Vec<f32> {
    let mut feat = vec![0.0f32; g * GROUP_F];
    for i in 0..g {
        let row = &mut feat[i * GROUP_F..(i + 1) * GROUP_F];
        row[0] = rng.below(257) as f32;
        row[1] = 256.0;
        row[2] = rng.below(33) as f32;
        row[3] = rng.f64() as f32;
        row[4] = rng.uniform(0.5, 1.0) as f32;
        row[5] = rng.below(33) as f32;
    }
    feat
}

#[test]
fn node_scorer_parity_random_sweep() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut xla = XlaBackend::new(dir).unwrap();
    let mut native = NativeBackend;
    let mut rng = Pcg32::seed_from_u64(0xA11CE);
    let strategies = [
        PlacementStrategy::NativeFirstFit,
        PlacementStrategy::Binpack,
        PlacementStrategy::EBinpack,
        PlacementStrategy::Spread,
        PlacementStrategy::ESpread,
    ];
    for case in 0..20 {
        let n = rng.range_inclusive(1, 700) as usize;
        let feat = random_node_features(&mut rng, n);
        let gpp = *rng.choose(&[1.0f32, 2.0, 4.0, 8.0]).unwrap();
        let job = [gpp, gpp * 4.0, 1.0, 0.0, 0.0, 2.0, 0.0, 0.0];
        let strat = *rng.choose(&strategies).unwrap();
        let phase = if rng.chance(0.5) {
            Phase::Primary
        } else {
            Phase::Fallback
        };
        let w: [f32; NUM_COMPONENTS] = node_weights(strat, phase, rng.chance(0.3));
        let a = xla.score_nodes(&feat, n, &job, &w);
        let b = native.score_nodes(&feat, n, &job, &w);
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!(
                (x - y).abs() <= 1e-3 * y.abs().max(1.0),
                "case {case} node {i}: xla={x} native={y} (n={n}, strat={strat:?})"
            );
        }
    }
}

#[test]
fn group_scorer_parity_random_sweep() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut xla = XlaBackend::new(dir).unwrap();
    let mut native = NativeBackend;
    let mut rng = Pcg32::seed_from_u64(0xB0B);
    for case in 0..15 {
        let g = rng.range_inclusive(1, 200) as usize;
        let feat = random_group_features(&mut rng, g);
        let job = [8.0, 256.0, 1.0, 0.0, 1.0, 2.0, 0.0, 0.0];
        let w: [f32; GROUP_COMPONENTS] =
            group_weights(PlacementStrategy::EBinpack, Phase::Primary, rng.chance(0.5));
        let a = xla.score_groups(&feat, g, &job, &w);
        let b = native.score_groups(&feat, g, &job, &w);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!(
                (x - y).abs() <= 1e-3 * y.abs().max(1.0),
                "case {case} group {i}: xla={x} native={y}"
            );
        }
    }
}

#[test]
fn chunking_over_largest_artifact() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut xla = XlaBackend::new(dir).unwrap();
    let mut native = NativeBackend;
    let mut rng = Pcg32::seed_from_u64(0xC0FFEE);
    // Bigger than the largest (4096) artifact → must chunk.
    let n = 5000;
    let feat = random_node_features(&mut rng, n);
    let job = [4.0, 64.0, 1.0, 0.0, 0.0, 2.0, 0.0, 0.0];
    let w = node_weights(PlacementStrategy::EBinpack, Phase::Primary, false);
    let a = xla.score_nodes(&feat, n, &job, &w);
    let b = native.score_nodes(&feat, n, &job, &w);
    assert_eq!(a.len(), n);
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() <= 1e-3 * y.abs().max(1.0));
    }
    assert!(xla.launches >= 2, "must have chunked");
}

#[test]
fn full_scheduler_run_is_decision_identical() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    use kant::config::{training_cluster, Scale};
    use kant::job::workload::WorkloadGen;
    use kant::qsch::Qsch;
    use kant::rsch::{Rsch, RschConfig};
    use kant::sim::{run, SimConfig};

    let mut env = training_cluster(Scale::Small, 3, 0.9);
    env.horizon_ms = 2 * 3_600_000;
    let jobs = WorkloadGen::new(env.workload.clone()).generate_until(env.horizon_ms);
    let sim = SimConfig {
        horizon_ms: env.horizon_ms + 6 * 3_600_000,
        ..SimConfig::default()
    };

    let mut s1 = env.state.clone();
    let mut q1 = Qsch::new(kant::qsch::policy::QschConfig::default(), env.ledger.clone());
    let backend = XlaBackend::new(dir).unwrap();
    let mut r1 = Rsch::with_backend(RschConfig::default(), &s1, Box::new(backend));
    let xla_out = run(&mut s1, &mut q1, &mut r1, jobs.clone(), &sim);

    let mut s2 = env.state.clone();
    let mut q2 = Qsch::new(kant::qsch::policy::QschConfig::default(), env.ledger.clone());
    let mut r2 = Rsch::new(RschConfig::default(), &s2);
    let native_out = run(&mut s2, &mut q2, &mut r2, jobs, &sim);

    assert_eq!(xla_out.metrics.jobs_finished, native_out.metrics.jobs_finished);
    assert_eq!(xla_out.end_ms, native_out.end_ms);
    assert!((xla_out.metrics.sor_final() - native_out.metrics.sor_final()).abs() < 1e-12);
    assert!((xla_out.metrics.gfr_avg() - native_out.metrics.gfr_avg()).abs() < 1e-12);
}
