//! Deliberately contended superspine-sharded prefetch: the CI
//! `sanitize` job runs this whole file under ThreadSanitizer.
//!
//! The cluster spans 8 superspines (one shard each) but confines the
//! hot GPU type to superspine 0, and 4 of every 5 jobs want that type —
//! so shard 0's worker is saturated while seven others spin on small
//! batches, maximising cross-thread traffic on the shared snapshot and
//! the plan-merge path. The digest must still be byte-identical for
//! every worker count, and TSan must see no data race getting there.

use kant::cluster::{ClusterBuilder, ClusterSpec, GpuModel, GpuTypeProfile};
use kant::cluster::{GpuTypeId, JobId, QuotaLedger, QuotaMode, TenantId};
use kant::job::spec::{JobKind, JobSpec};
use kant::qsch::policy::QschConfig;
use kant::qsch::Qsch;
use kant::rsch::{Rsch, RschConfig};
use kant::sim::{run, SimConfig, SimOutcome};

/// 8 superspines × 1 spine × 2 groups × 4 nodes × 8 GPUs = 512 GPUs.
/// The first profile covers exactly superspine 0's two groups, so
/// `GpuTypeId(0)` demand can route to one shard and nowhere else.
fn skewed_cluster() -> ClusterSpec {
    ClusterSpec {
        name: "stress8".to_string(),
        gpu_types: vec![
            GpuTypeProfile {
                model: GpuModel::TypeH,
                groups: 2,
            },
            GpuTypeProfile {
                model: GpuModel::TypeA,
                groups: 14,
            },
        ],
        groups_per_spine: 2,
        spines_per_superspine: 1,
        nodes_per_group: 4,
        hbd_size: 0,
        inference_zone_frac: 0.0,
    }
}

/// 140 training gangs over ~105 s of arrivals; 112 of them chase the
/// 64-GPU hot superspine (sustained queueing and eviction-free
/// contention), 28 spread over the 448 cold GPUs.
fn skewed_jobs() -> Vec<JobSpec> {
    (0..140u64)
        .map(|i| {
            let hot = i % 5 != 0;
            let gpu = if hot { GpuTypeId(0) } else { GpuTypeId(1) };
            let replicas = 1 + (i % 3) as u32;
            let gpus_per_pod = if i % 2 == 0 { 8 } else { 4 };
            let duration_ms = 45_000 + (i % 7) * 15_000;
            JobSpec::homogeneous(
                JobId(i),
                TenantId((i % 2) as u32),
                JobKind::Training,
                gpu,
                replicas,
                gpus_per_pod,
            )
            .with_times(i * 750, duration_ms)
        })
        .collect()
}

fn outcome(batch_shards: usize) -> SimOutcome {
    let mut state = ClusterBuilder::build(&skewed_cluster());
    let mut ledger = QuotaLedger::new(2, 2, QuotaMode::Shared);
    for t in 0..2u32 {
        ledger.set_limit(TenantId(t), GpuTypeId(0), 512);
        ledger.set_limit(TenantId(t), GpuTypeId(1), 512);
    }
    let qcfg = QschConfig {
        batch_shards,
        ..QschConfig::default()
    };
    let mut qsch = Qsch::new(qcfg, ledger);
    let mut rsch = Rsch::new(RschConfig::default(), &state);
    run(&mut state, &mut qsch, &mut rsch, skewed_jobs(), &SimConfig::default())
}

#[test]
fn stress_digest_invariant_across_worker_counts() {
    let base = outcome(1).digest_json().to_string_compact();
    for workers in [2usize, 3, 5, 8] {
        let got = outcome(workers).digest_json().to_string_compact();
        assert_eq!(
            base, got,
            "skewed prefetch digest moved with worker count {workers}"
        );
    }
}

#[test]
fn stress_scenario_actually_contends() {
    // Guard against the stress test rotting into a no-op: prove the
    // adversarial shape engaged the prefetch path.
    let o = outcome(8);
    assert!(o.rsch_stats.placements > 0, "nothing placed");
    assert!(o.rsch_stats.prefetch_batches > 0, "prefetch never ran");
    // Every counted batch contributes >= 1.0 (fullest shard / even
    // split); equality would mean perfectly balanced routing, which the
    // hot-type skew makes impossible over the whole run.
    assert!(
        o.rsch_stats.prefetch_imbalance_sum >= o.rsch_stats.prefetch_batches as f64,
        "imbalance telemetry broke its lower bound"
    );
    // The hot type outnumbers its 64-GPU island: queueing must happen.
    assert!(
        o.qsch_stats.placement_failures > 0 || o.qsch_stats.requeues > 0,
        "hot superspine never saturated — the skew is gone"
    );
}
