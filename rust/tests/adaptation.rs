//! Integration tests for the adaptive weight controller: digest
//! determinism across seeds and shard counts, the hard anti-starvation
//! bound end-to-end, and the frozen `--no-adapt` baseline.

use kant::config::Scale;
use kant::experiments::{class_jwtd_p99, weight_adaptation_arm, ADAPT_JWTD_BOUND_MS};
use kant::job::spec::{PlacementStrategy, Priority};
use kant::rsch::score::{group_weights, node_weights, Phase};
use kant::rsch::RschConfig;

const ARRIVAL_MS: u64 = 2 * 3_600_000;

fn digest(seed: u64, adapt: bool, bound_ms: u64, shards: usize) -> String {
    weight_adaptation_arm(Scale::Small, seed, ARRIVAL_MS, adapt, bound_ms, shards)
        .digest_json()
        .to_string_compact()
}

#[test]
fn adaptive_digests_deterministic_across_seeds_and_shards() {
    // The controller updates in the single-threaded QSCH phase, so
    // same-seed adaptive runs must be byte-identical for --shards
    // {0, 1, 8}; different seeds must diverge (the digest is live).
    let mut per_seed = Vec::new();
    for seed in [3u64, 7, 11] {
        let base = digest(seed, true, ADAPT_JWTD_BOUND_MS, 0);
        for shards in [1usize, 8] {
            assert_eq!(
                base,
                digest(seed, true, ADAPT_JWTD_BOUND_MS, shards),
                "adaptive digest moved with thread count: seed={seed} shards={shards}"
            );
        }
        per_seed.push(base);
    }
    assert_ne!(per_seed[0], per_seed[1], "seeds 3 and 7 must diverge");
    assert_ne!(per_seed[1], per_seed[2], "seeds 7 and 11 must diverge");
}

#[test]
fn anti_starvation_bound_holds_end_to_end() {
    let out = weight_adaptation_arm(Scale::Small, 7, ARRIVAL_MS, true, ADAPT_JWTD_BOUND_MS, 0);
    for class in 0..Priority::NUM_CLASSES {
        let p99 = class_jwtd_p99(&out.store, out.end_ms, class);
        assert!(
            p99 <= ADAPT_JWTD_BOUND_MS as f64,
            "class {class} censored p99 wait {p99} broke the {ADAPT_JWTD_BOUND_MS} ms bound"
        );
    }
    assert!(out.rsch_stats.adapt_ticks > 0, "controller never ticked");
    // The adaptive trajectory and starvation pass are both part of the
    // digest, so divergent trajectories cannot hide behind matching
    // job rows.
    let d = out.digest_json().to_string_compact();
    assert!(d.contains("rsch_adapt_fingerprint"), "{d}");
    assert!(d.contains("qsch_starvation_rescues"), "{d}");
}

#[test]
fn no_adapt_baseline_keeps_the_frozen_tables_and_digest() {
    // `--no-adapt` (the default) freezes the static weight tables: a
    // dormant controller contributes nothing to the run...
    let out = weight_adaptation_arm(Scale::Small, 7, ARRIVAL_MS, false, 0, 0);
    assert_eq!(out.rsch_stats.adapt_ticks, 0);
    assert_eq!(out.rsch_stats.adapt_shifts, 0);
    let d = out.digest_json().to_string_compact();
    assert!(
        d.contains("0000000000000000"),
        "dormant controller left a fingerprint: {d}"
    );
    // ... and the effective weight rows are exactly the frozen statics
    // for every strategy × phase × size combination.
    let cfg = RschConfig::default();
    for strat in [
        PlacementStrategy::NativeFirstFit,
        PlacementStrategy::Binpack,
        PlacementStrategy::EBinpack,
        PlacementStrategy::Spread,
        PlacementStrategy::ESpread,
    ] {
        for phase in [Phase::Primary, Phase::Fallback] {
            for large in [false, true] {
                assert_eq!(
                    cfg.node_w(strat, phase, large),
                    node_weights(strat, phase, large),
                    "{strat:?}/{phase:?}/large={large} node row drifted off the frozen table"
                );
                assert_eq!(
                    cfg.group_w(strat, phase, large),
                    group_weights(strat, phase, large),
                    "{strat:?}/{phase:?}/large={large} group row drifted off the frozen table"
                );
            }
        }
    }
}
