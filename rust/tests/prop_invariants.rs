//! System-level property tests: invariants that must hold for *any*
//! workload, checked over randomized end-to-end simulations.

use kant::cluster::builder::{ClusterBuilder, ClusterSpec};
use kant::cluster::ids::{GpuTypeId, JobId, TenantId};
use kant::cluster::tenant::{QuotaLedger, QuotaMode};
use kant::job::spec::{JobKind, JobSpec, Priority};
use kant::job::state::Phase;
use kant::prop_assert;
use kant::qsch::policy::{QschConfig, QueuePolicy};
use kant::qsch::Qsch;
use kant::rsch::{Rsch, RschConfig};
use kant::sim::{run, SimConfig};
use kant::util::prop;
use kant::util::rng::Pcg32;

const G: GpuTypeId = GpuTypeId(0);

fn random_job(rng: &mut Pcg32, id: u64, horizon_ms: u64) -> JobSpec {
    let sizes = [1u32, 2, 4, 8, 16, 32, 64];
    let gpus = *rng.choose(&sizes).unwrap();
    let (replicas, gpp) = if gpus > 8 { (gpus / 8, 8) } else { (1, gpus) };
    let kind = if rng.chance(0.7) {
        JobKind::Training
    } else {
        JobKind::Inference
    };
    let mut j =
        JobSpec::homogeneous(JobId(id), TenantId(rng.below(3) as u32), kind, G, replicas, gpp)
            .with_times(rng.below(horizon_ms), rng.range_inclusive(30_000, 600_000));
    j.priority = *rng
        .choose(&[Priority::LOW, Priority::NORMAL, Priority::HIGH])
        .unwrap();
    j
}

fn random_stack(rng: &mut Pcg32) -> (kant::cluster::state::ClusterState, Qsch, Rsch) {
    let groups = rng.range_inclusive(1, 3) as u32;
    let nodes = rng.range_inclusive(2, 6) as u32;
    let state = ClusterBuilder::build(&ClusterSpec::homogeneous("p", 1, groups, nodes));
    let mode = if rng.chance(0.5) {
        QuotaMode::Shared
    } else {
        QuotaMode::Isolated
    };
    let mut ledger = QuotaLedger::new(3, 1, mode);
    for t in 0..3 {
        ledger.set_limit(
            TenantId(t),
            G,
            rng.range_inclusive(8, state.total_gpus() as u64) as u32,
        );
    }
    let policy = *rng
        .choose(&[
            QueuePolicy::StrictFifo,
            QueuePolicy::BestEffortFifo,
            QueuePolicy::Backfill,
        ])
        .unwrap();
    let qcfg = QschConfig {
        policy,
        backfill_timeout_ms: rng.range_inclusive(60_000, 1_800_000),
        ..QschConfig::default()
    };
    let rcfg = RschConfig {
        two_level: rng.chance(0.7),
        snapshot_mode: if rng.chance(0.5) {
            kant::cluster::snapshot::SnapshotMode::Incremental
        } else {
            kant::cluster::snapshot::SnapshotMode::DeepCopy
        },
        ..RschConfig::default()
    };
    let rsch = Rsch::new(rcfg, &state);
    (state, Qsch::new(qcfg, ledger), rsch)
}

#[test]
fn random_sims_preserve_core_invariants() {
    prop::check(25, |rng| {
        let (mut state, mut qsch, mut rsch) = random_stack(rng);
        let horizon = 2 * 3_600_000;
        let n_jobs = rng.range_inclusive(5, 60);
        let mut jobs: Vec<JobSpec> = (1..=n_jobs)
            .map(|id| random_job(rng, id, horizon))
            .collect();
        jobs.sort_by_key(|j| j.submit_ms);
        let total = state.total_gpus();
        let cfg = SimConfig {
            horizon_ms: horizon * 4,
            stall_cycles: 500,
            ..SimConfig::default()
        };
        let out = run(&mut state, &mut qsch, &mut rsch, jobs, &cfg);

        // 1. Metric bounds.
        prop_assert!(
            (0.0..=1.0).contains(&out.metrics.gar_avg()),
            "GAR out of range: {}",
            out.metrics.gar_avg()
        );
        prop_assert!(
            (0.0..=1.0).contains(&out.metrics.sor_final()),
            "SOR out of range"
        );
        prop_assert!(
            (0.0..=1.0).contains(&out.metrics.gfr_avg()),
            "GFR out of range"
        );

        // 2. Conservation: every finished job released its GPUs; no
        //    devices leak.
        let allocated_now = state.allocated_gpus();
        let holding: u32 = out
            .store
            .iter()
            .filter(|j| j.holds_resources())
            .map(|j| j.spec.total_gpus())
            .sum();
        prop_assert!(
            allocated_now == holding,
            "allocation leak: state {allocated_now} vs holders {holding}"
        );
        prop_assert!(allocated_now <= total, "over-allocation");

        // 3. No double allocation at device level.
        for node in &state.nodes {
            let mut seen = std::collections::HashSet::new();
            for gpu in &node.gpus {
                if let Some(pod) = gpu.allocated_to {
                    prop_assert!(
                        seen.insert((pod, gpu.index)),
                        "duplicate device binding on {}",
                        node.id
                    );
                }
            }
        }

        // 4. Gang jobs: every scheduled gang job has ALL replicas placed.
        for j in out.store.iter() {
            if j.holds_resources() {
                let placements = state.placements_of(j.id()).expect("holder has placement");
                prop_assert!(
                    placements.len() as u32 == j.spec.total_replicas(),
                    "job {} holds {} of {} pods",
                    j.id(),
                    placements.len(),
                    j.spec.total_replicas()
                );
                let gpus: u32 = placements.iter().map(|p| p.devices.len() as u32).sum();
                prop_assert!(
                    gpus == j.spec.total_gpus(),
                    "job {} device-count mismatch",
                    j.id()
                );
            }
        }

        // 5. Terminal jobs hold nothing.
        for j in out.store.iter() {
            if j.is_terminal() {
                prop_assert!(
                    state.placements_of(j.id()).is_none(),
                    "finished job {} still placed",
                    j.id()
                );
            }
        }

        // 6. Quota ledger zeroed for finished-everything runs.
        if out.unfinished_jobs == 0 {
            for t in 0..3 {
                let e = qsch.ledger.entry(TenantId(t), G);
                prop_assert!(
                    e.used_own == 0 && e.borrowed == 0 && e.lent == 0,
                    "ledger not drained for tenant {t}: {e:?}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn indexed_candidate_selection_matches_linear_scan() {
    // The free-capacity node index is a pure acceleration structure:
    // across random clusters (zones, HBDs), random job streams (all
    // strategies, gangs, releases) and random health churn, every
    // placement it produces must be byte-identical to the linear scan's.
    use kant::cluster::gpu::Health;
    use kant::cluster::ids::NodeId;
    use kant::job::spec::PlacementStrategy;
    use kant::qsch::Placer;

    prop::check(20, |rng| {
        let mut spec_c = ClusterSpec::homogeneous(
            "ix",
            1,
            rng.range_inclusive(1, 4) as u32,
            rng.range_inclusive(2, 6) as u32,
        );
        if rng.chance(0.4) {
            spec_c.inference_zone_frac = 0.25;
        }
        if rng.chance(0.3) {
            spec_c.hbd_size = 2;
        }
        let mut s_lin = ClusterBuilder::build(&spec_c);
        let mut s_idx = s_lin.clone();
        let num_nodes = s_lin.nodes.len() as u64;
        let base = RschConfig {
            two_level: rng.chance(0.5),
            snapshot_mode: if rng.chance(0.5) {
                kant::cluster::snapshot::SnapshotMode::Incremental
            } else {
                kant::cluster::snapshot::SnapshotMode::DeepCopy
            },
            ..RschConfig::default()
        };
        let mut lin = Rsch::new(
            RschConfig {
                indexed_candidates: false,
                ..base.clone()
            },
            &s_lin,
        );
        let mut idx = Rsch::new(
            RschConfig {
                indexed_candidates: true,
                ..base
            },
            &s_idx,
        );
        let mut live: Vec<JobId> = Vec::new();
        let mut next = 1u64;
        for step in 0..rng.range_inclusive(10, 50) {
            match rng.below(5) {
                0..=2 => {
                    let gpp = rng.range_inclusive(1, 8) as u32;
                    let replicas = rng.range_inclusive(1, 3) as u32;
                    let kind = if rng.chance(0.6) {
                        JobKind::Training
                    } else {
                        JobKind::Inference
                    };
                    let mut j = JobSpec::homogeneous(
                        JobId(next),
                        TenantId(0),
                        kind,
                        G,
                        replicas,
                        gpp,
                    );
                    if rng.chance(0.6) {
                        j.strategy = Some(
                            *rng.choose(&[
                                PlacementStrategy::NativeFirstFit,
                                PlacementStrategy::Binpack,
                                PlacementStrategy::EBinpack,
                                PlacementStrategy::Spread,
                                PlacementStrategy::ESpread,
                            ])
                            .unwrap(),
                        );
                    }
                    if spec_c.hbd_size > 1 && rng.chance(0.3) {
                        j.needs_hbd = true;
                    }
                    j.gang = rng.chance(0.7);
                    let a = lin.place(&mut s_lin, &j);
                    let b = idx.place(&mut s_idx, &j);
                    prop_assert!(
                        a == b,
                        "outcome diverged at step {step} for job {}: {a:?} vs {b:?}",
                        j.id
                    );
                    prop_assert!(
                        s_lin.placements_of(j.id) == s_idx.placements_of(j.id),
                        "placements diverged at step {step} for job {}",
                        j.id
                    );
                    if a.is_ok() {
                        live.push(j.id);
                    }
                    next += 1;
                }
                3 => {
                    if let Some(i) = (!live.is_empty())
                        .then(|| rng.below(live.len() as u64) as usize)
                    {
                        let j = live.swap_remove(i);
                        s_lin.release_job(j).unwrap();
                        s_idx.release_job(j).unwrap();
                    }
                }
                _ => {
                    // Health churn on idle nodes (both worlds identically).
                    let node = NodeId(rng.below(num_nodes) as u32);
                    if s_lin.node(node).allocated_gpus() == 0 {
                        let h = if s_lin.node(node).health.schedulable() {
                            Health::Cordoned
                        } else {
                            Health::Healthy
                        };
                        s_lin.set_node_health(node, h);
                        s_idx.set_node_health(node, h);
                    }
                }
            }
        }
        prop_assert!(
            s_lin.allocated_gpus() == s_idx.allocated_gpus(),
            "allocation totals diverged"
        );
        for &j in &live {
            prop_assert!(
                s_lin.placements_of(j) == s_idx.placements_of(j),
                "final placements diverged for job {j}"
            );
        }
        Ok(())
    });
}

#[test]
fn health_storms_leak_no_allocations_and_aggregates_match_index() {
    // Reliability-subsystem invariant: after an arbitrary seeded storm of
    // health transitions (the full Healthy → Cordoned/Draining → Faulty →
    // Repairing lifecycle, on allocated nodes too), device-level faults,
    // fault-style evictions and releases-mid-drain, no device allocation
    // is leaked and the maintained free-GPU aggregates agree with both a
    // from-scratch recount and the NodeIndex buckets.
    use kant::cluster::gpu::Health;
    use kant::cluster::ids::{GroupId, NodeId, PodId};
    use kant::cluster::index::{NodeIndex, ZoneQuery};
    use kant::cluster::snapshot::{Snapshot, SnapshotMode};
    use kant::cluster::state::PodPlacement;

    prop::check(30, |rng| {
        let groups = rng.range_inclusive(1, 3) as u32;
        let nodes_per = rng.range_inclusive(2, 5) as u32;
        let mut s = ClusterBuilder::build(&ClusterSpec::homogeneous("hs", 1, groups, nodes_per));
        let mut snap = Snapshot::with_index(SnapshotMode::Incremental, true);
        snap.refresh(&s);
        let num_nodes = s.nodes.len();
        let healths = [
            Health::Healthy,
            Health::Cordoned,
            Health::Draining,
            Health::Faulty,
            Health::Repairing,
        ];
        let mut live: Vec<(u64, NodeId)> = Vec::new();
        let mut next = 1u64;
        for step in 0..rng.range_inclusive(20, 80) {
            match rng.below(6) {
                0 | 1 => {
                    // Place a 1-4 GPU pod on a random schedulable node.
                    let node = NodeId(rng.below(num_nodes as u64) as u32);
                    let want = rng.range_inclusive(1, 4) as usize;
                    let free = s.node(node).free_gpu_indices();
                    if free.len() >= want && s.node(node).health.schedulable() {
                        s.commit_placements(
                            JobId(next),
                            vec![PodPlacement {
                                pod: PodId::new(JobId(next), 0),
                                node,
                                devices: free[..want].to_vec(),
                                nic: 0,
                            }],
                        )
                        .unwrap();
                        live.push((next, node));
                        next += 1;
                    }
                }
                2 => {
                    // Release a random job — including residents of nodes
                    // that went Draining/Faulty meanwhile (the
                    // finish-mid-drain path).
                    if let Some(i) =
                        (!live.is_empty()).then(|| rng.below(live.len() as u64) as usize)
                    {
                        let (j, _) = live.swap_remove(i);
                        s.release_job(JobId(j)).unwrap();
                    }
                }
                3 | 4 => {
                    // Arbitrary lifecycle transition on ANY node. When a
                    // node leaves service the fault path evicts residents
                    // first (mirroring the runner's order of operations).
                    let node = NodeId(rng.below(num_nodes as u64) as u32);
                    let h = *rng.choose(&healths).unwrap();
                    if !h.schedulable() && rng.chance(0.7) {
                        let victims: Vec<u64> = live
                            .iter()
                            .filter(|&&(_, n)| n == node)
                            .map(|&(j, _)| j)
                            .collect();
                        for j in victims {
                            s.release_job(JobId(j)).unwrap();
                            live.retain(|&(id, _)| id != j);
                        }
                    }
                    s.set_node_health(node, h);
                }
                _ => {
                    // Device-level fault/repair churn.
                    let node = NodeId(rng.below(num_nodes as u64) as u32);
                    let dev = rng.below(8) as usize;
                    let cur = s.node(node).gpus[dev].health;
                    let occupied = s.node(node).gpus[dev].allocated_to.is_some();
                    if occupied {
                        continue; // Device faults on residents are the runner's (eviction) path.
                    }
                    let h = if cur.schedulable() {
                        Health::Faulty
                    } else {
                        Health::Healthy
                    };
                    s.set_gpu_health(node, dev as u8, h);
                }
            }

            // Invariant 1: allocation totals match a device-level recount.
            let recount: u32 = s.nodes.iter().map(|n| n.allocated_gpus()).sum();
            prop_assert!(
                s.allocated_gpus() == recount,
                "allocation leak at step {step}: tracked {} vs recount {recount}",
                s.allocated_gpus()
            );
            // Invariant 2: maintained free aggregates match a recount.
            for g in 0..groups {
                let want: u32 = s
                    .nodes
                    .iter()
                    .filter(|n| n.group == GroupId(g))
                    .map(|n| n.free_gpus())
                    .sum();
                prop_assert!(
                    s.group_free(GroupId(g)) == want,
                    "group {g} free drifted at step {step}: {} vs {want}",
                    s.group_free(GroupId(g))
                );
            }
            let pool_want: u32 = s.nodes.iter().map(|n| n.free_gpus()).sum();
            prop_assert!(
                s.pool_free_for_type(G) == pool_want,
                "pool free drifted at step {step}"
            );

            // Invariant 3: the NodeIndex buckets agree with the state.
            if rng.chance(0.5) || step == 0 {
                snap.refresh(&s);
                let ix = snap.index().unwrap();
                let fresh = NodeIndex::from_state(&s);
                for g in 0..groups {
                    for min in [1u32, 4, 8] {
                        let mut got = Vec::new();
                        ix.for_group(GroupId(g), min, ZoneQuery::Any, &mut got);
                        got.sort_unstable();
                        let mut scratch = Vec::new();
                        fresh.for_group(GroupId(g), min, ZoneQuery::Any, &mut scratch);
                        scratch.sort_unstable();
                        let want: Vec<NodeId> = s
                            .nodes
                            .iter()
                            .filter(|n| {
                                n.group == GroupId(g)
                                    && n.health.schedulable()
                                    && n.free_gpus() >= min
                            })
                            .map(|n| n.id)
                            .collect();
                        prop_assert!(
                            got == want && scratch == want,
                            "index diverged at step {step} (group {g}, min {min}): \
                             incremental {got:?} / fresh {scratch:?} vs state {want:?}"
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn gang_footprint_matches_min_tier_oracle() {
    // The O(1) GangFootprint tier query must equal the O(|placed|)
    // `Fabric::min_tier_to` scan for every node, under arbitrary
    // placement sequences on arbitrary multi-superspine fabrics.
    use kant::cluster::ids::NodeId;
    use kant::cluster::topology::GangFootprint;

    prop::check(40, |rng| {
        let mut spec = ClusterSpec::homogeneous(
            "fp",
            rng.range_inclusive(1, 6) as u32,
            rng.range_inclusive(1, 3) as u32,
            rng.range_inclusive(1, 4) as u32,
        );
        spec.spines_per_superspine = rng.range_inclusive(1, 3) as u32;
        let state = ClusterBuilder::build(&spec);
        let fabric = &state.fabric;
        let num_nodes = state.nodes.len() as u64;
        let mut fp = GangFootprint::new();
        let mut placed: Vec<NodeId> = Vec::new();
        for _ in 0..rng.range_inclusive(1, 12) {
            for probe in 0..num_nodes {
                let n = NodeId(probe as u32);
                prop_assert!(
                    fp.tier_to(fabric, n) == fabric.min_tier_to(n, &placed),
                    "tier diverged for {n} with placed {placed:?}"
                );
            }
            let next = NodeId(rng.below(num_nodes) as u32);
            fp.place(fabric, next);
            placed.push(next);
            prop_assert!(
                fp.groups_spanned() == fabric.groups_spanned(&placed)
                    && fp.spines_spanned() == fabric.spines_spanned(&placed)
                    && fp.superspines_spanned() == fabric.superspines_spanned(&placed),
                "span counters diverged with placed {placed:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn pooled_incremental_gang_scoring_matches_full_rebuild() {
    // The incremental row cache is a pure acceleration: across random
    // multi-superspine clusters and random job streams (gangs, releases,
    // all topology-aware strategies), placements must be byte-identical
    // to rebuilding every feature row per pod.
    use kant::job::spec::PlacementStrategy;
    use kant::qsch::Placer;
    use kant::rsch::GangScoring;

    prop::check(15, |rng| {
        let mut spec = ClusterSpec::homogeneous(
            "gc",
            rng.range_inclusive(2, 4) as u32,
            rng.range_inclusive(1, 2) as u32,
            rng.range_inclusive(2, 4) as u32,
        );
        spec.spines_per_superspine = rng.range_inclusive(1, 2) as u32;
        let mut s_inc = ClusterBuilder::build(&spec);
        let mut s_reb = s_inc.clone();
        let base = RschConfig {
            two_level: rng.chance(0.6),
            indexed_candidates: rng.chance(0.7),
            ..RschConfig::default()
        };
        let mut inc = Rsch::new(
            RschConfig {
                gang_scoring: GangScoring::PooledIncremental,
                ..base.clone()
            },
            &s_inc,
        );
        let mut reb = Rsch::new(
            RschConfig {
                gang_scoring: GangScoring::PooledRebuild,
                ..base
            },
            &s_reb,
        );
        let mut live: Vec<JobId> = Vec::new();
        let mut next = 1u64;
        for step in 0..rng.range_inclusive(8, 30) {
            if live.is_empty() || rng.chance(0.75) {
                let replicas = rng.range_inclusive(1, 8) as u32;
                let gpp = *rng.choose(&[2u32, 4, 8]).unwrap();
                let mut j = JobSpec::homogeneous(
                    JobId(next),
                    TenantId(0),
                    JobKind::Training,
                    G,
                    replicas,
                    gpp,
                );
                j.strategy = Some(
                    *rng.choose(&[PlacementStrategy::EBinpack, PlacementStrategy::ESpread])
                        .unwrap(),
                );
                let a = inc.place(&mut s_inc, &j);
                let b = reb.place(&mut s_reb, &j);
                prop_assert!(
                    a == b,
                    "outcome diverged at step {step} for job {}: {a:?} vs {b:?}",
                    j.id
                );
                prop_assert!(
                    s_inc.placements_of(j.id) == s_reb.placements_of(j.id),
                    "placements diverged at step {step} for job {}",
                    j.id
                );
                if a.is_ok() {
                    live.push(j.id);
                }
                next += 1;
            } else {
                let i = rng.below(live.len() as u64) as usize;
                let j = live.swap_remove(i);
                s_inc.release_job(j).unwrap();
                s_reb.release_job(j).unwrap();
            }
        }
        prop_assert!(
            s_inc.allocated_gpus() == s_reb.allocated_gpus(),
            "allocation totals diverged"
        );
        prop_assert!(
            inc.stats.nodes_scored <= reb.stats.nodes_scored,
            "the incremental cache must never score MORE rows"
        );
        Ok(())
    });
}

#[test]
fn preemption_never_loses_jobs() {
    // Under heavy HIGH-priority pressure with preemption enabled, every
    // job must end Finished or still-tracked — never dropped.
    prop::check(10, |rng| {
        let state0 = ClusterBuilder::build(&ClusterSpec::homogeneous("p", 1, 2, 3));
        let mut ledger = QuotaLedger::new(3, 1, QuotaMode::Shared);
        ledger.set_limit(TenantId(0), G, 48);
        ledger.set_limit(TenantId(1), G, 48);
        ledger.set_limit(TenantId(2), G, 48);
        let mut qsch = Qsch::new(
            QschConfig {
                policy: QueuePolicy::Backfill,
                backfill_timeout_ms: 120_000,
                priority_preempt_min_wait_ms: 60_000,
                ..QschConfig::default()
            },
            ledger,
        );
        let mut state = state0;
        let mut rsch = Rsch::new(RschConfig::default(), &state);
        let n = rng.range_inclusive(10, 40);
        let mut jobs: Vec<JobSpec> = (1..=n)
            .map(|id| random_job(rng, id, 1_800_000))
            .collect();
        // Make a third of them HIGH priority to force preemption churn.
        for j in jobs.iter_mut() {
            if rng.chance(0.3) {
                j.priority = Priority::HIGH;
            }
        }
        jobs.sort_by_key(|j| j.submit_ms);
        let cfg = SimConfig {
            horizon_ms: 24 * 3_600_000,
            stall_cycles: 500,
            ..SimConfig::default()
        };
        let out = run(&mut state, &mut qsch, &mut rsch, jobs, &cfg);
        prop_assert!(out.store.len() == n as usize, "job lost from the store");
        for j in out.store.iter() {
            prop_assert!(
                matches!(
                    j.phase,
                    Phase::Finished | Phase::Queued | Phase::Scheduled | Phase::Running
                ),
                "job {} in impossible terminal state {:?}",
                j.id(),
                j.phase
            );
        }
        // Preempted work is eventually re-run: if everything finished, all
        // remaining_ms are zero.
        if out.unfinished_jobs == 0 {
            for j in out.store.iter() {
                prop_assert!(j.remaining_ms == 0, "job {} kept owed work", j.id());
            }
        }
        Ok(())
    });
}

#[test]
fn requeue_aging_never_reorders_across_base_priority_classes() {
    // Requeue aging boosts a job inside its base class only
    // (`Priority::aged` clamps at the class ceiling), so however many
    // evict/requeue rounds a job survives — with arbitrary accumulated
    // boosts, far past any cap the scheduler would apply — the global
    // queue order must still serve every HIGH-class entry before any
    // NORMAL-class entry before any LOW-class entry.
    use kant::qsch::queue::{QueueEntry, TenantQueues};

    prop::check(40, |rng| {
        let mut queues = TenantQueues::new();
        let n = rng.range_inclusive(1, 60);
        for id in 1..=n {
            let base = *rng
                .choose(&[Priority::LOW, Priority::NORMAL, Priority::HIGH])
                .unwrap();
            let boost = rng.below(32) as u8;
            let aged = base.aged(boost);
            prop_assert!(
                aged.class_index() == base.class_index(),
                "aged({boost}) moved {base:?} across a class boundary to {aged:?}"
            );
            queues.push(QueueEntry {
                job: JobId(id),
                tenant: TenantId(rng.below(4) as u32),
                priority: aged,
                submit_ms: rng.below(3_600_000),
                total_gpus: rng.range_inclusive(1, 64) as u32,
            });
        }
        let order = queues.global_order();
        for w in order.windows(2) {
            prop_assert!(
                w[0].priority.class_index() >= w[1].priority.class_index(),
                "aged entry reordered across classes: {:?} served before {:?}",
                w[0].priority,
                w[1].priority
            );
        }
        Ok(())
    });
}

#[test]
fn moldable_shapes_stay_on_ladder_and_shrink_keeps_books_consistent() {
    // Moldable/malleable invariants under random laddered workloads with
    // fault-driven shrink pressure: a job's realized shape is always one
    // of its declared rungs, holders' placements match the realized
    // shape, no allocation leaks, the NodeIndex buckets agree with a
    // device-level recount, and every shrink refunded its quota charge
    // (ledger usage always equals what the holders occupy).
    use kant::cluster::ids::GroupId;
    use kant::cluster::index::{NodeIndex, ZoneQuery};
    use kant::job::spec::GangShape;
    use kant::sim::FaultConfig;

    prop::check(15, |rng| {
        let groups = rng.range_inclusive(1, 3) as u32;
        let nodes = rng.range_inclusive(3, 6) as u32;
        let mut state =
            ClusterBuilder::build(&ClusterSpec::homogeneous("mold", 1, groups, nodes));
        let mut ledger = QuotaLedger::new(3, 1, QuotaMode::Shared);
        for t in 0..3 {
            ledger.set_limit(TenantId(t), G, state.total_gpus());
        }
        let mut qsch = Qsch::new(
            QschConfig {
                enable_moldable: true,
                enable_shrink: true,
                ..QschConfig::default()
            },
            ledger,
        );
        let mut rsch = Rsch::new(
            RschConfig {
                indexed_candidates: rng.chance(0.5),
                ..RschConfig::default()
            },
            &state,
        );
        let horizon = 2 * 3_600_000;
        let n_jobs = rng.range_inclusive(8, 40);
        let mut jobs: Vec<JobSpec> = (1..=n_jobs)
            .map(|id| {
                let mut j = random_job(rng, id, horizon);
                // Attach a ladder to multi-pod training gangs (the only
                // shape the mold/shrink passes act on).
                if j.kind == JobKind::Training && j.total_replicas() >= 2 && rng.chance(0.7) {
                    let full = j.total_replicas();
                    let mut shapes = vec![GangShape {
                        replicas: full,
                        throughput: 1.0,
                    }];
                    let mut r = full / 2;
                    let mut thr = 0.45;
                    while r >= 1 && shapes.len() < 3 {
                        shapes.push(GangShape {
                            replicas: r,
                            throughput: thr,
                        });
                        r /= 2;
                        thr *= 0.45;
                    }
                    j = j.with_shapes(shapes);
                }
                j
            })
            .collect();
        jobs.sort_by_key(|j| j.submit_ms);
        let ladders: std::collections::HashMap<u64, Vec<u32>> = jobs
            .iter()
            .map(|j| (j.id.0, j.shapes.iter().map(|s| s.replicas).collect()))
            .collect();
        let cfg = SimConfig {
            horizon_ms: horizon * 4,
            stall_cycles: 500,
            faults: FaultConfig {
                seed: rng.below(1u64 << 32),
                node_mtbf_ms: 6 * 3_600_000, // A handful of faults per run.
                node_mttr_ms: 30 * 60_000,
                ..FaultConfig::default()
            },
            ..SimConfig::default()
        };
        let out = run(&mut state, &mut qsch, &mut rsch, jobs, &cfg);

        // 1. The realized shape is always one of the declared rungs, and
        //    ladder-free jobs are never reshaped.
        for j in out.store.iter() {
            let ladder = &ladders[&j.id().0];
            if ladder.is_empty() {
                prop_assert!(
                    j.shape_changes == 0,
                    "fixed job {} was reshaped {} times",
                    j.id(),
                    j.shape_changes
                );
            } else {
                prop_assert!(
                    ladder.contains(&j.spec.total_replicas()),
                    "job {} realized shape {} not on its ladder {ladder:?}",
                    j.id(),
                    j.spec.total_replicas()
                );
            }
        }

        // 2. Holders' placements match the realized (possibly shrunk)
        //    shape and no device allocation leaks.
        let holding: u32 = out
            .store
            .iter()
            .filter(|j| j.holds_resources())
            .map(|j| j.spec.total_gpus())
            .sum();
        prop_assert!(
            state.allocated_gpus() == holding,
            "allocation leak: state {} vs holders {holding}",
            state.allocated_gpus()
        );
        for j in out.store.iter() {
            if j.holds_resources() {
                let placements = state.placements_of(j.id()).expect("holder has placement");
                prop_assert!(
                    placements.len() as u32 == j.spec.total_replicas(),
                    "job {} holds {} of {} (reshaped) pods",
                    j.id(),
                    placements.len(),
                    j.spec.total_replicas()
                );
            }
        }

        // 3. NodeIndex buckets rebuilt from state agree with a direct
        //    per-node filter after all the mold/shrink churn.
        let ix = NodeIndex::from_state(&state);
        for g in 0..groups {
            for min in [1u32, 4, 8] {
                let mut got = Vec::new();
                ix.for_group(GroupId(g), min, ZoneQuery::Any, &mut got);
                got.sort_unstable();
                let want: Vec<_> = state
                    .nodes
                    .iter()
                    .filter(|n| {
                        n.group == GroupId(g) && n.health.schedulable() && n.free_gpus() >= min
                    })
                    .map(|n| n.id)
                    .collect();
                prop_assert!(
                    got == want,
                    "index diverged after shrink churn (group {g}, min {min})"
                );
            }
        }

        // 4. Quota conservation: ledger usage equals exactly what the
        //    holders occupy — i.e. every shrink's release refunded its
        //    charge before the re-placement charged the smaller shape.
        let used: u64 = (0..3)
            .map(|t| {
                let e = qsch.ledger.entry(TenantId(t), G);
                (e.used_own + e.borrowed) as u64
            })
            .sum();
        prop_assert!(
            used == holding as u64,
            "quota books off after shrink churn: charged {used} vs held {holding}"
        );
        Ok(())
    });
}

#[test]
fn moldable_pass_is_inert_for_ladder_free_workloads() {
    // Regression: turning `--moldable` on must not perturb a workload in
    // which no job declares a shape ladder — digests stay byte-identical
    // to the flags-off run.
    prop::check(10, |rng| {
        let horizon = 2 * 3_600_000;
        let n = rng.range_inclusive(5, 40);
        let mut jobs: Vec<JobSpec> = (1..=n).map(|id| random_job(rng, id, horizon)).collect();
        jobs.sort_by_key(|j| j.submit_ms);
        let run_with = |moldable: bool, jobs: Vec<JobSpec>| {
            let mut state = ClusterBuilder::build(&ClusterSpec::homogeneous("m", 1, 2, 4));
            let mut ledger = QuotaLedger::new(3, 1, QuotaMode::Shared);
            for t in 0..3 {
                ledger.set_limit(TenantId(t), G, state.total_gpus());
            }
            let mut qsch = Qsch::new(
                QschConfig {
                    enable_moldable: moldable,
                    enable_shrink: moldable,
                    ..QschConfig::default()
                },
                ledger,
            );
            let mut rsch = Rsch::new(RschConfig::default(), &state);
            let cfg = SimConfig {
                horizon_ms: horizon * 4,
                stall_cycles: 500,
                ..SimConfig::default()
            };
            run(&mut state, &mut qsch, &mut rsch, jobs, &cfg)
                .digest_json()
                .to_string_compact()
        };
        let off = run_with(false, jobs.clone());
        let on = run_with(true, jobs);
        prop_assert!(
            off == on,
            "the mold/shrink passes perturbed a ladder-free workload"
        );
        Ok(())
    });
}

#[test]
fn strict_fifo_never_reorders_same_priority() {
    // Under Strict FIFO, same-priority jobs must be *scheduled* in
    // submission order.
    prop::check(10, |rng| {
        let state0 = ClusterBuilder::build(&ClusterSpec::homogeneous("p", 1, 1, 4));
        let mut ledger = QuotaLedger::new(1, 1, QuotaMode::Shared);
        ledger.set_limit(TenantId(0), G, 32);
        let mut qsch = Qsch::new(QschConfig::strict_fifo(), ledger);
        let mut state = state0;
        let mut rsch = Rsch::new(RschConfig::default(), &state);
        let n = rng.range_inclusive(5, 25);
        let mut jobs: Vec<JobSpec> = (1..=n)
            .map(|id| {
                let mut j = random_job(rng, id, 600_000);
                j.priority = Priority::NORMAL;
                j.tenant = TenantId(0);
                j
            })
            .collect();
        jobs.sort_by_key(|j| j.submit_ms);
        let cfg = SimConfig {
            horizon_ms: 48 * 3_600_000,
            stall_cycles: 300,
            ..SimConfig::default()
        };
        let out = run(&mut state, &mut qsch, &mut rsch, jobs.clone(), &cfg);
        let mut scheduled: Vec<(u64, u64)> = out
            .store
            .iter()
            .filter_map(|j| j.scheduled_ms.map(|t| (t, j.submit_ms)))
            .collect();
        scheduled.sort_unstable();
        // For any two schedule times, the earlier-scheduled job must not
        // have been submitted later than one scheduled strictly earlier...
        // i.e. schedule order respects submit order.
        for w in scheduled.windows(2) {
            if w[0].0 < w[1].0 {
                prop_assert!(
                    w[0].1 <= w[1].1,
                    "strict FIFO reordered: submit {} scheduled before submit {}",
                    w[1].1,
                    w[0].1
                );
            }
        }
        Ok(())
    });
}
