//! Property test for the superspine-sharded scheduler core: the shard
//! *structure* is fixed by the topology and `--shards N` only chooses the
//! worker-thread count, so every N ≥ 1 must produce byte-identical
//! `SimOutcome::digest_json` output — including the counters
//! (`rsch_nodes_examined`, `rsch_nodes_scored`) that would immediately
//! expose a thread-count-dependent planning order.

use kant::config::{FaultPreset, Scale, SimOptions, SimSetup};
use kant::job::workload::WorkloadGen;
use kant::qsch::Qsch;
use kant::rsch::Rsch;
use kant::sim::run;

/// One full simulate run through the unified builder, horizon truncated
/// for test runtime.
fn outcome(
    scale: Scale,
    seed: u64,
    elastic: bool,
    faults: FaultPreset,
    shards: usize,
    arrival_ms: u64,
) -> kant::sim::SimOutcome {
    let opts = SimOptions::for_scale(scale)
        .seed(seed)
        .elastic(elastic)
        .faults(faults)
        .shards(shards);
    let SimSetup {
        mut env,
        qsch,
        rsch,
        mut sim,
    } = opts.build().expect("options are valid");
    env.horizon_ms = arrival_ms;
    sim.horizon_ms = arrival_ms + 12 * 3_600_000; // Drain window.
    let mut jobs = WorkloadGen::new(env.workload.clone()).generate_until(env.horizon_ms);
    opts.apply_job_policies(&mut jobs);
    let mut state = env.state;
    let mut qsch = Qsch::new(qsch, env.ledger);
    let mut rsch = Rsch::new(rsch, &state);
    run(&mut state, &mut qsch, &mut rsch, jobs, &sim)
}

/// Same, digested to the golden-gate JSON string.
fn digest(
    scale: Scale,
    seed: u64,
    elastic: bool,
    faults: FaultPreset,
    shards: usize,
    arrival_ms: u64,
) -> String {
    outcome(scale, seed, elastic, faults, shards, arrival_ms)
        .digest_json()
        .to_string_compact()
}

const SMALL_ARRIVAL_MS: u64 = 12 * 3_600_000;
const XLARGE_ARRIVAL_MS: u64 = 2 * 3_600_000;

#[test]
fn small_sharded_digests_invariant_across_thread_counts() {
    // Small preset spans 2 superspines (PR 5), so the sharded core has
    // real structure to get wrong. Three seeds × the plain, elastic and
    // fault-storm arms; shards ∈ {2, 4, 8} must replay shards = 1 exactly.
    for seed in [3u64, 7, 11] {
        for (elastic, faults) in [
            (false, FaultPreset::None),
            (true, FaultPreset::None),
            (false, FaultPreset::Storm),
        ] {
            let base = digest(Scale::Small, seed, elastic, faults, 1, SMALL_ARRIVAL_MS);
            for shards in [2usize, 4, 8] {
                let got = digest(Scale::Small, seed, elastic, faults, shards, SMALL_ARRIVAL_MS);
                assert_eq!(
                    base, got,
                    "digest moved with thread count: seed={seed} elastic={elastic} \
                     faults={faults:?} shards={shards}"
                );
            }
        }
    }
}

#[test]
fn small_sharded_digests_track_the_seed() {
    // Sanity: the digest is actually sensitive to the workload — a
    // constant digest would pass the invariance test vacuously.
    let a = digest(Scale::Small, 3, false, FaultPreset::None, 8, SMALL_ARRIVAL_MS);
    let b = digest(Scale::Small, 4, false, FaultPreset::None, 8, SMALL_ARRIVAL_MS);
    assert_ne!(a, b, "different seeds must diverge");
}

#[test]
fn fault_requeue_meets_prefetch_in_flight_thread_invariantly() {
    // The interleaving the blanket storm arms never pinned down: a
    // fault-storm eviction requeues aged gangs into the same cycles
    // whose candidate batches the sharded prefetch is routing. First
    // prove the scenario is real on the shards = 1 arm (prefetch is on
    // for every shards >= 1): evictions happened AND jobs were requeued
    // into prefetching cycles. Then shards = 8 must replay it
    // byte-for-byte.
    let base = outcome(Scale::Small, 13, false, FaultPreset::Storm, 1, SMALL_ARRIVAL_MS);
    assert!(
        base.metrics.reliability.fault_evictions > 0,
        "storm arm never evicted — the scenario is vacuous"
    );
    assert!(
        base.qsch_stats.requeues > 0,
        "no eviction requeue ever landed in a prefetching cycle"
    );
    let sharded = outcome(Scale::Small, 13, false, FaultPreset::Storm, 8, SMALL_ARRIVAL_MS);
    assert_eq!(
        base.digest_json().to_string_compact(),
        sharded.digest_json().to_string_compact(),
        "fault-requeue + prefetch interleaving moved with thread count"
    );
}

#[test]
fn xlarge_sharded_digests_invariant_across_thread_counts() {
    // The acceptance-bar preset: 1,250 nodes / 10,000 GPUs over 3
    // superspines, truncated arrival horizon for runtime.
    for seed in [3u64, 7, 11] {
        let base = digest(
            Scale::XLarge,
            seed,
            false,
            FaultPreset::None,
            1,
            XLARGE_ARRIVAL_MS,
        );
        for shards in [2usize, 8] {
            let got = digest(
                Scale::XLarge,
                seed,
                false,
                FaultPreset::None,
                shards,
                XLARGE_ARRIVAL_MS,
            );
            assert_eq!(
                base, got,
                "xlarge digest moved with thread count: seed={seed} shards={shards}"
            );
        }
    }
}

#[test]
fn xlarge_elastic_fault_arm_is_thread_invariant() {
    // The kitchen-sink arm on the xlarge preset: autoscaling loop, fault
    // storm, drain-aware defrag and sharded prefetch all at once.
    let base = digest(
        Scale::XLarge,
        5,
        true,
        FaultPreset::Storm,
        1,
        XLARGE_ARRIVAL_MS,
    );
    let got = digest(
        Scale::XLarge,
        5,
        true,
        FaultPreset::Storm,
        8,
        XLARGE_ARRIVAL_MS,
    );
    assert_eq!(base, got, "elastic+faults xlarge digest moved with thread count");
}
