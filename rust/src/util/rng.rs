//! Deterministic PRNG + distribution samplers.
//!
//! The offline environment ships no `rand` crate, so we implement the small
//! slice we need: [`SplitMix64`] for seeding, [`Pcg32`] as the workhorse
//! generator (implements [`rand_core::RngCore`]), and samplers for the
//! distributions the workload generator uses (uniform, exponential, normal,
//! log-normal, Poisson, categorical).
//!
//! Everything here is deterministic given a seed: the entire simulator and
//! every figure in the paper reproduction replays bit-for-bit.

use rand_core::RngCore;

/// SplitMix64: used to expand a single `u64` seed into stream seeds.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32): small, fast, statistically solid.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Build from a seed; the stream id is derived via SplitMix64 so two
    /// generators with different seeds are fully decorrelated.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = sm.next_u64();
        let inc = sm.next_u64() | 1;
        let mut pcg = Self { state: 0, inc };
        pcg.state = pcg.state.wrapping_add(state);
        pcg.next_u32();
        pcg
    }

    /// Derive a decorrelated child generator (for per-subsystem streams).
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        Pcg32::seed_from_u64(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        // Guard against ln(0).
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Log-normal: exp(N(mu, sigma)).
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Poisson-distributed count (Knuth for small means, normal approx above 30).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        if mean <= 0.0 {
            return 0;
        }
        if mean > 30.0 {
            let v = self.normal(mean, mean.sqrt()).round();
            return v.max(0.0) as u64;
        }
        let l = (-mean).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> Option<&'a T> {
        if xs.is_empty() {
            None
        } else {
            Some(&xs[self.below(xs.len() as u64) as usize])
        }
    }
}

impl RngCore for Pcg32 {
    fn next_u32(&mut self) -> u32 {
        Pcg32::next_u32(self)
    }
    fn next_u64(&mut self) -> u64 {
        Pcg32::next_u64(self)
    }
    fn fill_bytes(&mut self, dest: &mut [u8]) {
        let mut chunks = dest.chunks_exact_mut(4);
        for chunk in &mut chunks {
            chunk.copy_from_slice(&Pcg32::next_u32(self).to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let bytes = Pcg32::next_u32(self).to_le_bytes();
            rem.copy_from_slice(&bytes[..rem.len()]);
        }
    }
    fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), rand_core::Error> {
        self.fill_bytes(dest);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg32::seed_from_u64(42);
        let mut b = Pcg32::seed_from_u64(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seed_from_u64(1);
        let mut b = Pcg32::seed_from_u64(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg32::seed_from_u64(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "count {c} out of range");
        }
    }

    #[test]
    fn range_inclusive_covers_bounds() {
        let mut r = Pcg32::seed_from_u64(11);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            match r.range_inclusive(3, 6) {
                3 => saw_lo = true,
                6 => saw_hi = true,
                4 | 5 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Pcg32::seed_from_u64(13);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn normal_moments_close() {
        let mut r = Pcg32::seed_from_u64(17);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean {mean}");
        assert!((var - 4.0).abs() < 0.15, "var {var}");
    }

    #[test]
    fn poisson_mean_close_small_and_large() {
        let mut r = Pcg32::seed_from_u64(19);
        for target in [0.5, 4.0, 50.0] {
            let n = 50_000;
            let mean = (0..n).map(|_| r.poisson(target)).sum::<u64>() as f64 / n as f64;
            assert!(
                (mean - target).abs() / target < 0.05,
                "target {target} got {mean}"
            );
        }
    }

    #[test]
    fn categorical_follows_weights() {
        let mut r = Pcg32::seed_from_u64(23);
        let w = [1.0, 3.0];
        let n = 40_000;
        let ones = (0..n).filter(|_| r.categorical(&w) == 1).count();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.02, "frac {frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seed_from_u64(29);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_decorrelates() {
        let mut root = Pcg32::seed_from_u64(31);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fill_bytes_covers_remainder() {
        let mut r = Pcg32::seed_from_u64(37);
        let mut buf = [0u8; 7];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }
}
