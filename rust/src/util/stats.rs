//! Statistics utilities: summaries, percentiles, histograms, and
//! time-weighted series — the numeric backbone of the §4 metrics (GAR, SOR,
//! GFR are time-weighted ratios; JWTD/JTTED are per-bucket distributions).

/// Summary statistics over a set of samples.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub std: f64,
}

impl Summary {
    pub fn from_samples(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary::default();
        }
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let count = sorted.len();
        let mean = sorted.iter().sum::<f64>() / count as f64;
        let var = sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / count as f64;
        Summary {
            count,
            mean,
            min: sorted[0],
            max: sorted[count - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
            std: var.sqrt(),
        }
    }
}

/// Percentile by linear interpolation over a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Median of an unsorted slice.
pub fn median(samples: &[f64]) -> f64 {
    let mut sorted: Vec<f64> = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, 0.5)
}

/// A time-weighted series of a piecewise-constant quantity (e.g. the number
/// of allocated GPUs): push (time, value) points; integrals and averages are
/// weighted by how long each value was held.
///
/// This is exactly how SOR is defined in §4.2: GPU-hours allocated divided
/// by GPU-hours available — i.e. the time integral of the allocation count.
#[derive(Debug, Clone, Default)]
pub struct TimeWeighted {
    points: Vec<(u64, f64)>, // (time_ms, value from this time onward)
}

impl TimeWeighted {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record that `value` holds from `time_ms` onward. Times must be
    /// non-decreasing; same-time updates overwrite.
    pub fn push(&mut self, time_ms: u64, value: f64) {
        if let Some(last) = self.points.last_mut() {
            debug_assert!(time_ms >= last.0, "time went backwards");
            if last.0 == time_ms {
                last.1 = value;
                return;
            }
            if last.1 == value {
                return; // No change; keep series compact.
            }
        }
        self.points.push((time_ms, value));
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn last_value(&self) -> Option<f64> {
        self.points.last().map(|&(_, v)| v)
    }

    /// Integral of value dt over [t0, t1] in (value × ms).
    pub fn integral(&self, t0: u64, t1: u64) -> f64 {
        if t1 <= t0 || self.points.is_empty() {
            return 0.0;
        }
        let mut total = 0.0;
        for (i, &(start, value)) in self.points.iter().enumerate() {
            let end = self
                .points
                .get(i + 1)
                .map(|&(t, _)| t)
                .unwrap_or(u64::MAX);
            let seg0 = start.max(t0);
            let seg1 = end.min(t1);
            if seg1 > seg0 {
                total += value * (seg1 - seg0) as f64;
            }
        }
        total
    }

    /// Time-weighted average over [t0, t1].
    pub fn average(&self, t0: u64, t1: u64) -> f64 {
        if t1 <= t0 {
            return 0.0;
        }
        self.integral(t0, t1) / (t1 - t0) as f64
    }

    /// Sample the value at time `t` (value of the last point at or before `t`).
    pub fn at(&self, t: u64) -> f64 {
        match self.points.binary_search_by_key(&t, |&(ts, _)| ts) {
            Ok(i) => self.points[i].1,
            Err(0) => 0.0,
            Err(i) => self.points[i - 1].1,
        }
    }

    /// Downsample to at most `n` evenly spaced (time, value) samples over
    /// [t0, t1] — used by the figure renderers for time-series plots.
    pub fn sampled(&self, t0: u64, t1: u64, n: usize) -> Vec<(u64, f64)> {
        if n == 0 || t1 <= t0 {
            return Vec::new();
        }
        (0..n)
            .map(|i| {
                let t = t0 + (t1 - t0) * i as u64 / n.max(1) as u64;
                (t, self.at(t))
            })
            .collect()
    }
}

/// Fixed-bucket histogram keyed by job size (GPU count) — the bucketing the
/// paper uses for JWTD/JTTED ("fewer than 8 GPUs", "more than 64", …).
#[derive(Debug, Clone)]
pub struct SizeBuckets {
    bounds: Vec<u32>, // Upper-inclusive GPU-count bound per bucket.
    labels: Vec<String>,
    samples: Vec<Vec<f64>>,
}

impl SizeBuckets {
    /// The paper's canonical buckets: 1, 2–8, 9–64, 65–256, 257–1024, 1025–2048+.
    pub fn paper_default() -> SizeBuckets {
        SizeBuckets::new(&[1, 8, 64, 256, 1024, u32::MAX])
    }

    pub fn new(bounds: &[u32]) -> SizeBuckets {
        assert!(!bounds.is_empty());
        let mut labels = Vec::new();
        let mut lo = 1u64;
        for &b in bounds {
            if b == u32::MAX {
                labels.push(format!("{lo}+"));
            } else if u64::from(b) == lo {
                labels.push(format!("{b}"));
            } else {
                labels.push(format!("{lo}-{b}"));
            }
            lo = u64::from(b) + 1;
        }
        SizeBuckets {
            bounds: bounds.to_vec(),
            labels,
            samples: vec![Vec::new(); bounds.len()],
        }
    }

    pub fn bucket_of(&self, gpus: u32) -> usize {
        self.bounds
            .iter()
            .position(|&b| gpus <= b)
            .unwrap_or(self.bounds.len() - 1)
    }

    pub fn record(&mut self, gpus: u32, value: f64) {
        let idx = self.bucket_of(gpus);
        self.samples[idx].push(value);
    }

    pub fn labels(&self) -> &[String] {
        &self.labels
    }

    pub fn summary(&self, bucket: usize) -> Summary {
        Summary::from_samples(&self.samples[bucket])
    }

    pub fn summaries(&self) -> Vec<(String, Summary)> {
        self.labels
            .iter()
            .cloned()
            .zip(self.samples.iter().map(|s| Summary::from_samples(s)))
            .collect()
    }

    pub fn num_buckets(&self) -> usize {
        self.bounds.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_samples() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.count, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_empty_is_zeroes() {
        let s = Summary::from_samples(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile_sorted(&sorted, 0.5), 5.0);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 10.0);
    }

    #[test]
    fn time_weighted_integral_and_average() {
        let mut tw = TimeWeighted::new();
        tw.push(0, 4.0);
        tw.push(10, 8.0);
        tw.push(20, 0.0);
        // [0,10): 4, [10,20): 8, [20,..): 0.
        assert_eq!(tw.integral(0, 20), 4.0 * 10.0 + 8.0 * 10.0);
        assert_eq!(tw.average(0, 20), 6.0);
        assert_eq!(tw.integral(5, 15), 4.0 * 5.0 + 8.0 * 5.0);
        assert_eq!(tw.at(0), 4.0);
        assert_eq!(tw.at(15), 8.0);
        assert_eq!(tw.at(25), 0.0);
    }

    #[test]
    fn time_weighted_dedups_equal_values() {
        let mut tw = TimeWeighted::new();
        tw.push(0, 1.0);
        tw.push(5, 1.0);
        tw.push(10, 2.0);
        assert_eq!(tw.len(), 2);
    }

    #[test]
    fn time_weighted_same_time_overwrites() {
        let mut tw = TimeWeighted::new();
        tw.push(0, 1.0);
        tw.push(0, 3.0);
        assert_eq!(tw.at(0), 3.0);
        assert_eq!(tw.len(), 1);
    }

    #[test]
    fn sampled_series_has_n_points() {
        let mut tw = TimeWeighted::new();
        tw.push(0, 1.0);
        tw.push(500, 2.0);
        let pts = tw.sampled(0, 1000, 10);
        assert_eq!(pts.len(), 10);
        assert_eq!(pts[0].1, 1.0);
        assert_eq!(pts[9].1, 2.0);
    }

    #[test]
    fn paper_buckets_classify_sizes() {
        let b = SizeBuckets::paper_default();
        assert_eq!(b.bucket_of(1), 0);
        assert_eq!(b.bucket_of(8), 1);
        assert_eq!(b.bucket_of(9), 2);
        assert_eq!(b.bucket_of(64), 2);
        assert_eq!(b.bucket_of(256), 3);
        assert_eq!(b.bucket_of(1024), 4);
        assert_eq!(b.bucket_of(2048), 5);
        assert_eq!(b.labels()[0], "1");
        assert_eq!(b.labels()[1], "2-8");
        assert_eq!(b.labels()[5], "1025+");
    }

    #[test]
    fn bucket_records_aggregate() {
        let mut b = SizeBuckets::paper_default();
        b.record(4, 10.0);
        b.record(6, 20.0);
        b.record(2048, 100.0);
        assert_eq!(b.summary(1).mean, 15.0);
        assert_eq!(b.summary(5).count, 1);
        assert_eq!(b.summary(0).count, 0);
    }
}
