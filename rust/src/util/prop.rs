//! Tiny property-testing harness (no `proptest` available offline).
//!
//! Usage:
//! ```ignore
//! prop::check(200, |rng| {
//!     let n = rng.range_inclusive(1, 64) as usize;
//!     // ... build random inputs, assert invariants, return Ok(()) or Err(msg)
//!     Ok(())
//! });
//! ```
//! Each case gets a PRNG derived from a fixed master seed plus the case
//! index; on failure the panic message names the case seed so the exact
//! case replays with [`check_seeded`].

use super::rng::Pcg32;

/// Master seed for derived case seeds; stable across runs ("kant" in ASCII).
pub const MASTER_SEED: u64 = 0x6b61_6e74_0000_0000;

/// Run `cases` random cases of `property`. Panics on the first failure,
/// reporting the case index and seed.
pub fn check<F>(cases: usize, mut property: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = derive_seed(case);
        let mut rng = Pcg32::seed_from_u64(seed);
        if let Err(msg) = property(&mut rng) {
            panic!("property failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case by seed.
pub fn check_seeded<F>(seed: u64, mut property: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    let mut rng = Pcg32::seed_from_u64(seed);
    if let Err(msg) = property(&mut rng) {
        panic!("property failed (seed {seed:#x}): {msg}");
    }
}

fn derive_seed(case: usize) -> u64 {
    MASTER_SEED ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Assert helper that formats into the property's Err channel.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return Err(format!($($arg)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0;
        check(50, |rng| {
            ran += 1;
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range: {x}"))
            }
        });
        assert_eq!(ran, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(10, |_| Err("always fails".to_string()));
    }

    #[test]
    fn seeds_are_distinct_per_case() {
        let seeds: Vec<u64> = (0..100).map(derive_seed).collect();
        let mut dedup = seeds.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), seeds.len());
    }

    #[test]
    fn prop_assert_macro_formats() {
        let result: Result<(), String> = (|| {
            prop_assert!(1 + 1 == 3, "math broke: {}", 42);
            Ok(())
        })();
        assert_eq!(result.unwrap_err(), "math broke: 42");
    }
}
