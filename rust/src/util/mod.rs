//! Shared substrate utilities: PRNG, JSON, statistics, property-testing and
//! benchmarking harnesses. All hand-rolled — the offline build environment
//! provides no `rand`/`serde`/`proptest`/`criterion` (see DESIGN.md §3).

pub mod benchkit;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
