//! Minimal JSON value, writer and parser.
//!
//! The offline environment has no `serde`/`serde_json`; this module covers
//! the slice Kant needs: the AOT `manifest.json`, JSONL job traces, and
//! metric/figure exports. It is a full RFC-8259 parser minus some exotica
//! (`\u` surrogate pairs are handled; numbers parse via `f64`).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        if let Json::Obj(map) = self {
            map.insert(key.to_string(), value.into());
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Compact single-line rendering (JSONL-friendly).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    out.push_str(&(*x as i64).to_string());
                } else {
                    out.push_str(&x.to_string());
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        if (0xD800..0xDC00).contains(&cp) {
                            // Surrogate pair.
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("bad surrogate pair"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(
                                char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        } else {
                            out.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // Re-decode the multi-byte UTF-8 sequence.
                    let start = self.pos - 1;
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad utf-8")),
                    };
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(s);
                    self.pos = start + len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", "kant").set("gpus", 8u64).set("ok", true);
        let s = j.to_string_compact();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2.5, -3e2], "b": {"c": null}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(j.get("b").unwrap().get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_strings_with_escapes() {
        let j = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let j = Json::parse(r#""😀""#).unwrap();
        assert_eq!(j.as_str(), Some("😀"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let j = Json::parse("\"héllo 世界\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo 世界"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
    }

    #[test]
    fn integer_rendering_has_no_decimal_point() {
        let j = Json::Num(42.0);
        assert_eq!(j.to_string_compact(), "42");
        let j = Json::Num(4.25);
        assert_eq!(j.to_string_compact(), "4.25");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::parse("  [ ]  ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn control_chars_escaped_on_write() {
        let j = Json::Str("a\u{0001}b".to_string());
        assert_eq!(j.to_string_compact(), "\"a\\u0001b\"");
    }
}
