//! Micro-benchmark harness (no `criterion` available offline).
//!
//! `[[bench]] harness = false` binaries use [`Bench`] to run named cases
//! with warmup, a fixed iteration budget, and mean/p50/p99/throughput
//! reporting. Output is both human-readable and machine-parseable
//! (`BENCH\t<name>\t<mean_ns>\t<p50_ns>\t<p99_ns>\t<iters>`), which the
//! perf pass in EXPERIMENTS.md §Perf scrapes.

// Sanctioned wall-clock island: timing loops are this module's job.
#![allow(clippy::disallowed_methods)]

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::stats::Summary;

/// One benchmark runner with shared settings.
pub struct Bench {
    warmup_iters: usize,
    min_iters: usize,
    max_iters: usize,
    target: Duration,
    results: Vec<BenchResult>,
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    /// Optional caller-supplied items-per-iteration for throughput lines.
    pub items_per_iter: Option<f64>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Bench {
        Bench {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 1_000_000,
            target: Duration::from_secs(2),
            results: Vec::new(),
        }
    }

    pub fn warmup(mut self, iters: usize) -> Self {
        self.warmup_iters = iters;
        self
    }

    pub fn target_time(mut self, d: Duration) -> Self {
        self.target = d;
        self
    }

    pub fn min_iters(mut self, n: usize) -> Self {
        self.min_iters = n;
        self
    }

    pub fn max_iters(mut self, n: usize) -> Self {
        self.max_iters = n;
        self
    }

    /// Time `f` repeatedly; `f` should produce a value which is black-boxed.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        self.run_with_items(name, None, &mut f)
    }

    /// Like [`Bench::run`], also reporting `items` per iteration as
    /// throughput.
    pub fn run_throughput<T>(
        &mut self,
        name: &str,
        items: f64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.run_with_items(name, Some(items), &mut f)
    }

    fn run_with_items<T>(
        &mut self,
        name: &str,
        items: Option<f64>,
        f: &mut impl FnMut() -> T,
    ) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        // Estimate cost from one timed call to size the iteration budget.
        let probe = {
            let t0 = Instant::now();
            black_box(f());
            t0.elapsed()
        };
        let est = probe.max(Duration::from_nanos(1));
        let budget = (self.target.as_nanos() / est.as_nanos().max(1)) as usize;
        let iters = budget.clamp(self.min_iters, self.max_iters);

        let mut samples_ns = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        let s = Summary::from_samples(&samples_ns);
        let result = BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: s.mean,
            p50_ns: s.p50,
            p99_ns: s.p99,
            items_per_iter: items,
        };
        print_result(&result);
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

fn print_result(r: &BenchResult) {
    let human = format_ns(r.mean_ns);
    let mut line = format!(
        "{:<48} {:>12}/iter  p50 {:>10}  p99 {:>10}  ({} iters)",
        r.name,
        human,
        format_ns(r.p50_ns),
        format_ns(r.p99_ns),
        r.iters
    );
    if let Some(items) = r.items_per_iter {
        let per_sec = items / (r.mean_ns / 1e9);
        line.push_str(&format!("  {:.3e} items/s", per_sec));
    }
    println!("{line}");
    println!(
        "BENCH\t{}\t{:.1}\t{:.1}\t{:.1}\t{}",
        r.name, r.mean_ns, r.p50_ns, r.p99_ns, r.iters
    );
}

/// Render a result set as the `BENCH_baseline.json` document the perf
/// trajectory tracks across PRs (regenerate from the package root with
/// `BENCH_BASELINE_OUT=BENCH_baseline.json cargo bench --bench sched_cycle`).
pub fn baseline_json(bench: &str, scale: &str, results: &[BenchResult]) -> String {
    use super::json::Json;
    let entries: Vec<Json> = results
        .iter()
        .map(|r| {
            let mut e = Json::obj();
            e.set("name", r.name.as_str())
                .set("iters", r.iters)
                .set("mean_ns", r.mean_ns)
                .set("p50_ns", r.p50_ns)
                .set("p99_ns", r.p99_ns);
            if let Some(items) = r.items_per_iter {
                e.set("items_per_iter", items);
            }
            e
        })
        .collect();
    let mut doc = Json::obj();
    doc.set("schema", "benchkit-v1")
        .set("bench", bench)
        .set("scale", scale)
        .set("results", entries);
    doc.to_string_compact()
}

/// Render nanoseconds with an adaptive unit.
pub fn format_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut b = Bench::new()
            .warmup(1)
            .min_iters(5)
            .max_iters(20)
            .target_time(Duration::from_millis(5));
        b.run("noop", || 1 + 1);
        assert_eq!(b.results().len(), 1);
        let r = &b.results()[0];
        assert!(r.iters >= 5 && r.iters <= 20);
        assert!(r.mean_ns >= 0.0);
    }

    #[test]
    fn throughput_records_items() {
        let mut b = Bench::new()
            .warmup(0)
            .min_iters(3)
            .max_iters(3)
            .target_time(Duration::from_millis(1));
        let r = b.run_throughput("sum", 1000.0, || (0..1000u64).sum::<u64>());
        assert_eq!(r.items_per_iter, Some(1000.0));
    }

    #[test]
    fn baseline_json_roundtrips() {
        let mut b = Bench::new()
            .warmup(0)
            .min_iters(3)
            .max_iters(3)
            .target_time(Duration::from_millis(1));
        b.run_throughput("case", 2.0, || 1 + 1);
        let doc = baseline_json("sched_cycle", "Small", b.results());
        let parsed = crate::util::json::Json::parse(&doc).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some("benchkit-v1"));
        let results = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("name").unwrap().as_str(), Some("case"));
        assert_eq!(results[0].get("items_per_iter").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn format_ns_units() {
        assert_eq!(format_ns(500.0), "500 ns");
        assert_eq!(format_ns(1500.0), "1.50 µs");
        assert_eq!(format_ns(2.5e6), "2.50 ms");
        assert_eq!(format_ns(3.0e9), "3.00 s");
    }
}
