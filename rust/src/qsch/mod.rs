//! QSCH — the Queue-based Scheduler (§3.2): tenant queues, two-tier
//! admission, queueing policies (Strict FIFO / Best-Effort FIFO /
//! Backfill), the three preemption mechanisms, and requeueing.
//!
//! QSCH decides *which job goes next*; the actual placement is delegated to
//! a [`Placer`] (RSCH in production, mocks in tests) — mirroring the
//! paper's QSCH/RSCH decoupling.

pub mod admission;
pub mod policy;
pub mod preemption;
pub mod queue;

use crate::cluster::ids::JobId;
use crate::cluster::state::ClusterState;
use crate::cluster::tenant::QuotaLedger;
use crate::job::spec::{JobSpec, Priority};
use crate::job::state::Phase;
use crate::job::store::JobStore;
use crate::obs::{region_label, DecisionRecord, ObsPhase, ObsRecorder};
use crate::util::stats::percentile_sorted;

use admission::{demand_by_type, dynamic_admission, static_admission};
use policy::{QschConfig, QueuePolicy};
use preemption::{evict, select_victims, PreemptKind};
use queue::{QueueEntry, TenantQueues};

pub use admission::AdmissionFailure as Failure;
pub use policy::{QschConfig as Config, QueuePolicy as Policy};

/// Why a placement attempt failed (returned by the [`Placer`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlaceFailure {
    /// Not enough usable resources (fragmentation, topology constraints).
    Resources,
    /// The job's constraints can never be satisfied on this cluster
    /// (e.g. pod larger than any node). Such jobs are parked, not retried.
    Unsatisfiable,
}

/// The placement half of the pipeline (RSCH implements this).
pub trait Placer {
    /// Try to place `spec`, committing device allocations into `state` on
    /// success (all-or-nothing for gang jobs).
    fn place(&mut self, state: &mut ClusterState, spec: &JobSpec) -> Result<(), PlaceFailure>;

    /// Plan the whole queued batch ahead of the per-job [`Placer::place`]
    /// calls — the superspine-sharded concurrency hook. A placer may plan
    /// shard-local jobs on up to `threads` workers and serve the plans
    /// from a cache when `place` arrives; commits still happen in QSCH's
    /// single-threaded queue order, which *is* the deterministic merge.
    /// The default does nothing (sequential placers need no warm-up).
    fn prefetch(&mut self, _state: &ClusterState, _specs: &[&JobSpec], _threads: usize) {}

    /// Moldable shape selection: for each queued gang that declares a
    /// shape ladder, pick the ladder index it should assume this cycle
    /// (`None` = keep the current shape) given the cluster's current
    /// fragmentation. Called in QSCH's single-threaded phase *before*
    /// [`Placer::prefetch`], so sharded planners inherit the final shapes
    /// and `--shards N` digests stay byte-identical. The default keeps
    /// every shape (fixed-shape placers need no opinion).
    fn mold_shapes(&mut self, _state: &ClusterState, specs: &[&JobSpec]) -> Vec<Option<usize>> {
        vec![None; specs.len()]
    }
}

/// Outcome of one scheduling cycle.
#[derive(Debug, Clone, Default)]
pub struct CycleReport {
    pub scheduled: Vec<JobId>,
    pub preempted: Vec<JobId>,
    /// Malleable victims that shrank a shape rung instead of being
    /// evicted — *not* preemptions: no checkpoint rollback, no lost work.
    pub reshaped: Vec<JobId>,
    pub admission_failures: Vec<(JobId, String)>,
    pub placement_failures: Vec<JobId>,
    pub head_blocked: Option<JobId>,
}

/// Cumulative QSCH counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QschStats {
    pub cycles: u64,
    pub submitted: u64,
    pub scheduled: u64,
    pub scheduled_backfilled: u64,
    pub placement_failures: u64,
    pub backfill_preemptions: u64,
    pub priority_preemptions: u64,
    pub quota_reclaim_preemptions: u64,
    /// Tidal training jobs evicted so inference could scale back up.
    pub slo_pressure_preemptions: u64,
    pub requeues: u64,
    /// Jobs cancelled before natural completion (elastic scale-down).
    pub cancellations: u64,
    /// Starved class heads placed via starvation preemption.
    pub starvation_rescues: u64,
    /// Backfilled victims evicted by starvation preemption.
    pub starvation_preemptions: u64,
    /// Candidates skipped mid-cycle to hold reserved capacity for a
    /// starved class head that could not be placed.
    pub starvation_reservations: u64,
    /// Moldable queued gangs re-shaped by the admission shape-selection
    /// pass (up or down the ladder).
    pub shape_molds: u64,
    /// Malleable victims that shrank one shape rung instead of being
    /// evicted (SLO/fault pressure).
    pub shape_shrinks: u64,
}

/// The queue-based scheduler.
pub struct Qsch {
    pub cfg: QschConfig,
    pub ledger: QuotaLedger,
    pub queues: TenantQueues,
    /// Global head blockage tracker: (job, blocked-since ms).
    head_blocked: Option<(JobId, u64)>,
    pub stats: QschStats,
}

impl Qsch {
    pub fn new(cfg: QschConfig, ledger: QuotaLedger) -> Qsch {
        Qsch {
            cfg,
            ledger,
            queues: TenantQueues::new(),
            head_blocked: None,
            stats: QschStats::default(),
        }
    }

    /// Accept a job into its tenant queue.
    pub fn submit(&mut self, store: &mut JobStore, spec: JobSpec) {
        self.stats.submitted += 1;
        let entry = QueueEntry {
            job: spec.id,
            tenant: spec.tenant,
            priority: spec.priority,
            submit_ms: spec.submit_ms,
            total_gpus: spec.total_gpus(),
        };
        store.insert(crate::job::state::Job::new(spec));
        self.queues.push(entry);
    }

    /// Re-enqueue a job that lost its resources (preemption, node failure)
    /// or needs another attempt — the §3.2.4 requeueing mechanism.
    ///
    /// With `requeue_aging_cap > 0`, each preemption the job has suffered
    /// raises its queue priority one step (capped) — repeatedly-hit gangs
    /// climb the queue instead of starving behind fresher arrivals. The
    /// boost clamps at the class ceiling ([`Priority::aged`]) so an aged
    /// job reorders within its base-priority class but never crosses into
    /// the class above.
    pub fn requeue(&mut self, store: &JobStore, job: JobId) {
        let j = store.expect(job);
        debug_assert_eq!(j.phase, Phase::Queued, "requeue expects a Queued job");
        self.stats.requeues += 1;
        if !self.queues.contains(job) {
            let boost = (j.preemptions.min(u32::from(u8::MAX)) as u8)
                .min(self.cfg.requeue_aging_cap);
            self.queues.push(QueueEntry {
                job,
                tenant: j.spec.tenant,
                priority: j.spec.priority.aged(boost),
                submit_ms: j.submit_ms, // Keep original position.
                total_gpus: j.spec.total_gpus(),
            });
        }
    }

    /// Job completed: release resources + refund quota + close lifecycle.
    pub fn finish_job(
        &mut self,
        store: &mut JobStore,
        state: &mut ClusterState,
        job: JobId,
        now: u64,
    ) {
        state.release_job(job).expect("finished job held resources");
        self.ledger.refund(job).expect("finished job was charged");
        store.expect_mut(job).mark_finished(now);
    }

    /// Cancel a job before natural completion — the elastic scale-down
    /// path releasing a replica-delta child. Queued jobs just leave the
    /// queue; resource-holding jobs release devices and refund quota.
    /// Returns `false` (no-op) for jobs already terminal.
    pub fn cancel_job(
        &mut self,
        store: &mut JobStore,
        state: &mut ClusterState,
        job: JobId,
        now: u64,
    ) -> bool {
        let j = store.expect(job);
        match j.phase {
            Phase::Queued | Phase::Preempted => {
                self.queues.remove(job);
            }
            Phase::Scheduled | Phase::Running => {
                state.release_job(job).expect("cancelled job held resources");
                self.ledger.refund(job).expect("cancelled job was charged");
            }
            Phase::Finished => return false,
            Phase::Admitted => unreachable!("Admitted is cycle-internal"),
        }
        store.expect_mut(job).mark_finished(now);
        self.stats.cancellations += 1;
        true
    }

    /// Evict a running job due to an external failure (node fault) and
    /// requeue it — used by failure-injection tests and the simulator.
    pub fn evict_and_requeue(
        &mut self,
        store: &mut JobStore,
        state: &mut ClusterState,
        job: JobId,
        now: u64,
    ) {
        evict(state, store, &mut self.ledger, &[job], now);
        self.requeue(store, job);
    }

    /// One scheduling cycle over the queues.
    pub fn cycle(
        &mut self,
        now: u64,
        store: &mut JobStore,
        state: &mut ClusterState,
        placer: &mut dyn Placer,
    ) -> CycleReport {
        self.cycle_observed(now, store, state, placer, &mut ObsRecorder::disabled())
    }

    /// [`Qsch::cycle`] with an observability recorder attached: identical
    /// scheduling decisions (the recorder is write-only — no branch below
    /// reads it), plus wall-clock phase spans and [`DecisionRecord`]s.
    pub fn cycle_observed(
        &mut self,
        now: u64,
        store: &mut JobStore,
        state: &mut ClusterState,
        placer: &mut dyn Placer,
        obs: &mut ObsRecorder,
    ) -> CycleReport {
        self.stats.cycles += 1;
        let mut report = CycleReport::default();
        // ---- Moldable shape selection (single-threaded, pre-snapshot) ----
        // The placer re-shapes queued moldable gangs against the current
        // fragmentation picture. Runs before the candidate snapshot (so
        // molded entries are ordered by their new footprint this cycle)
        // and before prefetch (so sharded planners see final shapes —
        // `--shards N` digests stay byte-identical).
        if self.cfg.enable_moldable {
            let t = obs.span();
            self.mold_pass(now, store, state, placer, obs);
            obs.span_end(ObsPhase::Mold, t);
        }
        let candidates = self.queues.global_order();
        if self.cfg.batch_shards > 0 {
            // Sharded prefetch: hand the queued batch to the placer so it
            // can plan across superspine shards concurrently before the
            // sequential walk below consumes the plans in queue order.
            let specs: Vec<&JobSpec> = candidates
                .iter()
                .filter_map(|e| {
                    let j = store.expect(e.job);
                    (j.phase == Phase::Queued).then_some(&j.spec)
                })
                .collect();
            if !specs.is_empty() {
                let t = obs.span();
                placer.prefetch(state, &specs, self.cfg.batch_shards);
                obs.span_end(ObsPhase::Prefetch, t);
            }
        }

        // ---- Anti-starvation bound (hard per-class p99 wait ceiling) ----
        // Deterministic: computed over this cycle's candidate snapshot in
        // the single-threaded phase, so `--shards N` digests are unaffected.
        let bounds = self.cfg.max_jwtd_p99_ms;
        let mut starved = [false; Priority::NUM_CLASSES];
        if bounds.iter().any(|&b| b > 0) {
            let mut waits: [Vec<f64>; Priority::NUM_CLASSES] = Default::default();
            for e in &candidates {
                if store.expect(e.job).phase == Phase::Queued {
                    waits[e.priority.class_index()]
                        .push(now.saturating_sub(e.submit_ms) as f64);
                }
            }
            for (c, w) in waits.iter_mut().enumerate() {
                if bounds[c] == 0 || w.is_empty() {
                    continue;
                }
                w.sort_by(|a, b| a.partial_cmp(b).expect("waits are finite"));
                starved[c] = percentile_sorted(w, 0.99) > bounds[c] as f64;
            }
        }
        let mut class_head_seen = [false; Priority::NUM_CLASSES];
        let mut reserved_class: Option<usize> = None;
        let mut head_failed = false;

        for (i, entry) in candidates.iter().enumerate() {
            let is_head = i == 0;
            // Entries may have left the queue mid-cycle (victims are pushed
            // back with Queued phase but were not in this snapshot; a
            // scheduled job is removed). Only Queued jobs are attempted.
            if store.expect(entry.job).phase != Phase::Queued {
                continue;
            }
            let class = entry.priority.class_index();
            let class_head = !class_head_seen[class];
            class_head_seen[class] = true;
            // Reserved-capacity pass: once a starved class head failed to
            // place even via starvation preemption, capacity is held for
            // it — same-or-lower-class candidates stop competing for the
            // rest of this cycle (quota admission is never bypassed; the
            // held capacity simply is not re-backfilled from under it).
            if let Some(rc) = reserved_class {
                if class <= rc {
                    self.stats.starvation_reservations += 1;
                    continue;
                }
            }

            // ---- Tier 1: static quota admission ----
            let spec = store.expect(entry.job).spec.clone();
            if let Err(failure) = static_admission(&self.ledger, &spec) {
                let mut resolved = false;
                if self.cfg.enable_quota_reclaim {
                    let t = obs.span();
                    resolved =
                        self.try_quota_reclaim(now, store, state, &spec, &mut report, obs);
                    obs.span_end(ObsPhase::Preempt, t);
                }
                if !resolved || static_admission(&self.ledger, &spec).is_err() {
                    if obs.wants(2) {
                        let mut rec = DecisionRecord::for_spec(
                            now,
                            &spec,
                            "admission-rejected",
                            obs.overlay(),
                        );
                        rec.reason = failure.to_string();
                        obs.record(2, rec);
                    }
                    report
                        .admission_failures
                        .push((entry.job, failure.to_string()));
                    if is_head {
                        head_failed = true;
                        self.note_head_blocked(entry.job, now);
                    }
                    if self.cfg.policy.allows_bypass() {
                        continue;
                    } else {
                        break;
                    }
                }
            }

            // ---- Tier 2: dynamic admission + placement ----
            let bypassing = head_failed && !is_head;
            if self.attempt_place(now, store, state, placer, entry.job, bypassing, "", obs) {
                report.scheduled.push(entry.job);
                if is_head {
                    self.head_blocked = None;
                }
                continue;
            }
            report.placement_failures.push(entry.job);
            self.stats.placement_failures += 1;

            // ---- Escalations ----
            let mut rescued = false;
            if is_head {
                head_failed = true;
                let since = self.note_head_blocked(entry.job, now);
                if self.cfg.policy == QueuePolicy::Backfill
                    && now.saturating_sub(since) >= self.cfg.backfill_timeout_ms
                {
                    rescued = self.try_preempt_and_place(
                        now,
                        store,
                        state,
                        placer,
                        entry.job,
                        PreemptKind::Backfill,
                        &mut report,
                        obs,
                    );
                }
            }
            if !rescued
                && self.cfg.enable_priority_preemption
                && spec.priority >= Priority::HIGH
                && now.saturating_sub(spec.submit_ms) >= self.cfg.priority_preempt_min_wait_ms
            {
                rescued = self.try_preempt_and_place(
                    now,
                    store,
                    state,
                    placer,
                    entry.job,
                    PreemptKind::Priority,
                    &mut report,
                    obs,
                );
            }
            // SLO pressure: a blocked scale-up replica delta reclaims
            // capacity from tidal training immediately — inference SLOs
            // do not wait out backfill timeouts.
            if !rescued && self.cfg.enable_slo_reclaim && spec.service.is_some() {
                rescued = self.try_preempt_and_place(
                    now,
                    store,
                    state,
                    placer,
                    entry.job,
                    PreemptKind::SloPressure,
                    &mut report,
                    obs,
                );
            }
            // Anti-starvation rescue: the head of a class whose rolling
            // p99 wait broke its bound — once its own wait is at least
            // half the bound — evicts backfilled peers immediately. If
            // even that cannot place it, hold capacity for it instead.
            if !rescued
                && starved[class]
                && class_head
                && now.saturating_sub(entry.submit_ms) >= bounds[class] / 2
            {
                rescued = self.try_preempt_and_place(
                    now,
                    store,
                    state,
                    placer,
                    entry.job,
                    PreemptKind::Starvation,
                    &mut report,
                    obs,
                );
                if rescued {
                    self.stats.starvation_rescues += 1;
                } else {
                    reserved_class = Some(class);
                }
            }
            if rescued {
                report.scheduled.push(entry.job);
                report.placement_failures.pop();
                if is_head {
                    head_failed = false;
                    self.head_blocked = None;
                }
                continue;
            }

            if !self.cfg.policy.allows_bypass() {
                break; // Strict FIFO: a blocked head blocks everyone.
            }
        }

        if !head_failed {
            // Head either scheduled, or the queue is empty / head changed.
            match (self.head_blocked, self.queues.global_head()) {
                (Some((j, _)), Some(h)) if h.job == j => {}
                _ => self.head_blocked = None,
            }
        }
        report.head_blocked = self.head_blocked.map(|(j, _)| j);
        report
    }

    /// The admission shape-selection pass: hand every queued moldable
    /// gang (in global queue order — deterministic) to the placer and
    /// apply its picks. Re-shaped jobs rescale their owed wall-clock by
    /// the throughput ratio and re-enter the queue ordering with their
    /// new footprint.
    fn mold_pass(
        &mut self,
        now: u64,
        store: &mut JobStore,
        state: &ClusterState,
        placer: &mut dyn Placer,
        obs: &mut ObsRecorder,
    ) {
        let entries: Vec<QueueEntry> = self
            .queues
            .global_order()
            .into_iter()
            .filter(|e| {
                let j = store.expect(e.job);
                j.phase == Phase::Queued && j.spec.moldable()
            })
            .collect();
        if entries.is_empty() {
            return;
        }
        let specs: Vec<JobSpec> = entries
            .iter()
            .map(|e| store.expect(e.job).spec.clone())
            .collect();
        let refs: Vec<&JobSpec> = specs.iter().collect();
        let picks = placer.mold_shapes(state, &refs);
        debug_assert_eq!(picks.len(), refs.len(), "one pick per moldable spec");
        for (e, pick) in entries.iter().zip(picks) {
            let Some(k) = pick else { continue };
            let j = store.expect_mut(e.job);
            let old = j.spec.active_shape().unwrap_or(0);
            if k == old || k >= j.spec.shapes.len() {
                continue;
            }
            let thr_old = j.spec.active_throughput();
            let thr_new = j.spec.shapes[k].throughput;
            j.spec.apply_shape(k);
            j.mark_reshaped(now, thr_old, thr_new);
            self.stats.shape_molds += 1;
            if obs.wants(1) {
                let mut rec = DecisionRecord::for_spec(
                    now,
                    &store.expect(e.job).spec,
                    "molded",
                    obs.overlay(),
                );
                rec.reason = format!("rung {} -> {}", old, k);
                obs.record(1, rec);
            }
            // The queue key includes the gang size: re-insert with the
            // molded footprint (priority/submit keep their slot).
            self.queues.remove(e.job);
            self.queues.push(QueueEntry {
                total_gpus: store.expect(e.job).spec.total_gpus(),
                ..*e
            });
        }
    }

    /// Shrink a malleable victim one rung down its shape ladder instead
    /// of evicting it: the full old footprint is released and refunded
    /// (the capacity a beneficiary needs either way), the owed wall-clock
    /// rescales by the throughput ratio, and the job requeues at the
    /// smaller shape — **no checkpoint rollback, no lost work**: this
    /// models a coordinated re-shard, not a kill. Only moldable
    /// tidal/LOW-class resource holders with a rung left are eligible;
    /// returns `false` (caller should evict) otherwise.
    fn shrink_victim(
        &mut self,
        store: &mut JobStore,
        state: &mut ClusterState,
        job: JobId,
        now: u64,
    ) -> bool {
        if !self.cfg.enable_shrink {
            return false;
        }
        let j = store.expect(job);
        let low_class = j.spec.priority.class_index() == 0;
        if !(j.spec.moldable() && (j.spec.tidal || low_class) && j.holds_resources()) {
            return false;
        }
        let Some(k) = j.spec.active_shape() else {
            return false; // Off-ladder size (elastic drift): evict normally.
        };
        if k + 1 >= j.spec.shapes.len() {
            return false; // Ladder exhausted.
        }
        state
            .release_job(job)
            .expect("shrink victim holds resources");
        self.ledger.refund(job).expect("shrink victim was charged");
        let j = store.expect_mut(job);
        let thr_old = j.spec.shapes[k].throughput;
        let thr_new = j.spec.shapes[k + 1].throughput;
        j.mark_reshaped(now, thr_old, thr_new);
        j.spec.apply_shape(k + 1);
        j.mark_requeued();
        self.stats.shape_shrinks += 1;
        self.requeue(store, job);
        true
    }

    /// Fault-pressure victim entry point (the simulator's path): shrink a
    /// malleable victim if eligible, otherwise evict + requeue. Returns
    /// whether the job was shrunk (`false` ⇒ a real eviction happened).
    pub fn shrink_or_evict_and_requeue(
        &mut self,
        store: &mut JobStore,
        state: &mut ClusterState,
        job: JobId,
        now: u64,
    ) -> bool {
        if self.shrink_victim(store, state, job, now) {
            return true;
        }
        self.evict_and_requeue(store, state, job, now);
        false
    }

    /// Record/refresh head blockage; returns the blocked-since timestamp.
    fn note_head_blocked(&mut self, job: JobId, now: u64) -> u64 {
        match self.head_blocked {
            Some((j, since)) if j == job => since,
            _ => {
                self.head_blocked = Some((job, now));
                now
            }
        }
    }

    /// Dynamic admission + placer attempt + on success: quota charge and
    /// lifecycle transition. `via` labels how the attempt was reached
    /// ("" = plain queue walk, otherwise the escalation kind) — it only
    /// feeds the decision record, never a scheduling branch.
    #[allow(clippy::too_many_arguments)]
    fn attempt_place(
        &mut self,
        now: u64,
        store: &mut JobStore,
        state: &mut ClusterState,
        placer: &mut dyn Placer,
        job: JobId,
        bypassed_blocked_head: bool,
        via: &str,
        obs: &mut ObsRecorder,
    ) -> bool {
        let plan_span = obs.span();
        let spec = store.expect(job).spec.clone();
        if dynamic_admission(state, &spec).is_err() {
            obs.span_end(ObsPhase::Plan, plan_span);
            if obs.wants(2) {
                let mut rec =
                    DecisionRecord::for_spec(now, &spec, "placement-failed", obs.overlay());
                rec.reason = "dynamic-admission".to_string();
                obs.record(2, rec);
            }
            return false;
        }
        match placer.place(state, &spec) {
            Ok(()) => {
                obs.span_end(ObsPhase::Plan, plan_span);
                let commit_span = obs.span();
                self.ledger
                    .charge(job, spec.tenant, &demand_by_type(&spec))
                    .expect("static admission verified headroom");
                let j = store.expect_mut(job);
                j.mark_admitted();
                j.mark_scheduled(now);
                j.backfilled = bypassed_blocked_head;
                self.queues.remove(job);
                self.stats.scheduled += 1;
                if bypassed_blocked_head {
                    self.stats.scheduled_backfilled += 1;
                }
                obs.span_end(ObsPhase::Commit, commit_span);
                if obs.wants(1) {
                    let nodes = state.nodes_of(job);
                    let mut rec =
                        DecisionRecord::for_spec(now, &spec, "scheduled", obs.overlay());
                    rec.reason = if !via.is_empty() {
                        via.to_string()
                    } else if bypassed_blocked_head {
                        "backfill-bypass".to_string()
                    } else {
                        String::new()
                    };
                    rec.region = region_label(state, &nodes);
                    rec.nodes = nodes.len() as u64;
                    obs.record(1, rec);
                }
                true
            }
            Err(e) => {
                obs.span_end(ObsPhase::Plan, plan_span);
                if obs.wants(2) {
                    let mut rec =
                        DecisionRecord::for_spec(now, &spec, "placement-failed", obs.overlay());
                    rec.reason = match e {
                        PlaceFailure::Resources => "no-feasible-plan".to_string(),
                        PlaceFailure::Unsatisfiable => "unsatisfiable".to_string(),
                    };
                    obs.record(2, rec);
                }
                false
            }
        }
    }

    /// Preempt eligible victims for `job`, then retry placement once.
    ///
    /// The whole escalation (victim selection, eviction, retry) runs
    /// under one `Preempt` span; the retry's `Plan`/`Commit` time is
    /// also counted by `attempt_place`, so phase columns may overlap.
    fn try_preempt_and_place(
        &mut self,
        now: u64,
        store: &mut JobStore,
        state: &mut ClusterState,
        placer: &mut dyn Placer,
        job: JobId,
        kind: PreemptKind,
        report: &mut CycleReport,
        obs: &mut ObsRecorder,
    ) -> bool {
        let span = obs.span();
        let spec = store.expect(job).spec.clone();
        let need = demand_by_type(&spec);
        let prio = spec.priority;
        let victims = match kind {
            PreemptKind::Backfill => {
                let shortage = select_victims(state, store, &need, |j| {
                    j.backfilled && j.spec.priority <= prio
                });
                match shortage {
                    // Enough raw capacity exists but placement failed ⇒
                    // fragmentation: fall back to defrag victim selection.
                    Some(v) if v.is_empty() => {
                        preemption::select_defrag_victims(state, store, &need, |j| {
                            j.backfilled && j.spec.priority <= prio
                        })
                    }
                    other => other,
                }
            }
            PreemptKind::Priority => {
                select_victims(state, store, &need, |j| j.spec.priority < prio)
            }
            // Starvation mirrors Backfill's victim rule (backfilled jobs
            // of no higher base priority) but is triggered by the p99
            // bound, not the head timeout — an aged job's preemption
            // rights still read its base priority.
            PreemptKind::Starvation => {
                let shortage = select_victims(state, store, &need, |j| {
                    j.backfilled && j.spec.priority <= prio
                });
                match shortage {
                    Some(v) if v.is_empty() => {
                        preemption::select_defrag_victims(state, store, &need, |j| {
                            j.backfilled && j.spec.priority <= prio
                        })
                    }
                    other => other,
                }
            }
            PreemptKind::SloPressure => {
                let shortage = select_victims(state, store, &need, |j| j.spec.tidal);
                match shortage {
                    // Capacity exists but is fragmented: consolidate by
                    // evicting tidal jobs on fragmented nodes instead.
                    Some(v) if v.is_empty() => {
                        preemption::select_defrag_victims(state, store, &need, |j| {
                            j.spec.tidal
                        })
                    }
                    other => other,
                }
            }
            PreemptKind::QuotaReclaim => unreachable!("handled in try_quota_reclaim"),
        };
        let Some(victims) = victims else {
            obs.span_end(ObsPhase::Preempt, span);
            return false; // Conservative: no complete victim set.
        };
        if victims.is_empty() {
            obs.span_end(ObsPhase::Preempt, span);
            return false; // Resources exist; placement failed for another
                          // reason (fragmentation) — preemption won't help.
        }
        // Malleable victims shrink one rung instead of dying (SLO
        // pressure only — the reclamation that targets tidal training).
        // The full old footprint is freed either way, so the
        // beneficiary's capacity math is untouched.
        let mut evicted: Vec<JobId> = Vec::new();
        for &v in &victims {
            if kind == PreemptKind::SloPressure && self.shrink_victim(store, state, v, now) {
                report.reshaped.push(v);
                if obs.wants(1) {
                    // Spec already carries the shrunken rung here.
                    let mut rec = DecisionRecord::for_spec(
                        now,
                        &store.expect(v).spec,
                        "reshaped",
                        obs.overlay(),
                    );
                    rec.reason = preempt_label(kind).to_string();
                    obs.record(1, rec);
                }
            } else {
                evicted.push(v);
            }
        }
        evict(state, store, &mut self.ledger, &evicted, now);
        for &v in &evicted {
            self.requeue(store, v);
            report.preempted.push(v);
            if obs.wants(1) {
                let mut rec = DecisionRecord::for_spec(
                    now,
                    &store.expect(v).spec,
                    "preempted",
                    obs.overlay(),
                );
                rec.reason = preempt_label(kind).to_string();
                obs.record(1, rec);
            }
        }
        match kind {
            PreemptKind::Backfill => self.stats.backfill_preemptions += evicted.len() as u64,
            PreemptKind::Priority => self.stats.priority_preemptions += evicted.len() as u64,
            PreemptKind::SloPressure => {
                self.stats.slo_pressure_preemptions += evicted.len() as u64
            }
            PreemptKind::Starvation => {
                self.stats.starvation_preemptions += evicted.len() as u64
            }
            PreemptKind::QuotaReclaim => {}
        }
        let placed =
            self.attempt_place(now, store, state, placer, job, false, preempt_label(kind), obs);
        obs.span_end(ObsPhase::Preempt, span);
        placed
    }

    /// Quota-reclamation preemption: evict jobs borrowing this tenant's
    /// quota until the demand fits. Conservative: aborts (no eviction) if
    /// the reclaimable total cannot cover the shortfall.
    fn try_quota_reclaim(
        &mut self,
        now: u64,
        store: &mut JobStore,
        state: &mut ClusterState,
        spec: &JobSpec,
        report: &mut CycleReport,
        obs: &mut ObsRecorder,
    ) -> bool {
        let mut victims: Vec<JobId> = Vec::new();
        for (g, amount) in demand_by_type(spec) {
            let available = self.ledger.available(spec.tenant, g);
            if available >= amount {
                continue;
            }
            let mut shortfall = amount - available;
            for rec in self.ledger.debtors(spec.tenant, g) {
                if shortfall == 0 {
                    break;
                }
                if victims.contains(&rec.job) {
                    continue;
                }
                // Only evict jobs that actually hold resources.
                if store
                    .get(rec.job)
                    .map(|j| j.holds_resources())
                    .unwrap_or(false)
                {
                    victims.push(rec.job);
                    shortfall = shortfall.saturating_sub(rec.amount);
                }
            }
            if shortfall > 0 {
                return false; // Cannot reclaim enough; do nothing.
            }
        }
        if victims.is_empty() {
            return false;
        }
        evict(state, store, &mut self.ledger, &victims, now);
        self.stats.quota_reclaim_preemptions += victims.len() as u64;
        for &v in &victims {
            self.requeue(store, v);
            report.preempted.push(v);
            if obs.wants(1) {
                let mut rec = DecisionRecord::for_spec(
                    now,
                    &store.expect(v).spec,
                    "preempted",
                    obs.overlay(),
                );
                rec.reason = preempt_label(PreemptKind::QuotaReclaim).to_string();
                obs.record(1, rec);
            }
        }
        true
    }

    /// How long the current head has been blocked (for metrics/inspection).
    pub fn head_blocked_for(&self, now: u64) -> Option<(JobId, u64)> {
        self.head_blocked
            .map(|(j, since)| (j, now.saturating_sub(since)))
    }
}

/// Decision-record `reason` label for an escalation kind.
fn preempt_label(kind: PreemptKind) -> &'static str {
    match kind {
        PreemptKind::Backfill => "backfill-timeout",
        PreemptKind::Priority => "priority",
        PreemptKind::SloPressure => "slo-pressure",
        PreemptKind::Starvation => "starvation",
        PreemptKind::QuotaReclaim => "quota-reclaim",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::builder::{ClusterBuilder, ClusterSpec};
    use crate::cluster::ids::{GpuTypeId, NodeId, PodId, TenantId};
    use crate::cluster::state::PodPlacement;
    use crate::cluster::tenant::QuotaMode;
    use crate::job::spec::{GangShape, JobKind};

    const G: GpuTypeId = GpuTypeId(0);

    /// First-fit mock placer: one pod per `replicas`, each taking
    /// `gpus_per_pod` devices from the first nodes with room.
    struct FirstFit;

    impl Placer for FirstFit {
        fn place(
            &mut self,
            state: &mut ClusterState,
            spec: &JobSpec,
        ) -> Result<(), PlaceFailure> {
            let mut plan = Vec::new();
            let mut replica = 0u32;
            for d in &spec.demands {
                for _ in 0..d.replicas {
                    let mut found = None;
                    for n in &state.nodes {
                        let already: usize = plan
                            .iter()
                            .filter(|p: &&PodPlacement| p.node == n.id)
                            .map(|p| p.devices.len())
                            .sum();
                        let free = n.free_gpu_indices();
                        if free.len() >= already + d.gpus_per_pod as usize {
                            found = Some((
                                n.id,
                                free[already..already + d.gpus_per_pod as usize].to_vec(),
                            ));
                            break;
                        }
                    }
                    match found {
                        Some((node, devices)) => {
                            plan.push(PodPlacement {
                                pod: PodId::new(spec.id, replica),
                                node,
                                devices,
                                nic: 0,
                            });
                            replica += 1;
                        }
                        None => return Err(PlaceFailure::Resources),
                    }
                }
            }
            state
                .commit_placements(spec.id, plan)
                .map_err(|_| PlaceFailure::Resources)
        }
    }

    fn setup(policy: QschConfig) -> (Qsch, JobStore, ClusterState) {
        // 4 nodes × 8 GPUs = 32 GPUs, one group.
        let state = ClusterBuilder::build(&ClusterSpec::homogeneous("t", 1, 1, 4));
        let mut ledger = QuotaLedger::new(2, 1, QuotaMode::Shared);
        ledger.set_limit(TenantId(0), G, 32);
        ledger.set_limit(TenantId(1), G, 32);
        (Qsch::new(policy, ledger), JobStore::new(), state)
    }

    fn job(id: u64, gpus_per_pod: u32, replicas: u32) -> JobSpec {
        JobSpec::homogeneous(
            JobId(id),
            TenantId(0),
            JobKind::Training,
            G,
            replicas,
            gpus_per_pod,
        )
    }

    #[test]
    fn simple_job_schedules() {
        let (mut q, mut store, mut state) = setup(QschConfig::default());
        q.submit(&mut store, job(1, 8, 2));
        let r = q.cycle(0, &mut store, &mut state, &mut FirstFit);
        assert_eq!(r.scheduled, vec![JobId(1)]);
        assert_eq!(state.allocated_gpus(), 16);
        assert_eq!(store.expect(JobId(1)).phase, Phase::Scheduled);
        assert!(q.queues.is_empty());
    }

    #[test]
    fn strict_fifo_blocks_behind_big_head() {
        let (mut q, mut store, mut state) = setup(QschConfig::strict_fifo());
        // Occupy 24 of 32 GPUs.
        q.submit(&mut store, job(1, 8, 3).with_times(0, 100_000));
        q.cycle(0, &mut store, &mut state, &mut FirstFit);
        // Head needs 16 (impossible), small job behind it could fit.
        q.submit(&mut store, job(2, 8, 2).with_times(10, 100_000));
        q.submit(&mut store, job(3, 1, 1).with_times(20, 100_000));
        let r = q.cycle(1_000, &mut store, &mut state, &mut FirstFit);
        assert!(r.scheduled.is_empty(), "strict FIFO must not bypass");
        assert_eq!(r.head_blocked, Some(JobId(2)));
    }

    #[test]
    fn best_effort_bypasses_blocked_head() {
        let (mut q, mut store, mut state) = setup(QschConfig::best_effort());
        q.submit(&mut store, job(1, 8, 3).with_times(0, 100_000));
        q.cycle(0, &mut store, &mut state, &mut FirstFit);
        q.submit(&mut store, job(2, 8, 2).with_times(10, 100_000));
        q.submit(&mut store, job(3, 1, 1).with_times(20, 100_000));
        let r = q.cycle(1_000, &mut store, &mut state, &mut FirstFit);
        assert_eq!(r.scheduled, vec![JobId(3)]);
        assert!(store.expect(JobId(3)).backfilled);
    }

    #[test]
    fn backfill_preempts_after_timeout() {
        let mut cfg = QschConfig::backfill(5_000);
        cfg.enable_priority_preemption = false;
        let (mut q, mut store, mut state) = setup(cfg);
        // 24/32 GPUs busy with a job that will finish at t=6000.
        q.submit(&mut store, job(1, 8, 3).with_times(0, 6_000));
        q.cycle(0, &mut store, &mut state, &mut FirstFit);
        assert_eq!(state.allocated_gpus(), 24);
        // Head wants the whole cluster (32): blocked.
        q.submit(&mut store, job(2, 8, 4).with_times(10, 100_000));
        // A small job backfills into the remaining node.
        q.submit(&mut store, job(3, 8, 1).with_times(20, 1_000_000));
        let r = q.cycle(1_000, &mut store, &mut state, &mut FirstFit);
        assert_eq!(r.scheduled, vec![JobId(3)]);
        assert!(store.expect(JobId(3)).backfilled);
        assert_eq!(r.head_blocked, Some(JobId(2)));

        // Before the timeout: no preemption even though the head waits.
        let r = q.cycle(3_000, &mut store, &mut state, &mut FirstFit);
        assert!(r.preempted.is_empty());

        // job1 finishes; 24 free but the backfilled job still holds 8.
        q.finish_job(&mut store, &mut state, JobId(1), 6_000);
        // Past the timeout: evict the backfilled job → head fits.
        let r = q.cycle(7_000, &mut store, &mut state, &mut FirstFit);
        assert_eq!(r.preempted, vec![JobId(3)]);
        assert_eq!(r.scheduled, vec![JobId(2)]);
        assert_eq!(q.stats.backfill_preemptions, 1);
        assert_eq!(state.allocated_gpus(), 32);
        // The victim is requeued (§3.2.4) and keeps its original position.
        assert!(q.queues.contains(JobId(3)));
        assert_eq!(store.expect(JobId(3)).phase, Phase::Queued);
        assert_eq!(store.expect(JobId(3)).preemptions, 1);
    }

    #[test]
    fn priority_preemption_rescues_high_job() {
        let mut cfg = QschConfig::default();
        cfg.priority_preempt_min_wait_ms = 1_000;
        cfg.policy = QueuePolicy::BestEffortFifo;
        let (mut q, mut store, mut state) = setup(cfg);
        // Fill the whole cluster with NORMAL jobs.
        for i in 1..=4 {
            q.submit(&mut store, job(i, 8, 1).with_times(0, 1_000_000));
        }
        q.cycle(0, &mut store, &mut state, &mut FirstFit);
        assert_eq!(state.allocated_gpus(), 32);
        // HIGH-priority job arrives.
        q.submit(
            &mut store,
            job(5, 8, 1)
                .with_times(100, 10_000)
                .with_priority(Priority::HIGH),
        );
        // Too early (min wait not reached).
        let r = q.cycle(500, &mut store, &mut state, &mut FirstFit);
        assert!(r.scheduled.is_empty());
        // After min wait: evict one NORMAL job.
        let r = q.cycle(2_000, &mut store, &mut state, &mut FirstFit);
        assert_eq!(r.scheduled, vec![JobId(5)]);
        assert_eq!(r.preempted.len(), 1);
        assert_eq!(q.stats.priority_preemptions, 1);
    }

    #[test]
    fn quota_reclaim_evicts_debtor() {
        let mut cfg = QschConfig::default();
        cfg.policy = QueuePolicy::BestEffortFifo;
        let (mut q, mut store, mut state) = setup(cfg);
        // Tighter quotas: each tenant 16.
        q.ledger.set_limit(TenantId(0), G, 16);
        q.ledger.set_limit(TenantId(1), G, 16);
        // Tenant 0 borrows 16 beyond its own 16 → uses all 32.
        q.submit(&mut store, job(1, 8, 4).with_times(0, 1_000_000));
        q.cycle(0, &mut store, &mut state, &mut FirstFit);
        assert!(q.ledger.is_borrowing(JobId(1)));
        // Tenant 1 wants its quota back.
        let mut j2 = job(2, 8, 2).with_times(10, 10_000);
        j2.tenant = TenantId(1);
        q.submit(&mut store, j2);
        let r = q.cycle(1_000, &mut store, &mut state, &mut FirstFit);
        assert_eq!(r.preempted, vec![JobId(1)]);
        assert_eq!(r.scheduled, vec![JobId(2)]);
        assert_eq!(q.stats.quota_reclaim_preemptions, 1);
    }

    #[test]
    fn finish_job_releases_and_refunds() {
        let (mut q, mut store, mut state) = setup(QschConfig::default());
        q.submit(&mut store, job(1, 8, 1));
        q.cycle(0, &mut store, &mut state, &mut FirstFit);
        q.finish_job(&mut store, &mut state, JobId(1), 60_000);
        assert_eq!(state.allocated_gpus(), 0);
        assert_eq!(q.ledger.entry(TenantId(0), G).used_own, 0);
        assert!(store.expect(JobId(1)).is_terminal());
    }

    #[test]
    fn requeue_after_external_eviction() {
        let (mut q, mut store, mut state) = setup(QschConfig::default());
        q.submit(&mut store, job(1, 8, 1));
        q.cycle(0, &mut store, &mut state, &mut FirstFit);
        q.evict_and_requeue(&mut store, &mut state, JobId(1), 5_000);
        assert_eq!(store.expect(JobId(1)).phase, Phase::Queued);
        assert!(q.queues.contains(JobId(1)));
        // It reschedules next cycle.
        let r = q.cycle(6_000, &mut store, &mut state, &mut FirstFit);
        assert_eq!(r.scheduled, vec![JobId(1)]);
        // JWTD keeps the FIRST scheduling time.
        assert_eq!(store.expect(JobId(1)).scheduled_ms, Some(0));
    }

    #[test]
    fn gang_all_or_nothing_through_placer() {
        let (mut q, mut store, mut state) = setup(QschConfig::default());
        // 5 whole-node pods on a 4-node cluster: dynamic admission fails
        // (40 > 32) — nothing allocated.
        q.submit(&mut store, job(1, 8, 5));
        let r = q.cycle(0, &mut store, &mut state, &mut FirstFit);
        assert!(r.scheduled.is_empty());
        assert_eq!(state.allocated_gpus(), 0);
    }

    #[test]
    fn slo_pressure_evicts_tidal_training_for_scale_up() {
        let (mut q, mut store, mut state) = setup(QschConfig::default());
        // Fill the whole cluster with tidal LOW-priority training.
        for i in 1..=4 {
            q.submit(
                &mut store,
                job(i, 8, 1)
                    .with_times(0, 1_000_000)
                    .with_priority(Priority::LOW)
                    .with_tidal(),
            );
        }
        q.cycle(0, &mut store, &mut state, &mut FirstFit);
        assert_eq!(state.allocated_gpus(), 32);
        // An elastic scale-up replica delta arrives: 2 single-GPU pods.
        let mut child = job(5, 1, 2).with_times(10, 100_000);
        child.kind = JobKind::Inference;
        child.gang = false;
        child.service = Some(JobId(900));
        q.submit(&mut store, child);
        // Long before any backfill timeout, SLO pressure reclaims.
        let r = q.cycle(1_000, &mut store, &mut state, &mut FirstFit);
        assert_eq!(r.scheduled, vec![JobId(5)]);
        assert_eq!(r.preempted.len(), 1);
        assert!(store.expect(r.preempted[0]).spec.tidal);
        assert_eq!(q.stats.slo_pressure_preemptions, 1);
        // The victim is requeued for the next tide.
        assert!(q.queues.contains(r.preempted[0]));
    }

    #[test]
    fn slo_pressure_never_touches_non_tidal_jobs() {
        let (mut q, mut store, mut state) = setup(QschConfig::default());
        for i in 1..=4 {
            // Plain (non-tidal) training fills the cluster.
            q.submit(&mut store, job(i, 8, 1).with_times(0, 1_000_000));
        }
        q.cycle(0, &mut store, &mut state, &mut FirstFit);
        let mut child = job(5, 1, 2).with_times(10, 100_000);
        child.kind = JobKind::Inference;
        child.gang = false;
        child.service = Some(JobId(900));
        q.submit(&mut store, child);
        let r = q.cycle(1_000, &mut store, &mut state, &mut FirstFit);
        assert!(r.scheduled.is_empty());
        assert!(r.preempted.is_empty());
        assert_eq!(q.stats.slo_pressure_preemptions, 0);
    }

    #[test]
    fn cancel_job_releases_or_dequeues() {
        let (mut q, mut store, mut state) = setup(QschConfig::default());
        // A placed job: cancel releases devices and refunds quota.
        q.submit(&mut store, job(1, 8, 1));
        q.cycle(0, &mut store, &mut state, &mut FirstFit);
        assert_eq!(state.allocated_gpus(), 8);
        assert!(q.cancel_job(&mut store, &mut state, JobId(1), 5_000));
        assert_eq!(state.allocated_gpus(), 0);
        assert_eq!(q.ledger.entry(TenantId(0), G).used_own, 0);
        assert!(store.expect(JobId(1)).is_terminal());
        // A queued job: cancel just removes it from the queue.
        q.submit(&mut store, job(2, 8, 5)); // 40 > 32: never admits.
        q.cycle(6_000, &mut store, &mut state, &mut FirstFit);
        assert!(q.queues.contains(JobId(2)));
        assert!(q.cancel_job(&mut store, &mut state, JobId(2), 7_000));
        assert!(!q.queues.contains(JobId(2)));
        assert!(store.expect(JobId(2)).is_terminal());
        // Cancelling a terminal job is a no-op.
        assert!(!q.cancel_job(&mut store, &mut state, JobId(2), 8_000));
        assert_eq!(q.stats.cancellations, 2);
    }

    #[test]
    fn requeue_aging_lifts_repeatedly_evicted_jobs() {
        let run_order = |aging_cap: u8| -> Vec<u64> {
            let cfg = QschConfig {
                requeue_aging_cap: aging_cap,
                ..QschConfig::default()
            };
            let (mut q, mut store, mut state) = setup(cfg);
            // 24 of 32 GPUs busy; a 16-GPU job blocks; an 8-GPU job
            // backfills into the last node.
            q.submit(&mut store, job(1, 8, 3).with_times(0, 1_000_000));
            q.cycle(0, &mut store, &mut state, &mut FirstFit);
            q.submit(&mut store, job(2, 8, 2).with_times(5, 1_000_000));
            q.submit(&mut store, job(3, 8, 1).with_times(10, 1_000_000));
            q.cycle(100, &mut store, &mut state, &mut FirstFit);
            assert!(store.expect(JobId(3)).holds_resources());
            // A node fault evicts the backfilled job; it requeues behind
            // (or, aged, ahead of) the blocked 16-GPU job.
            q.evict_and_requeue(&mut store, &mut state, JobId(3), 1_000);
            q.queues.global_order().iter().map(|e| e.job.0).collect()
        };
        // Aged: one suffered preemption lifts job 3 above the NORMAL head.
        assert_eq!(run_order(4), vec![3, 2]);
        // Aging disabled: submit order rules; the evicted job waits.
        assert_eq!(run_order(0), vec![2, 3]);
    }

    #[test]
    fn starvation_bound_rescues_starved_class_head() {
        let mut cfg = QschConfig::default();
        cfg.backfill_timeout_ms = 1_000_000_000; // Isolate the starvation path.
        cfg.enable_priority_preemption = false;
        cfg.max_jwtd_p99_ms = [60_000, 0, 0]; // LOW class bounded at 60 s.
        let (mut q, mut store, mut state) = setup(cfg);
        // Nodes 0-1 pinned by long NORMAL work; nodes 2-3 by LOW jobs
        // that bypassed a blocked head earlier (marked backfilled
        // directly to keep the setup small).
        q.submit(&mut store, job(1, 8, 2).with_times(0, 10_000_000));
        q.cycle(0, &mut store, &mut state, &mut FirstFit);
        q.submit(
            &mut store,
            job(3, 8, 1).with_times(10, 10_000_000).with_priority(Priority::LOW),
        );
        q.submit(
            &mut store,
            job(4, 8, 1).with_times(11, 10_000_000).with_priority(Priority::LOW),
        );
        q.cycle(100, &mut store, &mut state, &mut FirstFit);
        assert_eq!(state.allocated_gpus(), 32);
        store.expect_mut(JobId(3)).backfilled = true;
        store.expect_mut(JobId(4)).backfilled = true;
        // The starving LOW gang: wants 16 GPUs behind a full cluster.
        q.submit(
            &mut store,
            job(2, 8, 2).with_times(200, 10_000_000).with_priority(Priority::LOW),
        );
        // Below the bound: placement fails, nothing is evicted.
        let r = q.cycle(30_000, &mut store, &mut state, &mut FirstFit);
        assert_eq!(r.placement_failures, vec![JobId(2)]);
        assert!(r.preempted.is_empty());
        assert_eq!(q.stats.starvation_rescues, 0);
        // Past the bound: the class head evicts the backfilled pair
        // without waiting out the (huge) backfill timeout.
        let r = q.cycle(100_000, &mut store, &mut state, &mut FirstFit);
        assert_eq!(r.preempted.len(), 2);
        assert_eq!(r.scheduled, vec![JobId(2)]);
        assert_eq!(q.stats.starvation_rescues, 1);
        assert_eq!(q.stats.starvation_preemptions, 2);
        // Victims are requeued, not lost.
        assert!(q.queues.contains(JobId(3)));
        assert!(q.queues.contains(JobId(4)));
    }

    #[test]
    fn starvation_reservation_holds_capacity_for_starved_head() {
        let run = |bound: u64| -> (CycleReport, QschStats, u32) {
            let mut cfg = QschConfig::default();
            cfg.backfill_timeout_ms = 1_000_000_000;
            cfg.enable_priority_preemption = false;
            cfg.max_jwtd_p99_ms = [bound, 0, 0];
            let (mut q, mut store, mut state) = setup(cfg);
            // 24 of 32 GPUs pinned by non-backfilled work: starvation
            // preemption has no eligible victims.
            q.submit(&mut store, job(1, 8, 3).with_times(0, 10_000_000));
            q.cycle(0, &mut store, &mut state, &mut FirstFit);
            // The starved head wants 16; a later LOW job would fit in
            // the one free node.
            q.submit(
                &mut store,
                job(2, 8, 2).with_times(10, 10_000_000).with_priority(Priority::LOW),
            );
            q.submit(
                &mut store,
                job(3, 8, 1).with_times(20, 10_000_000).with_priority(Priority::LOW),
            );
            let r = q.cycle(100_000, &mut store, &mut state, &mut FirstFit);
            (r, q.stats, state.allocated_gpus())
        };
        // Bound off: the small LOW job backfills into the free node.
        let (r, stats, used) = run(0);
        assert_eq!(r.scheduled, vec![JobId(3)]);
        assert_eq!(stats.starvation_reservations, 0);
        assert_eq!(used, 32);
        // Bound broken and no backfilled victims: the would-be
        // backfiller is skipped, leaving the free node held for job 2.
        let (r, stats, used) = run(60_000);
        assert!(r.scheduled.is_empty());
        assert_eq!(stats.starvation_reservations, 1);
        assert_eq!(stats.starvation_rescues, 0);
        assert_eq!(used, 24);
    }

    /// First-fit placer whose shape-selection pass always proposes the
    /// same ladder index for every moldable spec.
    struct MoldFirstFit {
        pick: Option<usize>,
    }

    impl Placer for MoldFirstFit {
        fn place(
            &mut self,
            state: &mut ClusterState,
            spec: &JobSpec,
        ) -> Result<(), PlaceFailure> {
            FirstFit.place(state, spec)
        }

        fn mold_shapes(
            &mut self,
            _state: &ClusterState,
            specs: &[&JobSpec],
        ) -> Vec<Option<usize>> {
            vec![self.pick; specs.len()]
        }
    }

    fn ladder_2_to_1() -> Vec<GangShape> {
        vec![
            GangShape {
                replicas: 2,
                throughput: 1.0,
            },
            GangShape {
                replicas: 1,
                throughput: 0.55,
            },
        ]
    }

    #[test]
    fn mold_pass_reshapes_queued_gangs_before_placement() {
        let cfg = QschConfig {
            enable_moldable: true,
            ..QschConfig::default()
        };
        let (mut q, mut store, mut state) = setup(cfg);
        // 24 of 32 GPUs pinned: the full 4-pod shape cannot fit.
        q.submit(&mut store, job(1, 8, 3).with_times(0, 1_000_000));
        q.cycle(0, &mut store, &mut state, &mut FirstFit);
        // A moldable 4-pod gang with a 1-pod fallback at 0.3× throughput.
        q.submit(
            &mut store,
            job(2, 8, 4).with_times(10, 100_000).with_shapes(vec![
                GangShape {
                    replicas: 4,
                    throughput: 1.0,
                },
                GangShape {
                    replicas: 1,
                    throughput: 0.3,
                },
            ]),
        );
        let mut p = MoldFirstFit { pick: Some(1) };
        let r = q.cycle(100, &mut store, &mut state, &mut p);
        assert_eq!(r.scheduled, vec![JobId(2)]);
        assert_eq!(q.stats.shape_molds, 1);
        let j = store.expect(JobId(2));
        assert_eq!(j.spec.active_shape(), Some(1));
        assert_eq!(j.spec.total_gpus(), 8, "molded to the 1-pod shape");
        assert_eq!(j.spec.base_total_gpus(), 32, "work content unchanged");
        assert_eq!(j.shape_changes, 1);
        // Owed wall-clock rescales by thr_old / thr_new.
        assert_eq!(j.remaining_ms, (100_000f64 * (1.0 / 0.3)).ceil() as u64);
        assert_eq!(state.allocated_gpus(), 32);
        // Moldable off: the same placer pick is never solicited.
        let (mut q, mut store, mut state) = setup(QschConfig::default());
        q.submit(
            &mut store,
            job(2, 8, 4).with_times(10, 100_000).with_shapes(vec![
                GangShape {
                    replicas: 4,
                    throughput: 1.0,
                },
                GangShape {
                    replicas: 1,
                    throughput: 0.3,
                },
            ]),
        );
        let r = q.cycle(100, &mut store, &mut state, &mut MoldFirstFit { pick: Some(1) });
        assert_eq!(r.scheduled, vec![JobId(2)]);
        assert_eq!(q.stats.shape_molds, 0);
        assert_eq!(store.expect(JobId(2)).spec.total_gpus(), 32);
    }

    #[test]
    fn slo_pressure_shrinks_malleable_tidal_instead_of_evicting() {
        let cfg = QschConfig {
            enable_shrink: true,
            ..QschConfig::default()
        };
        let (mut q, mut store, mut state) = setup(cfg);
        // Fill the cluster with 4 malleable tidal LOW gangs (2 pods × 4).
        for i in 1..=4 {
            q.submit(
                &mut store,
                job(i, 4, 2)
                    .with_times(0, 1_000_000)
                    .with_priority(Priority::LOW)
                    .with_tidal()
                    .with_shapes(ladder_2_to_1()),
            );
        }
        q.cycle(0, &mut store, &mut state, &mut FirstFit);
        assert_eq!(state.allocated_gpus(), 32);
        // An elastic scale-up replica delta arrives: 2 single-GPU pods.
        let mut child = job(5, 1, 2).with_times(10, 100_000);
        child.kind = JobKind::Inference;
        child.gang = false;
        child.service = Some(JobId(900));
        q.submit(&mut store, child);
        let r = q.cycle(1_000, &mut store, &mut state, &mut FirstFit);
        assert_eq!(r.scheduled, vec![JobId(5)]);
        // The tidal victim shrank instead of dying.
        assert_eq!(r.reshaped.len(), 1);
        assert!(r.preempted.is_empty());
        assert_eq!(q.stats.shape_shrinks, 1);
        assert_eq!(q.stats.slo_pressure_preemptions, 0);
        let v = store.expect(r.reshaped[0]);
        assert_eq!(v.spec.total_gpus(), 4, "one rung down the ladder");
        assert_eq!(v.preemptions, 0, "a shrink is not a preemption");
        assert_eq!(v.lost_work_ms, 0, "re-shard keeps all progress");
        assert_eq!(v.shape_changes, 1);
        assert!(q.queues.contains(v.id()));
        // Books: victim footprint refunded, child charged.
        assert_eq!(state.allocated_gpus(), 32 - 8 + 2);
        // The shrunk gang re-places at its smaller shape next cycle.
        let r = q.cycle(2_000, &mut store, &mut state, &mut FirstFit);
        assert_eq!(r.scheduled.len(), 1);
        assert_eq!(state.allocated_gpus(), 32 - 8 + 2 + 4);
    }

    #[test]
    fn shrink_falls_back_to_eviction_when_ladder_exhausted() {
        let cfg = QschConfig {
            enable_shrink: true,
            ..QschConfig::default()
        };
        let (mut q, mut store, mut state) = setup(cfg);
        q.submit(
            &mut store,
            job(1, 4, 2)
                .with_times(0, 1_000_000)
                .with_priority(Priority::LOW)
                .with_tidal()
                .with_shapes(ladder_2_to_1()),
        );
        q.submit(&mut store, job(2, 8, 1).with_times(0, 1_000_000));
        q.cycle(0, &mut store, &mut state, &mut FirstFit);
        // Fault pressure: the malleable job shrinks, keeping progress.
        assert!(q.shrink_or_evict_and_requeue(&mut store, &mut state, JobId(1), 1_000));
        assert_eq!(store.expect(JobId(1)).spec.total_gpus(), 4);
        assert_eq!(store.expect(JobId(1)).preemptions, 0);
        // Re-place at the smaller shape, then hit it again: the ladder is
        // exhausted, so this time it is a real eviction.
        q.cycle(2_000, &mut store, &mut state, &mut FirstFit);
        assert!(store.expect(JobId(1)).holds_resources());
        assert!(!q.shrink_or_evict_and_requeue(&mut store, &mut state, JobId(1), 3_000));
        assert_eq!(store.expect(JobId(1)).preemptions, 1);
        // Fixed-shape jobs always take the eviction path.
        assert!(!q.shrink_or_evict_and_requeue(&mut store, &mut state, JobId(2), 3_000));
        assert_eq!(store.expect(JobId(2)).preemptions, 1);
        assert_eq!(q.stats.shape_shrinks, 1);
    }

    #[test]
    fn static_quota_blocks_oversized_tenant() {
        let (mut q, mut store, mut state) = setup(QschConfig::default());
        q.ledger.set_limit(TenantId(0), G, 8);
        q.ledger.set_limit(TenantId(1), G, 0);
        q.submit(&mut store, job(1, 8, 2)); // Wants 16 > 8 available.
        let r = q.cycle(0, &mut store, &mut state, &mut FirstFit);
        assert!(r.scheduled.is_empty());
        assert_eq!(r.admission_failures.len(), 1);
        assert!(r.admission_failures[0].1.contains("static quota"));
    }
}
