//! Per-tenant job queues and the global candidate ordering (§3.2.2).
//!
//! Jobs enter their tenant's queue at submission; each scheduling cycle the
//! queues are merged into a globally ordered candidate list:
//! priority (desc) → submission time (asc) → job size (asc, tiebreak).

use std::collections::BTreeMap;

use crate::cluster::ids::{JobId, TenantId};
use crate::job::spec::Priority;

/// Ordering key captured at enqueue time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueEntry {
    pub job: JobId,
    pub tenant: TenantId,
    pub priority: Priority,
    pub submit_ms: u64,
    pub total_gpus: u32,
}

impl QueueEntry {
    /// The paper's ordering: priority desc, submit asc, size asc.
    fn key(&self) -> (std::cmp::Reverse<u8>, u64, u32, u64) {
        (
            std::cmp::Reverse(self.priority.0),
            self.submit_ms,
            self.total_gpus,
            self.job.0, // Final determinism tiebreak.
        )
    }
}

/// Per-tenant queues with a merged global view.
#[derive(Debug, Default)]
pub struct TenantQueues {
    queues: BTreeMap<TenantId, Vec<QueueEntry>>,
    len: usize,
}

impl TenantQueues {
    pub fn new() -> TenantQueues {
        TenantQueues::default()
    }

    pub fn push(&mut self, e: QueueEntry) {
        let q = self.queues.entry(e.tenant).or_default();
        debug_assert!(q.iter().all(|x| x.job != e.job), "job enqueued twice");
        q.push(e);
        q.sort_by_key(QueueEntry::key);
        self.len += 1;
    }

    /// Remove a job (on successful scheduling or cancellation).
    pub fn remove(&mut self, job: JobId) -> bool {
        for q in self.queues.values_mut() {
            if let Some(i) = q.iter().position(|e| e.job == job) {
                q.remove(i);
                self.len -= 1;
                return true;
            }
        }
        false
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn tenant_len(&self, t: TenantId) -> usize {
        self.queues.get(&t).map(Vec::len).unwrap_or(0)
    }

    pub fn contains(&self, job: JobId) -> bool {
        self.queues.values().any(|q| q.iter().any(|e| e.job == job))
    }

    /// The globally ordered candidate list for this cycle.
    pub fn global_order(&self) -> Vec<QueueEntry> {
        let mut all: Vec<QueueEntry> = self.queues.values().flatten().copied().collect();
        all.sort_by_key(QueueEntry::key);
        all
    }

    /// Head of the global order (the job Strict FIFO would insist on).
    pub fn global_head(&self) -> Option<QueueEntry> {
        self.queues
            .values()
            .filter_map(|q| q.first())
            .min_by_key(|e| e.key())
            .copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e(job: u64, tenant: u32, prio: u8, submit: u64, gpus: u32) -> QueueEntry {
        QueueEntry {
            job: JobId(job),
            tenant: TenantId(tenant),
            priority: Priority(prio),
            submit_ms: submit,
            total_gpus: gpus,
        }
    }

    #[test]
    fn global_order_priority_then_time_then_size() {
        let mut q = TenantQueues::new();
        q.push(e(1, 0, 4, 100, 8));
        q.push(e(2, 1, 8, 200, 64)); // Higher priority, later.
        q.push(e(3, 0, 4, 100, 2)); // Same prio/time as 1, smaller.
        q.push(e(4, 1, 4, 50, 512)); // Earliest normal.
        let order: Vec<u64> = q.global_order().iter().map(|x| x.job.0).collect();
        assert_eq!(order, vec![2, 4, 3, 1]);
        assert_eq!(q.global_head().unwrap().job, JobId(2));
    }

    #[test]
    fn remove_updates_len_and_head() {
        let mut q = TenantQueues::new();
        q.push(e(1, 0, 8, 10, 1));
        q.push(e(2, 0, 4, 20, 1));
        assert_eq!(q.len(), 2);
        assert!(q.remove(JobId(1)));
        assert!(!q.remove(JobId(1)));
        assert_eq!(q.len(), 1);
        assert_eq!(q.global_head().unwrap().job, JobId(2));
    }

    #[test]
    fn tenant_isolation_of_queues() {
        let mut q = TenantQueues::new();
        q.push(e(1, 0, 4, 10, 1));
        q.push(e(2, 1, 4, 20, 1));
        assert_eq!(q.tenant_len(TenantId(0)), 1);
        assert_eq!(q.tenant_len(TenantId(1)), 1);
        assert_eq!(q.tenant_len(TenantId(2)), 0);
    }

    #[test]
    fn empty_queue_has_no_head() {
        let q = TenantQueues::new();
        assert!(q.global_head().is_none());
        assert!(q.is_empty());
    }
}
