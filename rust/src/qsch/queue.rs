//! Per-tenant job queues and the global candidate ordering (§3.2.2).
//!
//! Jobs enter their tenant's queue at submission; each scheduling cycle the
//! queues are merged into a globally ordered candidate list:
//! priority (desc) → submission time (asc) → job size (asc, tiebreak).

use std::collections::BTreeMap;

use crate::cluster::ids::{JobId, TenantId};
use crate::job::spec::Priority;

/// Ordering key captured at enqueue time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueEntry {
    pub job: JobId,
    pub tenant: TenantId,
    pub priority: Priority,
    pub submit_ms: u64,
    pub total_gpus: u32,
}

impl QueueEntry {
    /// The paper's ordering: priority desc, submit asc, size asc.
    fn key(&self) -> (std::cmp::Reverse<u8>, u64, u32, u64) {
        (
            std::cmp::Reverse(self.priority.0),
            self.submit_ms,
            self.total_gpus,
            self.job.0, // Final determinism tiebreak.
        )
    }
}

/// Per-tenant queues with a merged global view.
#[derive(Debug, Default)]
pub struct TenantQueues {
    queues: BTreeMap<TenantId, Vec<QueueEntry>>,
    len: usize,
}

impl TenantQueues {
    pub fn new() -> TenantQueues {
        TenantQueues::default()
    }

    pub fn push(&mut self, e: QueueEntry) {
        let q = self.queues.entry(e.tenant).or_default();
        debug_assert!(q.iter().all(|x| x.job != e.job), "job enqueued twice");
        // Binary-search insertion into the already-sorted tenant queue:
        // O(log n + shift) instead of the O(n log n) full re-sort per
        // enqueue. Inserting after equal keys reproduces the stable-sort
        // order exactly (keys are strictly total anyway — the job id is
        // the final tiebreak).
        let pos = q.partition_point(|x| x.key() <= e.key());
        q.insert(pos, e);
        self.len += 1;
    }

    /// Remove a job (on successful scheduling or cancellation).
    pub fn remove(&mut self, job: JobId) -> bool {
        for q in self.queues.values_mut() {
            if let Some(i) = q.iter().position(|e| e.job == job) {
                q.remove(i);
                self.len -= 1;
                return true;
            }
        }
        false
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn tenant_len(&self, t: TenantId) -> usize {
        self.queues.get(&t).map(Vec::len).unwrap_or(0)
    }

    pub fn contains(&self, job: JobId) -> bool {
        self.queues.values().any(|q| q.iter().any(|e| e.job == job))
    }

    /// The globally ordered candidate list for this cycle: a k-way merge
    /// of the already-sorted per-tenant queues — O(n log k) per cycle
    /// instead of re-flattening and re-sorting everything (O(n log n)).
    /// Byte-identical to the flatten-and-sort order because the entry key
    /// is strictly total (job id tiebreak); property-tested below.
    pub fn global_order(&self) -> Vec<QueueEntry> {
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;
        let lists: Vec<&[QueueEntry]> = self.queues.values().map(Vec::as_slice).collect();
        let mut heap = BinaryHeap::with_capacity(lists.len());
        for (li, l) in lists.iter().enumerate() {
            if let Some(e) = l.first() {
                heap.push(Reverse((e.key(), li, 0usize)));
            }
        }
        let mut out = Vec::with_capacity(self.len);
        while let Some(Reverse((_, li, i))) = heap.pop() {
            out.push(lists[li][i]);
            if let Some(e) = lists[li].get(i + 1) {
                heap.push(Reverse((e.key(), li, i + 1)));
            }
        }
        out
    }

    /// Head of the global order (the job Strict FIFO would insist on).
    pub fn global_head(&self) -> Option<QueueEntry> {
        self.queues
            .values()
            .filter_map(|q| q.first())
            .min_by_key(|e| e.key())
            .copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e(job: u64, tenant: u32, prio: u8, submit: u64, gpus: u32) -> QueueEntry {
        QueueEntry {
            job: JobId(job),
            tenant: TenantId(tenant),
            priority: Priority(prio),
            submit_ms: submit,
            total_gpus: gpus,
        }
    }

    #[test]
    fn global_order_priority_then_time_then_size() {
        let mut q = TenantQueues::new();
        q.push(e(1, 0, 4, 100, 8));
        q.push(e(2, 1, 8, 200, 64)); // Higher priority, later.
        q.push(e(3, 0, 4, 100, 2)); // Same prio/time as 1, smaller.
        q.push(e(4, 1, 4, 50, 512)); // Earliest normal.
        let order: Vec<u64> = q.global_order().iter().map(|x| x.job.0).collect();
        assert_eq!(order, vec![2, 4, 3, 1]);
        assert_eq!(q.global_head().unwrap().job, JobId(2));
    }

    #[test]
    fn remove_updates_len_and_head() {
        let mut q = TenantQueues::new();
        q.push(e(1, 0, 8, 10, 1));
        q.push(e(2, 0, 4, 20, 1));
        assert_eq!(q.len(), 2);
        assert!(q.remove(JobId(1)));
        assert!(!q.remove(JobId(1)));
        assert_eq!(q.len(), 1);
        assert_eq!(q.global_head().unwrap().job, JobId(2));
    }

    #[test]
    fn tenant_isolation_of_queues() {
        let mut q = TenantQueues::new();
        q.push(e(1, 0, 4, 10, 1));
        q.push(e(2, 1, 4, 20, 1));
        assert_eq!(q.tenant_len(TenantId(0)), 1);
        assert_eq!(q.tenant_len(TenantId(1)), 1);
        assert_eq!(q.tenant_len(TenantId(2)), 0);
    }

    #[test]
    fn empty_queue_has_no_head() {
        let q = TenantQueues::new();
        assert!(q.global_head().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn merge_matches_flatten_and_sort_on_random_streams() {
        // The k-way merge and binary insertion must reproduce the legacy
        // flatten-and-sort global order exactly, under arbitrary
        // interleavings of pushes and removes.
        use crate::util::prop;
        use crate::util::rng::Pcg32;
        prop::check(40, |rng: &mut Pcg32| {
            let mut q = TenantQueues::new();
            let mut live: Vec<JobId> = Vec::new();
            let mut next = 1u64;
            for _ in 0..rng.range_inclusive(1, 120) {
                if live.is_empty() || rng.chance(0.7) {
                    let entry = e(
                        next,
                        rng.below(4) as u32,
                        *rng.choose(&[0u8, 4, 4, 8]).unwrap(),
                        rng.below(1_000), // Dense: plenty of key collisions.
                        rng.range_inclusive(1, 16) as u32,
                    );
                    q.push(entry);
                    live.push(JobId(next));
                    next += 1;
                } else {
                    let i = rng.below(live.len() as u64) as usize;
                    assert!(q.remove(live.swap_remove(i)));
                }
                // Oracle: flatten every tenant queue and stable-sort.
                let mut want: Vec<QueueEntry> =
                    q.queues.values().flatten().copied().collect();
                want.sort_by_key(QueueEntry::key);
                let got = q.global_order();
                crate::prop_assert!(got == want, "merge diverged from flatten+sort");
                crate::prop_assert!(
                    q.global_head() == want.first().copied(),
                    "head diverged"
                );
                // Per-tenant queues stay sorted under binary insertion.
                for tq in q.queues.values() {
                    crate::prop_assert!(
                        tq.windows(2).all(|w| w[0].key() <= w[1].key()),
                        "tenant queue unsorted"
                    );
                }
            }
            Ok(())
        });
    }
}
