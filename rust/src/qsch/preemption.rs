//! Preemption control (§3.2.3): victim selection and eviction for the three
//! mechanisms — backfill, priority, and quota-reclamation preemption.
//!
//! QSCH's policy is deliberately conservative: preemption only fires when a
//! complete victim set exists (partial eviction that still leaves the
//! beneficiary unschedulable would waste work), and victims are chosen to
//! minimize lost progress (lowest priority, most recently scheduled first).

use crate::cluster::ids::{GpuTypeId, JobId};
use crate::cluster::state::ClusterState;
use crate::cluster::tenant::QuotaLedger;
use crate::job::state::Job;
use crate::job::store::JobStore;

use super::admission::demand_by_type;

/// Which preemption mechanism fired (for stats/reporting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptKind {
    Backfill,
    Priority,
    QuotaReclaim,
    /// SLO-pressure reclamation: an elastic inference scale-up evicts
    /// tidally-backfilled training to win its capacity back. With
    /// `QschConfig::enable_shrink`, a moldable victim with a spare
    /// ladder rung shrinks instead of dying (`Qsch::shrink_victim`) —
    /// a shrink is a coordinated re-shard, not a preemption, so it is
    /// excluded from the SLO-pressure counters.
    SloPressure,
    /// Anti-starvation rescue: a class head whose rolling p99 wait broke
    /// its `max_jwtd_p99_ms` bound evicts backfilled peers (same victim
    /// rule as backfill preemption) without waiting out the backfill
    /// timeout.
    Starvation,
}

/// Select a minimal-cost victim set among resource-holding jobs matching
/// `eligible`, such that evicting them (plus current pool free space)
/// covers `need_by_type`. Returns `None` when no complete set exists —
/// the conservative policy then does nothing.
pub fn select_victims(
    state: &ClusterState,
    store: &JobStore,
    need_by_type: &[(GpuTypeId, u32)],
    eligible: impl Fn(&Job) -> bool,
) -> Option<Vec<JobId>> {
    // Outstanding need after counting currently-free pool capacity.
    let mut outstanding: Vec<(GpuTypeId, u32)> = need_by_type
        .iter()
        .map(|&(g, need)| (g, need.saturating_sub(state.pool_free_for_type(g))))
        .filter(|&(_, n)| n > 0)
        .collect();
    if outstanding.is_empty() {
        return Some(Vec::new()); // Resources already available.
    }

    // Candidates: eviction order = priority asc, scheduled_ms desc (newest
    // first — least progress lost), id for determinism.
    let mut candidates: Vec<&Job> = store
        .holding_resources()
        .filter(|j| eligible(j))
        .collect();
    candidates.sort_by(|a, b| {
        a.spec
            .priority
            .cmp(&b.spec.priority)
            .then(b.scheduled_ms.cmp(&a.scheduled_ms))
            .then(a.id().cmp(&b.id()))
    });

    let mut victims = Vec::new();
    for j in candidates {
        if outstanding.is_empty() {
            break;
        }
        // How much of the outstanding need would this victim free?
        let frees = demand_by_type(&j.spec);
        let helps = frees
            .iter()
            .any(|(g, _)| outstanding.iter().any(|(og, _)| og == g));
        if !helps {
            continue;
        }
        victims.push(j.id());
        for (g, freed) in frees {
            if let Some(slot) = outstanding.iter_mut().find(|(og, _)| *og == g) {
                slot.1 = slot.1.saturating_sub(freed);
            }
        }
        outstanding.retain(|&(_, n)| n > 0);
    }

    if outstanding.is_empty() {
        Some(victims)
    } else {
        None
    }
}

/// Defragmentation victims: when the pool nominally has enough free GPUs
/// (`select_victims` returns an empty set) but placement still fails, the
/// free capacity is *fragmented* across partially-used nodes. Evicting
/// eligible jobs that sit on fragmented nodes consolidates whole nodes for
/// the blocked head. Victims are accumulated until their holdings cover
/// the full demand (not merely the shortfall), since fragmented free space
/// can't be assumed usable.
pub fn select_defrag_victims(
    state: &ClusterState,
    store: &JobStore,
    need_by_type: &[(GpuTypeId, u32)],
    eligible: impl Fn(&Job) -> bool,
) -> Option<Vec<JobId>> {
    // Capacity already usable by whole-node pods: GPUs on fully-idle nodes.
    let whole_free = |g: GpuTypeId| -> u32 {
        state
            .nodes
            .iter()
            .filter(|n| {
                n.gpu_type == g
                    && n.health.schedulable()
                    && n.allocated_gpus() == 0
            })
            .map(|n| n.total_gpus())
            .sum()
    };
    let mut outstanding: Vec<(GpuTypeId, u32)> = need_by_type
        .iter()
        .map(|&(g, need)| (g, need.saturating_sub(whole_free(g))))
        .filter(|&(_, n)| n > 0)
        .collect();
    if outstanding.is_empty() {
        return None; // Whole nodes already cover the need; eviction won't help.
    }
    let mut candidates: Vec<&Job> = store
        .holding_resources()
        .filter(|j| eligible(j))
        .filter(|j| {
            state
                .nodes_of(j.id())
                .iter()
                .any(|&n| state.node(n).is_fragmented())
        })
        .collect();
    candidates.sort_by(|a, b| {
        a.spec
            .priority
            .cmp(&b.spec.priority)
            .then(b.scheduled_ms.cmp(&a.scheduled_ms))
            .then(a.id().cmp(&b.id()))
    });
    let mut victims = Vec::new();
    for j in candidates {
        if outstanding.is_empty() {
            break;
        }
        let frees = demand_by_type(&j.spec);
        if !frees
            .iter()
            .any(|(g, _)| outstanding.iter().any(|(og, _)| og == g))
        {
            continue;
        }
        victims.push(j.id());
        for (g, freed) in frees {
            if let Some(slot) = outstanding.iter_mut().find(|(og, _)| *og == g) {
                slot.1 = slot.1.saturating_sub(freed);
            }
        }
        outstanding.retain(|&(_, n)| n > 0);
    }
    (outstanding.is_empty() && !victims.is_empty()).then_some(victims)
}

/// Evict `victims`: release cluster resources, refund quota, and mark the
/// jobs preempted+requeued. The caller re-enqueues them.
pub fn evict(
    state: &mut ClusterState,
    store: &mut JobStore,
    ledger: &mut QuotaLedger,
    victims: &[JobId],
    now: u64,
) {
    for &v in victims {
        state
            .release_job(v)
            .expect("victim must hold resources");
        ledger.refund(v).expect("victim must be charged");
        let job = store.expect_mut(v);
        job.mark_preempted(now);
        job.mark_requeued();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::builder::{ClusterBuilder, ClusterSpec};
    use crate::cluster::ids::{NodeId, PodId, TenantId};
    use crate::cluster::state::PodPlacement;
    use crate::cluster::tenant::QuotaMode;
    use crate::job::spec::{JobKind, JobSpec, Priority};

    const G: GpuTypeId = GpuTypeId(0);

    fn setup() -> (ClusterState, JobStore, QuotaLedger) {
        // 2 groups x 2 nodes x 8 GPUs = 32 GPUs.
        let state = ClusterBuilder::build(&ClusterSpec::homogeneous("t", 1, 2, 2));
        let store = JobStore::new();
        let mut ledger = QuotaLedger::new(2, 1, QuotaMode::Shared);
        ledger.set_limit(TenantId(0), G, 16);
        ledger.set_limit(TenantId(1), G, 16);
        (state, store, ledger)
    }

    /// Place a 1-pod job of `gpus` on `node` and register everywhere.
    fn run_job(
        state: &mut ClusterState,
        store: &mut JobStore,
        ledger: &mut QuotaLedger,
        id: u64,
        tenant: u32,
        node: u32,
        gpus: u32,
        priority: Priority,
        now: u64,
        backfilled: bool,
    ) {
        let spec = JobSpec::homogeneous(
            JobId(id),
            TenantId(tenant),
            JobKind::Training,
            G,
            1,
            gpus,
        )
        .with_priority(priority);
        ledger
            .charge(JobId(id), TenantId(tenant), &demand_by_type(&spec))
            .unwrap();
        let free = state.node(NodeId(node)).free_gpu_indices();
        state
            .commit_placements(
                JobId(id),
                vec![PodPlacement {
                    pod: PodId::new(JobId(id), 0),
                    node: NodeId(node),
                    devices: free[..gpus as usize].to_vec(),
                    nic: 0,
                }],
            )
            .unwrap();
        let mut job = Job::new(spec);
        job.mark_admitted();
        job.mark_scheduled(now);
        job.mark_running(now);
        job.backfilled = backfilled;
        store.insert(job);
    }

    #[test]
    fn no_victims_needed_when_pool_has_room() {
        let (state, store, _) = setup();
        let v = select_victims(&state, &store, &[(G, 8)], |_| true).unwrap();
        assert!(v.is_empty());
    }

    #[test]
    fn picks_newest_lowest_priority_first() {
        let (mut state, mut store, mut ledger) = setup();
        // Fill all four nodes.
        run_job(&mut state, &mut store, &mut ledger, 1, 0, 0, 8, Priority::NORMAL, 10, false);
        run_job(&mut state, &mut store, &mut ledger, 2, 0, 1, 8, Priority::LOW, 20, false);
        run_job(&mut state, &mut store, &mut ledger, 3, 1, 2, 8, Priority::LOW, 30, false);
        run_job(&mut state, &mut store, &mut ledger, 4, 1, 3, 8, Priority::HIGH, 40, false);
        // Need 8 GPUs: expect the newest LOW job (3).
        let v = select_victims(&state, &store, &[(G, 8)], |_| true).unwrap();
        assert_eq!(v, vec![JobId(3)]);
        // Need 16: newest LOW (3) then older LOW (2).
        let v = select_victims(&state, &store, &[(G, 16)], |_| true).unwrap();
        assert_eq!(v, vec![JobId(3), JobId(2)]);
    }

    #[test]
    fn conservative_when_insufficient() {
        let (mut state, mut store, mut ledger) = setup();
        run_job(&mut state, &mut store, &mut ledger, 1, 0, 0, 8, Priority::NORMAL, 10, false);
        // Need 64 GPUs from a 32-GPU cluster: impossible even evicting all.
        assert!(select_victims(&state, &store, &[(G, 64)], |_| true).is_none());
    }

    #[test]
    fn eligibility_filter_respected() {
        let (mut state, mut store, mut ledger) = setup();
        for n in 0..4 {
            run_job(
                &mut state, &mut store, &mut ledger,
                n as u64 + 1, 0, n, 8, Priority::NORMAL, 10, n == 2,
            );
        }
        // Only backfilled jobs eligible → job 3 (on node 2).
        let v = select_victims(&state, &store, &[(G, 8)], |j| j.backfilled).unwrap();
        assert_eq!(v, vec![JobId(3)]);
        // Need 16 but only 8 backfilled → conservative None.
        assert!(select_victims(&state, &store, &[(G, 16)], |j| j.backfilled).is_none());
    }

    #[test]
    fn evict_releases_refunds_and_requeues() {
        let (mut state, mut store, mut ledger) = setup();
        run_job(&mut state, &mut store, &mut ledger, 1, 0, 0, 8, Priority::LOW, 10, true);
        assert_eq!(state.allocated_gpus(), 8);
        evict(&mut state, &mut store, &mut ledger, &[JobId(1)], 1_000);
        assert_eq!(state.allocated_gpus(), 0);
        assert_eq!(ledger.entry(TenantId(0), G).used_own, 0);
        let j = store.expect(JobId(1));
        assert_eq!(j.phase, crate::job::state::Phase::Queued);
        assert_eq!(j.preemptions, 1);
        assert_eq!(j.requeues, 1);
    }
}
