//! Queueing policies (§3.2.2, Table 1) and QSCH configuration.

use crate::job::spec::Priority;

/// Table 1's three queueing policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueuePolicy {
    /// Jobs scheduled strictly in arrival order; a blocked head blocks the
    /// whole queue. The "native scheduling system" baseline of §5.
    StrictFifo,
    /// Smaller jobs may bypass a blocked head; no preemption — risks
    /// starving large jobs (the Figure-4 pathology).
    BestEffortFifo,
    /// Bypass like Best-Effort, but once the head has waited past the
    /// threshold, backfilled jobs are preempted to make room for it.
    Backfill,
}

impl QueuePolicy {
    pub fn as_str(self) -> &'static str {
        match self {
            QueuePolicy::StrictFifo => "strict-fifo",
            QueuePolicy::BestEffortFifo => "best-effort-fifo",
            QueuePolicy::Backfill => "backfill",
        }
    }

    pub fn parse(s: &str) -> Option<QueuePolicy> {
        match s {
            "strict-fifo" | "fifo" => Some(QueuePolicy::StrictFifo),
            "best-effort-fifo" | "best-effort" => Some(QueuePolicy::BestEffortFifo),
            "backfill" => Some(QueuePolicy::Backfill),
            _ => None,
        }
    }

    /// May later jobs bypass a blocked head?
    pub fn allows_bypass(self) -> bool {
        !matches!(self, QueuePolicy::StrictFifo)
    }
}

/// QSCH tunables.
#[derive(Debug, Clone)]
pub struct QschConfig {
    pub policy: QueuePolicy,
    /// Backfill: head wait beyond this triggers preemption of backfilled
    /// jobs (§3.2.2/§3.2.3 backfill preemption).
    pub backfill_timeout_ms: u64,
    /// Priority preemption (§3.2.3): HIGH jobs may evict strictly
    /// lower-priority jobs after a conservative minimum wait.
    pub enable_priority_preemption: bool,
    pub priority_preempt_min_wait_ms: u64,
    /// Quota-reclamation preemption (§3.2.3): a lender may evict debtor
    /// jobs to reclaim loaned quota.
    pub enable_quota_reclaim: bool,
    /// SLO-pressure reclamation: when an elastic scale-up replica delta
    /// cannot place, evict tidally-backfilled training to make room —
    /// the reclamation half of tidal co-scheduling.
    pub enable_slo_reclaim: bool,
    /// Requeue priority aging (anti-starvation for repeatedly-evicted
    /// gangs): each preemption a job has suffered raises its *queue*
    /// priority by one step on requeue, capped here; 0 (the default)
    /// disables, keeping the pre-reliability queue order — the
    /// fault-tolerance arms and `kant simulate --faults` opt in. Aging
    /// affects ordering only — preemption rights still read the spec's
    /// base priority, so an aged LOW job cannot start evicting others.
    pub requeue_aging_cap: u8,
    /// Superspine-sharded placement prefetch: before walking the queue,
    /// QSCH hands the whole batch of queued candidates to the placer,
    /// which plans them concurrently across per-superspine shards on up
    /// to this many worker threads (`kant simulate --shards N`). The
    /// shard structure is fixed by the topology, so any value ≥ 1 yields
    /// byte-identical digests; 0 (the default) disables prefetch and
    /// keeps the legacy strictly-sequential plan-per-place path.
    pub batch_shards: usize,
    /// Hard per-class anti-starvation bound: `max_jwtd_p99_ms[c]` bounds
    /// the rolling p99 queue wait of base-priority class `c` (see
    /// [`Priority::class_index`]). When a class's p99 over the queued
    /// candidates exceeds its bound, the head of that class gains a
    /// starvation-preemption pass (evicting backfilled peers, like
    /// backfill preemption) and — if it still cannot place — a
    /// reserved-capacity hold that stops same-or-lower-class candidates
    /// from consuming freed capacity for the rest of the cycle. Static
    /// quota admission is never bypassed. 0 disables a class's bound
    /// (the default for every class).
    pub max_jwtd_p99_ms: [u64; Priority::NUM_CLASSES],
    /// Moldable gangs (`kant simulate --moldable`): before each cycle's
    /// placement walk, queued jobs that declare a shape ladder
    /// ([`crate::job::spec::JobSpec::shapes`]) are handed to the placer's
    /// shape-selection pass, which may re-shape them against the current
    /// fragmentation picture. Off (the default) no job is ever re-shaped
    /// and single-shape workloads replay byte-identically.
    pub enable_moldable: bool,
    /// Malleable shrink: SLO-pressure and fault victims that are moldable
    /// *and* tidal/LOW-class give up one shape rung (keeping their
    /// progress) instead of being evicted. Requires a remaining rung;
    /// ladder-exhausted jobs fall back to ordinary eviction.
    pub enable_shrink: bool,
}

impl Default for QschConfig {
    fn default() -> Self {
        QschConfig {
            policy: QueuePolicy::Backfill,
            backfill_timeout_ms: 30 * 60 * 1000, // 30 min.
            enable_priority_preemption: true,
            priority_preempt_min_wait_ms: 5 * 60 * 1000,
            enable_quota_reclaim: true,
            enable_slo_reclaim: true,
            requeue_aging_cap: 0,
            batch_shards: 0,
            max_jwtd_p99_ms: [0; Priority::NUM_CLASSES],
            enable_moldable: false,
            enable_shrink: false,
        }
    }
}

impl QschConfig {
    pub fn strict_fifo() -> QschConfig {
        QschConfig {
            policy: QueuePolicy::StrictFifo,
            enable_priority_preemption: false,
            enable_quota_reclaim: false,
            ..Default::default()
        }
    }

    pub fn best_effort() -> QschConfig {
        QschConfig {
            policy: QueuePolicy::BestEffortFifo,
            enable_priority_preemption: false,
            enable_quota_reclaim: false,
            ..Default::default()
        }
    }

    pub fn backfill(timeout_ms: u64) -> QschConfig {
        QschConfig {
            policy: QueuePolicy::Backfill,
            backfill_timeout_ms: timeout_ms,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for p in [
            QueuePolicy::StrictFifo,
            QueuePolicy::BestEffortFifo,
            QueuePolicy::Backfill,
        ] {
            assert_eq!(QueuePolicy::parse(p.as_str()), Some(p));
        }
        assert_eq!(QueuePolicy::parse("nope"), None);
    }

    #[test]
    fn bypass_semantics() {
        assert!(!QueuePolicy::StrictFifo.allows_bypass());
        assert!(QueuePolicy::BestEffortFifo.allows_bypass());
        assert!(QueuePolicy::Backfill.allows_bypass());
    }

    #[test]
    fn presets() {
        assert_eq!(QschConfig::strict_fifo().policy, QueuePolicy::StrictFifo);
        assert_eq!(QschConfig::backfill(1000).backfill_timeout_ms, 1000);
        assert!(!QschConfig::best_effort().enable_priority_preemption);
    }
}
