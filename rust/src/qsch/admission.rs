//! Two-tier admission control (§3.2.1): static quota admission against the
//! tenant ledger, then dynamic resource admission against live pool state
//! (with cross-pool joint admission for heterogeneous jobs).

use crate::cluster::ids::GpuTypeId;
use crate::cluster::state::ClusterState;
use crate::cluster::tenant::{QuotaError, QuotaLedger};
use crate::job::spec::JobSpec;

/// Why admission rejected a job.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmissionFailure {
    /// Static quota insufficient for some GPU type.
    Quota(QuotaError),
    /// Dynamic check: not enough free GPUs in the pool for `gpu_type`
    /// right now (`need` vs `free`).
    Resources {
        gpu_type: GpuTypeId,
        need: u32,
        free: u32,
    },
}

impl std::fmt::Display for AdmissionFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionFailure::Quota(e) => write!(f, "static quota: {e}"),
            AdmissionFailure::Resources { gpu_type, need, free } => {
                write!(f, "dynamic resources: type {gpu_type} need {need} free {free}")
            }
        }
    }
}

/// Aggregate a job's demand per GPU type (heterogeneous jobs may list
/// several demands with the same type).
pub fn demand_by_type(spec: &JobSpec) -> Vec<(GpuTypeId, u32)> {
    let mut out: Vec<(GpuTypeId, u32)> = Vec::new();
    for d in &spec.demands {
        match out.iter_mut().find(|(g, _)| *g == d.gpu_type) {
            Some((_, amt)) => *amt += d.total_gpus(),
            None => out.push((d.gpu_type, d.total_gpus())),
        }
    }
    out
}

/// Static quota admission: every typed demand must fit the tenant's
/// available quota (own + borrowable in Shared mode). Does not charge.
pub fn static_admission(ledger: &QuotaLedger, spec: &JobSpec) -> Result<(), AdmissionFailure> {
    for (g, amount) in demand_by_type(spec) {
        ledger
            .admit_check(spec.tenant, g, amount)
            .map_err(AdmissionFailure::Quota)?;
    }
    Ok(())
}

/// Dynamic resource admission: real-time free capacity in every matching
/// pool (cross-pool *joint* admission — all types must pass together).
/// A readiness check only; actual placement can still fail on
/// fragmentation/topology, which triggers requeueing (§3.2.4).
pub fn dynamic_admission(state: &ClusterState, spec: &JobSpec) -> Result<(), AdmissionFailure> {
    for (g, need) in demand_by_type(spec) {
        let free = state.pool_free_for_type(g);
        if free < need {
            return Err(AdmissionFailure::Resources {
                gpu_type: g,
                need,
                free,
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::builder::{ClusterBuilder, ClusterSpec};
    use crate::cluster::ids::{JobId, PodId, TenantId};
    use crate::cluster::state::PodPlacement;
    use crate::cluster::tenant::QuotaMode;
    use crate::job::spec::{JobKind, TypedDemand};

    fn ledger() -> QuotaLedger {
        let mut l = QuotaLedger::new(2, 2, QuotaMode::Isolated);
        l.set_limit(TenantId(0), GpuTypeId(0), 16);
        l.set_limit(TenantId(0), GpuTypeId(1), 4);
        l
    }

    fn train_job(gpus: u32) -> JobSpec {
        JobSpec::homogeneous(
            JobId(1),
            TenantId(0),
            JobKind::Training,
            GpuTypeId(0),
            (gpus / 8).max(1),
            8,
        )
    }

    #[test]
    fn static_admission_respects_quota() {
        let l = ledger();
        assert!(static_admission(&l, &train_job(16)).is_ok());
        assert!(matches!(
            static_admission(&l, &train_job(24)),
            Err(AdmissionFailure::Quota(_))
        ));
    }

    #[test]
    fn joint_admission_requires_all_types() {
        let l = ledger();
        let mut j = train_job(8);
        j.demands.push(TypedDemand {
            gpu_type: GpuTypeId(1),
            replicas: 1,
            gpus_per_pod: 8, // Over the type-1 quota of 4.
        });
        assert!(static_admission(&l, &j).is_err());
        j.demands[1].gpus_per_pod = 4;
        assert!(static_admission(&l, &j).is_ok());
    }

    #[test]
    fn demand_by_type_merges_same_type() {
        let mut j = train_job(8);
        j.demands.push(TypedDemand {
            gpu_type: GpuTypeId(0),
            replicas: 2,
            gpus_per_pod: 4,
        });
        assert_eq!(demand_by_type(&j), vec![(GpuTypeId(0), 16)]);
    }

    #[test]
    fn dynamic_admission_tracks_free_pool() {
        let mut s = ClusterBuilder::build(&ClusterSpec::homogeneous("t", 1, 1, 2)); // 16 GPUs.
        assert!(dynamic_admission(&s, &train_job(16)).is_ok());
        // Occupy one full node.
        s.commit_placements(
            JobId(50),
            vec![PodPlacement {
                pod: PodId::new(JobId(50), 0),
                node: crate::cluster::ids::NodeId(0),
                devices: (0..8).collect(),
                nic: 0,
            }],
        )
        .unwrap();
        let err = dynamic_admission(&s, &train_job(16)).unwrap_err();
        assert_eq!(
            err,
            AdmissionFailure::Resources {
                gpu_type: GpuTypeId(0),
                need: 16,
                free: 8
            }
        );
    }

    #[test]
    fn dynamic_admission_unknown_type_fails() {
        let s = ClusterBuilder::build(&ClusterSpec::homogeneous("t", 1, 1, 2));
        let mut j = train_job(8);
        j.demands[0].gpu_type = GpuTypeId(9);
        assert!(dynamic_admission(&s, &j).is_err());
    }
}
