//! Figures harness: regenerates every table and figure of the paper's §5
//! evaluation (see DESIGN.md §5 for the experiment index).
//!
//! Usage:
//!   figures [--scale small|paper|xlarge|xxlarge] [--seed N] [--out results/] <id>...
//!   ids: fig2 fig3 fig4 fig5 fig6 fig7 fig8 fig9 fig10 fig13 fig15
//!        table1 ablation-espread ablation-defrag ablation-index
//!        elastic-inference fault-tolerance topology-stress
//!        weight-adaptation moldable-gangs obs-phases all
//!   (fig10 covers 10-12; fig13 covers 13-14; snapshot/two-level ablations
//!    live in `cargo bench`.)

use std::path::PathBuf;

use kant::config::Scale;
use kant::experiments as exp;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut scale = Scale::Small;
    let mut seed = 42u64;
    let mut out_dir = PathBuf::from("results");
    let mut ids: Vec<String> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--scale" => {
                i += 1;
                scale = Scale::parse(&args[i]).ok_or_else(|| anyhow::anyhow!("bad scale"))?;
            }
            "--seed" => {
                i += 1;
                seed = args[i].parse()?;
            }
            "--out" => {
                i += 1;
                out_dir = PathBuf::from(&args[i]);
            }
            "-h" | "--help" => {
                println!("{}", HELP);
                return Ok(());
            }
            id => ids.push(id.to_string()),
        }
        i += 1;
    }
    if ids.is_empty() {
        println!("{}", HELP);
        return Ok(());
    }
    if ids.iter().any(|s| s == "all") {
        ids = vec![
            "fig2", "fig3", "fig4", "fig5", "table1", "fig6", "fig7", "fig8", "fig9",
            "fig10", "fig13", "fig15", "ablation-espread", "ablation-defrag",
            "ablation-index", "elastic-inference", "fault-tolerance", "topology-stress",
            "weight-adaptation", "moldable-gangs", "obs-phases",
        ]
        .into_iter()
        .map(String::from)
        .collect();
    }
    std::fs::create_dir_all(&out_dir)?;

    // Shared expensive runs, computed lazily.
    let mut policy_cmp: Option<exp::PolicyComparison> = None;
    let mut ebp_cmp: Option<exp::EBinpackComparison> = None;

    for id in &ids {
        eprintln!(">>> running {id} (scale={scale:?}, seed={seed})");
        let report = match id.as_str() {
            "fig2" => exp::fig2(seed),
            "fig3" | "fig4" | "fig5" | "table1" => {
                if policy_cmp.is_none() {
                    policy_cmp = Some(exp::run_policy_comparison(scale, seed));
                }
                let c = policy_cmp.as_ref().unwrap();
                match id.as_str() {
                    "fig3" => exp::fig3(c),
                    "fig4" => exp::fig4(c),
                    "fig5" => exp::fig5(c),
                    _ => exp::table1(c),
                }
            }
            "fig6" | "fig7" | "fig8" | "fig9" => {
                if ebp_cmp.is_none() {
                    ebp_cmp = Some(exp::run_ebinpack_comparison(scale, seed));
                }
                let c = ebp_cmp.as_ref().unwrap();
                match id.as_str() {
                    "fig6" => exp::fig6(c),
                    "fig7" => exp::fig7(c),
                    "fig8" => exp::fig8(c),
                    _ => exp::fig9(c),
                }
            }
            "fig10" | "fig11" | "fig12" => exp::fig10_11_12(seed),
            "fig13" | "fig14" => exp::fig13_14(seed),
            "fig15" => exp::fig15(seed),
            "ablation-espread" => exp::ablation_espread(seed),
            "ablation-defrag" => exp::ablation_defrag(seed),
            "ablation-index" => exp::ablation_candidate_index(scale, seed),
            "elastic-inference" => exp::elastic_inference(seed),
            "fault-tolerance" => exp::fault_tolerance(seed),
            "topology-stress" => exp::topology_stress(scale, seed),
            "weight-adaptation" => exp::weight_adaptation(seed),
            "moldable-gangs" => exp::moldable_gangs(seed),
            "obs-phases" => exp::obs_phases(scale, seed),
            other => {
                eprintln!("unknown figure id: {other}");
                continue;
            }
        };
        println!("{report}");
        let path = out_dir.join(format!("{id}.txt"));
        std::fs::write(&path, report)?;
        eprintln!("    wrote {}", path.display());
    }
    Ok(())
}

const HELP: &str = "\
figures — regenerate the paper's tables and figures
usage: figures [--scale small|paper|xlarge|xxlarge] [--seed N] [--out DIR] <id>... | all
ids: fig2 fig3 fig4 fig5 fig6 fig7 fig8 fig9 fig10 fig13 fig15 table1 \
ablation-espread ablation-defrag ablation-index elastic-inference fault-tolerance \
topology-stress weight-adaptation moldable-gangs obs-phases";
