//! The §5 experiment harness: one function per paper table/figure, shared
//! by the `figures` binary, the examples and the integration tests.
//! DESIGN.md §5 maps each id to the modules it exercises.

use crate::cluster::ids::GpuTypeId;
use crate::cluster::state::ClusterState;
use crate::config::{
    inference_cluster, training_cluster, Environment, InferencePreset, Scale, SimOptions,
};
use crate::job::spec::PlacementStrategy;
use crate::job::store::JobStore;
use crate::job::workload::{distribution_report, WorkloadGen};
use crate::metrics::report::{bucket_comparison, fmt_ms, pct, table};
use crate::qsch::policy::QschConfig;
use crate::qsch::{Placer, Qsch};
use crate::rsch::{Rsch, RschConfig, RschStats};
use crate::sim::{run, SimConfig, SimOutcome};
use crate::util::stats::{SizeBuckets, Summary};

/// One experiment arm: a queueing policy + placement configuration.
pub struct Arm {
    pub label: &'static str,
    pub qsch: QschConfig,
    pub rsch: RschConfig,
}

impl Arm {
    /// Build an arm straight from the unified [`SimOptions`] builder —
    /// the arm then runs exactly what `kant simulate` would run with the
    /// same options, so defaults cannot drift between entry points.
    pub fn from_options(label: &'static str, opts: SimOptions) -> Arm {
        let (qsch, rsch, _) = opts.configs().expect("arm options are statically valid");
        Arm { label, qsch, rsch }
    }

    /// The paper's "native scheduling system": Strict FIFO + spread-like
    /// (LeastAllocated) placement, flat scan, deep-copy snapshots. Kept
    /// on the explicit config presets: the baseline also disables
    /// priority preemption / quota reclaim and rescans per pod, knobs the
    /// builder deliberately does not expose.
    pub fn native_baseline() -> Arm {
        Arm {
            label: "native",
            qsch: QschConfig::strict_fifo(),
            rsch: RschConfig::native_baseline(),
        }
    }

    /// Kant with Backfill queueing (placement as configured by default).
    pub fn kant_backfill() -> Arm {
        Arm::from_options("backfill", SimOptions::for_scale(Scale::Small))
    }

    pub fn kant_strict() -> Arm {
        Arm {
            label: "strict-fifo",
            qsch: QschConfig::strict_fifo(),
            rsch: RschConfig::default(),
        }
    }

    pub fn kant_best_effort() -> Arm {
        Arm {
            label: "best-effort",
            qsch: QschConfig::best_effort(),
            rsch: RschConfig::default(),
        }
    }

    /// E-Binpack enabled (Kant full stack).
    pub fn kant_ebinpack() -> Arm {
        Arm::from_options("e-binpack", SimOptions::for_scale(Scale::Small))
    }
}

/// Run one arm over an environment's workload (deterministic per seed).
pub fn run_arm(env: &Environment, arm: &Arm, sim: &SimConfig) -> SimOutcome {
    let mut state = env.state.clone();
    let mut qsch = Qsch::new(arm.qsch.clone(), env.ledger.clone());
    let mut rsch = Rsch::new(arm.rsch.clone(), &state);
    let jobs = WorkloadGen::new(env.workload.clone()).generate_until(env.horizon_ms);
    let mut cfg = sim.clone();
    if cfg.horizon_ms == 0 {
        // Let in-flight jobs drain for a day past the arrival horizon.
        cfg.horizon_ms = env.horizon_ms + 24 * 3_600_000;
    }
    run(&mut state, &mut qsch, &mut rsch, jobs, &cfg)
}

/// JWTD including censored waits of never-scheduled jobs (starvation shows
/// up instead of disappearing — essential for the Best-Effort pathology).
pub fn jwtd_buckets(store: &JobStore, end_ms: u64) -> SizeBuckets {
    let mut b = SizeBuckets::paper_default();
    for j in store.iter() {
        b.record(j.spec.total_gpus(), j.waiting_ms(end_ms) as f64);
    }
    b
}

fn headline_rows(outs: &[(&str, &SimOutcome)]) -> Vec<Vec<String>> {
    outs.iter()
        .map(|(name, o)| {
            vec![
                name.to_string(),
                pct(o.metrics.gar_median(200)),
                pct(o.metrics.sor_final()),
                pct(o.metrics.gfr_avg()),
                o.metrics.jobs_scheduled.to_string(),
                o.metrics.jobs_finished.to_string(),
                o.unfinished_jobs.to_string(),
            ]
        })
        .collect()
}

fn headline_table(title: &str, outs: &[(&str, &SimOutcome)]) -> String {
    table(
        title,
        &["arm", "GAR", "SOR", "GFR", "sched", "done", "stuck"],
        &headline_rows(outs),
    )
}

// ---------------------------------------------------------------------
// Figure 2: job distribution by size (count share vs GPU-time share).
// ---------------------------------------------------------------------
pub fn fig2(seed: u64) -> String {
    let jobs = WorkloadGen::new(crate::job::workload::WorkloadConfig::paper_training(seed))
        .generate(20_000);
    let rows: Vec<Vec<String>> = distribution_report(&jobs)
        .into_iter()
        .map(|(size, count, time)| vec![size.to_string(), pct(count), pct(time)])
        .collect();
    let mut out = table(
        "Figure 2 — job distribution by percentage",
        &["GPUs", "job-count share", "GPU-time share"],
        &rows,
    );
    let small: f64 = distribution_report(&jobs)
        .iter()
        .filter(|(s, _, _)| *s <= 8)
        .map(|(_, c, _)| c)
        .sum();
    let big_time: f64 = distribution_report(&jobs)
        .iter()
        .filter(|(s, _, _)| *s >= 256)
        .map(|(_, _, t)| t)
        .sum();
    out.push_str(&format!(
        "\npaper claims: >90% of jobs ≤8 GPUs (measured {}), ≥256-GPU jobs >50% GPU-time (measured {})\n",
        pct(small),
        pct(big_time)
    ));
    out
}

// ---------------------------------------------------------------------
// Table 1 + Figures 3-5: queueing-policy comparison on the training
// cluster — Backfill vs Strict FIFO (vs Best-Effort for JWTD).
// ---------------------------------------------------------------------
pub struct PolicyComparison {
    pub strict: SimOutcome,
    pub backfill: SimOutcome,
    pub best_effort: SimOutcome,
}

pub fn run_policy_comparison(scale: Scale, seed: u64) -> PolicyComparison {
    let env = training_cluster(scale, seed, 0.98);
    let sim = SimConfig::default();
    PolicyComparison {
        strict: run_arm(&env, &Arm::kant_strict(), &sim),
        backfill: run_arm(&env, &Arm::kant_backfill(), &sim),
        best_effort: run_arm(&env, &Arm::kant_best_effort(), &sim),
    }
}

pub fn fig3(c: &PolicyComparison) -> String {
    let mut out = headline_table(
        "Figure 3 — GAR and SOR: Backfill vs Strict FIFO",
        &[
            ("strict-fifo", &c.strict),
            ("backfill", &c.backfill),
        ],
    );
    let sor_gain = c.backfill.metrics.sor_final() - c.strict.metrics.sor_final();
    let gar_gain = c.backfill.metrics.gar_median(200) - c.strict.metrics.gar_median(200);
    out.push_str(&format!(
        "\nSOR gain {} (paper: ≈ +3.6% median), GAR gain {} (paper: moderate improvement)\n",
        pct(sor_gain),
        pct(gar_gain)
    ));
    out
}

pub fn fig4(c: &PolicyComparison) -> String {
    let arms = vec![
        (
            "strict-fifo",
            jwtd_buckets(&c.strict.store, c.strict.end_ms).summaries(),
        ),
        (
            "backfill",
            jwtd_buckets(&c.backfill.store, c.backfill.end_ms).summaries(),
        ),
        (
            "best-effort",
            jwtd_buckets(&c.best_effort.store, c.best_effort.end_ms).summaries(),
        ),
    ];
    let mut out = bucket_comparison(
        "Figure 4 — JWTD (mean wait by job size): Backfill vs Strict vs Best-Effort",
        &arms
            .iter()
            .map(|(n, s)| (*n, s.clone()))
            .collect::<Vec<_>>(),
        fmt_ms,
    );
    out.push_str(
        "\npaper: Backfill ≈ Strict on waits; Best-Effort starves 1024/2048-GPU jobs\n",
    );
    out
}

pub fn fig5(c: &PolicyComparison) -> String {
    let mut out = table(
        "Figure 5 — GFR: Backfill vs Strict FIFO",
        &["arm", "GFR(avg)"],
        &[
            vec!["strict-fifo".into(), pct(c.strict.metrics.gfr_avg())],
            vec!["backfill".into(), pct(c.backfill.metrics.gfr_avg())],
        ],
    );
    out.push_str("\npaper: both <1% — Backfill has little effect on GFR\n");
    out
}

// ---------------------------------------------------------------------
// Figures 6-9: E-Binpack on/off vs the native baseline.
// ---------------------------------------------------------------------
pub struct EBinpackComparison {
    pub baseline: SimOutcome,
    pub ebinpack: SimOutcome,
}

pub fn run_ebinpack_comparison(scale: Scale, seed: u64) -> EBinpackComparison {
    let env = training_cluster(scale, seed, 0.96);
    let sim = SimConfig::default();
    EBinpackComparison {
        baseline: run_arm(&env, &Arm::native_baseline(), &sim),
        ebinpack: run_arm(&env, &Arm::kant_ebinpack(), &sim),
    }
}

pub fn fig6(c: &EBinpackComparison) -> String {
    let mut out = table(
        "Figure 6 — GFR with E-Binpack enabled vs disabled",
        &["arm", "GFR(avg)"],
        &[
            vec!["native (disabled)".into(), pct(c.baseline.metrics.gfr_avg())],
            vec!["e-binpack (enabled)".into(), pct(c.ebinpack.metrics.gfr_avg())],
        ],
    );
    out.push_str("\npaper: 8.5% average → below 1%\n");
    out
}

pub fn fig7(c: &EBinpackComparison) -> String {
    let mut out = headline_table(
        "Figure 7 — GAR and SOR with E-Binpack enabled vs disabled",
        &[
            ("native", &c.baseline),
            ("e-binpack", &c.ebinpack),
        ],
    );
    out.push_str(&format!(
        "\nGAR gain {} (paper ≈ +4.6%), SOR gain {} (paper ≈ +4.1%)\n",
        pct(c.ebinpack.metrics.gar_median(200) - c.baseline.metrics.gar_median(200)),
        pct(c.ebinpack.metrics.sor_final() - c.baseline.metrics.sor_final()),
    ));
    out
}

pub fn fig8(c: &EBinpackComparison) -> String {
    let arms = vec![
        (
            "native",
            jwtd_buckets(&c.baseline.store, c.baseline.end_ms).summaries(),
        ),
        (
            "e-binpack",
            jwtd_buckets(&c.ebinpack.store, c.ebinpack.end_ms).summaries(),
        ),
    ];
    let mut out = bucket_comparison(
        "Figure 8 — JWTD with E-Binpack enabled vs disabled",
        &arms
            .iter()
            .map(|(n, s)| (*n, s.clone()))
            .collect::<Vec<_>>(),
        fmt_ms,
    );
    out.push_str("\npaper: waits decrease across all job sizes\n");
    out
}

pub fn fig9(c: &EBinpackComparison) -> String {
    let arms_node = vec![
        ("native", c.baseline.metrics.jtted_node_summaries()),
        ("e-binpack", c.ebinpack.metrics.jtted_node_summaries()),
    ];
    let arms_group = vec![
        ("native", c.baseline.metrics.jtted_group_summaries()),
        ("e-binpack", c.ebinpack.metrics.jtted_group_summaries()),
    ];
    let arms_spine = vec![
        ("native", c.baseline.metrics.jtted_spine_summaries()),
        ("e-binpack", c.ebinpack.metrics.jtted_spine_summaries()),
    ];
    let arms_ss = vec![
        ("native", c.baseline.metrics.jtted_superspine_summaries()),
        ("e-binpack", c.ebinpack.metrics.jtted_superspine_summaries()),
    ];
    let mut out = bucket_comparison(
        "Figure 9a — JTTED NodeNum deviation ratio (actual/optimal nodes)",
        &arms_node,
        |x| format!("{x:.2}"),
    );
    out.push('\n');
    out.push_str(&bucket_comparison(
        "Figure 9b — JTTED NodeNetGroupNum deviation ratio (actual/optimal groups)",
        &arms_group,
        |x| format!("{x:.2}"),
    ));
    out.push('\n');
    out.push_str(&bucket_comparison(
        "Figure 9c — JTTED spine-span deviation ratio (actual/optimal spines)",
        &arms_spine,
        |x| format!("{x:.2}"),
    ));
    out.push('\n');
    out.push_str(&bucket_comparison(
        "Figure 9d — JTTED superspine-span deviation ratio (actual/optimal superspines)",
        &arms_ss,
        |x| format!("{x:.2}"),
    ));
    out.push_str("\npaper: deviation shrinks for all sizes except 2048-GPU jobs\n");
    out
}

// ---------------------------------------------------------------------
// Table 1: the three queueing policies side by side (mechanism summary
// backed by measured numbers).
// ---------------------------------------------------------------------
pub fn table1(c: &PolicyComparison) -> String {
    let big = |o: &SimOutcome| {
        let b = jwtd_buckets(&o.store, o.end_ms);
        let s = b.summaries();
        // Largest bucket with samples.
        s.iter()
            .rev()
            .find(|(_, sum)| sum.count > 0)
            .map(|(_, sum)| fmt_ms(sum.mean))
            .unwrap_or_else(|| "-".into())
    };
    let small = |o: &SimOutcome| {
        let b = jwtd_buckets(&o.store, o.end_ms);
        fmt_ms(b.summaries()[1].1.mean)
    };
    let rows = vec![
        vec![
            "strict-fifo".into(),
            pct(c.strict.metrics.sor_final()),
            small(&c.strict),
            big(&c.strict),
            c.strict.qsch_stats.scheduled_backfilled.to_string(),
            "0".into(),
        ],
        vec![
            "best-effort".into(),
            pct(c.best_effort.metrics.sor_final()),
            small(&c.best_effort),
            big(&c.best_effort),
            c.best_effort.qsch_stats.scheduled_backfilled.to_string(),
            "0".into(),
        ],
        vec![
            "backfill".into(),
            pct(c.backfill.metrics.sor_final()),
            small(&c.backfill),
            big(&c.backfill),
            c.backfill.qsch_stats.scheduled_backfilled.to_string(),
            c.backfill.qsch_stats.backfill_preemptions.to_string(),
        ],
    ];
    table(
        "Table 1 — queueing policies (measured)",
        &[
            "policy",
            "SOR",
            "small-job wait",
            "largest-job wait",
            "bypass-scheduled",
            "backfill-preempt",
        ],
        &rows,
    )
}

/// Peak concurrent GPU usage of one tenant on one GPU type over a run
/// (interval sweep over scheduled→released windows).
fn peak_concurrent_gpus(out: &SimOutcome, tenant: u32, gpu_type: GpuTypeId) -> u32 {
    let mut events: Vec<(u64, i64)> = Vec::new();
    for j in out.store.iter().filter(|j| j.spec.tenant.0 == tenant) {
        let Some(start) = j.scheduled_ms else { continue };
        let end = j.finished_ms.unwrap_or(out.end_ms);
        let gpus: i64 = j
            .spec
            .demands
            .iter()
            .filter(|d| d.gpu_type == gpu_type)
            .map(|d| d.total_gpus() as i64)
            .sum();
        if gpus > 0 {
            events.push((start, gpus));
            events.push((end, -gpus));
        }
    }
    events.sort_unstable();
    let (mut cur, mut peak) = (0i64, 0i64);
    for (_, d) in events {
        cur += d;
        peak = peak.max(cur);
    }
    peak.max(0) as u32
}

// ---------------------------------------------------------------------
// Figures 10-12: tenant quotas in the heterogeneous inference cluster.
// ---------------------------------------------------------------------
pub fn fig10_11_12(seed: u64) -> String {
    let env = inference_cluster(InferencePreset::I2, seed);
    let out = run_arm(&env, &Arm::kant_backfill(), &SimConfig::default());
    // Re-derive the final ledger state by replaying quota charges is
    // overkill: utilization at end-of-run is in the outcome's store —
    // instead report configured quota + peak concurrent usage per tenant.
    let mut rows_total: Vec<Vec<String>> = Vec::new();
    let num_types = env.state.gpu_types.len();
    let mut per_type_rows: Vec<Vec<Vec<String>>> = vec![Vec::new(); num_types];
    for (t, name) in (0..8).map(|t| (t, format!("tenant-{t}"))) {
        let mut total_quota = 0u32;
        let mut total_used = 0u32;
        for g in 0..num_types {
            let limit = env
                .ledger
                .entry(crate::cluster::ids::TenantId(t), GpuTypeId(g as u16))
                .limit;
            // Peak *concurrent* usage: sweep job (schedule, finish) intervals.
            let used: u32 = peak_concurrent_gpus(&out, t, GpuTypeId(g as u16));
            per_type_rows[g].push(vec![
                name.clone(),
                limit.to_string(),
                used.to_string(),
            ]);
            total_quota += limit;
            total_used += used;
        }
        rows_total.push(vec![
            name,
            total_quota.to_string(),
            total_used.to_string(),
            if total_quota > 0 {
                pct(total_used as f64 / total_quota as f64)
            } else {
                "-".into()
            },
        ]);
    }
    let mut s = table(
        "Figure 10 — GPU quota and quota utilization (per tenant)",
        &["tenant", "quota", "peak-job-GPUs", "utilization"],
        &rows_total,
    );
    for (g, rows) in per_type_rows.into_iter().enumerate() {
        let name = &env.state.gpu_types[g].name;
        s.push('\n');
        s.push_str(&table(
            &format!("Figure {} — {} GPU quota by tenant", 11 + g, name),
            &["tenant", "quota", "peak-job-GPUs"],
            &rows,
        ));
    }
    s.push_str(
        "\nnote: utilization >100% = borrowing under Shared quota mode (§3.2.1)\n",
    );
    s.push_str(&format!(
        "\nrun summary: GAR {} SOR {} GFR {}\n",
        pct(out.metrics.gar_avg()),
        pct(out.metrics.sor_final()),
        pct(out.metrics.gfr_avg())
    ));
    s
}

// ---------------------------------------------------------------------
// Figures 13-15: inference-cluster GAR/SOR/GFR time series and the
// GFR-vs-cluster-size comparison.
// ---------------------------------------------------------------------
pub fn fig13_14(seed: u64) -> String {
    let env = inference_cluster(InferencePreset::I2, seed);
    let out = run_arm(&env, &Arm::kant_backfill(), &SimConfig::default());
    let rows: Vec<Vec<String>> = out
        .metrics
        .series(24)
        .into_iter()
        .map(|(t, gar, sor, gfr)| {
            vec![
                format!("{:.1}d", t as f64 / 86_400_000.0),
                pct(gar),
                pct(sor),
                pct(gfr),
            ]
        })
        .collect();
    let mut s = table(
        "Figures 13/14 — cluster i2 time series (GAR, SOR, GFR)",
        &["t", "GAR", "SOR", "GFR"],
        &rows,
    );
    // Steady state: skip the warm-up ramp (first half of the window).
    let (a, b) = out.metrics.window();
    let mid = a + (b - a) / 2;
    s.push_str(&format!(
        "\nsteady-state (2nd half): GAR {} (paper ≈93%), GFR {} (paper ≈6.5%); SOR final {}\n",
        pct(out.metrics.gar_avg_between(mid, b)),
        pct(out.metrics.gfr_avg_between(mid, b)),
        pct(out.metrics.sor_final())
    ));
    s
}

pub fn fig15(seed: u64) -> String {
    // The paper's condition: "under the same task change frequency" —
    // the IDENTICAL workload stream hits all three clusters, so the
    // absolute number of fragmented nodes is comparable and the *ratio*
    // rises as the cluster shrinks.
    let a10 = inference_cluster(InferencePreset::A10, seed);
    let shared_workload = a10.workload.clone();
    let mut rows = Vec::new();
    // Kant's deployed inference config consolidates (E-Binpack fallback);
    // fragmented-node COUNT then tracks churn, so the RATIO rises as the
    // cluster shrinks.
    let mut arm = Arm::from_options("kant", SimOptions::for_scale(Scale::Small));
    arm.rsch.inference_strategy = PlacementStrategy::EBinpack;
    for preset in [InferencePreset::I7, InferencePreset::I2, InferencePreset::A10] {
        let mut env = inference_cluster(preset, seed);
        env.workload = shared_workload.clone();
        let out = run_arm(&env, &arm, &SimConfig::default());
        let (a, b) = out.metrics.window();
        let mid = a + (b - a) / 2;
        rows.push(vec![
            preset.label().to_string(),
            env.state.total_gpus().to_string(),
            env.state.nodes.len().to_string(),
            pct(out.metrics.gfr_avg_between(mid, b)),
        ]);
    }
    let mut s = table(
        "Figure 15 — GFR vs cluster size, identical churn (i7 > i2 > a10)",
        &["cluster", "GPUs", "nodes", "GFR(steady)"],
        &rows,
    );
    s.push_str("\npaper: smaller clusters show higher GFR under the same churn\n");
    s
}

// ---------------------------------------------------------------------
// Ablation: E-Spread's inference dedicated zone (§3.3.4). Mixed workload
// of many small HA inference replicas plus whole-node distributed
// inference jobs; plain Spread scatters the small replicas everywhere and
// starves the big jobs of whole nodes.
// ---------------------------------------------------------------------
pub fn ablation_espread(seed: u64) -> String {
    use crate::cluster::builder::{ClusterBuilder, ClusterSpec};
    use crate::cluster::ids::{JobId, TenantId};
    use crate::cluster::tenant::{QuotaLedger, QuotaMode};
    use crate::job::spec::{JobKind, JobSpec};
    use crate::util::rng::Pcg32;

    let run_with = |strategy: PlacementStrategy| -> (SimOutcome, u32) {
        let mut spec = ClusterSpec::homogeneous("espread", 2, 4, 4); // 32 nodes.
        spec.inference_zone_frac = 0.25;
        let mut state = ClusterBuilder::build(&spec);
        let mut ledger = QuotaLedger::new(2, 1, QuotaMode::Shared);
        ledger.set_limit(TenantId(0), GpuTypeId(0), state.total_gpus());
        ledger.set_limit(TenantId(1), GpuTypeId(0), 0);
        let mut qsch = Qsch::new(QschConfig::default(), ledger);
        let mut rsch = Rsch::new(RschConfig::default(), &state);

        let mut rng = Pcg32::seed_from_u64(seed);
        let mut jobs = Vec::new();
        let mut id = 1u64;
        // 60 small inference replicasets (1-2 GPU pods), staggered arrivals.
        for _ in 0..60 {
            let mut j = JobSpec::homogeneous(
                JobId(id),
                TenantId(0),
                JobKind::Inference,
                GpuTypeId(0),
                rng.range_inclusive(1, 3) as u32,
                rng.range_inclusive(1, 2) as u32,
            )
            .with_times(rng.below(3_600_000), 6 * 3_600_000)
            .with_strategy(strategy);
            j.gang = false;
            jobs.push(j);
            id += 1;
        }
        // 6 large distributed-inference jobs (4 whole nodes each) arriving
        // after the small ones have spread out.
        for k in 0..6u64 {
            let j = JobSpec::homogeneous(
                JobId(id),
                TenantId(0),
                JobKind::Inference,
                GpuTypeId(0),
                4,
                8,
            )
            .with_times(3_700_000 + k * 600_000, 4 * 3_600_000)
            .with_strategy(strategy)
            .with_gang(true);
            jobs.push(j);
            id += 1;
        }
        jobs.sort_by_key(|j| j.submit_ms);
        let out = run(
            &mut state,
            &mut qsch,
            &mut rsch,
            jobs,
            &SimConfig {
                horizon_ms: 24 * 3_600_000,
                ..SimConfig::default()
            },
        );
        let big_scheduled = out
            .store
            .iter()
            .filter(|j| j.spec.total_gpus() == 32 && j.scheduled_ms.is_some())
            .count() as u32;
        (out, big_scheduled)
    };

    let (spread_out, spread_big) = run_with(PlacementStrategy::Spread);
    let (espread_out, espread_big) = run_with(PlacementStrategy::ESpread);

    let big_wait = |o: &SimOutcome| -> String {
        let waits: Vec<f64> = o
            .store
            .iter()
            .filter(|j| j.spec.total_gpus() == 32)
            .map(|j| j.waiting_ms(o.end_ms) as f64)
            .collect();
        fmt_ms(Summary::from_samples(&waits).mean)
    };

    let rows = vec![
        vec![
            "spread".into(),
            format!("{spread_big}/6"),
            big_wait(&spread_out),
            pct(spread_out.metrics.gfr_avg()),
        ],
        vec![
            "e-spread".into(),
            format!("{espread_big}/6"),
            big_wait(&espread_out),
            pct(espread_out.metrics.gfr_avg()),
        ],
    ];
    let mut s = table(
        "Ablation — E-Spread dedicated zone vs plain Spread (§3.3.4)",
        &["strategy", "whole-node jobs scheduled", "mean big-job wait", "GFR"],
        &rows,
    );
    s.push_str(
        "\npaper: E-Spread preserves whole nodes for large distributed inference\n",
    );
    s
}

// ---------------------------------------------------------------------
// Ablation: sublinear candidate selection — the free-capacity node index
// vs the linear scan, at any scale up to `xlarge` (1,250 nodes / 10k
// GPUs). Warm the cluster first so the counters cover the loaded regime
// where per-cycle O(pool) work is the §3.4 bottleneck.
// ---------------------------------------------------------------------
/// Structured outcome of the candidate-index ablation — one labelled
/// [`RschStats`] per arm plus the placement-identity verdict. The
/// report string and the `kant harness` results JSON both render from
/// this.
pub struct AblationIndexResult {
    pub label: String,
    pub arms: Vec<(String, RschStats)>,
    /// Per-job placements byte-identical across indexed/linear arms.
    pub placements_identical: bool,
}

impl AblationIndexResult {
    /// Nodes examined per pod placed for arm `i`.
    pub fn examined_per_pod(&self, i: usize) -> f64 {
        let s = &self.arms[i].1;
        s.nodes_examined as f64 / s.pods_placed.max(1) as f64
    }
}

pub fn run_ablation_index(scale: Scale, seed: u64) -> AblationIndexResult {
    let env = training_cluster(scale, seed, 0.95);
    let jobs = WorkloadGen::new(env.workload.clone()).generate(300);
    let warm = jobs.len() * 2 / 3;
    let run_cfg = |indexed: bool, two_level: bool| -> (RschStats, ClusterState) {
        let mut state = env.state.clone();
        let cfg = RschConfig {
            indexed_candidates: indexed,
            two_level,
            ..RschConfig::default()
        };
        let mut rsch = Rsch::new(cfg, &state);
        for spec in &jobs[..warm] {
            let _ = rsch.place(&mut state, spec);
        }
        rsch.stats = RschStats::default(); // Count only the loaded regime.
        for spec in &jobs[warm..] {
            let _ = rsch.place(&mut state, spec);
        }
        (rsch.stats, state)
    };
    let arms = [
        ("flat + linear scan", false, false),
        ("flat + indexed", true, false),
        ("two-level + linear scan", false, true),
        ("two-level + indexed", true, true),
    ];
    let results: Vec<(&str, RschStats, ClusterState)> = arms
        .iter()
        .map(|&(label, indexed, two_level)| {
            let (stats, state) = run_cfg(indexed, two_level);
            (label, stats, state)
        })
        .collect();
    // Identity means per-job placements, not just allocation totals — a
    // node-choice divergence between the arms must show up here.
    let identical = |a: &ClusterState, b: &ClusterState| {
        jobs.iter().all(|j| a.placements_of(j.id) == b.placements_of(j.id))
    };
    AblationIndexResult {
        label: env.label.to_string(),
        placements_identical: identical(&results[0].2, &results[1].2)
            && identical(&results[2].2, &results[3].2),
        arms: results
            .into_iter()
            .map(|(label, stats, _)| (label.to_string(), stats))
            .collect(),
    }
}

pub fn ablation_candidate_index(scale: Scale, seed: u64) -> String {
    let r = run_ablation_index(scale, seed);
    let rows: Vec<Vec<String>> = r
        .arms
        .iter()
        .enumerate()
        .map(|(i, (label, s))| {
            vec![
                label.clone(),
                s.nodes_examined.to_string(),
                s.pods_placed.to_string(),
                format!("{:.1}", r.examined_per_pod(i)),
            ]
        })
        .collect();
    let mut out = table(
        &format!(
            "Ablation — candidate selection: free-capacity index vs linear scan ({})",
            r.label
        ),
        &["arm", "nodes examined", "pods placed", "examined/pod"],
        &rows,
    );
    out.push_str(&format!(
        "\nflat-scan reduction: {:.1}x fewer nodes examined per pod; \
         placements identical: {}\n",
        r.examined_per_pod(0) / r.examined_per_pod(1).max(1e-9),
        r.placements_identical,
    ));
    out
}

// ---------------------------------------------------------------------
// Elastic inference co-scheduling: the same diurnal service set run as
// (a) static fixed-size services provisioned at the curve's mean,
// (b) elastic autoscaling, (c) elastic + tidal training backfill.
// The unified-scheduling claim the paper sells: (c) should beat (a) on
// GAR at an equal-or-lower SLO violation rate.
// ---------------------------------------------------------------------
pub struct ElasticComparison {
    pub static_arm: SimOutcome,
    pub elastic: SimOutcome,
    pub tidal: SimOutcome,
}

/// Run the three arms over `days` simulated days (deterministic per
/// seed): 32 nodes / 256 GPUs, 12 diurnal inference services (8–16
/// replica peaks, aligned tide with seeded phase jitter), and — in the
/// tidal arm — a stream of LOW-priority 16-GPU tidal training gangs.
pub fn run_elastic_inference(seed: u64, days: f64) -> ElasticComparison {
    use crate::cluster::builder::{ClusterBuilder, ClusterSpec};
    use crate::cluster::ids::{JobId, TenantId};
    use crate::cluster::tenant::{QuotaLedger, QuotaMode};
    use crate::job::spec::{ElasticService, JobKind, JobSpec};
    use crate::job::workload::tidal_training_stream;
    use crate::sim::elastic::ElasticConfig;
    use crate::sim::run;
    use crate::util::rng::Pcg32;

    let horizon = (days * 24.0 * 3_600_000.0) as u64;
    let day = ElasticService::DAY_MS;

    // The diurnal service set — identical curves in every arm; only the
    // provisioning differs (mean-sized fixed vs floor-sized elastic).
    let services = |static_provisioning: bool| -> Vec<JobSpec> {
        let mut rng = Pcg32::seed_from_u64(seed ^ 0xe1a5);
        (0..12u64)
            .map(|k| {
                let max = 8 + (k % 3) as u32 * 4; // Peaks of 8 / 12 / 16.
                let min = (max / 4).max(1);
                let curve = ElasticService {
                    min_replicas: min,
                    max_replicas: max,
                    phase_ms: rng.below(4 * 3_600_000), // Aligned tide ±4 h.
                    amplitude: rng.uniform(0.8, 1.0),
                    period_ms: day,
                };
                let submit = rng.below(30 * 60_000);
                let mut j = JobSpec::homogeneous(
                    JobId(k + 1),
                    TenantId(0),
                    JobKind::Inference,
                    GpuTypeId(0),
                    max,
                    1,
                )
                .with_times(submit, horizon.saturating_sub(submit))
                .with_elastic(curve);
                if static_provisioning {
                    // Fixed-size arm: provisioned at the curve's mean
                    // demand forever; the controller only observes SLO.
                    let mid = min + (max - min) / 2;
                    for d in &mut j.demands {
                        d.replicas = mid;
                    }
                }
                j
            })
            .collect()
    };

    let sim = |elastic_cfg: ElasticConfig, jobs: Vec<JobSpec>| -> SimOutcome {
        let mut spec = ClusterSpec::homogeneous("elastic", 2, 4, 4); // 32 nodes.
        spec.inference_zone_frac = 0.25;
        let mut state = ClusterBuilder::build(&spec);
        let mut ledger = QuotaLedger::new(2, 1, QuotaMode::Shared);
        ledger.set_limit(TenantId(0), GpuTypeId(0), state.total_gpus());
        ledger.set_limit(TenantId(1), GpuTypeId(0), state.total_gpus());
        let mut qsch = Qsch::new(QschConfig::default(), ledger);
        let mut rsch = Rsch::new(RschConfig::default(), &state);
        let cfg = SimConfig {
            horizon_ms: horizon + 12 * 3_600_000, // Drain window.
            elastic: elastic_cfg,
            ..SimConfig::default()
        };
        run(&mut state, &mut qsch, &mut rsch, jobs, &cfg)
    };

    let static_arm = sim(ElasticConfig::observe_only(), services(true));
    let elastic = sim(ElasticConfig::enabled(), services(false));
    // Tidal arm: same elastic services + the backfill training stream
    // (ids far above the services so controller child ids never clash).
    let mut jobs = services(false);
    // Oversubscribed on purpose (~192 offered GPUs vs ~160 free on
    // average): a standing backlog keeps the night tide fully harvested
    // and forces morning scale-ups to reclaim, not just reuse slack.
    jobs.extend(tidal_training_stream(
        seed,
        1_000,
        TenantId(1),
        GpuTypeId(0),
        (days * 48.0).max(1.0) as usize,
        2,
        8,
        horizon.saturating_sub(3 * 3_600_000).max(1),
        6 * 3_600_000,
    ));
    jobs.sort_by_key(|j| j.submit_ms);
    let tidal = sim(ElasticConfig::enabled(), jobs);
    ElasticComparison {
        static_arm,
        elastic,
        tidal,
    }
}

/// The `figures elastic-inference` report.
pub fn elastic_inference(seed: u64) -> String {
    let c = run_elastic_inference(seed, 4.0);
    let row = |name: &str, o: &SimOutcome| -> Vec<String> {
        let (a, b) = o.metrics.window();
        vec![
            name.to_string(),
            pct(o.metrics.gar_avg()),
            pct(o.metrics.sor_final()),
            pct(o.metrics.elastic.slo_violation_rate()),
            o.metrics.elastic.replica_churn().to_string(),
            pct(o.metrics.elastic.elastic_utilization(a, b)),
            format!("{:.0}", o.metrics.elastic.tidal_gpu_hours(a, b)),
            o.qsch_stats.slo_pressure_preemptions.to_string(),
            format!(
                "{}/{}/{}",
                o.metrics.jobs_finished, o.metrics.jobs_cancelled, o.metrics.jobs_submitted
            ),
        ]
    };
    let rows = vec![
        row("static", &c.static_arm),
        row("elastic", &c.elastic),
        row("elastic+tidal", &c.tidal),
    ];
    let mut s = table(
        "Elastic inference co-scheduling — static vs elastic vs elastic+tidal",
        &[
            "arm",
            "GAR",
            "SOR",
            "SLO-viol",
            "churn",
            "elastic-util",
            "tidal-GPU-h",
            "slo-preempt",
            "done/cancelled/sub",
        ],
        &rows,
    );
    s.push_str(&format!(
        "\nelastic+tidal vs static: GAR {:+.2}% at SLO violation {:+.2}%\n\
         (diurnal autoscaling frees the night tide; tidal training backfills it; \
         SLO-pressure reclamation hands it back each morning)\n",
        (c.tidal.metrics.gar_avg() - c.static_arm.metrics.gar_avg()) * 100.0,
        (c.tidal.metrics.elastic.slo_violation_rate()
            - c.static_arm.metrics.elastic.slo_violation_rate())
            * 100.0,
    ));
    s
}

// ---------------------------------------------------------------------
// Reliability: stochastic fault injection — checkpoint/restart goodput
// and drain-aware scheduling. Every faulted arm replays the SAME seeded
// fault trace (the trace is a pure function of the fault seed + cluster
// shape + horizon); the arms differ only in checkpoint policy, requeue
// priority aging, and hot spares.
// ---------------------------------------------------------------------
pub struct FaultToleranceComparison {
    /// Fault-free baseline (the goodput ceiling).
    pub no_faults: SimOutcome,
    /// Faults + naive restarts: no checkpoints (evictions restart jobs
    /// from scratch), no requeue priority aging.
    pub naive: SimOutcome,
    /// Faults + interval checkpointing + requeue aging, per checkpoint
    /// interval (ms) — the sweep axis.
    pub checkpointed: Vec<(u64, SimOutcome)>,
    /// Best-practice arm: shortest checkpoint interval + aging + two hot
    /// spare nodes covering node faults.
    pub hardened: SimOutcome,
}

/// Checkpoint intervals the sweep covers (15 min / 1 h / 4 h).
pub const FAULT_CKPT_INTERVALS_MS: [u64; 3] = [900_000, 3_600_000, 14_400_000];

/// Requeue priority aging used by the resilient arms (and `--faults`).
pub const FAULT_REQUEUE_AGING_CAP: u8 = 4;

/// Run the reliability comparison over `days` simulated days
/// (deterministic per seed): 32 nodes / 256 GPUs with 2-node HBDs, a
/// stream of 1–3-node training gangs at ~0.85 offered load, and a storm
/// of node / GPU / HBD faults plus maintenance drains.
pub fn run_fault_tolerance(seed: u64, days: f64) -> FaultToleranceComparison {
    use crate::cluster::builder::{ClusterBuilder, ClusterSpec};
    use crate::cluster::ids::{JobId, TenantId};
    use crate::cluster::tenant::{QuotaLedger, QuotaMode};
    use crate::job::spec::{CheckpointPolicy, JobKind, JobSpec};
    use crate::sim::faults::FaultConfig;
    use crate::util::rng::Pcg32;

    let arrival_horizon = (days * 24.0 * 3_600_000.0) as u64;
    let horizon = arrival_horizon + 6 * 3_600_000; // Tight drain window.

    // ~0.85 offered load: mean job = 2 nodes x 8 GPUs for 5.5 h = 88
    // GPU-h against 256 x 24 = 6144 GPU-h/day.
    let workload = |ckpt: CheckpointPolicy| -> Vec<JobSpec> {
        let mut rng = Pcg32::seed_from_u64(seed ^ 0x0b5e_c0de);
        let n = ((days * 60.0) as u64).max(8);
        let mut jobs: Vec<JobSpec> = (1..=n)
            .map(|k| {
                let replicas = rng.range_inclusive(1, 3) as u32;
                let duration = rng.range_inclusive(3 * 3_600_000, 8 * 3_600_000);
                let submit = rng.below(arrival_horizon.max(1));
                let mut j = JobSpec::homogeneous(
                    JobId(k),
                    TenantId(0),
                    JobKind::Training,
                    GpuTypeId(0),
                    replicas,
                    8,
                )
                .with_times(submit, duration)
                .with_checkpoint(ckpt);
                // A quarter of the 1–2-node gangs pin an HBD (2-node
                // scale-up domains) — the correlated-failure exposure.
                if replicas <= 2 && rng.chance(0.25) {
                    j.needs_hbd = true;
                }
                j
            })
            .collect();
        jobs.sort_by_key(|j| j.submit_ms);
        jobs
    };

    let faults = FaultConfig::storm(seed ^ 0x5eed);
    let run_arm = |ckpt: CheckpointPolicy, aging: u8, fc: FaultConfig| -> SimOutcome {
        let mut spec = ClusterSpec::homogeneous("faulty", 2, 4, 4); // 32 nodes.
        spec.hbd_size = 2;
        let mut state = ClusterBuilder::build(&spec);
        let mut ledger = QuotaLedger::new(2, 1, QuotaMode::Shared);
        ledger.set_limit(TenantId(0), GpuTypeId(0), state.total_gpus());
        ledger.set_limit(TenantId(1), GpuTypeId(0), 0);
        let qcfg = QschConfig {
            requeue_aging_cap: aging,
            ..QschConfig::default()
        };
        let mut qsch = Qsch::new(qcfg, ledger);
        let mut rsch = Rsch::new(RschConfig::default(), &state);
        let cfg = SimConfig {
            horizon_ms: horizon,
            // Drain-aware reorganization every 30 simulated minutes.
            defrag_interval_ms: 30 * 60_000,
            faults: fc,
            ..SimConfig::default()
        };
        run(&mut state, &mut qsch, &mut rsch, workload(ckpt), &cfg)
    };

    let no_faults = run_arm(
        CheckpointPolicy::Continuous,
        FAULT_REQUEUE_AGING_CAP,
        FaultConfig::default(),
    );
    let naive = run_arm(CheckpointPolicy::None, 0, faults.clone());
    let checkpointed: Vec<(u64, SimOutcome)> = FAULT_CKPT_INTERVALS_MS
        .iter()
        .map(|&i| {
            (
                i,
                run_arm(
                    CheckpointPolicy::Interval(i),
                    FAULT_REQUEUE_AGING_CAP,
                    faults.clone(),
                ),
            )
        })
        .collect();
    let hardened = run_arm(
        CheckpointPolicy::Interval(FAULT_CKPT_INTERVALS_MS[0]),
        FAULT_REQUEUE_AGING_CAP,
        FaultConfig::storm_with_spares(seed ^ 0x5eed, 2),
    );
    FaultToleranceComparison {
        no_faults,
        naive,
        checkpointed,
        hardened,
    }
}

/// The `figures fault-tolerance` report.
pub fn fault_tolerance(seed: u64) -> String {
    let c = run_fault_tolerance(seed, 2.0);
    let row = |name: String, o: &SimOutcome| -> Vec<String> {
        let r = &o.metrics.reliability;
        vec![
            name,
            format!("{:.0}", r.goodput_gpu_hours()),
            pct(o.metrics.effective_gar()),
            pct(o.metrics.goodput_fraction()),
            format!("{:.0}", r.lost_gpu_hours()),
            r.fault_evictions.to_string(),
            format!("{:.2}", r.inflation_summary().p99),
            format!("{}/{}", o.metrics.jobs_finished, o.unfinished_jobs),
        ]
    };
    let mut rows = vec![
        row("no faults".into(), &c.no_faults),
        row("naive restart".into(), &c.naive),
    ];
    for (i, o) in &c.checkpointed {
        rows.push(row(format!("ckpt {}m + aging", i / 60_000), o));
    }
    rows.push(row("ckpt 15m + aging + spares".into(), &c.hardened));
    let mut s = table(
        "Fault tolerance — checkpoint/restart goodput under the same seeded fault storm",
        &[
            "arm",
            "goodput GPU-h",
            "eff-GAR",
            "goodput-frac",
            "lost GPU-h",
            "evictions",
            "inflation p99",
            "done/stuck",
        ],
        &rows,
    );
    s.push_str(
        "\ncheckpointing bounds redone work to one interval per eviction; requeue\n\
         aging keeps repeatedly-hit gangs from starving; spares hold capacity\n\
         steady through node repairs. Inflation = bind-to-finish time over the\n\
         fault-free ideal (1.0 = never hit).\n",
    );
    s
}

// ---------------------------------------------------------------------
// Topology stress: truthful cross-superspine tiers vs the blind baseline
// (the pre-fix scorer that collapsed every tier beyond same-spine into
// SameSuperSpine). A multi-superspine cluster takes an oversubscribed
// stream of whole-node gangs that each exceed one LeafGroup, so every
// gang must pick which groups to span — exactly the choice the blind
// scorer got wrong at zero cost. Same seed, same jobs; the arms differ
// only in `RschConfig::topo_blind`.
// ---------------------------------------------------------------------
pub struct TopologyStressComparison {
    /// The pre-fix baseline: cross-superspine crossings score like
    /// staying put.
    pub blind: SimOutcome,
    /// The truthful 5-tier scorer (the default config).
    pub truthful: SimOutcome,
}

/// Sample-weighted mean superspine-span deviation over the large-job
/// buckets (≥ 65 GPUs) — small jobs never span and would dilute the
/// signal.
pub fn large_gang_superspine_dev(out: &SimOutcome) -> f64 {
    let s = out.metrics.jtted_superspine_summaries();
    crate::metrics::Metrics::weighted_mean(&s[3..])
}

/// Same, for the spine-span deviation ratio.
pub fn large_gang_spine_dev(out: &SimOutcome) -> f64 {
    let s = out.metrics.jtted_spine_summaries();
    crate::metrics::Metrics::weighted_mean(&s[3..])
}

pub fn run_topology_stress(scale: Scale, seed: u64) -> TopologyStressComparison {
    use crate::cluster::builder::{ClusterBuilder, ClusterSpec};
    use crate::cluster::ids::{JobId, TenantId};
    use crate::cluster::tenant::{QuotaLedger, QuotaMode};
    use crate::job::spec::{JobKind, JobSpec};
    use crate::util::rng::Pcg32;

    // Every preset spans multiple superspines (a single-superspine fabric
    // cannot exhibit the bug).
    let spec = match scale {
        Scale::Paper => ClusterSpec::train8000(), // 2 superspines.
        Scale::XLarge => ClusterSpec::train10000(), // 3 superspines.
        Scale::Small => {
            // 96 nodes / 768 GPUs: 6 spines × 2 groups × 8 nodes under
            // 3 superspines of 2 spines each.
            let mut s = ClusterSpec::homogeneous("topo-stress", 6, 2, 8);
            s.spines_per_superspine = 2;
            s
        }
    };
    let npg = spec.nodes_per_group;
    let groups = spec.total_groups();

    // Oversubscribed stream: every large gang needs > 1 LeafGroup of
    // whole nodes (so it must choose what to span), and the offered load
    // exceeds capacity so gangs keep placing into a churning, unevenly
    // loaded fabric rather than a pristine one.
    let arrival_ms: u64 = 8 * 3_600_000;
    let mut rng = Pcg32::seed_from_u64(seed ^ 0x7090_57e5);
    let n_large = (groups as u64 * 3) / 2;
    let n_small = n_large * 2;
    let mut jobs: Vec<JobSpec> = Vec::new();
    let mut id = 1u64;
    for _ in 0..n_large {
        let replicas = rng.range_inclusive(npg as u64 * 5 / 4, npg as u64 * 5 / 2) as u32;
        let j = JobSpec::homogeneous(
            JobId(id),
            TenantId(0),
            JobKind::Training,
            GpuTypeId(0),
            replicas,
            8,
        )
        .with_times(
            rng.below(arrival_ms),
            rng.range_inclusive(2 * 3_600_000, 5 * 3_600_000),
        );
        jobs.push(j);
        id += 1;
    }
    for _ in 0..n_small {
        let mut j = JobSpec::homogeneous(
            JobId(id),
            TenantId(0),
            JobKind::Training,
            GpuTypeId(0),
            1,
            rng.range_inclusive(2, 8) as u32,
        )
        .with_times(
            rng.below(arrival_ms),
            rng.range_inclusive(3_600_000, 3 * 3_600_000),
        );
        j.gang = false;
        jobs.push(j);
        id += 1;
    }
    jobs.sort_by_key(|j| j.submit_ms);

    let run_one = |topo_blind: bool| -> SimOutcome {
        let mut state = ClusterBuilder::build(&spec);
        let mut ledger = QuotaLedger::new(1, 1, QuotaMode::Shared);
        ledger.set_limit(TenantId(0), GpuTypeId(0), state.total_gpus());
        let mut qsch = Qsch::new(QschConfig::default(), ledger);
        let rcfg = RschConfig {
            topo_blind,
            ..RschConfig::default()
        };
        let mut rsch = Rsch::new(rcfg, &state);
        let cfg = SimConfig {
            horizon_ms: arrival_ms + 22 * 3_600_000, // Drain window.
            ..SimConfig::default()
        };
        run(&mut state, &mut qsch, &mut rsch, jobs.clone(), &cfg)
    };

    TopologyStressComparison {
        blind: run_one(true),
        truthful: run_one(false),
    }
}

/// The `figures topology-stress` report.
pub fn topology_stress(scale: Scale, seed: u64) -> String {
    let c = run_topology_stress(scale, seed);
    let row = |name: &str, o: &SimOutcome| -> Vec<String> {
        vec![
            name.to_string(),
            pct(o.metrics.gar_avg()),
            pct(o.metrics.sor_final()),
            pct(o.metrics.gfr_avg()),
            format!("{:.3}", large_gang_spine_dev(o)),
            format!("{:.3}", large_gang_superspine_dev(o)),
            o.rsch_stats.nodes_scored.to_string(),
            format!("{}/{}", o.metrics.jobs_finished, o.unfinished_jobs),
        ]
    };
    let rows = vec![row("blind (pre-fix)", &c.blind), row("truthful", &c.truthful)];
    let mut s = table(
        "Topology stress — truthful cross-superspine tiers vs the blind baseline",
        &[
            "arm",
            "GAR",
            "SOR",
            "GFR",
            "spine-dev",
            "superspine-dev",
            "rows-scored",
            "done/stuck",
        ],
        &rows,
    );
    s.push_str(&format!(
        "\nsuperspine-span deviation (large gangs): blind {:.3} -> truthful {:.3}; \
         GAR delta {:+.2}%\n(the truthful scorer keeps gangs inside one superspine \
         wherever capacity allows, at no allocation cost)\n",
        large_gang_superspine_dev(&c.blind),
        large_gang_superspine_dev(&c.truthful),
        (c.truthful.metrics.gar_avg() - c.blind.metrics.gar_avg()) * 100.0,
    ));
    s
}

// ---------------------------------------------------------------------
// Ablation: periodic fragmentation reorganization (§3.3.3, the paper's
// planned extension) — defrag on/off under a churning small-job workload.
// ---------------------------------------------------------------------
pub fn ablation_defrag(seed: u64) -> String {
    let env = inference_cluster(InferencePreset::I2, seed);
    let arm = Arm::from_options("kant", SimOptions::for_scale(Scale::Small));
    let base = SimConfig::default();
    let off = run_arm(&env, &arm, &base);
    let on_cfg = SimConfig {
        defrag_interval_ms: 30 * 60_000, // Every 30 simulated minutes.
        ..base
    };
    let on = run_arm(&env, &arm, &on_cfg);
    let steady = |o: &SimOutcome| {
        let (a, b) = o.metrics.window();
        o.metrics.gfr_avg_between(a + (b - a) / 2, b)
    };
    let rows = vec![
        vec![
            "defrag off".into(),
            pct(steady(&off)),
            pct(off.metrics.gar_avg()),
            "0".into(),
        ],
        vec![
            "defrag on (30m)".into(),
            pct(steady(&on)),
            pct(on.metrics.gar_avg()),
            on.migrations.to_string(),
        ],
    ];
    let mut s = table(
        "Ablation — periodic fragmentation reorganization (§3.3.3)",
        &["config", "GFR(steady)", "GAR", "migrations"],
        &rows,
    );
    s.push_str(
        "\npaper (planned): consolidating scattered resources via rescheduling improves utilization\n",
    );
    s
}

// ---------------------------------------------------------------------
// Weight adaptation: the frozen static weight tables vs the seeded
// adaptive controller vs adaptive + the hard per-class anti-starvation
// bound, on an oversubscribed multi-tenant training stream with explicit
// priority classes (large gangs ride LOW behind the small-job flood).
// ---------------------------------------------------------------------
pub struct WeightAdaptationComparison {
    /// Frozen PR-5 tables, no controller, no bound (`--no-adapt`).
    pub static_arm: SimOutcome,
    /// Controller on, bound off.
    pub adaptive: SimOutcome,
    /// Controller on + hard per-class p99 wait ceiling.
    pub adaptive_bound: SimOutcome,
    /// The ceiling (ms) the bound arm enforced on every class.
    pub bound_ms: u64,
}

/// Default anti-starvation ceiling for the adaptation experiments (12 h):
/// feasible under the ~1.15× offered load (the drain window can clear the
/// backlog inside it) yet tight enough that the rescue/reservation pass
/// and the controller's fairness axis both engage on the aged LOW gangs.
pub const ADAPT_JWTD_BOUND_MS: u64 = 12 * 3_600_000;

/// One arm of the adaptation comparison. Public so the integration tests
/// can replay a single arm at different `--shards` values and compare
/// digests byte-for-byte.
pub fn weight_adaptation_arm(
    scale: Scale,
    seed: u64,
    arrival_ms: u64,
    adapt: bool,
    bound_ms: u64,
    shards: usize,
) -> SimOutcome {
    use crate::job::spec::Priority;

    let opts = SimOptions::for_scale(scale)
        .seed(seed)
        .rho(1.15) // Oversubscribed: a standing backlog ages every class.
        .adapt(adapt)
        .jwtd_bound_ms(bound_ms)
        .shards(shards);
    let setup = opts.build().expect("adaptation options are statically valid");
    let mut jobs = WorkloadGen::new(setup.env.workload.clone()).generate_until(arrival_ms);
    // On top of the generator's 5% HIGH / 5% LOW split, pin every large
    // gang LOW: the starvation-prone cohort the bound protects is then
    // exactly the jobs that also need the most contiguous capacity.
    for j in jobs.iter_mut() {
        if j.total_gpus() >= 64 {
            j.priority = Priority::LOW;
        }
    }
    let mut state = setup.env.state.clone();
    let mut qsch = Qsch::new(setup.qsch, setup.env.ledger.clone());
    let mut rsch = Rsch::new(setup.rsch, &state);
    let mut sim = setup.sim;
    // Truncated arrival horizon + a day of drain so censored waits are
    // finite and the backlog actually clears.
    sim.horizon_ms = arrival_ms + 24 * 3_600_000;
    run(&mut state, &mut qsch, &mut rsch, jobs, &sim)
}

pub fn run_weight_adaptation(
    scale: Scale,
    seed: u64,
    arrival_ms: u64,
) -> WeightAdaptationComparison {
    let bound = ADAPT_JWTD_BOUND_MS;
    WeightAdaptationComparison {
        static_arm: weight_adaptation_arm(scale, seed, arrival_ms, false, 0, 0),
        adaptive: weight_adaptation_arm(scale, seed, arrival_ms, true, 0, 0),
        adaptive_bound: weight_adaptation_arm(scale, seed, arrival_ms, true, bound, 0),
        bound_ms: bound,
    }
}

/// Censored per-class JWTD p99 over a whole run: never-scheduled jobs
/// count at their end-of-run wait, so starvation cannot hide.
pub fn class_jwtd_p99(store: &JobStore, end_ms: u64, class: usize) -> f64 {
    let mut waits: Vec<f64> = store
        .iter()
        .filter(|j| j.spec.priority.class_index() == class)
        .map(|j| j.waiting_ms(end_ms) as f64)
        .collect();
    waits.sort_by(|a, b| a.partial_cmp(b).expect("waits are finite"));
    crate::util::stats::percentile_sorted(&waits, 0.99)
}

/// The `figures weight-adaptation` report.
pub fn weight_adaptation(seed: u64) -> String {
    let c = run_weight_adaptation(Scale::Small, seed, 6 * 3_600_000);
    let row = |name: &str, o: &SimOutcome| -> Vec<String> {
        vec![
            name.to_string(),
            pct(o.metrics.gar_avg()),
            pct(o.metrics.gfr_avg()),
            fmt_ms(class_jwtd_p99(&o.store, o.end_ms, 0)),
            fmt_ms(class_jwtd_p99(&o.store, o.end_ms, 1)),
            fmt_ms(class_jwtd_p99(&o.store, o.end_ms, 2)),
            o.rsch_stats.adapt_shifts.to_string(),
            format!(
                "{}/{}",
                o.qsch_stats.starvation_rescues, o.qsch_stats.starvation_reservations
            ),
            format!("{}/{}", o.metrics.jobs_finished, o.unfinished_jobs),
        ]
    };
    let rows = vec![
        row("static", &c.static_arm),
        row("adaptive", &c.adaptive),
        row("adaptive+bound", &c.adaptive_bound),
    ];
    let mut s = table(
        "Weight adaptation — frozen tables vs adaptive controller vs adaptive + bound",
        &[
            "arm",
            "GAR",
            "GFR",
            "p99-wait LOW",
            "p99-wait NORM",
            "p99-wait HIGH",
            "w-shifts",
            "rescue/reserve",
            "done/stuck",
        ],
        &rows,
    );
    s.push_str(&format!(
        "\nbound: {} on every class (adaptive+bound arm only); GAR delta vs \
         static {:+.2}%\n(the controller trades packing weight for the fairness \
         term when a class's rolling p99 breaks its bound; the QSCH starvation \
         pass rescues aged class heads without ever bypassing quota)\n",
        fmt_ms(c.bound_ms as f64),
        (c.adaptive_bound.metrics.gar_avg() - c.static_arm.metrics.gar_avg()) * 100.0,
    ));
    s
}

// ---------------------------------------------------------------------
// Moldable & malleable gangs: the SAME oversubscribed fragmented mix —
// diurnal inference services (the SLO-pressure source) plus LOW tidal
// training gangs that all declare a shape ladder and checkpoint nothing
// — run under three flag products. Only the scheduler flags differ, so
// the fixed arm is a true control: a fixed-arm eviction restarts a gang
// from scratch, while the malleable arm shrinks it one rung and keeps
// its progress.
// ---------------------------------------------------------------------
pub struct MoldableComparison {
    /// Ladders present in the specs, both passes off.
    pub fixed: SimOutcome,
    /// Admission-time shape selection only.
    pub moldable: SimOutcome,
    /// Shape selection + malleable shrink under SLO/fault pressure.
    pub malleable: SimOutcome,
}

/// Which moldable/malleable flag product an arm runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MoldableMode {
    Fixed,
    Moldable,
    Malleable,
}

/// One arm of the moldable comparison. Public so the integration tests
/// can replay a single arm at different `--shards` values and compare
/// digests byte-for-byte (the mold/shrink decisions live in QSCH's
/// single-threaded phase, so every worker count must agree).
pub fn moldable_gangs_arm(
    seed: u64,
    days: f64,
    mode: MoldableMode,
    shards: usize,
) -> SimOutcome {
    use crate::cluster::builder::{ClusterBuilder, ClusterSpec};
    use crate::cluster::ids::{JobId, TenantId};
    use crate::cluster::tenant::{QuotaLedger, QuotaMode};
    use crate::job::spec::{CheckpointPolicy, ElasticService, GangShape, JobKind, JobSpec};
    use crate::job::workload::tidal_training_stream;
    use crate::sim::elastic::ElasticConfig;
    use crate::util::rng::Pcg32;

    let horizon = (days * 24.0 * 3_600_000.0) as u64;
    let day = ElasticService::DAY_MS;

    // Diurnal SLO-pressure source: the same curve family as the elastic
    // experiment — morning scale-ups must reclaim capacity from the
    // tidal backlog, which is exactly when victims shrink (or, in the
    // control, are evicted).
    let mut rng = Pcg32::seed_from_u64(seed ^ 0x301d);
    let mut jobs: Vec<JobSpec> = (0..12u64)
        .map(|k| {
            let max = 8 + (k % 3) as u32 * 4; // Peaks of 8 / 12 / 16.
            let min = (max / 4).max(1);
            let curve = ElasticService {
                min_replicas: min,
                max_replicas: max,
                phase_ms: rng.below(4 * 3_600_000),
                amplitude: rng.uniform(0.8, 1.0),
                period_ms: day,
            };
            let submit = rng.below(30 * 60_000);
            JobSpec::homogeneous(
                JobId(k + 1),
                TenantId(0),
                JobKind::Inference,
                GpuTypeId(0),
                max,
                1,
            )
            .with_times(submit, horizon.saturating_sub(submit))
            .with_elastic(curve)
        })
        .collect();

    // Oversubscribed tidal mix: LOW 4-pod × 8-GPU gangs with NO
    // checkpoints, every spec carrying the same sub-linear ladder in
    // every arm. Rung throughputs sit below the linear fraction, so a
    // shrunk gang pays a real efficiency premium (more GPU-time for the
    // same work) — the experiment's claim is that this premium still
    // beats restarting from scratch.
    jobs.extend(
        tidal_training_stream(
            seed,
            1_000,
            TenantId(1),
            GpuTypeId(0),
            (days * 32.0).max(1.0) as usize,
            4,
            8,
            horizon.saturating_sub(3 * 3_600_000).max(1),
            6 * 3_600_000,
        )
        .into_iter()
        .map(|mut j| {
            j.checkpoint = CheckpointPolicy::None;
            j.with_shapes(vec![
                GangShape {
                    replicas: 4,
                    throughput: 1.0,
                },
                GangShape {
                    replicas: 2,
                    throughput: 0.45,
                },
                GangShape {
                    replicas: 1,
                    throughput: 0.20,
                },
            ])
        }),
    );
    jobs.sort_by_key(|j| j.submit_ms);

    let mut spec = ClusterSpec::homogeneous("moldable", 2, 4, 4); // 32 nodes.
    spec.inference_zone_frac = 0.25;
    let mut state = ClusterBuilder::build(&spec);
    let mut ledger = QuotaLedger::new(2, 1, QuotaMode::Shared);
    ledger.set_limit(TenantId(0), GpuTypeId(0), state.total_gpus());
    ledger.set_limit(TenantId(1), GpuTypeId(0), state.total_gpus());
    let qsch_cfg = QschConfig {
        enable_moldable: mode != MoldableMode::Fixed,
        enable_shrink: mode == MoldableMode::Malleable,
        batch_shards: shards,
        ..QschConfig::default()
    };
    let mut qsch = Qsch::new(qsch_cfg, ledger);
    let mut rsch = Rsch::new(RschConfig::default(), &state);
    let cfg = SimConfig {
        horizon_ms: horizon + 12 * 3_600_000, // Drain window.
        elastic: ElasticConfig::enabled(),
        ..SimConfig::default()
    };
    run(&mut state, &mut qsch, &mut rsch, jobs, &cfg)
}

/// Run the three arms over `days` simulated days (deterministic per
/// seed).
pub fn run_moldable_gangs(seed: u64, days: f64) -> MoldableComparison {
    MoldableComparison {
        fixed: moldable_gangs_arm(seed, days, MoldableMode::Fixed, 0),
        moldable: moldable_gangs_arm(seed, days, MoldableMode::Moldable, 0),
        malleable: moldable_gangs_arm(seed, days, MoldableMode::Malleable, 0),
    }
}

/// The `figures moldable-gangs` report.
pub fn moldable_gangs(seed: u64) -> String {
    let c = run_moldable_gangs(seed, 2.0);
    // Discarded work across ALL eviction paths (SLO pressure included),
    // in GPU-hours — what the reliability counter only tracks for
    // faults.
    let lost_gpu_h = |o: &SimOutcome| -> f64 {
        o.store
            .iter()
            .map(|j| j.lost_work_ms.saturating_mul(j.spec.total_gpus() as u64))
            .sum::<u64>() as f64
            / 3_600_000.0
    };
    let row = |name: &str, o: &SimOutcome| -> Vec<String> {
        vec![
            name.to_string(),
            pct(o.metrics.gar_avg()),
            pct(o.metrics.goodput_fraction()),
            fmt_ms(class_jwtd_p99(&o.store, o.end_ms, 0)),
            o.qsch_stats.shape_molds.to_string(),
            o.qsch_stats.shape_shrinks.to_string(),
            o.qsch_stats.slo_pressure_preemptions.to_string(),
            format!("{:.0}", lost_gpu_h(o)),
            format!("{}/{}", o.metrics.jobs_finished, o.metrics.jobs_submitted),
        ]
    };
    let rows = vec![
        row("fixed", &c.fixed),
        row("moldable", &c.moldable),
        row("moldable+malleable", &c.malleable),
    ];
    let mut s = table(
        "Moldable & malleable gangs — fixed vs moldable vs moldable+malleable",
        &[
            "arm",
            "GAR",
            "goodput-frac",
            "p99-wait LOW",
            "molds",
            "shrinks",
            "slo-evict",
            "lost-GPU-h",
            "done/sub",
        ],
        &rows,
    );
    s.push_str(&format!(
        "\nmoldable+malleable vs fixed: goodput fraction {:+.2}%, LOW p99 wait \
         {:+.1} h, GAR {:+.2}%\n(admission molding slides queued gangs down their \
         ladder only as far as fragmentation forces; under morning SLO pressure \
         malleable victims give up one rung — keeping their progress — where the \
         control restarts them from scratch)\n",
        (c.malleable.metrics.goodput_fraction() - c.fixed.metrics.goodput_fraction()) * 100.0,
        (class_jwtd_p99(&c.malleable.store, c.malleable.end_ms, 0)
            - class_jwtd_p99(&c.fixed.store, c.fixed.end_ms, 0))
            / 3_600_000.0,
        (c.malleable.metrics.gar_avg() - c.fixed.metrics.gar_avg()) * 100.0,
    ));
    s
}

// ---------------------------------------------------------------------
// Observability self-portrait: the digest-inert phase profiler watching
// one standard run. Not a paper figure — the `figures obs-phases` id
// regenerates the scheduler-overhead evidence (wall-clock per phase,
// per-cycle overhead fraction) that PR 9's obs layer reports.
// ---------------------------------------------------------------------
pub fn obs_phases(scale: Scale, seed: u64) -> String {
    use crate::metrics::report::phase_table;
    use crate::obs::ObsRecorder;
    use crate::sim::run_observed;

    let setup = SimOptions::for_scale(scale)
        .seed(seed)
        .build()
        .expect("scale presets are statically valid");
    let mut env = setup.env;
    let jobs = WorkloadGen::new(env.workload.clone()).generate_until(env.horizon_ms);
    let mut qsch = Qsch::new(setup.qsch, env.ledger.clone());
    let mut rsch = Rsch::new(setup.rsch, &env.state);
    let mut obs = ObsRecorder::enabled(1);
    let out = run_observed(
        &mut env.state,
        &mut qsch,
        &mut rsch,
        jobs,
        Vec::new(),
        &setup.sim,
        &mut obs,
    );
    let mut s = phase_table(&out.health, setup.sim.cycle_ms);
    s.push_str(
        "\n(digest-inert: the same seed with the recorder disabled reproduces \
         the run digest byte-for-byte — `tests/obs.rs` holds that line)\n",
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_report_contains_claims() {
        let s = fig2(3);
        assert!(s.contains("Figure 2"));
        assert!(s.contains("GPU-time share"));
    }

    #[test]
    fn run_arm_is_deterministic() {
        let env = inference_cluster(InferencePreset::A10, 5);
        let a = run_arm(&env, &Arm::kant_backfill(), &SimConfig::default());
        let b = run_arm(&env, &Arm::kant_backfill(), &SimConfig::default());
        assert_eq!(a.metrics.jobs_finished, b.metrics.jobs_finished);
        assert!((a.metrics.sor_final() - b.metrics.sor_final()).abs() < 1e-12);
        assert_eq!(a.end_ms, b.end_ms);
    }

    #[test]
    fn candidate_index_ablation_reports_identical_placements() {
        let s = ablation_candidate_index(Scale::Small, 11);
        assert!(s.contains("candidate selection"));
        assert!(s.contains("placements identical: true"), "{s}");
    }

    #[test]
    fn elastic_tidal_beats_static_on_gar_without_slo_cost() {
        let c = run_elastic_inference(7, 1.0);
        let gar = |o: &SimOutcome| o.metrics.gar_avg();
        let slo = |o: &SimOutcome| o.metrics.elastic.slo_violation_rate();
        // Static mean-provisioning violates the diurnal SLO about half
        // the day; the controller tracks the curve.
        assert!(slo(&c.static_arm) > 0.2, "static SLO {}", slo(&c.static_arm));
        assert!(
            slo(&c.elastic) < slo(&c.static_arm) / 2.0,
            "elastic SLO {} vs static {}",
            slo(&c.elastic),
            slo(&c.static_arm)
        );
        // The acceptance bar: elastic+tidal beats static on GAR at an
        // equal-or-lower SLO violation rate.
        assert!(
            gar(&c.tidal) > gar(&c.static_arm),
            "tidal GAR {} must beat static {}",
            gar(&c.tidal),
            gar(&c.static_arm)
        );
        assert!(slo(&c.tidal) <= slo(&c.static_arm));
        // The tide was actually harvested and reclaimed.
        let (a, b) = c.tidal.metrics.window();
        assert!(c.tidal.metrics.elastic.elastic_utilization(a, b) > 0.0);
        assert!(
            c.tidal.qsch_stats.slo_pressure_preemptions > 0,
            "morning scale-up should reclaim tidal capacity at least once"
        );
        assert_eq!(c.elastic.qsch_stats.slo_pressure_preemptions, 0);
    }

    #[test]
    fn elastic_inference_deterministic_per_seed() {
        let digest = |c: &ElasticComparison| {
            [&c.static_arm, &c.elastic, &c.tidal]
                .iter()
                .map(|o| o.digest_json().to_string_compact())
                .collect::<Vec<_>>()
        };
        let a = run_elastic_inference(11, 0.5);
        let b = run_elastic_inference(11, 0.5);
        assert_eq!(digest(&a), digest(&b));
        let c = run_elastic_inference(12, 0.5);
        assert_ne!(digest(&a), digest(&c));
    }

    #[test]
    fn checkpointing_and_aging_beat_naive_restart() {
        let c = run_fault_tolerance(5, 1.0);
        let naive = &c.naive;
        // Shortest checkpoint interval = the headline resilient arm.
        let best = &c.checkpointed[0].1;
        // The storm actually happened in both arms. (The *trace* is
        // identical by construction; the delivered count can differ only
        // because an arm that finishes all work stops listening early.)
        assert!(naive.metrics.reliability.faults_injected() > 0);
        assert!(best.metrics.reliability.faults_injected() > 0);
        assert!(naive.metrics.reliability.fault_evictions > 0);
        // Acceptance: checkpointing + priority aging yields strictly
        // higher goodput per allocated GPU-hour...
        let gf = |o: &SimOutcome| o.metrics.goodput_fraction();
        assert!(
            gf(best) > gf(naive),
            "checkpointed goodput fraction {} must beat naive {}",
            gf(best),
            gf(naive)
        );
        assert!(
            best.metrics.reliability.goodput_gpu_hours()
                >= naive.metrics.reliability.goodput_gpu_hours(),
            "checkpointing must not finish less work"
        );
        // ... with strictly less work thrown away ...
        assert!(
            best.metrics.reliability.lost_gpu_hours()
                < naive.metrics.reliability.lost_gpu_hours(),
            "checkpointed lost {} GPU-h vs naive {}",
            best.metrics.reliability.lost_gpu_hours(),
            naive.metrics.reliability.lost_gpu_hours()
        );
        // ... and a lower p99 completion inflation (the JTTED tail).
        // Censored over ALL jobs — an arm must not look good by simply
        // never finishing its most-inflated gangs.
        let p99 = |o: &SimOutcome| {
            let samples: Vec<f64> = o
                .store
                .iter()
                .map(|j| {
                    let ideal = (j.spec.duration_ms + 30_000).max(1) as f64;
                    let end = j.finished_ms.unwrap_or(o.end_ms);
                    let start = j.scheduled_ms.unwrap_or(j.submit_ms);
                    end.saturating_sub(start) as f64 / ideal
                })
                .collect();
            Summary::from_samples(&samples).p99
        };
        assert!(
            p99(best) < p99(naive),
            "checkpointed inflation p99 {} must beat naive {}",
            p99(best),
            p99(naive)
        );
        // The fault-free ceiling stays the ceiling.
        assert!(gf(&c.no_faults) >= gf(best));
        assert_eq!(c.no_faults.metrics.reliability.faults_injected(), 0);
    }

    #[test]
    fn fault_tolerance_deterministic_per_seed() {
        let digest = |c: &FaultToleranceComparison| {
            let mut d: Vec<String> = vec![
                c.no_faults.digest_json().to_string_compact(),
                c.naive.digest_json().to_string_compact(),
                c.hardened.digest_json().to_string_compact(),
            ];
            d.extend(
                c.checkpointed
                    .iter()
                    .map(|(_, o)| o.digest_json().to_string_compact()),
            );
            d
        };
        let a = run_fault_tolerance(11, 0.5);
        let b = run_fault_tolerance(11, 0.5);
        assert_eq!(digest(&a), digest(&b), "same seed must replay byte-identically");
        let c = run_fault_tolerance(12, 0.5);
        assert_ne!(digest(&a), digest(&c), "different seeds must diverge");
    }

    #[test]
    fn topology_stress_truthful_reduces_superspine_spans_at_no_gar_cost() {
        let c = run_topology_stress(Scale::Small, 17);
        let blind = large_gang_superspine_dev(&c.blind);
        let truthful = large_gang_superspine_dev(&c.truthful);
        assert!(
            blind > 1.0,
            "the blind arm must actually cross superspines ({blind})"
        );
        assert!(
            truthful < blind,
            "truthful tiers must strictly reduce superspine spans: {truthful} vs {blind}"
        );
        // "No GAR cost": topology preference only reorders feasible
        // choices, so allocation must not degrade beyond noise.
        let gar_blind = c.blind.metrics.gar_avg();
        let gar_truthful = c.truthful.metrics.gar_avg();
        assert!(
            gar_truthful >= gar_blind - 0.02,
            "truthful GAR {gar_truthful} fell below blind {gar_blind}"
        );
        // Large gangs were recorded into the big buckets at all.
        assert!(c.truthful.metrics.jtted_superspine_summaries()[3..]
            .iter()
            .any(|(_, s)| s.count > 0));
    }

    #[test]
    fn binpack_spread_digests_invariant_to_truthful_tiers() {
        // The truthful-tier refactor's digest guarantee: topology-agnostic
        // weight rows (Binpack, Spread — zero w_topo) must produce
        // byte-identical same-seed runs whether or not the scorer can see
        // cross-superspine crossings.
        for strat in [PlacementStrategy::Binpack, PlacementStrategy::Spread] {
            let digest = |topo_blind: bool| {
                let env = inference_cluster(InferencePreset::A10, 9);
                let arm = Arm {
                    label: "invariance",
                    qsch: QschConfig::default(),
                    rsch: RschConfig {
                        training_strategy: strat,
                        inference_strategy: strat,
                        dev_strategy: strat,
                        topo_blind,
                        ..RschConfig::default()
                    },
                };
                run_arm(&env, &arm, &SimConfig::default())
                    .digest_json()
                    .to_string_compact()
            };
            assert_eq!(
                digest(false),
                digest(true),
                "{strat:?} digest moved with the topo_blind flag"
            );
        }
    }

    #[test]
    fn weight_adaptation_bound_holds_with_low_gar_cost() {
        use crate::job::spec::Priority;
        let c = run_weight_adaptation(Scale::Small, 7, 6 * 3_600_000);
        // (a) The bound arm holds every class's censored p99 wait within
        // the configured ceiling.
        for class in 0..Priority::NUM_CLASSES {
            let p99 = class_jwtd_p99(&c.adaptive_bound.store, c.adaptive_bound.end_ms, class);
            assert!(
                p99 <= c.bound_ms as f64,
                "class {class} p99 wait {p99} broke the {} ms bound",
                c.bound_ms
            );
        }
        // (b) < 1% GAR loss vs the frozen static tables.
        let gar_static = c.static_arm.metrics.gar_avg();
        let gar_bound = c.adaptive_bound.metrics.gar_avg();
        assert!(
            gar_bound >= gar_static - 0.01,
            "adaptive+bound GAR {gar_bound} lost more than 1% vs static {gar_static}"
        );
        // (c) The controller actually ran on the adaptive arms — and the
        // static arm provably never ticked (the frozen `--no-adapt` path).
        assert!(c.adaptive.rsch_stats.adapt_ticks > 0);
        assert!(c.adaptive_bound.rsch_stats.adapt_ticks > 0);
        assert_eq!(c.static_arm.rsch_stats.adapt_ticks, 0);
        assert_eq!(c.static_arm.rsch_stats.adapt_shifts, 0);
    }

    #[test]
    fn weight_adaptation_digests_shard_invariant() {
        // The controller updates in the single-threaded QSCH phase, so
        // the sharded prefetch arms inherit the identical overlay: same
        // seed => byte-identical digests for --shards {0, 1, 8}.
        let digest = |shards: usize| {
            weight_adaptation_arm(Scale::Small, 7, 2 * 3_600_000, true, ADAPT_JWTD_BOUND_MS, shards)
                .digest_json()
                .to_string_compact()
        };
        let d0 = digest(0);
        assert_eq!(d0, digest(1), "--shards 1 digest diverged with --adapt on");
        assert_eq!(d0, digest(8), "--shards 8 digest diverged with --adapt on");
    }

    #[test]
    #[ignore = "xlarge adaptation arm (minutes) — CI runs it on main via --include-ignored"]
    fn weight_adaptation_bound_holds_at_xlarge() {
        use crate::job::spec::Priority;
        let c = run_weight_adaptation(Scale::XLarge, 7, 2 * 3_600_000);
        for class in 0..Priority::NUM_CLASSES {
            let p99 = class_jwtd_p99(&c.adaptive_bound.store, c.adaptive_bound.end_ms, class);
            assert!(
                p99 <= c.bound_ms as f64,
                "class {class} p99 wait {p99} broke the {} ms bound at xlarge",
                c.bound_ms
            );
        }
        assert!(
            c.adaptive_bound.metrics.gar_avg() >= c.static_arm.metrics.gar_avg() - 0.01,
            "adaptive+bound lost more than 1% GAR vs static at xlarge"
        );
        assert!(c.adaptive_bound.rsch_stats.adapt_ticks > 0);
    }

    #[test]
    fn jwtd_buckets_include_censored() {
        use crate::cluster::ids::{GpuTypeId, JobId, TenantId};
        use crate::job::spec::{JobKind, JobSpec};
        use crate::job::state::Job;
        let mut store = JobStore::new();
        let spec = JobSpec::homogeneous(
            JobId(1),
            TenantId(0),
            JobKind::Training,
            GpuTypeId(0),
            1,
            8,
        );
        store.insert(Job::new(spec)); // Never scheduled.
        let b = jwtd_buckets(&store, 10_000);
        assert_eq!(b.summaries()[1].1.count, 1);
        assert_eq!(b.summaries()[1].1.mean, 10_000.0);
    }

    #[test]
    fn moldable_malleable_beats_fixed_on_goodput_at_no_gar_cost() {
        let c = run_moldable_gangs(7, 1.0);
        let gf = |o: &SimOutcome| o.metrics.goodput_fraction();
        let p99 = |o: &SimOutcome| class_jwtd_p99(&o.store, o.end_ms, 0);
        // The control never molds or shrinks even though every spec
        // carries a ladder.
        assert_eq!(c.fixed.qsch_stats.shape_molds, 0);
        assert_eq!(c.fixed.qsch_stats.shape_shrinks, 0);
        // Admission molding fires under fragmentation; shrink only in
        // the malleable arm.
        assert!(c.moldable.qsch_stats.shape_molds > 0);
        assert_eq!(c.moldable.qsch_stats.shape_shrinks, 0);
        assert!(
            c.malleable.qsch_stats.shape_shrinks > 0,
            "morning SLO pressure should shrink at least one tidal gang"
        );
        // The acceptance bar: moldable+malleable beats fixed on
        // realized-throughput-weighted goodput and LOW-class JWTD p99,
        // at no GAR cost.
        assert!(
            gf(&c.malleable) > gf(&c.fixed),
            "malleable goodput fraction {} must beat fixed {}",
            gf(&c.malleable),
            gf(&c.fixed)
        );
        assert!(
            p99(&c.malleable) < p99(&c.fixed),
            "malleable LOW p99 wait {} must beat fixed {}",
            p99(&c.malleable),
            p99(&c.fixed)
        );
        assert!(
            c.malleable.metrics.gar_avg() >= c.fixed.metrics.gar_avg() - 0.02,
            "malleable GAR {} must not cost vs fixed {}",
            c.malleable.metrics.gar_avg(),
            c.fixed.metrics.gar_avg()
        );
    }

    #[test]
    fn moldable_gangs_deterministic_per_seed() {
        let digest = |c: &MoldableComparison| {
            [&c.fixed, &c.moldable, &c.malleable]
                .iter()
                .map(|o| o.digest_json().to_string_compact())
                .collect::<Vec<_>>()
        };
        let a = run_moldable_gangs(11, 0.5);
        let b = run_moldable_gangs(11, 0.5);
        assert_eq!(digest(&a), digest(&b));
        let c = run_moldable_gangs(12, 0.5);
        assert_ne!(digest(&a), digest(&c));
    }

    #[test]
    fn moldable_digests_shard_invariant() {
        // Shape selection and shrink both live in the single-threaded
        // QSCH phase (mold pass runs before the prefetch fan-out), so
        // every worker count must produce the identical schedule: same
        // seed => byte-identical digests for --shards {0, 1, 8}.
        let digest = |shards: usize| {
            moldable_gangs_arm(7, 0.5, MoldableMode::Malleable, shards)
                .digest_json()
                .to_string_compact()
        };
        let d0 = digest(0);
        assert_eq!(d0, digest(1), "--shards 1 digest diverged with molding on");
        assert_eq!(d0, digest(8), "--shards 8 digest diverged with molding on");
    }
}
