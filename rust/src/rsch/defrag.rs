//! Periodic fragmentation reorganization (§3.3.3, the paper's planned
//! extension): consolidate scattered sub-node allocations by migrating
//! pods off lightly-fragmented nodes, freeing whole nodes for large jobs.
//!
//! Policy (conservative, like everything in Kant):
//! * only *non-gang* pods and single-pod jobs migrate (migrating one pod
//!   of a distributed gang would stall the whole job);
//! * a migration only happens if the pod fits on another node that is
//!   already fragmented or busier (never create a new fragmented node);
//! * per-round migration budget caps churn.
//!
//! **Drain-aware scheduling** (reliability subsystem): nodes in the
//! [`Health::Draining`] lifecycle state are first-class sources — they
//! must be emptied regardless of fragmentation class or drain cost, and
//! (unlike fragmentation consolidation) their pods may land on idle
//! nodes, because vacating the drain target outranks packing quality.
//!
//! Each migration is modelled with a configurable service interruption:
//! the simulator replays it as release→place, so metrics see the real
//! cost.

use crate::cluster::gpu::Health;
use crate::cluster::ids::{GroupId, JobId, NodeId};
use crate::cluster::index::{NodeIndex, ZoneQuery};
use crate::cluster::state::{ClusterState, PodPlacement};
use crate::job::store::JobStore;

use super::device_alloc::{select_devices, select_nic};

/// Defragmentation tunables.
#[derive(Debug, Clone)]
pub struct DefragConfig {
    /// Max pod migrations per reorganization round.
    pub max_migrations_per_round: usize,
    /// Only consider source nodes with at most this many allocated GPUs
    /// (cheap to drain).
    pub max_source_alloc: u32,
}

impl Default for DefragConfig {
    fn default() -> Self {
        DefragConfig {
            max_migrations_per_round: 8,
            max_source_alloc: 4,
        }
    }
}

/// One planned migration.
#[derive(Debug, Clone, PartialEq)]
pub struct Migration {
    pub job: JobId,
    pub from: NodeId,
    pub to: NodeId,
    pub devices_to: Vec<u8>,
    pub nic_to: u8,
}

/// Outcome counters for a round.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DefragReport {
    pub migrations: usize,
    pub nodes_freed: usize,
    pub gpus_moved: u32,
}

/// Plan one reorganization round against the current state. Pure planning:
/// no mutation (the runner executes migrations so lifecycle/metrics see
/// them).
pub fn plan_round(
    state: &ClusterState,
    store: &JobStore,
    cfg: &DefragConfig,
) -> Vec<Migration> {
    // One O(nodes) free-capacity index build per round replaces both the
    // O(cluster) fragmented-source scan and the per-pod O(pool)
    // destination scans: only buckets that can matter are walked, and
    // exact eligibility is re-checked per candidate, so plans are
    // identical to the full scans.
    let index = NodeIndex::from_state(state);
    // Pool→groups is static topology; deriving it here is one O(nodes)
    // pass per round, kept local so plan_round stays a free function.
    let pool_groups = state.pool_groups();

    // Source candidates: fragmented nodes with little to drain, emptiest
    // first (cheapest whole-node wins). Fragmented nodes have >= 1 free
    // GPU, so fully-allocated nodes — the bulk of a busy cluster — are
    // never walked; whole-free ones are rejected by the exact check.
    let mut source_ids: Vec<NodeId> = Vec::new();
    for g in 0..index.num_groups() {
        index.for_group(GroupId(g as u32), 1, ZoneQuery::Any, &mut source_ids);
    }
    let mut sources: Vec<&crate::cluster::node::Node> = source_ids
        .iter()
        .map(|&n| state.node(n))
        .filter(|n| n.is_fragmented() && n.allocated_gpus() <= cfg.max_source_alloc)
        .collect();
    sources.sort_by_key(|n| (n.allocated_gpus(), n.id));

    // Drain-aware sources: Draining nodes with residents come FIRST and
    // bypass the fragmentation/drain-cost filters — they must be emptied.
    // (The index excludes unschedulable nodes, so they need a direct scan.)
    let mut drain_sources: Vec<&crate::cluster::node::Node> = state
        .nodes
        .iter()
        .filter(|n| n.health == Health::Draining && n.allocated_gpus() > 0)
        .collect();
    drain_sources.sort_by_key(|n| (n.allocated_gpus(), n.id));

    let mut migrations: Vec<Migration> = Vec::new();
    // Track planned deltas so one round's plans don't conflict, and keep
    // sources/destinations disjoint (otherwise two fragmented nodes just
    // swap pods and nothing is freed).
    let mut planned_free: std::collections::BTreeMap<NodeId, Vec<u8>> =
        std::collections::BTreeMap::new();
    let mut planned_dests: std::collections::BTreeSet<NodeId> = std::collections::BTreeSet::new();
    let mut planned_sources: std::collections::BTreeSet<NodeId> =
        std::collections::BTreeSet::new();
    let free_of = |state: &ClusterState,
                   planned: &std::collections::BTreeMap<NodeId, Vec<u8>>,
                   n: NodeId|
     -> Vec<u8> {
        planned
            .get(&n)
            .cloned()
            .unwrap_or_else(|| state.node(n).free_gpu_indices())
    };

    let ordered: Vec<(&crate::cluster::node::Node, bool)> = drain_sources
        .into_iter()
        .map(|n| (n, true))
        .chain(sources.into_iter().map(|n| (n, false)))
        .collect();
    'source: for (src, draining_src) in ordered {
        if migrations.len() >= cfg.max_migrations_per_round {
            break;
        }
        if planned_dests.contains(&src.id) {
            continue; // This node is being filled; don't drain it.
        }
        // Every resident pod must be migratable or we skip the node (a
        // partially-drained node stays fragmented — no gain).
        let pods = src.resident_pods();
        let mut node_plan: Vec<Migration> = Vec::new();
        for pod in &pods {
            let job = match store.get(pod.job) {
                Some(j) => j,
                None => continue 'source,
            };
            // Conservative eligibility: non-gang jobs or single-pod jobs.
            if job.spec.gang && job.spec.total_replicas() > 1 {
                continue 'source;
            }
            let devs_here = src.devices_of(*pod);
            let want = devs_here.len() as u32;
            // Destination: a *more* allocated, still-capable node of the
            // same pool (never an idle node — that would undo the work).
            // Bucket walk: only pool nodes with `free >= want` right now.
            let mut dests: Vec<NodeId> = Vec::new();
            if let Some(p) = state.pools.pool_for_type(src.gpu_type) {
                for &g in &pool_groups[p.id.index()] {
                    index.for_group(g, want, ZoneQuery::Any, &mut dests);
                }
            }
            dests.retain(|&d| {
                d != src.id
                    && !planned_sources.contains(&d)
                    && state.node(d).health.schedulable()
                    // Consolidation never targets idle nodes (that would
                    // undo the work); vacating a drain may.
                    && (draining_src || state.node(d).allocated_gpus() > 0)
                    && free_of(state, &planned_free, d).len() as u32 >= want
            });
            // Best-fit: fullest destination first; among equally-full
            // destinations prefer the topologically-nearest (same leaf <
            // spine < superspine < cross-superspine, now truthful), so a
            // migration never crosses more fabric than the packing win
            // requires.
            dests.sort_by_key(|&d| {
                (
                    free_of(state, &planned_free, d).len(),
                    state.fabric.tier(src.id, d) as u8,
                    d,
                )
            });
            let Some(&dest) = dests.first() else {
                continue 'source;
            };
            let gpu_type = state.gpu_type(state.node(dest).gpu_type);
            let dest_free = free_of(state, &planned_free, dest);
            let Some(devices_to) = select_devices(gpu_type, &dest_free, want) else {
                continue 'source;
            };
            let nic_to = select_nic(gpu_type, &devices_to);
            node_plan.push(Migration {
                job: pod.job,
                from: src.id,
                to: dest,
                devices_to,
                nic_to,
            });
        }
        // Commit the node's plan into the round.
        let remaining_budget = cfg.max_migrations_per_round - migrations.len();
        if node_plan.is_empty() || node_plan.len() > remaining_budget {
            continue;
        }
        for m in &node_plan {
            let mut f = free_of(state, &planned_free, m.to);
            f.retain(|d| !m.devices_to.contains(d));
            planned_free.insert(m.to, f);
            planned_dests.insert(m.to);
        }
        planned_sources.insert(src.id);
        migrations.extend(node_plan);
    }
    migrations
}

/// Execute planned migrations: atomically re-home each job's pods.
/// Returns the report plus the jobs actually moved; skips any migration
/// that no longer applies.
pub fn execute(
    state: &mut ClusterState,
    migrations: &[Migration],
) -> (DefragReport, Vec<JobId>) {
    let mut report = DefragReport::default();
    let mut moved: Vec<JobId> = Vec::new();
    let mut touched_sources: Vec<NodeId> = Vec::new();
    for m in migrations {
        // The job must still hold exactly its old placement.
        let Some(old) = state.placements_of(m.job).map(|p| p.to_vec()) else {
            continue;
        };
        let Ok(freed) = state.release_job(m.job) else {
            continue;
        };
        // Re-place every pod: moved pods go to the new node, others return
        // to where they were.
        let new_plan: Vec<PodPlacement> = freed
            .iter()
            .map(|p| {
                if p.node == m.from {
                    PodPlacement {
                        pod: p.pod,
                        node: m.to,
                        devices: m.devices_to.clone(),
                        nic: m.nic_to,
                    }
                } else {
                    p.clone()
                }
            })
            .collect();
        match state.commit_placements(m.job, new_plan) {
            Ok(()) => {
                report.migrations += 1;
                report.gpus_moved += m.devices_to.len() as u32;
                moved.push(m.job);
                touched_sources.push(m.from);
            }
            Err(_) => {
                // Roll back to the original placement (must succeed: we
                // just freed those devices).
                state
                    .commit_placements(m.job, old)
                    .expect("rollback placement");
            }
        }
    }
    touched_sources.sort_unstable();
    touched_sources.dedup();
    report.nodes_freed = touched_sources
        .iter()
        .filter(|&&n| state.node(n).allocated_gpus() == 0)
        .count();
    (report, moved)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::builder::{ClusterBuilder, ClusterSpec};
    use crate::cluster::ids::{GpuTypeId, PodId, TenantId};
    use crate::job::spec::{JobKind, JobSpec};
    use crate::job::state::Job;

    fn setup() -> (ClusterState, JobStore) {
        let state = ClusterBuilder::build(&ClusterSpec::homogeneous("d", 1, 1, 4));
        (state, JobStore::new())
    }

    /// Place a single-pod non-gang job on a specific node.
    fn place(state: &mut ClusterState, store: &mut JobStore, id: u64, node: u32, gpus: u32) {
        let spec = JobSpec::homogeneous(
            JobId(id),
            TenantId(0),
            JobKind::Inference,
            GpuTypeId(0),
            1,
            gpus,
        )
        .with_gang(false);
        let free = state.node(NodeId(node)).free_gpu_indices();
        state
            .commit_placements(
                JobId(id),
                vec![PodPlacement {
                    pod: PodId::new(JobId(id), 0),
                    node: NodeId(node),
                    devices: free[..gpus as usize].to_vec(),
                    nic: 0,
                }],
            )
            .unwrap();
        let mut j = Job::new(spec);
        j.mark_admitted();
        j.mark_scheduled(0);
        store.insert(j);
    }

    #[test]
    fn consolidates_two_fragmented_nodes() {
        let (mut state, mut store) = setup();
        place(&mut state, &mut store, 1, 0, 2);
        place(&mut state, &mut store, 2, 1, 2);
        assert!((state.fragmentation_ratio(None) - 0.5).abs() < 1e-9);
        let plan = plan_round(&state, &store, &DefragConfig::default());
        assert!(!plan.is_empty());
        let (report, _moved) = execute(&mut state, &plan);
        assert!(report.migrations >= 1);
        // One of the two fragmented nodes is now empty.
        assert!((state.fragmentation_ratio(None) - 0.25).abs() < 1e-9);
        assert!(report.nodes_freed >= 1);
        // No allocation lost.
        assert_eq!(state.allocated_gpus(), 4);
    }

    #[test]
    fn never_migrates_gang_pods() {
        let (mut state, mut store) = setup();
        // A 2-pod gang across nodes 0 and 1 (2 GPUs each) — fragmented but
        // untouchable.
        let spec = JobSpec::homogeneous(
            JobId(1),
            TenantId(0),
            JobKind::Training,
            GpuTypeId(0),
            2,
            2,
        );
        state
            .commit_placements(
                JobId(1),
                vec![
                    PodPlacement {
                        pod: PodId::new(JobId(1), 0),
                        node: NodeId(0),
                        devices: vec![0, 1],
                        nic: 0,
                    },
                    PodPlacement {
                        pod: PodId::new(JobId(1), 1),
                        node: NodeId(1),
                        devices: vec![0, 1],
                        nic: 0,
                    },
                ],
            )
            .unwrap();
        let mut j = Job::new(spec);
        j.mark_admitted();
        j.mark_scheduled(0);
        store.insert(j);
        let plan = plan_round(&state, &store, &DefragConfig::default());
        assert!(plan.is_empty(), "gang pods must not migrate: {plan:?}");
    }

    #[test]
    fn never_targets_idle_nodes() {
        let (mut state, mut store) = setup();
        place(&mut state, &mut store, 1, 0, 1);
        // Only one fragmented node and three idle ones: nowhere to go.
        let plan = plan_round(&state, &store, &DefragConfig::default());
        assert!(plan.is_empty());
    }

    #[test]
    fn draining_node_is_emptied_even_onto_idle_nodes() {
        let (mut state, mut store) = setup();
        place(&mut state, &mut store, 1, 0, 2);
        state.set_node_health(NodeId(0), Health::Draining);
        // Only idle destinations exist: plain consolidation would stay
        // put (see `never_targets_idle_nodes`), a drain moves anyway.
        let plan = plan_round(&state, &store, &DefragConfig::default());
        assert_eq!(plan.len(), 1, "drain source must be vacated: {plan:?}");
        assert_eq!(plan[0].from, NodeId(0));
        let (report, moved) = execute(&mut state, &plan);
        assert_eq!(report.migrations, 1);
        assert_eq!(moved, vec![JobId(1)]);
        assert_eq!(state.node(NodeId(0)).allocated_gpus(), 0);
        assert_eq!(state.allocated_gpus(), 2, "no allocation lost in the move");
    }

    #[test]
    fn draining_node_with_gang_residents_waits() {
        let (mut state, mut store) = setup();
        // A 2-pod gang with one pod on the draining node: untouchable.
        let spec = JobSpec::homogeneous(
            JobId(1),
            TenantId(0),
            JobKind::Training,
            GpuTypeId(0),
            2,
            2,
        );
        state
            .commit_placements(
                JobId(1),
                vec![
                    PodPlacement {
                        pod: PodId::new(JobId(1), 0),
                        node: NodeId(0),
                        devices: vec![0, 1],
                        nic: 0,
                    },
                    PodPlacement {
                        pod: PodId::new(JobId(1), 1),
                        node: NodeId(1),
                        devices: vec![0, 1],
                        nic: 0,
                    },
                ],
            )
            .unwrap();
        let mut j = Job::new(spec);
        j.mark_admitted();
        j.mark_scheduled(0);
        store.insert(j);
        state.set_node_health(NodeId(0), Health::Draining);
        let plan = plan_round(&state, &store, &DefragConfig::default());
        assert!(plan.is_empty(), "gang pods must not migrate off a drain");
    }

    #[test]
    fn equally_full_destinations_prefer_nearby_fabric() {
        // Source on group 0; equally-loaded destinations in the same
        // group and across the superspine: the migration must stay local.
        let mut spec = ClusterSpec::homogeneous("near", 2, 1, 2);
        spec.spines_per_superspine = 1; // 2 superspines of 1 spine each.
        let mut state = ClusterBuilder::build(&spec);
        let mut store = JobStore::new();
        place(&mut state, &mut store, 1, 0, 2); // Source (fragmented).
        place(&mut state, &mut store, 2, 1, 3); // Same leaf, 5 free.
        place(&mut state, &mut store, 3, 2, 3); // Cross-superspine, 5 free.
        let plan = plan_round(&state, &store, &DefragConfig::default());
        assert!(!plan.is_empty());
        assert_eq!(plan[0].from, NodeId(0));
        assert_eq!(
            plan[0].to,
            NodeId(1),
            "equally-full destinations must break ties toward the same leaf: {plan:?}"
        );
    }

    #[test]
    fn budget_caps_migrations() {
        let (mut state, mut store) = setup();
        for (id, node) in [(1u64, 0u32), (2, 1), (3, 2), (4, 3)] {
            place(&mut state, &mut store, id, node, 1);
        }
        let cfg = DefragConfig {
            max_migrations_per_round: 2,
            ..DefragConfig::default()
        };
        let plan = plan_round(&state, &store, &cfg);
        assert!(plan.len() <= 2);
    }

    #[test]
    fn execute_skips_stale_migrations() {
        let (mut state, mut store) = setup();
        place(&mut state, &mut store, 1, 0, 2);
        place(&mut state, &mut store, 2, 1, 2);
        let plan = plan_round(&state, &store, &DefragConfig::default());
        // Job finishes before execution.
        state.release_job(JobId(1)).ok();
        state.release_job(JobId(2)).ok();
        let (report, _moved) = execute(&mut state, &plan);
        assert_eq!(report.migrations, 0);
        assert_eq!(state.allocated_gpus(), 0);
    }

    #[test]
    fn multi_gpu_pod_moves_whole() {
        let (mut state, mut store) = setup();
        place(&mut state, &mut store, 1, 0, 4);
        place(&mut state, &mut store, 2, 1, 3);
        let plan = plan_round(&state, &store, &DefragConfig::default());
        let (report, _moved) = execute(&mut state, &plan);
        assert!(report.migrations >= 1);
        assert_eq!(state.allocated_gpus(), 7);
        // The moved job's devices all live on one node.
        for id in [1u64, 2] {
            let nodes = state.nodes_of(JobId(id));
            assert_eq!(nodes.len(), 1, "job {id} split across nodes");
        }
    }
}
