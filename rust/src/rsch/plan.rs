//! Placement-plan builder: tracks in-flight device takings against a
//! snapshot so gang placement is transactional — nothing touches
//! `ClusterState` until the whole plan commits (§3.3.2).

use std::collections::HashMap;

use crate::cluster::ids::{GroupId, HbdId, JobId, NodeId, PodId};
use crate::cluster::snapshot::Snapshot;
use crate::cluster::state::{ClusterState, PodPlacement};
use crate::cluster::topology::{FootprintDelta, GangFootprint, Tier};

use super::device_alloc::{select_devices, select_nic};
use super::features::PlanView;

/// How many `gpus_per_pod`-sized pod slots `nodes` currently expose under
/// `snapshot` (healthy nodes only; a node with 8 free holds two 4-GPU
/// pods) — the O(candidates) feasibility probe behind moldable shape
/// selection ([`super::Rsch`]'s `mold_shapes`).
pub fn pod_slots(
    snapshot: &Snapshot,
    nodes: &[NodeId],
    gpus_per_pod: u32,
) -> u64 {
    if gpus_per_pod == 0 {
        return 0;
    }
    nodes
        .iter()
        .map(|n| &snapshot.nodes[n.index()])
        .filter(|rec| rec.healthy)
        .map(|rec| (rec.free / gpus_per_pod) as u64)
        .sum()
}

/// Builds a multi-pod placement incrementally.
pub struct PlanBuilder<'a> {
    state: &'a ClusterState,
    snapshot: &'a Snapshot,
    job: JobId,
    /// Free device indices per touched node (lazily seeded from state).
    free_devs: HashMap<NodeId, Vec<u8>>,
    pods_on_node: HashMap<NodeId, u32>,
    pods_in_group: HashMap<GroupId, u32>,
    /// GPUs taken from each group by this plan.
    group_taken: HashMap<GroupId, u32>,
    /// Topology footprint of the placed pods: O(1) feature-8 tier queries
    /// plus the per-layer deltas that drive incremental score updates.
    footprint: GangFootprint,
    /// Delta reported by the most recent successful [`PlanBuilder::place_pod`].
    last_delta: FootprintDelta,
    /// Reproduce the pre-truthful-tier scorer for ablations: tiers beyond
    /// `SameSpine` are collapsed to `SameSuperSpine`, so the scorer cannot
    /// see core-layer crossings (the historical bug, kept as a baseline).
    topo_blind: bool,
    plan: Vec<PodPlacement>,
    next_replica: u32,
    /// HBD the job is pinned to once the first pod of an HBD job lands.
    pub hbd_lock: Option<HbdId>,
}

impl<'a> PlanBuilder<'a> {
    pub fn new(
        state: &'a ClusterState,
        snapshot: &'a Snapshot,
        job: JobId,
        topo_blind: bool,
    ) -> PlanBuilder<'a> {
        PlanBuilder {
            state,
            snapshot,
            job,
            free_devs: HashMap::new(),
            pods_on_node: HashMap::new(),
            pods_in_group: HashMap::new(),
            group_taken: HashMap::new(),
            footprint: GangFootprint::new(),
            last_delta: FootprintDelta::default(),
            topo_blind,
            plan: Vec::new(),
            next_replica: 0,
            hbd_lock: None,
        }
    }

    fn free_of(&mut self, node: NodeId) -> &mut Vec<u8> {
        let state = self.state;
        self.free_devs
            .entry(node)
            .or_insert_with(|| state.node(node).free_gpu_indices())
    }

    /// Pre-seed the builder with placements already claimed by *other*
    /// plans built against the same (stale) snapshot — the claim-chaining
    /// mechanism of the sharded prefetch path. Claimed devices and group
    /// capacity become invisible to this plan, but the gang footprint,
    /// replica numbering and pod-affinity counters stay per-job: a
    /// neighbour's pods must not change this job's topology score.
    pub fn preclaim(&mut self, prior: &[PodPlacement]) {
        for p in prior {
            self.free_of(p.node).retain(|d| !p.devices.contains(d));
            let group = self.state.node(p.node).group;
            *self.group_taken.entry(group).or_default() += p.devices.len() as u32;
        }
    }

    /// Place one pod of `gpus` devices on `node`. Returns false (no
    /// mutation) if the node can't hold it under the current plan.
    pub fn place_pod(&mut self, node: NodeId, gpus: u32) -> bool {
        let gpu_type = self.state.gpu_type(self.state.node(node).gpu_type).clone();
        let free = self.free_of(node).clone();
        let Some(devices) = select_devices(&gpu_type, &free, gpus) else {
            return false;
        };
        let nic = select_nic(&gpu_type, &devices);
        self.free_of(node).retain(|d| !devices.contains(d));
        *self.pods_on_node.entry(node).or_default() += 1;
        let group = self.state.node(node).group;
        *self.pods_in_group.entry(group).or_default() += 1;
        *self.group_taken.entry(group).or_default() += gpus;
        self.last_delta = self.footprint.place(&self.state.fabric, node);
        if self.hbd_lock.is_none() {
            self.hbd_lock = self.state.node(node).hbd;
        }
        self.plan.push(PodPlacement {
            pod: PodId::new(self.job, self.next_replica),
            node,
            devices,
            nic,
        });
        self.next_replica += 1;
        true
    }

    pub fn pods_planned(&self) -> usize {
        self.plan.len()
    }

    /// The plan's topology footprint so far.
    pub fn footprint(&self) -> &GangFootprint {
        &self.footprint
    }

    /// Which topology layers the most recent placement newly entered
    /// (drives incremental score-row invalidation).
    pub fn last_delta(&self) -> FootprintDelta {
        self.last_delta
    }

    /// Consume the builder, yielding the plan for `commit_placements`.
    pub fn into_plan(self) -> Vec<PodPlacement> {
        self.plan
    }
}

impl PlanView for PlanBuilder<'_> {
    fn free_gpus(&self, node: NodeId) -> u32 {
        match self.free_devs.get(&node) {
            Some(v) => v.len() as u32,
            None => self.snapshot.nodes[node.index()].free,
        }
    }

    fn pods_on_node(&self, node: NodeId) -> u32 {
        self.pods_on_node.get(&node).copied().unwrap_or(0)
    }

    fn pods_in_group(&self, group: GroupId) -> u32 {
        self.pods_in_group.get(&group).copied().unwrap_or(0)
    }

    fn group_free(&self, group: GroupId) -> u32 {
        let base = self.snapshot.groups[group.index()].free;
        base.saturating_sub(self.group_taken.get(&group).copied().unwrap_or(0))
    }

    fn largest_free_island(&self, node: NodeId) -> u32 {
        match self.free_devs.get(&node) {
            Some(free) => {
                let gpu_type = self.state.gpu_type(self.state.node(node).gpu_type);
                gpu_type
                    .nvlink_islands
                    .iter()
                    .map(|isle| isle.iter().filter(|d| free.contains(d)).count() as u32)
                    .max()
                    .unwrap_or(0)
            }
            None => self.snapshot.nodes[node.index()].largest_free_island,
        }
    }

    fn tier_to(&self, node: NodeId) -> Tier {
        let t = self.footprint.tier_to(&self.state.fabric, node);
        if self.topo_blind {
            t.min(Tier::SameSuperSpine)
        } else {
            t
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::builder::{ClusterBuilder, ClusterSpec};
    use crate::cluster::snapshot::SnapshotMode;

    fn setup() -> (ClusterState, Snapshot) {
        let state = ClusterBuilder::build(&ClusterSpec::homogeneous("t", 1, 2, 2));
        let mut snap = Snapshot::new(SnapshotMode::DeepCopy);
        snap.refresh(&state);
        (state, snap)
    }

    #[test]
    fn plan_tracks_deltas_without_touching_state() {
        let (state, snap) = setup();
        let mut pb = PlanBuilder::new(&state, &snap, JobId(1), false);
        assert_eq!(pb.tier_to(NodeId(0)), Tier::WORST);
        assert!(pb.place_pod(NodeId(0), 4));
        assert_eq!(pb.free_gpus(NodeId(0)), 4);
        assert_eq!(pb.pods_on_node(NodeId(0)), 1);
        assert_eq!(pb.group_free(GroupId(0)), 12);
        assert_eq!(pb.tier_to(NodeId(0)), Tier::SameNode);
        assert_eq!(pb.tier_to(NodeId(1)), Tier::SameLeaf);
        assert!(pb.last_delta().first_pod);
        assert_eq!(pb.footprint().nodes_spanned(), 1);
        // State untouched until commit.
        assert_eq!(state.node(NodeId(0)).free_gpus(), 8);
    }

    #[test]
    fn pod_slots_counts_per_node_multiples() {
        let (mut state, mut snap) = setup();
        // 4 nodes × 8 GPUs: 8 slots of 4, 4 slots of 8, 0 slots of 9.
        let nodes: Vec<NodeId> = (0..4).map(NodeId).collect();
        assert_eq!(pod_slots(&snap, &nodes, 4), 8);
        assert_eq!(pod_slots(&snap, &nodes, 8), 4);
        assert_eq!(pod_slots(&snap, &nodes, 9), 0);
        assert_eq!(pod_slots(&snap, &nodes, 0), 0);
        // Partial allocation shrinks the count: 5 taken on node 0 leaves
        // 3 free there — no 4-GPU slot.
        let mut pb = PlanBuilder::new(&state, &snap, JobId(1), false);
        assert!(pb.place_pod(NodeId(0), 5));
        state.commit_placements(JobId(1), pb.into_plan()).unwrap();
        snap.refresh(&state);
        assert_eq!(pod_slots(&snap, &nodes, 4), 6);
    }

    #[test]
    fn plan_rejects_overflow() {
        let (state, snap) = setup();
        let mut pb = PlanBuilder::new(&state, &snap, JobId(1), false);
        assert!(pb.place_pod(NodeId(0), 8));
        assert!(!pb.place_pod(NodeId(0), 1));
        assert_eq!(pb.pods_planned(), 1);
    }

    #[test]
    fn committed_plan_matches_builder() {
        let (mut state, snap) = setup();
        let mut pb = PlanBuilder::new(&state, &snap, JobId(1), false);
        assert!(pb.place_pod(NodeId(1), 2));
        assert!(pb.place_pod(NodeId(2), 8));
        let plan = pb.into_plan();
        assert_eq!(plan.len(), 2);
        assert_eq!(plan[0].pod, PodId::new(JobId(1), 0));
        assert_eq!(plan[1].pod, PodId::new(JobId(1), 1));
        state.commit_placements(JobId(1), plan).unwrap();
        assert_eq!(state.allocated_gpus(), 10);
    }

    #[test]
    fn preclaim_hides_devices_without_touching_footprint() {
        let (state, snap) = setup();
        let mut prior = PlanBuilder::new(&state, &snap, JobId(1), false);
        assert!(prior.place_pod(NodeId(0), 6));
        let claimed = prior.into_plan();

        let mut pb = PlanBuilder::new(&state, &snap, JobId(2), false);
        pb.preclaim(&claimed);
        // Node 0 has only 2 free devices under the claim; group capacity
        // shrinks too — but the footprint and pod counters stay this-job.
        assert_eq!(pb.free_gpus(NodeId(0)), 2);
        assert_eq!(pb.group_free(GroupId(0)), 10);
        assert_eq!(pb.pods_on_node(NodeId(0)), 0);
        assert_eq!(pb.tier_to(NodeId(0)), Tier::WORST);
        assert!(!pb.place_pod(NodeId(0), 4));
        assert!(pb.place_pod(NodeId(0), 2));
        // Replica numbering starts at 0 for this job despite the claims.
        let plan = pb.into_plan();
        assert_eq!(plan[0].pod, PodId::new(JobId(2), 0));
        assert!(plan[0].devices.iter().all(|d| !claimed[0].devices.contains(d)));
    }

    #[test]
    fn island_tracking_under_plan() {
        let (state, snap) = setup();
        let mut pb = PlanBuilder::new(&state, &snap, JobId(1), false);
        assert_eq!(pb.largest_free_island(NodeId(0)), 8);
        pb.place_pod(NodeId(0), 5);
        assert_eq!(pb.largest_free_island(NodeId(0)), 3);
    }

    #[test]
    fn blind_plan_collapses_cross_superspine() {
        // 2 spines, 1 superspine each: nodes under different spines are
        // CrossSuperSpine truthfully, SameSuperSpine when blind.
        let mut spec = ClusterSpec::homogeneous("b", 2, 1, 2);
        spec.spines_per_superspine = 1;
        let state = ClusterBuilder::build(&spec);
        let mut snap = Snapshot::new(SnapshotMode::DeepCopy);
        snap.refresh(&state);
        let mut truthful = PlanBuilder::new(&state, &snap, JobId(1), false);
        let mut blind = PlanBuilder::new(&state, &snap, JobId(2), true);
        assert!(truthful.place_pod(NodeId(0), 8));
        assert!(blind.place_pod(NodeId(0), 8));
        assert_eq!(truthful.tier_to(NodeId(2)), Tier::CrossSuperSpine);
        assert_eq!(blind.tier_to(NodeId(2)), Tier::SameSuperSpine);
        // Tiers at or below SameSpine are untouched by blindness.
        assert_eq!(blind.tier_to(NodeId(1)), Tier::SameLeaf);
    }
}
