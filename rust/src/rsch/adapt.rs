//! Adaptive multi-objective weight control (the dynamic counterpart of
//! the hand-tuned [`score`](super::score) tables).
//!
//! The static E-Binpack/E-Spread rows balance utilization (GAR),
//! fragmentation (GFR) and waiting time (JWTD) with constants retuned by
//! hand; under mixed tenant pressure a fixed mix either over-packs
//! (fragmenting large gangs) or over-spreads (starving low-priority tidal
//! work). Following the dynamic multi-objective scheduling line of work,
//! [`WeightController`] turns the mix into a deterministic feedback loop:
//! once per QSCH cycle it reads rolling-window GFR/GAR/per-class JWTD
//! signals and shifts a *bounded, quantized* [`WeightOverlay`] between
//! packing, spreading, and a fairness term — with hysteresis dead bands
//! and a ±1-step-per-tick clamp so same-seed runs replay byte-identical
//! digests, and the hand-tuned table untouched as the frozen `--no-adapt`
//! baseline.
//!
//! Determinism contract: the controller state is two small integers
//! (`pack_steps`, `fairness_steps`); overlay floats are derived from them
//! by constant multiplication, never accumulated, so there is no
//! float-drift path. Ticks happen only in the single-threaded QSCH phase
//! (`sim::runner`), and shard workers see the overlay through a cloned
//! [`RschConfig`](super::RschConfig) — which is why `--shards N` digests
//! stay byte-identical for every N.

use crate::job::spec::Priority;
use crate::job::state::Phase as JobPhase;
use crate::job::store::JobStore;
use crate::metrics::Metrics;
use crate::util::stats::percentile_sorted;

use super::score::{GROUP_COMPONENTS, NUM_COMPONENTS};

/// Rolling observation window for the controller's signals (2 h): long
/// enough to smooth cycle-level noise, short enough to track tidal shifts.
pub const ADAPT_WINDOW_MS: u64 = 2 * 3_600_000;

/// Packing-axis quantum: one `pack_steps` unit moves `fill` up and
/// `spread` down by this much (symmetric, so the axis is packing↔spread).
const PACK_STEP: f32 = 0.05;

/// Fairness-axis quantum per `fairness_steps` unit.
const FAIR_STEP: f32 = 0.125;

/// FNV-1a offset/prime — the same hash family as the digest fingerprint.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Controller tunables. `Default` is **disabled**: the scorer runs the
/// frozen hand-tuned table bitwise-unchanged unless `--adapt` opts in.
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptConfig {
    /// Master switch. Off = the PR-5 frozen table, byte-for-byte.
    pub enabled: bool,
    /// Seeds the telemetry fingerprint so adaptive runs are attributable
    /// to their workload seed in the digest.
    pub seed: u64,
    /// GFR setpoint the packing axis regulates around.
    pub gfr_target: f64,
    /// Hysteresis dead band around the setpoint: no packing-axis movement
    /// while `|gfr - gfr_target| <= gfr_band`.
    pub gfr_band: f64,
    /// Packing-axis clamp: `pack_steps` stays in `[-max, +max]`.
    pub max_pack_steps: i16,
    /// Fairness-axis clamp: `fairness_steps` stays in `[0, max]`.
    pub max_fairness_steps: i16,
    /// Per-priority-class (LOW/NORMAL/HIGH) hard anti-starvation bound on
    /// rolling JWTD p99; 0 disables the bound for that class. Mirrors
    /// `QschConfig::max_jwtd_p99_ms` — here it drives the fairness axis,
    /// there it drives the reserved-capacity escalation.
    pub jwtd_bound_ms: [u64; Priority::NUM_CLASSES],
}

impl Default for AdaptConfig {
    fn default() -> Self {
        AdaptConfig {
            enabled: false,
            seed: 0,
            gfr_target: 0.06,
            gfr_band: 0.02,
            max_pack_steps: 5,
            max_fairness_steps: 8,
            jwtd_bound_ms: [0; Priority::NUM_CLASSES],
        }
    }
}

/// Bounded additive shift applied on top of the static weight tables.
/// Derived from quantized controller state by constant multiplication —
/// never accumulated in float space.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WeightOverlay {
    /// Packing↔spreading bias: positive packs harder (`fill` up, `spread`
    /// down), negative spreads harder. In `[-max_pack_steps·PACK_STEP,
    /// +max_pack_steps·PACK_STEP]`.
    pub pack_bias: f32,
    /// The fairness term: consolidation pressure that keeps contiguous
    /// group capacity whole so aged large gangs can still place. In
    /// `[0, max_fairness_steps·FAIR_STEP]`.
    pub fairness: f32,
}

impl WeightOverlay {
    /// True when the overlay is the identity (the frozen-table case).
    pub fn is_zero(&self) -> bool {
        self.pack_bias == 0.0 && self.fairness == 0.0
    }

    /// Shift a node-weight row: packing bias moves fill↔spread; the
    /// fairness term raises `group_pack` and damps `group_empty` so
    /// small work consolidates into already-used groups instead of
    /// nibbling the empty ones starving gangs need. The topology
    /// component (`W_TOPO`) is never touched — the pooled-gang gate and
    /// tier semantics stay exactly the static table's.
    pub fn apply_node(&self, w: &mut [f32; NUM_COMPONENTS]) {
        w[0] += self.pack_bias; // fill
        w[1] -= self.pack_bias; // spread
        w[2] += self.fairness; // group_pack
        w[3] -= 0.5 * self.fairness; // group_empty
    }

    /// Shift a group-weight row (same fairness semantics at group
    /// granularity: prefer packed groups, spare the empty ones).
    pub fn apply_group(&self, w: &mut [f32; GROUP_COMPONENTS]) {
        w[0] += self.fairness; // pack
        w[1] -= 0.5 * self.fairness; // empty
    }
}

/// Rolling-window observations the controller consumes each tick. Plain
/// data so shard workers and benches can synthesize them.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AdaptSignals {
    /// Time-weighted GPU allocation ratio over the window.
    pub gar: f64,
    /// Time-weighted GPU fragmentation ratio over the window.
    pub gfr: f64,
    /// Rolling JWTD p99 per priority class (LOW/NORMAL/HIGH), censored:
    /// still-queued jobs count their wait up to `now`.
    pub class_p99_wait_ms: [f64; Priority::NUM_CLASSES],
}

/// Controller telemetry — surfaced in the sim digest so adaptive runs are
/// distinguishable (and replayable) at a glance.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdaptStats {
    pub ticks: u64,
    /// Packing-axis movements (either direction).
    pub pack_shifts: u64,
    /// Fairness escalations (a bounded class's rolling p99 over bound).
    pub escalations: u64,
    /// Fairness releases (every bounded class back under half its bound).
    pub releases: u64,
    /// FNV-1a over the seed and every tick's quantized state: two runs
    /// with equal fingerprints replayed the same control trajectory.
    pub fingerprint: u64,
}

/// The seeded deterministic weight controller (see module docs).
#[derive(Debug, Clone)]
pub struct WeightController {
    cfg: AdaptConfig,
    /// Quantized packing axis in `[-max_pack_steps, +max_pack_steps]`.
    pack_steps: i16,
    /// Quantized fairness axis in `[0, max_fairness_steps]`.
    fairness_steps: i16,
    pub stats: AdaptStats,
}

impl WeightController {
    pub fn new(cfg: AdaptConfig) -> WeightController {
        let fingerprint = FNV_OFFSET ^ cfg.seed;
        WeightController {
            cfg,
            pack_steps: 0,
            fairness_steps: 0,
            stats: AdaptStats {
                fingerprint,
                ..AdaptStats::default()
            },
        }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// Current overlay (identity until the first tick moves an axis).
    pub fn overlay(&self) -> WeightOverlay {
        WeightOverlay {
            pack_bias: f32::from(self.pack_steps) * PACK_STEP,
            fairness: f32::from(self.fairness_steps) * FAIR_STEP,
        }
    }

    /// One controller tick (call once per QSCH cycle, single-threaded).
    /// Each axis moves at most one quantum per tick (step clamping), and
    /// only outside its hysteresis band — so the trajectory is a pure
    /// function of the signal sequence.
    pub fn tick(&mut self, s: &AdaptSignals) -> WeightOverlay {
        self.stats.ticks += 1;

        // Packing axis: negative feedback on fragmentation. Above the
        // band, pack harder (consolidation reduces GFR); below it, relax
        // toward — and on a busy cluster (GAR >= 0.5) beyond — the
        // baseline into spreading. An idle cluster's near-zero GFR must
        // not drive a spread bias, so the negative half is utilization-
        // gated.
        if s.gfr > self.cfg.gfr_target + self.cfg.gfr_band {
            if self.pack_steps < self.cfg.max_pack_steps {
                self.pack_steps += 1;
                self.stats.pack_shifts += 1;
            }
        } else if s.gfr < self.cfg.gfr_target - self.cfg.gfr_band {
            let floor = if s.gar >= 0.5 {
                -self.cfg.max_pack_steps
            } else {
                0
            };
            if self.pack_steps > floor {
                self.pack_steps -= 1;
                self.stats.pack_shifts += 1;
            }
        }

        // Fairness axis: escalate while any bounded class's rolling p99
        // wait exceeds its bound; release only when every bounded class
        // is back under half its bound (the hysteresis band between
        // bound/2 and bound holds the current level).
        let mut over = false;
        let mut all_clear = true;
        for (c, &bound) in self.cfg.jwtd_bound_ms.iter().enumerate() {
            if bound == 0 {
                continue;
            }
            let p99 = s.class_p99_wait_ms[c];
            if p99 > bound as f64 {
                over = true;
            }
            if 2.0 * p99 > bound as f64 {
                all_clear = false;
            }
        }
        if over {
            if self.fairness_steps < self.cfg.max_fairness_steps {
                self.fairness_steps += 1;
                self.stats.escalations += 1;
            }
        } else if all_clear && self.fairness_steps > 0 {
            self.fairness_steps -= 1;
            self.stats.releases += 1;
        }

        // Fold the post-tick quantized state into the fingerprint.
        let mut h = self.stats.fingerprint;
        h = (h ^ (self.pack_steps as u16 as u64)).wrapping_mul(FNV_PRIME);
        h = (h ^ (self.fairness_steps as u16 as u64)).wrapping_mul(FNV_PRIME);
        self.stats.fingerprint = h;

        self.overlay()
    }
}

/// Assemble the controller's rolling-window signals from the metrics'
/// accessors plus a censored scan of still-waiting jobs. Queued and
/// preempted jobs contribute their wait-so-far, so a starving class is
/// visible *before* its jobs ever schedule — the property the hard
/// anti-starvation bound depends on. Samples are sorted before the
/// percentile, so the store's hash-order iteration cannot perturb the
/// result.
pub fn collect_signals(now: u64, metrics: &Metrics, store: &JobStore) -> AdaptSignals {
    let t0 = now.saturating_sub(ADAPT_WINDOW_MS);
    let mut waits: [Vec<f64>; Priority::NUM_CLASSES] = Default::default();
    for (c, w) in waits.iter_mut().enumerate() {
        *w = metrics.class_wait_samples_between(c, t0, now);
    }
    for j in store.iter() {
        if matches!(j.phase, JobPhase::Queued | JobPhase::Preempted) {
            waits[j.spec.priority.class_index()].push(j.waiting_ms(now) as f64);
        }
    }
    let mut class_p99_wait_ms = [0.0; Priority::NUM_CLASSES];
    for (c, w) in waits.iter_mut().enumerate() {
        w.sort_by(|a, b| a.partial_cmp(b).expect("waits are finite"));
        class_p99_wait_ms[c] = percentile_sorted(w, 0.99);
    }
    AdaptSignals {
        gar: metrics.gar_avg_between(t0, now),
        gfr: metrics.gfr_avg_between(t0, now),
        class_p99_wait_ms,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enabled_cfg() -> AdaptConfig {
        AdaptConfig {
            enabled: true,
            seed: 7,
            jwtd_bound_ms: [6 * 3_600_000; Priority::NUM_CLASSES],
            ..AdaptConfig::default()
        }
    }

    fn sig(gar: f64, gfr: f64, p99_h: f64) -> AdaptSignals {
        AdaptSignals {
            gar,
            gfr,
            class_p99_wait_ms: [p99_h * 3_600_000.0; Priority::NUM_CLASSES],
        }
    }

    #[test]
    fn default_is_disabled_identity() {
        let c = WeightController::new(AdaptConfig::default());
        assert!(!c.enabled());
        assert!(c.overlay().is_zero());
    }

    #[test]
    fn packing_axis_steps_and_clamps() {
        let mut c = WeightController::new(enabled_cfg());
        // High fragmentation: one quantum per tick up to the clamp.
        for i in 1..=7 {
            c.tick(&sig(0.9, 0.5, 0.0));
            let expect = i.min(5);
            assert_eq!(c.overlay().pack_bias, expect as f32 * PACK_STEP);
        }
        assert_eq!(c.stats.pack_shifts, 5);
        // Low fragmentation on a busy cluster: walk down past zero.
        for _ in 0..12 {
            c.tick(&sig(0.9, 0.0, 0.0));
        }
        assert_eq!(c.overlay().pack_bias, -5.0 * PACK_STEP);
    }

    #[test]
    fn idle_cluster_never_gets_spread_bias() {
        let mut c = WeightController::new(enabled_cfg());
        for _ in 0..10 {
            c.tick(&sig(0.1, 0.0, 0.0));
        }
        assert_eq!(c.overlay().pack_bias, 0.0);
    }

    #[test]
    fn dead_band_holds_the_axis() {
        let mut c = WeightController::new(enabled_cfg());
        c.tick(&sig(0.9, 0.5, 0.0));
        let level = c.overlay().pack_bias;
        assert!(level > 0.0);
        // Inside the band: no movement either way.
        for _ in 0..5 {
            c.tick(&sig(0.9, 0.06, 0.0));
        }
        assert_eq!(c.overlay().pack_bias, level);
    }

    #[test]
    fn fairness_escalates_on_bound_breach_and_releases_under_half() {
        let mut c = WeightController::new(enabled_cfg());
        // p99 of 7h > 6h bound: escalate.
        c.tick(&sig(0.9, 0.06, 7.0));
        assert_eq!(c.overlay().fairness, FAIR_STEP);
        assert_eq!(c.stats.escalations, 1);
        // 4h is inside the (3h, 6h] hysteresis band: hold.
        c.tick(&sig(0.9, 0.06, 4.0));
        assert_eq!(c.overlay().fairness, FAIR_STEP);
        // 2h < bound/2: release back to zero.
        c.tick(&sig(0.9, 0.06, 2.0));
        assert_eq!(c.overlay().fairness, 0.0);
        assert_eq!(c.stats.releases, 1);
    }

    #[test]
    fn unbounded_classes_are_ignored() {
        let mut c = WeightController::new(AdaptConfig {
            jwtd_bound_ms: [0; Priority::NUM_CLASSES],
            ..enabled_cfg()
        });
        c.tick(&sig(0.9, 0.06, 100.0));
        assert_eq!(c.overlay().fairness, 0.0);
        assert_eq!(c.stats.escalations, 0);
    }

    #[test]
    fn fairness_clamps_at_max() {
        let mut c = WeightController::new(enabled_cfg());
        for _ in 0..20 {
            c.tick(&sig(0.9, 0.06, 100.0));
        }
        assert_eq!(c.overlay().fairness, 8.0 * FAIR_STEP);
        assert_eq!(c.stats.escalations, 8);
    }

    #[test]
    fn same_signal_sequence_replays_the_same_trajectory() {
        let seq: Vec<AdaptSignals> = (0..50)
            .map(|i| sig(0.5 + 0.4 * ((i % 3) as f64 / 2.0), (i % 7) as f64 * 0.04, (i % 11) as f64))
            .collect();
        let run = || {
            let mut c = WeightController::new(enabled_cfg());
            let overlays: Vec<WeightOverlay> = seq.iter().map(|s| c.tick(s)).collect();
            (overlays, c.stats)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn fingerprint_tracks_seed_and_trajectory() {
        let mut a = WeightController::new(enabled_cfg());
        let mut b = WeightController::new(AdaptConfig {
            seed: 8,
            ..enabled_cfg()
        });
        for _ in 0..3 {
            a.tick(&sig(0.9, 0.5, 0.0));
            b.tick(&sig(0.9, 0.5, 0.0));
        }
        assert_ne!(a.stats.fingerprint, b.stats.fingerprint);
    }

    #[test]
    fn overlay_moves_only_the_documented_components() {
        let o = WeightOverlay {
            pack_bias: 0.2,
            fairness: 0.5,
        };
        let mut w = [0.0f32; NUM_COMPONENTS];
        o.apply_node(&mut w);
        assert_eq!(w[0], 0.2);
        assert_eq!(w[1], -0.2);
        assert_eq!(w[2], 0.5);
        assert_eq!(w[3], -0.25);
        // Topology, colocation, zone and NVLink are never shifted.
        assert_eq!(&w[4..], &[0.0; 4]);
        let mut g = [0.0f32; GROUP_COMPONENTS];
        o.apply_group(&mut g);
        assert_eq!(g[0], 0.5);
        assert_eq!(g[1], -0.25);
        assert_eq!(&g[2..], &[0.0; 4]);
    }
}
