//! Fine-grained device selection (§3.3.1 + §3.3.5 intra-node topology):
//! given a node and a GPU count, pick the exact devices — preferring the
//! *smallest NVLink island that fits* (best-fit, which preserves large
//! islands for future multi-GPU pods) — and pair the pod with the NIC
//! serving the majority of the chosen devices.

use crate::cluster::gpu::GpuType;

/// Choose `count` device indices from `free` (free device indices on the
/// node) honouring NVLink islands. Returns `None` if `free.len() < count`.
///
/// Policy:
/// 1. Best-fit island: the island with the fewest free devices that still
///    holds `count` — keeps big islands intact.
/// 2. If no single island fits, take whole islands smallest-first and
///    top up from the next (cross-island placement is allowed but last).
pub fn select_devices(gpu_type: &GpuType, free: &[u8], count: u32) -> Option<Vec<u8>> {
    let count = count as usize;
    if free.len() < count || count == 0 {
        return if count == 0 { Some(Vec::new()) } else { None };
    }

    // Free devices per island, in island order.
    let mut islands: Vec<Vec<u8>> = gpu_type
        .nvlink_islands
        .iter()
        .map(|isle| {
            isle.iter()
                .copied()
                .filter(|d| free.contains(d))
                .collect::<Vec<u8>>()
        })
        .collect();
    // Devices outside any island (defensive; shouldn't happen).
    let stray: Vec<u8> = free
        .iter()
        .copied()
        .filter(|d| gpu_type.island_of(*d).is_none())
        .collect();
    if !stray.is_empty() {
        islands.push(stray);
    }

    // 1. Best-fit single island.
    let fit = islands
        .iter()
        .filter(|i| i.len() >= count)
        .min_by_key(|i| (i.len(), i.first().copied().unwrap_or(255)));
    if let Some(isle) = fit {
        return Some(isle[..count].to_vec());
    }

    // 2. Combine islands, smallest first (consume fragments, preserve the
    //    biggest contiguous capacity).
    let mut order: Vec<usize> = (0..islands.len()).collect();
    order.sort_by_key(|&i| (islands[i].len(), i));
    let mut picked = Vec::with_capacity(count);
    for i in order {
        for &d in &islands[i] {
            if picked.len() == count {
                break;
            }
            picked.push(d);
        }
        if picked.len() == count {
            break;
        }
    }
    debug_assert_eq!(picked.len(), count);
    Some(picked)
}

/// The NIC index to pair with a device set: the NIC serving the most
/// selected devices (ties → lowest NIC index).
pub fn select_nic(gpu_type: &GpuType, devices: &[u8]) -> u8 {
    if devices.is_empty() {
        return 0;
    }
    let mut counts = vec![0u32; gpu_type.nics_per_node as usize];
    for &d in devices {
        counts[gpu_type.nic_for_gpu(d) as usize] += 1;
    }
    counts
        .iter()
        .enumerate()
        .max_by_key(|&(i, c)| (*c, std::cmp::Reverse(i)))
        .map(|(i, _)| i as u8)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ids::GpuTypeId;

    #[test]
    fn whole_island_board_takes_prefix() {
        let t = GpuType::type_h(GpuTypeId(0));
        let free: Vec<u8> = (0..8).collect();
        assert_eq!(select_devices(&t, &free, 4).unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(select_devices(&t, &free, 8).unwrap().len(), 8);
    }

    #[test]
    fn best_fit_prefers_smaller_island() {
        let t = GpuType::type_l(GpuTypeId(0)); // Quads [0-3], [4-7].
        // Quad0 has 2 free, quad1 has 4 free.
        let free = vec![2, 3, 4, 5, 6, 7];
        // A 2-GPU pod should take the 2-free quad, preserving the full quad.
        assert_eq!(select_devices(&t, &free, 2).unwrap(), vec![2, 3]);
        // A 4-GPU pod needs the intact quad.
        assert_eq!(select_devices(&t, &free, 4).unwrap(), vec![4, 5, 6, 7]);
    }

    #[test]
    fn cross_island_when_no_single_island_fits() {
        let t = GpuType::type_l(GpuTypeId(0));
        let free = vec![0, 1, 4, 5, 6]; // 2 + 3 free.
        let picked = select_devices(&t, &free, 5).unwrap();
        assert_eq!(picked.len(), 5);
        // Smallest island consumed first.
        assert!(picked.contains(&0) && picked.contains(&1));
    }

    #[test]
    fn insufficient_free_is_none() {
        let t = GpuType::type_h(GpuTypeId(0));
        assert!(select_devices(&t, &[1, 2], 3).is_none());
        assert_eq!(select_devices(&t, &[], 0).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn nic_pairing_majority() {
        let t = GpuType::type_h(GpuTypeId(0)); // 2 GPUs per NIC.
        assert_eq!(select_nic(&t, &[0, 1]), 0);
        assert_eq!(select_nic(&t, &[6, 7]), 3);
        assert_eq!(select_nic(&t, &[0, 2, 3]), 1); // NIC1 serves 2 of 3.
        assert_eq!(select_nic(&t, &[0, 2]), 0); // Tie → lowest.
    }
}
