//! Feature extraction: cluster snapshot + in-flight placement plan → the
//! dense `[N, NODE_F]` / `[G, GROUP_F]` matrices of the scoring contract.
//!
//! This is the single definition both scorer backends consume — the native
//! Rust scorer and the XLA artifact see byte-identical features, which is
//! what makes the parity tests meaningful. The layout mirrors
//! `python/compile/kernels/ref.py`; keep them in lockstep.

use crate::cluster::ids::{GroupId, NodeId};
use crate::cluster::snapshot::Snapshot;
use crate::cluster::topology::Tier;
use crate::job::spec::{JobKind, JobSpec, PlacementStrategy};

/// Node feature indices (see ref.py for semantics).
pub const NODE_F: usize = 12;
pub const F_FREE: usize = 0;
pub const F_TOTAL: usize = 1;
pub const F_ALLOC: usize = 2;
pub const F_HEALTHY: usize = 3;
pub const F_GROUP_FREE: usize = 4;
pub const F_GROUP_TOTAL: usize = 5;
pub const F_PODS_ON_NODE: usize = 6;
pub const F_PODS_IN_GROUP: usize = 7;
/// Minimum communication tier to the job's already-placed pods: 0 node /
/// 1 leaf / 2 spine / 3 superspine / 4 cross-superspine, and 4
/// ([`Tier::WORST`]) while nothing is placed. Scorers normalize by
/// `clamp(0, 4) / 4`.
pub const F_TOPO_TIER: usize = 8;
pub const F_IN_ZONE: usize = 9;
pub const F_HBD_FREE: usize = 10;
pub const F_NVLINK_CLIQUE: usize = 11;

/// Group feature indices.
pub const GROUP_F: usize = 6;
pub const GF_FREE: usize = 0;
pub const GF_TOTAL: usize = 1;
pub const GF_PODS_IN_GROUP: usize = 2;
pub const GF_ZONE_FRAC: usize = 3;
pub const GF_HEALTHY_FRAC: usize = 4;
pub const GF_WHOLE_FREE: usize = 5;

/// Job descriptor layout.
pub const JOB_D: usize = 8;

/// Dynamic per-plan deltas tracked while building a placement (the
/// authoritative state is only mutated at commit).
pub trait PlanView {
    /// Free healthy GPUs on the node, minus devices taken by this plan.
    fn free_gpus(&self, node: NodeId) -> u32;
    /// This job's pods placed on the node so far.
    fn pods_on_node(&self, node: NodeId) -> u32;
    /// This job's pods placed in the group so far.
    fn pods_in_group(&self, group: GroupId) -> u32;
    /// Group free GPUs minus this plan's takings.
    fn group_free(&self, group: GroupId) -> u32;
    /// Largest free NVLink island on the node under this plan.
    fn largest_free_island(&self, node: NodeId) -> u32;
    /// Minimum communication tier from `node` to this plan's already-
    /// placed pods ([`Tier::WORST`] while the plan is empty) — feature 8.
    /// Implementations answer in O(1) from an incrementally-maintained
    /// [`crate::cluster::topology::GangFootprint`], not a per-pod scan.
    fn tier_to(&self, node: NodeId) -> Tier;
}

/// Encode the job descriptor for the scorers.
pub fn job_descriptor(spec: &JobSpec, gpus_per_pod: u32) -> [f32; JOB_D] {
    let strategy_id = match spec.strategy {
        Some(PlacementStrategy::NativeFirstFit) => 0.0,
        Some(PlacementStrategy::Binpack) => 1.0,
        Some(PlacementStrategy::EBinpack) | None => 2.0,
        Some(PlacementStrategy::Spread) => 3.0,
        Some(PlacementStrategy::ESpread) => 4.0,
    };
    [
        gpus_per_pod as f32,
        spec.total_gpus() as f32,
        if spec.gang { 1.0 } else { 0.0 },
        if spec.kind == JobKind::Inference { 1.0 } else { 0.0 },
        if gpus_per_pod >= 8 { 1.0 } else { 0.0 },
        strategy_id,
        if spec.needs_hbd { 1.0 } else { 0.0 },
        0.0,
    ]
}

/// Build the node feature matrix (row-major `[candidates.len(), NODE_F]`)
/// for the given candidates under an in-flight plan.
pub fn node_features(
    snapshot: &Snapshot,
    plan: &dyn PlanView,
    candidates: &[NodeId],
) -> Vec<f32> {
    let mut out = Vec::with_capacity(candidates.len() * NODE_F);
    for &n in candidates {
        let rec = &snapshot.nodes[n.index()];
        let grec = &snapshot.groups[rec.group.index()];
        let free = plan.free_gpus(n);
        let alloc = rec.total - free;
        out.extend_from_slice(&[
            free as f32,
            rec.total as f32,
            alloc as f32,
            if rec.healthy { 1.0 } else { 0.0 },
            plan.group_free(rec.group) as f32,
            grec.total as f32,
            plan.pods_on_node(n) as f32,
            plan.pods_in_group(rec.group) as f32,
            plan.tier_to(n).as_f32(),
            if rec.in_inference_zone { 1.0 } else { 0.0 },
            rec.hbd_free as f32,
            plan.largest_free_island(n) as f32,
        ]);
    }
    out
}

/// Build the group feature matrix for the given groups under a plan.
pub fn group_features(
    snapshot: &Snapshot,
    plan: &dyn PlanView,
    groups: &[GroupId],
) -> Vec<f32> {
    let mut out = Vec::with_capacity(groups.len() * GROUP_F);
    for &g in groups {
        let rec = &snapshot.groups[g.index()];
        out.extend_from_slice(&[
            plan.group_free(g) as f32,
            rec.total as f32,
            plan.pods_in_group(g) as f32,
            rec.zone_frac,
            rec.healthy_frac,
            rec.whole_free_nodes as f32,
        ]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::builder::{ClusterBuilder, ClusterSpec};
    use crate::cluster::ids::{GpuTypeId, JobId, TenantId};
    use crate::cluster::snapshot::SnapshotMode;

    /// A no-delta plan view (fresh plan, nothing placed yet).
    pub struct EmptyPlan<'a> {
        pub snapshot: &'a Snapshot,
    }

    impl PlanView for EmptyPlan<'_> {
        fn free_gpus(&self, node: NodeId) -> u32 {
            self.snapshot.nodes[node.index()].free
        }
        fn pods_on_node(&self, _: NodeId) -> u32 {
            0
        }
        fn pods_in_group(&self, _: GroupId) -> u32 {
            0
        }
        fn group_free(&self, group: GroupId) -> u32 {
            self.snapshot.groups[group.index()].free
        }
        fn largest_free_island(&self, node: NodeId) -> u32 {
            self.snapshot.nodes[node.index()].largest_free_island
        }
        fn tier_to(&self, _: NodeId) -> Tier {
            Tier::WORST
        }
    }

    #[test]
    fn fresh_cluster_features() {
        let state = ClusterBuilder::build(&ClusterSpec::homogeneous("t", 1, 2, 2));
        let mut snap = Snapshot::new(SnapshotMode::DeepCopy);
        snap.refresh(&state);
        let plan = EmptyPlan { snapshot: &snap };
        let cands: Vec<NodeId> = (0..4).map(NodeId).collect();
        let feat = node_features(&snap, &plan, &cands);
        assert_eq!(feat.len(), 4 * NODE_F);
        // Row 0: all free, healthy, tier 4 = WORST (nothing placed).
        assert_eq!(feat[F_FREE], 8.0);
        assert_eq!(feat[F_ALLOC], 0.0);
        assert_eq!(feat[F_HEALTHY], 1.0);
        assert_eq!(feat[F_GROUP_FREE], 16.0);
        assert_eq!(feat[F_TOPO_TIER], 4.0);
        assert_eq!(feat[F_NVLINK_CLIQUE], 8.0);
    }

    #[test]
    fn group_features_shape() {
        let state = ClusterBuilder::build(&ClusterSpec::homogeneous("t", 1, 2, 2));
        let mut snap = Snapshot::new(SnapshotMode::DeepCopy);
        snap.refresh(&state);
        let plan = EmptyPlan { snapshot: &snap };
        let gs: Vec<GroupId> = vec![GroupId(0), GroupId(1)];
        let gf = group_features(&snap, &plan, &gs);
        assert_eq!(gf.len(), 2 * GROUP_F);
        assert_eq!(gf[GF_FREE], 16.0);
        assert_eq!(gf[GF_WHOLE_FREE], 2.0);
        assert_eq!(gf[GF_HEALTHY_FRAC], 1.0);
    }

    #[test]
    fn job_descriptor_encodes_strategy_and_kind() {
        let mut spec = crate::job::spec::JobSpec::homogeneous(
            JobId(1),
            TenantId(0),
            crate::job::spec::JobKind::Inference,
            GpuTypeId(0),
            4,
            2,
        );
        spec.strategy = Some(PlacementStrategy::ESpread);
        let d = job_descriptor(&spec, 2);
        assert_eq!(d[0], 2.0);
        assert_eq!(d[1], 8.0);
        assert_eq!(d[2], 0.0); // Non-gang.
        assert_eq!(d[3], 1.0); // Inference.
        assert_eq!(d[4], 0.0); // Not whole-node.
        assert_eq!(d[5], 4.0); // E-Spread.
    }
}
