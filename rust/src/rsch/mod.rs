//! RSCH — the Resource-aware Scheduler (§3.3): fine-grained device-level
//! placement with Gang semantics, Binpack/E-Binpack, Spread/E-Spread,
//! topology awareness, and the §3.4 performance mechanisms (GPU-type node
//! pools, two-level NodeNetGroup scheduling, incremental snapshots).
//!
//! The per-pod scoring hot-spot runs through a pluggable [`ScoreBackend`]:
//! the pure-Rust [`NativeBackend`] or the AOT-compiled XLA artifact
//! (`runtime::XlaBackend`) — both consume identical feature matrices.

pub mod adapt;
pub mod defrag;
pub mod device_alloc;
pub mod features;
pub mod plan;
pub mod score;

use std::collections::BTreeMap;

use crate::cluster::ids::{GpuTypeId, GroupId, JobId, NodeId};
use crate::cluster::index::ZoneQuery;
use crate::cluster::shard::ShardMap;
use crate::cluster::snapshot::{Snapshot, SnapshotMode};
use crate::cluster::state::{ClusterState, PodPlacement};
use crate::job::spec::{JobKind, JobSpec, PlacementStrategy, TypedDemand};
use crate::qsch::{PlaceFailure, Placer};

use adapt::{AdaptConfig, AdaptSignals, WeightController, WeightOverlay};
use features::{group_features, job_descriptor, node_features, NODE_F};
use plan::PlanBuilder;
use score::{
    argmax, feasible, group_weights, is_large_job, node_weights, NativeBackend, Phase,
    ScoreBackend, W_TOPO,
};

/// How multi-pod jobs are scored across their pods (the §3.3 gang hot
/// path). The modes are placement-identical between `PooledRebuild` and
/// `PooledIncremental` (property-tested); they differ only in how many
/// feature rows are rebuilt per pod (`RschStats::nodes_scored`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GangScoring {
    /// Re-select candidates and rebuild the full feature matrix for every
    /// pod (the historical baseline: O(pods · candidates) feature rows
    /// per gang, with a fresh group preselect per pod).
    PerPodRescan,
    /// Freeze one candidate region sized to the whole gang's demand, but
    /// still rebuild every feature row for every pod (the ablation arm
    /// isolating the incremental-update win).
    PooledRebuild,
    /// Freeze the region once and re-extract only the rows the previous
    /// pod's placement invalidated — the placed node, its NodeNetGroup,
    /// and any topology layer the gang newly entered (tracked by the
    /// plan's [`crate::cluster::topology::GangFootprint`] delta). The
    /// default.
    PooledIncremental,
}

/// RSCH tunables.
#[derive(Debug, Clone)]
pub struct RschConfig {
    /// Default strategy per job kind when the spec doesn't pin one.
    pub training_strategy: PlacementStrategy,
    pub inference_strategy: PlacementStrategy,
    pub dev_strategy: PlacementStrategy,
    /// Two-level (group-preselect) scheduling (§3.4.2). Off = flat scan of
    /// the whole pool (the ablation baseline).
    pub two_level: bool,
    /// Snapshot refresh mode (§3.4.3).
    pub snapshot_mode: SnapshotMode,
    /// Groups to try per pod in two-level mode (top-K preselection).
    pub group_fanout: usize,
    /// Sublinear candidate selection through the snapshot's incremental
    /// free-capacity [`NodeIndex`](crate::cluster::index::NodeIndex):
    /// walk only buckets with `free >= gpus_per_pod` instead of scanning
    /// every node. Off = the linear scan (the ablation baseline).
    /// Placements are identical either way (property-tested).
    pub indexed_candidates: bool,
    /// Gang scoring mode (see [`GangScoring`]). Only strategies with a
    /// live topology component (`w[W_TOPO] != 0`) and single-phase,
    /// non-HBD demands take the pooled paths; everything else keeps the
    /// per-pod walk, so Binpack / Spread / first-fit placements are
    /// byte-identical across all three modes.
    pub gang_scoring: GangScoring,
    /// Ablation baseline reproducing the pre-fix cross-superspine
    /// blindness: feature 8 collapses [`crate::cluster::topology::Tier::CrossSuperSpine`]
    /// into `SameSuperSpine`, so the scorer cannot see core-layer
    /// crossings. Topology-agnostic strategies (zero `w[W_TOPO]`) are
    /// digest-invariant to this flag.
    pub topo_blind: bool,
    /// Adaptive weight-controller tunables (`--adapt`). Disabled by
    /// default: the scorer reads the frozen hand-tuned tables untouched.
    pub adapt: AdaptConfig,
    /// Current controller output, applied on top of the static tables by
    /// [`RschConfig::node_w`] / [`RschConfig::group_w`]. Written only by
    /// [`Rsch::adapt_tick`] in the single-threaded QSCH phase; shard and
    /// parallel workers inherit it through their cloned config, so every
    /// worker scores with the same vector.
    pub overlay: WeightOverlay,
}

impl Default for RschConfig {
    fn default() -> Self {
        RschConfig {
            training_strategy: PlacementStrategy::EBinpack,
            inference_strategy: PlacementStrategy::ESpread,
            dev_strategy: PlacementStrategy::Binpack,
            two_level: true,
            snapshot_mode: SnapshotMode::Incremental,
            group_fanout: 4,
            indexed_candidates: true,
            gang_scoring: GangScoring::PooledIncremental,
            topo_blind: false,
            adapt: AdaptConfig::default(),
            overlay: WeightOverlay::default(),
        }
    }
}

impl RschConfig {
    /// The §5 baseline: the "native scheduling system" — first-fit
    /// placement, flat scan, deep-copy snapshots.
    pub fn native_baseline() -> RschConfig {
        // Kubernetes' default LeastAllocated scoring is spread-like; that
        // is what produces the ~8.5 % baseline GFR E-Binpack collapses in
        // Figure 6.
        RschConfig {
            training_strategy: PlacementStrategy::Spread,
            inference_strategy: PlacementStrategy::Spread,
            dev_strategy: PlacementStrategy::Spread,
            two_level: false,
            snapshot_mode: SnapshotMode::DeepCopy,
            group_fanout: 4,
            indexed_candidates: false,
            gang_scoring: GangScoring::PerPodRescan,
            topo_blind: false,
            adapt: AdaptConfig::default(),
            overlay: WeightOverlay::default(),
        }
    }

    /// First-fit variant of the baseline (Table-1 style comparisons).
    pub fn first_fit_baseline() -> RschConfig {
        RschConfig {
            training_strategy: PlacementStrategy::NativeFirstFit,
            inference_strategy: PlacementStrategy::NativeFirstFit,
            dev_strategy: PlacementStrategy::NativeFirstFit,
            two_level: false,
            snapshot_mode: SnapshotMode::DeepCopy,
            group_fanout: 4,
            indexed_candidates: false,
            gang_scoring: GangScoring::PerPodRescan,
            topo_blind: false,
            adapt: AdaptConfig::default(),
            overlay: WeightOverlay::default(),
        }
    }

    /// Node weight row for a strategy/phase: the frozen hand-tuned table,
    /// plus the controller overlay when adaptation is live. First-fit is
    /// exempt — its all-zero row *is* its semantics (lowest feasible
    /// node id), and a packing bias would silently turn it into a scorer.
    pub fn node_w(
        &self,
        strategy: PlacementStrategy,
        phase: Phase,
        large: bool,
    ) -> [f32; score::NUM_COMPONENTS] {
        let mut w = node_weights(strategy, phase, large);
        if self.adapt.enabled
            && !self.overlay.is_zero()
            && strategy != PlacementStrategy::NativeFirstFit
        {
            self.overlay.apply_node(&mut w);
        }
        w
    }

    /// Group weight row with the controller overlay (see
    /// [`RschConfig::node_w`] for the exemptions).
    pub fn group_w(
        &self,
        strategy: PlacementStrategy,
        phase: Phase,
        large: bool,
    ) -> [f32; score::GROUP_COMPONENTS] {
        let mut w = group_weights(strategy, phase, large);
        if self.adapt.enabled
            && !self.overlay.is_zero()
            && strategy != PlacementStrategy::NativeFirstFit
        {
            self.overlay.apply_group(&mut w);
        }
        w
    }
}

/// Cumulative RSCH counters (scoring volume feeds the perf analysis).
#[derive(Debug, Clone, Copy, Default)]
pub struct RschStats {
    pub placements: u64,
    pub pods_placed: u64,
    pub failures: u64,
    /// Nodes touched during candidate filtering — the work the
    /// free-capacity index collapses (compare indexed vs linear runs).
    pub nodes_examined: u64,
    pub nodes_scored: u64,
    pub groups_scored: u64,
    pub snapshot_refreshes: u64,
    /// Weight-controller telemetry (zero in non-adaptive runs), mirrored
    /// into the sim digest so adaptive trajectories are replay-checkable.
    pub adapt_ticks: u64,
    pub adapt_shifts: u64,
    pub adapt_fingerprint: u64,
    /// `place` calls served from the sharded-prefetch plan cache vs
    /// falling through to a fresh sequential plan. Observability-only:
    /// feeds `SchedulerHealth`, **never** the sim digest.
    pub plan_cache_hits: u64,
    pub plan_cache_misses: u64,
    /// Prefetch batches routed, and the sum over batches of
    /// `max shard load ÷ ideal shard load` (≥ 1.0; mean = the
    /// `SchedulerHealth` shard-imbalance factor). Digest-inert like the
    /// cache counters above.
    pub prefetch_batches: u64,
    pub prefetch_imbalance_sum: f64,
}

/// Candidate zone filter for E-Spread phases.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ZoneFilter {
    All,
    ZoneOnly,
    GeneralOnly,
}

/// The resource-aware scheduler.
pub struct Rsch {
    pub cfg: RschConfig,
    snapshot: Snapshot,
    backend: Box<dyn ScoreBackend>,
    /// Cached groups per pool id (pool index → group list).
    pool_groups: Vec<Vec<GroupId>>,
    /// Superspine shard structure (fixed by topology; one shard per
    /// superspine) — the partition [`Placer::prefetch`] plans across.
    shards: ShardMap,
    /// Plans built by the sharded prefetch, consumed by [`Placer::place`]
    /// in QSCH's single-threaded queue order (the deterministic merge).
    /// Ordered map for defence in depth: consumed by point lookup in
    /// queue order, but stable order keeps any traversal deterministic.
    plan_cache: BTreeMap<JobId, Vec<PodPlacement>>,
    /// The adaptive weight controller (`--adapt`); dormant when disabled.
    controller: WeightController,
    pub stats: RschStats,
}

impl Rsch {
    pub fn new(cfg: RschConfig, state: &ClusterState) -> Rsch {
        Rsch::with_backend(cfg, state, Box::new(NativeBackend))
    }

    pub fn with_backend(
        cfg: RschConfig,
        state: &ClusterState,
        backend: Box<dyn ScoreBackend>,
    ) -> Rsch {
        let pool_groups = state.pool_groups();
        Rsch {
            snapshot: Snapshot::with_index(cfg.snapshot_mode, cfg.indexed_candidates),
            controller: WeightController::new(cfg.adapt.clone()),
            cfg,
            backend,
            pool_groups,
            shards: ShardMap::new(state),
            plan_cache: BTreeMap::new(),
            stats: RschStats::default(),
        }
    }

    /// Is the adaptive weight controller live (`--adapt`)?
    pub fn wants_adapt(&self) -> bool {
        self.controller.enabled()
    }

    /// One controller tick: fold the rolling-window signals into the
    /// quantized controller state and publish the resulting overlay to
    /// the config every worker clones. Call once per QSCH cycle from the
    /// single-threaded simulator loop *before* `Qsch::cycle` — never from
    /// shard workers — so sharded digests stay byte-identical for any
    /// `--shards N`.
    pub fn adapt_tick(&mut self, signals: &AdaptSignals) {
        if !self.controller.enabled() {
            return;
        }
        self.cfg.overlay = self.controller.tick(signals);
        let s = self.controller.stats;
        self.stats.adapt_ticks = s.ticks;
        self.stats.adapt_shifts = s.pack_shifts + s.escalations + s.releases;
        self.stats.adapt_fingerprint = s.fingerprint;
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn snapshot_stats(&self) -> crate::cluster::snapshot::SnapshotStats {
        self.snapshot.stats
    }

    fn strategy_for(&self, spec: &JobSpec) -> PlacementStrategy {
        spec.strategy.unwrap_or(match spec.kind {
            JobKind::Training => self.cfg.training_strategy,
            JobKind::Inference => self.cfg.inference_strategy,
            JobKind::Dev => self.cfg.dev_strategy,
        })
    }

    /// The scheduling phases a pod goes through for its strategy.
    fn phases(strategy: PlacementStrategy, gpus_per_pod: u32) -> Vec<(Phase, ZoneFilter)> {
        match strategy {
            // E-Spread: pods under a full board spread inside the dedicated
            // zone first, then fall back to E-Binpack in the general pool;
            // whole-node inference pods go straight to the general pool
            // (preserving zone nodes for small HA replicas).
            PlacementStrategy::ESpread if gpus_per_pod < 8 => vec![
                (Phase::Primary, ZoneFilter::ZoneOnly),
                (Phase::Fallback, ZoneFilter::GeneralOnly),
            ],
            PlacementStrategy::ESpread => vec![(Phase::Fallback, ZoneFilter::GeneralOnly)],
            _ => vec![(Phase::Primary, ZoneFilter::All)],
        }
    }
}

/// One job's planned pod placements (or why planning failed).
type PlanResult = Result<Vec<crate::cluster::state::PodPlacement>, PlaceFailure>;

/// Frozen candidate region for one pooled demand: the node list with its
/// feature matrix and scores, patched row-wise as the plan grows.
struct GangCache {
    candidates: Vec<NodeId>,
    feat: Vec<f32>,
    scores: Vec<f32>,
}

impl GangCache {
    /// Best feasible row (argmax with lowest-index tiebreak), if any.
    fn best(&self) -> Option<usize> {
        argmax(&self.scores).filter(|&i| feasible(self.scores[i]))
    }
}

/// Borrow-split planning context: snapshot immutably feeds the
/// [`PlanBuilder`] while the backend/stats stay mutably borrowable.
struct Planner<'a> {
    cfg: &'a RschConfig,
    snapshot: &'a Snapshot,
    backend: &'a mut dyn ScoreBackend,
    pool_groups: &'a [Vec<GroupId>],
    stats: &'a mut RschStats,
}

impl Planner<'_> {
    /// Indexed selection needs both the config flag and an index-carrying
    /// snapshot; the two only diverge if `Rsch::cfg` is mutated after
    /// construction — degrade to the linear scan instead of panicking.
    fn use_index(&self) -> bool {
        self.cfg.indexed_candidates && self.snapshot.index().is_some()
    }

    /// Plan one pod; returns the chosen node or None.
    fn plan_pod(
        &mut self,
        state: &ClusterState,
        pb: &mut PlanBuilder,
        spec: &JobSpec,
        demand: &TypedDemand,
        strategy: PlacementStrategy,
        large: bool,
    ) -> Option<NodeId> {
        let pool = state.pools.pool_for_type(demand.gpu_type)?;
        let job = job_descriptor(spec, demand.gpus_per_pod);

        for (phase, zone_filter) in Rsch::phases(strategy, demand.gpus_per_pod) {
            let node = if self.cfg.two_level {
                self.plan_pod_two_level(
                    state, pb, spec, demand, strategy, large, phase, zone_filter, &job,
                    pool.id.index(),
                )
            } else {
                let candidates = if self.use_index() {
                    let groups: &[GroupId] = &self.pool_groups[pool.id.index()];
                    self.indexed_candidates(state, pb, groups, demand, spec, zone_filter)
                } else {
                    self.filter_candidates(state, pb, &pool.nodes, demand, spec, zone_filter)
                };
                self.pick_node(pb, &candidates, &job, strategy, phase, large)
            };
            if let Some(n) = node {
                if pb.place_pod(n, demand.gpus_per_pod) {
                    return Some(n);
                }
            }
        }
        None
    }

    /// Two-level: preselect top-K groups by score, then pick a node within.
    #[allow(clippy::too_many_arguments)]
    fn plan_pod_two_level(
        &mut self,
        state: &ClusterState,
        pb: &mut PlanBuilder,
        spec: &JobSpec,
        demand: &TypedDemand,
        strategy: PlacementStrategy,
        large: bool,
        phase: Phase,
        zone_filter: ZoneFilter,
        job: &[f32; features::JOB_D],
        pool_idx: usize,
    ) -> Option<NodeId> {
        let groups = &self.pool_groups[pool_idx];
        if groups.is_empty() {
            return None;
        }
        let gfeat = group_features(self.snapshot, pb, groups);
        let gw = self.cfg.group_w(strategy, phase, large);
        let gscores = self
            .backend
            .score_groups(&gfeat, groups.len(), job, &gw);
        self.stats.groups_scored += groups.len() as u64;

        // Order groups by score desc (stable by index) and walk the top-K
        // feasible ones.
        let mut order: Vec<usize> = (0..groups.len()).collect();
        order.sort_by(|&a, &b| {
            gscores[b]
                .partial_cmp(&gscores[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        for &gi in order.iter().take(self.cfg.group_fanout.max(1)) {
            if !feasible(gscores[gi]) {
                break;
            }
            let candidates = if self.use_index() {
                self.indexed_candidates(
                    state,
                    pb,
                    std::slice::from_ref(&groups[gi]),
                    demand,
                    spec,
                    zone_filter,
                )
            } else {
                let group_nodes = &state.fabric.groups[groups[gi].index()].nodes;
                self.filter_candidates(state, pb, group_nodes, demand, spec, zone_filter)
            };
            if candidates.is_empty() {
                continue;
            }
            if let Some(n) = self.pick_node(pb, &candidates, job, strategy, phase, large) {
                return Some(n);
            }
        }
        None
    }

    /// The single admission predicate both candidate-selection paths
    /// share: health, GPU type, plan-adjusted capacity, zone, HBD pin.
    /// Keeping it in one place is what guarantees the indexed walk stays
    /// behaviorally identical to the linear scan.
    fn admit(
        &self,
        state: &ClusterState,
        pb: &PlanBuilder,
        n: NodeId,
        demand: &TypedDemand,
        spec: &JobSpec,
        zone_filter: ZoneFilter,
    ) -> bool {
        use features::PlanView;
        let rec = &self.snapshot.nodes[n.index()];
        if !rec.healthy || rec.gpu_type != demand.gpu_type {
            return false;
        }
        if pb.free_gpus(n) < demand.gpus_per_pod {
            return false;
        }
        match zone_filter {
            ZoneFilter::All => {}
            ZoneFilter::ZoneOnly if !rec.in_inference_zone => return false,
            ZoneFilter::GeneralOnly if rec.in_inference_zone => return false,
            _ => {}
        }
        if spec.needs_hbd {
            match (pb.hbd_lock, state.node(n).hbd) {
                (Some(lock), Some(h)) if lock == h => {}
                (Some(_), _) => return false,
                (None, Some(h)) => {
                    // First pod: the HBD must fit the whole job.
                    if state.hbd_free(h) < spec.total_gpus() {
                        return false;
                    }
                }
                (None, None) => return false,
            }
        }
        true
    }

    /// Linear candidate selection: scan every node of the slice (the
    /// ablation baseline; `RschConfig::indexed_candidates = false`).
    fn filter_candidates(
        &mut self,
        state: &ClusterState,
        pb: &PlanBuilder,
        nodes: &[NodeId],
        demand: &TypedDemand,
        spec: &JobSpec,
        zone_filter: ZoneFilter,
    ) -> Vec<NodeId> {
        self.stats.nodes_examined += nodes.len() as u64;
        let mut out = Vec::new();
        for &n in nodes {
            if self.admit(state, pb, n, demand, spec, zone_filter) {
                out.push(n);
            }
        }
        out
    }

    /// Sublinear candidate selection: walk only the free-capacity buckets
    /// that can hold the pod (`free >= gpus_per_pod`, matching zone class),
    /// then re-apply [`Planner::admit`] for plan-local state the index
    /// cannot know (in-flight device takings, HBD pinning). Sorted
    /// ascending so the result is byte-identical to the linear scan.
    fn indexed_candidates(
        &mut self,
        state: &ClusterState,
        pb: &PlanBuilder,
        groups: &[GroupId],
        demand: &TypedDemand,
        spec: &JobSpec,
        zone_filter: ZoneFilter,
    ) -> Vec<NodeId> {
        let ix = self
            .snapshot
            .index()
            .expect("indexed_candidates needs Snapshot::with_index");
        let zone = match zone_filter {
            ZoneFilter::All => ZoneQuery::Any,
            ZoneFilter::ZoneOnly => ZoneQuery::ZoneOnly,
            ZoneFilter::GeneralOnly => ZoneQuery::GeneralOnly,
        };
        let mut walked = Vec::new();
        let mut examined = 0u64;
        for &g in groups {
            examined += ix.for_group(g, demand.gpus_per_pod, zone, &mut walked);
        }
        self.stats.nodes_examined += examined;
        walked.retain(|&n| self.admit(state, pb, n, demand, spec, zone_filter));
        walked.sort_unstable();
        walked
    }

    /// Plan a whole job against the snapshot (no state mutation). Returns
    /// the placement plan or the failure kind.
    fn plan_job(
        &mut self,
        state: &ClusterState,
        spec: &JobSpec,
        default_strategy: PlacementStrategy,
    ) -> PlanResult {
        self.plan_job_with_claims(state, spec, default_strategy, &[])
    }

    /// [`Planner::plan_job`] with claim chaining: `claims` are placements
    /// already planned by *earlier* jobs against the same snapshot (the
    /// sharded prefetch path); their devices and group capacity are
    /// invisible to this plan, so shard-local plans are mutually
    /// device-disjoint and commit cleanly in queue order.
    fn plan_job_with_claims(
        &mut self,
        state: &ClusterState,
        spec: &JobSpec,
        default_strategy: PlacementStrategy,
        claims: &[PodPlacement],
    ) -> PlanResult {
        // Sanity: every demand must be satisfiable in principle.
        for d in &spec.demands {
            let Some(pool) = state.pools.pool_for_type(d.gpu_type) else {
                self.stats.failures += 1;
                return Err(PlaceFailure::Unsatisfiable);
            };
            let per_node = state.gpu_type(d.gpu_type).gpus_per_node as u32;
            if d.gpus_per_pod > per_node || d.total_gpus() > pool.total_gpus {
                self.stats.failures += 1;
                return Err(PlaceFailure::Unsatisfiable);
            }
        }
        let strategy = spec.strategy.unwrap_or(default_strategy);
        let mut pb = PlanBuilder::new(state, self.snapshot, spec.id, self.cfg.topo_blind);
        if !claims.is_empty() {
            pb.preclaim(claims);
        }
        for d in &spec.demands {
            let pool_idx = state
                .pools
                .pool_for_type(d.gpu_type)
                .expect("checked above")
                .id
                .index();
            let cap = self.pool_groups[pool_idx]
                .first()
                .map(|&g| state.group_total(g))
                .unwrap_or(0);
            let large = is_large_job(spec.total_gpus(), cap);
            // Pooled gang scoring applies to single-phase, non-HBD demands
            // of topology-aware strategies: their pods arbitrate across
            // the whole candidate region through feature 8, and the score
            // cache makes that O(1) rows per pod instead of a full
            // rebuild. Everything else (Binpack / Spread / first-fit /
            // E-Spread's two-phase small pods / HBD pins) keeps the
            // legacy per-pod walk, byte-identical to the pre-refactor
            // path.
            let phases = Rsch::phases(strategy, d.gpus_per_pod);
            // The gate reads the *base* table: the adapt overlay never
            // touches W_TOPO, so pooled-path eligibility is identical
            // with and without `--adapt`.
            let pooled = self.cfg.gang_scoring != GangScoring::PerPodRescan
                && !spec.needs_hbd
                && phases.len() == 1
                && node_weights(strategy, phases[0].0, large)[W_TOPO] != 0.0;
            let ok = if pooled {
                let (phase, zone_filter) = phases[0];
                self.plan_demand_pooled(
                    state, &mut pb, spec, d, strategy, large, phase, zone_filter, pool_idx,
                )
            } else {
                (0..d.replicas)
                    .all(|_| self.plan_pod(state, &mut pb, spec, d, strategy, large).is_some())
            };
            if !ok {
                // Gang all-or-nothing: abandon the whole plan. (Non-gang
                // jobs are treated the same at job granularity; see
                // DESIGN.md §6 for the pod-level-admission note.)
                self.stats.failures += 1;
                return Err(PlaceFailure::Resources);
            }
        }
        Ok(pb.into_plan())
    }

    /// Pooled gang planning: freeze one candidate region sized to the
    /// demand, score it once, then per pod pick the argmax and refresh
    /// only the rows the placement invalidated.
    #[allow(clippy::too_many_arguments)]
    fn plan_demand_pooled(
        &mut self,
        state: &ClusterState,
        pb: &mut PlanBuilder,
        spec: &JobSpec,
        demand: &TypedDemand,
        strategy: PlacementStrategy,
        large: bool,
        phase: Phase,
        zone_filter: ZoneFilter,
        pool_idx: usize,
    ) -> bool {
        let job = job_descriptor(spec, demand.gpus_per_pod);
        let w = self.cfg.node_w(strategy, phase, large);
        let incremental = self.cfg.gang_scoring == GangScoring::PooledIncremental;

        let mut cache: Option<GangCache> = None;
        for pod in 0..demand.replicas {
            let remaining = demand.replicas - pod;
            let mut fresh = false;
            if cache.is_none() {
                cache = Some(self.build_gang_cache(
                    state, pb, spec, demand, strategy, large, phase, zone_filter, &job, &w,
                    pool_idx, remaining,
                ));
                fresh = true;
            } else if !incremental {
                // PooledRebuild: same frozen region, full row rebuild per
                // pod (the work-counter baseline).
                let c = cache.as_mut().expect("cache built");
                c.feat = node_features(self.snapshot, &*pb, &c.candidates);
                c.scores = self.backend.score_nodes(&c.feat, c.candidates.len(), &job, &w);
                self.stats.nodes_scored += c.candidates.len() as u64;
            }
            let mut pick = cache.as_ref().and_then(GangCache::best);
            if pick.is_none() && !fresh {
                // The frozen region ran dry mid-gang (or a stale row
                // masked the last capacity): one fresh reselection
                // against the current plan before giving up.
                cache = Some(self.build_gang_cache(
                    state, pb, spec, demand, strategy, large, phase, zone_filter, &job, &w,
                    pool_idx, remaining,
                ));
                pick = cache.as_ref().and_then(GangCache::best);
            }
            let Some(row) = pick else {
                return false;
            };
            let node = cache.as_ref().expect("cache built").candidates[row];
            if !pb.place_pod(node, demand.gpus_per_pod) {
                return false; // Defensive: the mask guarantees capacity.
            }
            if incremental && pod + 1 < demand.replicas {
                self.refresh_invalidated_rows(
                    state,
                    pb,
                    node,
                    cache.as_mut().expect("cache built"),
                    &job,
                    &w,
                );
            }
        }
        true
    }

    /// Select the candidate region for a whole (remaining) demand and
    /// score every row once. Two-level mode takes feasible groups in
    /// score order until the region both covers the demand's GPUs and
    /// spans at least `group_fanout` groups (large gangs get a region
    /// sized to the gang, not to one pod); flat mode pools the whole
    /// pool. Candidates are ordered group-major by group score so exact
    /// node-score ties still resolve toward the preferred group.
    #[allow(clippy::too_many_arguments)]
    fn build_gang_cache(
        &mut self,
        state: &ClusterState,
        pb: &PlanBuilder,
        spec: &JobSpec,
        demand: &TypedDemand,
        strategy: PlacementStrategy,
        large: bool,
        phase: Phase,
        zone_filter: ZoneFilter,
        job: &[f32; features::JOB_D],
        w: &[f32; score::NUM_COMPONENTS],
        pool_idx: usize,
        remaining_pods: u32,
    ) -> GangCache {
        use features::PlanView;
        let candidates = if self.cfg.two_level {
            let groups = &self.pool_groups[pool_idx];
            let mut region: Vec<NodeId> = Vec::new();
            if !groups.is_empty() {
                let gfeat = group_features(self.snapshot, pb, groups);
                let gw = self.cfg.group_w(strategy, phase, large);
                let gscores = self.backend.score_groups(&gfeat, groups.len(), job, &gw);
                self.stats.groups_scored += groups.len() as u64;
                let mut order: Vec<usize> = (0..groups.len()).collect();
                order.sort_by(|&a, &b| {
                    gscores[b]
                        .partial_cmp(&gscores[a])
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.cmp(&b))
                });
                let demand_gpus = remaining_pods as u64 * demand.gpus_per_pod as u64;
                let mut capacity = 0u64;
                let mut taken = 0usize;
                for &gi in &order {
                    if !feasible(gscores[gi]) {
                        break;
                    }
                    if taken >= self.cfg.group_fanout.max(1) && capacity >= demand_gpus {
                        break;
                    }
                    let cands = if self.use_index() {
                        self.indexed_candidates(
                            state,
                            pb,
                            std::slice::from_ref(&groups[gi]),
                            demand,
                            spec,
                            zone_filter,
                        )
                    } else {
                        let group_nodes = &state.fabric.groups[groups[gi].index()].nodes;
                        self.filter_candidates(state, pb, group_nodes, demand, spec, zone_filter)
                    };
                    if cands.is_empty() {
                        continue;
                    }
                    capacity += cands.iter().map(|&n| pb.free_gpus(n) as u64).sum::<u64>();
                    region.extend(cands);
                    taken += 1;
                }
            }
            region
        } else if self.use_index() {
            let groups: &[GroupId] = &self.pool_groups[pool_idx];
            self.indexed_candidates(state, pb, groups, demand, spec, zone_filter)
        } else {
            let pool = state.pools.pool_for_type(demand.gpu_type).expect("pool exists");
            self.filter_candidates(state, pb, &pool.nodes, demand, spec, zone_filter)
        };
        let feat = node_features(self.snapshot, pb, &candidates);
        let scores = self.backend.score_nodes(&feat, candidates.len(), job, w);
        self.stats.nodes_scored += candidates.len() as u64;
        GangCache {
            candidates,
            feat,
            scores,
        }
    }

    /// Re-extract and re-score exactly the rows invalidated by placing a
    /// pod on `placed`: the node itself (capacity / colocation / NVLink),
    /// its NodeNetGroup (group-free deltas), and — per the footprint
    /// delta — any candidates whose minimum tier the placement improved
    /// (everything on a first pod; otherwise only nodes in a newly-
    /// entered spine or superspine). All other rows are provably
    /// score-identical, so the cached values stand.
    fn refresh_invalidated_rows(
        &mut self,
        state: &ClusterState,
        pb: &PlanBuilder,
        placed: NodeId,
        cache: &mut GangCache,
        job: &[f32; features::JOB_D],
        w: &[f32; score::NUM_COMPONENTS],
    ) {
        let fabric = &state.fabric;
        let delta = pb.last_delta();
        let group = fabric.group_of(placed);
        let spine = fabric.spine_of(placed);
        let superspine = fabric.superspine_of(placed);
        let mut rows: Vec<usize> = Vec::new();
        for (i, &c) in cache.candidates.iter().enumerate() {
            let invalid = delta.first_pod
                || c == placed
                || fabric.group_of(c) == group
                || (delta.new_spine && fabric.spine_of(c) == spine)
                || (delta.new_superspine && fabric.superspine_of(c) == superspine);
            if invalid {
                rows.push(i);
            }
        }
        if rows.is_empty() {
            return;
        }
        let sub: Vec<NodeId> = rows.iter().map(|&i| cache.candidates[i]).collect();
        let sfeat = node_features(self.snapshot, pb, &sub);
        let sscores = self.backend.score_nodes(&sfeat, sub.len(), job, w);
        self.stats.nodes_scored += sub.len() as u64;
        for (k, &i) in rows.iter().enumerate() {
            cache.feat[i * NODE_F..(i + 1) * NODE_F]
                .copy_from_slice(&sfeat[k * NODE_F..(k + 1) * NODE_F]);
            cache.scores[i] = sscores[k];
        }
    }

    /// Score candidates and return the best feasible node.
    #[allow(clippy::too_many_arguments)]
    fn pick_node(
        &mut self,
        pb: &PlanBuilder,
        candidates: &[NodeId],
        job: &[f32; features::JOB_D],
        strategy: PlacementStrategy,
        phase: Phase,
        large: bool,
    ) -> Option<NodeId> {
        if candidates.is_empty() {
            return None;
        }
        let feat = node_features(self.snapshot, pb, candidates);
        let w = self.cfg.node_w(strategy, phase, large);
        let scores = self
            .backend
            .score_nodes(&feat, candidates.len(), job, &w);
        self.stats.nodes_scored += candidates.len() as u64;
        let best = argmax(&scores)?;
        feasible(scores[best]).then_some(candidates[best])
    }
}

impl Placer for Rsch {
    fn place(&mut self, state: &mut ClusterState, spec: &JobSpec) -> Result<(), PlaceFailure> {
        // Serve a prefetched shard-local plan when one exists. Claim
        // chaining makes same-shard plans device-disjoint and routing
        // makes cross-shard plans node-disjoint, so the commit normally
        // succeeds; if the world changed since the prefetch (preemption,
        // fault) the stale plan is discarded and the job falls through to
        // a fresh sequential replan — both outcomes are thread-invariant.
        if let Some(plan) = self.plan_cache.remove(&spec.id) {
            let pods = plan.len() as u64;
            if state.commit_placements(spec.id, plan).is_ok() {
                self.stats.placements += 1;
                self.stats.pods_placed += pods;
                self.stats.plan_cache_hits += 1;
                return Ok(());
            }
        }
        // Fall-through (no prefetched plan, or a stale one): a cache miss
        // for the health rollup — counted only on sharded runs that
        // actually prefetch, so the hit rate stays meaningful.
        if self.stats.prefetch_batches > 0 {
            self.stats.plan_cache_misses += 1;
        }
        self.snapshot.refresh(state);
        self.stats.snapshot_refreshes += 1;
        let default_strategy = self.strategy_for(spec);
        let mut planner = Planner {
            cfg: &self.cfg,
            snapshot: &self.snapshot,
            backend: self.backend.as_mut(),
            pool_groups: &self.pool_groups,
            stats: &mut self.stats,
        };
        let plan = planner.plan_job(state, spec, default_strategy)?;
        let pods = plan.len() as u64;
        state
            .commit_placements(spec.id, plan)
            .map_err(|_| PlaceFailure::Resources)?;
        self.stats.placements += 1;
        self.stats.pods_placed += pods;
        Ok(())
    }

    /// Superspine-sharded batch planning (the PR-6 sharded core).
    ///
    /// The shard structure is the fixed per-superspine [`ShardMap`];
    /// `threads` only sets how many workers sweep it, so any thread count
    /// produces byte-identical plans, stats, and digests:
    ///
    /// 1. **Route** each queued job, in queue order, to the feasible home
    ///    shard with the most remaining free GPUs (ties → lowest shard
    ///    id), debiting the shard's headroom. Jobs no single shard can
    ///    hold — cross-superspine gangs — get no cache entry and take
    ///    the serialized global phase (the sequential [`Placer::place`]
    ///    path against the whole fabric, still in queue order).
    /// 2. **Plan** each shard's jobs sequentially against one shared
    ///    snapshot, chaining claims so same-shard plans are mutually
    ///    device-disjoint. Workers force two-level mode (the shard *is*
    ///    a group partition) on the native backend — the same constraint
    ///    `place_many_parallel` applies, surfaced by `SimOptions` as the
    ///    `--xla-scorer`-excludes-`--shards` rule.
    /// 3. **Merge** per-shard plan logs and counters in shard-id order.
    fn prefetch(&mut self, state: &ClusterState, specs: &[&JobSpec], threads: usize) {
        self.plan_cache.clear();
        if specs.is_empty() {
            return;
        }
        self.snapshot.refresh(state);
        self.stats.snapshot_refreshes += 1;
        let num_shards = self.shards.num_shards();
        let workers = threads.clamp(1, num_shards);

        // ---- 1. Route jobs to home shards (queue order). ----
        let mut remaining: Vec<Vec<i64>> = (0..num_shards)
            .map(|s| {
                self.shards
                    .free_by_pool(state, s)
                    .iter()
                    .map(|&f| f as i64)
                    .collect()
            })
            .collect();
        let mut routed: Vec<Vec<usize>> = vec![Vec::new(); num_shards];
        // Largest free HBD per shard: a `needs_hbd` gang is only feasible
        // on a shard with one domain big enough for the *whole* job. Pool
        // headroom alone over-admits, and the lowest-shard-id tie-break
        // then parks the job on a shard whose planner can never place it
        // — instead of a shard (or the global phase) that could.
        let mut hbd_max: Vec<u32> = vec![0; num_shards];
        for h in &state.fabric.hbds {
            let Some(&first) = h.nodes.first() else {
                continue;
            };
            let s = self.shards.shard_of_group(state.fabric.group_of(first));
            hbd_max[s] = hbd_max[s].max(state.hbd_free(h.id));
        }
        for (i, spec) in specs.iter().enumerate() {
            // Aggregate the demand per pool; unknown pools go to the
            // global phase (the sequential path reports Unsatisfiable).
            let mut need: Vec<(usize, i64)> = Vec::new();
            let mut known = true;
            for d in &spec.demands {
                match state.pools.pool_for_type(d.gpu_type) {
                    Some(p) => {
                        let idx = p.id.index();
                        match need.iter_mut().find(|(pi, _)| *pi == idx) {
                            Some((_, amt)) => *amt += d.total_gpus() as i64,
                            None => need.push((idx, d.total_gpus() as i64)),
                        }
                    }
                    None => {
                        known = false;
                        break;
                    }
                }
            }
            if !known {
                continue;
            }
            let mut best: Option<(usize, i64)> = None;
            for (s, rem) in remaining.iter().enumerate() {
                if spec.needs_hbd && hbd_max[s] < spec.total_gpus() {
                    continue;
                }
                if need.iter().all(|&(p, amt)| rem[p] >= amt) {
                    let headroom: i64 = rem.iter().sum();
                    let better = match best {
                        Some((_, h)) => headroom > h,
                        None => true,
                    };
                    if better {
                        best = Some((s, headroom));
                    }
                }
            }
            if let Some((s, _)) = best {
                for &(p, amt) in &need {
                    remaining[s][p] -= amt;
                }
                if spec.needs_hbd {
                    // Conservative debit: the routed gang will consume one
                    // domain's capacity; without this, a second HBD gang
                    // could route onto a shard that just spent its only
                    // adequate domain.
                    hbd_max[s] = hbd_max[s].saturating_sub(spec.total_gpus());
                }
                routed[s].push(i);
            }
        }
        // Routing-balance telemetry (digest-inert): how far the fullest
        // shard sits above the ideal even split of this batch.
        let routed_total: usize = routed.iter().map(Vec::len).sum();
        if routed_total > 0 {
            let max_len = routed.iter().map(Vec::len).max().unwrap_or(0);
            self.stats.prefetch_batches += 1;
            self.stats.prefetch_imbalance_sum +=
                max_len as f64 * num_shards as f64 / routed_total as f64;
        }

        // ---- 2. Plan shards concurrently (shard→worker round-robin). ----
        let strategies: Vec<PlacementStrategy> =
            specs.iter().map(|sp| self.strategy_for(sp)).collect();
        let shard_cfg = RschConfig {
            two_level: true,
            ..self.cfg.clone()
        };
        let snapshot = &self.snapshot;
        let shards = &self.shards;
        type ShardLog = (Vec<(JobId, Vec<PodPlacement>)>, RschStats);
        let mut per_shard: Vec<ShardLog> = (0..num_shards)
            .map(|_| (Vec::new(), RschStats::default()))
            .collect();
        let per_shard_ref = &mut per_shard;
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for t in 0..workers {
                let routed = &routed;
                let strategies = &strategies;
                let shard_cfg = &shard_cfg;
                handles.push(scope.spawn(move || {
                    let mut out: Vec<(usize, ShardLog)> = Vec::new();
                    let mut s = t;
                    while s < num_shards {
                        let mut backend = NativeBackend;
                        let mut stats = RschStats::default();
                        let mut planner = Planner {
                            cfg: shard_cfg,
                            snapshot,
                            backend: &mut backend,
                            pool_groups: shards.pool_groups(s),
                            stats: &mut stats,
                        };
                        let mut claims: Vec<PodPlacement> = Vec::new();
                        let mut plans = Vec::new();
                        for &i in &routed[s] {
                            if let Ok(plan) = planner.plan_job_with_claims(
                                state,
                                specs[i],
                                strategies[i],
                                &claims,
                            ) {
                                claims.extend(plan.iter().cloned());
                                plans.push((specs[i].id, plan));
                            }
                        }
                        drop(planner);
                        out.push((s, (plans, stats)));
                        s += workers;
                    }
                    out
                }));
            }
            for h in handles {
                for (s, log) in h.join().expect("shard planner thread panicked") {
                    per_shard_ref[s] = log;
                }
            }
        });

        // ---- 3. Deterministic merge in shard-id order. ----
        for (plans, stats) in per_shard {
            self.stats.nodes_examined += stats.nodes_examined;
            self.stats.nodes_scored += stats.nodes_scored;
            self.stats.groups_scored += stats.groups_scored;
            self.stats.failures += stats.failures;
            for (job, plan) in plans {
                self.plan_cache.insert(job, plan);
            }
        }
    }

    /// Moldable shape selection (the admission half of moldable &
    /// malleable gangs): for each queued gang with a shape ladder, pick
    /// the *largest* rung whose footprint fits the current free-capacity
    /// picture — maximal per-job throughput, sliding down the ladder
    /// only as far as fragmentation forces ([`score::best_feasible_shape`]).
    /// `None` keeps the current shape, both when the job already holds
    /// its best rung and when not even the smallest rung fits (a
    /// saturated cluster queues jobs at full size instead of thrashing
    /// them to the floor).
    ///
    /// Cost is O(shapes) probes per job: each rung checks a virtual
    /// pool-headroom ledger (debited in queue order, so earlier jobs
    /// claim capacity first and the pass is batch-deterministic), a
    /// [`plan::pod_slots`] count over the free-capacity
    /// [`NodeIndex`](crate::cluster::index::NodeIndex) buckets (linear
    /// snapshot scan when `indexed_candidates` is off), and — for
    /// `needs_hbd` gangs — a whole-gang HBD-domain fit. The pick is
    /// advisory: the E-Binpack / pooled gang scoring that follows this
    /// pass re-verifies real feasibility, so an optimistic pick just
    /// leaves the job queued for the next cycle.
    ///
    /// This runs in QSCH's single-threaded phase *before*
    /// [`Placer::prefetch`], so shard routing and the concurrent
    /// planners see the already-molded specs and `--shards N` digests
    /// stay byte-identical.
    fn mold_shapes(&mut self, state: &ClusterState, specs: &[&JobSpec]) -> Vec<Option<usize>> {
        if specs.is_empty() {
            return Vec::new();
        }
        self.snapshot.refresh(state);
        self.stats.snapshot_refreshes += 1;
        let mut claimed: BTreeMap<GpuTypeId, u64> = BTreeMap::new();
        let mut picks = Vec::with_capacity(specs.len());
        for spec in specs {
            // Moldable gangs are sole-demand by construction
            // (`JobSpec::with_shapes` pins the sole demand's replicas).
            let pick = match spec.demands.as_slice() {
                [d] if spec.moldable() => self.pick_shape(state, spec, d, &claimed),
                _ => None,
            };
            if let Some(k) = pick {
                let d = &spec.demands[0];
                let gpus = spec.shapes[k].replicas as u64 * d.gpus_per_pod as u64;
                *claimed.entry(d.gpu_type).or_default() += gpus;
            } else if let [d] = spec.demands.as_slice() {
                // Keeping the current shape still consumes headroom.
                *claimed.entry(d.gpu_type).or_default() += spec.total_gpus() as u64;
            }
            picks.push(pick);
        }
        picks
    }
}

impl Rsch {
    /// One job's shape pick for [`Placer::mold_shapes`]: the first
    /// ladder rung passing the three-part feasibility probe.
    fn pick_shape(
        &mut self,
        state: &ClusterState,
        spec: &JobSpec,
        d: &TypedDemand,
        claimed: &BTreeMap<GpuTypeId, u64>,
    ) -> Option<usize> {
        if d.gpus_per_pod == 0 {
            return None;
        }
        let already = claimed.get(&d.gpu_type).copied().unwrap_or(0);
        let free = (state.pool_free_for_type(d.gpu_type) as u64).saturating_sub(already);
        let slots = self
            .pool_pod_slots(state, d.gpu_type, d.gpus_per_pod)
            .saturating_sub(already / d.gpus_per_pod as u64);
        score::best_feasible_shape(&spec.shapes, |s| {
            let gpus = s.replicas as u64 * d.gpus_per_pod as u64;
            if gpus > free || s.replicas as u64 > slots {
                return false;
            }
            if spec.needs_hbd {
                // The whole gang must fit one HBD domain.
                return state
                    .fabric
                    .hbds
                    .iter()
                    .any(|h| state.hbd_free(h.id) as u64 >= gpus + already);
            }
            true
        })
    }

    /// How many `gpus_per_pod`-sized pod slots the pool for `gpu_type`
    /// exposes right now. With `indexed_candidates` this walks only the
    /// free-capacity index buckets at `free >= gpus_per_pod`; otherwise
    /// it scans the pool's snapshot records linearly.
    fn pool_pod_slots(&mut self, state: &ClusterState, gpu_type: GpuTypeId, gpus_per_pod: u32) -> u64 {
        let Some(pool) = state.pools.pool_for_type(gpu_type) else {
            return 0;
        };
        if self.cfg.indexed_candidates {
            if let Some(ix) = self.snapshot.index() {
                let mut candidates = Vec::new();
                let mut examined = 0u64;
                for &g in &self.pool_groups[pool.id.index()] {
                    examined += ix.for_group(g, gpus_per_pod, ZoneQuery::Any, &mut candidates);
                }
                self.stats.nodes_examined += examined;
                return plan::pod_slots(&self.snapshot, &candidates, gpus_per_pod);
            }
        }
        self.stats.nodes_examined += pool.nodes.len() as u64;
        plan::pod_slots(&self.snapshot, &pool.nodes, gpus_per_pod)
    }

    /// Multi-instance parallel scheduling (§3.1 / §3.4.2 "parallel
    /// scheduling across groups"): plan many jobs concurrently against one
    /// consistent snapshot (each worker thread = one RSCH instance with
    /// its own native scorer), then commit optimistically in input order.
    /// Plans invalidated by earlier commits fall back to the sequential
    /// path — determinism is preserved because commit order is the input
    /// order.
    ///
    /// The parallel planners always use the native backend (the PJRT
    /// client is not `Send`); the sequential fallback uses whatever
    /// backend the instance was built with.
    pub fn place_many_parallel(
        &mut self,
        state: &mut ClusterState,
        specs: &[JobSpec],
        threads: usize,
    ) -> Vec<Result<(), PlaceFailure>> {
        self.snapshot.refresh(state);
        self.stats.snapshot_refreshes += 1;
        let threads = threads.max(1);

        // Shard NodeNetGroups round-robin across worker threads (§3.4.2
        // "parallel scheduling across groups"): planners touch disjoint
        // node sets, so optimistic commits almost never conflict. Each
        // worker forces two-level mode (the shard IS a group partition).
        let sharded_groups: Vec<Vec<Vec<GroupId>>> = (0..threads)
            .map(|t| {
                self.pool_groups
                    .iter()
                    .map(|gs| {
                        gs.iter()
                            .enumerate()
                            .filter(|(i, _)| i % threads == t)
                            .map(|(_, &g)| g)
                            .collect()
                    })
                    .collect()
            })
            .collect();
        let parallel_cfg = RschConfig {
            two_level: true,
            ..self.cfg.clone()
        };

        // Phase 1: parallel planning against the shared snapshot.
        let mut plans: Vec<Option<PlanResult>> = (0..specs.len()).map(|_| None).collect();
        let snapshot = &self.snapshot;
        let strategies: Vec<PlacementStrategy> =
            specs.iter().map(|sp| self.strategy_for(sp)).collect();
        let state_ref: &ClusterState = state;
        let mut thread_stats: Vec<RschStats> = vec![RschStats::default(); threads];

        let plans_ref = &mut plans;
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for (t, stats_slot) in thread_stats.iter_mut().enumerate() {
                let strategies = &strategies;
                let shard = &sharded_groups[t];
                let parallel_cfg = &parallel_cfg;
                let handle = scope.spawn(move || {
                    let mut backend = NativeBackend;
                    let mut stats = RschStats::default();
                    let mut out = Vec::new();
                    let mut planner = Planner {
                        cfg: parallel_cfg,
                        snapshot,
                        backend: &mut backend,
                        pool_groups: shard,
                        stats: &mut stats,
                    };
                    for (i, spec) in specs.iter().enumerate() {
                        if i % threads != t {
                            continue;
                        }
                        out.push((i, planner.plan_job(state_ref, spec, strategies[i])));
                    }
                    (out, stats)
                });
                handles.push((handle, stats_slot));
            }
            for (handle, slot) in handles {
                let (out, stats) = handle.join().expect("planner thread panicked");
                *slot = stats;
                for (i, r) in out {
                    plans_ref[i] = Some(r);
                }
            }
        });
        for ts in thread_stats {
            self.stats.nodes_examined += ts.nodes_examined;
            self.stats.nodes_scored += ts.nodes_scored;
            self.stats.groups_scored += ts.groups_scored;
            self.stats.failures += ts.failures;
        }

        // Phase 2: optimistic sequential commit in input order.
        let mut results = Vec::with_capacity(specs.len());
        for (spec, plan) in specs.iter().zip(plans.into_iter()) {
            let plan = plan.expect("every index planned");
            let res = match plan {
                Err(PlaceFailure::Unsatisfiable) => Err(PlaceFailure::Unsatisfiable),
                // The thread's group shard may simply have been too
                // narrow for this job — replan with the full view.
                Err(PlaceFailure::Resources) => self.place(state, spec),
                Ok(plan) => match state.commit_placements(spec.id, plan) {
                    Ok(()) => {
                        self.stats.placements += 1;
                        Ok(())
                    }
                    Err(_) => {
                        // Conflict with an earlier commit: replan
                        // sequentially against fresh state.
                        self.place(state, spec)
                    }
                },
            };
            results.push(res);
        }
        results
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::builder::{ClusterBuilder, ClusterSpec};
    use crate::cluster::ids::{GpuTypeId, JobId, TenantId};
    use crate::cluster::node::Zone;
    use crate::job::spec::JobKind;

    const G: GpuTypeId = GpuTypeId(0);

    fn state_2x4() -> ClusterState {
        // 1 spine × 2 groups × 4 nodes × 8 GPUs = 64 GPUs.
        ClusterBuilder::build(&ClusterSpec::homogeneous("t", 1, 2, 4))
    }

    fn train(id: u64, replicas: u32, gpp: u32) -> JobSpec {
        JobSpec::homogeneous(JobId(id), TenantId(0), JobKind::Training, G, replicas, gpp)
    }

    #[test]
    fn places_simple_job() {
        let mut state = state_2x4();
        let mut rsch = Rsch::new(RschConfig::default(), &state);
        rsch.place(&mut state, &train(1, 2, 8)).unwrap();
        assert_eq!(state.allocated_gpus(), 16);
        assert_eq!(state.placements_of(JobId(1)).unwrap().len(), 2);
    }

    #[test]
    fn gang_is_all_or_nothing() {
        let mut state = state_2x4();
        let mut rsch = Rsch::new(RschConfig::default(), &state);
        // 9 whole-node pods on an 8-node cluster.
        let err = rsch.place(&mut state, &train(1, 9, 8)).unwrap_err();
        assert_eq!(err, PlaceFailure::Unsatisfiable); // 72 > 64 capacity.
        // 8 pods fit exactly.
        rsch.place(&mut state, &train(2, 8, 8)).unwrap();
        assert_eq!(state.allocated_gpus(), 64);
        // Next job: resources, not unsatisfiable.
        let err = rsch.place(&mut state, &train(3, 1, 8)).unwrap_err();
        assert_eq!(err, PlaceFailure::Resources);
        assert!(state.placements_of(JobId(3)).is_none());
    }

    #[test]
    fn oversized_pod_unsatisfiable() {
        let mut state = state_2x4();
        let mut rsch = Rsch::new(RschConfig::default(), &state);
        let err = rsch.place(&mut state, &train(1, 1, 9)).unwrap_err();
        assert_eq!(err, PlaceFailure::Unsatisfiable);
    }

    #[test]
    fn ebinpack_consolidates_small_jobs_on_one_node() {
        let mut state = state_2x4();
        let mut rsch = Rsch::new(RschConfig::default(), &state);
        // Three 2-GPU jobs should stack onto the same node.
        for id in 1..=3 {
            rsch.place(&mut state, &train(id, 1, 2)).unwrap();
        }
        let n0 = state.nodes_of(JobId(1))[0];
        assert_eq!(state.nodes_of(JobId(2)), vec![n0]);
        assert_eq!(state.nodes_of(JobId(3)), vec![n0]);
        assert!((state.fragmentation_ratio(None) - 1.0 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn spread_scatters_inference_replicas() {
        let mut state = state_2x4();
        let cfg = RschConfig {
            inference_strategy: PlacementStrategy::Spread,
            ..RschConfig::default()
        };
        let mut rsch = Rsch::new(cfg, &state);
        let mut spec = JobSpec::homogeneous(JobId(1), TenantId(0), JobKind::Inference, G, 4, 1);
        spec.strategy = Some(PlacementStrategy::Spread);
        rsch.place(&mut state, &spec).unwrap();
        // 4 replicas on 4 distinct nodes.
        assert_eq!(state.nodes_of(JobId(1)).len(), 4);
    }

    #[test]
    fn multi_node_gang_stays_in_one_group_when_possible() {
        let mut state = state_2x4();
        let mut rsch = Rsch::new(RschConfig::default(), &state);
        // 4 whole nodes = exactly one group.
        rsch.place(&mut state, &train(1, 4, 8)).unwrap();
        let nodes = state.nodes_of(JobId(1));
        assert_eq!(nodes.len(), 4);
        assert_eq!(state.fabric.groups_spanned(&nodes), 1);
    }

    #[test]
    fn espread_prefers_zone_then_falls_back() {
        let mut spec3 = ClusterSpec::homogeneous("z", 1, 4, 2);
        spec3.inference_zone_frac = 0.25; // Group 3 is the zone (2 nodes).
        let mut state = ClusterBuilder::build(&spec3);
        let mut rsch = Rsch::new(RschConfig::default(), &state);
        // Small inference pods land in the zone.
        let mut inf = JobSpec::homogeneous(JobId(1), TenantId(0), JobKind::Inference, G, 2, 1);
        inf.strategy = Some(PlacementStrategy::ESpread);
        rsch.place(&mut state, &inf).unwrap();
        for n in state.nodes_of(JobId(1)) {
            assert_eq!(state.node(n).zone, Zone::InferenceDedicated);
        }
        // Fill the zone completely.
        let mut filler = JobSpec::homogeneous(JobId(2), TenantId(0), JobKind::Inference, G, 14, 1);
        filler.strategy = Some(PlacementStrategy::ESpread);
        rsch.place(&mut state, &filler).unwrap();
        // Overflow replica must fall back to the general pool.
        let mut inf2 = JobSpec::homogeneous(JobId(3), TenantId(0), JobKind::Inference, G, 1, 1);
        inf2.strategy = Some(PlacementStrategy::ESpread);
        rsch.place(&mut state, &inf2).unwrap();
        let n = state.nodes_of(JobId(3))[0];
        assert_eq!(state.node(n).zone, Zone::General);
    }

    #[test]
    fn first_fit_baseline_walks_node_order() {
        let mut state = state_2x4();
        let mut rsch = Rsch::new(RschConfig::first_fit_baseline(), &state);
        rsch.place(&mut state, &train(1, 1, 2)).unwrap();
        assert_eq!(state.nodes_of(JobId(1)), vec![NodeId(0)]);
        rsch.place(&mut state, &train(2, 1, 8)).unwrap();
        // Node 0 has only 6 free → next node.
        assert_eq!(state.nodes_of(JobId(2)), vec![NodeId(1)]);
    }

    #[test]
    fn mold_shapes_picks_largest_feasible_rung() {
        use crate::job::spec::GangShape;
        let mut state = state_2x4();
        let mut rsch = Rsch::new(RschConfig::default(), &state);
        let ladder = vec![
            GangShape {
                replicas: 8,
                throughput: 1.0,
            },
            GangShape {
                replicas: 4,
                throughput: 0.55,
            },
            GangShape {
                replicas: 2,
                throughput: 0.3,
            },
        ];
        let spec = train(1, 8, 8).with_shapes(ladder);
        // Empty cluster: the full shape is feasible (QSCH treats a pick
        // equal to the active shape as a no-op).
        assert_eq!(rsch.mold_shapes(&state, &[&spec]), vec![Some(0)]);
        // 5 of 8 nodes taken: 24 free / 3 whole-node slots — only the
        // 2-replica rung fits.
        rsch.place(&mut state, &train(9, 5, 8)).unwrap();
        assert_eq!(rsch.mold_shapes(&state, &[&spec]), vec![Some(2)]);
        // Saturated: not even the smallest rung fits → keep the shape.
        rsch.place(&mut state, &train(10, 3, 8)).unwrap();
        assert_eq!(rsch.mold_shapes(&state, &[&spec]), vec![None]);
        // Fixed (ladder-less) jobs are never molded.
        assert_eq!(rsch.mold_shapes(&state, &[&train(2, 4, 8)]), vec![None]);
    }

    #[test]
    fn mold_ledger_serializes_the_batch_in_queue_order() {
        use crate::job::spec::GangShape;
        let mut state = state_2x4();
        let mut rsch = Rsch::new(RschConfig::default(), &state);
        let ladder = vec![
            GangShape {
                replicas: 4,
                throughput: 1.0,
            },
            GangShape {
                replicas: 2,
                throughput: 0.55,
            },
        ];
        let a = train(1, 4, 8).with_shapes(ladder.clone());
        let b = train(2, 4, 8).with_shapes(ladder);
        // 8 free nodes: both gangs keep their full 4-node shapes.
        assert_eq!(rsch.mold_shapes(&state, &[&a, &b]), vec![Some(0), Some(0)]);
        // 2 nodes taken → 6 slots. The earlier-queued gang claims 4 at
        // full shape; the later one sees 2 left and slides a rung.
        rsch.place(&mut state, &train(9, 2, 8)).unwrap();
        assert_eq!(rsch.mold_shapes(&state, &[&a, &b]), vec![Some(0), Some(1)]);
    }

    #[test]
    fn two_level_equals_flat_feasibility() {
        // Whatever two-level does, it must not lose schedulability for a
        // simple sequence that flat placement can schedule.
        let mut s1 = state_2x4();
        let mut s2 = state_2x4();
        let mut two = Rsch::new(RschConfig::default(), &s1);
        let flat = RschConfig {
            two_level: false,
            ..RschConfig::default()
        };
        let mut flat = Rsch::new(flat, &s2);
        for id in 1..=8 {
            assert!(two.place(&mut s1, &train(id, 1, 8)).is_ok());
            assert!(flat.place(&mut s2, &train(id, 1, 8)).is_ok());
        }
        assert_eq!(s1.allocated_gpus(), 64);
        assert_eq!(s2.allocated_gpus(), 64);
    }

    #[test]
    fn hbd_job_lands_in_single_domain() {
        let mut spec = ClusterSpec::homogeneous("h", 1, 2, 4);
        spec.hbd_size = 2; // 2-node (16-GPU) HBDs.
        let mut state = ClusterBuilder::build(&spec);
        let mut rsch = Rsch::new(RschConfig::default(), &state);
        let mut job = train(1, 2, 8);
        job.needs_hbd = true;
        rsch.place(&mut state, &job).unwrap();
        let nodes = state.nodes_of(JobId(1));
        assert_eq!(nodes.len(), 2);
        let h0 = state.node(nodes[0]).hbd.unwrap();
        assert!(nodes.iter().all(|&n| state.node(n).hbd == Some(h0)));
        // A 3-node HBD job can't fit any 2-node domain.
        let mut big = train(2, 3, 8);
        big.needs_hbd = true;
        assert_eq!(rsch.place(&mut state, &big).unwrap_err(), PlaceFailure::Resources);
    }

    #[test]
    fn device_level_allocation_records_nic() {
        let mut state = state_2x4();
        let mut rsch = Rsch::new(RschConfig::default(), &state);
        rsch.place(&mut state, &train(1, 1, 2)).unwrap();
        let p = &state.placements_of(JobId(1)).unwrap()[0];
        assert_eq!(p.devices.len(), 2);
        // Type-H: GPUs 0,1 → NIC 0.
        assert_eq!(p.nic, 0);
    }

    #[test]
    fn parallel_placement_matches_sequential_outcomes() {
        // Same specs through the parallel path and the sequential path:
        // every job that one can place, the other can too, and the
        // resulting allocation totals agree.
        let specs: Vec<JobSpec> = (1..=12)
            .map(|id| train(id, 1, ((id % 4) + 1) as u32 * 2))
            .collect();
        let mut s1 = state_2x4();
        let mut par = Rsch::new(RschConfig::default(), &s1);
        let r1 = par.place_many_parallel(&mut s1, &specs, 4);
        let mut s2 = state_2x4();
        let mut seq = Rsch::new(RschConfig::default(), &s2);
        let r2: Vec<_> = specs.iter().map(|sp| seq.place(&mut s2, sp)).collect();
        assert_eq!(r1.iter().filter(|r| r.is_ok()).count(),
                   r2.iter().filter(|r| r.is_ok()).count());
        assert_eq!(s1.allocated_gpus(), s2.allocated_gpus());
    }

    #[test]
    fn parallel_placement_handles_conflicts() {
        // Jobs that all want the same scarce capacity: optimistic commits
        // conflict and replan; no double allocation, gang invariants hold.
        let mut state = state_2x4(); // 64 GPUs.
        let mut rsch = Rsch::new(RschConfig::default(), &state);
        let specs: Vec<JobSpec> = (1..=10).map(|id| train(id, 1, 8)).collect();
        let results = rsch.place_many_parallel(&mut state, &specs, 4);
        let ok = results.iter().filter(|r| r.is_ok()).count();
        assert_eq!(ok, 8, "exactly 8 whole-node jobs fit");
        assert_eq!(state.allocated_gpus(), 64);
        // Every placed job holds exactly its demand.
        for (spec, r) in specs.iter().zip(&results) {
            if r.is_ok() {
                let gpus: u32 = state
                    .placements_of(spec.id)
                    .unwrap()
                    .iter()
                    .map(|p| p.devices.len() as u32)
                    .sum();
                assert_eq!(gpus, spec.total_gpus());
            } else {
                assert!(state.placements_of(spec.id).is_none());
            }
        }
    }

    #[test]
    fn parallel_single_thread_equals_multi() {
        let specs: Vec<JobSpec> = (1..=9).map(|id| train(id, 1, 4)).collect();
        let mut s1 = state_2x4();
        let mut a = Rsch::new(RschConfig::default(), &s1);
        let r1 = a.place_many_parallel(&mut s1, &specs, 1);
        let mut s2 = state_2x4();
        let mut b = Rsch::new(RschConfig::default(), &s2);
        let r2 = b.place_many_parallel(&mut s2, &specs, 8);
        assert_eq!(r1, r2);
        assert_eq!(s1.allocated_gpus(), s2.allocated_gpus());
    }

    #[test]
    fn stats_accumulate() {
        let mut state = state_2x4();
        let mut rsch = Rsch::new(RschConfig::default(), &state);
        rsch.place(&mut state, &train(1, 2, 4)).unwrap();
        assert_eq!(rsch.stats.placements, 1);
        assert_eq!(rsch.stats.pods_placed, 2);
        assert!(rsch.stats.nodes_examined > 0);
        assert!(rsch.stats.nodes_scored > 0);
        assert!(rsch.stats.groups_scored > 0);
    }

    /// Run the same job sequence through an indexed and a linear-scan RSCH
    /// and demand byte-identical placements plus strictly less filter work
    /// on the indexed side once the cluster is loaded.
    fn assert_indexed_matches_linear(two_level: bool, specs: &[JobSpec]) {
        let mut s_idx = state_2x4();
        let mut s_lin = state_2x4();
        let base = RschConfig {
            two_level,
            ..RschConfig::default()
        };
        let mut idx = Rsch::new(base.clone(), &s_idx);
        let mut lin = Rsch::new(
            RschConfig {
                indexed_candidates: false,
                ..base
            },
            &s_lin,
        );
        for spec in specs {
            let a = idx.place(&mut s_idx, spec);
            let b = lin.place(&mut s_lin, spec);
            assert_eq!(a, b, "outcome diverged for job {}", spec.id);
            assert_eq!(
                s_idx.placements_of(spec.id),
                s_lin.placements_of(spec.id),
                "placements diverged for job {}",
                spec.id
            );
        }
        assert_eq!(s_idx.allocated_gpus(), s_lin.allocated_gpus());
    }

    #[test]
    fn indexed_candidates_match_linear_scan_flat_and_two_level() {
        let specs: Vec<JobSpec> = (1..=14)
            .map(|id| train(id, ((id % 3) + 1) as u32, ((id % 4) + 1) as u32 * 2))
            .collect();
        assert_indexed_matches_linear(false, &specs);
        assert_indexed_matches_linear(true, &specs);
    }

    #[test]
    fn indexed_candidates_examine_fewer_nodes_when_loaded() {
        // Fill 6 of 8 nodes whole; small pods then only fit on 2 nodes,
        // which is all the index should walk in flat mode.
        let mut s = state_2x4();
        let cfg = RschConfig {
            two_level: false,
            ..RschConfig::default()
        };
        let mut rsch = Rsch::new(cfg, &s);
        rsch.place(&mut s, &train(1, 6, 8)).unwrap();
        rsch.stats = RschStats::default();
        rsch.place(&mut s, &train(2, 1, 2)).unwrap();
        let indexed = rsch.stats.nodes_examined;
        assert_eq!(indexed, 2, "index must walk only the two free nodes");

        let mut s2 = state_2x4();
        let mut lin = Rsch::new(
            RschConfig {
                two_level: false,
                indexed_candidates: false,
                ..RschConfig::default()
            },
            &s2,
        );
        lin.place(&mut s2, &train(1, 6, 8)).unwrap();
        lin.stats = RschStats::default();
        lin.place(&mut s2, &train(2, 1, 2)).unwrap();
        assert_eq!(lin.stats.nodes_examined, 8, "linear scan walks the pool");
    }

    #[test]
    fn indexed_parallel_placement_matches_linear_parallel() {
        let specs: Vec<JobSpec> = (1..=12)
            .map(|id| train(id, 1, ((id % 4) + 1) as u32 * 2))
            .collect();
        let mut s_idx = state_2x4();
        let mut idx = Rsch::new(RschConfig::default(), &s_idx);
        let r_idx = idx.place_many_parallel(&mut s_idx, &specs, 4);
        let mut s_lin = state_2x4();
        let mut lin = Rsch::new(
            RschConfig {
                indexed_candidates: false,
                ..RschConfig::default()
            },
            &s_lin,
        );
        let r_lin = lin.place_many_parallel(&mut s_lin, &specs, 4);
        assert_eq!(r_idx, r_lin);
        for spec in &specs {
            assert_eq!(s_idx.placements_of(spec.id), s_lin.placements_of(spec.id));
        }
    }

    #[test]
    fn indexed_espread_zone_phases_match_linear() {
        let mut spec3 = ClusterSpec::homogeneous("z", 1, 4, 2);
        spec3.inference_zone_frac = 0.25;
        let mut s_idx = ClusterBuilder::build(&spec3);
        let mut s_lin = s_idx.clone();
        let mut idx = Rsch::new(RschConfig::default(), &s_idx);
        let mut lin = Rsch::new(
            RschConfig {
                indexed_candidates: false,
                ..RschConfig::default()
            },
            &s_lin,
        );
        for id in 1..=10u64 {
            let mut inf =
                JobSpec::homogeneous(JobId(id), TenantId(0), JobKind::Inference, G, 2, 1);
            inf.strategy = Some(PlacementStrategy::ESpread);
            let a = idx.place(&mut s_idx, &inf);
            let b = lin.place(&mut s_lin, &inf);
            assert_eq!(a, b);
            assert_eq!(s_idx.placements_of(JobId(id)), s_lin.placements_of(JobId(id)));
        }
    }

    /// 4 spines × 1 group × 4 nodes under 2 superspines (2 spines each):
    /// groups 0/1 sit under superspine 0, groups 2/3 under superspine 1.
    fn state_two_superspines() -> ClusterState {
        let mut spec = ClusterSpec::homogeneous("ss", 4, 1, 4);
        spec.spines_per_superspine = 2;
        ClusterBuilder::build(&spec)
    }

    /// Hand-place a 2-GPU non-gang filler so the named group is no longer
    /// pristine (breaks group-score ties deterministically).
    fn filler(state: &mut ClusterState, id: u64, node: u32) {
        use crate::cluster::ids::PodId;
        use crate::cluster::state::PodPlacement;
        state
            .commit_placements(
                JobId(id),
                vec![PodPlacement {
                    pod: PodId::new(JobId(id), 0),
                    node: NodeId(node),
                    devices: vec![0, 1],
                    nic: 0,
                }],
            )
            .unwrap();
    }

    #[test]
    fn truthful_tiers_keep_large_gangs_in_one_superspine() {
        // A 6-node (48-GPU) gang on a half-filler'd fabric. After its
        // first 4 pods fill group 0, the last 2 pods choose between the
        // slightly-busy group 1 (same superspine) and the pristine group
        // 2 (across the core). The truthful scorer stays; the blind
        // baseline chases the emptier group across the superspine — the
        // exact §3.3.5 bug this PR fixes.
        let run = |blind: bool| -> Vec<NodeId> {
            let mut state = state_two_superspines();
            filler(&mut state, 90, 4); // group 1, superspine 0.
            filler(&mut state, 91, 12); // group 3, superspine 1.
            let cfg = RschConfig {
                topo_blind: blind,
                ..RschConfig::default()
            };
            let mut rsch = Rsch::new(cfg, &state);
            rsch.place(&mut state, &train(1, 6, 8)).unwrap();
            let mut nodes = state.nodes_of(JobId(1));
            nodes.sort_unstable();
            nodes
        };
        let truthful = run(false);
        let blind = run(true);
        let fabric = state_two_superspines().fabric;
        assert_eq!(
            fabric.superspines_spanned(&truthful),
            1,
            "truthful gang must stay under one superspine: {truthful:?}"
        );
        assert_eq!(
            fabric.superspines_spanned(&blind),
            2,
            "the blind baseline crosses the core for an emptier group: {blind:?}"
        );
    }

    #[test]
    fn pooled_incremental_matches_rebuild_with_fewer_rows_scored() {
        // PooledIncremental must place byte-identically to PooledRebuild
        // (row invalidation is exact) while rebuilding strictly fewer
        // feature rows — the `nodes_scored` work counter is the proof.
        let run = |mode: GangScoring| {
            let mut state = state_two_superspines();
            filler(&mut state, 90, 4);
            filler(&mut state, 91, 12);
            let cfg = RschConfig {
                gang_scoring: mode,
                ..RschConfig::default()
            };
            let mut rsch = Rsch::new(cfg, &state);
            rsch.place(&mut state, &train(1, 6, 8)).unwrap();
            rsch.place(&mut state, &train(2, 3, 4)).unwrap();
            let placements: Vec<_> = [1u64, 2]
                .iter()
                .map(|&id| state.placements_of(JobId(id)).unwrap().to_vec())
                .collect();
            (placements, rsch.stats.nodes_scored)
        };
        let (inc_placements, inc_rows) = run(GangScoring::PooledIncremental);
        let (reb_placements, reb_rows) = run(GangScoring::PooledRebuild);
        assert_eq!(inc_placements, reb_placements, "modes must place identically");
        assert!(
            inc_rows < reb_rows,
            "incremental must score fewer rows ({inc_rows} vs {reb_rows})"
        );
    }

    #[test]
    fn topology_blindness_cannot_change_topo_agnostic_placements() {
        // Binpack and Spread carry zero topology weight: their placements
        // (and hence same-seed digests) must be invariant to both the
        // truthful-tier fix and the blind ablation flag.
        for strat in [PlacementStrategy::Binpack, PlacementStrategy::Spread] {
            let run = |blind: bool| {
                let mut state = state_two_superspines();
                let mut rsch = Rsch::new(
                    RschConfig {
                        topo_blind: blind,
                        ..RschConfig::default()
                    },
                    &state,
                );
                let mut placements = Vec::new();
                for id in 1..=10u64 {
                    let mut j = train(id, ((id % 3) + 1) as u32, ((id % 4) + 1) as u32 * 2);
                    j.strategy = Some(strat);
                    let _ = rsch.place(&mut state, &j);
                    placements.push(state.placements_of(JobId(id)).map(|p| p.to_vec()));
                }
                placements
            };
            assert_eq!(run(false), run(true), "{strat:?} placements moved with the flag");
        }
    }

    #[test]
    fn prefetch_placements_are_thread_invariant() {
        // The shard structure is topological; `threads` only picks how
        // many workers sweep it — placements, allocation totals, and the
        // digest-visible work counters must be byte-identical.
        let specs: Vec<JobSpec> = (1..=10)
            .map(|id| train(id, ((id % 3) + 1) as u32, ((id % 4) + 1) as u32 * 2))
            .collect();
        let run = |threads: usize| {
            let mut state = state_two_superspines();
            let mut rsch = Rsch::new(RschConfig::default(), &state);
            let refs: Vec<&JobSpec> = specs.iter().collect();
            rsch.prefetch(&state, &refs, threads);
            for spec in &specs {
                let _ = rsch.place(&mut state, spec);
            }
            let placements: Vec<_> = specs
                .iter()
                .map(|sp| state.placements_of(sp.id).map(|p| p.to_vec()))
                .collect();
            (
                placements,
                state.allocated_gpus(),
                rsch.stats.nodes_examined,
                rsch.stats.nodes_scored,
            )
        };
        let one = run(1);
        assert!(one.1 > 0, "batch must place something");
        assert_eq!(one, run(2));
        assert_eq!(one, run(8));
    }

    #[test]
    fn prefetched_plans_commit_without_replanning() {
        let mut state = state_two_superspines();
        let mut rsch = Rsch::new(RschConfig::default(), &state);
        let spec = train(1, 2, 8);
        rsch.prefetch(&state, &[&spec], 4);
        assert!(rsch.plan_cache.contains_key(&JobId(1)));
        rsch.place(&mut state, &spec).unwrap();
        assert_eq!(state.allocated_gpus(), 16);
        // One refresh for the prefetch, none for the cached commit.
        assert_eq!(rsch.stats.snapshot_refreshes, 1);
        assert!(rsch.plan_cache.is_empty());
    }

    /// Hand-place `gpus` devices on one node (bigger sibling of `filler`
    /// for shaping per-shard headroom exactly).
    fn fill_node(state: &mut ClusterState, id: u64, node: u32, gpus: u8) {
        use crate::cluster::ids::PodId;
        use crate::cluster::state::PodPlacement;
        state
            .commit_placements(
                JobId(id),
                vec![PodPlacement {
                    pod: PodId::new(JobId(id), 0),
                    node: NodeId(node),
                    devices: (0..gpus).collect(),
                    nic: 0,
                }],
            )
            .unwrap();
    }

    #[test]
    fn prefetch_routes_hbd_jobs_to_shards_with_adequate_domains() {
        // Two superspines with 2-node (16-GPU) HBDs. Both shards hold 56
        // free GPUs, but shard 0's domains are all nibbled (2 GPUs on one
        // node of each), so its best free HBD is 14 GPUs — shard 1 keeps
        // whole domains. The old routing compared pool headroom only:
        // the tie broke to shard 0, whose planner can never place the
        // gang, and the job fell through to the serialized global phase.
        let mut spec = ClusterSpec::homogeneous("ss", 4, 1, 4);
        spec.spines_per_superspine = 2;
        spec.hbd_size = 2;
        let mut state = ClusterBuilder::build(&spec);
        for (k, node) in [0u32, 2, 4, 6].into_iter().enumerate() {
            filler(&mut state, 90 + k as u64, node); // Shard 0: -2 × 4.
        }
        fill_node(&mut state, 94, 8, 8); // Shard 1: -8, one domain spent.
        let mut rsch = Rsch::new(RschConfig::default(), &state);
        let mut job = train(1, 2, 8);
        job.needs_hbd = true;
        rsch.prefetch(&state, &[&job], 2);
        assert!(
            rsch.plan_cache.contains_key(&JobId(1)),
            "the HBD-feasible shard must get the plan"
        );
        rsch.place(&mut state, &job).unwrap();
        let nodes = state.nodes_of(JobId(1));
        assert!(
            nodes.iter().all(|&n| n.index() >= 8),
            "gang must land in superspine 1's free domain: {nodes:?}"
        );
        // And the cached plan committed without a global replan.
        assert_eq!(rsch.stats.snapshot_refreshes, 1);
    }

    #[test]
    fn adapt_tick_publishes_overlay_and_telemetry() {
        let state = state_2x4();
        let cfg = RschConfig {
            adapt: adapt::AdaptConfig {
                enabled: true,
                seed: 7,
                ..adapt::AdaptConfig::default()
            },
            ..RschConfig::default()
        };
        let mut rsch = Rsch::new(cfg, &state);
        assert!(rsch.wants_adapt());
        // High fragmentation on a busy cluster: the packing axis moves.
        rsch.adapt_tick(&AdaptSignals {
            gar: 0.9,
            gfr: 0.5,
            ..AdaptSignals::default()
        });
        assert!(rsch.cfg.overlay.pack_bias > 0.0);
        assert_eq!(rsch.stats.adapt_ticks, 1);
        assert_eq!(rsch.stats.adapt_shifts, 1);
        assert_ne!(rsch.stats.adapt_fingerprint, 0);
        // The published overlay reaches the scoring rows...
        let base = node_weights(PlacementStrategy::EBinpack, Phase::Primary, false);
        let adapted = rsch.cfg.node_w(PlacementStrategy::EBinpack, Phase::Primary, false);
        assert!(adapted[0] > base[0]);
        // ...but never the topology component or first-fit semantics.
        assert_eq!(adapted[W_TOPO], base[W_TOPO]);
        assert_eq!(
            rsch.cfg.node_w(PlacementStrategy::NativeFirstFit, Phase::Primary, false),
            [0.0; score::NUM_COMPONENTS]
        );
    }

    #[test]
    fn disabled_controller_is_bitwise_frozen() {
        let state = state_2x4();
        let mut rsch = Rsch::new(RschConfig::default(), &state);
        assert!(!rsch.wants_adapt());
        rsch.adapt_tick(&AdaptSignals {
            gar: 0.9,
            gfr: 0.5,
            ..AdaptSignals::default()
        });
        assert!(rsch.cfg.overlay.is_zero());
        assert_eq!(rsch.stats.adapt_ticks, 0);
        for strat in [
            PlacementStrategy::NativeFirstFit,
            PlacementStrategy::Binpack,
            PlacementStrategy::EBinpack,
            PlacementStrategy::Spread,
            PlacementStrategy::ESpread,
        ] {
            for phase in [Phase::Primary, Phase::Fallback] {
                for large in [false, true] {
                    assert_eq!(
                        rsch.cfg.node_w(strat, phase, large),
                        node_weights(strat, phase, large)
                    );
                    assert_eq!(
                        rsch.cfg.group_w(strat, phase, large),
                        group_weights(strat, phase, large)
                    );
                }
            }
        }
    }

    #[test]
    fn cross_superspine_gang_takes_global_phase() {
        // Each superspine holds 64 GPUs; an 80-GPU gang fits no single
        // shard, so prefetch must leave it to the serialized global path.
        let mut state = state_two_superspines();
        let mut rsch = Rsch::new(RschConfig::default(), &state);
        let spec = train(1, 10, 8);
        rsch.prefetch(&state, &[&spec], 4);
        assert!(rsch.plan_cache.is_empty());
        rsch.place(&mut state, &spec).unwrap();
        assert_eq!(state.allocated_gpus(), 80);
        // Refresh for the prefetch and for the sequential fallback.
        assert_eq!(rsch.stats.snapshot_refreshes, 2);
    }
}
