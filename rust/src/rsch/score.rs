//! Scoring: strategy weight vectors and the native (pure-Rust) scorer
//! backend. The math mirrors `python/compile/kernels/ref.py` exactly, in
//! f32, so the native and XLA backends are interchangeable and
//! parity-testable.
//!
//! The tables in [`node_weights`] / [`group_weights`] are the *frozen*
//! hand-tuned baseline: the adaptive controller
//! ([`adapt`](super::adapt)) never edits them — it adds a bounded
//! [`WeightOverlay`](super::adapt::WeightOverlay) on top via
//! [`RschConfig`](super::RschConfig)'s weight accessors, and only when
//! `--adapt` is on. `--no-adapt` runs read these rows bitwise-unchanged
//! (regression-pinned in the tests below and in `tests/adaptation.rs`).

use crate::job::spec::PlacementStrategy;

use super::features::{GROUP_F, JOB_D, NODE_F};

/// Number of node score components / weights.
pub const NUM_COMPONENTS: usize = 8;
/// Index of the topology-closeness component in a node weight row. A
/// zero `w[W_TOPO]` marks the strategy topology-agnostic: its placements
/// are invariant to tier truthfulness (the digest guarantee Binpack /
/// Spread carry across the truthful-tier refactor), and RSCH only takes
/// the pooled gang-scoring fast path when this component is live.
pub const W_TOPO: usize = 4;
/// Number of group score components / weights.
pub const GROUP_COMPONENTS: usize = 6;
/// Infeasible-node sink value (finite so sorting stays total).
pub const BIG: f32 = 1.0e9;
const EPS: f32 = 1.0e-6;

/// A scoring backend: native Rust or the AOT XLA artifact.
pub trait ScoreBackend {
    /// Score `n` nodes; `feat` is row-major `[n, NODE_F]`.
    fn score_nodes(
        &mut self,
        feat: &[f32],
        n: usize,
        job: &[f32; JOB_D],
        weights: &[f32; NUM_COMPONENTS],
    ) -> Vec<f32>;

    /// Score `g` groups; `gfeat` is row-major `[g, GROUP_F]`.
    fn score_groups(
        &mut self,
        gfeat: &[f32],
        g: usize,
        job: &[f32; JOB_D],
        weights: &[f32; GROUP_COMPONENTS],
    ) -> Vec<f32>;

    fn name(&self) -> &'static str;
}

/// Which phase of a (possibly two-phase) strategy is scoring: E-Spread
/// first targets the dedicated zone, then falls back to the general pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Primary,
    Fallback,
}

/// Node weight vector for a strategy (component order: fill, spread,
/// group_pack, group_empty, topo, colocate, zone, nvlink — see ref.py).
pub fn node_weights(
    strategy: PlacementStrategy,
    phase: Phase,
    large_job: bool,
) -> [f32; NUM_COMPONENTS] {
    match (strategy, phase) {
        // First fit: all-zero weights; argmax with index tiebreak = lowest
        // feasible node id.
        (PlacementStrategy::NativeFirstFit, _) => [0.0; NUM_COMPONENTS],
        (PlacementStrategy::Binpack, _) => {
            [1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]
        }
        (PlacementStrategy::EBinpack, _) => {
            if large_job {
                // Large gangs prefer empty groups (reserve busy groups for
                // small jobs) and tight topology. With the truthful 5-tier
                // scale (topo step = w_topo / 4 per tier), w_topo = 1.6
                // makes one tier worth 0.4 — so a candidate in another
                // superspine only wins over a same-superspine one when the
                // remote group is > 2/3 emptier (0.4 / w_group_empty):
                // large gangs actively avoid core-layer crossings instead
                // of scoring them at zero cost.
                [1.0, 0.0, 0.0, 0.6, 1.6, 0.4, -0.5, 0.2]
            } else {
                // Small jobs consolidate: busy groups, co-located pods.
                // (0.6 / 4 per tier ≈ the old 0.5 / 3 step.)
                [1.0, 0.0, 0.6, 0.0, 0.6, 0.8, -0.3, 0.2]
            }
        }
        (PlacementStrategy::Spread, _) => {
            [0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.1]
        }
        // E-Spread primary: spread *inside* the dedicated zone.
        (PlacementStrategy::ESpread, Phase::Primary) => {
            [0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 2.0, 0.1]
        }
        // E-Spread fallback: consolidate in the general pool (E-Binpack
        // weights, zone-averse; topo step matches the small-job row).
        (PlacementStrategy::ESpread, Phase::Fallback) => {
            [1.0, 0.0, 0.6, 0.0, 0.6, 0.8, -0.5, 0.2]
        }
    }
}

/// Group weight vector (component order: pack, empty, colocate, zone,
/// health, whole_fit).
pub fn group_weights(
    strategy: PlacementStrategy,
    phase: Phase,
    large_job: bool,
) -> [f32; GROUP_COMPONENTS] {
    match (strategy, phase) {
        (PlacementStrategy::NativeFirstFit, _) => [0.0; GROUP_COMPONENTS],
        (PlacementStrategy::Binpack, _) => [1.0, 0.0, 0.0, 0.0, 0.1, 0.0],
        (PlacementStrategy::EBinpack, _) => {
            if large_job {
                // colocate dominates: once a gang starts filling a group it
                // must stay there (each whole-node pod costs ~0.25·0.6 of
                // `empty`; one pod adds 1/64·16 = 0.25 of colocate).
                [0.0, 0.6, 16.0, -0.5, 0.3, 1.0]
            } else {
                [1.0, 0.0, 0.8, -0.5, 0.3, 0.0]
            }
        }
        (PlacementStrategy::Spread, _) => [0.0, 0.3, 0.0, 0.0, 0.3, 0.0],
        (PlacementStrategy::ESpread, Phase::Primary) => {
            [0.0, 0.3, 0.3, 2.0, 0.2, 0.0]
        }
        (PlacementStrategy::ESpread, Phase::Fallback) => {
            [1.0, 0.0, 0.8, -0.5, 0.3, 0.0]
        }
    }
}

/// Is the job "large" for E-Binpack's group policy? Large jobs get whole
/// (empty) LeafGroups; small jobs consolidate into busy ones (§3.3.3).
pub fn is_large_job(total_gpus: u32, group_total_gpus: u32) -> bool {
    total_gpus * 4 >= group_total_gpus // ≥ 25 % of a LeafGroup.
}

/// The native scorer: straight-line Rust implementing the ref.py contract.
#[derive(Debug, Default, Clone)]
pub struct NativeBackend;

impl ScoreBackend for NativeBackend {
    fn score_nodes(
        &mut self,
        feat: &[f32],
        n: usize,
        job: &[f32; JOB_D],
        w: &[f32; NUM_COMPONENTS],
    ) -> Vec<f32> {
        debug_assert_eq!(feat.len(), n * NODE_F);
        let gpus_per_pod = job[0];
        let mut out = Vec::with_capacity(n);
        for row in feat.chunks_exact(NODE_F) {
            let free = row[0];
            let total = row[1].max(EPS);
            let alloc = row[2];
            let healthy = row[3];
            let group_free = row[4];
            let group_total = row[5].max(EPS);
            let pods_on_node = row[6];
            let topo_tier = row[8];
            let in_zone = row[9];
            let clique = row[11];

            let fill_after = ((alloc + gpus_per_pod) / total).clamp(0.0, 1.0);
            let spread = 1.0 - (alloc / total).clamp(0.0, 1.0);
            let group_pack = 1.0 - (group_free / group_total).clamp(0.0, 1.0);
            let group_empty = (group_free / group_total).clamp(0.0, 1.0);
            // Truthful 5-tier scale: 0 node … 4 cross-superspine, so a
            // same-superspine candidate keeps a 0.25 edge over one that
            // crosses the core layer (mirrors ref.py; keep in lockstep).
            let topo = 1.0 - topo_tier.clamp(0.0, 4.0) / 4.0;
            let colocate = pods_on_node.clamp(0.0, 8.0) / 8.0;
            let nvlink = if clique >= gpus_per_pod { 1.0 } else { 0.0 };

            let raw = w[0] * fill_after
                + w[1] * spread
                + w[2] * group_pack
                + w[3] * group_empty
                + w[4] * topo
                + w[5] * colocate
                + w[6] * in_zone
                + w[7] * nvlink;

            let mask = if healthy > 0.5 && free >= gpus_per_pod {
                1.0
            } else {
                0.0
            };
            out.push(mask * raw + (mask - 1.0) * BIG);
        }
        out
    }

    fn score_groups(
        &mut self,
        gfeat: &[f32],
        g: usize,
        job: &[f32; JOB_D],
        w: &[f32; GROUP_COMPONENTS],
    ) -> Vec<f32> {
        debug_assert_eq!(gfeat.len(), g * GROUP_F);
        let mut out = Vec::with_capacity(g);
        for row in gfeat.chunks_exact(GROUP_F) {
            let free = row[0];
            let total = row[1].max(EPS);
            let pods_in_group = row[2];
            let zone_frac = row[3];
            let healthy_frac = row[4];
            let whole_free = row[5];

            let pack = 1.0 - (free / total).clamp(0.0, 1.0);
            let empty = (free / total).clamp(0.0, 1.0);
            let colocate = pods_in_group.clamp(0.0, 64.0) / 64.0;
            let need_nodes = (job[1] / 8.0).ceil();
            let whole_fit = (whole_free / need_nodes.max(1.0)).clamp(0.0, 1.0);

            let raw = w[0] * pack
                + w[1] * empty
                + w[2] * colocate
                + w[3] * zone_frac
                + w[4] * healthy_frac
                + w[5] * whole_fit;

            let mask = if free >= job[0] && healthy_frac > 0.0 {
                1.0
            } else {
                0.0
            };
            out.push(mask * raw + (mask - 1.0) * BIG);
        }
        out
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Argmax with lowest-index tiebreak (matches the XLA stable argsort).
pub fn argmax(scores: &[f32]) -> Option<usize> {
    let mut best: Option<(usize, f32)> = None;
    for (i, &s) in scores.iter().enumerate() {
        match best {
            None => best = Some((i, s)),
            Some((_, bs)) if s > bs => best = Some((i, s)),
            _ => {}
        }
    }
    best.map(|(i, _)| i)
}

/// Is a score the infeasible sink?
#[inline]
pub fn feasible(score: f32) -> bool {
    score > -BIG / 2.0
}

/// The moldable-admission shape rule: a shape ladder is ordered by
/// strictly decreasing replicas and (by [`crate::job::trace`]
/// validation) decreasing throughput, so the *first* index the
/// feasibility probe accepts is the goodput-maximizing pick — full
/// shape when the cluster has room, sliding down the ladder only as far
/// as fragmentation forces. `None` means not even the smallest rung
/// fits right now; the caller keeps the current shape rather than
/// thrash a saturated cluster to the floor.
pub fn best_feasible_shape(
    shapes: &[crate::job::spec::GangShape],
    mut probe: impl FnMut(&crate::job::spec::GangShape) -> bool,
) -> Option<usize> {
    shapes.iter().position(|s| probe(s))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(
        free: f32,
        total: f32,
        alloc: f32,
        healthy: f32,
        group_free: f32,
        group_total: f32,
    ) -> [f32; NODE_F] {
        let mut r = [0.0; NODE_F];
        r[0] = free;
        r[1] = total;
        r[2] = alloc;
        r[3] = healthy;
        r[4] = group_free;
        r[5] = group_total;
        r[8] = 4.0; // WORST: nothing placed yet.
        r[11] = free;
        r
    }

    #[test]
    fn best_feasible_shape_walks_down_the_ladder() {
        use crate::job::spec::GangShape;
        let ladder = [
            GangShape {
                replicas: 8,
                throughput: 1.0,
            },
            GangShape {
                replicas: 4,
                throughput: 0.55,
            },
            GangShape {
                replicas: 2,
                throughput: 0.3,
            },
        ];
        // Plenty of room: keep the full shape.
        assert_eq!(best_feasible_shape(&ladder, |_| true), Some(0));
        // Only 4 replicas fit: slide one rung.
        assert_eq!(best_feasible_shape(&ladder, |s| s.replicas <= 4), Some(1));
        // Nothing fits: keep the current shape (no pick).
        assert_eq!(best_feasible_shape(&ladder, |_| false), None);
        assert_eq!(best_feasible_shape(&[], |_| true), None);
    }

    #[test]
    fn binpack_prefers_fuller_node() {
        let mut b = NativeBackend;
        let feat: Vec<f32> = [
            row(8.0, 8.0, 0.0, 1.0, 64.0, 64.0), // Empty node.
            row(4.0, 8.0, 4.0, 1.0, 60.0, 64.0), // Half-full node.
        ]
        .concat();
        let job = [2.0, 2.0, 1.0, 0.0, 0.0, 1.0, 0.0, 0.0];
        let w = node_weights(PlacementStrategy::Binpack, Phase::Primary, false);
        let s = b.score_nodes(&feat, 2, &job, &w);
        assert!(s[1] > s[0], "binpack must prefer the fuller node: {s:?}");
    }

    #[test]
    fn spread_prefers_emptier_node() {
        let mut b = NativeBackend;
        let feat: Vec<f32> = [
            row(8.0, 8.0, 0.0, 1.0, 64.0, 64.0),
            row(4.0, 8.0, 4.0, 1.0, 60.0, 64.0),
        ]
        .concat();
        let job = [1.0, 4.0, 0.0, 1.0, 0.0, 3.0, 0.0, 0.0];
        let w = node_weights(PlacementStrategy::Spread, Phase::Primary, false);
        let s = b.score_nodes(&feat, 2, &job, &w);
        assert!(s[0] > s[1], "spread must prefer the emptier node: {s:?}");
    }

    #[test]
    fn infeasible_sinks_below_feasible() {
        let mut b = NativeBackend;
        let feat: Vec<f32> = [
            row(1.0, 8.0, 7.0, 1.0, 64.0, 64.0), // Too few free.
            row(8.0, 8.0, 0.0, 0.0, 64.0, 64.0), // Unhealthy.
            row(8.0, 8.0, 0.0, 1.0, 64.0, 64.0), // Feasible.
        ]
        .concat();
        let job = [4.0, 4.0, 1.0, 0.0, 0.0, 2.0, 0.0, 0.0];
        let w = node_weights(PlacementStrategy::EBinpack, Phase::Primary, false);
        let s = b.score_nodes(&feat, 3, &job, &w);
        assert!(!feasible(s[0]) && !feasible(s[1]) && feasible(s[2]));
        assert_eq!(argmax(&s), Some(2));
    }

    #[test]
    fn first_fit_ties_break_by_index() {
        let mut b = NativeBackend;
        let feat: Vec<f32> = [
            row(8.0, 8.0, 0.0, 1.0, 64.0, 64.0),
            row(8.0, 8.0, 0.0, 1.0, 64.0, 64.0),
        ]
        .concat();
        let job = [1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let w = node_weights(PlacementStrategy::NativeFirstFit, Phase::Primary, false);
        let s = b.score_nodes(&feat, 2, &job, &w);
        assert_eq!(argmax(&s), Some(0));
    }

    #[test]
    fn espread_primary_pulls_into_zone() {
        let mut b = NativeBackend;
        let mut in_zone = row(8.0, 8.0, 0.0, 1.0, 64.0, 64.0);
        in_zone[9] = 1.0;
        let out_zone = row(8.0, 8.0, 0.0, 1.0, 64.0, 64.0);
        let feat: Vec<f32> = [out_zone, in_zone].concat();
        let job = [1.0, 2.0, 0.0, 1.0, 0.0, 4.0, 0.0, 0.0];
        let w = node_weights(PlacementStrategy::ESpread, Phase::Primary, false);
        let s = b.score_nodes(&feat, 2, &job, &w);
        assert!(s[1] > s[0]);
        // Fallback phase is zone-averse.
        let w = node_weights(PlacementStrategy::ESpread, Phase::Fallback, false);
        let s = b.score_nodes(&feat, 2, &job, &w);
        assert!(s[0] > s[1]);
    }

    #[test]
    fn large_job_group_weights_prefer_empty_groups() {
        let mut b = NativeBackend;
        // Group rows: free,total,pods,zone,health,whole_free.
        let gfeat: Vec<f32> = [
            [100.0, 256.0, 0.0, 0.0, 1.0, 4.0], // Busy group.
            [256.0, 256.0, 0.0, 0.0, 1.0, 32.0], // Empty group.
        ]
        .concat();
        let job = [8.0, 512.0, 1.0, 0.0, 1.0, 2.0, 0.0, 0.0];
        let w = group_weights(PlacementStrategy::EBinpack, Phase::Primary, true);
        let s = b.score_groups(&gfeat, 2, &job, &w);
        assert!(s[1] > s[0], "{s:?}");
        // Small jobs go the other way.
        let job_small = [2.0, 2.0, 1.0, 0.0, 0.0, 2.0, 0.0, 0.0];
        let w = group_weights(PlacementStrategy::EBinpack, Phase::Primary, false);
        let s = b.score_groups(&gfeat, 2, &job_small, &w);
        assert!(s[0] > s[1], "{s:?}");
    }

    #[test]
    fn group_mask_blocks_empty_capacity() {
        let mut b = NativeBackend;
        let gfeat: Vec<f32> = [[0.0, 256.0, 0.0, 0.0, 1.0, 0.0]].concat();
        let job = [8.0, 8.0, 1.0, 0.0, 1.0, 2.0, 0.0, 0.0];
        let w = group_weights(PlacementStrategy::EBinpack, Phase::Primary, false);
        let s = b.score_groups(&gfeat, 1, &job, &w);
        assert!(!feasible(s[0]));
    }

    #[test]
    fn large_gang_weights_penalize_core_crossings() {
        // Two otherwise-identical empty nodes; one sits in the gang's
        // superspine (tier 3), the other across the core (tier 4). The
        // truthful scorer must prefer staying — and must keep preferring
        // it even when the remote node's group is moderately emptier.
        let mut b = NativeBackend;
        let mut near = row(8.0, 8.0, 0.0, 1.0, 128.0, 256.0);
        near[8] = 3.0;
        let mut far = row(8.0, 8.0, 0.0, 1.0, 256.0, 256.0);
        far[8] = 4.0;
        let feat: Vec<f32> = [near, far].concat();
        let job = [8.0, 512.0, 1.0, 0.0, 1.0, 2.0, 0.0, 0.0];
        let w = node_weights(PlacementStrategy::EBinpack, Phase::Primary, true);
        let s = b.score_nodes(&feat, 2, &job, &w);
        assert!(
            s[0] > s[1],
            "same-superspine must beat a core crossing despite a half-empty group: {s:?}"
        );
    }

    #[test]
    fn frozen_table_regression() {
        // The hand-tuned PR-5 rows are the `--no-adapt` contract: any
        // retune must be deliberate and update this pin (and the digest
        // goldens) in the same change.
        use PlacementStrategy::*;
        assert_eq!(node_weights(EBinpack, Phase::Primary, true),
                   [1.0, 0.0, 0.0, 0.6, 1.6, 0.4, -0.5, 0.2]);
        assert_eq!(node_weights(EBinpack, Phase::Primary, false),
                   [1.0, 0.0, 0.6, 0.0, 0.6, 0.8, -0.3, 0.2]);
        assert_eq!(node_weights(ESpread, Phase::Primary, false),
                   [0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 2.0, 0.1]);
        assert_eq!(node_weights(ESpread, Phase::Fallback, false),
                   [1.0, 0.0, 0.6, 0.0, 0.6, 0.8, -0.5, 0.2]);
        assert_eq!(group_weights(EBinpack, Phase::Primary, true),
                   [0.0, 0.6, 16.0, -0.5, 0.3, 1.0]);
        assert_eq!(group_weights(EBinpack, Phase::Primary, false),
                   [1.0, 0.0, 0.8, -0.5, 0.3, 0.0]);
        assert_eq!(node_weights(NativeFirstFit, Phase::Primary, true), [0.0; NUM_COMPONENTS]);
    }

    #[test]
    fn is_large_job_threshold() {
        assert!(is_large_job(64, 256));
        assert!(!is_large_job(63, 256));
        assert!(is_large_job(2048, 256));
    }
}
