//! The XLA scorer backend: serves RSCH's scoring hot path from the
//! AOT-compiled artifacts (L1 Pallas kernel → L2 JAX pipeline → HLO text →
//! PJRT executable). Interchangeable with the native Rust scorer; parity
//! between the two is tested in `rust/tests/xla_parity.rs`.

use anyhow::{bail, Context, Result};

use crate::rsch::features::{GROUP_F, JOB_D, NODE_F};
use crate::rsch::score::{ScoreBackend, BIG, GROUP_COMPONENTS, NUM_COMPONENTS};
use crate::util::json::Json;

use super::client::{literal_f32_1d, literal_f32_2d, Runtime};

/// Artifact inventory parsed from `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub node_sizes: Vec<(usize, String)>, // Ascending (n, file).
    pub group_sizes: Vec<(usize, String)>,
}

impl Manifest {
    pub fn load(artifacts_dir: &std::path::Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Json::parse(&text).context("parsing manifest.json")?;
        if v.get("node_f").and_then(Json::as_u64) != Some(NODE_F as u64)
            || v.get("job_d").and_then(Json::as_u64) != Some(JOB_D as u64)
            || v.get("num_components").and_then(Json::as_u64) != Some(NUM_COMPONENTS as u64)
        {
            bail!("manifest layout mismatch — rebuild artifacts (make artifacts)");
        }
        let mut node_sizes = Vec::new();
        for e in v
            .get("node_scorers")
            .and_then(Json::as_arr)
            .context("manifest.node_scorers")?
        {
            node_sizes.push((
                e.get("n").and_then(Json::as_u64).context("n")? as usize,
                e.get("file").and_then(Json::as_str).context("file")?.to_string(),
            ));
        }
        let mut group_sizes = Vec::new();
        for e in v
            .get("group_scorers")
            .and_then(Json::as_arr)
            .context("manifest.group_scorers")?
        {
            group_sizes.push((
                e.get("g").and_then(Json::as_u64).context("g")? as usize,
                e.get("file").and_then(Json::as_str).context("file")?.to_string(),
            ));
        }
        node_sizes.sort_by_key(|&(n, _)| n);
        group_sizes.sort_by_key(|&(g, _)| g);
        anyhow::ensure!(!node_sizes.is_empty(), "no node scorers in manifest");
        anyhow::ensure!(!group_sizes.is_empty(), "no group scorers in manifest");
        Ok(Manifest {
            node_sizes,
            group_sizes,
        })
    }

    /// Smallest artifact with capacity ≥ n, else the largest (chunked).
    fn pick(sizes: &[(usize, String)], n: usize) -> (usize, &str) {
        for (cap, file) in sizes {
            if *cap >= n {
                return (*cap, file);
            }
        }
        let (cap, file) = sizes.last().unwrap();
        (*cap, file)
    }
}

/// Scorer backend executing the AOT artifacts through PJRT.
pub struct XlaBackend {
    runtime: Runtime,
    manifest: Manifest,
    /// Executed-launch counter (per-cycle cost signal for §Perf).
    pub launches: u64,
}

impl XlaBackend {
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<XlaBackend> {
        let dir = artifacts_dir.as_ref();
        let manifest = Manifest::load(dir)?;
        let runtime = Runtime::cpu(dir)?;
        Ok(XlaBackend {
            runtime,
            manifest,
            launches: 0,
        })
    }

    /// Warm the executable cache (compile everything up front so the first
    /// scheduling cycle doesn't pay JIT latency).
    pub fn warmup(&mut self) -> Result<()> {
        let files: Vec<String> = self
            .manifest
            .node_sizes
            .iter()
            .chain(self.manifest.group_sizes.iter())
            .map(|(_, f)| f.clone())
            .collect();
        for f in files {
            self.runtime.load(&f)?;
        }
        Ok(())
    }

    fn run_node_chunk(
        &mut self,
        feat: &[f32],
        n: usize,
        job: &[f32; JOB_D],
        weights: &[f32; NUM_COMPONENTS],
    ) -> Result<Vec<f32>> {
        let (cap, file) = Manifest::pick(&self.manifest.node_sizes, n);
        let file = file.to_string();
        debug_assert!(n <= cap);
        // Pad with zero rows: healthy=0 ⇒ masked to -BIG by the kernel.
        let mut padded = Vec::with_capacity(cap * NODE_F);
        padded.extend_from_slice(feat);
        padded.resize(cap * NODE_F, 0.0);
        let lit_feat = literal_f32_2d(&padded, cap, NODE_F)?;
        let lit_job = literal_f32_1d(job);
        let lit_w = literal_f32_1d(weights);
        let outputs = self.runtime.run(&file, &[lit_feat, lit_job, lit_w])?;
        self.launches += 1;
        // score_and_rank returns (scores, order); we consume scores here.
        anyhow::ensure!(outputs.len() == 2, "expected (scores, order)");
        let scores: Vec<f32> = outputs[0].to_vec().context("scores to_vec")?;
        Ok(scores[..n].to_vec())
    }

    fn run_group_chunk(
        &mut self,
        gfeat: &[f32],
        g: usize,
        job: &[f32; JOB_D],
        weights: &[f32; GROUP_COMPONENTS],
    ) -> Result<Vec<f32>> {
        let (cap, file) = Manifest::pick(&self.manifest.group_sizes, g);
        let file = file.to_string();
        let mut padded = Vec::with_capacity(cap * GROUP_F);
        padded.extend_from_slice(gfeat);
        padded.resize(cap * GROUP_F, 0.0);
        let lit_feat = literal_f32_2d(&padded, cap, GROUP_F)?;
        let lit_job = literal_f32_1d(job);
        let lit_w = literal_f32_1d(weights);
        let outputs = self.runtime.run(&file, &[lit_feat, lit_job, lit_w])?;
        self.launches += 1;
        anyhow::ensure!(outputs.len() == 1, "expected (scores,)");
        let scores: Vec<f32> = outputs[0].to_vec().context("group scores to_vec")?;
        Ok(scores[..g].to_vec())
    }
}

impl ScoreBackend for XlaBackend {
    fn score_nodes(
        &mut self,
        feat: &[f32],
        n: usize,
        job: &[f32; JOB_D],
        weights: &[f32; NUM_COMPONENTS],
    ) -> Vec<f32> {
        let max_cap = self.manifest.node_sizes.last().unwrap().0;
        let mut out = Vec::with_capacity(n);
        let mut offset = 0;
        while offset < n {
            let chunk = (n - offset).min(max_cap);
            let slice = &feat[offset * NODE_F..(offset + chunk) * NODE_F];
            match self.run_node_chunk(slice, chunk, job, weights) {
                Ok(scores) => out.extend_from_slice(&scores),
                Err(e) => {
                    // A scoring failure must not wedge the scheduler: treat
                    // the chunk as infeasible and log.
                    eprintln!("error: xla node scoring failed: {e:#}");
                    out.resize(out.len() + chunk, -BIG);
                }
            }
            offset += chunk;
        }
        out
    }

    fn score_groups(
        &mut self,
        gfeat: &[f32],
        g: usize,
        job: &[f32; JOB_D],
        weights: &[f32; GROUP_COMPONENTS],
    ) -> Vec<f32> {
        let max_cap = self.manifest.group_sizes.last().unwrap().0;
        let mut out = Vec::with_capacity(g);
        let mut offset = 0;
        while offset < g {
            let chunk = (g - offset).min(max_cap);
            let slice = &gfeat[offset * GROUP_F..(offset + chunk) * GROUP_F];
            match self.run_group_chunk(slice, chunk, job, weights) {
                Ok(scores) => out.extend_from_slice(&scores),
                Err(e) => {
                    eprintln!("error: xla group scoring failed: {e:#}");
                    out.resize(out.len() + chunk, -BIG);
                }
            }
            offset += chunk;
        }
        out
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<std::path::PathBuf> {
        let p = std::path::PathBuf::from("artifacts");
        p.join("manifest.json").exists().then_some(p)
    }

    #[test]
    fn manifest_parses_and_orders() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.node_sizes.windows(2).all(|w| w[0].0 < w[1].0));
        assert_eq!(Manifest::pick(&m.node_sizes, 1).0, m.node_sizes[0].0);
        assert_eq!(
            Manifest::pick(&m.node_sizes, 100_000).0,
            m.node_sizes.last().unwrap().0
        );
    }

    #[test]
    fn xla_backend_scores_nodes() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let Ok(mut b) = XlaBackend::new(&dir) else {
            eprintln!("skipping: PJRT unavailable (stub xla backend)");
            return;
        };
        // Two nodes: one feasible-and-empty, one unhealthy.
        let mut feat = vec![0.0f32; 2 * NODE_F];
        feat[0] = 8.0; // free
        feat[1] = 8.0; // total
        feat[3] = 1.0; // healthy
        feat[4] = 64.0;
        feat[5] = 64.0;
        feat[8] = 3.0;
        feat[11] = 8.0;
        feat[NODE_F + 1] = 8.0; // total (unhealthy row)
        let job = [2.0, 2.0, 1.0, 0.0, 0.0, 2.0, 0.0, 0.0];
        let w = [1.0, 0.0, 0.6, 0.0, 0.5, 0.8, -0.3, 0.2];
        let scores = b.score_nodes(&feat, 2, &job, &w);
        assert_eq!(scores.len(), 2);
        assert!(scores[0] > -BIG / 2.0);
        assert!(scores[1] <= -BIG / 2.0);
        assert_eq!(b.launches, 1);
    }
}
