//! PJRT runtime: load AOT HLO-text artifacts and execute them on the CPU
//! PJRT client. Python never runs here — the artifacts in `artifacts/`
//! were lowered once at build time by `python/compile/aot.py`.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids the bundled xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// A PJRT client plus a cache of compiled executables keyed by file name.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime rooted at an artifacts directory.
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
            executables: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Load + compile an HLO-text artifact (cached by name).
    pub fn load(&mut self, file: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(file) {
            let path = self.artifacts_dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            self.executables.insert(file.to_string(), exe);
        }
        Ok(&self.executables[file])
    }

    /// Execute a loaded artifact with literal inputs; returns the flattened
    /// tuple outputs (aot.py lowers with `return_tuple=True`).
    pub fn run(&mut self, file: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.load(file)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {file}"))?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        result.to_tuple().context("untupling result")
    }

    pub fn loaded_count(&self) -> usize {
        self.executables.len()
    }
}

/// Build a rank-2 f32 literal from row-major data.
pub fn literal_f32_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    anyhow::ensure!(data.len() == rows * cols, "shape mismatch");
    xla::Literal::vec1(data)
        .reshape(&[rows as i64, cols as i64])
        .context("reshaping literal")
}

/// Build a rank-1 f32 literal.
pub fn literal_f32_1d(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        std::path::Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn cpu_runtime_comes_up() {
        // The vendored stub backend cannot create a PJRT client; this test
        // only exercises the real runtime when one is linked in.
        let Ok(rt) = Runtime::cpu("artifacts") else {
            eprintln!("skipping: PJRT unavailable (stub xla backend)");
            return;
        };
        assert!(!rt.platform().is_empty());
        assert_eq!(rt.loaded_count(), 0);
    }

    #[test]
    fn loads_and_caches_artifact() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let Ok(mut rt) = Runtime::cpu("artifacts") else {
            eprintln!("skipping: PJRT unavailable (stub xla backend)");
            return;
        };
        rt.load("node_scorer_256.hlo.txt").unwrap();
        rt.load("node_scorer_256.hlo.txt").unwrap();
        assert_eq!(rt.loaded_count(), 1);
    }

    #[test]
    fn literal_helpers_shape_check() {
        // The size mismatch is caught before any PJRT call, so this holds
        // for both the stub and the real backend.
        assert!(literal_f32_2d(&[1.0, 2.0, 3.0], 2, 2).is_err());
        // The ok path needs a real literal implementation; skip under the
        // vendored stub.
        match literal_f32_2d(&[1.0, 2.0, 3.0, 4.0], 2, 2) {
            Ok(_) => {}
            Err(e) if format!("{e:#}").contains("xla stub") => {
                eprintln!("skipping ok-path: stub xla backend");
            }
            Err(e) => panic!("well-shaped literal failed: {e:#}"),
        }
    }
}
