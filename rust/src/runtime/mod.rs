//! Runtime bridge: loads the AOT HLO-text artifacts through the PJRT C API
//! (`xla` crate) and serves them to RSCH as a [`ScoreBackend`]. Python is
//! build-time only; this module is the entire run-time footprint of L1/L2.

pub mod client;
pub mod scorer;

pub use client::Runtime;
pub use scorer::{Manifest, XlaBackend};
