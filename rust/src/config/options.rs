//! `SimOptions` — the one typed builder behind every entry point.
//!
//! Historically each knob (`--flat`, `--deep-snapshot`, `--no-index`,
//! `--topo-blind`, `--elastic`, `--faults`, `--checkpoint-min`, …) was
//! hand-threaded through `main.rs`, `bin/figures.rs`, the experiments
//! and the examples, so defaults could silently drift between entry
//! points. `SimOptions` is now the single constructor of the
//! `QschConfig`/`RschConfig`/`SimConfig` product (and, via
//! [`SimOptions::build`], of the whole preset [`Environment`]); the CLI
//! is a thin adapter onto it.
//!
//! ```no_run
//! use kant::config::{FaultPreset, Scale, SimOptions};
//!
//! let setup = SimOptions::for_scale(Scale::XLarge)
//!     .seed(7)
//!     .elastic(true)
//!     .faults(FaultPreset::Storm)
//!     .shards(8)
//!     .build()
//!     .unwrap();
//! ```

use std::fmt;

use crate::job::spec::{CheckpointPolicy, JobKind, JobSpec, PlacementStrategy};
use crate::qsch::policy::{QschConfig, QueuePolicy};
use crate::rsch::RschConfig;
use crate::sim::{ElasticConfig, FaultConfig, SimConfig};

use super::{inference_cluster, training_cluster, Environment, InferencePreset, Scale};

/// Which cluster preset a run targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterChoice {
    /// The §5.1 homogeneous training cluster at the chosen [`Scale`].
    Training,
    /// One of the §5.2 inference clusters (scale is fixed by the preset).
    Inference(InferencePreset),
}

/// Fault-injection preset (`--faults` maps to [`FaultPreset::Storm`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FaultPreset {
    /// No fault injection (the default).
    #[default]
    None,
    /// The seeded MTBF/MTTR storm of the reliability experiments, plus
    /// requeue priority aging, periodic training checkpoints, and
    /// drain-aware defrag rounds every 30 simulated minutes.
    Storm,
}

/// Invalid option combinations surfaced at build time — the constraints
/// the ad-hoc flag plumbing used to apply silently.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum OptionsError {
    /// Sharded prefetch workers always plan on the native backend (the
    /// PJRT client is not `Send`), so combining the XLA scorer with
    /// `shards >= 1` would silently ignore the requested backend.
    XlaScorerWithShards { shards: usize },
    /// The adaptive weight controller shifts the native scorer's weight
    /// tables at runtime; the AOT-compiled XLA artifact bakes the static
    /// rows in, so `--xla-scorer` would silently ignore `--adapt`.
    XlaScorerWithAdapt,
}

impl fmt::Display for OptionsError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OptionsError::XlaScorerWithShards { shards } => write!(
                f,
                "--xla-scorer cannot be combined with --shards {shards}: sharded \
                 prefetch workers always score on the native backend (the PJRT \
                 client is not Send); drop --shards or the XLA scorer"
            ),
            OptionsError::XlaScorerWithAdapt => write!(
                f,
                "--xla-scorer cannot be combined with --adapt: the adaptive \
                 controller shifts the native scorer's weight tables at \
                 runtime, while the AOT XLA artifact bakes the static rows \
                 in; drop --adapt or the XLA scorer"
            ),
        }
    }
}

impl std::error::Error for OptionsError {}

/// Everything [`SimOptions::build`] produces: the preset environment plus
/// the three scheduler/simulator configs, guaranteed mutually consistent.
pub struct SimSetup {
    pub env: Environment,
    pub qsch: QschConfig,
    pub rsch: RschConfig,
    pub sim: SimConfig,
}

/// The unified option set. Construct with [`SimOptions::for_scale`] (or
/// [`SimOptions::for_inference`]), chain setters, then [`build`]
/// (environment + configs) or [`configs`] (configs only, for callers
/// bringing their own cluster).
///
/// [`build`]: SimOptions::build
/// [`configs`]: SimOptions::configs
#[derive(Debug, Clone)]
pub struct SimOptions {
    scale: Scale,
    cluster: ClusterChoice,
    seed: u64,
    rho: f64,
    policy: QueuePolicy,
    strategy: Option<PlacementStrategy>,
    flat: bool,
    deep_snapshot: bool,
    no_index: bool,
    topo_blind: bool,
    elastic: bool,
    faults: FaultPreset,
    checkpoint_min: u64,
    shards: usize,
    xla_scorer: bool,
    adapt: bool,
    jwtd_bound_ms: u64,
    moldable: bool,
}

impl SimOptions {
    /// Training cluster at `scale`, with the defaults every entry point
    /// used to re-declare by hand: seed 42, ρ = 0.95, backfill queueing,
    /// kind-default strategies, two-level + incremental snapshot +
    /// indexed candidates, no elasticity/faults, sequential core.
    pub fn for_scale(scale: Scale) -> SimOptions {
        SimOptions {
            scale,
            cluster: ClusterChoice::Training,
            seed: 42,
            rho: 0.95,
            policy: QueuePolicy::Backfill,
            strategy: None,
            flat: false,
            deep_snapshot: false,
            no_index: false,
            topo_blind: false,
            elastic: false,
            faults: FaultPreset::None,
            checkpoint_min: 30,
            shards: 0,
            xla_scorer: false,
            adapt: false,
            jwtd_bound_ms: 0,
            moldable: false,
        }
    }

    /// One of the §5.2 inference clusters (their size is part of the
    /// preset, so `scale` only affects the label of non-cluster knobs).
    pub fn for_inference(preset: InferencePreset) -> SimOptions {
        let mut o = SimOptions::for_scale(Scale::Small);
        o.cluster = ClusterChoice::Inference(preset);
        o
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Offered-load factor for the training workload calibration.
    pub fn rho(mut self, rho: f64) -> Self {
        self.rho = rho;
        self
    }

    pub fn policy(mut self, policy: QueuePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Pin one placement strategy for every job kind (`--strategy`);
    /// `None` keeps the kind defaults (E-Binpack / E-Spread / Binpack).
    pub fn strategy(mut self, strategy: Option<PlacementStrategy>) -> Self {
        self.strategy = strategy;
        self
    }

    /// Disable two-level (NodeNetGroup preselect) scheduling (`--flat`).
    pub fn flat(mut self, flat: bool) -> Self {
        self.flat = flat;
        self
    }

    /// Rebuild the full snapshot every refresh (`--deep-snapshot`).
    pub fn deep_snapshot(mut self, deep: bool) -> Self {
        self.deep_snapshot = deep;
        self
    }

    /// Linear candidate scans instead of the free-capacity index
    /// (`--no-index`).
    pub fn no_index(mut self, no_index: bool) -> Self {
        self.no_index = no_index;
        self
    }

    /// Pre-fix topology ablation (`--topo-blind`).
    pub fn topo_blind(mut self, blind: bool) -> Self {
        self.topo_blind = blind;
        self
    }

    /// Elastic inference: diurnal replica sets + the autoscaling loop
    /// (`--elastic`).
    pub fn elastic(mut self, elastic: bool) -> Self {
        self.elastic = elastic;
        self
    }

    pub fn faults(mut self, faults: FaultPreset) -> Self {
        self.faults = faults;
        self
    }

    /// Checkpoint interval (minutes) for training jobs under fault
    /// injection; 0 = naive restart-from-scratch (`--checkpoint-min`).
    pub fn checkpoint_min(mut self, minutes: u64) -> Self {
        self.checkpoint_min = minutes;
        self
    }

    /// Worker threads for the superspine-sharded placement prefetch
    /// (`--shards N`). 0 (default) keeps the legacy sequential core; any
    /// value ≥ 1 enables prefetch — the shard *structure* is fixed by
    /// the topology, so every N ≥ 1 yields byte-identical digests.
    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    /// Score through the AOT-compiled XLA artifact (`--xla-scorer`).
    /// Invalid with [`SimOptions::shards`] ≥ 1 — see
    /// [`OptionsError::XlaScorerWithShards`].
    pub fn xla_scorer(mut self, xla: bool) -> Self {
        self.xla_scorer = xla;
        self
    }

    /// Seeded adaptive weight controller (`--adapt`): shift the native
    /// scorer's packing/spreading/fairness mix once per QSCH cycle from
    /// rolling GAR/GFR/JWTD windows. Off (the default) keeps the frozen
    /// static tables. Invalid with [`SimOptions::xla_scorer`] — see
    /// [`OptionsError::XlaScorerWithAdapt`].
    pub fn adapt(mut self, adapt: bool) -> Self {
        self.adapt = adapt;
        self
    }

    /// Hard anti-starvation bound (`--jwtd-bound`, here in ms): cap every
    /// base-priority class's rolling p99 queue wait. Feeds both QSCH's
    /// starvation rescue/reservation pass and — when [`SimOptions::adapt`]
    /// is on — the controller's fairness axis. 0 (default) disables.
    pub fn jwtd_bound_ms(mut self, bound_ms: u64) -> Self {
        self.jwtd_bound_ms = bound_ms;
        self
    }

    /// Moldable & malleable gangs (`--moldable`): half the generated
    /// multi-replica training gangs declare a shape ladder, RSCH's
    /// shape-selection pass may re-shape them at admission, and
    /// SLO-pressure / fault victims with a spare rung shrink instead of
    /// being evicted. Off (the default) no job carries shapes, no extra
    /// workload RNG draws happen, and every pre-moldable run replays
    /// byte-identically.
    pub fn moldable(mut self, moldable: bool) -> Self {
        self.moldable = moldable;
        self
    }

    pub fn wants_xla(&self) -> bool {
        self.xla_scorer
    }

    pub fn is_elastic(&self) -> bool {
        self.elastic
    }

    pub fn has_faults(&self) -> bool {
        self.faults != FaultPreset::None
    }

    /// The `QschConfig`/`RschConfig`/`SimConfig` product, validated. The
    /// `horizon_ms` is left at the `SimConfig` default (run-until-drained)
    /// — [`SimOptions::build`] stamps the preset horizon; callers with
    /// their own cluster set their own.
    pub fn configs(&self) -> Result<(QschConfig, RschConfig, SimConfig), OptionsError> {
        if self.xla_scorer && self.shards >= 1 {
            return Err(OptionsError::XlaScorerWithShards {
                shards: self.shards,
            });
        }
        if self.xla_scorer && self.adapt {
            return Err(OptionsError::XlaScorerWithAdapt);
        }
        let faults = self.has_faults();
        let qsch = QschConfig {
            policy: self.policy,
            // Fault runs opt into requeue priority aging (anti-starvation
            // for repeatedly-hit gangs); fault-free runs keep legacy order.
            requeue_aging_cap: if faults {
                crate::experiments::FAULT_REQUEUE_AGING_CAP
            } else {
                0
            },
            batch_shards: self.shards,
            // One shared wait ceiling for every base-priority class; 0
            // keeps the starvation pass disabled.
            max_jwtd_p99_ms: [self.jwtd_bound_ms;
                crate::job::spec::Priority::NUM_CLASSES],
            enable_moldable: self.moldable,
            enable_shrink: self.moldable,
            ..QschConfig::default()
        };
        let mut rsch = RschConfig::default();
        if self.adapt {
            rsch.adapt = crate::rsch::adapt::AdaptConfig {
                enabled: true,
                seed: self.seed,
                jwtd_bound_ms: [self.jwtd_bound_ms;
                    crate::job::spec::Priority::NUM_CLASSES],
                ..crate::rsch::adapt::AdaptConfig::default()
            };
        }
        if let Some(strat) = self.strategy {
            rsch.training_strategy = strat;
            rsch.inference_strategy = strat;
            rsch.dev_strategy = strat;
        }
        if self.flat {
            rsch.two_level = false;
        }
        if self.deep_snapshot {
            rsch.snapshot_mode = crate::cluster::snapshot::SnapshotMode::DeepCopy;
        }
        if self.no_index {
            rsch.indexed_candidates = false;
        }
        if self.topo_blind {
            rsch.topo_blind = true;
        }
        let sim = SimConfig {
            elastic: if self.elastic {
                ElasticConfig::enabled()
            } else {
                ElasticConfig::default()
            },
            faults: match self.faults {
                FaultPreset::None => FaultConfig::default(),
                // Keep the fault trace decorrelated from the workload seed.
                FaultPreset::Storm => FaultConfig::storm(self.seed ^ 0xFA),
            },
            // Drain-aware reorganization needs defrag rounds to act on.
            defrag_interval_ms: if faults { 30 * 60_000 } else { 0 },
            ..SimConfig::default()
        };
        Ok((qsch, rsch, sim))
    }

    /// Build the preset [`Environment`] plus the validated configs — the
    /// single constructor behind `kant simulate` and the examples.
    pub fn build(&self) -> Result<SimSetup, OptionsError> {
        let (qsch, rsch, mut sim) = self.configs()?;
        let mut env = match self.cluster {
            ClusterChoice::Training => training_cluster(self.scale, self.seed, self.rho),
            ClusterChoice::Inference(preset) => inference_cluster(preset, self.seed),
        };
        if self.elastic {
            env.workload.elastic_frac = 0.7;
        }
        if self.moldable {
            env.workload.moldable_frac = 0.5;
        }
        // Generous grace past the arrival horizon so in-flight jobs drain.
        sim.horizon_ms = env.horizon_ms + 24 * 3_600_000;
        Ok(SimSetup {
            env,
            qsch,
            rsch,
            sim,
        })
    }

    /// Apply the per-job policies the options imply (today: periodic
    /// training checkpoints under fault injection). Call on the generated
    /// or trace-loaded workload before running.
    pub fn apply_job_policies(&self, jobs: &mut [JobSpec]) {
        if !self.has_faults() {
            return;
        }
        let ckpt = if self.checkpoint_min == 0 {
            CheckpointPolicy::None
        } else {
            CheckpointPolicy::Interval(self.checkpoint_min * 60_000)
        };
        for j in jobs.iter_mut() {
            if j.kind == JobKind::Training {
                j.checkpoint = ckpt;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ids::{GpuTypeId, JobId, TenantId};
    use crate::cluster::snapshot::SnapshotMode;

    #[test]
    fn defaults_match_legacy_config_defaults() {
        let (qsch, rsch, sim) = SimOptions::for_scale(Scale::Small).configs().unwrap();
        assert_eq!(qsch.policy, QueuePolicy::Backfill);
        assert_eq!(qsch.requeue_aging_cap, 0);
        assert_eq!(qsch.batch_shards, 0);
        assert!(rsch.two_level);
        assert!(rsch.indexed_candidates);
        assert!(!rsch.topo_blind);
        assert_eq!(rsch.snapshot_mode, SnapshotMode::Incremental);
        assert!(!sim.faults.enabled());
        assert_eq!(sim.elastic.sample_ms, ElasticConfig::default().sample_ms);
        assert_eq!(sim.defrag_interval_ms, 0);
    }

    #[test]
    fn ablation_knobs_map_onto_rsch() {
        let (_, rsch, _) = SimOptions::for_scale(Scale::Small)
            .flat(true)
            .deep_snapshot(true)
            .no_index(true)
            .topo_blind(true)
            .strategy(Some(PlacementStrategy::Spread))
            .configs()
            .unwrap();
        assert!(!rsch.two_level);
        assert_eq!(rsch.snapshot_mode, SnapshotMode::DeepCopy);
        assert!(!rsch.indexed_candidates);
        assert!(rsch.topo_blind);
        assert_eq!(rsch.training_strategy, PlacementStrategy::Spread);
        assert_eq!(rsch.inference_strategy, PlacementStrategy::Spread);
        assert_eq!(rsch.dev_strategy, PlacementStrategy::Spread);
    }

    #[test]
    fn storm_preset_wires_reliability_knobs() {
        let opts = SimOptions::for_scale(Scale::Small)
            .seed(7)
            .faults(FaultPreset::Storm);
        let (qsch, _, sim) = opts.configs().unwrap();
        assert_eq!(
            qsch.requeue_aging_cap,
            crate::experiments::FAULT_REQUEUE_AGING_CAP
        );
        assert!(sim.faults.enabled());
        assert_eq!(sim.faults.seed, 7 ^ 0xFA);
        assert_eq!(sim.defrag_interval_ms, 30 * 60_000);
        // Training jobs get interval checkpoints; inference is untouched.
        let mut jobs = vec![
            JobSpec::homogeneous(JobId(1), TenantId(0), JobKind::Training, GpuTypeId(0), 1, 8),
            JobSpec::homogeneous(JobId(2), TenantId(0), JobKind::Inference, GpuTypeId(0), 1, 1),
        ];
        opts.apply_job_policies(&mut jobs);
        assert_eq!(jobs[0].checkpoint, CheckpointPolicy::Interval(30 * 60_000));
        assert_eq!(jobs[1].checkpoint, CheckpointPolicy::Continuous);
        // checkpoint_min = 0 → naive restarts.
        let naive = SimOptions::for_scale(Scale::Small)
            .faults(FaultPreset::Storm)
            .checkpoint_min(0);
        naive.apply_job_policies(&mut jobs);
        assert_eq!(jobs[0].checkpoint, CheckpointPolicy::None);
    }

    #[test]
    fn elastic_enables_loop_and_workload_mix() {
        let setup = SimOptions::for_scale(Scale::Small)
            .elastic(true)
            .build()
            .unwrap();
        assert_eq!(setup.sim.elastic.sample_ms, 5 * 60_000);
        assert!((setup.env.workload.elastic_frac - 0.7).abs() < 1e-9);
        assert_eq!(setup.sim.horizon_ms, setup.env.horizon_ms + 24 * 3_600_000);
    }

    #[test]
    fn shards_flow_into_qsch_batching() {
        let (qsch, _, _) = SimOptions::for_scale(Scale::XLarge)
            .shards(8)
            .configs()
            .unwrap();
        assert_eq!(qsch.batch_shards, 8);
    }

    #[test]
    fn xla_scorer_excludes_sharded_prefetch() {
        let err = SimOptions::for_scale(Scale::Small)
            .xla_scorer(true)
            .shards(8)
            .configs()
            .unwrap_err();
        assert_eq!(err, OptionsError::XlaScorerWithShards { shards: 8 });
        assert!(err.to_string().contains("--shards 8"));
        // The XLA scorer alone stays valid (sequential core).
        assert!(SimOptions::for_scale(Scale::Small)
            .xla_scorer(true)
            .configs()
            .is_ok());
    }

    #[test]
    fn adapt_knobs_map_onto_configs() {
        use crate::job::spec::Priority;
        // Defaults: controller disabled, no bounds.
        let (qsch, rsch, _) = SimOptions::for_scale(Scale::Small).configs().unwrap();
        assert_eq!(qsch.max_jwtd_p99_ms, [0; Priority::NUM_CLASSES]);
        assert!(!rsch.adapt.enabled);
        // --adapt --jwtd-bound: controller seeded from the run seed, the
        // shared bound fanned out to every class on both sides.
        let (qsch, rsch, _) = SimOptions::for_scale(Scale::Small)
            .seed(9)
            .adapt(true)
            .jwtd_bound_ms(360 * 60_000)
            .configs()
            .unwrap();
        assert_eq!(qsch.max_jwtd_p99_ms, [360 * 60_000; Priority::NUM_CLASSES]);
        assert!(rsch.adapt.enabled);
        assert_eq!(rsch.adapt.seed, 9);
        assert_eq!(rsch.adapt.jwtd_bound_ms, [360 * 60_000; Priority::NUM_CLASSES]);
        // --jwtd-bound alone: hard bound without the controller.
        let (qsch, rsch, _) = SimOptions::for_scale(Scale::Small)
            .jwtd_bound_ms(60_000)
            .configs()
            .unwrap();
        assert_eq!(qsch.max_jwtd_p99_ms, [60_000; Priority::NUM_CLASSES]);
        assert!(!rsch.adapt.enabled);
        // --adapt composes with --shards (single-threaded controller tick).
        assert!(SimOptions::for_scale(Scale::Small)
            .adapt(true)
            .shards(8)
            .configs()
            .is_ok());
    }

    #[test]
    fn moldable_knob_maps_onto_qsch_and_workload() {
        // Defaults: both passes off, no ladder generation.
        let setup = SimOptions::for_scale(Scale::Small).build().unwrap();
        assert!(!setup.qsch.enable_moldable);
        assert!(!setup.qsch.enable_shrink);
        assert_eq!(setup.env.workload.moldable_frac, 0.0);
        // --moldable: mold pass + malleable shrink + ladder generation.
        let setup = SimOptions::for_scale(Scale::Small)
            .moldable(true)
            .build()
            .unwrap();
        assert!(setup.qsch.enable_moldable);
        assert!(setup.qsch.enable_shrink);
        assert!((setup.env.workload.moldable_frac - 0.5).abs() < 1e-9);
        // Composes with the sharded core and fault injection.
        assert!(SimOptions::for_scale(Scale::Small)
            .moldable(true)
            .shards(8)
            .faults(FaultPreset::Storm)
            .configs()
            .is_ok());
    }

    #[test]
    fn xla_scorer_excludes_adapt() {
        let err = SimOptions::for_scale(Scale::Small)
            .xla_scorer(true)
            .adapt(true)
            .configs()
            .unwrap_err();
        assert_eq!(err, OptionsError::XlaScorerWithAdapt);
        assert!(err.to_string().contains("--adapt"));
    }

    #[test]
    fn inference_presets_build() {
        let setup = SimOptions::for_inference(InferencePreset::A10)
            .seed(3)
            .build()
            .unwrap();
        assert_eq!(setup.env.state.total_gpus(), 40);
        assert_eq!(setup.env.label, "a10");
    }
}
