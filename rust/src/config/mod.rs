//! Experiment presets: the clusters, tenants/quotas and workloads of the
//! paper's §5 evaluation, plus down-scaled variants for quick runs.

pub mod options;

pub use options::{ClusterChoice, FaultPreset, OptionsError, SimOptions, SimSetup};

use crate::cluster::builder::{ClusterBuilder, ClusterSpec, GpuModel, GpuTypeProfile};
use crate::cluster::ids::{GpuTypeId, TenantId};
use crate::cluster::state::ClusterState;
use crate::cluster::tenant::{QuotaLedger, QuotaMode};
use crate::job::workload::WorkloadConfig;

/// Run scale: `Paper` mirrors §5's sizes; `Small` is CI-friendly;
/// `XLarge` is the "tens of thousands of GPUs" end of the abstract's
/// claim (1,250 nodes / 10,000 GPUs) — the scale where sublinear
/// candidate selection earns its keep; `XXLarge` is the 100,000-GPU
/// frontier cluster (12,500 nodes over 10 superspines) that the
/// superspine-sharded scheduler core targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Small,
    Paper,
    XLarge,
    XXLarge,
}

impl Scale {
    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "small" => Some(Scale::Small),
            "paper" | "full" => Some(Scale::Paper),
            "xlarge" | "10k" => Some(Scale::XLarge),
            "xxlarge" | "100k" => Some(Scale::XXLarge),
            _ => None,
        }
    }
}

/// A fully-specified experiment environment.
pub struct Environment {
    pub state: ClusterState,
    pub ledger: QuotaLedger,
    pub workload: WorkloadConfig,
    /// Simulated horizon (ms).
    pub horizon_ms: u64,
    pub label: String,
}

/// §5.1 large-scale training cluster (homogeneous Type-H).
///
/// `Paper`: 1,024 nodes / 8,192 GPUs (the paper's "8,000-GPU" cluster),
/// 32-node LeafGroups. `Small`: 128 nodes / 1,024 GPUs, same group shape.
/// `XLarge`: 1,250 nodes / 10,000 GPUs in 50 LeafGroups of 25.
pub fn training_cluster(scale: Scale, seed: u64, rho: f64) -> Environment {
    let (spec, days) = match scale {
        Scale::Paper => (ClusterSpec::train8000(), 14.0),
        Scale::XLarge => (ClusterSpec::train10000(), 14.0),
        Scale::XXLarge => (ClusterSpec::train100000(), 14.0),
        Scale::Small => {
            // Same 128-node / 1,024-GPU shape as before, but spread over
            // 4 spines in 2 superspines so small-scale runs exercise the
            // truthful cross-superspine tier (a single-superspine preset
            // would never produce `Tier::CrossSuperSpine`).
            let mut s = ClusterSpec::homogeneous("train1024", 4, 1, 32);
            s.spines_per_superspine = 2;
            (s, 4.0)
        }
    };
    let state = ClusterBuilder::build(&spec);
    let num_tenants = 4;
    // Training tenants share one big pool; quotas sized so static admission
    // is not the binding constraint (the paper's training experiments focus
    // on queueing/placement, not quota contention).
    let mut ledger = QuotaLedger::new(num_tenants, 1, QuotaMode::Shared);
    for t in 0..num_tenants {
        ledger.set_limit(
            TenantId(t as u32),
            GpuTypeId(0),
            state.total_gpus() / num_tenants as u32,
        );
    }
    let mut workload = WorkloadConfig::paper_training(seed);
    workload.num_tenants = num_tenants as u32;
    // Cap job sizes at half the cluster so the biggest class stays
    // schedulable (2048-GPU jobs on the paper-scale cluster; 512 on small).
    workload.max_gpus = (state.total_gpus() / 4).next_power_of_two().min(2048);
    let workload = workload.calibrate_load(state.total_gpus(), rho);
    Environment {
        horizon_ms: (days * 24.0 * 3_600_000.0) as u64,
        label: format!("{}({} GPUs)", spec.name, state.total_gpus()),
        state,
        ledger,
        workload,
    }
}

/// §5.2 inference clusters. The paper's i7 > i2 > a10 size ordering.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InferencePreset {
    /// Hundred-GPU heterogeneous cluster (Figures 13–14): Type-L + Type-A.
    I2,
    /// Larger homogeneous sibling (Figure 15 leftmost).
    I7,
    /// Small cluster (Figure 15 rightmost, highest GFR).
    A10,
}

impl InferencePreset {
    pub fn parse(s: &str) -> Option<InferencePreset> {
        match s {
            "i2" => Some(InferencePreset::I2),
            "i7" => Some(InferencePreset::I7),
            "a10" => Some(InferencePreset::A10),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            InferencePreset::I2 => "i2",
            InferencePreset::I7 => "i7",
            InferencePreset::A10 => "a10",
        }
    }
}

/// Build an inference environment. All presets run multi-tenant, non-gang,
/// small-job workloads near capacity (the paper observes GAR ≈ 93 % with no
/// pending jobs on i2).
pub fn inference_cluster(preset: InferencePreset, seed: u64) -> Environment {
    let spec = match preset {
        // 8 Type-L nodes (64 GPUs) + 12 Type-A nodes (48 GPUs) = 112 GPUs.
        InferencePreset::I2 => ClusterSpec {
            name: "i2".into(),
            gpu_types: vec![
                GpuTypeProfile {
                    model: GpuModel::TypeL,
                    groups: 2,
                },
                GpuTypeProfile {
                    model: GpuModel::TypeA,
                    groups: 3,
                },
            ],
            groups_per_spine: 5,
            spines_per_superspine: 2,
            nodes_per_group: 4,
            hbd_size: 0,
            // No dedicated zone on a 20-node cluster: even one zoned
            // LeafGroup would set a 20 % GFR floor (DESIGN.md §6).
            inference_zone_frac: 0.0,
        },
        // 56 Type-L nodes = 448 GPUs.
        InferencePreset::I7 => ClusterSpec {
            name: "i7".into(),
            gpu_types: vec![GpuTypeProfile {
                model: GpuModel::TypeL,
                groups: 7,
            }],
            groups_per_spine: 4,
            spines_per_superspine: 2,
            nodes_per_group: 8,
            hbd_size: 0,
            inference_zone_frac: 0.25,
        },
        // 10 Type-A nodes = 40 GPUs.
        InferencePreset::A10 => ClusterSpec {
            name: "a10".into(),
            gpu_types: vec![GpuTypeProfile {
                model: GpuModel::TypeA,
                groups: 2,
            }],
            groups_per_spine: 2,
            spines_per_superspine: 1,
            nodes_per_group: 5,
            hbd_size: 0,
            inference_zone_frac: 0.0,
        },
    };
    let state = ClusterBuilder::build(&spec);
    let num_tenants = 8usize;
    let num_types = state.gpu_types.len();
    let mut ledger = QuotaLedger::new(num_tenants, num_types, QuotaMode::Shared);
    // Uneven quotas across tenants (Figure 10's varied quota profile):
    // tenant t gets a share proportional to (t % 4) + 1.
    for g in 0..num_types {
        let pool_total = state.pool_free_for_type(GpuTypeId(g as u16));
        let weight_sum: u32 = (0..num_tenants).map(|t| (t as u32 % 4) + 1).sum();
        for t in 0..num_tenants {
            let share = pool_total * ((t as u32 % 4) + 1) / weight_sum;
            ledger.set_limit(TenantId(t as u32), GpuTypeId(g as u16), share);
        }
    }
    let mut workload = WorkloadConfig::paper_inference(seed);
    workload.num_tenants = num_tenants as u32;
    // Tenant demand tracks the quota profile (Figure 10's utilization is
    // then meaningful rather than dominated by borrowing).
    workload.tenant_weights = (0..num_tenants).map(|t| ((t % 4) + 1) as f64).collect();
    // Demand proportional to each pool's capacity, shaped to its boards.
    workload.type_mix = state
        .gpu_types
        .iter()
        .map(|t| {
            (
                t.id,
                state.pool_free_for_type(t.id) as f64,
                t.gpus_per_node as u32,
            )
        })
        .collect();
    workload.max_gpus = 4; // Small HA services (≤ smallest board).
    let workload = workload.calibrate_load(state.total_gpus(), 0.93);
    Environment {
        horizon_ms: 5 * 24 * 3_600_000, // 5 simulated days.
        label: preset.label().to_string(),
        state,
        ledger,
        workload,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn training_scales() {
        let small = training_cluster(Scale::Small, 1, 0.9);
        assert_eq!(small.state.total_gpus(), 1024);
        let paper = training_cluster(Scale::Paper, 1, 0.9);
        assert_eq!(paper.state.total_gpus(), 8192);
        assert!(paper.horizon_ms > small.horizon_ms);
        let xlarge = training_cluster(Scale::XLarge, 1, 0.9);
        assert_eq!(xlarge.state.total_gpus(), 10_000);
        assert_eq!(xlarge.state.nodes.len(), 1250);
    }

    #[test]
    fn training_xxlarge_is_hundred_thousand_gpus() {
        let xx = training_cluster(Scale::XXLarge, 1, 0.9);
        assert_eq!(xx.state.total_gpus(), 100_000);
        assert_eq!(xx.state.nodes.len(), 12_500);
        assert_eq!(xx.state.fabric.num_superspines, 10);
    }

    #[test]
    fn inference_size_ordering_matches_paper() {
        let i7 = inference_cluster(InferencePreset::I7, 1);
        let i2 = inference_cluster(InferencePreset::I2, 1);
        let a10 = inference_cluster(InferencePreset::A10, 1);
        assert!(i7.state.total_gpus() > i2.state.total_gpus());
        assert!(i2.state.total_gpus() > a10.state.total_gpus());
    }

    #[test]
    fn i2_is_heterogeneous_with_quotas() {
        let i2 = inference_cluster(InferencePreset::I2, 1);
        assert_eq!(i2.state.pools.len(), 2);
        let util = i2.ledger.utilization(GpuTypeId(0));
        assert_eq!(util.len(), 8);
        // Quotas vary across tenants.
        let limits: Vec<u32> = util.iter().map(|&(_, l, _)| l).collect();
        assert!(limits.iter().any(|&l| l != limits[0]));
    }

    #[test]
    fn workload_caps_match_cluster() {
        let env = training_cluster(Scale::Small, 2, 0.8);
        assert!(env.workload.max_gpus <= env.state.total_gpus() / 2);
        assert!(env.workload.max_gpus >= 256);
    }

    #[test]
    fn scale_parses() {
        assert_eq!(Scale::parse("paper"), Some(Scale::Paper));
        assert_eq!(Scale::parse("small"), Some(Scale::Small));
        assert_eq!(Scale::parse("xlarge"), Some(Scale::XLarge));
        assert_eq!(Scale::parse("10k"), Some(Scale::XLarge));
        assert_eq!(Scale::parse("xxlarge"), Some(Scale::XXLarge));
        assert_eq!(Scale::parse("100k"), Some(Scale::XXLarge));
        assert_eq!(InferencePreset::parse("a10"), Some(InferencePreset::A10));
    }
}
