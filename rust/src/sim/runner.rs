//! The simulation runner: wires workload → QSCH → RSCH → cluster → metrics
//! over the discrete-event engine. This is the §5 experiment driver.

use crate::cluster::gpu::Health;
use crate::cluster::ids::JobId;
use crate::cluster::state::ClusterState;
use crate::job::spec::{CheckpointPolicy, JobSpec};
use crate::job::state::Phase;
use crate::job::store::JobStore;
use crate::metrics::report::fmt_ms;
use crate::metrics::Metrics;
use crate::obs::{ObsPhase, ObsRecorder, SchedulerHealth};
use crate::qsch::Qsch;
use crate::rsch::Rsch;

use super::engine::{Engine, Event, SimTime};
use super::faults::{FaultConfig, FaultInjector, FaultTarget};

/// Runner tunables.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Scheduling cycle period.
    pub cycle_ms: u64,
    /// Metrics sampling period.
    pub sample_ms: u64,
    /// Platform overhead from resource binding to Running (image pull,
    /// container start — the §4.2 window that still accrues SOR).
    pub platform_overhead_ms: u64,
    /// Hard stop (0 = run to completion).
    pub horizon_ms: u64,
    /// Scheduling-deadlock heuristic: abort after this many *consecutive*
    /// no-progress cycles (a cycle that neither scheduled nor preempted
    /// anything) once no substantive events remain queued. Any progress
    /// resets the counter, and pending arrivals/finishes/defrag/health
    /// events keep the simulation alive regardless — so a stall can only
    /// trip when queued jobs genuinely cannot ever be placed (e.g. a gang
    /// larger than what failures left schedulable). At the default
    /// `cycle_ms` of 5 s, the default 10,000 cycles ≈ 14 simulated hours
    /// of standstill before the runner gives up and reports the
    /// diagnostic (sim time, unfinished jobs, queue depth) on stderr.
    pub stall_cycles: u64,
    /// Periodic fragmentation reorganization (§3.3.3); 0 = disabled.
    pub defrag_interval_ms: u64,
    /// Service interruption charged to each migrated job.
    pub migration_penalty_ms: u64,
    /// Defrag planner tunables.
    pub defrag: crate::rsch::defrag::DefragConfig,
    /// Elasticity loop (diurnal inference autoscaling + tidal
    /// co-scheduling); `elastic.sample_ms == 0` disables it.
    pub elastic: super::elastic::ElasticConfig,
    /// Stochastic fault injection (seeded MTBF/MTTR renewal processes per
    /// GPU / node / HBD plus maintenance drains); the default config
    /// disables every domain. The trace is pre-generated at sim start, so
    /// same seed + config replays byte-identically.
    pub faults: FaultConfig,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            cycle_ms: 5_000,
            sample_ms: 60_000,
            platform_overhead_ms: 30_000,
            horizon_ms: 0,
            stall_cycles: 10_000,
            defrag_interval_ms: 0,
            migration_penalty_ms: 30_000,
            defrag: crate::rsch::defrag::DefragConfig::default(),
            elastic: super::elastic::ElasticConfig::default(),
            faults: FaultConfig::default(),
        }
    }
}

/// Everything a finished simulation reports.
pub struct SimOutcome {
    pub metrics: Metrics,
    pub qsch_stats: crate::qsch::QschStats,
    pub rsch_stats: crate::rsch::RschStats,
    pub snapshot_stats: crate::cluster::snapshot::SnapshotStats,
    pub end_ms: SimTime,
    pub events_processed: u64,
    pub unfinished_jobs: usize,
    pub store: JobStore,
    /// Total defrag migrations executed.
    pub migrations: u64,
    /// Wall-clock scheduler-health rollup (empty unless the run used
    /// [`run_observed`] with an enabled recorder). Digest-inert: nothing
    /// here feeds [`SimOutcome::digest_json`].
    pub health: SchedulerHealth,
}

/// Scheduler counters deliberately NOT covered by
/// [`SimOutcome::digest_json`].
///
/// Every field on `QschStats`/`RschStats` must either be read by
/// `digest_json` or be listed here with a reason — the `kant lint`
/// digest-coverage rule checks the partition in both directions, so a
/// new counter cannot silently dodge the determinism gate. Only
/// counters that are *not* invariant across `--shards` worker counts
/// belong here: the digest must stay byte-identical for any N >= 1,
/// while these measure work performed, which legitimately varies with
/// the prefetch fan-out (and between the sequential and sharded cores).
pub const DIGEST_INERT: &[(&str, &str)] = &[
    ("rsch.failures", "workers and the sequential fallback both count a failed plan"),
    ("rsch.groups_scored", "per-worker planning effort; varies with the prefetch fan-out"),
    ("rsch.snapshot_refreshes", "per-batch under prefetch, per-placement sequentially"),
    ("rsch.plan_cache_hits", "observability counter; hit/miss split varies with fan-out"),
    ("rsch.plan_cache_misses", "observability counter; failed worker plans replan sequentially"),
    ("rsch.prefetch_batches", "counts prefetch rounds, not scheduling outcomes"),
    ("rsch.prefetch_imbalance_sum", "shard-skew telemetry; depends on worker count"),
];

impl SimOutcome {
    /// Deterministic digest of the whole run for the golden-gate
    /// determinism CI job: two runs with the same seed and config must
    /// produce byte-identical renderings of this document. Covers the
    /// headline metrics, every scheduler counter, and an
    /// order-independent FNV-1a fingerprint of each job's trajectory
    /// (schedule/run/finish times, preemptions, requeues, migrations,
    /// shape changes).
    pub fn digest_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut rows: Vec<[u64; 9]> = self
            .store
            .iter()
            .map(|j| {
                [
                    j.id().0,
                    j.scheduled_ms.map(|t| t + 1).unwrap_or(0),
                    j.running_ms.map(|t| t + 1).unwrap_or(0),
                    j.finished_ms.map(|t| t + 1).unwrap_or(0),
                    j.preemptions as u64,
                    j.requeues as u64,
                    j.migrations as u64,
                    j.lost_work_ms,
                    j.shape_changes as u64,
                ]
            })
            .collect();
        rows.sort_unstable();
        let mut h: u64 = 0xcbf2_9ce4_8422_2325; // FNV-1a offset basis.
        for row in &rows {
            for &x in row {
                for b in x.to_le_bytes() {
                    h ^= b as u64;
                    h = h.wrapping_mul(0x0000_0100_0000_01b3);
                }
            }
        }
        let mut d = Json::obj();
        d.set("schema", "kant-sim-digest-v1")
            .set("end_ms", self.end_ms)
            .set("events", self.events_processed)
            .set("jobs_submitted", self.metrics.jobs_submitted)
            .set("jobs_scheduled", self.metrics.jobs_scheduled)
            .set("jobs_finished", self.metrics.jobs_finished)
            .set("jobs_cancelled", self.metrics.jobs_cancelled)
            .set("unfinished", self.unfinished_jobs)
            .set("migrations", self.migrations)
            .set("gar_avg", self.metrics.gar_avg())
            .set("sor_final", self.metrics.sor_final())
            .set("gfr_avg", self.metrics.gfr_avg())
            .set("slo_violation_rate", self.metrics.elastic.slo_violation_rate())
            .set("replica_churn", self.metrics.elastic.replica_churn())
            .set("qsch_cycles", self.qsch_stats.cycles)
            .set("qsch_submitted", self.qsch_stats.submitted)
            .set("qsch_scheduled", self.qsch_stats.scheduled)
            .set("qsch_backfilled", self.qsch_stats.scheduled_backfilled)
            .set("qsch_preempt_backfill", self.qsch_stats.backfill_preemptions)
            .set("qsch_preempt_priority", self.qsch_stats.priority_preemptions)
            .set("qsch_preempt_quota", self.qsch_stats.quota_reclaim_preemptions)
            .set("qsch_preempt_slo", self.qsch_stats.slo_pressure_preemptions)
            .set("qsch_preempt_starvation", self.qsch_stats.starvation_preemptions)
            .set("qsch_starvation_rescues", self.qsch_stats.starvation_rescues)
            .set(
                "qsch_starvation_reservations",
                self.qsch_stats.starvation_reservations,
            )
            .set("qsch_cancellations", self.qsch_stats.cancellations)
            .set("qsch_placement_failures", self.qsch_stats.placement_failures)
            .set("qsch_requeues", self.qsch_stats.requeues)
            .set("qsch_shape_molds", self.qsch_stats.shape_molds)
            .set("qsch_shape_shrinks", self.qsch_stats.shape_shrinks)
            .set("rsch_placements", self.rsch_stats.placements)
            .set("rsch_pods_placed", self.rsch_stats.pods_placed)
            .set("rsch_nodes_examined", self.rsch_stats.nodes_examined)
            .set("rsch_nodes_scored", self.rsch_stats.nodes_scored)
            .set("rsch_adapt_ticks", self.rsch_stats.adapt_ticks)
            .set("rsch_adapt_shifts", self.rsch_stats.adapt_shifts)
            .set(
                "rsch_adapt_fingerprint",
                format!("{:016x}", self.rsch_stats.adapt_fingerprint),
            )
            .set(
                "jtted_spine_dev_mean",
                Metrics::weighted_mean(&self.metrics.jtted_spine_summaries()),
            )
            .set(
                "jtted_superspine_dev_mean",
                Metrics::weighted_mean(&self.metrics.jtted_superspine_summaries()),
            )
            .set("faults_injected", self.metrics.reliability.faults_injected())
            .set("fault_evictions", self.metrics.reliability.fault_evictions)
            .set("repairs", self.metrics.reliability.repairs)
            .set("lost_gpu_ms", self.metrics.reliability.lost_gpu_ms)
            .set("goodput_gpu_ms", self.metrics.reliability.goodput_gpu_ms())
            .set("jobs_fingerprint", format!("{h:016x}"));
        d
    }
}

/// Evict the victims of a fault or health flip. Elastic replica-delta
/// children are *cancelled* — devices released, quota refunded, the
/// controller's books updated — because a dead replica is better
/// re-provisioned fresh at the next load sample than requeued with a
/// stale submit window. Malleable tidal/LOW gangs with a spare ladder
/// rung *shrink* instead (`--moldable`): the surviving replicas
/// re-shard and keep their progress, modelling an elastic trainer that
/// tolerates replica loss, so no eviction or lost work is charged.
/// Everything else requeues (§3.2.4) with priority aging. Returns how
/// many victims were cancelled (they leave the job population, so the
/// runner's liveness accounting must see them).
fn evict_fault_victims(
    now: u64,
    victims: &[JobId],
    store: &mut JobStore,
    state: &mut ClusterState,
    qsch: &mut Qsch,
    elastic: &mut Option<super::elastic::ElasticController>,
    metrics: &mut Metrics,
) -> u64 {
    let mut cancelled = 0u64;
    for &v in victims {
        let j = store.expect(v);
        if !j.holds_resources() {
            continue; // Already evicted by an overlapping fault.
        }
        let gpus = j.spec.total_gpus() as u64;
        let lost_before = j.lost_work_ms;
        if j.spec.service.is_some() {
            if let Some(ctrl) = elastic.as_mut() {
                ctrl.on_child_evicted(v);
            }
            qsch.cancel_job(store, state, v, now);
            metrics.on_cancelled();
            metrics.reliability.on_eviction(gpus, 0);
            cancelled += 1;
        } else if qsch.shrink_or_evict_and_requeue(store, state, v, now) {
            metrics.reliability.on_shrink();
        } else {
            let lost = store.expect(v).lost_work_ms - lost_before;
            metrics.reliability.on_eviction(gpus, lost);
        }
    }
    cancelled
}

/// Run a workload to completion (or horizon) against a scheduler stack.
pub fn run(
    state: &mut ClusterState,
    qsch: &mut Qsch,
    rsch: &mut Rsch,
    jobs: Vec<JobSpec>,
    cfg: &SimConfig,
) -> SimOutcome {
    run_with_events(state, qsch, rsch, jobs, Vec::new(), cfg)
}

/// Like [`run`], with extra pre-scheduled events (failure injection etc.).
pub fn run_with_events(
    state: &mut ClusterState,
    qsch: &mut Qsch,
    rsch: &mut Rsch,
    jobs: Vec<JobSpec>,
    extra_events: Vec<(SimTime, Event)>,
    cfg: &SimConfig,
) -> SimOutcome {
    run_observed(
        state,
        qsch,
        rsch,
        jobs,
        extra_events,
        cfg,
        &mut ObsRecorder::disabled(),
    )
}

/// Like [`run_with_events`], with an observability recorder attached.
/// The recorder is strictly write-only for the scheduling stack — same
/// seed + config produces byte-identical digests whether it is enabled,
/// disabled, or absent.
pub fn run_observed(
    state: &mut ClusterState,
    qsch: &mut Qsch,
    rsch: &mut Rsch,
    jobs: Vec<JobSpec>,
    extra_events: Vec<(SimTime, Event)>,
    cfg: &SimConfig,
    obs: &mut ObsRecorder,
) -> SimOutcome {
    let mut engine = Engine::new();
    for (t, e) in extra_events {
        engine.schedule(t, e);
    }

    // Reliability loop: cordon the hot-spare fleet, then pre-schedule the
    // seeded fault trace. With no explicit horizon, faults cover the
    // arrival window plus a one-day drain.
    let mut faults = if cfg.faults.enabled() {
        Some(FaultInjector::new(&cfg.faults, state))
    } else {
        None
    };
    if faults.is_some() {
        let fault_horizon = if cfg.horizon_ms > 0 {
            cfg.horizon_ms
        } else {
            jobs.iter()
                .map(|j| j.submit_ms.saturating_add(j.duration_ms))
                .max()
                .unwrap_or(0)
                + 24 * 3_600_000
        };
        for (t, e) in FaultInjector::trace(&cfg.faults, state, fault_horizon) {
            engine.schedule(t, e);
        }
    }

    let mut store = JobStore::new();
    let mut metrics = Metrics::new(state, 0);

    // Elastic services spawn/cancel replica-delta children at runtime, so
    // the job population (and with it the liveness accounting) is mutable.
    let mut elastic = super::elastic::ElasticController::from_jobs(&cfg.elastic, &jobs);
    let mut total_jobs = jobs.len() as u64;
    for j in jobs {
        engine.schedule(j.submit_ms, Event::Arrival(Box::new(j)));
    }
    engine.schedule(0, Event::Cycle);
    engine.schedule(0, Event::Sample);
    if elastic.is_some() {
        engine.schedule(0, Event::LoadSample);
    }
    if cfg.defrag_interval_ms > 0 {
        engine.schedule(cfg.defrag_interval_ms, Event::Defrag);
    }
    let mut migrations_total: u64 = 0;

    let mut finished: u64 = 0;
    let mut stall: u64 = 0;
    let mut deadlocked = false;

    loop {
        // Every job departed: stop before draining the rest of the fault
        // trace — pure health churn with no work left would pointlessly
        // stretch the metrics window.
        if faults.is_some() && total_jobs > 0 && finished >= total_jobs {
            break;
        }
        let Some((now, event)) = engine.next() else {
            break;
        };
        if cfg.horizon_ms > 0 && now > cfg.horizon_ms {
            break;
        }
        match event {
            Event::Arrival(spec) => {
                metrics.on_submit();
                qsch.submit(&mut store, *spec);
            }
            Event::Cycle => {
                obs.begin_cycle();
                // Adaptive scoring tick (single-threaded phase, before the
                // queue walk): the controller reads rolling GAR/GFR/JWTD
                // windows and publishes the weight overlay the sharded
                // planners will inherit — identical for every `--shards N`.
                if rsch.wants_adapt() {
                    let t = obs.span();
                    let signals =
                        crate::rsch::adapt::collect_signals(now, &metrics, &store);
                    rsch.adapt_tick(&signals);
                    obs.span_end(ObsPhase::Adapt, t);
                }
                if obs.is_enabled() {
                    obs.set_overlay(
                        f64::from(rsch.cfg.overlay.pack_bias),
                        f64::from(rsch.cfg.overlay.fairness),
                    );
                }
                let report = qsch.cycle_observed(now, &mut store, state, rsch, obs);
                let progressed = !report.scheduled.is_empty() || !report.preempted.is_empty();
                for &job in &report.scheduled {
                    let j = store.expect(job);
                    metrics.on_scheduled(now, state, j);
                    engine.schedule(
                        now + cfg.platform_overhead_ms,
                        Event::RunningStart {
                            job,
                            epoch: j.epoch,
                        },
                    );
                }
                if progressed {
                    metrics.observe_cluster(now, state);
                    stall = 0;
                } else {
                    stall += 1;
                }
                // Keep cycling while any job is still in flight.
                let live = finished < total_jobs;
                deadlocked = stall >= cfg.stall_cycles && !engine.has_substantive_events();
                if live && !deadlocked {
                    engine.schedule_in(cfg.cycle_ms, Event::Cycle);
                } else if deadlocked {
                    eprintln!(
                        "warning: scheduling deadlock at t={} (sim time {}): \
                         {} unfinished job(s), {} queued, no substantive events \
                         pending after {} idle cycles",
                        now,
                        fmt_ms(now as f64),
                        total_jobs - finished,
                        qsch.queues.len(),
                        stall,
                    );
                    // Stall diagnostic: who is stuck at the head, why this
                    // cycle rejected what it rejected, and the last N
                    // decisions the recorder saw before the stall tripped.
                    if let Some(h) = qsch.queues.global_head() {
                        eprintln!(
                            "  queue head: job {} ({} GPUs, submitted {})",
                            h.job.0,
                            h.total_gpus,
                            fmt_ms(h.submit_ms as f64),
                        );
                    }
                    for (job, reason) in &report.admission_failures {
                        eprintln!("  admission rejected: job {} — {}", job.0, reason);
                    }
                    if !report.placement_failures.is_empty() {
                        let ids: Vec<u64> =
                            report.placement_failures.iter().map(|j| j.0).collect();
                        eprintln!("  placement failed: jobs {ids:?}");
                    }
                    let trace: Vec<String> = obs
                        .recent()
                        .map(|r| r.to_json().to_string_compact())
                        .collect();
                    if trace.is_empty() {
                        eprintln!(
                            "  (enable observability — e.g. `kant simulate \
                             --obs-out FILE` — for a decision trace here)"
                        );
                    } else {
                        eprintln!("  last {} decision record(s):", trace.len());
                        for line in trace {
                            eprintln!("    {line}");
                        }
                    }
                }
                obs.end_cycle(
                    now,
                    qsch.queues.len() as u64,
                    report.scheduled.len() as u64,
                    report.preempted.len() as u64,
                );
            }
            Event::RunningStart { job, epoch } => {
                let j = store.expect_mut(job);
                if j.phase == Phase::Scheduled && j.epoch == epoch {
                    j.mark_running(now);
                    let remaining = j.remaining_ms;
                    if let CheckpointPolicy::Interval(i) = j.spec.checkpoint {
                        engine.schedule(now + i.max(1), Event::CheckpointTick { job, epoch });
                    }
                    engine.schedule(now + remaining, Event::Finish { job, epoch });
                }
            }
            Event::Finish { job, epoch } => {
                let j = store.expect(job);
                if j.phase == Phase::Running && j.epoch == epoch {
                    // Goodput: the finished work survives; inflation is
                    // bind→finish wall time over the fault-free ideal. The
                    // credit is the *base-shape* footprint — a moldable job's
                    // work content is fixed, so finishing shrunk earns the
                    // same credit over more allocated GPU-time (that gap IS
                    // the throughput-weighted goodput loss).
                    let goodput =
                        j.spec.duration_ms.saturating_mul(j.spec.base_total_gpus() as u64);
                    let ideal = (j.spec.duration_ms + cfg.platform_overhead_ms).max(1);
                    let actual = now.saturating_sub(j.scheduled_ms.unwrap_or(j.submit_ms));
                    metrics
                        .reliability
                        .on_job_complete(goodput, actual as f64 / ideal as f64);
                    qsch.finish_job(&mut store, state, job, now);
                    metrics.on_finished();
                    metrics.observe_cluster(now, state);
                    finished += 1;
                }
            }
            Event::CheckpointTick { job, epoch } => {
                if let Some(j) = store.get_mut(job) {
                    if j.phase == Phase::Running && j.epoch == epoch {
                        j.mark_checkpoint(now);
                        if let CheckpointPolicy::Interval(i) = j.spec.checkpoint {
                            engine
                                .schedule(now + i.max(1), Event::CheckpointTick { job, epoch });
                        }
                    }
                }
            }
            Event::Sample => {
                metrics.observe_cluster(now, state);
                if finished < total_jobs && !deadlocked {
                    engine.schedule_in(cfg.sample_ms, Event::Sample);
                }
            }
            Event::LoadSample => {
                if let Some(ctrl) = elastic.as_mut() {
                    let d = ctrl.on_sample(now, &mut store, state, qsch, &mut metrics);
                    total_jobs += d.submitted;
                    finished += d.cancelled;
                    if d.cancelled > 0 {
                        // Scale-down released capacity; sample it so GAR
                        // sees the tide recede at the release instant.
                        metrics.observe_cluster(now, state);
                    }
                    if finished < total_jobs && !deadlocked {
                        engine.schedule_in(cfg.elastic.sample_ms, Event::LoadSample);
                    }
                }
            }
            Event::Defrag => {
                let span = obs.span();
                let plan = crate::rsch::defrag::plan_round(state, &store, &cfg.defrag);
                // Only migrate Running jobs (Scheduled ones are mid-start).
                let plan: Vec<_> = plan
                    .into_iter()
                    .filter(|m| {
                        store
                            .get(m.job)
                            .map(|j| j.phase == Phase::Running)
                            .unwrap_or(false)
                    })
                    .collect();
                let (report, moved) = crate::rsch::defrag::execute(state, &plan);
                if report.migrations > 0 {
                    migrations_total += report.migrations as u64;
                    // Charge the interruption and restart the finish clock
                    // under a fresh epoch — only for jobs that truly moved.
                    let mut seen = std::collections::HashSet::new();
                    for job in moved {
                        if !seen.insert(job) {
                            continue;
                        }
                        let j = store.expect_mut(job);
                        j.mark_migrated(now, cfg.migration_penalty_ms);
                        let epoch = j.epoch;
                        let remaining = j.remaining_ms;
                        if let CheckpointPolicy::Interval(i) = j.spec.checkpoint {
                            engine
                                .schedule(now + i.max(1), Event::CheckpointTick { job, epoch });
                        }
                        engine.schedule(now + remaining, Event::Finish { job, epoch });
                    }
                    metrics.observe_cluster(now, state);
                }
                obs.span_end(ObsPhase::Defrag, span);
                if finished < total_jobs && !deadlocked {
                    engine.schedule_in(cfg.defrag_interval_ms, Event::Defrag);
                }
            }
            Event::NodeHealth { node, healthy } => {
                let span = obs.span();
                // Evict any resident jobs first (they lose their devices),
                // then flip health — the §3.2.4 requeue path. Elastic
                // children are cancelled + re-provisioned instead (see
                // `evict_fault_victims`).
                if !healthy {
                    let mut victims: Vec<JobId> = state
                        .node(node)
                        .resident_pods()
                        .iter()
                        .map(|p| p.job)
                        .collect();
                    victims.sort_unstable();
                    victims.dedup();
                    finished += evict_fault_victims(
                        now,
                        &victims,
                        &mut store,
                        state,
                        qsch,
                        &mut elastic,
                        &mut metrics,
                    );
                }
                state.set_node_health(
                    node,
                    if healthy { Health::Healthy } else { Health::Faulty },
                );
                metrics.observe_cluster(now, state);
                obs.span_end(ObsPhase::Fault, span);
            }
            Event::FaultInject { target } => {
                if let Some(fi) = faults.as_mut() {
                    let span = obs.span();
                    let victims = fi.victims(state, target);
                    finished += evict_fault_victims(
                        now,
                        &victims,
                        &mut store,
                        state,
                        qsch,
                        &mut elastic,
                        &mut metrics,
                    );
                    fi.apply_fault(state, target);
                    match target {
                        FaultTarget::Node { .. } => metrics.reliability.node_faults += 1,
                        FaultTarget::Gpu { .. } => metrics.reliability.gpu_faults += 1,
                        FaultTarget::Hbd { .. } => metrics.reliability.hbd_faults += 1,
                        FaultTarget::Drain { .. } => metrics.reliability.drains += 1,
                    }
                    metrics.observe_cluster(now, state);
                    obs.span_end(ObsPhase::Fault, span);
                }
            }
            Event::RepairDone { target } => {
                if let Some(fi) = faults.as_mut() {
                    fi.apply_repair(state, target);
                    metrics.reliability.repairs += 1;
                    metrics.observe_cluster(now, state);
                }
            }
        }
    }

    let end_ms = engine.now();
    metrics.observe_cluster(end_ms, state);
    let unfinished = store.iter().filter(|j| !j.is_terminal()).count();

    // Roll the wall-clock profiles into the health report and graft on
    // the RSCH-side counters the recorder cannot see. All of this stays
    // outside `digest_json` — the digest-inertness invariant.
    let mut health = obs.health();
    let plan_attempts = rsch.stats.plan_cache_hits + rsch.stats.plan_cache_misses;
    if plan_attempts > 0 {
        health.plan_cache_hit_rate = rsch.stats.plan_cache_hits as f64 / plan_attempts as f64;
    }
    if rsch.stats.prefetch_batches > 0 {
        health.shard_imbalance =
            rsch.stats.prefetch_imbalance_sum / rsch.stats.prefetch_batches as f64;
    }
    health.nodes_examined = rsch.stats.nodes_examined;
    health.nodes_scored = rsch.stats.nodes_scored;
    obs.write_trailer(&health);

    SimOutcome {
        metrics,
        qsch_stats: qsch.stats,
        rsch_stats: rsch.stats,
        snapshot_stats: rsch.snapshot_stats(),
        end_ms,
        events_processed: engine.processed(),
        unfinished_jobs: unfinished,
        store,
        migrations: migrations_total,
        health,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::builder::{ClusterBuilder, ClusterSpec};
    use crate::cluster::ids::{GpuTypeId, JobId, TenantId};
    use crate::cluster::tenant::{QuotaLedger, QuotaMode};
    use crate::job::spec::{JobKind, JobSpec};
    use crate::qsch::policy::QschConfig;
    use crate::rsch::RschConfig;

    const G: GpuTypeId = GpuTypeId(0);

    fn stack(nodes: u32) -> (ClusterState, Qsch, Rsch) {
        let state = ClusterBuilder::build(&ClusterSpec::homogeneous("t", 1, 1, nodes));
        let mut ledger = QuotaLedger::new(2, 1, QuotaMode::Shared);
        ledger.set_limit(TenantId(0), G, nodes * 8);
        ledger.set_limit(TenantId(1), G, nodes * 8);
        let qsch = Qsch::new(QschConfig::default(), ledger);
        let rsch = Rsch::new(RschConfig::default(), &state);
        (state, qsch, rsch)
    }

    fn train(id: u64, replicas: u32, gpp: u32, submit: u64, dur: u64) -> JobSpec {
        JobSpec::homogeneous(JobId(id), TenantId(0), JobKind::Training, G, replicas, gpp)
            .with_times(submit, dur)
    }

    #[test]
    fn single_job_runs_to_completion() {
        let (mut state, mut qsch, mut rsch) = stack(2);
        let out = run(
            &mut state,
            &mut qsch,
            &mut rsch,
            vec![train(1, 2, 8, 0, 60_000)],
            &SimConfig::default(),
        );
        assert_eq!(out.unfinished_jobs, 0);
        assert_eq!(out.metrics.jobs_finished, 1);
        assert_eq!(state.allocated_gpus(), 0);
        // Job ran 60 s plus 30 s platform overhead from t=0 scheduling.
        assert!(out.end_ms >= 90_000);
        assert!(out.metrics.sor_final() > 0.0);
    }

    #[test]
    fn contention_serializes_jobs() {
        let (mut state, mut qsch, mut rsch) = stack(1); // 8 GPUs only.
        let jobs = vec![
            train(1, 1, 8, 0, 50_000),
            train(2, 1, 8, 0, 50_000),
            train(3, 1, 8, 0, 50_000),
        ];
        let out = run(&mut state, &mut qsch, &mut rsch, jobs, &SimConfig::default());
        assert_eq!(out.unfinished_jobs, 0);
        // Each must wait for the predecessor: scheduled at ~0 / ~80 s / ~160 s.
        let w: Vec<u64> = (1..=3)
            .map(|i| out.store.expect(JobId(i)).waiting_ms(out.end_ms))
            .collect();
        assert!(w[0] < 10_000, "{w:?}");
        assert!(w[1] > 50_000, "{w:?}");
        assert!(w[2] > w[1], "{w:?}");
    }

    #[test]
    fn unsatisfiable_job_does_not_hang_the_sim() {
        let (mut state, mut qsch, mut rsch) = stack(2);
        let cfg = SimConfig {
            stall_cycles: 10,
            ..SimConfig::default()
        };
        let jobs = vec![
            train(1, 1, 8, 0, 20_000),
            train(2, 5, 8, 0, 20_000), // 40 GPUs on a 16-GPU cluster.
        ];
        let out = run(&mut state, &mut qsch, &mut rsch, jobs, &cfg);
        assert_eq!(out.unfinished_jobs, 1);
        assert_eq!(out.metrics.jobs_finished, 1);
    }

    #[test]
    fn node_failure_evicts_requeues_and_recovers() {
        use crate::cluster::ids::NodeId;
        let (mut state, mut qsch, mut rsch) = stack(2);
        // Fail node 0 mid-run, recover it later. The resident job must be
        // evicted, requeued (§3.2.4) and finish eventually.
        let events = vec![
            (
                50_000,
                Event::NodeHealth {
                    node: NodeId(0),
                    healthy: false,
                },
            ),
            (
                200_000,
                Event::NodeHealth {
                    node: NodeId(0),
                    healthy: true,
                },
            ),
        ];
        // Two jobs filling both nodes; the one on node 0 gets hit.
        let jobs = vec![train(1, 1, 8, 0, 100_000), train(2, 1, 8, 0, 100_000)];
        let out = run_with_events(
            &mut state,
            &mut qsch,
            &mut rsch,
            jobs,
            events,
            &SimConfig::default(),
        );
        assert_eq!(out.unfinished_jobs, 0);
        assert_eq!(out.metrics.jobs_finished, 2);
        // Exactly one job suffered a preemption + requeue.
        let preempted: u32 = (1..=2).map(|i| out.store.expect(JobId(i)).preemptions).sum();
        assert_eq!(preempted, 1);
        assert_eq!(state.allocated_gpus(), 0);
    }

    #[test]
    fn elastic_service_scales_through_the_sim() {
        use crate::job::spec::ElasticService;
        let (mut state, mut qsch, mut rsch) = stack(2); // 16 GPUs.
        let day = ElasticService::DAY_MS;
        let svc = JobSpec::homogeneous(JobId(1), TenantId(0), JobKind::Inference, G, 8, 1)
            .with_times(0, day)
            .with_elastic(ElasticService {
                min_replicas: 2,
                max_replicas: 8,
                phase_ms: 0,
                amplitude: 1.0,
                period_ms: day,
            });
        let cfg = SimConfig {
            elastic: crate::sim::elastic::ElasticConfig::enabled(),
            ..SimConfig::default()
        };
        let out = run(&mut state, &mut qsch, &mut rsch, vec![svc], &cfg);
        assert_eq!(out.unfinished_jobs, 0);
        assert_eq!(state.allocated_gpus(), 0);
        // The service climbed toward its 8-replica noon peak and let the
        // tide back out (scale-downs or end-of-service cancellations).
        assert!(
            out.metrics.elastic.scale_up_replicas >= 6,
            "scale-ups {}",
            out.metrics.elastic.scale_up_replicas
        );
        assert!(out.metrics.elastic.samples > 100);
        assert!(out.metrics.elastic.replica_churn() >= out.metrics.elastic.scale_up_replicas);
        // Every job ended exactly one way: natural finish or cancellation.
        assert_eq!(
            out.metrics.jobs_submitted,
            out.metrics.jobs_finished + out.metrics.jobs_cancelled
        );
        // Demand tracking keeps violations rare.
        assert!(
            out.metrics.elastic.slo_violation_rate() < 0.1,
            "slo violation rate {}",
            out.metrics.elastic.slo_violation_rate()
        );
    }

    #[test]
    fn checkpoint_policy_bounds_lost_work_on_fault() {
        use crate::cluster::ids::NodeId;
        let run_policy = |p: CheckpointPolicy| -> (u64, u64) {
            let (mut state, mut qsch, mut rsch) = stack(1);
            let job = train(1, 1, 8, 0, 100_000).with_checkpoint(p);
            let events = vec![
                (
                    80_000,
                    Event::NodeHealth {
                        node: NodeId(0),
                        healthy: false,
                    },
                ),
                (
                    150_000,
                    Event::NodeHealth {
                        node: NodeId(0),
                        healthy: true,
                    },
                ),
            ];
            let out = run_with_events(
                &mut state,
                &mut qsch,
                &mut rsch,
                vec![job],
                events,
                &SimConfig::default(),
            );
            assert_eq!(out.unfinished_jobs, 0);
            (
                out.store.expect(JobId(1)).lost_work_ms,
                out.metrics.reliability.lost_gpu_ms,
            )
        };
        // Running from t=30s (platform overhead), failed at t=80s: 50s of
        // the 100s ran. Continuous keeps it all; Interval(20s) ticked at
        // 50s/70s so 40s survive (10s lost); None redoes everything.
        assert_eq!(run_policy(CheckpointPolicy::Continuous), (0, 0));
        assert_eq!(
            run_policy(CheckpointPolicy::Interval(20_000)),
            (10_000, 10_000 * 8)
        );
        assert_eq!(run_policy(CheckpointPolicy::None), (50_000, 50_000 * 8));
    }

    #[test]
    fn fault_storm_is_deterministic_and_releases_everything() {
        use crate::sim::faults::FaultConfig;
        let run_once = || {
            let (mut state, mut qsch, mut rsch) = stack(4);
            let jobs: Vec<JobSpec> = (1..=12)
                .map(|i| {
                    train(i, 1, 8, i * 60_000, 600_000)
                        .with_checkpoint(CheckpointPolicy::Interval(120_000))
                })
                .collect();
            let cfg = SimConfig {
                horizon_ms: 24 * 3_600_000,
                faults: FaultConfig {
                    seed: 9,
                    node_mtbf_ms: 2 * 3_600_000,
                    node_mttr_ms: 600_000,
                    gpu_mtbf_ms: 8 * 3_600_000,
                    gpu_mttr_ms: 600_000,
                    drain_mtbf_ms: 8 * 3_600_000,
                    drain_duration_ms: 900_000,
                    ..FaultConfig::default()
                },
                ..SimConfig::default()
            };
            let out = run(&mut state, &mut qsch, &mut rsch, jobs, &cfg);
            (
                out.digest_json().to_string_compact(),
                state.allocated_gpus(),
                out.metrics.reliability.faults_injected(),
                out.unfinished_jobs,
            )
        };
        let (a, alloc, faults, unfinished) = run_once();
        let (b, _, _, _) = run_once();
        assert_eq!(a, b, "same-seed fault storms must replay identically");
        assert!(faults > 0, "a day-long storm must inject something");
        assert_eq!(unfinished, 0);
        assert_eq!(alloc, 0, "every device released after the run");
    }

    #[test]
    fn drain_migrates_resident_via_defrag_without_eviction() {
        use crate::job::spec::JobKind;
        use crate::sim::faults::{FaultConfig, FaultTarget};
        // Single-pod non-gang job; learn its node from a dry run, then
        // replay with a maintenance drain on that node.
        let job = || {
            let mut j =
                JobSpec::homogeneous(JobId(1), TenantId(0), JobKind::Inference, G, 1, 4)
                    .with_times(0, 3_600_000);
            j.gang = false;
            j
        };
        let probe = {
            let (mut state, mut qsch, mut rsch) = stack(2);
            let cfg = SimConfig {
                horizon_ms: 60_000,
                ..SimConfig::default()
            };
            run(&mut state, &mut qsch, &mut rsch, vec![job()], &cfg);
            state.nodes_of(JobId(1))[0]
        };
        let (mut state, mut qsch, mut rsch) = stack(2);
        // Drains enabled but at an unreachable rate: the only drain is
        // the hand-scheduled one below.
        let cfg = SimConfig {
            defrag_interval_ms: 120_000,
            faults: FaultConfig {
                seed: 1,
                drain_mtbf_ms: u64::MAX,
                drain_duration_ms: 600_000,
                ..FaultConfig::default()
            },
            ..SimConfig::default()
        };
        let events = vec![(
            30_000,
            Event::FaultInject {
                target: FaultTarget::Drain { node: probe },
            },
        )];
        let out = run_with_events(&mut state, &mut qsch, &mut rsch, vec![job()], events, &cfg);
        assert_eq!(out.unfinished_jobs, 0);
        let j = out.store.expect(JobId(1));
        assert_eq!(j.preemptions, 0, "drains never evict");
        assert_eq!(j.migrations, 1, "defrag must vacate the drain");
        assert_eq!(out.migrations, 1);
        assert_eq!(out.metrics.reliability.drains, 1);
        assert_eq!(state.allocated_gpus(), 0);
    }

    #[test]
    fn digest_replays_byte_identical() {
        let run_once = |perturb: bool| {
            let (mut state, mut qsch, mut rsch) = stack(2);
            let jobs = vec![
                train(1, 1, 8, 0, 50_000),
                train(2, 1, 8, 0, 50_000),
                train(3, 2, 8, 10_000, if perturb { 45_000 } else { 40_000 }),
            ];
            run(&mut state, &mut qsch, &mut rsch, jobs, &SimConfig::default())
                .digest_json()
                .to_string_compact()
        };
        assert_eq!(run_once(false), run_once(false));
        assert_ne!(run_once(false), run_once(true));
    }

    #[test]
    fn sor_counts_binding_before_running() {
        // SOR accrues from scheduling (binding), including the platform
        // overhead window (§4.2).
        let (mut state, mut qsch, mut rsch) = stack(1);
        let cfg = SimConfig {
            platform_overhead_ms: 60_000, // Long image pull.
            ..SimConfig::default()
        };
        let out = run(
            &mut state,
            &mut qsch,
            &mut rsch,
            vec![train(1, 1, 8, 0, 60_000)],
            &cfg,
        );
        // Held 8/8 GPUs for 120 s of a ~120 s sim → SOR near 1.
        assert!(out.metrics.sor_final() > 0.9, "{}", out.metrics.sor_final());
    }
}
