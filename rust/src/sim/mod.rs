//! Discrete-event simulation: the engine, the elasticity loop, the
//! reliability (fault-injection) loop, and the experiment runner.

pub mod elastic;
pub mod engine;
pub mod faults;
pub mod runner;

pub use elastic::{ElasticConfig, ElasticController};
pub use engine::{Engine, Event, SimTime};
pub use faults::{FaultConfig, FaultInjector, FaultTarget};
pub use runner::{run, run_observed, run_with_events, SimConfig, SimOutcome};
