//! Discrete-event simulation: the engine and the experiment runner.

pub mod engine;
pub mod runner;

pub use engine::{Engine, Event, SimTime};
pub use runner::{run, run_with_events, SimConfig, SimOutcome};
