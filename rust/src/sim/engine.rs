//! Deterministic discrete-event engine: a time-ordered heap with stable
//! FIFO ordering for simultaneous events.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::cluster::ids::JobId;
use crate::job::spec::JobSpec;

/// Simulation time in milliseconds.
pub type SimTime = u64;

/// Events the runner understands.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// A job arrives at QSCH.
    Arrival(Box<JobSpec>),
    /// Periodic scheduling cycle.
    Cycle,
    /// A scheduled job's containers come up (platform overhead elapsed).
    /// `epoch` = the job's preemption count at scheduling time; stale
    /// events (job preempted meanwhile) are dropped.
    RunningStart { job: JobId, epoch: u32 },
    /// A running job completes.
    Finish { job: JobId, epoch: u32 },
    /// Periodic metrics sample.
    Sample,
    /// Periodic elastic-inference load sample: the controller reads each
    /// service's diurnal demand and issues replica-delta requests.
    LoadSample,
    /// Periodic fragmentation reorganization round (§3.3.3).
    Defrag,
    /// Inject a node health flip (hand-scheduled failure injection).
    NodeHealth {
        node: crate::cluster::ids::NodeId,
        healthy: bool,
    },
    /// A failure domain goes down (stochastic fault injection; see
    /// [`crate::sim::faults`]).
    FaultInject {
        target: crate::sim::faults::FaultTarget,
    },
    /// A failed/drained domain returns to service (MTTR elapsed).
    RepairDone {
        target: crate::sim::faults::FaultTarget,
    },
    /// Periodic per-job checkpoint tick (`CheckpointPolicy::Interval`):
    /// progress up to the tick survives later evictions. Stale epochs
    /// (job preempted/migrated meanwhile) are dropped.
    CheckpointTick { job: JobId, epoch: u32 },
}

#[derive(Debug)]
struct Scheduled {
    time: SimTime,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// The event queue.
#[derive(Debug, Default)]
pub struct Engine {
    heap: BinaryHeap<Reverse<Scheduled>>,
    seq: u64,
    now: SimTime,
    processed: u64,
}

impl Engine {
    pub fn new() -> Engine {
        Engine::default()
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `event` at absolute time `at` (clamped to now).
    pub fn schedule(&mut self, at: SimTime, event: Event) {
        let at = at.max(self.now);
        self.seq += 1;
        self.heap.push(Reverse(Scheduled {
            time: at,
            seq: self.seq,
            event,
        }));
    }

    pub fn schedule_in(&mut self, delay: SimTime, event: Event) {
        self.schedule(self.now + delay, event);
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Does the queue hold anything besides Cycle/Sample/checkpoint ticks?
    /// Fault/repair events count as substantive: a repair can unblock a
    /// queued gang that looks permanently unschedulable right now.
    pub fn has_substantive_events(&self) -> bool {
        self.heap.iter().any(|Reverse(s)| {
            !matches!(
                s.event,
                Event::Cycle
                    | Event::Sample
                    | Event::LoadSample
                    | Event::Defrag
                    | Event::CheckpointTick { .. }
            )
        })
    }
}

/// Popping the next event advances the clock.
impl Iterator for Engine {
    type Item = (SimTime, Event);

    fn next(&mut self) -> Option<(SimTime, Event)> {
        let Reverse(s) = self.heap.pop()?;
        debug_assert!(s.time >= self.now, "time went backwards");
        self.now = s.time;
        self.processed += 1;
        Some((s.time, s.event))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut e = Engine::new();
        e.schedule(10, Event::Cycle);
        e.schedule(5, Event::Sample);
        e.schedule(7, Event::Cycle);
        let order: Vec<SimTime> = std::iter::from_fn(|| e.next().map(|(t, _)| t)).collect();
        assert_eq!(order, vec![5, 7, 10]);
        assert_eq!(e.processed(), 3);
    }

    #[test]
    fn simultaneous_events_fifo() {
        let mut e = Engine::new();
        e.schedule(5, Event::Cycle);
        e.schedule(5, Event::Sample);
        assert_eq!(e.next().unwrap().1, Event::Cycle);
        assert_eq!(e.next().unwrap().1, Event::Sample);
    }

    #[test]
    fn clock_advances_and_clamps() {
        let mut e = Engine::new();
        e.schedule(10, Event::Cycle);
        e.next();
        assert_eq!(e.now(), 10);
        e.schedule(3, Event::Cycle); // Past time clamps to now.
        assert_eq!(e.next().unwrap().0, 10);
    }

    #[test]
    fn substantive_event_detection() {
        let mut e = Engine::new();
        e.schedule(1, Event::Cycle);
        e.schedule(2, Event::Sample);
        e.schedule(3, Event::LoadSample);
        assert!(!e.has_substantive_events());
        e.schedule(3, Event::Finish {
            job: JobId(1),
            epoch: 0,
        });
        assert!(e.has_substantive_events());
    }
}
