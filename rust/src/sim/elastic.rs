//! The elasticity loop: diurnal autoscaling of inference replica sets
//! plus tidal training co-scheduling.
//!
//! Every `Event::LoadSample` the controller reads each elastic service's
//! deterministic demand curve ([`ElasticService::demand_replicas`]) and
//! drives the replica count toward it:
//!
//! * **scale-up** — immediately submits single-replica *child* jobs
//!   (`JobSpec::service = Some(base)`) into QSCH; they place through the
//!   ordinary cycle/RSCH path (E-Spread zone rules and the free-capacity
//!   `NodeIndex` apply unchanged), and a blocked delta triggers
//!   SLO-pressure reclamation of tidal training
//!   ([`crate::qsch::preemption::PreemptKind::SloPressure`]).
//! * **scale-down** — after a hysteresis window, cancels the *newest*
//!   children first (least progress lost), releasing their devices and
//!   refunding their quota; the freed capacity is what tidal training
//!   backfills overnight.
//!
//! The controller is pure bookkeeping over the seeded workload: same
//! seed + config ⇒ the same replica-delta sequence, which is what the
//! golden-gate determinism CI job pins.

use crate::cluster::ids::JobId;
use crate::cluster::state::ClusterState;
use crate::job::spec::{ElasticService, JobKind, JobSpec, TypedDemand};
use crate::job::store::JobStore;
use crate::metrics::Metrics;
use crate::qsch::Qsch;

/// Elasticity-loop tunables (carried by `SimConfig`).
#[derive(Debug, Clone)]
pub struct ElasticConfig {
    /// Load-sample period in ms; 0 disables the loop entirely (no
    /// `LoadSample` events are scheduled).
    pub sample_ms: u64,
    /// Headroom factor: desired = ceil(demand / target_utilization),
    /// clamped to the service envelope. 1.0 provisions exactly the
    /// demand; lower values keep spare replicas.
    pub target_utilization: f64,
    /// Hysteresis: consecutive samples demand must sit below the current
    /// size before scaling down. Scale-up is immediate — SLO pressure
    /// does not wait out a stability window.
    pub scale_down_stable_samples: u32,
    /// When false the controller only observes (SLO accounting for the
    /// static arm); no replica deltas are issued.
    pub controller: bool,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig {
            sample_ms: 0,
            target_utilization: 1.0,
            scale_down_stable_samples: 3,
            controller: true,
        }
    }
}

impl ElasticConfig {
    /// The loop enabled at a 5-minute sampling period.
    pub fn enabled() -> ElasticConfig {
        ElasticConfig {
            sample_ms: 5 * 60_000,
            ..ElasticConfig::default()
        }
    }

    /// Observe-only variant (the static experiment arm): SLO violations
    /// are measured against the same curves, but nothing scales.
    pub fn observe_only() -> ElasticConfig {
        ElasticConfig {
            controller: false,
            ..ElasticConfig::enabled()
        }
    }
}

/// Net job-count delta of one load sample, fed back into the runner's
/// liveness accounting (children enter and leave the job population
/// outside the pre-generated workload).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SampleDelta {
    /// Replica-delta child jobs submitted (scale-up).
    pub submitted: u64,
    /// Jobs cancelled (scale-down releases + retired services' children).
    pub cancelled: u64,
}

/// Controller state for one elastic service.
#[derive(Debug)]
struct ServiceState {
    base: JobId,
    curve: ElasticService,
    /// Live single-replica children, oldest first (scale-down pops the
    /// back: newest replicas are the lowest-value ones).
    children: Vec<JobId>,
    /// Replicas the controller currently asks for: base floor + live
    /// children (placed or still queued).
    requested: u32,
    /// Consecutive samples with desired < requested (hysteresis).
    below: u32,
    /// The service retires once its base job is terminal.
    retired: bool,
}

/// The target-utilization elastic controller (one per simulation run).
pub struct ElasticController {
    cfg: ElasticConfig,
    services: Vec<ServiceState>,
    next_child: u64,
}

impl ElasticController {
    /// Build from the workload; `None` when the loop is disabled or no
    /// job carries an [`ElasticService`].
    pub fn from_jobs(cfg: &ElasticConfig, jobs: &[JobSpec]) -> Option<ElasticController> {
        if cfg.sample_ms == 0 {
            return None;
        }
        let mut services: Vec<ServiceState> = jobs
            .iter()
            .filter_map(|j| {
                j.elastic.map(|curve| ServiceState {
                    base: j.id,
                    curve,
                    children: Vec::new(),
                    requested: j.total_replicas().max(curve.min_replicas),
                    below: 0,
                    retired: false,
                })
            })
            .collect();
        if services.is_empty() {
            return None;
        }
        // Deterministic walk order + child-id base above every workload id.
        services.sort_by_key(|s| s.base);
        let next_child = jobs.iter().map(|j| j.id.0).max().unwrap_or(0) + 1;
        Some(ElasticController {
            cfg: cfg.clone(),
            services,
            next_child,
        })
    }

    pub fn num_services(&self) -> usize {
        self.services.len()
    }

    /// A fault (or health flip) killed one of this controller's
    /// replica-delta children: drop it from the service's books so the
    /// next load sample re-provisions the replica instead of
    /// double-counting a dead one. The caller cancels the job itself
    /// (releasing devices and refunding quota via `Qsch::cancel_job`).
    /// Returns whether the id was a live child; base jobs and ordinary
    /// workload jobs are a no-op.
    pub fn on_child_evicted(&mut self, child: JobId) -> bool {
        for svc in self.services.iter_mut() {
            if let Some(pos) = svc.children.iter().position(|&c| c == child) {
                svc.children.remove(pos);
                svc.requested = svc.requested.saturating_sub(1);
                return true;
            }
        }
        false
    }

    /// One `Event::LoadSample`: SLO accounting for every live service,
    /// then (controller mode) replica deltas toward the demand curve.
    pub fn on_sample(
        &mut self,
        now: u64,
        store: &mut JobStore,
        state: &mut ClusterState,
        qsch: &mut Qsch,
        metrics: &mut Metrics,
    ) -> SampleDelta {
        let mut delta = SampleDelta::default();
        let mut live_services = 0u64;
        let mut freed_gpus = 0u64;

        // Detach the service list so the loop can borrow self.cfg /
        // self.next_child freely alongside each mutable service entry.
        let mut services = std::mem::take(&mut self.services);
        for svc in services.iter_mut() {
            let Some(base_job) = store.get(svc.base) else {
                continue; // Not yet submitted to QSCH.
            };
            if svc.retired {
                continue;
            }
            if base_job.is_terminal() {
                // Service over: cancel whatever children remain.
                for c in std::mem::take(&mut svc.children) {
                    if qsch.cancel_job(store, state, c, now) {
                        delta.cancelled += 1;
                        metrics.on_cancelled();
                    }
                }
                svc.retired = true;
                continue;
            }
            live_services += 1;

            let spec = base_job.spec.clone();
            let gpus_per_pod = spec.gpus_per_replica().max(1);
            let base_replicas = spec.total_replicas();
            let service_end = spec.submit_ms.saturating_add(spec.duration_ms);

            // Prune children that reached a natural end (service tail).
            let mut natural = 0u32;
            svc.children.retain(|&c| {
                let done = store.get(c).map(|j| j.is_terminal()).unwrap_or(true);
                if done {
                    natural += 1;
                }
                !done
            });
            svc.requested = svc.requested.saturating_sub(natural);

            // Demand vs what actually holds resources right now.
            let demand = svc.curve.demand_replicas(now);
            let base_active = if store.expect(svc.base).holds_resources() {
                base_replicas
            } else {
                0
            };
            let child_active = svc
                .children
                .iter()
                .filter(|&&c| store.expect(c).holds_resources())
                .count() as u32;
            let active = base_active + child_active;
            metrics.elastic.samples += 1;
            if active < demand {
                metrics.elastic.slo_violations += 1;
            }

            if self.cfg.controller && base_active > 0 {
                let target = self.cfg.target_utilization.clamp(0.05, 1.0);
                let desired = ((demand as f64 / target).ceil() as u32)
                    .clamp(svc.curve.min_replicas, svc.curve.max_replicas);
                if desired > svc.requested {
                    // Scale-up: one single-replica child per missing
                    // replica, submitted into the ordinary QSCH queue.
                    svc.below = 0;
                    let grow = desired - svc.requested;
                    for _ in 0..grow {
                        let child = replica_delta_spec(
                            &spec,
                            JobId(self.next_child),
                            now,
                            service_end,
                            gpus_per_pod,
                        );
                        self.next_child += 1;
                        svc.children.push(child.id);
                        metrics.on_submit();
                        qsch.submit(store, child);
                        delta.submitted += 1;
                    }
                    metrics.elastic.scale_up_replicas += grow as u64;
                    svc.requested = desired;
                } else if desired < svc.requested {
                    svc.below += 1;
                    if svc.below >= self.cfg.scale_down_stable_samples {
                        // Scale-down: release the newest children first,
                        // never below the base floor.
                        let mut released = 0u64;
                        while svc.requested > desired {
                            let Some(c) = svc.children.pop() else {
                                break; // At the base floor already.
                            };
                            if qsch.cancel_job(store, state, c, now) {
                                delta.cancelled += 1;
                                released += 1;
                                metrics.on_cancelled();
                            }
                            svc.requested -= 1;
                        }
                        metrics.elastic.scale_down_replicas += released;
                        svc.below = 0;
                    }
                } else {
                    svc.below = 0;
                }
            }

            freed_gpus += svc.curve.max_replicas.saturating_sub(svc.requested) as u64
                * gpus_per_pod as u64;
        }
        self.services = services;

        // Tidal harvest: GPUs currently held by tidal training.
        let tidal_gpus: u64 = store
            .holding_resources()
            .filter(|j| j.spec.tidal)
            .map(|j| j.spec.total_gpus() as u64)
            .sum();
        metrics.elastic.services = metrics.elastic.services.max(live_services);
        metrics.elastic.observe(now, freed_gpus as u32, tidal_gpus as u32);
        delta
    }
}

/// A single-replica scale-up child of `base`, inheriting tenant,
/// priority, strategy, HBD constraint, and GPU model; retires with the
/// service. Elastic services are sole-demand by construction
/// ([`crate::job::spec::JobSpec::with_elastic`] pins every demand to the
/// floor, and the generator emits single-demand services), so the child
/// replicates `demands[0]`.
fn replica_delta_spec(
    base: &JobSpec,
    id: JobId,
    now: u64,
    service_end: u64,
    gpus_per_pod: u32,
) -> JobSpec {
    JobSpec {
        id,
        tenant: base.tenant,
        kind: JobKind::Inference,
        priority: base.priority,
        gang: false,
        demands: vec![TypedDemand {
            gpu_type: base.demands[0].gpu_type,
            replicas: 1,
            gpus_per_pod,
        }],
        submit_ms: now,
        duration_ms: service_end.saturating_sub(now).max(60_000),
        strategy: base.strategy,
        needs_hbd: base.needs_hbd,
        elastic: None,
        service: Some(base.id),
        tidal: false,
        checkpoint: crate::job::spec::CheckpointPolicy::Continuous,
        shapes: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::builder::{ClusterBuilder, ClusterSpec};
    use crate::cluster::ids::{GpuTypeId, TenantId};
    use crate::cluster::tenant::{QuotaLedger, QuotaMode};
    use crate::qsch::policy::QschConfig;
    use crate::rsch::{Rsch, RschConfig};

    const G: GpuTypeId = GpuTypeId(0);
    const DAY: u64 = ElasticService::DAY_MS;

    fn curve(min: u32, max: u32) -> ElasticService {
        ElasticService {
            min_replicas: min,
            max_replicas: max,
            phase_ms: 0,
            amplitude: 1.0,
            period_ms: DAY,
        }
    }

    fn service(id: u64, min: u32, max: u32) -> JobSpec {
        JobSpec::homogeneous(JobId(id), TenantId(0), JobKind::Inference, G, max, 1)
            .with_times(0, 2 * DAY)
            .with_elastic(curve(min, max))
    }

    /// Cluster + QSCH + RSCH + store with the base service placed.
    fn harness(min: u32, max: u32) -> (ClusterState, Qsch, Rsch, JobStore, Metrics) {
        let state = ClusterBuilder::build(&ClusterSpec::homogeneous("e", 1, 2, 4));
        let mut ledger = QuotaLedger::new(2, 1, QuotaMode::Shared);
        ledger.set_limit(TenantId(0), G, 64);
        ledger.set_limit(TenantId(1), G, 0);
        let mut qsch = Qsch::new(QschConfig::default(), ledger);
        let rsch = Rsch::new(RschConfig::default(), &state);
        let mut store = JobStore::new();
        let metrics = Metrics::new(&state, 0);
        qsch.submit(&mut store, service(1, min, max));
        (state, qsch, rsch, store, metrics)
    }

    #[test]
    fn disabled_or_inelastic_workloads_have_no_controller() {
        let jobs = vec![service(1, 2, 8)];
        assert!(ElasticController::from_jobs(&ElasticConfig::default(), &jobs).is_none());
        let plain = vec![JobSpec::homogeneous(
            JobId(1),
            TenantId(0),
            JobKind::Training,
            G,
            1,
            8,
        )];
        assert!(ElasticController::from_jobs(&ElasticConfig::enabled(), &plain).is_none());
    }

    #[test]
    fn scale_up_submits_children_and_scale_down_cancels_newest() {
        let (mut state, mut qsch, mut rsch, mut store, mut metrics) = harness(2, 10);
        let jobs = vec![service(1, 2, 10)];
        let mut cfg = ElasticConfig::enabled();
        cfg.scale_down_stable_samples = 2;
        let mut ctrl = ElasticController::from_jobs(&cfg, &jobs).unwrap();
        assert_eq!(ctrl.num_services(), 1);

        // Place the base set (2 replicas).
        qsch.cycle(0, &mut store, &mut state, &mut rsch);
        assert_eq!(state.allocated_gpus(), 2);

        // Midday: demand 10 → 8 children submitted.
        let noon = DAY / 2;
        let d = ctrl.on_sample(noon, &mut store, &mut state, &mut qsch, &mut metrics);
        assert_eq!(d.submitted, 8);
        assert_eq!(metrics.elastic.scale_up_replicas, 8);
        qsch.cycle(noon + 1, &mut store, &mut state, &mut rsch);
        assert_eq!(state.allocated_gpus(), 10);

        // Same demand: no extra submissions (requested tracking).
        let d = ctrl.on_sample(noon + 60_000, &mut store, &mut state, &mut qsch, &mut metrics);
        assert_eq!(d, SampleDelta::default());

        // Night: demand 2. Hysteresis holds one sample, then releases.
        let night = DAY;
        let d = ctrl.on_sample(night, &mut store, &mut state, &mut qsch, &mut metrics);
        assert_eq!(d.cancelled, 0, "first below-sample waits");
        let d = ctrl.on_sample(night + 60_000, &mut store, &mut state, &mut qsch, &mut metrics);
        assert_eq!(d.cancelled, 8);
        assert_eq!(metrics.elastic.scale_down_replicas, 8);
        assert_eq!(state.allocated_gpus(), 2, "base floor survives");
        assert_eq!(qsch.stats.cancellations, 8);
    }

    #[test]
    fn slo_violations_recorded_when_under_demand() {
        let (mut state, mut qsch, mut rsch, mut store, mut metrics) = harness(2, 10);
        let jobs = vec![service(1, 2, 10)];
        // Observe-only: the static arm measures, never scales.
        let cfg = ElasticConfig::observe_only();
        let mut ctrl = ElasticController::from_jobs(&cfg, &jobs).unwrap();
        qsch.cycle(0, &mut store, &mut state, &mut rsch);
        let d = ctrl.on_sample(DAY / 2, &mut store, &mut state, &mut qsch, &mut metrics);
        assert_eq!(d, SampleDelta::default(), "observe-only never acts");
        assert_eq!(metrics.elastic.samples, 1);
        assert_eq!(metrics.elastic.slo_violations, 1, "2 active < 10 demanded");
        assert!(metrics.elastic.slo_violation_rate() > 0.99);
    }

    #[test]
    fn fault_evicted_child_is_reprovisioned_next_sample() {
        let (mut state, mut qsch, mut rsch, mut store, mut metrics) = harness(2, 10);
        let jobs = vec![service(1, 2, 10)];
        let mut ctrl = ElasticController::from_jobs(&ElasticConfig::enabled(), &jobs).unwrap();
        qsch.cycle(0, &mut store, &mut state, &mut rsch);
        let noon = DAY / 2;
        ctrl.on_sample(noon, &mut store, &mut state, &mut qsch, &mut metrics);
        qsch.cycle(noon + 1, &mut store, &mut state, &mut rsch);
        assert_eq!(state.allocated_gpus(), 10);
        // A fault kills child 2 (ids 2..=9 are the scale-up children):
        // the books drop it and the cancel releases its device.
        assert!(ctrl.on_child_evicted(JobId(2)));
        assert!(qsch.cancel_job(&mut store, &mut state, JobId(2), noon + 2));
        assert_eq!(state.allocated_gpus(), 9);
        // The base job is not a child; unknown ids are no-ops.
        assert!(!ctrl.on_child_evicted(JobId(1)));
        // Same demand next sample: exactly the dead replica is re-made.
        let d = ctrl.on_sample(noon + 60_000, &mut store, &mut state, &mut qsch, &mut metrics);
        assert_eq!(d.submitted, 1);
        qsch.cycle(noon + 60_001, &mut store, &mut state, &mut rsch);
        assert_eq!(state.allocated_gpus(), 10, "replica count restored");
    }

    #[test]
    fn retired_service_cancels_children() {
        let (mut state, mut qsch, mut rsch, mut store, mut metrics) = harness(2, 6);
        let jobs = vec![service(1, 2, 6)];
        let mut ctrl = ElasticController::from_jobs(&ElasticConfig::enabled(), &jobs).unwrap();
        qsch.cycle(0, &mut store, &mut state, &mut rsch);
        ctrl.on_sample(DAY / 2, &mut store, &mut state, &mut qsch, &mut metrics);
        qsch.cycle(DAY / 2 + 1, &mut store, &mut state, &mut rsch);
        assert_eq!(state.allocated_gpus(), 6);
        // Base job ends.
        qsch.finish_job(&mut store, &mut state, JobId(1), DAY / 2 + 2);
        let d = ctrl.on_sample(DAY / 2 + 60_000, &mut store, &mut state, &mut qsch, &mut metrics);
        assert_eq!(d.cancelled, 4);
        assert_eq!(state.allocated_gpus(), 0);
        // A retired service stays quiet.
        let d = ctrl.on_sample(DAY, &mut store, &mut state, &mut qsch, &mut metrics);
        assert_eq!(d, SampleDelta::default());
    }
}
