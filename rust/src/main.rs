//! `kant` — the leader entrypoint / CLI.
//!
//! Subcommands:
//!   simulate     run a workload (generated or trace) on a cluster preset
//!   gen-trace    generate and save a workload trace (JSONL)
//!   validate     smoke-check the AOT artifacts through the PJRT runtime
//!   bench-check  validate / diff benchkit baseline documents (the CI
//!                bench-regression gate, also runnable locally)
//!   obs          summarize an observability JSONL stream (--obs-out)
//!   explain      replay one job's decision records from a stream
//!   harness      run the whole experiment zoo into one results JSON
//!   lint         determinism & concurrency static analysis over src/**
//!
//! The figures harness lives in the separate `figures` binary.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use kant::config::{FaultPreset, InferencePreset, Scale, SimOptions, SimSetup};
use kant::experiments::jwtd_buckets;
use kant::job::spec::PlacementStrategy;
use kant::job::trace;
use kant::job::workload::{WorkloadConfig, WorkloadGen};
use kant::metrics::report::{bucket_comparison, fmt_ms, headline, pct, phase_table};
use kant::obs::{DecisionRecord, ObsRecorder, SchedulerHealth};
use kant::qsch::policy::QueuePolicy;
use kant::qsch::Qsch;
use kant::rsch::{Rsch, RschConfig};
use kant::sim::run_observed;
use kant::util::json::Json;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("simulate") => simulate(&args[1..]),
        Some("gen-trace") => gen_trace(&args[1..]),
        Some("validate") => validate(&args[1..]),
        Some("bench-check") => bench_check(&args[1..]),
        Some("obs") => obs_cmd(&args[1..]),
        Some("explain") => explain(&args[1..]),
        Some("harness") => harness(&args[1..]),
        Some("lint") => lint_cmd(&args[1..]),
        Some("-h" | "--help") | None => {
            println!("{HELP}");
            Ok(())
        }
        Some(other) => bail!("unknown subcommand '{other}'\n{HELP}"),
    }
}

const HELP: &str = "\
kant — unified scheduling system for large-scale AI clusters (paper reproduction)

usage:
  kant simulate [--cluster train|i2|i7|a10] [--scale small|paper|xlarge|xxlarge]
                [--seed N] [--policy strict-fifo|best-effort|backfill]
                [--strategy native|binpack|e-binpack|spread|e-spread]
                [--trace FILE] [--xla-scorer] [--flat] [--deep-snapshot]
                [--no-index] [--topo-blind] [--elastic] [--faults]
                [--checkpoint-min N] [--shards N] [--adapt]
                [--jwtd-bound MIN] [--moldable] [--digest FILE]
                [--obs-out FILE] [--obs-verbosity 0|1|2]
  kant gen-trace [--seed N] [--jobs N] [--mix training|inference] --out FILE
  kant validate [--artifacts DIR]
  kant bench-check validate FILE
  kant bench-check diff BASELINE FRESH [--tolerance X]
  kant obs summarize FILE
  kant explain --job ID FILE
  kant harness [--scale small|paper|xlarge] [--seed N] [--out FILE]
  kant harness validate FILE
  kant lint [--root DIR] [--json] [--github] [--out FILE]

Every flag is a thin adapter onto the typed `SimOptions` builder
(kant::config::SimOptions) — the single constructor of the scheduler and
simulator configs, so defaults cannot drift between entry points.

flags:
  --scale          cluster preset size; `xxlarge` (alias `100k`) is the
                   100,000-GPU / 12,500-node frontier cluster spanning 10
                   superspines (one scheduler shard each)
  --flat           disable two-level (NodeNetGroup preselect) scheduling
  --deep-snapshot  rebuild the full snapshot every cycle (no §3.4.3 delta)
  --no-index       linear candidate scans instead of the free-capacity index
  --topo-blind     pre-fix topology ablation: the scorer cannot distinguish
                   cross-superspine from same-superspine placement (digests
                   for topology-agnostic strategies are invariant to this)
  --elastic        elastic inference: most services become diurnal replica
                   sets and the autoscaling controller runs every 5 min
  --faults         stochastic fault injection: seeded MTBF/MTTR storms per
                   GPU / node / HBD plus maintenance drains; training jobs
                   checkpoint every 30 min (see --checkpoint-min) and
                   drain-aware defrag runs every 30 min
  --checkpoint-min N  checkpoint interval for training jobs under --faults
                   (minutes; 0 = naive restart-from-scratch)
  --shards N       superspine-sharded placement prefetch on N worker
                   threads (0 = legacy sequential core). The shard
                   structure is fixed by the topology, so every N >= 1 is
                   digest-identical; incompatible with --xla-scorer
  --adapt          seeded adaptive weight controller: once per cycle, shift
                   the native scorer's packing/spreading/fairness mix from
                   rolling GAR/GFR/JWTD windows (hysteresis + step clamps
                   keep same-seed digests byte-identical, for any --shards);
                   off = the frozen static tables; incompatible with
                   --xla-scorer
  --jwtd-bound MIN hard anti-starvation bound (minutes): cap every priority
                   class's rolling p99 queue wait; a class over its bound
                   gets a starvation-preemption pass and reserved capacity
                   (quota is never bypassed). Also drives the --adapt
                   fairness axis. 0 = off
  --moldable       moldable & malleable gangs: half the generated
                   multi-replica training gangs declare a shape ladder
                   (e.g. 512 pods @ 1.0x -> 256 @ 0.55x), RSCH picks the
                   largest rung the current fragmentation can hold before
                   each placement walk, and SLO-pressure / fault victims
                   with a spare rung shrink in place of eviction. Shape
                   choice and shrinks are seeded and digest-identical for
                   any --shards; off = no job carries shapes and legacy
                   runs replay byte-identically
  --digest FILE    write the deterministic run digest (JSON) to FILE — the
                   golden-gate CI job diffs two same-seed digests
  --obs-out FILE   digest-inert observability: stream structured decision
                   records (JSONL, one per scheduled/preempted/rejected/
                   molded job: chosen region, feature vector + active
                   weight overlay, shape rung, rejection reason) plus a
                   trailing scheduler-health rollup (per-phase wall-clock
                   p50/p95/p99, queue depth, plan-cache hit rate, shard
                   imbalance, scheduler-overhead-per-cycle). Enabling this
                   never changes a same-seed digest — the recorder is
                   write-only for the scheduling core
  --obs-verbosity  0 = phase profiles only, 1 = + scheduled/preempted/
                   molded decisions, 2 = + admission & placement
                   rejections (default 2; only read with --obs-out)

obs / explain / harness (the observability readers + results harness):
  obs summarize FILE    phase-timing table, overhead row and per-action
                        decision counts from an --obs-out stream
  explain --job ID FILE every decision record touching job ID, in order
  harness [--scale S]   run the whole experiment zoo (ablation-index,
                        elastic, fault-tolerance, topology-stress,
                        weight-adaptation, moldable-gangs, kant-lint) and
                        emit one timestamped kant-harness-v1 results JSON
                        (--out, default harness_results.json)
  harness validate FILE schema-check a results JSON the same way
                        bench-check validate gates the bench baseline

lint (the determinism & concurrency static-analysis pass):
  lint             scan the source tree for determinism-contract
                   violations: hash-container iteration in the
                   digest-affecting modules (cluster/ qsch/ rsch/ sim/
                   job/), wall-clock reads outside obs/ / util/benchkit.rs
                   / main.rs, ambient nondeterminism (thread identity,
                   unseeded RNG, env reads in the core), and stats
                   counters missing from both digest_json and the
                   DIGEST_INERT manifest. Exits non-zero on any finding.
                   A justified site carries a line comment
                   `kant-lint: allow(<rule>) — <reason>`
  --root DIR       source root to scan (default src/, falling back to the
                   crate's own src/ when run from elsewhere)
  --json           print the kant-lint-v1 JSON document instead of text
  --github         also print GitHub Actions ::error annotations
  --path-prefix P  file prefix for --github annotations (default rust/src/)
  --out FILE       also write the kant-lint-v1 JSON document to FILE

bench-check (the CI bench-regression gate):
  validate FILE    hard-check a benchkit-v1 document: schema tag, non-empty
                   results, non-empty unique scenario names, positive
                   mean_ns and iters
  diff BASE FRESH  validate both, then compare per scenario name: missing
                   or renamed scenarios fail, and a fresh mean_ns beyond
                   --tolerance (default 3.0) x baseline fails
";

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn simulate(args: &[String]) -> Result<()> {
    // Parse the raw flags, then hand everything to the `SimOptions`
    // builder — the CLI owns no scheduling defaults of its own.
    let cluster = flag_value(args, "--cluster").unwrap_or("train");
    let scale = Scale::parse(flag_value(args, "--scale").unwrap_or("small"))
        .context("bad --scale")?;
    let policy = QueuePolicy::parse(flag_value(args, "--policy").unwrap_or("backfill"))
        .context("bad --policy")?;
    let strategy = match flag_value(args, "--strategy") {
        Some(s) => Some(PlacementStrategy::parse(s).context("bad --strategy")?),
        None => None,
    };

    let opts = match cluster {
        "train" => SimOptions::for_scale(scale),
        other => SimOptions::for_inference(
            InferencePreset::parse(other)
                .with_context(|| format!("unknown cluster '{other}'"))?,
        ),
    }
    .seed(flag_value(args, "--seed").unwrap_or("42").parse()?)
    .policy(policy)
    .strategy(strategy)
    .flat(has_flag(args, "--flat"))
    .deep_snapshot(has_flag(args, "--deep-snapshot"))
    .no_index(has_flag(args, "--no-index"))
    .topo_blind(has_flag(args, "--topo-blind"))
    .elastic(has_flag(args, "--elastic"))
    .faults(if has_flag(args, "--faults") {
        FaultPreset::Storm
    } else {
        FaultPreset::None
    })
    .checkpoint_min(flag_value(args, "--checkpoint-min").unwrap_or("30").parse()?)
    .shards(flag_value(args, "--shards").unwrap_or("0").parse()?)
    .adapt(has_flag(args, "--adapt"))
    .moldable(has_flag(args, "--moldable"))
    .jwtd_bound_ms(
        flag_value(args, "--jwtd-bound")
            .unwrap_or("0")
            .parse::<u64>()?
            * 60_000,
    )
    .xla_scorer(has_flag(args, "--xla-scorer"));

    let SimSetup {
        mut env,
        qsch: qsch_cfg,
        rsch: rsch_cfg,
        sim: sim_cfg,
    } = opts.build()?;

    let mut jobs = match flag_value(args, "--trace") {
        Some(path) => trace::read_trace(&PathBuf::from(path))?,
        None => WorkloadGen::new(env.workload.clone()).generate_until(env.horizon_ms),
    };
    opts.apply_job_policies(&mut jobs);

    println!(
        "cluster={} gpus={} jobs={} policy={} two_level={} snapshot={:?} indexed={} \
         scorer={} shards={} adapt={} jwtd_bound_ms={}",
        env.label,
        env.state.total_gpus(),
        jobs.len(),
        qsch_cfg.policy.as_str(),
        rsch_cfg.two_level,
        rsch_cfg.snapshot_mode,
        rsch_cfg.indexed_candidates,
        if opts.wants_xla() { "xla" } else { "native" },
        qsch_cfg.batch_shards,
        rsch_cfg.adapt.enabled,
        qsch_cfg.max_jwtd_p99_ms[0],
    );

    let elastic = opts.is_elastic();
    let faults = opts.has_faults();
    let mut qsch = Qsch::new(qsch_cfg, env.ledger.clone());
    let mut rsch = build_rsch(&opts, rsch_cfg, &env.state)?;

    // Observability is strictly additive: the recorder never feeds a
    // scheduling branch, so --obs-out cannot move a same-seed digest.
    let obs_out = flag_value(args, "--obs-out");
    let obs_verbosity: u8 = flag_value(args, "--obs-verbosity").unwrap_or("2").parse()?;
    let mut obs = match obs_out {
        Some(path) => {
            let file = std::fs::File::create(path)
                .with_context(|| format!("creating obs stream {path}"))?;
            ObsRecorder::enabled(obs_verbosity)
                .with_sink(Box::new(std::io::BufWriter::new(file)))
        }
        None => ObsRecorder::disabled(),
    };
    let out = run_observed(
        &mut env.state,
        &mut qsch,
        &mut rsch,
        jobs,
        Vec::new(),
        &sim_cfg,
        &mut obs,
    );

    if let Some(path) = flag_value(args, "--digest") {
        let doc = out.digest_json().to_string_compact();
        std::fs::write(path, doc.clone() + "\n")
            .with_context(|| format!("writing digest to {path}"))?;
        println!("digest: {doc}");
    }

    if let Some(path) = obs_out {
        println!("{}", phase_table(&out.health, sim_cfg.cycle_ms));
        println!(
            "obs: {} decision record(s) + health trailer -> {path}",
            out.health.decisions
        );
    }

    println!("{}", headline(env.label.as_str(), &out.metrics));
    let arms = vec![("wait", jwtd_buckets(&out.store, out.end_ms).summaries())];
    println!(
        "{}",
        bucket_comparison("JWTD (mean wait by job size)", &arms, fmt_ms)
    );
    println!(
        "qsch: {:?}\nrsch: {:?}\nsnapshot: {:?}",
        out.qsch_stats, out.rsch_stats, out.snapshot_stats
    );
    println!(
        "sim: end={} events={} unfinished={} | GAR {} SOR {} GFR {}",
        fmt_ms(out.end_ms as f64),
        out.events_processed,
        out.unfinished_jobs,
        pct(out.metrics.gar_avg()),
        pct(out.metrics.sor_final()),
        pct(out.metrics.gfr_avg()),
    );
    if elastic {
        let (a, b) = out.metrics.window();
        println!(
            "elastic: services={} slo-violation={} churn={} elastic-util={} slo-preempt={}",
            out.metrics.elastic.services,
            pct(out.metrics.elastic.slo_violation_rate()),
            out.metrics.elastic.replica_churn(),
            pct(out.metrics.elastic.elastic_utilization(a, b)),
            out.qsch_stats.slo_pressure_preemptions,
        );
    }
    if faults {
        let r = &out.metrics.reliability;
        println!(
            "reliability: faults={} (node {} / gpu {} / hbd {} / drain {}) repairs={} \
             evictions={} lost={:.1} GPU-h goodput={:.0} GPU-h eff-GAR={} \
             goodput-frac={} inflation-p99={:.2} migrations={}",
            r.faults_injected(),
            r.node_faults,
            r.gpu_faults,
            r.hbd_faults,
            r.drains,
            r.repairs,
            r.fault_evictions,
            r.lost_gpu_hours(),
            r.goodput_gpu_hours(),
            pct(out.metrics.effective_gar()),
            pct(out.metrics.goodput_fraction()),
            r.inflation_summary().p99,
            out.migrations,
        );
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn build_rsch(
    opts: &SimOptions,
    cfg: RschConfig,
    state: &kant::cluster::state::ClusterState,
) -> Result<Rsch> {
    if opts.wants_xla() {
        let mut backend = kant::runtime::XlaBackend::new("artifacts")
            .context("loading XLA scorer artifacts (run `make artifacts`)")?;
        backend.warmup().context("compiling artifacts")?;
        Ok(Rsch::with_backend(cfg, state, Box::new(backend)))
    } else {
        Ok(Rsch::new(cfg, state))
    }
}

#[cfg(not(feature = "xla"))]
fn build_rsch(
    opts: &SimOptions,
    cfg: RschConfig,
    state: &kant::cluster::state::ClusterState,
) -> Result<Rsch> {
    if opts.wants_xla() {
        bail!("this build has no XLA runtime; rebuild with `--features xla`");
    }
    Ok(Rsch::new(cfg, state))
}

/// `kant bench-check` — the CI bench-regression gate, also runnable
/// locally. `validate` hard-checks one benchkit-v1 document; `diff`
/// compares a fresh `BENCH_SCALE=small` run against the committed
/// baseline per scenario name. The wide default tolerance (3×) absorbs
/// shared-runner noise: the gate exists to catch order-of-magnitude
/// regressions and lost scenarios, not jitter.
fn bench_check(args: &[String]) -> Result<()> {
    const USAGE: &str = "usage: kant bench-check validate FILE | \
                         diff BASELINE FRESH [--tolerance X]";
    match args.first().map(String::as_str) {
        Some("validate") => {
            let path = args.get(1).context(USAGE)?;
            let scenarios = load_bench_doc(path)?;
            println!("bench-check: {path} OK ({} scenarios)", scenarios.len());
            Ok(())
        }
        Some("diff") => {
            let base_path = args.get(1).context(USAGE)?;
            let fresh_path = args.get(2).context(USAGE)?;
            let tolerance: f64 = flag_value(args, "--tolerance").unwrap_or("3.0").parse()?;
            anyhow::ensure!(tolerance >= 1.0, "--tolerance must be >= 1.0");
            let base = load_bench_doc(base_path)?;
            let fresh = load_bench_doc(fresh_path)?;
            let mut failures: Vec<String> = Vec::new();
            for (name, base_mean) in &base {
                match fresh.iter().find(|(n, _)| n == name) {
                    None => failures.push(format!(
                        "scenario '{name}' missing from {fresh_path} — renamed or \
                         dropped? regenerate the committed baseline"
                    )),
                    Some((_, fresh_mean)) => {
                        let ratio = fresh_mean / base_mean;
                        println!(
                            "bench-check: {name}: {base_mean:.0} ns -> \
                             {fresh_mean:.0} ns ({ratio:.2}x)"
                        );
                        if ratio > tolerance {
                            failures.push(format!(
                                "scenario '{name}' regressed {ratio:.2}x \
                                 (limit {tolerance:.1}x): {base_mean:.0} ns -> \
                                 {fresh_mean:.0} ns"
                            ));
                        }
                    }
                }
            }
            for (name, _) in &fresh {
                if !base.iter().any(|(n, _)| n == name) {
                    failures.push(format!(
                        "scenario '{name}' is not in {base_path} — new scenarios \
                         must land with a regenerated baseline"
                    ));
                }
            }
            if !failures.is_empty() {
                bail!("bench-check failed:\n  {}", failures.join("\n  "));
            }
            println!(
                "bench-check: {} scenarios within {tolerance:.1}x of baseline",
                base.len()
            );
            Ok(())
        }
        _ => bail!(USAGE),
    }
}

/// Parse and validate one benchkit-v1 document, returning each
/// scenario's `(name, mean_ns)`.
fn load_bench_doc(path: &str) -> Result<Vec<(String, f64)>> {
    use kant::util::json::Json;
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let doc = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    match doc.get("schema").and_then(Json::as_str) {
        Some("benchkit-v1") => {}
        other => bail!("{path}: schema must be \"benchkit-v1\", found {other:?}"),
    }
    let results = doc
        .get("results")
        .and_then(Json::as_arr)
        .with_context(|| format!("{path}: missing `results` array"))?;
    if results.is_empty() {
        bail!("{path}: empty `results` — the bench produced no scenarios");
    }
    let mut out: Vec<(String, f64)> = Vec::with_capacity(results.len());
    for (i, e) in results.iter().enumerate() {
        let name = e.get("name").and_then(Json::as_str).unwrap_or("");
        if name.is_empty() {
            bail!("{path}: results[{i}] has an empty or missing `name`");
        }
        if out.iter().any(|(n, _)| n == name) {
            bail!("{path}: duplicate scenario name '{name}'");
        }
        let mean = e.get("mean_ns").and_then(Json::as_f64).unwrap_or(-1.0);
        if !mean.is_finite() || mean <= 0.0 {
            bail!("{path}: scenario '{name}' needs a positive finite mean_ns");
        }
        let iters = e.get("iters").and_then(Json::as_f64).unwrap_or(0.0);
        if !iters.is_finite() || iters < 1.0 {
            bail!("{path}: scenario '{name}' needs positive iters");
        }
        out.push((name.to_string(), mean));
    }
    Ok(out)
}

/// Parse an `--obs-out` JSONL stream back into decision records plus
/// the trailing scheduler-health rollup (absent if the run died before
/// the trailer, or at `--obs-verbosity 0` with no decisions there may
/// be only the health line).
fn read_obs_stream(path: &str) -> Result<(Vec<DecisionRecord>, Option<SchedulerHealth>)> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let mut decisions = Vec::new();
    let mut health = None;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| anyhow::anyhow!("{path}:{}: {e}", i + 1))?;
        if let Some(rec) = DecisionRecord::from_json(&j) {
            decisions.push(rec);
        } else if let Some(h) = SchedulerHealth::from_json(&j) {
            health = Some(h);
        } else {
            bail!("{path}:{}: neither a decision record nor a health rollup", i + 1);
        }
    }
    Ok((decisions, health))
}

/// `kant obs summarize` — offline reader for an `--obs-out` stream:
/// phase-timing table + overhead row from the health trailer, then
/// per-action decision counts.
fn obs_cmd(args: &[String]) -> Result<()> {
    const USAGE: &str = "usage: kant obs summarize FILE [--cycle-ms N]";
    match args.first().map(String::as_str) {
        Some("summarize") => {
            let path = args.get(1).context(USAGE)?;
            // The stream does not carry the cycle period; default to the
            // simulator's 5 s cycle for the overhead-fraction row.
            let cycle_ms: u64 = flag_value(args, "--cycle-ms").unwrap_or("5000").parse()?;
            let (decisions, health) = read_obs_stream(path)?;
            match &health {
                Some(h) => println!("{}", phase_table(h, cycle_ms)),
                None => println!("{path}: no health trailer (run still in flight?)"),
            }
            let mut counts: std::collections::BTreeMap<&str, u64> =
                std::collections::BTreeMap::new();
            for d in &decisions {
                *counts.entry(d.action.as_str()).or_default() += 1;
            }
            println!("{} decision record(s):", decisions.len());
            for (action, n) in counts {
                println!("  {action:<20} {n}");
            }
            Ok(())
        }
        _ => bail!(USAGE),
    }
}

/// `kant explain --job ID FILE` — replay every decision record touching
/// one job, in stream order, with the evidence behind each decision
/// (features, weight overlay, region, rejection reason).
fn explain(args: &[String]) -> Result<()> {
    const USAGE: &str = "usage: kant explain --job ID FILE";
    let job_pos = args.iter().position(|a| a == "--job").context(USAGE)?;
    let id: u64 = args.get(job_pos + 1).context(USAGE)?.parse()?;
    let path = args
        .iter()
        .enumerate()
        .find(|&(i, a)| i != job_pos && i != job_pos + 1 && !a.starts_with("--"))
        .map(|(_, a)| a.as_str())
        .context(USAGE)?;
    let (decisions, _) = read_obs_stream(path)?;
    let hits: Vec<&DecisionRecord> = decisions.iter().filter(|d| d.job == id).collect();
    if hits.is_empty() {
        println!(
            "no decision records for job {id} in {path} \
             (job never reached a decision, or raise --obs-verbosity)"
        );
        return Ok(());
    }
    println!("job {id}: {} decision record(s)", hits.len());
    for d in hits {
        let mut line = format!("  t={:<8} {:<18}", fmt_ms(d.t_ms as f64), d.action);
        if !d.reason.is_empty() {
            line.push_str(&format!(" reason={}", d.reason));
        }
        if !d.region.is_empty() {
            line.push_str(&format!(" region={} nodes={}", d.region, d.nodes));
        }
        if d.shape_rung >= 0 {
            line.push_str(&format!(" rung={}", d.shape_rung));
        }
        line.push_str(&format!(
            " overlay=({:+.3},{:+.3})",
            d.overlay_pack_bias, d.overlay_fairness
        ));
        println!("{line}");
        println!("    features: {:?}", d.features);
    }
    Ok(())
}

/// The seven experiments `kant harness` must cover, in run order. The
/// validator requires each exactly once — dropping one from the harness
/// fails CI the same way a lost bench scenario does. `kant-lint` rides
/// along so one artifact carries both the perf claims and the
/// static-analysis status they depend on.
const HARNESS_EXPERIMENTS: [&str; 7] = [
    "ablation-index",
    "elastic",
    "fault-tolerance",
    "topology-stress",
    "weight-adaptation",
    "moldable-gangs",
    "kant-lint",
];

/// `kant harness` — run the whole experiment zoo into one timestamped
/// results JSON; `harness validate FILE` is the CI gate (mirrors
/// `bench-check validate`). Every arm payload is the run's digest
/// object, so two same-seed harness runs differ only in timestamps.
// Wall-clock reads here time the experiment sections of the results
// document — sanctioned: nothing feeds back into scheduling.
#[allow(clippy::disallowed_methods, clippy::disallowed_types)]
fn harness(args: &[String]) -> Result<()> {
    use kant::experiments as exp;
    const USAGE: &str = "usage: kant harness [--scale small|paper|xlarge] [--seed N] \
                         [--out FILE] | kant harness validate FILE";
    if args.first().map(String::as_str) == Some("validate") {
        let path = args.get(1).context(USAGE)?;
        let names = load_harness_doc(path)?;
        println!("harness: {path} OK ({} experiments)", names.len());
        return Ok(());
    }
    let scale_label = flag_value(args, "--scale").unwrap_or("small");
    let scale = Scale::parse(scale_label).context("bad --scale")?;
    let seed: u64 = flag_value(args, "--seed").unwrap_or("42").parse()?;
    let out_path = flag_value(args, "--out").unwrap_or("harness_results.json");
    // Simulated-time budget for the duration-driven experiments: half a
    // day keeps the small preset CI-friendly; larger scales earn the
    // paper's two-day window.
    let days = if scale == Scale::Small { 0.5 } else { 2.0 };
    let generated_unix_ms = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0);

    fn digest_arms(pairs: &[(&str, &kant::sim::SimOutcome)]) -> Json {
        let mut arms = Json::obj();
        for (label, out) in pairs {
            arms.set(label, out.digest_json());
        }
        arms
    }
    fn push_exp(experiments: &mut Vec<Json>, name: &str, t0: std::time::Instant, arms: Json) {
        let elapsed = t0.elapsed();
        let mut e = Json::obj();
        e.set("name", name)
            .set("elapsed_ms", elapsed.as_millis() as u64)
            .set("arms", arms);
        experiments.push(e);
        println!("harness: {name} done in {:.1}s", elapsed.as_secs_f64());
    }

    let mut experiments: Vec<Json> = Vec::new();

    // ablation-index: arms carry RSCH scan counters, not digests — the
    // experiment's claim is about work done, and the struct separately
    // asserts the placements were byte-identical across arms.
    let t0 = std::time::Instant::now();
    let r = exp::run_ablation_index(scale, seed);
    let mut arms = Json::obj();
    for (i, (label, s)) in r.arms.iter().enumerate() {
        let mut a = Json::obj();
        a.set("nodes_examined", s.nodes_examined)
            .set("nodes_scored", s.nodes_scored)
            .set("pods_placed", s.pods_placed)
            .set("examined_per_pod", r.examined_per_pod(i));
        arms.set(label, a);
    }
    let elapsed = t0.elapsed();
    let mut e = Json::obj();
    e.set("name", "ablation-index")
        .set("elapsed_ms", elapsed.as_millis() as u64)
        .set("arms", arms)
        .set("placements_identical", r.placements_identical);
    experiments.push(e);
    println!("harness: ablation-index done in {:.1}s", elapsed.as_secs_f64());

    let t0 = std::time::Instant::now();
    let r = exp::run_elastic_inference(seed, days);
    push_exp(
        &mut experiments,
        "elastic",
        t0,
        digest_arms(&[
            ("static", &r.static_arm),
            ("elastic", &r.elastic),
            ("tidal", &r.tidal),
        ]),
    );

    let t0 = std::time::Instant::now();
    let r = exp::run_fault_tolerance(seed, days);
    let mut pairs: Vec<(String, &kant::sim::SimOutcome)> = vec![
        ("no-faults".to_string(), &r.no_faults),
        ("naive".to_string(), &r.naive),
        ("hardened".to_string(), &r.hardened),
    ];
    for (interval_ms, out) in &r.checkpointed {
        pairs.push((format!("checkpointed-{}m", interval_ms / 60_000), out));
    }
    let mut arms = Json::obj();
    for (label, out) in &pairs {
        arms.set(label, out.digest_json());
    }
    push_exp(&mut experiments, "fault-tolerance", t0, arms);

    let t0 = std::time::Instant::now();
    let r = exp::run_topology_stress(scale, seed);
    push_exp(
        &mut experiments,
        "topology-stress",
        t0,
        digest_arms(&[("blind", &r.blind), ("truthful", &r.truthful)]),
    );

    let t0 = std::time::Instant::now();
    let r = exp::run_weight_adaptation(scale, seed, 6 * 3_600_000);
    let arms = digest_arms(&[
        ("static", &r.static_arm),
        ("adaptive", &r.adaptive),
        ("adaptive-bound", &r.adaptive_bound),
    ]);
    // bound_ms is metadata, not an arm: hang it off the experiment.
    let mut e = Json::obj();
    let elapsed = t0.elapsed();
    e.set("name", "weight-adaptation")
        .set("elapsed_ms", elapsed.as_millis() as u64)
        .set("arms", arms)
        .set("bound_ms", r.bound_ms);
    experiments.push(e);
    println!(
        "harness: weight-adaptation done in {:.1}s",
        elapsed.as_secs_f64()
    );

    let t0 = std::time::Instant::now();
    let r = exp::run_moldable_gangs(seed, days);
    push_exp(
        &mut experiments,
        "moldable-gangs",
        t0,
        digest_arms(&[
            ("fixed", &r.fixed),
            ("moldable", &r.moldable),
            ("malleable", &r.malleable),
        ]),
    );

    // The kant-lint status rides in the same results document as the
    // perf claims that depend on it.
    let t0 = std::time::Instant::now();
    let report = kant::lint::lint_tree(&lint_root(None))?;
    let mut arm = Json::obj();
    arm.set("files_scanned", report.files_scanned as u64)
        .set("findings", report.findings.len() as u64)
        .set("allows_used", report.allows_used as u64)
        .set("digest_fields_checked", report.digest_fields_checked as u64)
        .set("clean", report.is_clean());
    let mut arms = Json::obj();
    arms.set("src", arm);
    push_exp(&mut experiments, "kant-lint", t0, arms);
    if !report.is_clean() {
        eprint!("{}", report.render_text());
        bail!("kant harness: the kant-lint section found violations");
    }

    let mut doc = Json::obj();
    doc.set("schema", "kant-harness-v1")
        .set("generated_unix_ms", generated_unix_ms)
        .set("scale", scale_label)
        .set("seed", seed)
        .set("days", days)
        .set("experiments", Json::Arr(experiments));
    std::fs::write(out_path, doc.to_string_compact() + "\n")
        .with_context(|| format!("writing {out_path}"))?;
    // Self-check the document we just wrote through the same validator
    // CI runs, so a schema drift fails at generation time too.
    let names = load_harness_doc(out_path)?;
    println!("harness: wrote {out_path} ({} experiments)", names.len());
    Ok(())
}

/// Parse and validate one kant-harness-v1 document, returning the
/// experiment names. Hard-fails on: wrong schema tag, missing/zero
/// timestamp, an experiment missing from [`HARNESS_EXPERIMENTS`],
/// duplicates, negative elapsed time, or empty/non-object arms.
fn load_harness_doc(path: &str) -> Result<Vec<String>> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let doc = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    match doc.get("schema").and_then(Json::as_str) {
        Some("kant-harness-v1") => {}
        other => bail!("{path}: schema must be \"kant-harness-v1\", found {other:?}"),
    }
    if doc.get("generated_unix_ms").and_then(Json::as_u64).unwrap_or(0) == 0 {
        bail!("{path}: missing or zero `generated_unix_ms`");
    }
    let exps = doc
        .get("experiments")
        .and_then(Json::as_arr)
        .with_context(|| format!("{path}: missing `experiments` array"))?;
    let mut names: Vec<String> = Vec::with_capacity(exps.len());
    for (i, e) in exps.iter().enumerate() {
        let name = e.get("name").and_then(Json::as_str).unwrap_or("");
        if name.is_empty() {
            bail!("{path}: experiments[{i}] has an empty or missing `name`");
        }
        if names.iter().any(|n| n == name) {
            bail!("{path}: duplicate experiment '{name}'");
        }
        let elapsed = e.get("elapsed_ms").and_then(Json::as_f64).unwrap_or(-1.0);
        if !elapsed.is_finite() || elapsed < 0.0 {
            bail!("{path}: experiment '{name}' needs a finite non-negative elapsed_ms");
        }
        match e.get("arms") {
            Some(Json::Obj(map)) if !map.is_empty() => {
                for (arm, v) in map {
                    if !matches!(v, Json::Obj(m) if !m.is_empty()) {
                        bail!(
                            "{path}: arm '{arm}' of '{name}' must be a non-empty object"
                        );
                    }
                }
            }
            _ => bail!("{path}: experiment '{name}' needs a non-empty `arms` object"),
        }
        names.push(name.to_string());
    }
    for required in HARNESS_EXPERIMENTS {
        if !names.iter().any(|n| n == required) {
            bail!("{path}: missing required experiment '{required}'");
        }
    }
    Ok(names)
}

/// Source root for `kant lint`: an explicit `--root`, else `src/` in
/// the working directory (the CI jobs run from `rust/`), else this
/// crate's own `src/` so the harness works from any directory.
fn lint_root(explicit: Option<&str>) -> PathBuf {
    if let Some(dir) = explicit {
        return PathBuf::from(dir);
    }
    let local = PathBuf::from("src");
    if local.is_dir() {
        local
    } else {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/src"))
    }
}

/// `kant lint` — run the determinism & concurrency static analysis
/// over the source tree. Exits non-zero on any finding, so both CI and
/// a plain local run gate the same way.
fn lint_cmd(args: &[String]) -> Result<()> {
    let root = lint_root(flag_value(args, "--root"));
    let report = kant::lint::lint_tree(&root)
        .with_context(|| format!("scanning {}", root.display()))?;
    if has_flag(args, "--github") {
        print!(
            "{}",
            report.github_annotations(flag_value(args, "--path-prefix").unwrap_or("rust/src/"))
        );
    }
    let doc = report.to_json().to_string_compact();
    if let Some(path) = flag_value(args, "--out") {
        std::fs::write(path, doc.clone() + "\n")
            .with_context(|| format!("writing lint report to {path}"))?;
    }
    if has_flag(args, "--json") {
        println!("{doc}");
    } else {
        print!("{}", report.render_text());
    }
    if report.is_clean() {
        Ok(())
    } else {
        bail!("kant lint: {} finding(s)", report.findings.len())
    }
}

fn gen_trace(args: &[String]) -> Result<()> {
    let seed: u64 = flag_value(args, "--seed").unwrap_or("42").parse()?;
    let n: usize = flag_value(args, "--jobs").unwrap_or("1000").parse()?;
    let out = flag_value(args, "--out").context("--out FILE required")?;
    let cfg = match flag_value(args, "--mix").unwrap_or("training") {
        "training" => WorkloadConfig::paper_training(seed),
        "inference" => WorkloadConfig::paper_inference(seed),
        other => bail!("unknown mix '{other}'"),
    };
    let jobs = WorkloadGen::new(cfg).generate(n);
    trace::write_trace(&PathBuf::from(out), &jobs)?;
    println!("wrote {n} jobs to {out}");
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn validate(_args: &[String]) -> Result<()> {
    bail!("`kant validate` needs the XLA runtime; rebuild with `--features xla`")
}

#[cfg(feature = "xla")]
fn validate(args: &[String]) -> Result<()> {
    let dir = flag_value(args, "--artifacts").unwrap_or("artifacts");
    let mut backend = kant::runtime::XlaBackend::new(dir)
        .context("loading artifacts (run `make artifacts` first)")?;
    backend.warmup().context("compiling artifacts")?;
    // Score a toy candidate set and check the math against the native
    // backend (the same parity the integration tests enforce).
    use kant::rsch::features::NODE_F;
    use kant::rsch::score::{NativeBackend, ScoreBackend};
    let n = 64;
    let mut feat = vec![0.0f32; n * NODE_F];
    for i in 0..n {
        let row = &mut feat[i * NODE_F..(i + 1) * NODE_F];
        row[0] = (i % 9) as f32; // free
        row[1] = 8.0;
        row[2] = 8.0 - row[0];
        row[3] = 1.0;
        row[4] = 200.0;
        row[5] = 256.0;
        row[8] = 3.0;
        row[11] = row[0];
    }
    let job = [2.0, 16.0, 1.0, 0.0, 0.0, 2.0, 0.0, 0.0];
    let w = [1.0, 0.0, 0.6, 0.0, 0.5, 0.8, -0.3, 0.2];
    let xla = backend.score_nodes(&feat, n, &job, &w);
    let native = NativeBackend.score_nodes(&feat, n, &job, &w);
    let max_diff = xla
        .iter()
        .zip(&native)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "validate: scored {n} nodes via XLA ({} launches); max |xla - native| = {max_diff:.2e}",
        backend.launches
    );
    anyhow::ensure!(max_diff < 1e-3, "XLA/native scorer divergence");
    println!("validate OK — artifacts healthy, parity holds");
    Ok(())
}
