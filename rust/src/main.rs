//! `kant` — the leader entrypoint / CLI.
//!
//! Subcommands:
//!   simulate     run a workload (generated or trace) on a cluster preset
//!   gen-trace    generate and save a workload trace (JSONL)
//!   validate     smoke-check the AOT artifacts through the PJRT runtime
//!   bench-check  validate / diff benchkit baseline documents (the CI
//!                bench-regression gate, also runnable locally)
//!
//! The figures harness lives in the separate `figures` binary.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use kant::config::{FaultPreset, InferencePreset, Scale, SimOptions, SimSetup};
use kant::experiments::jwtd_buckets;
use kant::job::spec::PlacementStrategy;
use kant::job::trace;
use kant::job::workload::{WorkloadConfig, WorkloadGen};
use kant::metrics::report::{bucket_comparison, fmt_ms, headline, pct};
use kant::qsch::policy::QueuePolicy;
use kant::qsch::Qsch;
use kant::rsch::{Rsch, RschConfig};
use kant::sim::run;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("simulate") => simulate(&args[1..]),
        Some("gen-trace") => gen_trace(&args[1..]),
        Some("validate") => validate(&args[1..]),
        Some("bench-check") => bench_check(&args[1..]),
        Some("-h" | "--help") | None => {
            println!("{HELP}");
            Ok(())
        }
        Some(other) => bail!("unknown subcommand '{other}'\n{HELP}"),
    }
}

const HELP: &str = "\
kant — unified scheduling system for large-scale AI clusters (paper reproduction)

usage:
  kant simulate [--cluster train|i2|i7|a10] [--scale small|paper|xlarge|xxlarge]
                [--seed N] [--policy strict-fifo|best-effort|backfill]
                [--strategy native|binpack|e-binpack|spread|e-spread]
                [--trace FILE] [--xla-scorer] [--flat] [--deep-snapshot]
                [--no-index] [--topo-blind] [--elastic] [--faults]
                [--checkpoint-min N] [--shards N] [--adapt]
                [--jwtd-bound MIN] [--moldable] [--digest FILE]
  kant gen-trace [--seed N] [--jobs N] [--mix training|inference] --out FILE
  kant validate [--artifacts DIR]
  kant bench-check validate FILE
  kant bench-check diff BASELINE FRESH [--tolerance X]

Every flag is a thin adapter onto the typed `SimOptions` builder
(kant::config::SimOptions) — the single constructor of the scheduler and
simulator configs, so defaults cannot drift between entry points.

flags:
  --scale          cluster preset size; `xxlarge` (alias `100k`) is the
                   100,000-GPU / 12,500-node frontier cluster spanning 10
                   superspines (one scheduler shard each)
  --flat           disable two-level (NodeNetGroup preselect) scheduling
  --deep-snapshot  rebuild the full snapshot every cycle (no §3.4.3 delta)
  --no-index       linear candidate scans instead of the free-capacity index
  --topo-blind     pre-fix topology ablation: the scorer cannot distinguish
                   cross-superspine from same-superspine placement (digests
                   for topology-agnostic strategies are invariant to this)
  --elastic        elastic inference: most services become diurnal replica
                   sets and the autoscaling controller runs every 5 min
  --faults         stochastic fault injection: seeded MTBF/MTTR storms per
                   GPU / node / HBD plus maintenance drains; training jobs
                   checkpoint every 30 min (see --checkpoint-min) and
                   drain-aware defrag runs every 30 min
  --checkpoint-min N  checkpoint interval for training jobs under --faults
                   (minutes; 0 = naive restart-from-scratch)
  --shards N       superspine-sharded placement prefetch on N worker
                   threads (0 = legacy sequential core). The shard
                   structure is fixed by the topology, so every N >= 1 is
                   digest-identical; incompatible with --xla-scorer
  --adapt          seeded adaptive weight controller: once per cycle, shift
                   the native scorer's packing/spreading/fairness mix from
                   rolling GAR/GFR/JWTD windows (hysteresis + step clamps
                   keep same-seed digests byte-identical, for any --shards);
                   off = the frozen static tables; incompatible with
                   --xla-scorer
  --jwtd-bound MIN hard anti-starvation bound (minutes): cap every priority
                   class's rolling p99 queue wait; a class over its bound
                   gets a starvation-preemption pass and reserved capacity
                   (quota is never bypassed). Also drives the --adapt
                   fairness axis. 0 = off
  --moldable       moldable & malleable gangs: half the generated
                   multi-replica training gangs declare a shape ladder
                   (e.g. 512 pods @ 1.0x -> 256 @ 0.55x), RSCH picks the
                   largest rung the current fragmentation can hold before
                   each placement walk, and SLO-pressure / fault victims
                   with a spare rung shrink in place of eviction. Shape
                   choice and shrinks are seeded and digest-identical for
                   any --shards; off = no job carries shapes and legacy
                   runs replay byte-identically
  --digest FILE    write the deterministic run digest (JSON) to FILE — the
                   golden-gate CI job diffs two same-seed digests

bench-check (the CI bench-regression gate):
  validate FILE    hard-check a benchkit-v1 document: schema tag, non-empty
                   results, non-empty unique scenario names, positive
                   mean_ns and iters
  diff BASE FRESH  validate both, then compare per scenario name: missing
                   or renamed scenarios fail, and a fresh mean_ns beyond
                   --tolerance (default 3.0) x baseline fails
";

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn simulate(args: &[String]) -> Result<()> {
    // Parse the raw flags, then hand everything to the `SimOptions`
    // builder — the CLI owns no scheduling defaults of its own.
    let cluster = flag_value(args, "--cluster").unwrap_or("train");
    let scale = Scale::parse(flag_value(args, "--scale").unwrap_or("small"))
        .context("bad --scale")?;
    let policy = QueuePolicy::parse(flag_value(args, "--policy").unwrap_or("backfill"))
        .context("bad --policy")?;
    let strategy = match flag_value(args, "--strategy") {
        Some(s) => Some(PlacementStrategy::parse(s).context("bad --strategy")?),
        None => None,
    };

    let opts = match cluster {
        "train" => SimOptions::for_scale(scale),
        other => SimOptions::for_inference(
            InferencePreset::parse(other)
                .with_context(|| format!("unknown cluster '{other}'"))?,
        ),
    }
    .seed(flag_value(args, "--seed").unwrap_or("42").parse()?)
    .policy(policy)
    .strategy(strategy)
    .flat(has_flag(args, "--flat"))
    .deep_snapshot(has_flag(args, "--deep-snapshot"))
    .no_index(has_flag(args, "--no-index"))
    .topo_blind(has_flag(args, "--topo-blind"))
    .elastic(has_flag(args, "--elastic"))
    .faults(if has_flag(args, "--faults") {
        FaultPreset::Storm
    } else {
        FaultPreset::None
    })
    .checkpoint_min(flag_value(args, "--checkpoint-min").unwrap_or("30").parse()?)
    .shards(flag_value(args, "--shards").unwrap_or("0").parse()?)
    .adapt(has_flag(args, "--adapt"))
    .moldable(has_flag(args, "--moldable"))
    .jwtd_bound_ms(
        flag_value(args, "--jwtd-bound")
            .unwrap_or("0")
            .parse::<u64>()?
            * 60_000,
    )
    .xla_scorer(has_flag(args, "--xla-scorer"));

    let SimSetup {
        mut env,
        qsch: qsch_cfg,
        rsch: rsch_cfg,
        sim: sim_cfg,
    } = opts.build()?;

    let mut jobs = match flag_value(args, "--trace") {
        Some(path) => trace::read_trace(&PathBuf::from(path))?,
        None => WorkloadGen::new(env.workload.clone()).generate_until(env.horizon_ms),
    };
    opts.apply_job_policies(&mut jobs);

    println!(
        "cluster={} gpus={} jobs={} policy={} two_level={} snapshot={:?} indexed={} \
         scorer={} shards={} adapt={} jwtd_bound_ms={}",
        env.label,
        env.state.total_gpus(),
        jobs.len(),
        qsch_cfg.policy.as_str(),
        rsch_cfg.two_level,
        rsch_cfg.snapshot_mode,
        rsch_cfg.indexed_candidates,
        if opts.wants_xla() { "xla" } else { "native" },
        qsch_cfg.batch_shards,
        rsch_cfg.adapt.enabled,
        qsch_cfg.max_jwtd_p99_ms[0],
    );

    let elastic = opts.is_elastic();
    let faults = opts.has_faults();
    let mut qsch = Qsch::new(qsch_cfg, env.ledger.clone());
    let mut rsch = build_rsch(&opts, rsch_cfg, &env.state)?;
    let out = run(&mut env.state, &mut qsch, &mut rsch, jobs, &sim_cfg);

    if let Some(path) = flag_value(args, "--digest") {
        let doc = out.digest_json().to_string_compact();
        std::fs::write(path, doc.clone() + "\n")
            .with_context(|| format!("writing digest to {path}"))?;
        println!("digest: {doc}");
    }

    println!("{}", headline(env.label.as_str(), &out.metrics));
    let arms = vec![("wait", jwtd_buckets(&out.store, out.end_ms).summaries())];
    println!(
        "{}",
        bucket_comparison("JWTD (mean wait by job size)", &arms, fmt_ms)
    );
    println!(
        "qsch: {:?}\nrsch: {:?}\nsnapshot: {:?}",
        out.qsch_stats, out.rsch_stats, out.snapshot_stats
    );
    println!(
        "sim: end={} events={} unfinished={} | GAR {} SOR {} GFR {}",
        fmt_ms(out.end_ms as f64),
        out.events_processed,
        out.unfinished_jobs,
        pct(out.metrics.gar_avg()),
        pct(out.metrics.sor_final()),
        pct(out.metrics.gfr_avg()),
    );
    if elastic {
        let (a, b) = out.metrics.window();
        println!(
            "elastic: services={} slo-violation={} churn={} elastic-util={} slo-preempt={}",
            out.metrics.elastic.services,
            pct(out.metrics.elastic.slo_violation_rate()),
            out.metrics.elastic.replica_churn(),
            pct(out.metrics.elastic.elastic_utilization(a, b)),
            out.qsch_stats.slo_pressure_preemptions,
        );
    }
    if faults {
        let r = &out.metrics.reliability;
        println!(
            "reliability: faults={} (node {} / gpu {} / hbd {} / drain {}) repairs={} \
             evictions={} lost={:.1} GPU-h goodput={:.0} GPU-h eff-GAR={} \
             goodput-frac={} inflation-p99={:.2} migrations={}",
            r.faults_injected(),
            r.node_faults,
            r.gpu_faults,
            r.hbd_faults,
            r.drains,
            r.repairs,
            r.fault_evictions,
            r.lost_gpu_hours(),
            r.goodput_gpu_hours(),
            pct(out.metrics.effective_gar()),
            pct(out.metrics.goodput_fraction()),
            r.inflation_summary().p99,
            out.migrations,
        );
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn build_rsch(
    opts: &SimOptions,
    cfg: RschConfig,
    state: &kant::cluster::state::ClusterState,
) -> Result<Rsch> {
    if opts.wants_xla() {
        let mut backend = kant::runtime::XlaBackend::new("artifacts")
            .context("loading XLA scorer artifacts (run `make artifacts`)")?;
        backend.warmup().context("compiling artifacts")?;
        Ok(Rsch::with_backend(cfg, state, Box::new(backend)))
    } else {
        Ok(Rsch::new(cfg, state))
    }
}

#[cfg(not(feature = "xla"))]
fn build_rsch(
    opts: &SimOptions,
    cfg: RschConfig,
    state: &kant::cluster::state::ClusterState,
) -> Result<Rsch> {
    if opts.wants_xla() {
        bail!("this build has no XLA runtime; rebuild with `--features xla`");
    }
    Ok(Rsch::new(cfg, state))
}

/// `kant bench-check` — the CI bench-regression gate, also runnable
/// locally. `validate` hard-checks one benchkit-v1 document; `diff`
/// compares a fresh `BENCH_SCALE=small` run against the committed
/// baseline per scenario name. The wide default tolerance (3×) absorbs
/// shared-runner noise: the gate exists to catch order-of-magnitude
/// regressions and lost scenarios, not jitter.
fn bench_check(args: &[String]) -> Result<()> {
    const USAGE: &str = "usage: kant bench-check validate FILE | \
                         diff BASELINE FRESH [--tolerance X]";
    match args.first().map(String::as_str) {
        Some("validate") => {
            let path = args.get(1).context(USAGE)?;
            let scenarios = load_bench_doc(path)?;
            println!("bench-check: {path} OK ({} scenarios)", scenarios.len());
            Ok(())
        }
        Some("diff") => {
            let base_path = args.get(1).context(USAGE)?;
            let fresh_path = args.get(2).context(USAGE)?;
            let tolerance: f64 = flag_value(args, "--tolerance").unwrap_or("3.0").parse()?;
            anyhow::ensure!(tolerance >= 1.0, "--tolerance must be >= 1.0");
            let base = load_bench_doc(base_path)?;
            let fresh = load_bench_doc(fresh_path)?;
            let mut failures: Vec<String> = Vec::new();
            for (name, base_mean) in &base {
                match fresh.iter().find(|(n, _)| n == name) {
                    None => failures.push(format!(
                        "scenario '{name}' missing from {fresh_path} — renamed or \
                         dropped? regenerate the committed baseline"
                    )),
                    Some((_, fresh_mean)) => {
                        let ratio = fresh_mean / base_mean;
                        println!(
                            "bench-check: {name}: {base_mean:.0} ns -> \
                             {fresh_mean:.0} ns ({ratio:.2}x)"
                        );
                        if ratio > tolerance {
                            failures.push(format!(
                                "scenario '{name}' regressed {ratio:.2}x \
                                 (limit {tolerance:.1}x): {base_mean:.0} ns -> \
                                 {fresh_mean:.0} ns"
                            ));
                        }
                    }
                }
            }
            for (name, _) in &fresh {
                if !base.iter().any(|(n, _)| n == name) {
                    failures.push(format!(
                        "scenario '{name}' is not in {base_path} — new scenarios \
                         must land with a regenerated baseline"
                    ));
                }
            }
            if !failures.is_empty() {
                bail!("bench-check failed:\n  {}", failures.join("\n  "));
            }
            println!(
                "bench-check: {} scenarios within {tolerance:.1}x of baseline",
                base.len()
            );
            Ok(())
        }
        _ => bail!(USAGE),
    }
}

/// Parse and validate one benchkit-v1 document, returning each
/// scenario's `(name, mean_ns)`.
fn load_bench_doc(path: &str) -> Result<Vec<(String, f64)>> {
    use kant::util::json::Json;
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let doc = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    match doc.get("schema").and_then(Json::as_str) {
        Some("benchkit-v1") => {}
        other => bail!("{path}: schema must be \"benchkit-v1\", found {other:?}"),
    }
    let results = doc
        .get("results")
        .and_then(Json::as_arr)
        .with_context(|| format!("{path}: missing `results` array"))?;
    if results.is_empty() {
        bail!("{path}: empty `results` — the bench produced no scenarios");
    }
    let mut out: Vec<(String, f64)> = Vec::with_capacity(results.len());
    for (i, e) in results.iter().enumerate() {
        let name = e.get("name").and_then(Json::as_str).unwrap_or("");
        if name.is_empty() {
            bail!("{path}: results[{i}] has an empty or missing `name`");
        }
        if out.iter().any(|(n, _)| n == name) {
            bail!("{path}: duplicate scenario name '{name}'");
        }
        let mean = e.get("mean_ns").and_then(Json::as_f64).unwrap_or(-1.0);
        if !mean.is_finite() || mean <= 0.0 {
            bail!("{path}: scenario '{name}' needs a positive finite mean_ns");
        }
        let iters = e.get("iters").and_then(Json::as_f64).unwrap_or(0.0);
        if !iters.is_finite() || iters < 1.0 {
            bail!("{path}: scenario '{name}' needs positive iters");
        }
        out.push((name.to_string(), mean));
    }
    Ok(out)
}

fn gen_trace(args: &[String]) -> Result<()> {
    let seed: u64 = flag_value(args, "--seed").unwrap_or("42").parse()?;
    let n: usize = flag_value(args, "--jobs").unwrap_or("1000").parse()?;
    let out = flag_value(args, "--out").context("--out FILE required")?;
    let cfg = match flag_value(args, "--mix").unwrap_or("training") {
        "training" => WorkloadConfig::paper_training(seed),
        "inference" => WorkloadConfig::paper_inference(seed),
        other => bail!("unknown mix '{other}'"),
    };
    let jobs = WorkloadGen::new(cfg).generate(n);
    trace::write_trace(&PathBuf::from(out), &jobs)?;
    println!("wrote {n} jobs to {out}");
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn validate(_args: &[String]) -> Result<()> {
    bail!("`kant validate` needs the XLA runtime; rebuild with `--features xla`")
}

#[cfg(feature = "xla")]
fn validate(args: &[String]) -> Result<()> {
    let dir = flag_value(args, "--artifacts").unwrap_or("artifacts");
    let mut backend = kant::runtime::XlaBackend::new(dir)
        .context("loading artifacts (run `make artifacts` first)")?;
    backend.warmup().context("compiling artifacts")?;
    // Score a toy candidate set and check the math against the native
    // backend (the same parity the integration tests enforce).
    use kant::rsch::features::NODE_F;
    use kant::rsch::score::{NativeBackend, ScoreBackend};
    let n = 64;
    let mut feat = vec![0.0f32; n * NODE_F];
    for i in 0..n {
        let row = &mut feat[i * NODE_F..(i + 1) * NODE_F];
        row[0] = (i % 9) as f32; // free
        row[1] = 8.0;
        row[2] = 8.0 - row[0];
        row[3] = 1.0;
        row[4] = 200.0;
        row[5] = 256.0;
        row[8] = 3.0;
        row[11] = row[0];
    }
    let job = [2.0, 16.0, 1.0, 0.0, 0.0, 2.0, 0.0, 0.0];
    let w = [1.0, 0.0, 0.6, 0.0, 0.5, 0.8, -0.3, 0.2];
    let xla = backend.score_nodes(&feat, n, &job, &w);
    let native = NativeBackend.score_nodes(&feat, n, &job, &w);
    let max_diff = xla
        .iter()
        .zip(&native)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "validate: scored {n} nodes via XLA ({} launches); max |xla - native| = {max_diff:.2e}",
        backend.launches
    );
    anyhow::ensure!(max_diff < 1e-3, "XLA/native scorer divergence");
    println!("validate OK — artifacts healthy, parity holds");
    Ok(())
}
