//! # Kant — a unified scheduling system for large-scale AI clusters
//!
//! Reproduction of *“Kant: An Efficient Unified Scheduling System for
//! Large-Scale AI Clusters”* (Zeng et al., ZTE, CS.DC 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the Kant scheduler itself: QSCH (queueing,
//!   admission, preemption) + RSCH (placement, gang, E-Binpack/E-Spread,
//!   topology awareness) over a discrete-event cluster simulator.
//! * **L2/L1 (`python/compile`)** — the per-cycle node/group scoring
//!   hot-spot as JAX + Pallas, AOT-lowered to HLO text in `artifacts/`.
//! * **runtime** — loads those artifacts through PJRT (`xla` crate) so the
//!   XLA scorer can serve RSCH's hot path with Python nowhere at runtime.
//!
//! See DESIGN.md for the module inventory and the experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod cluster;
pub mod config;
pub mod experiments;
pub mod job;
pub mod lint;
pub mod obs;
pub mod qsch;
pub mod rsch;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod sim;
pub mod metrics;
pub mod util;
