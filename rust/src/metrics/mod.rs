//! The paper's five key metrics (§4): GAR, SOR, GFR, JWTD, JTTED —
//! collected live during simulation and rendered by `metrics::report`.

pub mod report;

use crate::cluster::state::ClusterState;
use crate::job::spec::Priority;
use crate::job::state::Job;
use crate::util::stats::{SizeBuckets, Summary, TimeWeighted};

/// Elastic-inference telemetry: the SLO and tidal-co-scheduling metrics
/// the elasticity loop (`sim::elastic`) reports into.
///
/// * **SLO violation rate** — share of per-service load samples where the
///   replicas actually holding resources fell short of the diurnal
///   demand (a service serving under capacity).
/// * **Elastic-capacity utilization** — GPU-time tidal training ran in
///   the capacity inference scale-down freed, over that freed GPU-time
///   (how well the night-time tide is harvested).
/// * **Replica churn** — total scale-up plus scale-down replica
///   transitions (the control-plane cost of following the curve).
#[derive(Debug, Clone, Default)]
pub struct ElasticTelemetry {
    /// Elastic services observed (live base replica sets).
    pub services: u64,
    /// Per-service load samples taken.
    pub samples: u64,
    /// Samples where active replicas < diurnal demand.
    pub slo_violations: u64,
    /// Replicas added by scale-up decisions.
    pub scale_up_replicas: u64,
    /// Replicas released by scale-down decisions.
    pub scale_down_replicas: u64,
    /// GPUs of elastic headroom currently released below the services'
    /// peak envelope (the tidal pool), over time.
    freed_gpus: TimeWeighted,
    /// GPUs held by tidal training jobs, over time.
    tidal_gpus: TimeWeighted,
}

impl ElasticTelemetry {
    /// Record a load-sample observation of the elastic state.
    pub fn observe(&mut self, now: u64, freed_gpus: u32, tidal_gpus: u32) {
        self.freed_gpus.push(now, freed_gpus as f64);
        self.tidal_gpus.push(now, tidal_gpus as f64);
    }

    /// Share of service-samples violating the demand SLO.
    pub fn slo_violation_rate(&self) -> f64 {
        if self.samples == 0 {
            return 0.0;
        }
        self.slo_violations as f64 / self.samples as f64
    }

    /// Total replica transitions (scale-ups + scale-downs).
    pub fn replica_churn(&self) -> u64 {
        self.scale_up_replicas + self.scale_down_replicas
    }

    /// Tidal GPU-time over freed GPU-time in `[t0, t1]` (0 when nothing
    /// was freed). Can exceed 1.0 when tidal training also consumes
    /// capacity the services never claimed.
    pub fn elastic_utilization(&self, t0: u64, t1: u64) -> f64 {
        let freed = self.freed_gpus.integral(t0, t1);
        if freed <= 0.0 {
            return 0.0;
        }
        self.tidal_gpus.integral(t0, t1) / freed
    }

    /// Tidal training GPU-hours harvested in `[t0, t1]`.
    pub fn tidal_gpu_hours(&self, t0: u64, t1: u64) -> f64 {
        self.tidal_gpus.integral(t0, t1) / 3_600_000.0
    }
}

/// Reliability telemetry: the goodput/restart accounting of the fault
/// subsystem (`sim::faults`).
///
/// * **Goodput** — GPU-time that produced surviving work: Σ over finished
///   jobs of `duration × GPUs`. Redone (lost) work, binding overhead and
///   early-cancelled replicas allocate GPUs without adding goodput. For
///   moldable gangs the credit is the *base-shape* footprint
///   (`duration × base_total_gpus`): the job's work content is fixed, so
///   a job that ran shrunk occupies more allocated GPU-time for the same
///   credit and [`Metrics::goodput_fraction`] becomes the
///   realized-throughput-weighted goodput of the ISSUE — sub-linear
///   ladder rungs show up as efficiency loss, not free capacity.
/// * **Effective GAR** — goodput over the window's total GPU-time: the
///   fraction of the fleet that produced durable work
///   ([`Metrics::effective_gar`]).
/// * **Restart inflation** — per finished job, bind→finish wall time
///   over the fault-free ideal (duration + platform overhead). 1.0 means
///   the job was never hit; the p99 is the JTTED tail that restarts
///   inflate.
#[derive(Debug, Clone, Default)]
pub struct ReliabilityTelemetry {
    /// Node-failure events delivered.
    pub node_faults: u64,
    /// GPU-device-failure events delivered.
    pub gpu_faults: u64,
    /// HBD / switch-group failure events delivered.
    pub hbd_faults: u64,
    /// Maintenance-drain windows started.
    pub drains: u64,
    /// Repair / drain-end events delivered.
    pub repairs: u64,
    /// Jobs that lost their resources to a fault or health flip.
    pub fault_evictions: u64,
    /// Fault victims that gave up a shape rung (malleable shrink) instead
    /// of restarting — they keep their progress, so they charge no
    /// `lost_gpu_ms` and no eviction.
    pub fault_shrinks: u64,
    /// Work discarded by evictions, in GPU-milliseconds (what the
    /// checkpoint policy could not save).
    pub lost_gpu_ms: u64,
    /// GPU-milliseconds of finished (surviving) work.
    goodput_gpu_ms: u64,
    /// Per-finished-job completion-inflation samples.
    inflation: Vec<f64>,
}

impl ReliabilityTelemetry {
    /// A job lost its resources: `gpus` held, `lost_ms` of work discarded.
    pub fn on_eviction(&mut self, gpus: u64, lost_ms: u64) {
        self.fault_evictions += 1;
        self.lost_gpu_ms += gpus.saturating_mul(lost_ms);
    }

    /// A fault victim shrank instead of restarting: no work lost, no
    /// eviction — just the downgrade count for the reliability report.
    pub fn on_shrink(&mut self) {
        self.fault_shrinks += 1;
    }

    /// A job finished: credit its useful GPU-time and record how much
    /// restarts inflated its completion (1.0 = fault-free ideal).
    pub fn on_job_complete(&mut self, goodput_gpu_ms: u64, inflation: f64) {
        self.goodput_gpu_ms += goodput_gpu_ms;
        self.inflation.push(inflation);
    }

    /// Total fault events delivered (node + GPU + HBD + drains).
    pub fn faults_injected(&self) -> u64 {
        self.node_faults + self.gpu_faults + self.hbd_faults + self.drains
    }

    /// GPU-hours of work discarded by evictions.
    pub fn lost_gpu_hours(&self) -> f64 {
        self.lost_gpu_ms as f64 / 3_600_000.0
    }

    /// GPU-hours of finished (surviving) work.
    pub fn goodput_gpu_hours(&self) -> f64 {
        self.goodput_gpu_ms as f64 / 3_600_000.0
    }

    /// Raw goodput in GPU-ms (digest-stable integer form).
    pub fn goodput_gpu_ms(&self) -> u64 {
        self.goodput_gpu_ms
    }

    /// Fault-driven restarts per finished job.
    pub fn restarts_per_finished_job(&self) -> f64 {
        if self.inflation.is_empty() {
            return 0.0;
        }
        self.fault_evictions as f64 / self.inflation.len() as f64
    }

    /// Distribution of per-job completion inflation (p99 is the headline).
    pub fn inflation_summary(&self) -> Summary {
        Summary::from_samples(&self.inflation)
    }
}

/// Live metrics collector. The runner calls the hooks; figures read the
/// accessors.
#[derive(Debug, Clone)]
pub struct Metrics {
    total_gpus: u32,
    t0: u64,
    last_ms: u64,
    /// Allocated-GPU count over time → GAR(t) and SOR via integral (§4.1/4.2).
    gar: TimeWeighted,
    /// Fragmentation ratio over time (§4.3).
    gfr: TimeWeighted,
    /// Waiting time (ms) by job size (§4.4).
    jwtd: SizeBuckets,
    /// Waiting time (ms) by base-priority class, timestamped at schedule
    /// time — the rolling-window JWTD signal the adaptive weight
    /// controller reads ([`Metrics::class_wait_samples_between`]).
    class_waits: [Vec<(u64, f64)>; Priority::NUM_CLASSES],
    /// Node-count deviation ratio by job size (§4.5).
    jtted_node: SizeBuckets,
    /// NodeNetGroup deviation ratio by job size (§4.5).
    jtted_group: SizeBuckets,
    /// Spine-span deviation ratio by job size (§4.5 extension).
    jtted_spine: SizeBuckets,
    /// Superspine-span deviation ratio by job size (§4.5 extension) —
    /// each point above 1.0 is a core-layer crossing the topology-blind
    /// scorer used to hand out for free.
    jtted_superspine: SizeBuckets,
    pub jobs_submitted: u64,
    pub jobs_finished: u64,
    pub jobs_scheduled: u64,
    /// Jobs deliberately cancelled before natural completion (elastic
    /// scale-down / service retirement) — together with `jobs_finished`
    /// and the run's unfinished count these partition `jobs_submitted`.
    pub jobs_cancelled: u64,
    /// Elastic-inference telemetry (SLO, tidal co-scheduling, churn).
    pub elastic: ElasticTelemetry,
    /// Reliability telemetry (faults, goodput, lost work, inflation).
    pub reliability: ReliabilityTelemetry,
}

impl Metrics {
    pub fn new(state: &ClusterState, t0: u64) -> Metrics {
        let mut m = Metrics {
            total_gpus: state.total_gpus(),
            t0,
            last_ms: t0,
            gar: TimeWeighted::new(),
            gfr: TimeWeighted::new(),
            jwtd: SizeBuckets::paper_default(),
            class_waits: Default::default(),
            jtted_node: SizeBuckets::paper_default(),
            jtted_group: SizeBuckets::paper_default(),
            jtted_spine: SizeBuckets::paper_default(),
            jtted_superspine: SizeBuckets::paper_default(),
            jobs_submitted: 0,
            jobs_finished: 0,
            jobs_scheduled: 0,
            jobs_cancelled: 0,
            elastic: ElasticTelemetry::default(),
            reliability: ReliabilityTelemetry::default(),
        };
        m.observe_cluster(t0, state);
        m
    }

    /// Record the instantaneous allocation + fragmentation state.
    pub fn observe_cluster(&mut self, now: u64, state: &ClusterState) {
        self.last_ms = self.last_ms.max(now);
        self.gar.push(now, state.allocated_gpus() as f64);
        self.gfr.push(now, state.fragmentation_ratio(None));
    }

    pub fn on_submit(&mut self) {
        self.jobs_submitted += 1;
    }

    /// Record a successful (first) scheduling: JWTD + JTTED.
    pub fn on_scheduled(&mut self, now: u64, state: &ClusterState, job: &Job) {
        self.jobs_scheduled += 1;
        let gpus = job.spec.total_gpus();
        let wait = job.waiting_ms(now) as f64;
        self.jwtd.record(gpus, wait);
        self.class_waits[job.spec.priority.class_index()].push((now, wait));

        // JTTED (§4.5): deviation from the optimal packing.
        let nodes = state.nodes_of(job.id());
        if nodes.is_empty() {
            return;
        }
        let gpus_per_node = state
            .gpu_type(state.node(nodes[0]).gpu_type)
            .gpus_per_node as u32;
        let optimal_nodes = gpus.div_ceil(gpus_per_node).max(1);
        let actual_nodes = nodes.len() as u32;
        self.jtted_node
            .record(gpus, actual_nodes as f64 / optimal_nodes as f64);

        let group = state.node(nodes[0]).group;
        let nodes_per_group = state.fabric.groups[group.index()].nodes.len() as u32;
        let optimal_groups = optimal_nodes.div_ceil(nodes_per_group.max(1)).max(1);
        let actual_groups = state.fabric.groups_spanned(&nodes) as u32;
        self.jtted_group
            .record(gpus, actual_groups as f64 / optimal_groups as f64);

        // Spine / superspine span deviation: optimal counts follow the
        // same capacity chain (nodes → groups → spines → superspines),
        // sized from the first placed node's subtree like the group calc.
        let spine = state.fabric.spine_of(nodes[0]);
        let groups_per_spine = state.fabric.spines[spine.index()].groups.len() as u32;
        let optimal_spines = optimal_groups.div_ceil(groups_per_spine.max(1)).max(1);
        let actual_spines = state.fabric.spines_spanned(&nodes) as u32;
        self.jtted_spine
            .record(gpus, actual_spines as f64 / optimal_spines as f64);

        let ss = state.fabric.superspine_of(nodes[0]);
        let spines_per_ss = state.fabric.spines_in_superspine(ss) as u32;
        let optimal_ss = optimal_spines.div_ceil(spines_per_ss.max(1)).max(1);
        let actual_ss = state.fabric.superspines_spanned(&nodes) as u32;
        self.jtted_superspine
            .record(gpus, actual_ss as f64 / optimal_ss as f64);
    }

    pub fn on_finished(&mut self) {
        self.jobs_finished += 1;
    }

    pub fn on_cancelled(&mut self) {
        self.jobs_cancelled += 1;
    }

    // ---- accessors (figures) ----

    pub fn window(&self) -> (u64, u64) {
        (self.t0, self.last_ms)
    }

    /// Instantaneous GAR at `t`.
    pub fn gar_at(&self, t: u64) -> f64 {
        self.gar.at(t) / self.total_gpus.max(1) as f64
    }

    /// **GAR** (GPU Allocation Ratio, §4.1): allocated GPUs / total GPUs,
    /// time-averaged over the whole observation window.
    ///
    /// ```
    /// use kant::cluster::builder::{ClusterBuilder, ClusterSpec};
    /// use kant::cluster::ids::{JobId, NodeId, PodId};
    /// use kant::cluster::state::PodPlacement;
    /// use kant::metrics::Metrics;
    ///
    /// // 2 nodes x 8 GPUs = 16 GPUs.
    /// let mut state = ClusterBuilder::build(&ClusterSpec::homogeneous("t", 1, 1, 2));
    /// let mut m = Metrics::new(&state, 0);
    /// state.commit_placements(JobId(1), vec![PodPlacement {
    ///     pod: PodId::new(JobId(1), 0),
    ///     node: NodeId(0),
    ///     devices: (0..8).collect(),
    ///     nic: 0,
    /// }]).unwrap();
    /// m.observe_cluster(0, &state);   // 8/16 GPUs held from t = 0 ms ...
    /// m.observe_cluster(100, &state); // ... through t = 100 ms.
    /// assert!((m.gar_avg() - 0.5).abs() < 1e-9);
    /// ```
    pub fn gar_avg(&self) -> f64 {
        let (a, b) = self.window();
        if b <= a {
            return 0.0;
        }
        self.gar.average(a, b) / self.total_gpus.max(1) as f64
    }

    /// Median of the sampled instantaneous GAR series (what the paper's
    /// GAR bars report — distinct from the cumulative SOR).
    /// Samples both endpoints (`points + 1` samples over `[a, b]`): a
    /// half-open `(a, b]` sweep never sees the window start, which biases
    /// short windows toward whatever the tail happens to hold.
    pub fn gar_median(&self, points: usize) -> f64 {
        let (a, b) = self.window();
        if b <= a || points == 0 {
            return 0.0;
        }
        let samples: Vec<f64> = (0..=points)
            .map(|i| self.gar_at(a + (b - a) * i as u64 / points as u64))
            .collect();
        crate::util::stats::median(&samples)
    }

    /// SOR at `t`: cumulative allocated GPU-time / available GPU-time (§4.2).
    pub fn sor_at(&self, t: u64) -> f64 {
        if t <= self.t0 {
            return 0.0;
        }
        self.gar.integral(self.t0, t) / (self.total_gpus.max(1) as f64 * (t - self.t0) as f64)
    }

    /// **SOR** (Scheduling Occupancy Rate, §4.2) at the end of the run:
    /// cumulative allocated GPU-time over available GPU-time. Unlike
    /// [`Metrics::gar_avg`] sampled at an instant, SOR integrates the
    /// whole history, so it also charges the §4.2 binding window (image
    /// pull, container start) where GPUs are held but not yet running.
    ///
    /// ```
    /// use kant::cluster::builder::{ClusterBuilder, ClusterSpec};
    /// use kant::cluster::ids::{JobId, NodeId, PodId};
    /// use kant::cluster::state::PodPlacement;
    /// use kant::metrics::Metrics;
    ///
    /// let mut state = ClusterBuilder::build(&ClusterSpec::homogeneous("t", 1, 1, 2));
    /// let mut m = Metrics::new(&state, 0);
    /// state.commit_placements(JobId(1), vec![PodPlacement {
    ///     pod: PodId::new(JobId(1), 0),
    ///     node: NodeId(0),
    ///     devices: (0..8).collect(),
    ///     nic: 0,
    /// }]).unwrap();
    /// m.observe_cluster(0, &state);
    /// m.observe_cluster(100, &state);
    /// state.release_job(JobId(1)).unwrap();
    /// m.observe_cluster(100, &state);
    /// m.observe_cluster(200, &state);
    /// // 8 GPUs held for 100 of 200 ms on a 16-GPU cluster: SOR = 0.25.
    /// assert!((m.sor_final() - 0.25).abs() < 1e-9);
    /// ```
    pub fn sor_final(&self) -> f64 {
        self.sor_at(self.last_ms)
    }

    /// **GFR** (GPU node Fragmentation Ratio, §4.3): fragmented nodes /
    /// schedulable nodes, time-averaged over the run. A node is
    /// *fragmented* when partially allocated — neither fully idle nor
    /// fully occupied (see [`crate::cluster::Node::is_fragmented`]); the
    /// instantaneous value comes from
    /// [`crate::cluster::ClusterState::fragmentation_ratio`].
    ///
    /// ```
    /// use kant::cluster::builder::{ClusterBuilder, ClusterSpec};
    /// use kant::cluster::ids::{JobId, NodeId, PodId};
    /// use kant::cluster::state::PodPlacement;
    /// use kant::metrics::Metrics;
    ///
    /// let mut state = ClusterBuilder::build(&ClusterSpec::homogeneous("t", 1, 1, 2));
    /// let mut m = Metrics::new(&state, 0);
    /// // 2 of 8 GPUs on one node: 1 of 2 nodes fragmented.
    /// state.commit_placements(JobId(1), vec![PodPlacement {
    ///     pod: PodId::new(JobId(1), 0),
    ///     node: NodeId(0),
    ///     devices: vec![0, 1],
    ///     nic: 0,
    /// }]).unwrap();
    /// m.observe_cluster(0, &state);
    /// m.observe_cluster(100, &state);
    /// assert!((m.gfr_avg() - 0.5).abs() < 1e-9);
    /// ```
    pub fn gfr_avg(&self) -> f64 {
        let (a, b) = self.window();
        if b <= a {
            return 0.0;
        }
        self.gfr.average(a, b)
    }

    /// **Effective GAR** (reliability extension): goodput — GPU-time of
    /// *finished* work — over the window's total GPU-time. Plain GAR
    /// counts a GPU as productive while it redoes lost work; effective
    /// GAR only counts work that survived, so the gap between the two is
    /// the price of failures the checkpoint policy could not cover.
    pub fn effective_gar(&self) -> f64 {
        let (a, b) = self.window();
        if b <= a {
            return 0.0;
        }
        self.reliability.goodput_gpu_ms() as f64
            / (self.total_gpus.max(1) as f64 * (b - a) as f64)
    }

    /// **Goodput fraction** (reliability extension): finished-work
    /// GPU-time over *allocated* GPU-time — of everything the scheduler
    /// handed out, how much produced durable results. The complement is
    /// redone work, binding overhead and abandoned (unfinished or
    /// cancelled) allocations.
    pub fn goodput_fraction(&self) -> f64 {
        let (a, b) = self.window();
        let allocated = self.gar.integral(a, b);
        if allocated <= 0.0 {
            return 0.0;
        }
        self.reliability.goodput_gpu_ms() as f64 / allocated
    }

    /// Time-averaged GAR over an explicit window (steady-state reporting).
    pub fn gar_avg_between(&self, t0: u64, t1: u64) -> f64 {
        if t1 <= t0 {
            return 0.0;
        }
        self.gar.average(t0, t1) / self.total_gpus.max(1) as f64
    }

    /// Time-averaged GFR over an explicit window.
    pub fn gfr_avg_between(&self, t0: u64, t1: u64) -> f64 {
        if t1 <= t0 {
            return 0.0;
        }
        self.gfr.average(t0, t1)
    }

    pub fn gfr_at(&self, t: u64) -> f64 {
        self.gfr.at(t)
    }

    /// Evenly-sampled series for time-series figures: (t, GAR, SOR, GFR).
    pub fn series(&self, points: usize) -> Vec<(u64, f64, f64, f64)> {
        let (a, b) = self.window();
        if points == 0 || b <= a {
            return Vec::new();
        }
        (1..=points)
            .map(|i| {
                let t = a + (b - a) * i as u64 / points as u64;
                (t, self.gar_at(t), self.sor_at(t), self.gfr_at(t))
            })
            .collect()
    }

    /// **JWTD** (Job Waiting Time Distribution, §4.4): per-size-bucket
    /// summaries of submit→schedule waits, recorded by
    /// [`Metrics::on_scheduled`]. Buckets follow the paper (1, 2–8, 9–64,
    /// 65–256, 257–1024, 1025+ GPUs). For censored waits of
    /// never-scheduled jobs use [`crate::experiments::jwtd_buckets`].
    ///
    /// ```
    /// use kant::cluster::builder::{ClusterBuilder, ClusterSpec};
    /// use kant::cluster::ids::{GpuTypeId, JobId, TenantId};
    /// use kant::job::spec::{JobKind, JobSpec};
    /// use kant::job::state::Job;
    /// use kant::metrics::Metrics;
    ///
    /// let state = ClusterBuilder::build(&ClusterSpec::homogeneous("t", 1, 1, 2));
    /// let mut m = Metrics::new(&state, 0);
    /// let spec = JobSpec::homogeneous(
    ///     JobId(7), TenantId(0), JobKind::Training, GpuTypeId(0), 1, 8);
    /// let mut job = Job::new(spec); // Submitted at t = 0 ...
    /// job.mark_admitted();
    /// job.mark_scheduled(30_000);   // ... scheduled 30 s later.
    /// m.on_scheduled(30_000, &state, &job);
    /// let buckets = m.jwtd_summaries();
    /// assert_eq!(buckets[1].0, "2-8"); // An 8-GPU job: the 2–8 bucket.
    /// assert_eq!(buckets[1].1.count, 1);
    /// assert!((buckets[1].1.mean - 30_000.0).abs() < 1e-9);
    /// ```
    pub fn jwtd_summaries(&self) -> Vec<(String, Summary)> {
        self.jwtd.summaries()
    }

    /// Waits (ms) of jobs in base-priority class `class` scheduled in
    /// `(t0, t1]` — the rolling-window slice the adaptive controller
    /// folds with censored still-waiting samples before taking a p99
    /// (see [`crate::rsch::adapt::collect_signals`]). Samples arrive in
    /// schedule order, so the slice is deterministic.
    pub fn class_wait_samples_between(&self, class: usize, t0: u64, t1: u64) -> Vec<f64> {
        self.class_waits[class]
            .iter()
            .filter(|&&(t, _)| t > t0 && t <= t1)
            .map(|&(_, w)| w)
            .collect()
    }

    /// **JTTED** node deviation (Job Training Time Estimation Distribution,
    /// §4.5): actual node count / optimal node count per size bucket — 1.0
    /// is a perfect packing, higher means the job was scattered across
    /// more nodes than its GPU demand requires.
    ///
    /// ```
    /// use kant::cluster::builder::{ClusterBuilder, ClusterSpec};
    /// use kant::cluster::ids::{GpuTypeId, JobId, NodeId, PodId, TenantId};
    /// use kant::cluster::state::PodPlacement;
    /// use kant::job::spec::{JobKind, JobSpec};
    /// use kant::job::state::Job;
    /// use kant::metrics::Metrics;
    ///
    /// let mut state = ClusterBuilder::build(&ClusterSpec::homogeneous("t", 1, 1, 2));
    /// let mut m = Metrics::new(&state, 0);
    /// // An 8-GPU job on exactly one 8-GPU node: the optimal packing.
    /// state.commit_placements(JobId(1), vec![PodPlacement {
    ///     pod: PodId::new(JobId(1), 0),
    ///     node: NodeId(0),
    ///     devices: (0..8).collect(),
    ///     nic: 0,
    /// }]).unwrap();
    /// let spec = JobSpec::homogeneous(
    ///     JobId(1), TenantId(0), JobKind::Training, GpuTypeId(0), 1, 8);
    /// let mut job = Job::new(spec);
    /// job.mark_admitted();
    /// job.mark_scheduled(1_000);
    /// m.on_scheduled(1_000, &state, &job);
    /// let dev = m.jtted_node_summaries();
    /// assert_eq!(dev[1].1.count, 1);
    /// assert!((dev[1].1.mean - 1.0).abs() < 1e-9); // actual/optimal = 1/1.
    /// ```
    pub fn jtted_node_summaries(&self) -> Vec<(String, Summary)> {
        self.jtted_node.summaries()
    }

    /// **JTTED** NodeNetGroup deviation (§4.5): actual groups spanned /
    /// optimal group count per size bucket — the communication-locality
    /// half of the JTTED story (crossing LeafGroups costs bandwidth).
    /// Recorded alongside [`Metrics::jtted_node_summaries`] by
    /// [`Metrics::on_scheduled`]; the same example yields a 1.0 mean here
    /// too (one node ⇒ one group).
    pub fn jtted_group_summaries(&self) -> Vec<(String, Summary)> {
        self.jtted_group.summaries()
    }

    /// **JTTED** spine-span deviation (§4.5 extension): distinct spines
    /// spanned / optimal spine count per size bucket, recorded alongside
    /// the node and group ratios by [`Metrics::on_scheduled`].
    pub fn jtted_spine_summaries(&self) -> Vec<(String, Summary)> {
        self.jtted_spine.summaries()
    }

    /// **JTTED** superspine-span deviation (§4.5 extension): distinct
    /// superspines spanned / optimal superspine count per size bucket.
    /// 1.0 means the gang never crossed the core layer beyond what its
    /// size forces; the truthful-tier scorer exists to push this toward
    /// 1.0 where the blind scorer drifted above it.
    pub fn jtted_superspine_summaries(&self) -> Vec<(String, Summary)> {
        self.jtted_superspine.summaries()
    }

    /// Sample-weighted mean over every bucket of a JTTED distribution —
    /// the single-number form the run digest and the topology-stress
    /// experiment compare across arms.
    pub fn weighted_mean(summaries: &[(String, Summary)]) -> f64 {
        let (count, sum) = summaries
            .iter()
            .fold((0usize, 0.0f64), |(c, s), (_, summary)| {
                (c + summary.count, s + summary.mean * summary.count as f64)
            });
        if count == 0 {
            0.0
        } else {
            sum / count as f64
        }
    }

    pub fn total_gpus(&self) -> u32 {
        self.total_gpus
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::builder::{ClusterBuilder, ClusterSpec};
    use crate::cluster::ids::{GpuTypeId, JobId, NodeId, PodId, TenantId};
    use crate::cluster::state::PodPlacement;
    use crate::job::spec::{JobKind, JobSpec};

    fn place(state: &mut ClusterState, id: u64, node: u32, devs: Vec<u8>) {
        state
            .commit_placements(
                JobId(id),
                vec![PodPlacement {
                    pod: PodId::new(JobId(id), 0),
                    node: NodeId(node),
                    devices: devs,
                    nic: 0,
                }],
            )
            .unwrap();
    }

    #[test]
    fn gar_and_sor_track_allocation_over_time() {
        let mut state = ClusterBuilder::build(&ClusterSpec::homogeneous("t", 1, 1, 2)); // 16 GPUs.
        let mut m = Metrics::new(&state, 0);
        place(&mut state, 1, 0, (0..8).collect());
        m.observe_cluster(0, &state);
        m.observe_cluster(100, &state); // Hold 8/16 for [0,100).
        state.release_job(JobId(1)).unwrap();
        m.observe_cluster(100, &state);
        m.observe_cluster(200, &state);
        assert!((m.gar_at(50) - 0.5).abs() < 1e-9);
        assert!((m.gar_at(150) - 0.0).abs() < 1e-9);
        // SOR at 200: (8×100) / (16×200) = 0.25.
        assert!((m.sor_at(200) - 0.25).abs() < 1e-9);
        assert!((m.gar_avg() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn gfr_reflects_partial_nodes() {
        let mut state = ClusterBuilder::build(&ClusterSpec::homogeneous("t", 1, 1, 4));
        let mut m = Metrics::new(&state, 0);
        place(&mut state, 1, 0, vec![0, 1]);
        m.observe_cluster(10, &state);
        assert!((m.gfr_at(10) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn jwtd_and_jtted_record_on_schedule() {
        let mut state = ClusterBuilder::build(&ClusterSpec::homogeneous("t", 1, 2, 2));
        let mut m = Metrics::new(&state, 0);
        let spec =
            JobSpec::homogeneous(JobId(1), TenantId(0), JobKind::Training, GpuTypeId(0), 2, 8)
                .with_times(0, 1000);
        let mut job = Job::new(spec);
        // Spans two groups (worst case for a 2-node job here).
        place(&mut state, 1, 0, (0..8).collect());
        state
            .commit_placements(
                JobId(99),
                vec![PodPlacement {
                    pod: PodId::new(JobId(99), 0),
                    node: NodeId(2),
                    devices: (0..8).collect(),
                    nic: 0,
                }],
            )
            .unwrap();
        job.mark_admitted();
        job.mark_scheduled(500);
        // Fake: job 1 occupies nodes 0 (own) — nodes_of uses placements of job 1 only.
        m.on_scheduled(500, &state, &job);
        let jwtd = m.jwtd_summaries();
        // 16-GPU job → bucket "9-64".
        assert_eq!(jwtd[2].1.count, 1);
        assert_eq!(jwtd[2].1.mean, 500.0);
        let node_dev = m.jtted_node_summaries();
        // Actual 1 node placed (only node 0 for job1) vs optimal 2 → 0.5;
        // (degenerate because we hand-placed half the job — the value just
        // needs to be recorded).
        assert_eq!(node_dev[2].1.count, 1);
    }

    #[test]
    fn jtted_spanning_ratios_record_on_schedule() {
        // 2 spines × 1 group × 2 nodes with one spine per superspine: a
        // 2-node job split across groups spans 2 spines and 2 superspines
        // where 1 of each would do — deviation 2.0 in every new bucket.
        let mut spec = ClusterSpec::homogeneous("span", 2, 1, 2);
        spec.spines_per_superspine = 1;
        let mut state = ClusterBuilder::build(&spec);
        let mut m = Metrics::new(&state, 0);
        state
            .commit_placements(
                JobId(1),
                vec![
                    PodPlacement {
                        pod: PodId::new(JobId(1), 0),
                        node: NodeId(0),
                        devices: (0..8).collect(),
                        nic: 0,
                    },
                    PodPlacement {
                        pod: PodId::new(JobId(1), 1),
                        node: NodeId(2),
                        devices: (0..8).collect(),
                        nic: 0,
                    },
                ],
            )
            .unwrap();
        let spec =
            JobSpec::homogeneous(JobId(1), TenantId(0), JobKind::Training, GpuTypeId(0), 2, 8);
        let mut job = Job::new(spec);
        job.mark_admitted();
        job.mark_scheduled(100);
        m.on_scheduled(100, &state, &job);
        let spine = m.jtted_spine_summaries();
        let ss = m.jtted_superspine_summaries();
        assert_eq!(spine[2].1.count, 1);
        assert!((spine[2].1.mean - 2.0).abs() < 1e-9, "{}", spine[2].1.mean);
        assert_eq!(ss[2].1.count, 1);
        assert!((ss[2].1.mean - 2.0).abs() < 1e-9, "{}", ss[2].1.mean);
        assert!((Metrics::weighted_mean(&ss) - 2.0).abs() < 1e-9);
        assert_eq!(Metrics::weighted_mean(&m.jtted_spine_summaries()), 2.0);
    }

    #[test]
    fn class_wait_samples_window_by_schedule_time() {
        use crate::job::spec::Priority;
        let state = ClusterBuilder::build(&ClusterSpec::homogeneous("t", 1, 1, 2));
        let mut m = Metrics::new(&state, 0);
        let schedule = |m: &mut Metrics, id: u64, prio: Priority, submit: u64, at: u64| {
            let spec = JobSpec::homogeneous(
                JobId(id),
                TenantId(0),
                JobKind::Training,
                GpuTypeId(0),
                1,
                8,
            )
            .with_times(submit, 1000)
            .with_priority(prio);
            let mut job = Job::new(spec);
            job.mark_admitted();
            job.mark_scheduled(at);
            m.on_scheduled(at, &state, &job);
        };
        schedule(&mut m, 1, Priority::LOW, 0, 500);
        schedule(&mut m, 2, Priority::LOW, 100, 2_000);
        schedule(&mut m, 3, Priority::HIGH, 0, 1_500);
        // Half-open window (1000, 2000]: only the second LOW sample.
        assert_eq!(m.class_wait_samples_between(0, 1_000, 2_000), vec![1_900.0]);
        // Full window: both LOW waits, in schedule order.
        assert_eq!(
            m.class_wait_samples_between(0, 0, 2_000),
            vec![500.0, 1_900.0]
        );
        // HIGH goes to its own class; NORMAL stays empty.
        assert_eq!(m.class_wait_samples_between(2, 0, 2_000), vec![1_500.0]);
        assert!(m.class_wait_samples_between(1, 0, 2_000).is_empty());
    }

    #[test]
    fn gar_median_samples_both_endpoints() {
        // 8/16 GPUs held over [0, 100) of a 150 ms window. Sampling both
        // endpoints sees [0.5, 0.5, 0.0] at points = 2 → median 0.5; the
        // old (a, b] sweep saw only [0.5, 0.0] and reported 0.25.
        let mut state = ClusterBuilder::build(&ClusterSpec::homogeneous("t", 1, 1, 2));
        let mut m = Metrics::new(&state, 0);
        place(&mut state, 1, 0, (0..8).collect());
        m.observe_cluster(0, &state);
        state.release_job(JobId(1)).unwrap();
        m.observe_cluster(100, &state);
        m.observe_cluster(150, &state);
        assert!((m.gar_median(2) - 0.5).abs() < 1e-9, "{}", m.gar_median(2));
    }

    #[test]
    fn elastic_telemetry_rates_and_utilization() {
        let mut e = ElasticTelemetry::default();
        e.samples = 10;
        e.slo_violations = 2;
        assert!((e.slo_violation_rate() - 0.2).abs() < 1e-12);
        e.scale_up_replicas = 3;
        e.scale_down_replicas = 4;
        assert_eq!(e.replica_churn(), 7);
        // 10 GPUs freed, 5 used by tidal training over [0, 100).
        e.observe(0, 10, 5);
        e.observe(100, 0, 0);
        assert!((e.elastic_utilization(0, 100) - 0.5).abs() < 1e-12);
        assert!((e.tidal_gpu_hours(0, 100) - 500.0 / 3_600_000.0).abs() < 1e-12);
        // Empty telemetry divides to zero, not NaN.
        let empty = ElasticTelemetry::default();
        assert_eq!(empty.slo_violation_rate(), 0.0);
        assert_eq!(empty.elastic_utilization(0, 100), 0.0);
    }

    #[test]
    fn reliability_goodput_and_inflation_accessors() {
        let mut state = ClusterBuilder::build(&ClusterSpec::homogeneous("t", 1, 1, 2)); // 16 GPUs.
        let mut m = Metrics::new(&state, 0);
        place(&mut state, 1, 0, (0..8).collect());
        m.observe_cluster(0, &state);
        m.observe_cluster(100, &state);
        state.release_job(JobId(1)).unwrap();
        m.observe_cluster(100, &state);
        m.observe_cluster(200, &state);
        // The job held 8 GPUs for 100 ms but only 50 ms was useful work
        // (the rest was a redo): goodput 400 GPU-ms of 800 allocated.
        m.reliability.on_job_complete(8 * 50, 2.0);
        m.reliability.on_eviction(8, 50);
        assert!((m.goodput_fraction() - 0.5).abs() < 1e-9);
        // Effective GAR: 400 GPU-ms over 16 GPUs × 200 ms.
        assert!((m.effective_gar() - 400.0 / 3200.0).abs() < 1e-9);
        assert_eq!(m.reliability.lost_gpu_ms, 400);
        assert_eq!(m.reliability.fault_evictions, 1);
        assert!((m.reliability.restarts_per_finished_job() - 1.0).abs() < 1e-12);
        let infl = m.reliability.inflation_summary();
        assert_eq!(infl.count, 1);
        assert!((infl.p99 - 2.0).abs() < 1e-12);
        // Empty telemetry divides to zero, not NaN.
        let empty = Metrics::new(&state, 0);
        assert_eq!(empty.goodput_fraction(), 0.0);
        assert_eq!(empty.reliability.restarts_per_finished_job(), 0.0);
    }

    #[test]
    fn series_is_monotone_in_time() {
        let state = ClusterBuilder::build(&ClusterSpec::homogeneous("t", 1, 1, 2));
        let mut m = Metrics::new(&state, 0);
        m.observe_cluster(1000, &state);
        let s = m.series(10);
        assert_eq!(s.len(), 10);
        assert!(s.windows(2).all(|w| w[0].0 <= w[1].0));
    }
}
