//! Rendering: aligned text tables and CSV export for the figures harness.

use crate::obs::{SchedulerHealth, PHASE_NAMES};
use crate::util::stats::Summary;

use super::Metrics;

/// Render a table of (label, columns) rows with a header.
pub fn table(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:>w$}", w = w))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let headers: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&headers, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

/// CSV rendering of the same rows.
pub fn csv(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str(&headers.join(","));
    out.push('\n');
    for row in rows {
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

/// Format milliseconds as a human duration.
pub fn fmt_ms(ms: f64) -> String {
    if ms < 1_000.0 {
        format!("{ms:.0}ms")
    } else if ms < 60_000.0 {
        format!("{:.1}s", ms / 1_000.0)
    } else if ms < 3_600_000.0 {
        format!("{:.1}m", ms / 60_000.0)
    } else {
        format!("{:.2}h", ms / 3_600_000.0)
    }
}

/// Format a ratio as a percentage.
pub fn pct(x: f64) -> String {
    format!("{:.2}%", x * 100.0)
}

/// Headline table for one run (GAR/SOR/GFR + throughput).
pub fn headline(name: &str, m: &Metrics) -> String {
    let rows = vec![vec![
        name.to_string(),
        pct(m.gar_avg()),
        pct(m.sor_final()),
        pct(m.gfr_avg()),
        m.jobs_scheduled.to_string(),
        m.jobs_finished.to_string(),
    ]];
    table(
        "headline",
        &["run", "GAR(avg)", "SOR", "GFR(avg)", "scheduled", "finished"],
        &rows,
    )
}

/// Format nanoseconds as a human duration (wall-clock phase spans).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.1}µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1_000_000.0)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Per-phase wall-clock profile of a run, plus the scheduler-overhead
/// row: mean scheduling wall-clock per simulated cycle and its fraction
/// of the cycle period — the honest counterpart of the paper's SOR
/// story (how much of each real-time cycle window the scheduler would
/// spend deciding). Phase columns may overlap (a preemption's retry
/// also counts under plan/commit), so only the overhead row is
/// additive.
pub fn phase_table(h: &SchedulerHealth, cycle_ms: u64) -> String {
    let rows: Vec<Vec<String>> = PHASE_NAMES
        .iter()
        .enumerate()
        .map(|(k, name)| {
            let p = &h.phases[k];
            vec![
                name.to_string(),
                fmt_ns(p.total_ns as f64),
                fmt_ns(p.p50_ns),
                fmt_ns(p.p95_ns),
                fmt_ns(p.p99_ns),
            ]
        })
        .collect();
    let mut out = table(
        &format!("scheduler phases ({} cycles profiled)", h.cycles),
        &["phase", "total", "p50", "p95", "p99"],
        &rows,
    );
    out.push_str(&format!(
        "\nscheduler overhead: {}/cycle ({} of the {} cycle period) | \
         queue depth mean {:.1} max {} | plan-cache hit rate {} | \
         shard imbalance {:.2} | nodes examined {} scored {} | decisions {}\n",
        fmt_ns(h.overhead_ns_per_cycle()),
        pct(h.overhead_fraction(cycle_ms)),
        fmt_ms(cycle_ms as f64),
        h.queue_depth_mean,
        h.queue_depth_max,
        pct(h.plan_cache_hit_rate),
        h.shard_imbalance,
        h.nodes_examined,
        h.nodes_scored,
        h.decisions,
    ));
    out
}

/// Side-by-side per-bucket summaries, e.g. JWTD for two arms.
pub fn bucket_comparison(
    title: &str,
    arms: &[(&str, Vec<(String, Summary)>)],
    value_fmt: fn(f64) -> String,
) -> String {
    let mut headers = vec!["size"];
    for &(name, _) in arms {
        headers.push(name);
    }
    let num_buckets = arms.first().map(|(_, s)| s.len()).unwrap_or(0);
    let mut rows = Vec::new();
    for b in 0..num_buckets {
        let mut row = vec![arms[0].1[b].0.clone()];
        for (_, summaries) in arms {
            let s = &summaries[b].1;
            row.push(if s.count == 0 {
                "-".to_string()
            } else {
                value_fmt(s.mean)
            });
        }
        rows.push(row);
    }
    table(title, &headers, &rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_aligns_columns() {
        let t = table(
            "x",
            &["a", "bbbb"],
            &[
                vec!["1".into(), "2".into()],
                vec!["100".into(), "20000".into()],
            ],
        );
        assert!(t.contains("== x =="));
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    fn csv_joins_cells() {
        let c = csv(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert_eq!(c, "a,b\n1,2\n");
    }

    #[test]
    fn fmt_ms_units() {
        assert_eq!(fmt_ms(500.0), "500ms");
        assert_eq!(fmt_ms(90_000.0), "1.5m");
        assert_eq!(fmt_ms(7_200_000.0), "2.00h");
    }

    #[test]
    fn pct_formats() {
        assert_eq!(pct(0.9312), "93.12%");
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1_500.0), "1.5µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50ms");
        assert_eq!(fmt_ns(3e9), "3.00s");
    }

    #[test]
    fn phase_table_renders_overhead_row() {
        let h = SchedulerHealth {
            cycles: 4,
            sched_wall_ns: 4_000_000,
            ..SchedulerHealth::default()
        };
        let t = phase_table(&h, 5_000);
        assert!(t.contains("4 cycles profiled"), "{t}");
        // 1 ms of scheduling per 5 s cycle = 0.02% overhead.
        assert!(t.contains("scheduler overhead: 1.00ms/cycle (0.02%"), "{t}");
        for name in PHASE_NAMES {
            assert!(t.contains(name), "missing phase row {name}");
        }
    }

    #[test]
    fn bucket_comparison_renders_dash_for_empty() {
        let arms = vec![
            (
                "arm1",
                vec![("1".to_string(), Summary::from_samples(&[10.0]))],
            ),
            ("arm2", vec![("1".to_string(), Summary::from_samples(&[]))]),
        ];
        let t = bucket_comparison("jwtd", &arms, |x| format!("{x:.0}"));
        assert!(t.contains("10"));
        assert!(t.contains('-'));
    }
}
