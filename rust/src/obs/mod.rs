//! Digest-inert observability: per-cycle phase profiling, structured
//! decision tracing, and the `SchedulerHealth` rollup.
//!
//! The hard invariant this module is built around: **observability must
//! never perturb scheduling**. Every wall-clock measurement lives
//! strictly outside the deterministic digest
//! ([`SimOutcome::digest_json`](crate::sim::SimOutcome::digest_json)),
//! and the recorder only *reads* scheduler state — it is handed into
//! [`Qsch::cycle_observed`](crate::qsch::Qsch::cycle_observed) and the
//! runner as `&mut ObsRecorder`, but no scheduling branch ever consults
//! it. A disabled recorder ([`ObsRecorder::disabled`]) allocates nothing
//! and reduces every span to one branch on a bool, so the legacy
//! `Qsch::cycle` / `sim::run` entry points pay ~nothing.
//!
//! Three artifacts come out of a run:
//! * [`CycleProfile`]s — monotonic wall-clock spans (`std::time::Instant`)
//!   around each scheduling phase of a cycle, rolled up into
//! * [`SchedulerHealth`] — p50/p95/p99 per phase, queue depth, plan-cache
//!   hit rate, shard imbalance, and the scheduler-overhead row (wall-clock
//!   scheduling time per simulated cycle); and
//! * [`DecisionRecord`]s — why each job landed (or did not land) where it
//!   did, streamed as JSONL through `kant simulate --obs-out FILE` and
//!   read back by `kant obs summarize` / `kant explain`.

// Sanctioned wall-clock island: the whole module exists to measure
// scheduler overhead, and nothing here feeds back into scheduling.
#![allow(clippy::disallowed_methods)]

use std::collections::VecDeque;
use std::io::Write;
use std::time::Instant;

use crate::cluster::ids::NodeId;
use crate::cluster::state::ClusterState;
use crate::job::spec::JobSpec;
use crate::util::json::Json;
use crate::util::stats::percentile_sorted;

/// Number of profiled scheduling phases.
pub const PHASE_COUNT: usize = 8;

/// Phase names, indexable by `ObsPhase as usize` (JSON/report keys).
pub const PHASE_NAMES: [&str; PHASE_COUNT] = [
    "adapt", "mold", "prefetch", "plan", "commit", "preempt", "defrag", "fault",
];

/// One profiled phase of the scheduling pipeline.
///
/// `Plan` covers dynamic admission + the placer's plan/score walk;
/// `Commit` the quota charge + lifecycle transition on success. The
/// `Preempt` span wraps a whole escalation (victim selection, eviction,
/// and the retry placement), so its retry's `Plan`/`Commit` time is
/// counted under both — phase columns may overlap; only the cycle
/// wall-clock is additive.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ObsPhase {
    /// Adaptive weight-controller tick (runner, pre-cycle).
    Adapt,
    /// Moldable shape-selection pass.
    Mold,
    /// Superspine-sharded batch prefetch.
    Prefetch,
    /// Dynamic admission + placement planning for one job.
    Plan,
    /// Successful placement commit (charge + lifecycle + dequeue).
    Commit,
    /// A preemption escalation (victims + eviction + retry).
    Preempt,
    /// A defrag round (runner event, folded into the next cycle profile).
    Defrag,
    /// Fault/health delivery (runner event, folded like `Defrag`).
    Fault,
}

/// Wall-clock profile of one scheduling cycle (digest-inert).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CycleProfile {
    /// Simulated time of the cycle.
    pub t_ms: u64,
    /// Per-phase wall-clock nanoseconds (see [`ObsPhase`] for overlap).
    pub phase_ns: [u64; PHASE_COUNT],
    /// Wall-clock nanoseconds of the whole cycle event (adapt + queue walk).
    pub cycle_ns: u64,
    /// Queue depth after the cycle.
    pub queue_depth: u64,
    /// Jobs scheduled this cycle.
    pub scheduled: u64,
    /// Jobs preempted this cycle.
    pub preempted: u64,
}

/// One structured scheduling decision: why a job landed (or did not).
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionRecord {
    pub t_ms: u64,
    pub job: u64,
    /// `scheduled` | `admission-rejected` | `placement-failed` |
    /// `preempted` | `reshaped` | `molded`.
    pub action: String,
    /// Rejection reason, escalation kind, or empty.
    pub reason: String,
    /// Chosen region (`ss2/sp5/g17` from the gang's first node, with a
    /// `+Nn` suffix for the node count); empty when nothing was placed.
    pub region: String,
    /// Distinct nodes in the placement (0 when nothing was placed).
    pub nodes: u64,
    /// Shape-ladder rung in effect (-1 = fixed shape).
    pub shape_rung: i64,
    /// The scorer-facing job descriptor ([`features::job_descriptor`]).
    ///
    /// [`features::job_descriptor`]: crate::rsch::features::job_descriptor
    pub features: Vec<f64>,
    /// Active adaptive weight overlay when the decision was made.
    pub overlay_pack_bias: f64,
    pub overlay_fairness: f64,
}

impl DecisionRecord {
    /// Base record for `spec`: job id, feature vector, shape rung, and
    /// the active overlay. Caller fills `reason`/`region`/`nodes`.
    pub fn for_spec(
        t_ms: u64,
        spec: &JobSpec,
        action: &str,
        overlay: (f64, f64),
    ) -> DecisionRecord {
        DecisionRecord {
            t_ms,
            job: spec.id.0,
            action: action.to_string(),
            reason: String::new(),
            region: String::new(),
            nodes: 0,
            shape_rung: spec.active_shape().map(|k| k as i64).unwrap_or(-1),
            features: job_features(spec),
            overlay_pack_bias: overlay.0,
            overlay_fairness: overlay.1,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut d = Json::obj();
        d.set("kind", "decision")
            .set("t_ms", self.t_ms)
            .set("job", self.job)
            .set("action", self.action.as_str())
            .set("reason", self.reason.as_str())
            .set("region", self.region.as_str())
            .set("nodes", self.nodes)
            .set("shape_rung", self.shape_rung)
            .set("features", self.features.clone())
            .set("overlay_pack_bias", self.overlay_pack_bias)
            .set("overlay_fairness", self.overlay_fairness);
        d
    }

    pub fn from_json(j: &Json) -> Option<DecisionRecord> {
        if j.get("kind").and_then(Json::as_str) != Some("decision") {
            return None;
        }
        Some(DecisionRecord {
            t_ms: j.get("t_ms")?.as_u64()?,
            job: j.get("job")?.as_u64()?,
            action: j.get("action")?.as_str()?.to_string(),
            reason: j.get("reason")?.as_str()?.to_string(),
            region: j.get("region")?.as_str()?.to_string(),
            nodes: j.get("nodes")?.as_u64()?,
            shape_rung: j.get("shape_rung")?.as_f64()? as i64,
            features: j
                .get("features")?
                .as_arr()?
                .iter()
                .map(|x| x.as_f64())
                .collect::<Option<Vec<f64>>>()?,
            overlay_pack_bias: j.get("overlay_pack_bias")?.as_f64()?,
            overlay_fairness: j.get("overlay_fairness")?.as_f64()?,
        })
    }
}

/// Wall-clock summary of one phase across a run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseSummary {
    pub total_ns: u64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
}

/// The per-run scheduler health rollup (digest-inert by construction:
/// every field is wall-clock- or counter-derived and none feeds back
/// into a scheduling branch).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SchedulerHealth {
    /// Profiled scheduling cycles.
    pub cycles: u64,
    /// Total wall-clock ns spent scheduling: cycle events plus the
    /// defrag/fault spans delivered between cycles.
    pub sched_wall_ns: u64,
    /// Per-phase totals/percentiles, indexed like [`PHASE_NAMES`].
    pub phases: [PhaseSummary; PHASE_COUNT],
    pub queue_depth_mean: f64,
    pub queue_depth_max: u64,
    /// Prefetched-plan commits over all `Rsch::place` calls (sharded runs;
    /// 0 when the sequential core never prefetches).
    pub plan_cache_hit_rate: f64,
    /// Mean over prefetch batches of `max shard load / ideal shard load`
    /// (1.0 = perfectly balanced routing; 0 when nothing was prefetched).
    pub shard_imbalance: f64,
    pub nodes_examined: u64,
    pub nodes_scored: u64,
    /// Decision records emitted (0 at verbosity 0).
    pub decisions: u64,
}

impl SchedulerHealth {
    /// Aggregate the raw per-cycle profiles; the RSCH-derived fields
    /// (cache hit rate, imbalance, scoring volume) are filled by the
    /// caller who holds the `RschStats`.
    pub fn from_profiles(profiles: &[CycleProfile]) -> SchedulerHealth {
        let mut h = SchedulerHealth {
            cycles: profiles.len() as u64,
            ..SchedulerHealth::default()
        };
        if profiles.is_empty() {
            return h;
        }
        for p in profiles {
            h.sched_wall_ns += p.cycle_ns
                + p.phase_ns[ObsPhase::Defrag as usize]
                + p.phase_ns[ObsPhase::Fault as usize];
            h.queue_depth_max = h.queue_depth_max.max(p.queue_depth);
            h.queue_depth_mean += p.queue_depth as f64;
        }
        h.queue_depth_mean /= profiles.len() as f64;
        for k in 0..PHASE_COUNT {
            let mut samples: Vec<f64> =
                profiles.iter().map(|p| p.phase_ns[k] as f64).collect();
            samples.sort_by(|a, b| a.partial_cmp(b).expect("ns are finite"));
            h.phases[k] = PhaseSummary {
                total_ns: profiles.iter().map(|p| p.phase_ns[k]).sum(),
                p50_ns: percentile_sorted(&samples, 0.50),
                p95_ns: percentile_sorted(&samples, 0.95),
                p99_ns: percentile_sorted(&samples, 0.99),
            };
        }
        h
    }

    /// Mean wall-clock scheduling nanoseconds per simulated cycle.
    pub fn overhead_ns_per_cycle(&self) -> f64 {
        self.sched_wall_ns as f64 / self.cycles.max(1) as f64
    }

    /// Scheduler-overhead fraction: wall-clock scheduling time per cycle
    /// over the simulated cycle period — the honest counterpart of the
    /// paper's SOR story (how much of each real-time cycle window a
    /// production scheduler would spend deciding).
    pub fn overhead_fraction(&self, cycle_ms: u64) -> f64 {
        self.overhead_ns_per_cycle() / (cycle_ms.max(1) as f64 * 1e6)
    }

    pub fn to_json(&self) -> Json {
        let mut phases = Json::obj();
        for (k, name) in PHASE_NAMES.iter().enumerate() {
            let mut p = Json::obj();
            p.set("total_ns", self.phases[k].total_ns)
                .set("p50_ns", self.phases[k].p50_ns)
                .set("p95_ns", self.phases[k].p95_ns)
                .set("p99_ns", self.phases[k].p99_ns);
            phases.set(name, p);
        }
        let mut d = Json::obj();
        d.set("kind", "health")
            .set("schema", "kant-obs-health-v1")
            .set("cycles", self.cycles)
            .set("sched_wall_ns", self.sched_wall_ns)
            .set("phases", phases)
            .set("queue_depth_mean", self.queue_depth_mean)
            .set("queue_depth_max", self.queue_depth_max)
            .set("plan_cache_hit_rate", self.plan_cache_hit_rate)
            .set("shard_imbalance", self.shard_imbalance)
            .set("nodes_examined", self.nodes_examined)
            .set("nodes_scored", self.nodes_scored)
            .set("decisions", self.decisions);
        d
    }

    pub fn from_json(j: &Json) -> Option<SchedulerHealth> {
        if j.get("kind").and_then(Json::as_str) != Some("health") {
            return None;
        }
        let mut phases = [PhaseSummary::default(); PHASE_COUNT];
        let pj = j.get("phases")?;
        for (k, name) in PHASE_NAMES.iter().enumerate() {
            let p = pj.get(name)?;
            phases[k] = PhaseSummary {
                total_ns: p.get("total_ns")?.as_u64()?,
                p50_ns: p.get("p50_ns")?.as_f64()?,
                p95_ns: p.get("p95_ns")?.as_f64()?,
                p99_ns: p.get("p99_ns")?.as_f64()?,
            };
        }
        Some(SchedulerHealth {
            cycles: j.get("cycles")?.as_u64()?,
            sched_wall_ns: j.get("sched_wall_ns")?.as_u64()?,
            phases,
            queue_depth_mean: j.get("queue_depth_mean")?.as_f64()?,
            queue_depth_max: j.get("queue_depth_max")?.as_u64()?,
            plan_cache_hit_rate: j.get("plan_cache_hit_rate")?.as_f64()?,
            shard_imbalance: j.get("shard_imbalance")?.as_f64()?,
            nodes_examined: j.get("nodes_examined")?.as_u64()?,
            nodes_scored: j.get("nodes_scored")?.as_u64()?,
            decisions: j.get("decisions")?.as_u64()?,
        })
    }
}

/// Recorder tunables.
#[derive(Debug, Clone)]
pub struct ObsConfig {
    pub enabled: bool,
    /// 0 = phase profiles only; 1 = + scheduled/preempted/molded
    /// decisions; 2 = + admission/placement rejections.
    pub verbosity: u8,
    /// Ring-buffer capacity for the stall-diagnostic trace.
    pub trace_capacity: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            enabled: false,
            verbosity: 0,
            trace_capacity: 32,
        }
    }
}

/// The observability recorder threaded through the scheduling core.
pub struct ObsRecorder {
    cfg: ObsConfig,
    /// Phase accumulators for the cycle being profiled.
    cur: [u64; PHASE_COUNT],
    cycle_started: Option<Instant>,
    profiles: Vec<CycleProfile>,
    /// Last-N decisions for the stall diagnostic.
    ring: VecDeque<DecisionRecord>,
    /// Optional JSONL stream (`--obs-out`).
    sink: Option<Box<dyn Write>>,
    decisions: u64,
    overlay: (f64, f64),
}

impl ObsRecorder {
    /// The allocation-free no-op recorder every legacy entry point uses.
    pub fn disabled() -> ObsRecorder {
        ObsRecorder {
            cfg: ObsConfig::default(),
            cur: [0; PHASE_COUNT],
            cycle_started: None,
            profiles: Vec::new(),
            ring: VecDeque::new(),
            sink: None,
            decisions: 0,
            overlay: (0.0, 0.0),
        }
    }

    pub fn enabled(verbosity: u8) -> ObsRecorder {
        ObsRecorder {
            cfg: ObsConfig {
                enabled: true,
                verbosity,
                ..ObsConfig::default()
            },
            ..ObsRecorder::disabled()
        }
    }

    /// Attach a JSONL sink; every decision streams out as one line and
    /// the health rollup goes out as the trailer line.
    pub fn with_sink(mut self, sink: Box<dyn Write>) -> ObsRecorder {
        self.sink = Some(sink);
        self
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// Should a decision at `level` be recorded? Callers gate feature
    /// extraction behind this so disabled runs pay one bool check.
    #[inline]
    pub fn wants(&self, level: u8) -> bool {
        self.cfg.enabled && self.cfg.verbosity >= level
    }

    /// Publish the active weight overlay (runner, once per cycle) so
    /// decision records can snapshot it.
    pub fn set_overlay(&mut self, pack_bias: f64, fairness: f64) {
        self.overlay = (pack_bias, fairness);
    }

    pub fn overlay(&self) -> (f64, f64) {
        self.overlay
    }

    /// Open a span. Returns `None` when disabled — `span_end` then does
    /// no work, so instrumentation sites stay branch-cheap.
    #[inline]
    pub fn span(&self) -> Option<Instant> {
        if self.cfg.enabled {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Close a span, folding its wall-clock time into the current cycle.
    #[inline]
    pub fn span_end(&mut self, phase: ObsPhase, started: Option<Instant>) {
        if let Some(t) = started {
            self.cur[phase as usize] += t.elapsed().as_nanos() as u64;
        }
    }

    /// Start profiling a cycle event (runner, before the adapt tick).
    pub fn begin_cycle(&mut self) {
        if self.cfg.enabled {
            self.cycle_started = Some(Instant::now());
        }
    }

    /// Close the cycle profile. Defrag/fault spans delivered *between*
    /// cycles accumulate in the same buffers and roll into the next
    /// cycle's profile (their time is outside `cycle_ns` either way).
    pub fn end_cycle(&mut self, t_ms: u64, queue_depth: u64, scheduled: u64, preempted: u64) {
        let Some(started) = self.cycle_started.take() else {
            return;
        };
        self.profiles.push(CycleProfile {
            t_ms,
            phase_ns: self.cur,
            cycle_ns: started.elapsed().as_nanos() as u64,
            queue_depth,
            scheduled,
            preempted,
        });
        self.cur = [0; PHASE_COUNT];
    }

    /// Record one decision at `level` (see [`ObsConfig::verbosity`]):
    /// ring-buffered for the stall diagnostic and streamed to the JSONL
    /// sink when one is attached.
    pub fn record(&mut self, level: u8, rec: DecisionRecord) {
        if !self.wants(level) {
            return;
        }
        self.decisions += 1;
        if let Some(sink) = self.sink.as_mut() {
            let _ = writeln!(sink, "{}", rec.to_json().to_string_compact());
        }
        if self.ring.len() >= self.cfg.trace_capacity {
            self.ring.pop_front();
        }
        self.ring.push_back(rec);
    }

    /// The last-N decisions (oldest first) — the stall-diagnostic dump.
    pub fn recent(&self) -> impl Iterator<Item = &DecisionRecord> {
        self.ring.iter()
    }

    pub fn decisions(&self) -> u64 {
        self.decisions
    }

    pub fn profiles(&self) -> &[CycleProfile] {
        &self.profiles
    }

    /// Roll the profiles up; RSCH-derived fields are the caller's to fill.
    pub fn health(&self) -> SchedulerHealth {
        let mut h = SchedulerHealth::from_profiles(&self.profiles);
        h.decisions = self.decisions;
        h
    }

    /// Write the health rollup as the JSONL trailer line and flush.
    pub fn write_trailer(&mut self, health: &SchedulerHealth) {
        if let Some(sink) = self.sink.as_mut() {
            let _ = writeln!(sink, "{}", health.to_json().to_string_compact());
            let _ = sink.flush();
        }
    }
}

/// The scorer-facing feature vector for a decision record — the same
/// descriptor RSCH hands its linear/XLA scorer, widened to f64 for JSON.
pub fn job_features(spec: &JobSpec) -> Vec<f64> {
    crate::rsch::features::job_descriptor(spec, spec.gpus_per_replica())
        .iter()
        .map(|&x| f64::from(x))
        .collect()
}

/// Human-readable region label for a placement: superspine / spine /
/// group of the first node, plus the distinct-node count.
pub fn region_label(state: &ClusterState, nodes: &[NodeId]) -> String {
    let Some(&first) = nodes.first() else {
        return String::new();
    };
    let g = state.fabric.group_of(first);
    format!(
        "ss{}/sp{}/g{}+{}n",
        state.fabric.superspine_of(first).0,
        state.fabric.spine_of(first).0,
        g.0,
        nodes.len(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_record() -> DecisionRecord {
        DecisionRecord {
            t_ms: 5_000,
            job: 42,
            action: "scheduled".to_string(),
            reason: "backfill".to_string(),
            region: "ss0/sp1/g2+4n".to_string(),
            nodes: 4,
            shape_rung: 1,
            features: vec![2.0, 16.0, 1.0, 0.25],
            overlay_pack_bias: 0.125,
            overlay_fairness: -0.5,
        }
    }

    #[test]
    fn decision_record_roundtrips_through_jsonl() {
        let rec = sample_record();
        let line = rec.to_json().to_string_compact();
        let back = DecisionRecord::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(rec, back);
        // A health line is not a decision.
        let h = SchedulerHealth::default().to_json().to_string_compact();
        assert!(DecisionRecord::from_json(&Json::parse(&h).unwrap()).is_none());
    }

    #[test]
    fn health_roundtrips_through_jsonl() {
        let mut profiles = Vec::new();
        for i in 0..10u64 {
            let mut phase_ns = [0u64; PHASE_COUNT];
            phase_ns[ObsPhase::Plan as usize] = 1_000 * (i + 1);
            phase_ns[ObsPhase::Defrag as usize] = 37;
            profiles.push(CycleProfile {
                t_ms: i * 5_000,
                phase_ns,
                cycle_ns: 2_000 * (i + 1),
                queue_depth: i,
                scheduled: 1,
                preempted: 0,
            });
        }
        let mut h = SchedulerHealth::from_profiles(&profiles);
        h.plan_cache_hit_rate = 0.75;
        h.shard_imbalance = 1.25;
        h.nodes_examined = 9_001;
        h.nodes_scored = 5_000;
        h.decisions = 12;
        let line = h.to_json().to_string_compact();
        let back = SchedulerHealth::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(h, back);
    }

    #[test]
    fn health_rollup_math() {
        let mut phase_ns = [0u64; PHASE_COUNT];
        phase_ns[ObsPhase::Fault as usize] = 10;
        let profiles = [
            CycleProfile {
                t_ms: 0,
                phase_ns,
                cycle_ns: 100,
                queue_depth: 4,
                ..CycleProfile::default()
            },
            CycleProfile {
                t_ms: 5_000,
                phase_ns: [0; PHASE_COUNT],
                cycle_ns: 300,
                queue_depth: 8,
                ..CycleProfile::default()
            },
        ];
        let h = SchedulerHealth::from_profiles(&profiles);
        assert_eq!(h.cycles, 2);
        // Fault spans count toward the scheduling wall clock.
        assert_eq!(h.sched_wall_ns, 100 + 300 + 10);
        assert_eq!(h.queue_depth_max, 8);
        assert!((h.queue_depth_mean - 6.0).abs() < 1e-9);
        assert!((h.overhead_ns_per_cycle() - 205.0).abs() < 1e-9);
        // 205 ns per 5 s simulated cycle.
        assert!((h.overhead_fraction(5_000) - 205.0 / 5e9).abs() < 1e-15);
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let mut obs = ObsRecorder::disabled();
        obs.begin_cycle();
        let t = obs.span();
        assert!(t.is_none());
        obs.span_end(ObsPhase::Plan, t);
        obs.end_cycle(0, 9, 1, 0);
        obs.record(1, sample_record());
        assert!(obs.profiles().is_empty());
        assert_eq!(obs.decisions(), 0);
        assert_eq!(obs.recent().count(), 0);
    }

    #[test]
    fn verbosity_gates_decision_levels() {
        let mut obs = ObsRecorder::enabled(1);
        obs.record(1, sample_record());
        obs.record(2, sample_record()); // Rejection detail: suppressed.
        assert_eq!(obs.decisions(), 1);
        assert!(obs.wants(1) && !obs.wants(2));
    }

    #[test]
    fn ring_buffer_keeps_last_n() {
        let mut obs = ObsRecorder::enabled(1);
        for i in 0..100u64 {
            let rec = DecisionRecord {
                job: i,
                ..sample_record()
            };
            obs.record(1, rec);
        }
        let jobs: Vec<u64> = obs.recent().map(|r| r.job).collect();
        assert_eq!(jobs.len(), ObsConfig::default().trace_capacity);
        assert_eq!(*jobs.last().unwrap(), 99);
        assert_eq!(jobs[0], 100 - jobs.len() as u64);
    }
}
