//! Jobs: specifications, lifecycle state, synthetic workload generation
//! (Figure-2 calibrated) and JSONL trace record/replay.

pub mod spec;
pub mod state;
pub mod store;
pub mod trace;
pub mod workload;

pub use spec::{JobKind, JobSpec, PlacementStrategy, Priority, TypedDemand};
pub use state::{Job, Phase};
pub use store::JobStore;
pub use workload::{distribution_report, with_strategy, WorkloadConfig, WorkloadGen};
