//! In-memory job table shared by QSCH, RSCH and the simulator.

use std::collections::BTreeMap;

use crate::cluster::ids::JobId;

use super::state::{Job, Phase};

/// All jobs known to the system, keyed by id.
///
/// A `BTreeMap` rather than a `HashMap`: [`JobStore::iter`] and
/// [`JobStore::holding_resources`] feed digest-affecting consumers
/// (preemption candidate collection, elastic tidal sums, the runner's
/// liveness accounting), so traversal must be in stable id order —
/// hash order would leak `RandomState` into scheduling decisions.
#[derive(Debug, Default)]
pub struct JobStore {
    jobs: BTreeMap<JobId, Job>,
}

impl JobStore {
    pub fn new() -> JobStore {
        JobStore::default()
    }

    pub fn insert(&mut self, job: Job) {
        let id = job.id();
        let prev = self.jobs.insert(id, job);
        debug_assert!(prev.is_none(), "job {id} inserted twice");
    }

    pub fn get(&self, id: JobId) -> Option<&Job> {
        self.jobs.get(&id)
    }

    pub fn get_mut(&mut self, id: JobId) -> Option<&mut Job> {
        self.jobs.get_mut(&id)
    }

    /// Panic-on-missing accessors for internal invariants.
    pub fn expect(&self, id: JobId) -> &Job {
        self.jobs.get(&id).unwrap_or_else(|| panic!("unknown job {id}"))
    }

    pub fn expect_mut(&mut self, id: JobId) -> &mut Job {
        self.jobs.get_mut(&id).unwrap_or_else(|| panic!("unknown job {id}"))
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// All jobs, in ascending id order (deterministic traversal).
    pub fn iter(&self) -> impl Iterator<Item = &Job> {
        self.jobs.values()
    }

    /// Jobs currently holding resources (Scheduled or Running), in
    /// ascending id order.
    pub fn holding_resources(&self) -> impl Iterator<Item = &Job> {
        self.jobs.values().filter(|j| j.holds_resources())
    }

    pub fn count_in_phase(&self, phase: Phase) -> usize {
        self.jobs.values().filter(|j| j.phase == phase).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ids::{GpuTypeId, TenantId};
    use crate::job::spec::{JobKind, JobSpec};

    fn mk(id: u64) -> Job {
        Job::new(JobSpec::homogeneous(
            JobId(id),
            TenantId(0),
            JobKind::Dev,
            GpuTypeId(0),
            1,
            1,
        ))
    }

    #[test]
    fn insert_get_iter() {
        let mut s = JobStore::new();
        s.insert(mk(1));
        s.insert(mk(2));
        assert_eq!(s.len(), 2);
        assert!(s.get(JobId(1)).is_some());
        assert!(s.get(JobId(3)).is_none());
        assert_eq!(s.iter().count(), 2);
    }

    #[test]
    fn phase_counting() {
        let mut s = JobStore::new();
        s.insert(mk(1));
        s.insert(mk(2));
        s.expect_mut(JobId(1)).mark_admitted();
        s.expect_mut(JobId(1)).mark_scheduled(10);
        assert_eq!(s.count_in_phase(Phase::Queued), 1);
        assert_eq!(s.count_in_phase(Phase::Scheduled), 1);
        assert_eq!(s.holding_resources().count(), 1);
    }
}
