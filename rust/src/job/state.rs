//! Job lifecycle state machine and the bookkeeping the metrics need.
//!
//! ```text
//! Submitted ─▶ Queued ─▶ Admitted ─▶ Scheduled ─▶ Running ─▶ Finished
//!     ▲           ▲                      │
//!     └───────────┴──── Requeued ◀──────┴── (preempted / failed)
//! ```
//!
//! JWTD measures Submitted→Scheduled; SOR accrues from Scheduled (resource
//! binding) even before Running (§4.2's image-pull window).

use super::spec::JobSpec;
use crate::cluster::ids::JobId;

/// Lifecycle phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Known to QSCH, not yet past admission.
    Queued,
    /// Passed static + dynamic admission, waiting for RSCH.
    Admitted,
    /// Resources bound (SOR accrual starts here).
    Scheduled,
    /// Containers up (after platform overhead).
    Running,
    Finished,
    /// Evicted by preemption; will requeue.
    Preempted,
}

/// A job plus its runtime state.
#[derive(Debug, Clone)]
pub struct Job {
    pub spec: JobSpec,
    pub phase: Phase,
    /// First time QSCH saw the job.
    pub submit_ms: u64,
    /// When resources were bound (last successful scheduling).
    pub scheduled_ms: Option<u64>,
    /// When containers started running.
    pub running_ms: Option<u64>,
    /// When the job finished.
    pub finished_ms: Option<u64>,
    /// Number of preemptions suffered.
    pub preemptions: u32,
    /// Number of defragmentation migrations (§3.3.3 reorganization).
    pub migrations: u32,
    /// Event epoch: bumped by preemption AND migration; stale simulator
    /// events (RunningStart/Finish scheduled under an older epoch) are
    /// dropped on delivery.
    pub epoch: u32,
    /// Number of requeue events (scheduling failures).
    pub requeues: u32,
    /// Remaining work (ms of runtime still owed); preemption pauses it.
    pub remaining_ms: u64,
    /// Whether the job was scheduled by bypassing a blocked queue head
    /// (Backfill) — such jobs are the preferred victims of backfill
    /// preemption (§3.2.2/§3.2.3).
    pub backfilled: bool,
}

impl Job {
    pub fn new(spec: JobSpec) -> Job {
        let submit_ms = spec.submit_ms;
        let remaining_ms = spec.duration_ms;
        Job {
            spec,
            phase: Phase::Queued,
            submit_ms,
            scheduled_ms: None,
            running_ms: None,
            finished_ms: None,
            preemptions: 0,
            migrations: 0,
            epoch: 0,
            requeues: 0,
            remaining_ms,
            backfilled: false,
        }
    }

    pub fn id(&self) -> JobId {
        self.spec.id
    }

    /// Waiting time as JWTD defines it: submission → scheduling start
    /// (resource binding). For jobs never scheduled, `now` gives the
    /// censored value.
    pub fn waiting_ms(&self, now: u64) -> u64 {
        match self.scheduled_ms {
            Some(t) => t.saturating_sub(self.submit_ms),
            None => now.saturating_sub(self.submit_ms),
        }
    }

    pub fn mark_admitted(&mut self) {
        debug_assert!(matches!(self.phase, Phase::Queued | Phase::Preempted));
        self.phase = Phase::Admitted;
    }

    pub fn mark_scheduled(&mut self, now: u64) {
        debug_assert!(matches!(self.phase, Phase::Admitted | Phase::Queued));
        // JWTD counts until FIRST successful scheduling; keep the earliest.
        if self.scheduled_ms.is_none() {
            self.scheduled_ms = Some(now);
        }
        self.phase = Phase::Scheduled;
    }

    pub fn mark_running(&mut self, now: u64) {
        debug_assert_eq!(self.phase, Phase::Scheduled);
        self.running_ms = Some(now);
        self.phase = Phase::Running;
    }

    pub fn mark_finished(&mut self, now: u64) {
        self.finished_ms = Some(now);
        self.remaining_ms = 0;
        self.phase = Phase::Finished;
    }

    /// Preempt at `now`, crediting completed runtime.
    pub fn mark_preempted(&mut self, now: u64) {
        if let Some(start) = self.running_ms {
            let ran = now.saturating_sub(start);
            self.remaining_ms = self.remaining_ms.saturating_sub(ran);
        }
        self.preemptions += 1;
        self.epoch += 1;
        self.phase = Phase::Preempted;
        self.running_ms = None;
    }

    /// Defragmentation migration (§3.3.3): the pod restarts elsewhere with
    /// a service interruption of `penalty_ms`. The job stays Running; its
    /// progress is credited and the penalty added to the remaining work.
    pub fn mark_migrated(&mut self, now: u64, penalty_ms: u64) {
        debug_assert_eq!(self.phase, Phase::Running);
        if let Some(start) = self.running_ms {
            let ran = now.saturating_sub(start);
            self.remaining_ms = self.remaining_ms.saturating_sub(ran);
        }
        self.remaining_ms += penalty_ms;
        self.running_ms = Some(now);
        self.migrations += 1;
        self.epoch += 1;
    }

    /// Return to the queue after preemption or scheduling failure.
    pub fn mark_requeued(&mut self) {
        debug_assert!(matches!(
            self.phase,
            Phase::Preempted | Phase::Admitted | Phase::Queued
        ));
        self.requeues += 1;
        self.phase = Phase::Queued;
    }

    pub fn is_terminal(&self) -> bool {
        self.phase == Phase::Finished
    }

    /// Holds resources (bound or running)?
    pub fn holds_resources(&self) -> bool {
        matches!(self.phase, Phase::Scheduled | Phase::Running)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ids::{GpuTypeId, TenantId};
    use crate::job::spec::JobKind;

    fn job() -> Job {
        let spec = crate::job::spec::JobSpec::homogeneous(
            JobId(1),
            TenantId(0),
            JobKind::Training,
            GpuTypeId(0),
            2,
            8,
        )
        .with_times(100, 5_000);
        Job::new(spec)
    }

    #[test]
    fn happy_path_lifecycle() {
        let mut j = job();
        assert_eq!(j.phase, Phase::Queued);
        j.mark_admitted();
        j.mark_scheduled(250);
        assert_eq!(j.waiting_ms(9999), 150);
        j.mark_running(300);
        assert!(j.holds_resources());
        j.mark_finished(5_300);
        assert!(j.is_terminal());
        assert_eq!(j.remaining_ms, 0);
    }

    #[test]
    fn waiting_time_censored_until_scheduled() {
        let j = job();
        assert_eq!(j.waiting_ms(600), 500);
    }

    #[test]
    fn preemption_credits_progress_and_requeues() {
        let mut j = job();
        j.mark_admitted();
        j.mark_scheduled(200);
        j.mark_running(200);
        j.mark_preempted(2_200); // Ran 2s of 5s.
        assert_eq!(j.remaining_ms, 3_000);
        assert_eq!(j.preemptions, 1);
        assert!(!j.holds_resources());
        j.mark_requeued();
        assert_eq!(j.phase, Phase::Queued);
        assert_eq!(j.requeues, 1);
        // Rescheduling keeps the original scheduled_ms for JWTD.
        j.mark_admitted();
        j.mark_scheduled(3_000);
        assert_eq!(j.scheduled_ms, Some(200));
    }

    #[test]
    fn preempt_before_running_keeps_full_remaining() {
        let mut j = job();
        j.mark_admitted();
        j.mark_scheduled(200);
        j.mark_preempted(400);
        assert_eq!(j.remaining_ms, 5_000);
    }
}
