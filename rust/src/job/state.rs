//! Job lifecycle state machine and the bookkeeping the metrics need.
//!
//! ```text
//! Submitted ─▶ Queued ─▶ Admitted ─▶ Scheduled ─▶ Running ─▶ Finished
//!     ▲           ▲                      │
//!     └───────────┴──── Requeued ◀──────┴── (preempted / failed)
//! ```
//!
//! JWTD measures Submitted→Scheduled; SOR accrues from Scheduled (resource
//! binding) even before Running (§4.2's image-pull window).

use super::spec::{CheckpointPolicy, JobSpec};
use crate::cluster::ids::JobId;

/// Lifecycle phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Known to QSCH, not yet past admission.
    Queued,
    /// Passed static + dynamic admission, waiting for RSCH.
    Admitted,
    /// Resources bound (SOR accrual starts here).
    Scheduled,
    /// Containers up (after platform overhead).
    Running,
    Finished,
    /// Evicted by preemption; will requeue.
    Preempted,
}

/// A job plus its runtime state.
#[derive(Debug, Clone)]
pub struct Job {
    pub spec: JobSpec,
    pub phase: Phase,
    /// First time QSCH saw the job.
    pub submit_ms: u64,
    /// When resources were bound (last successful scheduling).
    pub scheduled_ms: Option<u64>,
    /// When containers started running.
    pub running_ms: Option<u64>,
    /// When the job finished.
    pub finished_ms: Option<u64>,
    /// Number of preemptions suffered.
    pub preemptions: u32,
    /// Number of defragmentation migrations (§3.3.3 reorganization).
    pub migrations: u32,
    /// Event epoch: bumped by preemption AND migration; stale simulator
    /// events (RunningStart/Finish scheduled under an older epoch) are
    /// dropped on delivery.
    pub epoch: u32,
    /// Number of requeue events (scheduling failures).
    pub requeues: u32,
    /// Number of malleable shape changes (moldable admission downgrades
    /// plus runtime shrinks) this job has gone through.
    pub shape_changes: u32,
    /// Remaining work (ms of runtime still owed); preemption pauses it.
    pub remaining_ms: u64,
    /// Completed work (ms) persisted by the last checkpoint — what an
    /// eviction rolls back to under `CheckpointPolicy::Interval`.
    pub checkpointed_ms: u64,
    /// Cumulative work (ms) discarded by evictions: the gap between
    /// progress at eviction time and the restart point the checkpoint
    /// policy allows. Feeds `ReliabilityTelemetry`'s lost GPU-hours.
    pub lost_work_ms: u64,
    /// Whether the job was scheduled by bypassing a blocked queue head
    /// (Backfill) — such jobs are the preferred victims of backfill
    /// preemption (§3.2.2/§3.2.3).
    pub backfilled: bool,
}

impl Job {
    pub fn new(spec: JobSpec) -> Job {
        let submit_ms = spec.submit_ms;
        let remaining_ms = spec.duration_ms;
        Job {
            spec,
            phase: Phase::Queued,
            submit_ms,
            scheduled_ms: None,
            running_ms: None,
            finished_ms: None,
            preemptions: 0,
            migrations: 0,
            epoch: 0,
            requeues: 0,
            shape_changes: 0,
            remaining_ms,
            checkpointed_ms: 0,
            lost_work_ms: 0,
            backfilled: false,
        }
    }

    pub fn id(&self) -> JobId {
        self.spec.id
    }

    /// Waiting time as JWTD defines it: submission → scheduling start
    /// (resource binding). For jobs never scheduled, `now` gives the
    /// censored value.
    pub fn waiting_ms(&self, now: u64) -> u64 {
        match self.scheduled_ms {
            Some(t) => t.saturating_sub(self.submit_ms),
            None => now.saturating_sub(self.submit_ms),
        }
    }

    pub fn mark_admitted(&mut self) {
        debug_assert!(matches!(self.phase, Phase::Queued | Phase::Preempted));
        self.phase = Phase::Admitted;
    }

    pub fn mark_scheduled(&mut self, now: u64) {
        debug_assert!(matches!(self.phase, Phase::Admitted | Phase::Queued));
        // JWTD counts until FIRST successful scheduling; keep the earliest.
        if self.scheduled_ms.is_none() {
            self.scheduled_ms = Some(now);
        }
        self.phase = Phase::Scheduled;
    }

    pub fn mark_running(&mut self, now: u64) {
        debug_assert_eq!(self.phase, Phase::Scheduled);
        self.running_ms = Some(now);
        self.phase = Phase::Running;
    }

    pub fn mark_finished(&mut self, now: u64) {
        self.finished_ms = Some(now);
        self.remaining_ms = 0;
        self.phase = Phase::Finished;
    }

    /// Completed work (ms) at time `now` for a Running job, derived from
    /// what would still be owed under ideal checkpointing. Migration
    /// penalties inflate `remaining_ms`, so a penalized run segment first
    /// pays the penalty debt before it counts as completed work —
    /// consistent with how the simulator charges the interruption.
    fn completed_at(&self, now: u64) -> u64 {
        let ran = self
            .running_ms
            .map(|start| now.saturating_sub(start))
            .unwrap_or(0);
        self.spec
            .duration_ms
            .saturating_sub(self.remaining_ms.saturating_sub(ran))
    }

    /// Persist progress at a checkpoint tick: everything completed up to
    /// `now` survives future evictions (`CheckpointPolicy::Interval`).
    pub fn mark_checkpoint(&mut self, now: u64) {
        if self.phase == Phase::Running {
            self.checkpointed_ms = self.checkpointed_ms.max(self.completed_at(now));
        }
    }

    /// Preempt at `now`. How much completed runtime survives depends on
    /// the spec's [`CheckpointPolicy`]: `Continuous` keeps everything
    /// (the legacy semantics, byte-for-byte — including any outstanding
    /// migration-penalty debt above `duration_ms`), `Interval` rolls back
    /// to the last `mark_checkpoint`, `None` restarts from scratch. The
    /// work re-added relative to ideal checkpointing accrues
    /// `lost_work_ms`.
    pub fn mark_preempted(&mut self, now: u64) {
        if let Some(start) = self.running_ms {
            let ran = now.saturating_sub(start);
            // Owed under ideal (continuous) checkpointing; may exceed
            // duration_ms while a migration penalty is outstanding, and
            // that debt survives the restart under every policy.
            let owed_ideal = self.remaining_ms.saturating_sub(ran);
            let done = self.spec.duration_ms.saturating_sub(owed_ideal);
            let kept = match self.spec.checkpoint {
                CheckpointPolicy::Continuous => done,
                CheckpointPolicy::Interval(_) => self.checkpointed_ms.min(done),
                CheckpointPolicy::None => 0,
            };
            let owed_new = (self.spec.duration_ms - kept).max(owed_ideal);
            self.lost_work_ms += owed_new - owed_ideal;
            self.remaining_ms = owed_new;
        }
        self.preemptions += 1;
        self.epoch += 1;
        self.phase = Phase::Preempted;
        self.running_ms = None;
    }

    /// Moldable/malleable shape change from throughput `thr_old` to
    /// `thr_new` — a coordinated re-shard, NOT an eviction. Progress of a
    /// running segment is credited first, then the remaining wall-clock
    /// is rescaled to the new shape's relative throughput (half the
    /// throughput = twice the wall-clock still owed). Unlike
    /// [`Job::mark_preempted`], no checkpoint rollback applies and
    /// `lost_work_ms` does not grow: malleable frameworks re-shard from
    /// live state. A resource-holding job moves to `Preempted` so the
    /// caller can release + requeue it at the new shape; a queued job
    /// (moldable admission) just has its owed wall-clock rescaled.
    pub fn mark_reshaped(&mut self, now: u64, thr_old: f64, thr_new: f64) {
        if let Some(start) = self.running_ms {
            let ran = now.saturating_sub(start);
            self.remaining_ms = self.remaining_ms.saturating_sub(ran);
        }
        let scale = thr_old.max(1e-9) / thr_new.max(1e-9);
        self.remaining_ms = ((self.remaining_ms as f64) * scale).ceil() as u64;
        self.shape_changes += 1;
        if self.holds_resources() {
            self.epoch += 1;
            self.phase = Phase::Preempted;
            self.running_ms = None;
        }
    }

    /// Defragmentation migration (§3.3.3): the pod restarts elsewhere with
    /// a service interruption of `penalty_ms`. The job stays Running; its
    /// progress is credited and the penalty added to the remaining work.
    pub fn mark_migrated(&mut self, now: u64, penalty_ms: u64) {
        debug_assert_eq!(self.phase, Phase::Running);
        if let Some(start) = self.running_ms {
            let ran = now.saturating_sub(start);
            self.remaining_ms = self.remaining_ms.saturating_sub(ran);
        }
        self.remaining_ms += penalty_ms;
        self.running_ms = Some(now);
        self.migrations += 1;
        self.epoch += 1;
    }

    /// Return to the queue after preemption or scheduling failure.
    pub fn mark_requeued(&mut self) {
        debug_assert!(matches!(
            self.phase,
            Phase::Preempted | Phase::Admitted | Phase::Queued
        ));
        self.requeues += 1;
        self.phase = Phase::Queued;
    }

    pub fn is_terminal(&self) -> bool {
        self.phase == Phase::Finished
    }

    /// Holds resources (bound or running)?
    pub fn holds_resources(&self) -> bool {
        matches!(self.phase, Phase::Scheduled | Phase::Running)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ids::{GpuTypeId, TenantId};
    use crate::job::spec::JobKind;

    fn job() -> Job {
        let spec = crate::job::spec::JobSpec::homogeneous(
            JobId(1),
            TenantId(0),
            JobKind::Training,
            GpuTypeId(0),
            2,
            8,
        )
        .with_times(100, 5_000);
        Job::new(spec)
    }

    #[test]
    fn happy_path_lifecycle() {
        let mut j = job();
        assert_eq!(j.phase, Phase::Queued);
        j.mark_admitted();
        j.mark_scheduled(250);
        assert_eq!(j.waiting_ms(9999), 150);
        j.mark_running(300);
        assert!(j.holds_resources());
        j.mark_finished(5_300);
        assert!(j.is_terminal());
        assert_eq!(j.remaining_ms, 0);
    }

    #[test]
    fn waiting_time_censored_until_scheduled() {
        let j = job();
        assert_eq!(j.waiting_ms(600), 500);
    }

    #[test]
    fn preemption_credits_progress_and_requeues() {
        let mut j = job();
        j.mark_admitted();
        j.mark_scheduled(200);
        j.mark_running(200);
        j.mark_preempted(2_200); // Ran 2s of 5s.
        assert_eq!(j.remaining_ms, 3_000);
        assert_eq!(j.preemptions, 1);
        assert!(!j.holds_resources());
        j.mark_requeued();
        assert_eq!(j.phase, Phase::Queued);
        assert_eq!(j.requeues, 1);
        // Rescheduling keeps the original scheduled_ms for JWTD.
        j.mark_admitted();
        j.mark_scheduled(3_000);
        assert_eq!(j.scheduled_ms, Some(200));
    }

    #[test]
    fn preempt_before_running_keeps_full_remaining() {
        let mut j = job();
        j.mark_admitted();
        j.mark_scheduled(200);
        j.mark_preempted(400);
        assert_eq!(j.remaining_ms, 5_000);
        assert_eq!(j.lost_work_ms, 0);
    }

    #[test]
    fn preemption_after_migration_keeps_penalty_debt() {
        // A migration penalty can push remaining_ms above duration_ms;
        // a following preemption must not forgive the debt (legacy
        // Continuous semantics) nor count it as lost work.
        let mut j = job();
        j.mark_admitted();
        j.mark_scheduled(200);
        j.mark_running(200);
        j.mark_migrated(300, 2_000); // Ran 100ms, owes 4_900 + 2_000.
        assert_eq!(j.remaining_ms, 6_900);
        j.mark_preempted(400); // Another 100ms ran, paying down penalty.
        assert_eq!(j.remaining_ms, 6_800);
        assert_eq!(j.lost_work_ms, 0);
    }

    #[test]
    fn reshape_rescales_wall_clock_without_losing_work() {
        // Queued job molded at admission to a half-throughput shape: the
        // owed wall-clock doubles, nothing else changes.
        let mut q = job();
        q.mark_reshaped(0, 1.0, 0.5);
        assert_eq!(q.phase, Phase::Queued);
        assert_eq!(q.remaining_ms, 10_000);
        assert_eq!(q.shape_changes, 1);
        assert_eq!(q.lost_work_ms, 0);

        // Running job shrunk mid-flight: the 2s already run are credited,
        // the remaining 3s rescale to 6s at half throughput, and the job
        // is handed back for requeue — with zero lost work (contrast
        // `naive_restart_loses_all_progress`).
        let mut j = job();
        j.spec = j.spec.clone().with_checkpoint(crate::job::spec::CheckpointPolicy::None);
        j.mark_admitted();
        j.mark_scheduled(200);
        j.mark_running(200);
        j.mark_reshaped(2_200, 1.0, 0.5);
        assert_eq!(j.remaining_ms, 6_000);
        assert_eq!(j.phase, Phase::Preempted);
        assert_eq!(j.lost_work_ms, 0);
        assert_eq!(j.preemptions, 0);
        assert_eq!(j.shape_changes, 1);
        j.mark_requeued();
        assert_eq!(j.phase, Phase::Queued);
    }

    #[test]
    fn naive_restart_loses_all_progress() {
        let mut j = job();
        j.spec = j.spec.clone().with_checkpoint(crate::job::spec::CheckpointPolicy::None);
        j.mark_admitted();
        j.mark_scheduled(200);
        j.mark_running(200);
        j.mark_preempted(2_200); // Ran 2s of 5s — all of it discarded.
        assert_eq!(j.remaining_ms, 5_000);
        assert_eq!(j.lost_work_ms, 2_000);
    }

    #[test]
    fn interval_checkpoint_rolls_back_to_last_tick() {
        let mut j = job();
        j.spec = j
            .spec
            .clone()
            .with_checkpoint(crate::job::spec::CheckpointPolicy::Interval(1_000));
        j.mark_admitted();
        j.mark_scheduled(0);
        j.mark_running(0);
        j.mark_checkpoint(1_000);
        j.mark_checkpoint(2_000);
        assert_eq!(j.checkpointed_ms, 2_000);
        j.mark_preempted(2_700); // 700ms since the last tick is lost.
        assert_eq!(j.remaining_ms, 3_000);
        assert_eq!(j.lost_work_ms, 700);
        // The restart resumes from the checkpoint and keeps accruing.
        j.mark_requeued();
        j.mark_admitted();
        j.mark_scheduled(5_000);
        j.mark_running(5_000);
        j.mark_checkpoint(6_000); // 2s checkpointed + 1s more run.
        assert_eq!(j.checkpointed_ms, 3_000);
        j.mark_preempted(6_500);
        assert_eq!(j.remaining_ms, 2_000);
        assert_eq!(j.lost_work_ms, 700 + 500);
    }
}
