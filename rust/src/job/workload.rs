//! Synthetic workload generation matching the paper's §5.1.1 job
//! characteristics (Figure 2): job sizes span 1–2048 GPUs, **over 90 % of
//! jobs request ≤ 8 GPUs**, yet **jobs of ≥ 256 GPUs consume more than half
//! of all GPU-time** — small jobs' cumulative GPU-time is under 10 %.
//!
//! The generator is fully deterministic given a seed and can be calibrated
//! to a target offered load against a cluster's capacity.

use crate::cluster::ids::{GpuTypeId, JobId, TenantId};
use crate::util::rng::Pcg32;

use super::spec::{
    ElasticService, GangShape, JobKind, JobSpec, PlacementStrategy, Priority, TypedDemand,
};

/// One size class of the Figure-2 distribution.
#[derive(Debug, Clone, Copy)]
pub struct SizeClass {
    pub gpus: u32,
    /// Relative job-count weight.
    pub weight: f64,
    /// Mean duration (hours) for this class; durations are log-normal
    /// around this mean (large jobs run much longer — that is what makes
    /// their GPU-time share dominate).
    pub mean_hours: f64,
}

/// Workload shape parameters.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub seed: u64,
    /// Size-class mix (defaults to the Figure-2 calibration).
    pub classes: Vec<SizeClass>,
    /// Mean job inter-arrival time in ms (Poisson process).
    pub mean_interarrival_ms: f64,
    /// Tenants to spread jobs across (round-robin weighting by rng).
    pub num_tenants: u32,
    /// Per-tenant demand weights (empty = uniform). Length must equal
    /// `num_tenants` when set — lets quota profiles match demand (Fig. 10).
    pub tenant_weights: Vec<f64>,
    /// Fraction of jobs that are training (gang); the rest split between
    /// inference and dev.
    pub training_frac: f64,
    pub inference_frac: f64,
    /// GPU model for generated jobs (single-pool workloads).
    pub gpu_type: GpuTypeId,
    /// GPUs per node in the target cluster (pods are sized to boards).
    pub gpus_per_node: u32,
    /// Heterogeneous demand mix: (gpu_type, weight, gpus_per_node). When
    /// non-empty this overrides `gpu_type`/`gpus_per_node`, sampling a
    /// model per job — multi-pool clusters need demand in every pool.
    pub type_mix: Vec<(GpuTypeId, f64, u32)>,
    /// Log-normal sigma for durations.
    pub duration_sigma: f64,
    /// Fraction of HIGH-priority jobs; equal share of LOW; rest NORMAL.
    pub high_priority_frac: f64,
    /// Cap sizes at this many GPUs (small clusters); 0 = uncapped.
    pub max_gpus: u32,
    /// Fraction of inference services generated as *elastic* replica
    /// sets (single-GPU replicas, diurnal demand curve with per-service
    /// phase/amplitude drawn from the seeded RNG). 0 = classic static
    /// services (all pre-elastic presets are unchanged).
    pub elastic_frac: f64,
    /// Fraction of multi-replica training gangs that declare a *moldable*
    /// shape ladder (halving replica counts with sub-linear per-step
    /// throughput, drawn from the seeded RNG). 0 = every job is
    /// fixed-shape and **no extra RNG draws happen**, so all pre-moldable
    /// presets replay byte-identically per seed.
    pub moldable_frac: f64,
}

impl WorkloadConfig {
    /// Figure-2-calibrated training-cluster mix (per mille job counts).
    pub fn paper_training(seed: u64) -> WorkloadConfig {
        WorkloadConfig {
            seed,
            classes: vec![
                SizeClass { gpus: 1, weight: 400.0, mean_hours: 0.5 },
                SizeClass { gpus: 2, weight: 130.0, mean_hours: 0.5 },
                SizeClass { gpus: 4, weight: 120.0, mean_hours: 0.75 },
                SizeClass { gpus: 8, weight: 270.0, mean_hours: 1.0 },
                SizeClass { gpus: 16, weight: 25.0, mean_hours: 2.0 },
                SizeClass { gpus: 32, weight: 12.0, mean_hours: 3.0 },
                SizeClass { gpus: 64, weight: 8.0, mean_hours: 4.0 },
                SizeClass { gpus: 128, weight: 10.0, mean_hours: 6.0 },
                SizeClass { gpus: 256, weight: 10.0, mean_hours: 8.0 },
                SizeClass { gpus: 512, weight: 8.0, mean_hours: 10.0 },
                SizeClass { gpus: 1024, weight: 5.0, mean_hours: 12.0 },
                SizeClass { gpus: 2048, weight: 2.0, mean_hours: 16.0 },
            ],
            mean_interarrival_ms: 60_000.0,
            num_tenants: 4,
            tenant_weights: Vec::new(),
            training_frac: 0.85,
            inference_frac: 0.05,
            gpu_type: GpuTypeId(0),
            gpus_per_node: 8,
            type_mix: Vec::new(),
            duration_sigma: 0.35,
            high_priority_frac: 0.05,
            max_gpus: 0,
            elastic_frac: 0.0,
            moldable_frac: 0.0,
        }
    }

    /// Small multi-tenant inference-cluster mix (§5.2): 1–8 GPU services,
    /// long-lived, non-gang.
    pub fn paper_inference(seed: u64) -> WorkloadConfig {
        WorkloadConfig {
            seed,
            classes: vec![
                SizeClass { gpus: 1, weight: 45.0, mean_hours: 24.0 },
                SizeClass { gpus: 2, weight: 25.0, mean_hours: 24.0 },
                SizeClass { gpus: 4, weight: 20.0, mean_hours: 48.0 },
                SizeClass { gpus: 8, weight: 10.0, mean_hours: 48.0 },
            ],
            mean_interarrival_ms: 600_000.0,
            num_tenants: 8,
            tenant_weights: Vec::new(),
            training_frac: 0.0,
            inference_frac: 0.95,
            gpu_type: GpuTypeId(0),
            gpus_per_node: 8,
            type_mix: Vec::new(),
            duration_sigma: 0.5,
            high_priority_frac: 0.1,
            max_gpus: 8,
            elastic_frac: 0.0,
            moldable_frac: 0.0,
        }
    }

    /// Elastic inference mix: the `paper_inference` services, but most of
    /// them are diurnal replica sets (the §2 "unified co-scheduling"
    /// workload the elastic controller drives).
    pub fn paper_elastic_inference(seed: u64) -> WorkloadConfig {
        WorkloadConfig {
            elastic_frac: 0.7,
            ..WorkloadConfig::paper_inference(seed)
        }
    }

    /// Moldable training mix: the `paper_training` jobs, but half of the
    /// multi-replica gangs declare a shrink ladder (the Arena-style
    /// adaptive-parallelism workload behind `--moldable`).
    pub fn paper_moldable_training(seed: u64) -> WorkloadConfig {
        WorkloadConfig {
            moldable_frac: 0.5,
            ..WorkloadConfig::paper_training(seed)
        }
    }

    /// Mean GPU-hours per job under this mix (closed form over classes,
    /// honouring the `max_gpus` size cap).
    pub fn mean_gpu_hours(&self) -> f64 {
        let total_w: f64 = self.classes.iter().map(|c| c.weight).sum();
        self.classes
            .iter()
            .map(|c| {
                let gpus = if self.max_gpus > 0 {
                    c.gpus.min(self.max_gpus)
                } else {
                    c.gpus
                };
                c.weight / total_w * gpus as f64 * c.mean_hours
            })
            .sum()
    }

    /// Calibrate the arrival rate so offered load ≈ `rho` × `capacity_gpus`
    /// (steady state): interarrival = mean_job_gpu_hours / (rho × capacity).
    pub fn calibrate_load(mut self, capacity_gpus: u32, rho: f64) -> WorkloadConfig {
        let gpu_hours_per_job = self.mean_gpu_hours();
        let jobs_per_hour = rho * capacity_gpus as f64 / gpu_hours_per_job;
        self.mean_interarrival_ms = 3_600_000.0 / jobs_per_hour;
        self
    }
}

/// The deterministic workload generator.
pub struct WorkloadGen {
    cfg: WorkloadConfig,
    rng: Pcg32,
    next_id: u64,
    clock_ms: f64,
}

impl WorkloadGen {
    pub fn new(cfg: WorkloadConfig) -> WorkloadGen {
        let rng = Pcg32::seed_from_u64(cfg.seed);
        WorkloadGen {
            cfg,
            rng,
            next_id: 1,
            clock_ms: 0.0,
        }
    }

    pub fn config(&self) -> &WorkloadConfig {
        &self.cfg
    }

    /// Generate the next job (advancing the arrival clock).
    pub fn next_job(&mut self) -> JobSpec {
        let dt = self
            .rng
            .exponential(1.0 / self.cfg.mean_interarrival_ms.max(1e-9));
        self.clock_ms += dt;
        let submit_ms = self.clock_ms as u64;

        // Size class.
        let weights: Vec<f64> = self.cfg.classes.iter().map(|c| c.weight).collect();
        let class = self.cfg.classes[self.rng.categorical(&weights)];
        let mut gpus = class.gpus;
        if self.cfg.max_gpus > 0 {
            gpus = gpus.min(self.cfg.max_gpus);
        }

        // GPU model (heterogeneous mix or the single configured type).
        let (gpu_type, node_size) = if self.cfg.type_mix.is_empty() {
            (self.cfg.gpu_type, self.cfg.gpus_per_node)
        } else {
            let tw: Vec<f64> = self.cfg.type_mix.iter().map(|&(_, w, _)| w).collect();
            let pick = self.cfg.type_mix[self.rng.categorical(&tw)];
            (pick.0, pick.2)
        };
        // Pods can never exceed the model's board size.
        gpus = gpus.min(node_size.max(1) * 256);

        // Kind.
        let r = self.rng.f64();
        let kind = if r < self.cfg.training_frac {
            JobKind::Training
        } else if r < self.cfg.training_frac + self.cfg.inference_frac {
            JobKind::Inference
        } else {
            JobKind::Dev
        };

        // Elastic replica sets: a slice of inference services scales with
        // a diurnal curve. Per-service phase/amplitude come from the
        // seeded RNG, so the whole tide replays per seed. The draws only
        // happen when the mix enables elasticity, keeping pre-elastic
        // presets byte-identical per seed.
        let elastic = if kind == JobKind::Inference
            && self.cfg.elastic_frac > 0.0
            && self.rng.chance(self.cfg.elastic_frac)
        {
            let max_replicas = gpus.max(2);
            Some(ElasticService {
                min_replicas: (max_replicas / 4).max(1),
                max_replicas,
                phase_ms: self.rng.below(ElasticService::DAY_MS),
                amplitude: self.rng.uniform(0.6, 1.0),
                period_ms: ElasticService::DAY_MS,
            })
        } else {
            None
        };

        // Shape: jobs larger than one node become N whole-node pods;
        // sub-node jobs are a single pod (training) or `gpus` single-GPU
        // replicas (inference services scale by replica). Elastic
        // services start at their floor and grow by child deltas.
        let per_node = node_size.max(1);
        let (replicas, gpus_per_pod) = if let Some(e) = elastic {
            (e.min_replicas, 1)
        } else if gpus > per_node {
            let pods = gpus.div_ceil(per_node);
            (pods, per_node)
        } else if kind == JobKind::Inference && gpus > 1 {
            (gpus, 1)
        } else {
            (1, gpus)
        };

        // Duration: log-normal with the class mean.
        let mean_ms = class.mean_hours * 3_600_000.0;
        let sigma = self.cfg.duration_sigma;
        let mu = mean_ms.ln() - sigma * sigma / 2.0;
        let duration_ms = self.rng.log_normal(mu, sigma).max(10_000.0) as u64;

        // Priority.
        let pr = self.rng.f64();
        let priority = if pr < self.cfg.high_priority_frac {
            Priority::HIGH
        } else if pr < 2.0 * self.cfg.high_priority_frac {
            Priority::LOW
        } else {
            Priority::NORMAL
        };

        let tenant = if self.cfg.tenant_weights.len() == self.cfg.num_tenants as usize
            && !self.cfg.tenant_weights.is_empty()
        {
            TenantId(self.rng.categorical(&self.cfg.tenant_weights) as u32)
        } else {
            TenantId(self.rng.below(self.cfg.num_tenants.max(1) as u64) as u32)
        };
        let id = JobId(self.next_id);
        self.next_id += 1;

        // Moldable shape ladder: a slice of multi-replica training gangs
        // declares halving fallback shapes with sub-linear per-GPU
        // efficiency (shrinking is never free). Drawn last, and only when
        // the mix enables moldability — zero RNG draws otherwise, keeping
        // every pre-moldable preset byte-identical per seed.
        let shapes = if self.cfg.moldable_frac > 0.0
            && kind == JobKind::Training
            && replicas >= 2
            && self.rng.chance(self.cfg.moldable_frac)
        {
            let mut ladder = vec![GangShape {
                replicas,
                throughput: 1.0,
            }];
            let mut r = replicas;
            let mut thr = 1.0;
            while ladder.len() < 3 && r >= 2 {
                let next = r / 2;
                thr *= (next as f64 / r as f64) * self.rng.uniform(0.85, 0.95);
                ladder.push(GangShape {
                    replicas: next,
                    throughput: thr,
                });
                r = next;
            }
            ladder
        } else {
            Vec::new()
        };

        JobSpec {
            id,
            tenant,
            kind,
            priority,
            gang: kind == JobKind::Training,
            demands: vec![TypedDemand {
                gpu_type,
                replicas,
                gpus_per_pod,
            }],
            submit_ms,
            duration_ms,
            strategy: None,
            needs_hbd: false,
            elastic,
            service: None,
            checkpoint: crate::job::spec::CheckpointPolicy::Continuous,
            tidal: false,
            shapes,
        }
    }

    /// Generate `n` jobs (sorted by submit time by construction).
    pub fn generate(&mut self, n: usize) -> Vec<JobSpec> {
        (0..n).map(|_| self.next_job()).collect()
    }

    /// Generate jobs until the arrival clock passes `horizon_ms`.
    pub fn generate_until(&mut self, horizon_ms: u64) -> Vec<JobSpec> {
        let mut out = Vec::new();
        loop {
            let j = self.next_job();
            if j.submit_ms > horizon_ms {
                break;
            }
            out.push(j);
        }
        out
    }
}

/// Deterministic tidal-training stream: `n` LOW-priority gang jobs of
/// `replicas` pods × `gpus_per_pod` GPUs, arriving evenly (with seeded
/// jitter) over `[0, horizon_ms)` and flagged `tidal` — the backfill
/// fuel for the elastic+tidal co-scheduling arm. Tidal jobs run in
/// whatever capacity inference scale-down frees and are the designated
/// victims of SLO-pressure reclamation.
#[allow(clippy::too_many_arguments)]
pub fn tidal_training_stream(
    seed: u64,
    first_id: u64,
    tenant: TenantId,
    gpu_type: GpuTypeId,
    n: usize,
    replicas: u32,
    gpus_per_pod: u32,
    horizon_ms: u64,
    mean_duration_ms: u64,
) -> Vec<JobSpec> {
    let mut rng = Pcg32::seed_from_u64(seed ^ 0x71da_1ca1);
    let slot = horizon_ms / n.max(1) as u64;
    (0..n)
        .map(|i| {
            let submit = i as u64 * slot + rng.below(slot.max(1));
            let duration =
                (rng.uniform(0.5, 1.5) * mean_duration_ms as f64).max(60_000.0) as u64;
            JobSpec::homogeneous(
                JobId(first_id + i as u64),
                tenant,
                JobKind::Training,
                gpu_type,
                replicas,
                gpus_per_pod,
            )
            .with_times(submit, duration)
            .with_priority(Priority::LOW)
            .with_tidal()
        })
        .collect()
}

/// Assign every job a fixed strategy (for A/B experiment arms).
pub fn with_strategy(mut jobs: Vec<JobSpec>, s: PlacementStrategy) -> Vec<JobSpec> {
    for j in &mut jobs {
        j.strategy = Some(s);
    }
    jobs
}

/// Figure-2 style distribution report: per size class, the share of job
/// count and of GPU-time.
pub fn distribution_report(jobs: &[JobSpec]) -> Vec<(u32, f64, f64)> {
    let mut sizes: Vec<u32> = jobs.iter().map(|j| j.total_gpus()).collect();
    sizes.sort_unstable();
    sizes.dedup();
    let total_jobs = jobs.len() as f64;
    let total_gpu_time: f64 = jobs
        .iter()
        .map(|j| j.total_gpus() as f64 * j.duration_ms as f64)
        .sum();
    sizes
        .into_iter()
        .map(|s| {
            let of_size: Vec<&JobSpec> = jobs.iter().filter(|j| j.total_gpus() == s).collect();
            let count_share = of_size.len() as f64 / total_jobs;
            let time_share = of_size
                .iter()
                .map(|j| j.total_gpus() as f64 * j.duration_ms as f64)
                .sum::<f64>()
                / total_gpu_time.max(1.0);
            (s, count_share, time_share)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let a = WorkloadGen::new(WorkloadConfig::paper_training(7)).generate(100);
        let b = WorkloadGen::new(WorkloadConfig::paper_training(7)).generate(100);
        assert_eq!(a, b);
        let c = WorkloadGen::new(WorkloadConfig::paper_training(8)).generate(100);
        assert_ne!(a, c);
    }

    #[test]
    fn figure2_shape_holds() {
        let jobs = WorkloadGen::new(WorkloadConfig::paper_training(42)).generate(10_000);
        let report = distribution_report(&jobs);
        let small_count: f64 = report
            .iter()
            .filter(|(s, _, _)| *s <= 8)
            .map(|(_, c, _)| c)
            .sum();
        let small_time: f64 = report
            .iter()
            .filter(|(s, _, _)| *s <= 8)
            .map(|(_, _, t)| t)
            .sum();
        let big_time: f64 = report
            .iter()
            .filter(|(s, _, _)| *s >= 256)
            .map(|(_, _, t)| t)
            .sum();
        assert!(small_count > 0.90, "small-job count share {small_count}");
        assert!(small_time < 0.10, "small-job GPU-time share {small_time}");
        assert!(big_time > 0.50, "big-job GPU-time share {big_time}");
    }

    #[test]
    fn arrivals_are_monotone_and_poisson_mean() {
        let cfg = WorkloadConfig::paper_training(1);
        let mean = cfg.mean_interarrival_ms;
        let jobs = WorkloadGen::new(cfg).generate(5_000);
        for w in jobs.windows(2) {
            assert!(w[0].submit_ms <= w[1].submit_ms);
        }
        let span = jobs.last().unwrap().submit_ms as f64;
        let measured = span / jobs.len() as f64;
        assert!(
            (measured - mean).abs() / mean < 0.1,
            "interarrival {measured} vs {mean}"
        );
    }

    #[test]
    fn large_jobs_are_whole_node_gangs() {
        let jobs = WorkloadGen::new(WorkloadConfig::paper_training(3)).generate(5_000);
        for j in jobs.iter().filter(|j| j.total_gpus() > 8) {
            let d = j.demands[0];
            assert_eq!(d.gpus_per_pod, 8, "large jobs use whole boards");
            assert_eq!(d.replicas * 8, j.total_gpus());
        }
    }

    #[test]
    fn inference_mix_is_small_and_non_gang() {
        let jobs = WorkloadGen::new(WorkloadConfig::paper_inference(5)).generate(2_000);
        assert!(jobs.iter().all(|j| j.total_gpus() <= 8));
        let gang = jobs.iter().filter(|j| j.gang).count();
        assert!(gang == 0, "inference workload must be non-gang, got {gang}");
        let inf = jobs
            .iter()
            .filter(|j| j.kind == JobKind::Inference)
            .count() as f64
            / jobs.len() as f64;
        assert!(inf > 0.9);
    }

    #[test]
    fn calibrate_load_hits_target_roughly() {
        // rho=0.8 against 1024 GPUs: offered GPU-hours/hour ≈ 819.
        let cfg = WorkloadConfig::paper_training(11).calibrate_load(1024, 0.8);
        let jobs = WorkloadGen::new(cfg).generate(4_000);
        let span_h = jobs.last().unwrap().submit_ms as f64 / 3_600_000.0;
        let offered: f64 = jobs
            .iter()
            .map(|j| j.total_gpus() as f64 * j.duration_ms as f64 / 3_600_000.0)
            .sum();
        let rate = offered / span_h;
        let target = 0.8 * 1024.0;
        assert!(
            (rate - target).abs() / target < 0.25,
            "offered {rate} GPU-h/h vs target {target}"
        );
    }

    #[test]
    fn priorities_follow_config_fractions() {
        let jobs = WorkloadGen::new(WorkloadConfig::paper_training(13)).generate(10_000);
        let high = jobs.iter().filter(|j| j.priority == Priority::HIGH).count() as f64
            / jobs.len() as f64;
        assert!((high - 0.05).abs() < 0.01, "high frac {high}");
    }

    #[test]
    fn tenants_are_spread() {
        let jobs = WorkloadGen::new(WorkloadConfig::paper_training(17)).generate(4_000);
        for t in 0..4u32 {
            let share = jobs.iter().filter(|j| j.tenant == TenantId(t)).count() as f64
                / jobs.len() as f64;
            assert!((share - 0.25).abs() < 0.05, "tenant {t} share {share}");
        }
    }

    #[test]
    fn generate_until_respects_horizon() {
        let jobs =
            WorkloadGen::new(WorkloadConfig::paper_training(19)).generate_until(3_600_000);
        assert!(!jobs.is_empty());
        assert!(jobs.iter().all(|j| j.submit_ms <= 3_600_000));
    }

    #[test]
    fn max_gpus_caps_sizes() {
        let mut cfg = WorkloadConfig::paper_training(23);
        cfg.max_gpus = 8;
        let jobs = WorkloadGen::new(cfg).generate(2_000);
        assert!(jobs.iter().all(|j| j.total_gpus() <= 8));
    }

    #[test]
    fn elastic_mix_generates_diurnal_replica_sets() {
        let jobs = WorkloadGen::new(WorkloadConfig::paper_elastic_inference(31)).generate(2_000);
        let b = WorkloadGen::new(WorkloadConfig::paper_elastic_inference(31)).generate(2_000);
        assert_eq!(jobs, b, "elastic generation must replay per seed");
        let inference: Vec<&JobSpec> =
            jobs.iter().filter(|j| j.kind == JobKind::Inference).collect();
        let elastic: Vec<&JobSpec> =
            inference.iter().copied().filter(|j| j.elastic.is_some()).collect();
        let frac = elastic.len() as f64 / inference.len() as f64;
        assert!((frac - 0.7).abs() < 0.05, "elastic frac {frac}");
        for j in &elastic {
            let e = j.elastic.unwrap();
            assert!(e.min_replicas >= 1 && e.min_replicas <= e.max_replicas);
            assert_eq!(j.total_replicas(), e.min_replicas, "base starts at floor");
            assert_eq!(j.gpus_per_replica(), 1, "elastic replicas are single-GPU");
            assert!(e.amplitude >= 0.6 && e.amplitude <= 1.0);
            assert!(e.phase_ms < ElasticService::DAY_MS);
            assert_eq!(e.period_ms, ElasticService::DAY_MS);
        }
        // Phases actually vary across services (per-service RNG draws).
        let phases: std::collections::HashSet<u64> =
            elastic.iter().map(|j| j.elastic.unwrap().phase_ms).collect();
        assert!(phases.len() > 1);
    }

    #[test]
    fn moldable_mix_generates_strictly_decreasing_ladders() {
        let a = WorkloadGen::new(WorkloadConfig::paper_moldable_training(37)).generate(4_000);
        let b = WorkloadGen::new(WorkloadConfig::paper_moldable_training(37)).generate(4_000);
        assert_eq!(a, b, "moldable generation must replay per seed");
        let moldable: Vec<&JobSpec> = a.iter().filter(|j| j.moldable()).collect();
        assert!(!moldable.is_empty());
        for j in &moldable {
            assert!(j.gang && j.kind == JobKind::Training);
            assert_eq!(j.shapes[0].replicas, j.total_replicas(), "shape 0 is the full gang");
            assert!((j.shapes[0].throughput - 1.0).abs() < 1e-12);
            for w in j.shapes.windows(2) {
                assert!(w[0].replicas > w[1].replicas, "ladder strictly decreasing");
                assert!(w[0].throughput > w[1].throughput);
                // Sub-linear scaling: shrinking always costs efficiency.
                let linear = w[1].replicas as f64 / w[0].replicas as f64;
                assert!(w[1].throughput / w[0].throughput < linear);
            }
        }
        // Roughly half of the eligible (multi-replica training) gangs opt in.
        let candidates = a
            .iter()
            .filter(|j| j.kind == JobKind::Training && j.total_replicas() >= 2)
            .count();
        let frac = moldable.len() as f64 / candidates.max(1) as f64;
        assert!((frac - 0.5).abs() < 0.1, "moldable frac {frac}");
        // Nothing else ever carries shapes.
        assert!(a
            .iter()
            .filter(|j| !(j.kind == JobKind::Training && j.total_replicas() >= 2))
            .all(|j| j.shapes.is_empty()));
    }

    #[test]
    fn tidal_stream_is_low_priority_gang_and_deterministic() {
        use crate::job::workload::tidal_training_stream;
        let mk = || {
            tidal_training_stream(
                9,
                1_000,
                TenantId(1),
                GpuTypeId(0),
                20,
                1,
                8,
                24 * 3_600_000,
                2 * 3_600_000,
            )
        };
        let a = mk();
        assert_eq!(a, mk());
        assert_eq!(a.len(), 20);
        for (i, j) in a.iter().enumerate() {
            assert!(j.tidal && j.gang);
            assert_eq!(j.priority, Priority::LOW);
            assert_eq!(j.id, JobId(1_000 + i as u64));
            assert!(j.submit_ms < 24 * 3_600_000);
            assert!(j.duration_ms >= 60_000);
        }
        // Arrivals are sorted by construction (one per slot).
        assert!(a.windows(2).all(|w| w[0].submit_ms <= w[1].submit_ms));
    }
}
