//! JSONL job traces: record a generated workload to disk and replay it —
//! lets experiment arms (Backfill vs FIFO, E-Binpack on/off) consume the
//! *identical* input, and lets users bring their own traces.

use std::io::{BufRead, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::cluster::ids::{GpuTypeId, JobId, TenantId};
use crate::util::json::Json;

use super::spec::{
    CheckpointPolicy, ElasticService, GangShape, JobKind, JobSpec, PlacementStrategy, Priority,
    TypedDemand,
};

/// Serialize one job to a JSON object.
pub fn job_to_json(j: &JobSpec) -> Json {
    let mut o = Json::obj();
    o.set("id", j.id.0)
        .set("tenant", j.tenant.0)
        .set("kind", j.kind.as_str())
        .set("priority", j.priority.0 as u64)
        .set("gang", j.gang)
        .set("submit_ms", j.submit_ms)
        .set("duration_ms", j.duration_ms)
        .set("needs_hbd", j.needs_hbd);
    if let Some(s) = j.strategy {
        o.set("strategy", s.as_str());
    }
    if let Some(e) = j.elastic {
        let mut m = Json::obj();
        m.set("min_replicas", e.min_replicas)
            .set("max_replicas", e.max_replicas)
            .set("phase_ms", e.phase_ms)
            .set("amplitude", e.amplitude)
            .set("period_ms", e.period_ms);
        o.set("elastic", m);
    }
    if let Some(parent) = j.service {
        o.set("service", parent.0);
    }
    if j.tidal {
        o.set("tidal", true);
    }
    match j.checkpoint {
        CheckpointPolicy::Continuous => {}
        CheckpointPolicy::Interval(i) => {
            o.set("checkpoint_ms", i);
        }
        CheckpointPolicy::None => {
            o.set("checkpoint", "none");
        }
    }
    if !j.shapes.is_empty() {
        let shapes: Vec<Json> = j
            .shapes
            .iter()
            .map(|s| {
                let mut m = Json::obj();
                m.set("replicas", s.replicas).set("throughput", s.throughput);
                m
            })
            .collect();
        o.set("shapes", shapes);
    }
    let demands: Vec<Json> = j
        .demands
        .iter()
        .map(|d| {
            let mut m = Json::obj();
            m.set("gpu_type", d.gpu_type.0 as u64)
                .set("replicas", d.replicas)
                .set("gpus_per_pod", d.gpus_per_pod);
            m
        })
        .collect();
    o.set("demands", demands);
    o
}

/// Parse one job from a JSON object.
pub fn job_from_json(v: &Json) -> Result<JobSpec> {
    let get = |k: &str| v.get(k).with_context(|| format!("missing field '{k}'"));
    let kind_s = get("kind")?.as_str().context("kind not a string")?;
    let kind = JobKind::parse(kind_s).with_context(|| format!("bad kind '{kind_s}'"))?;
    let demands_json = get("demands")?.as_arr().context("demands not an array")?;
    if demands_json.is_empty() {
        bail!("job has no demands");
    }
    let mut demands = Vec::with_capacity(demands_json.len());
    for d in demands_json {
        demands.push(TypedDemand {
            gpu_type: GpuTypeId(
                d.get("gpu_type")
                    .and_then(Json::as_u64)
                    .context("demand.gpu_type")? as u16,
            ),
            replicas: d
                .get("replicas")
                .and_then(Json::as_u64)
                .context("demand.replicas")? as u32,
            gpus_per_pod: d
                .get("gpus_per_pod")
                .and_then(Json::as_u64)
                .context("demand.gpus_per_pod")? as u32,
        });
    }
    let strategy = match v.get("strategy").and_then(Json::as_str) {
        Some(s) => {
            Some(PlacementStrategy::parse(s).with_context(|| format!("bad strategy '{s}'"))?)
        }
        None => None,
    };
    let elastic = match v.get("elastic") {
        Some(e) => Some(ElasticService {
            min_replicas: e
                .get("min_replicas")
                .and_then(Json::as_u64)
                .context("elastic.min_replicas")? as u32,
            max_replicas: e
                .get("max_replicas")
                .and_then(Json::as_u64)
                .context("elastic.max_replicas")? as u32,
            phase_ms: e
                .get("phase_ms")
                .and_then(Json::as_u64)
                .context("elastic.phase_ms")?,
            amplitude: e
                .get("amplitude")
                .and_then(Json::as_f64)
                .context("elastic.amplitude")?,
            period_ms: e
                .get("period_ms")
                .and_then(Json::as_u64)
                .context("elastic.period_ms")?,
        }),
        None => None,
    };
    let shapes = match v.get("shapes").and_then(Json::as_arr) {
        Some(arr) => {
            let mut shapes = Vec::with_capacity(arr.len());
            for s in arr {
                shapes.push(GangShape {
                    replicas: s
                        .get("replicas")
                        .and_then(Json::as_u64)
                        .context("shape.replicas")? as u32,
                    throughput: s
                        .get("throughput")
                        .and_then(Json::as_f64)
                        .context("shape.throughput")?,
                });
            }
            if !shapes.windows(2).all(|w| w[0].replicas > w[1].replicas) {
                bail!("shape ladder must be strictly decreasing in replicas");
            }
            shapes
        }
        None => Vec::new(),
    };
    Ok(JobSpec {
        id: JobId(get("id")?.as_u64().context("id")?),
        tenant: TenantId(get("tenant")?.as_u64().context("tenant")? as u32),
        kind,
        priority: Priority(get("priority")?.as_u64().context("priority")? as u8),
        gang: get("gang")?.as_bool().context("gang")?,
        demands,
        submit_ms: get("submit_ms")?.as_u64().context("submit_ms")?,
        duration_ms: get("duration_ms")?.as_u64().context("duration_ms")?,
        strategy,
        needs_hbd: v.get("needs_hbd").and_then(Json::as_bool).unwrap_or(false),
        elastic,
        service: v.get("service").and_then(Json::as_u64).map(JobId),
        tidal: v.get("tidal").and_then(Json::as_bool).unwrap_or(false),
        checkpoint: match v.get("checkpoint_ms").and_then(Json::as_u64) {
            Some(i) => CheckpointPolicy::Interval(i),
            None if v.get("checkpoint").and_then(Json::as_str) == Some("none") => {
                CheckpointPolicy::None
            }
            None => CheckpointPolicy::Continuous,
        },
        shapes,
    })
}

/// Write a trace as JSON-lines.
pub fn write_trace(path: &Path, jobs: &[JobSpec]) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating trace file {}", path.display()))?;
    let mut w = std::io::BufWriter::new(f);
    for j in jobs {
        writeln!(w, "{}", job_to_json(j).to_string_compact())?;
    }
    Ok(())
}

/// Read a JSONL trace.
pub fn read_trace(path: &Path) -> Result<Vec<JobSpec>> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening trace file {}", path.display()))?;
    let r = std::io::BufReader::new(f);
    let mut jobs = Vec::new();
    for (i, line) in r.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(&line).with_context(|| format!("trace line {}", i + 1))?;
        jobs.push(job_from_json(&v).with_context(|| format!("trace line {}", i + 1))?);
    }
    Ok(jobs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::workload::{WorkloadConfig, WorkloadGen};

    #[test]
    fn json_roundtrip_single() {
        let spec = JobSpec::homogeneous(
            JobId(7),
            TenantId(2),
            JobKind::Inference,
            GpuTypeId(1),
            4,
            1,
        )
        .with_times(123, 456_000)
        .with_strategy(PlacementStrategy::ESpread);
        let j = job_to_json(&spec);
        let back = job_from_json(&j).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn file_roundtrip_workload() {
        let jobs = WorkloadGen::new(WorkloadConfig::paper_training(99)).generate(200);
        let dir = std::env::temp_dir().join("kant_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        write_trace(&path, &jobs).unwrap();
        let back = read_trace(&path).unwrap();
        assert_eq!(back, jobs);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn json_roundtrip_elastic_and_tidal() {
        let svc = JobSpec::homogeneous(
            JobId(11),
            TenantId(1),
            JobKind::Inference,
            GpuTypeId(0),
            4,
            1,
        )
        .with_elastic(ElasticService {
            min_replicas: 2,
            max_replicas: 9,
            phase_ms: 3_600_000,
            amplitude: 0.85,
            period_ms: ElasticService::DAY_MS,
        });
        assert_eq!(job_from_json(&job_to_json(&svc)).unwrap(), svc);
        let tidal = JobSpec::homogeneous(
            JobId(12),
            TenantId(0),
            JobKind::Training,
            GpuTypeId(0),
            1,
            8,
        )
        .with_tidal();
        assert_eq!(job_from_json(&job_to_json(&tidal)).unwrap(), tidal);
    }

    #[test]
    fn json_roundtrip_checkpoint_policies() {
        let base =
            JobSpec::homogeneous(JobId(20), TenantId(0), JobKind::Training, GpuTypeId(0), 2, 8);
        for policy in [
            CheckpointPolicy::Continuous,
            CheckpointPolicy::Interval(900_000),
            CheckpointPolicy::None,
        ] {
            let j = base.clone().with_checkpoint(policy);
            assert_eq!(job_from_json(&job_to_json(&j)).unwrap(), j);
        }
    }

    #[test]
    fn json_roundtrip_shapes() {
        let moldable = JobSpec::homogeneous(
            JobId(30),
            TenantId(0),
            JobKind::Training,
            GpuTypeId(0),
            4,
            8,
        )
        .with_tidal()
        .with_shapes(vec![
            GangShape {
                replicas: 4,
                throughput: 1.0,
            },
            GangShape {
                replicas: 2,
                throughput: 0.55,
            },
            GangShape {
                replicas: 1,
                throughput: 0.3,
            },
        ]);
        let back = job_from_json(&job_to_json(&moldable)).unwrap();
        assert_eq!(back, moldable);
        assert!(back.moldable());
        // Fixed-shape jobs omit the field entirely — old traces parse
        // unchanged and new traces of fixed jobs stay byte-identical.
        let fixed =
            JobSpec::homogeneous(JobId(31), TenantId(0), JobKind::Training, GpuTypeId(0), 2, 8);
        assert!(!job_to_json(&fixed).to_string_compact().contains("shapes"));
        assert_eq!(job_from_json(&job_to_json(&fixed)).unwrap(), fixed);
    }

    #[test]
    fn non_decreasing_shape_ladder_rejected() {
        let moldable = JobSpec::homogeneous(
            JobId(32),
            TenantId(0),
            JobKind::Training,
            GpuTypeId(0),
            4,
            8,
        )
        .with_shapes(vec![
            GangShape {
                replicas: 4,
                throughput: 1.0,
            },
            GangShape {
                replicas: 2,
                throughput: 0.55,
            },
        ]);
        let mut j = job_to_json(&moldable);
        // Corrupt the ladder so it is no longer strictly decreasing.
        let shapes: Vec<Json> = vec![
            {
                let mut m = Json::obj();
                m.set("replicas", 2u32).set("throughput", 0.55);
                m
            },
            {
                let mut m = Json::obj();
                m.set("replicas", 4u32).set("throughput", 1.0);
                m
            },
        ];
        j.set("shapes", shapes);
        assert!(job_from_json(&j).is_err());
    }

    #[test]
    fn missing_field_errors() {
        let v = Json::parse(r#"{"id": 1}"#).unwrap();
        let err = job_from_json(&v).unwrap_err().to_string();
        assert!(err.contains("missing field"), "{err}");
    }

    #[test]
    fn bad_kind_errors() {
        let spec =
            JobSpec::homogeneous(JobId(1), TenantId(0), JobKind::Dev, GpuTypeId(0), 1, 1);
        let mut j = job_to_json(&spec);
        j.set("kind", "bogus");
        assert!(job_from_json(&j).is_err());
    }

    #[test]
    fn empty_demands_rejected() {
        let spec =
            JobSpec::homogeneous(JobId(1), TenantId(0), JobKind::Dev, GpuTypeId(0), 1, 1);
        let mut j = job_to_json(&spec);
        j.set("demands", Vec::<Json>::new());
        assert!(job_from_json(&j).is_err());
    }
}
