//! Job and pod specifications — what users submit.
//!
//! The paper's workload taxonomy (§2): LLM distributed training (gang,
//! large), inference services (non-gang, small, HA-sensitive), and
//! dev/debug tasks (small, latency-sensitive). Jobs may request multiple
//! GPU models in heterogeneous clusters (cross-pool joint admission,
//! §3.2.1); the common case is a single model.

use crate::cluster::ids::{GpuTypeId, JobId, TenantId};

/// Task category (§2 "Diverse Task Types").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobKind {
    Training,
    Inference,
    Dev,
}

impl JobKind {
    pub fn as_str(self) -> &'static str {
        match self {
            JobKind::Training => "training",
            JobKind::Inference => "inference",
            JobKind::Dev => "dev",
        }
    }

    pub fn parse(s: &str) -> Option<JobKind> {
        match s {
            "training" => Some(JobKind::Training),
            "inference" => Some(JobKind::Inference),
            "dev" => Some(JobKind::Dev),
            _ => None,
        }
    }
}

/// Scheduling priority; higher value = more important.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Priority(pub u8);

impl Priority {
    pub const LOW: Priority = Priority(0);
    pub const NORMAL: Priority = Priority(4);
    pub const HIGH: Priority = Priority(8);

    /// Number of base priority classes (LOW / NORMAL / HIGH) — the
    /// granularity of the per-class anti-starvation bounds and the
    /// adaptive controller's JWTD signals.
    pub const NUM_CLASSES: usize = 3;

    /// Base class this priority falls in: 0 = LOW, 1 = NORMAL, 2 = HIGH.
    /// Requeue aging may raise the raw value within a class but never
    /// across one (see [`Priority::aged`]).
    pub fn class_index(self) -> usize {
        match self.0 {
            0..=3 => 0,
            4..=7 => 1,
            _ => 2,
        }
    }

    /// Apply a requeue-aging boost, clamped below the next class base so
    /// aging can reorder jobs *within* a class but never promote one
    /// across class boundaries (LOW caps at 3, NORMAL at 7; HIGH has no
    /// class above it and saturates on `u8`).
    pub fn aged(self, boost: u8) -> Priority {
        let raised = self.0.saturating_add(boost);
        let ceiling = match self.class_index() {
            0 => 3,
            1 => 7,
            _ => u8::MAX,
        };
        Priority(raised.min(ceiling))
    }
}

/// Placement strategy requested for (or assigned to) a job (§3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementStrategy {
    /// Baseline: first fit in node-id order, no consolidation ("native
    /// scheduling system" in §5).
    NativeFirstFit,
    /// Plain Binpack: fill partially-used nodes first (§3.3.3).
    Binpack,
    /// Enhanced Binpack: node-level co-location + LeafGroup consolidation
    /// (§3.3.3 E-Binpack).
    EBinpack,
    /// Plain Spread: spread replicas across nodes (§3.3.4).
    Spread,
    /// Enhanced Spread: inference dedicated zone + E-Binpack overflow
    /// (§3.3.4 E-Spread).
    ESpread,
}

impl PlacementStrategy {
    pub fn as_str(self) -> &'static str {
        match self {
            PlacementStrategy::NativeFirstFit => "native",
            PlacementStrategy::Binpack => "binpack",
            PlacementStrategy::EBinpack => "e-binpack",
            PlacementStrategy::Spread => "spread",
            PlacementStrategy::ESpread => "e-spread",
        }
    }

    pub fn parse(s: &str) -> Option<PlacementStrategy> {
        match s {
            "native" => Some(PlacementStrategy::NativeFirstFit),
            "binpack" => Some(PlacementStrategy::Binpack),
            "e-binpack" | "ebinpack" => Some(PlacementStrategy::EBinpack),
            "spread" => Some(PlacementStrategy::Spread),
            "e-spread" | "espread" => Some(PlacementStrategy::ESpread),
            _ => None,
        }
    }
}

/// Elastic replica-set parameters for an inference service: a replica
/// envelope plus a deterministic diurnal demand curve. The controller
/// (`sim::elastic`) samples the curve and scales the service between
/// `min_replicas` and `max_replicas`; freed night-time capacity is what
/// tidal training backfills into.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ElasticService {
    /// Floor the service never shrinks below (the base replica set).
    pub min_replicas: u32,
    /// Daytime peak replica count.
    pub max_replicas: u32,
    /// Phase offset of the diurnal curve (ms into the period).
    pub phase_ms: u64,
    /// Swing of the curve in [0, 1]: 1.0 oscillates over the full
    /// min..max envelope, 0.0 pins demand at the midpoint.
    pub amplitude: f64,
    /// Curve period in ms (24 h for a diurnal cycle).
    pub period_ms: u64,
}

impl ElasticService {
    pub const DAY_MS: u64 = 24 * 3_600_000;

    /// Normalized demand in [0, 1] at sim time `t`: a cosine day curve
    /// (trough at phase 0, peak half a period later), centered on 0.5
    /// with the configured amplitude. Deterministic in `t`.
    pub fn load(&self, t: u64) -> f64 {
        let period = self.period_ms.max(1);
        let x = ((t + self.phase_ms) % period) as f64 / period as f64;
        let wave = -(2.0 * std::f64::consts::PI * x).cos(); // [-1, 1]
        (0.5 + 0.5 * self.amplitude.clamp(0.0, 1.0) * wave).clamp(0.0, 1.0)
    }

    /// Replicas the load curve demands at `t` (within the envelope).
    pub fn demand_replicas(&self, t: u64) -> u32 {
        let span = self.max_replicas.saturating_sub(self.min_replicas) as f64;
        self.min_replicas + (self.load(t) * span).round() as u32
    }
}

/// How a job persists progress across fault restarts — the knob the
/// reliability experiments sweep (`experiments::run_fault_tolerance`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CheckpointPolicy {
    /// Idealized continuous checkpointing: every completed millisecond
    /// survives an eviction (the legacy pre-reliability semantics, and
    /// the default).
    Continuous,
    /// Periodic checkpoints every given ms of running wall-clock time
    /// (driven by `Event::CheckpointTick`): an eviction loses the work
    /// done since the last tick.
    Interval(u64),
    /// No checkpointing: an eviction restarts the job from scratch (the
    /// naive-restart baseline).
    None,
}

/// One feasible parallelism shape of a moldable gang: a replica count and
/// the job throughput realized at that count, relative to the full shape
/// (shape 0, throughput 1.0). The scheduler may admit the job at any
/// declared shape (moldable admission) and shrink a running tidal/LOW job
/// down the ladder instead of evicting it (malleable runtime). Shapes are
/// declared in strictly decreasing replica order; wall-clock duration at
/// shape `k` is `duration_ms / throughput`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GangShape {
    /// Pod replicas at this shape (GPUs = replicas × gpus_per_pod).
    pub replicas: u32,
    /// Job throughput relative to shape 0, in (0, 1].
    pub throughput: f64,
}

/// Resource demand for one GPU model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TypedDemand {
    pub gpu_type: GpuTypeId,
    /// Pod replicas requesting this model.
    pub replicas: u32,
    /// GPUs per replica (1..=gpus_per_node; whole-node jobs use 8).
    pub gpus_per_pod: u32,
}

impl TypedDemand {
    pub fn total_gpus(&self) -> u32 {
        self.replicas * self.gpus_per_pod
    }
}

/// A submitted job.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub id: JobId,
    pub tenant: TenantId,
    pub kind: JobKind,
    pub priority: Priority,
    /// Gang (all-or-nothing) scheduling semantics (§3.3.2). Training jobs
    /// are gang; classic inference replicas are not.
    pub gang: bool,
    /// Per-GPU-model demands. Single-entry for homogeneous jobs; multiple
    /// entries trigger cross-pool joint admission.
    pub demands: Vec<TypedDemand>,
    /// Submission time (ms since sim start).
    pub submit_ms: u64,
    /// Service/run duration once scheduled (ms).
    pub duration_ms: u64,
    /// Placement strategy; `None` = scheduler default for the kind.
    pub strategy: Option<PlacementStrategy>,
    /// Whether the job needs its pods inside one HBD (EP/TP patterns,
    /// §3.3.5 scale-up).
    pub needs_hbd: bool,
    /// Elastic replica-set parameters (inference autoscaling). `Some`
    /// marks this job as the *base* replica set of an elastic service;
    /// its `demands` hold `min_replicas` and the controller grows it via
    /// replica-delta child jobs.
    pub elastic: Option<ElasticService>,
    /// Replica-delta marker: `Some(parent)` makes this job a scale-up
    /// child of an elastic service. Such jobs are eligible for
    /// SLO-pressure reclamation of tidal training on placement failure.
    pub service: Option<JobId>,
    /// Tidally-backfilled training: runs opportunistically in capacity
    /// freed by inference scale-down and is the designated victim of
    /// SLO-pressure preemption when inference must scale back up.
    pub tidal: bool,
    /// Progress persistence across restarts (fault evictions and
    /// preemptions): what an eviction costs in redone work.
    pub checkpoint: CheckpointPolicy,
    /// Feasible parallelism shapes of a moldable gang, in strictly
    /// decreasing replica order; `shapes[0]` is the full (preferred)
    /// shape with throughput 1.0 and must match the submitted `demands`.
    /// Empty (the default) = fixed-shape job; the moldable/malleable
    /// machinery never touches it.
    pub shapes: Vec<GangShape>,
}

impl JobSpec {
    /// Total GPUs across all demands.
    pub fn total_gpus(&self) -> u32 {
        self.demands.iter().map(TypedDemand::total_gpus).sum()
    }

    /// Total pod replicas.
    pub fn total_replicas(&self) -> u32 {
        self.demands.iter().map(|d| d.replicas).sum()
    }

    /// The single GPU type for homogeneous jobs.
    pub fn sole_type(&self) -> Option<GpuTypeId> {
        match self.demands.as_slice() {
            [d] => Some(d.gpu_type),
            _ => None,
        }
    }

    /// Builder for the common homogeneous case.
    pub fn homogeneous(
        id: JobId,
        tenant: TenantId,
        kind: JobKind,
        gpu_type: GpuTypeId,
        replicas: u32,
        gpus_per_pod: u32,
    ) -> JobSpec {
        JobSpec {
            id,
            tenant,
            kind,
            priority: Priority::NORMAL,
            gang: kind == JobKind::Training,
            demands: vec![TypedDemand {
                gpu_type,
                replicas,
                gpus_per_pod,
            }],
            submit_ms: 0,
            duration_ms: 60_000,
            strategy: None,
            needs_hbd: false,
            elastic: None,
            service: None,
            tidal: false,
            checkpoint: CheckpointPolicy::Continuous,
            shapes: Vec::new(),
        }
    }

    pub fn with_priority(mut self, p: Priority) -> JobSpec {
        self.priority = p;
        self
    }

    pub fn with_times(mut self, submit_ms: u64, duration_ms: u64) -> JobSpec {
        self.submit_ms = submit_ms;
        self.duration_ms = duration_ms;
        self
    }

    pub fn with_strategy(mut self, s: PlacementStrategy) -> JobSpec {
        self.strategy = Some(s);
        self
    }

    pub fn with_gang(mut self, gang: bool) -> JobSpec {
        self.gang = gang;
        self
    }

    /// Turn this job into an elastic service base: replicas pinned to
    /// `min_replicas`, the envelope/curve recorded for the controller.
    pub fn with_elastic(mut self, e: ElasticService) -> JobSpec {
        for d in &mut self.demands {
            d.replicas = e.min_replicas.max(1);
        }
        self.elastic = Some(e);
        self
    }

    /// Mark as tidal backfill (preemptible under SLO pressure).
    pub fn with_tidal(mut self) -> JobSpec {
        self.tidal = true;
        self
    }

    /// Set the checkpoint/restart policy.
    pub fn with_checkpoint(mut self, c: CheckpointPolicy) -> JobSpec {
        self.checkpoint = c;
        self
    }

    /// GPUs per replica of an elastic service (sole-demand services).
    pub fn gpus_per_replica(&self) -> u32 {
        self.demands.first().map(|d| d.gpus_per_pod).unwrap_or(0)
    }

    /// Declare the moldable shape ladder and pin the demands to the full
    /// shape (`shapes[0]`). Only meaningful on sole-demand gang jobs; the
    /// ladder must be strictly decreasing in replicas with shape 0 at
    /// throughput 1.0.
    pub fn with_shapes(mut self, shapes: Vec<GangShape>) -> JobSpec {
        debug_assert!(
            shapes.windows(2).all(|w| w[0].replicas > w[1].replicas),
            "shape ladder must be strictly decreasing"
        );
        if let (Some(first), [d]) = (shapes.first(), self.demands.as_mut_slice()) {
            d.replicas = first.replicas;
        }
        self.shapes = shapes;
        self
    }

    /// A moldable job declares at least two feasible shapes.
    pub fn moldable(&self) -> bool {
        self.shapes.len() > 1
    }

    /// Index of the shape the demands currently realize (replica-count
    /// match); `None` for fixed-shape jobs. Replica counts are strictly
    /// decreasing, so the match is unique.
    pub fn active_shape(&self) -> Option<usize> {
        let r = self.total_replicas();
        self.shapes.iter().position(|s| s.replicas == r)
    }

    /// Throughput of the active shape relative to the full shape (1.0 for
    /// fixed-shape jobs and for the full shape itself).
    pub fn active_throughput(&self) -> f64 {
        self.active_shape()
            .map(|k| self.shapes[k].throughput)
            .unwrap_or(1.0)
    }

    /// Total GPUs of the *full* shape — the job's work content measured in
    /// full-shape GPU-time. Equals `total_gpus()` for fixed-shape jobs.
    pub fn base_total_gpus(&self) -> u32 {
        match (self.shapes.first(), self.demands.first()) {
            (Some(s), Some(d)) => s.replicas * d.gpus_per_pod,
            _ => self.total_gpus(),
        }
    }

    /// Rewrite the demands to shape `k` of the ladder. Sole-demand jobs
    /// only (the generator never declares shapes on multi-type jobs).
    pub fn apply_shape(&mut self, k: usize) {
        debug_assert!(k < self.shapes.len());
        let replicas = self.shapes[k].replicas;
        if let [d] = self.demands.as_mut_slice() {
            d.replicas = replicas;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> JobSpec {
        JobSpec::homogeneous(JobId(1), TenantId(0), JobKind::Training, GpuTypeId(0), 4, 8)
    }

    #[test]
    fn totals() {
        let j = spec();
        assert_eq!(j.total_gpus(), 32);
        assert_eq!(j.total_replicas(), 4);
        assert_eq!(j.sole_type(), Some(GpuTypeId(0)));
    }

    #[test]
    fn training_defaults_to_gang() {
        assert!(spec().gang);
        let inf = JobSpec::homogeneous(
            JobId(2),
            TenantId(0),
            JobKind::Inference,
            GpuTypeId(0),
            2,
            1,
        );
        assert!(!inf.gang);
    }

    #[test]
    fn multi_type_has_no_sole_type() {
        let mut j = spec();
        j.demands.push(TypedDemand {
            gpu_type: GpuTypeId(1),
            replicas: 1,
            gpus_per_pod: 4,
        });
        assert_eq!(j.sole_type(), None);
        assert_eq!(j.total_gpus(), 36);
    }

    #[test]
    fn strategy_roundtrip() {
        for s in [
            PlacementStrategy::NativeFirstFit,
            PlacementStrategy::Binpack,
            PlacementStrategy::EBinpack,
            PlacementStrategy::Spread,
            PlacementStrategy::ESpread,
        ] {
            assert_eq!(PlacementStrategy::parse(s.as_str()), Some(s));
        }
        assert_eq!(PlacementStrategy::parse("bogus"), None);
    }

    #[test]
    fn kind_roundtrip() {
        for k in [JobKind::Training, JobKind::Inference, JobKind::Dev] {
            assert_eq!(JobKind::parse(k.as_str()), Some(k));
        }
    }

    #[test]
    fn priority_ordering() {
        assert!(Priority::HIGH > Priority::NORMAL);
        assert!(Priority::NORMAL > Priority::LOW);
    }

    #[test]
    fn aging_never_crosses_a_class_boundary() {
        for boost in 0..=u8::MAX {
            assert_eq!(Priority::LOW.aged(boost).class_index(), 0);
            assert_eq!(Priority::NORMAL.aged(boost).class_index(), 1);
            assert_eq!(Priority::HIGH.aged(boost).class_index(), 2);
        }
        assert_eq!(Priority::LOW.aged(200), Priority(3));
        assert_eq!(Priority::NORMAL.aged(200), Priority(7));
        assert_eq!(Priority::HIGH.aged(200), Priority(208));
        assert_eq!(Priority::HIGH.aged(255), Priority(255));
    }

    #[test]
    fn elastic_curve_troughs_and_peaks() {
        let e = ElasticService {
            min_replicas: 2,
            max_replicas: 10,
            phase_ms: 0,
            amplitude: 1.0,
            period_ms: ElasticService::DAY_MS,
        };
        // Trough at phase 0 (night), peak half a day later.
        assert_eq!(e.demand_replicas(0), 2);
        assert_eq!(e.demand_replicas(ElasticService::DAY_MS / 2), 10);
        // Quarter-day sits at the midpoint.
        assert_eq!(e.demand_replicas(ElasticService::DAY_MS / 4), 6);
        // Periodic.
        assert_eq!(e.demand_replicas(100), e.demand_replicas(100 + ElasticService::DAY_MS));
        // Amplitude 0 pins the midpoint.
        let flat = ElasticService { amplitude: 0.0, ..e };
        for t in [0, ElasticService::DAY_MS / 2] {
            assert_eq!(flat.demand_replicas(t), 6);
        }
    }

    #[test]
    fn shape_ladder_pins_demands_and_tracks_active_shape() {
        let ladder = vec![
            GangShape {
                replicas: 4,
                throughput: 1.0,
            },
            GangShape {
                replicas: 2,
                throughput: 0.55,
            },
            GangShape {
                replicas: 1,
                throughput: 0.3,
            },
        ];
        let mut j = spec().with_shapes(ladder);
        assert!(j.moldable());
        assert_eq!(j.total_replicas(), 4);
        assert_eq!(j.active_shape(), Some(0));
        assert_eq!(j.base_total_gpus(), 32);
        assert!((j.active_throughput() - 1.0).abs() < 1e-12);
        j.apply_shape(1);
        assert_eq!(j.total_replicas(), 2);
        assert_eq!(j.total_gpus(), 16);
        assert_eq!(j.active_shape(), Some(1));
        assert!((j.active_throughput() - 0.55).abs() < 1e-12);
        // Work content stays measured at the full shape.
        assert_eq!(j.base_total_gpus(), 32);
    }

    #[test]
    fn fixed_shape_jobs_report_no_shape_state() {
        let j = spec();
        assert!(!j.moldable());
        assert_eq!(j.active_shape(), None);
        assert!((j.active_throughput() - 1.0).abs() < 1e-12);
        assert_eq!(j.base_total_gpus(), j.total_gpus());
    }

    #[test]
    fn checkpoint_policy_defaults_continuous() {
        let j = spec();
        assert_eq!(j.checkpoint, CheckpointPolicy::Continuous);
        let naive = spec().with_checkpoint(CheckpointPolicy::None);
        assert_eq!(naive.checkpoint, CheckpointPolicy::None);
        let ckpt = spec().with_checkpoint(CheckpointPolicy::Interval(900_000));
        assert_eq!(ckpt.checkpoint, CheckpointPolicy::Interval(900_000));
    }

    #[test]
    fn with_elastic_pins_base_to_min() {
        let e = ElasticService {
            min_replicas: 3,
            max_replicas: 12,
            phase_ms: 0,
            amplitude: 0.8,
            period_ms: ElasticService::DAY_MS,
        };
        let j = JobSpec::homogeneous(JobId(9), TenantId(0), JobKind::Inference, GpuTypeId(0), 8, 1)
            .with_elastic(e);
        assert_eq!(j.total_replicas(), 3);
        assert_eq!(j.gpus_per_replica(), 1);
        assert!(j.elastic.is_some());
        assert!(!j.tidal);
        let t = JobSpec::homogeneous(JobId(10), TenantId(0), JobKind::Training, GpuTypeId(0), 1, 8)
            .with_tidal();
        assert!(t.tidal);
    }
}
