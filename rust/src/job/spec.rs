//! Job and pod specifications — what users submit.
//!
//! The paper's workload taxonomy (§2): LLM distributed training (gang,
//! large), inference services (non-gang, small, HA-sensitive), and
//! dev/debug tasks (small, latency-sensitive). Jobs may request multiple
//! GPU models in heterogeneous clusters (cross-pool joint admission,
//! §3.2.1); the common case is a single model.

use crate::cluster::ids::{GpuTypeId, JobId, TenantId};

/// Task category (§2 "Diverse Task Types").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobKind {
    Training,
    Inference,
    Dev,
}

impl JobKind {
    pub fn as_str(self) -> &'static str {
        match self {
            JobKind::Training => "training",
            JobKind::Inference => "inference",
            JobKind::Dev => "dev",
        }
    }

    pub fn parse(s: &str) -> Option<JobKind> {
        match s {
            "training" => Some(JobKind::Training),
            "inference" => Some(JobKind::Inference),
            "dev" => Some(JobKind::Dev),
            _ => None,
        }
    }
}

/// Scheduling priority; higher value = more important.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Priority(pub u8);

impl Priority {
    pub const LOW: Priority = Priority(0);
    pub const NORMAL: Priority = Priority(4);
    pub const HIGH: Priority = Priority(8);
}

/// Placement strategy requested for (or assigned to) a job (§3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementStrategy {
    /// Baseline: first fit in node-id order, no consolidation ("native
    /// scheduling system" in §5).
    NativeFirstFit,
    /// Plain Binpack: fill partially-used nodes first (§3.3.3).
    Binpack,
    /// Enhanced Binpack: node-level co-location + LeafGroup consolidation
    /// (§3.3.3 E-Binpack).
    EBinpack,
    /// Plain Spread: spread replicas across nodes (§3.3.4).
    Spread,
    /// Enhanced Spread: inference dedicated zone + E-Binpack overflow
    /// (§3.3.4 E-Spread).
    ESpread,
}

impl PlacementStrategy {
    pub fn as_str(self) -> &'static str {
        match self {
            PlacementStrategy::NativeFirstFit => "native",
            PlacementStrategy::Binpack => "binpack",
            PlacementStrategy::EBinpack => "e-binpack",
            PlacementStrategy::Spread => "spread",
            PlacementStrategy::ESpread => "e-spread",
        }
    }

    pub fn parse(s: &str) -> Option<PlacementStrategy> {
        match s {
            "native" => Some(PlacementStrategy::NativeFirstFit),
            "binpack" => Some(PlacementStrategy::Binpack),
            "e-binpack" | "ebinpack" => Some(PlacementStrategy::EBinpack),
            "spread" => Some(PlacementStrategy::Spread),
            "e-spread" | "espread" => Some(PlacementStrategy::ESpread),
            _ => None,
        }
    }
}

/// Resource demand for one GPU model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TypedDemand {
    pub gpu_type: GpuTypeId,
    /// Pod replicas requesting this model.
    pub replicas: u32,
    /// GPUs per replica (1..=gpus_per_node; whole-node jobs use 8).
    pub gpus_per_pod: u32,
}

impl TypedDemand {
    pub fn total_gpus(&self) -> u32 {
        self.replicas * self.gpus_per_pod
    }
}

/// A submitted job.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub id: JobId,
    pub tenant: TenantId,
    pub kind: JobKind,
    pub priority: Priority,
    /// Gang (all-or-nothing) scheduling semantics (§3.3.2). Training jobs
    /// are gang; classic inference replicas are not.
    pub gang: bool,
    /// Per-GPU-model demands. Single-entry for homogeneous jobs; multiple
    /// entries trigger cross-pool joint admission.
    pub demands: Vec<TypedDemand>,
    /// Submission time (ms since sim start).
    pub submit_ms: u64,
    /// Service/run duration once scheduled (ms).
    pub duration_ms: u64,
    /// Placement strategy; `None` = scheduler default for the kind.
    pub strategy: Option<PlacementStrategy>,
    /// Whether the job needs its pods inside one HBD (EP/TP patterns,
    /// §3.3.5 scale-up).
    pub needs_hbd: bool,
}

impl JobSpec {
    /// Total GPUs across all demands.
    pub fn total_gpus(&self) -> u32 {
        self.demands.iter().map(TypedDemand::total_gpus).sum()
    }

    /// Total pod replicas.
    pub fn total_replicas(&self) -> u32 {
        self.demands.iter().map(|d| d.replicas).sum()
    }

    /// The single GPU type for homogeneous jobs.
    pub fn sole_type(&self) -> Option<GpuTypeId> {
        match self.demands.as_slice() {
            [d] => Some(d.gpu_type),
            _ => None,
        }
    }

    /// Builder for the common homogeneous case.
    pub fn homogeneous(
        id: JobId,
        tenant: TenantId,
        kind: JobKind,
        gpu_type: GpuTypeId,
        replicas: u32,
        gpus_per_pod: u32,
    ) -> JobSpec {
        JobSpec {
            id,
            tenant,
            kind,
            priority: Priority::NORMAL,
            gang: kind == JobKind::Training,
            demands: vec![TypedDemand {
                gpu_type,
                replicas,
                gpus_per_pod,
            }],
            submit_ms: 0,
            duration_ms: 60_000,
            strategy: None,
            needs_hbd: false,
        }
    }

    pub fn with_priority(mut self, p: Priority) -> JobSpec {
        self.priority = p;
        self
    }

    pub fn with_times(mut self, submit_ms: u64, duration_ms: u64) -> JobSpec {
        self.submit_ms = submit_ms;
        self.duration_ms = duration_ms;
        self
    }

    pub fn with_strategy(mut self, s: PlacementStrategy) -> JobSpec {
        self.strategy = Some(s);
        self
    }

    pub fn with_gang(mut self, gang: bool) -> JobSpec {
        self.gang = gang;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> JobSpec {
        JobSpec::homogeneous(JobId(1), TenantId(0), JobKind::Training, GpuTypeId(0), 4, 8)
    }

    #[test]
    fn totals() {
        let j = spec();
        assert_eq!(j.total_gpus(), 32);
        assert_eq!(j.total_replicas(), 4);
        assert_eq!(j.sole_type(), Some(GpuTypeId(0)));
    }

    #[test]
    fn training_defaults_to_gang() {
        assert!(spec().gang);
        let inf = JobSpec::homogeneous(
            JobId(2),
            TenantId(0),
            JobKind::Inference,
            GpuTypeId(0),
            2,
            1,
        );
        assert!(!inf.gang);
    }

    #[test]
    fn multi_type_has_no_sole_type() {
        let mut j = spec();
        j.demands.push(TypedDemand {
            gpu_type: GpuTypeId(1),
            replicas: 1,
            gpus_per_pod: 4,
        });
        assert_eq!(j.sole_type(), None);
        assert_eq!(j.total_gpus(), 36);
    }

    #[test]
    fn strategy_roundtrip() {
        for s in [
            PlacementStrategy::NativeFirstFit,
            PlacementStrategy::Binpack,
            PlacementStrategy::EBinpack,
            PlacementStrategy::Spread,
            PlacementStrategy::ESpread,
        ] {
            assert_eq!(PlacementStrategy::parse(s.as_str()), Some(s));
        }
        assert_eq!(PlacementStrategy::parse("bogus"), None);
    }

    #[test]
    fn kind_roundtrip() {
        for k in [JobKind::Training, JobKind::Inference, JobKind::Dev] {
            assert_eq!(JobKind::parse(k.as_str()), Some(k));
        }
    }

    #[test]
    fn priority_ordering() {
        assert!(Priority::HIGH > Priority::NORMAL);
        assert!(Priority::NORMAL > Priority::LOW);
    }
}
