//! The scale-out RDMA fabric (leaf / spine / superspine) and scale-up HBD
//! domains (§3.3.5), plus the NodeNetGroup abstraction (§3.4.2).
//!
//! The fabric is a static tree built once by `cluster::builder`; distance
//! queries are O(1) from precomputed per-node group/spine/superspine ids.

use std::collections::HashSet;
use std::fmt;

use super::ids::{GroupId, HbdId, NodeId, SpineId, SuperSpineId};

/// Communication tier between two nodes — lower is better (§3.3.5 orders
/// preference: same leaf < same spine < same superspine < crossing the
/// core layer). `CrossSuperSpine` is the truthful worst case: traffic
/// between different superspines transits the core and is the §3.3.5
/// overhead E-Binpack large gangs must avoid, so it scores strictly worse
/// than `SameSuperSpine`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Tier {
    SameNode = 0,
    SameLeaf = 1,
    SameSpine = 2,
    SameSuperSpine = 3,
    CrossSuperSpine = 4,
}

impl Tier {
    pub fn as_f32(self) -> f32 {
        self as u8 as f32
    }

    /// The worst (largest) tier — what an empty placement defaults to in
    /// the feature-8 contract.
    pub const WORST: Tier = Tier::CrossSuperSpine;
}

/// Error from [`Fabric::finalize`]: the builder referenced fewer nodes
/// than exist, leaving a stray node outside every NodeNetGroup. Letting
/// such a node through would carry `GroupId(u32::MAX)` into `group_of`
/// and the `NodeIndex` in release builds — a silent corruption — so
/// finalization refuses instead.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OrphanNodeError(pub NodeId);

impl fmt::Display for OrphanNodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} belongs to no NodeNetGroup — every node must be assigned before finalize",
            self.0
        )
    }
}

impl std::error::Error for OrphanNodeError {}

/// One NodeNetGroup = one LeafGroup: the basic scheduling management unit.
#[derive(Debug, Clone, PartialEq)]
pub struct NetGroup {
    pub id: GroupId,
    pub spine: SpineId,
    pub nodes: Vec<NodeId>,
}

/// One spine group (aggregation layer).
#[derive(Debug, Clone, PartialEq)]
pub struct Spine {
    pub id: SpineId,
    pub superspine: SuperSpineId,
    pub groups: Vec<GroupId>,
}

/// One HBD (Hyper Bandwidth Domain): a scale-up island whose member nodes'
/// GPUs are all interconnected at high speed (EP/TP patterns).
#[derive(Debug, Clone, PartialEq)]
pub struct Hbd {
    pub id: HbdId,
    pub nodes: Vec<NodeId>,
}

/// The whole fabric. Per-node lookups are precomputed dense arrays.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Fabric {
    pub groups: Vec<NetGroup>,
    pub spines: Vec<Spine>,
    pub num_superspines: u32,
    pub hbds: Vec<Hbd>,
    node_group: Vec<GroupId>,
    node_spine: Vec<SpineId>,
    node_superspine: Vec<SuperSpineId>,
    node_hbd: Vec<Option<HbdId>>,
}

impl Fabric {
    /// Build the per-node lookup tables; call once after groups/spines/hbds
    /// are populated. `num_nodes` must cover every node referenced.
    ///
    /// Errors if any node in `0..num_nodes` belongs to no NodeNetGroup —
    /// a hard error in every build profile (not just a `debug_assert`),
    /// because an orphan node would otherwise carry sentinel ids into the
    /// distance tables and the free-capacity index silently.
    pub fn finalize(&mut self, num_nodes: usize) -> Result<(), OrphanNodeError> {
        self.node_group = vec![GroupId(u32::MAX); num_nodes];
        self.node_spine = vec![SpineId(u32::MAX); num_nodes];
        self.node_superspine = vec![SuperSpineId(u32::MAX); num_nodes];
        self.node_hbd = vec![None; num_nodes];
        for g in &self.groups {
            let spine = &self.spines[g.spine.index()];
            for &n in &g.nodes {
                self.node_group[n.index()] = g.id;
                self.node_spine[n.index()] = g.spine;
                self.node_superspine[n.index()] = spine.superspine;
            }
        }
        for h in &self.hbds {
            for &n in &h.nodes {
                self.node_hbd[n.index()] = Some(h.id);
            }
        }
        if let Some(orphan) = self
            .node_group
            .iter()
            .position(|g| g.0 == u32::MAX)
            .map(|i| NodeId(i as u32))
        {
            return Err(OrphanNodeError(orphan));
        }
        Ok(())
    }

    #[inline]
    pub fn group_of(&self, n: NodeId) -> GroupId {
        self.node_group[n.index()]
    }

    #[inline]
    pub fn spine_of(&self, n: NodeId) -> SpineId {
        self.node_spine[n.index()]
    }

    #[inline]
    pub fn superspine_of(&self, n: NodeId) -> SuperSpineId {
        self.node_superspine[n.index()]
    }

    #[inline]
    pub fn hbd_of(&self, n: NodeId) -> Option<HbdId> {
        self.node_hbd[n.index()]
    }

    /// Communication tier between two nodes. Truthful across the whole
    /// tree: two nodes under *different* superspines are
    /// [`Tier::CrossSuperSpine`], not collapsed into
    /// [`Tier::SameSuperSpine`].
    pub fn tier(&self, a: NodeId, b: NodeId) -> Tier {
        if a == b {
            Tier::SameNode
        } else if self.group_of(a) == self.group_of(b) {
            Tier::SameLeaf
        } else if self.spine_of(a) == self.spine_of(b) {
            Tier::SameSpine
        } else if self.superspine_of(a) == self.superspine_of(b) {
            Tier::SameSuperSpine
        } else {
            Tier::CrossSuperSpine
        }
    }

    /// Minimum tier from `n` to any node in `placed` ([`Tier::WORST`] when
    /// `placed` is empty) — feature 8 of the scoring contract.
    ///
    /// O(|placed|); the scheduling hot path uses the O(1)
    /// [`GangFootprint::tier_to`] instead, with this scan kept as the
    /// property-test oracle.
    pub fn min_tier_to(&self, n: NodeId, placed: &[NodeId]) -> Tier {
        placed
            .iter()
            .map(|&p| self.tier(n, p))
            .min()
            .unwrap_or(Tier::WORST)
    }

    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    /// Number of distinct NodeNetGroups spanned by a set of nodes — the
    /// numerator of JTTED's NodeNetGroupNum deviation ratio (§4.5).
    pub fn groups_spanned(&self, nodes: &[NodeId]) -> usize {
        Self::distinct(nodes.iter().map(|&n| self.group_of(n)))
    }

    /// Number of distinct spines spanned by a set of nodes — numerator of
    /// the JTTED spine-span deviation ratio.
    pub fn spines_spanned(&self, nodes: &[NodeId]) -> usize {
        Self::distinct(nodes.iter().map(|&n| self.spine_of(n)))
    }

    /// Number of distinct superspines spanned by a set of nodes —
    /// numerator of the JTTED superspine-span deviation ratio (each extra
    /// superspine is a core-layer crossing for the gang's collectives).
    pub fn superspines_spanned(&self, nodes: &[NodeId]) -> usize {
        Self::distinct(nodes.iter().map(|&n| self.superspine_of(n)))
    }

    /// Spines under the superspine of `s` (superspines may be ragged when
    /// the spine count doesn't divide evenly).
    pub fn spines_in_superspine(&self, ss: SuperSpineId) -> usize {
        self.spines.iter().filter(|s| s.superspine == ss).count()
    }

    fn distinct<T: Ord>(it: impl Iterator<Item = T>) -> usize {
        let mut v: Vec<T> = it.collect();
        v.sort_unstable();
        v.dedup();
        v.len()
    }
}

/// Incrementally-maintained topology footprint of one job's in-flight
/// placement: which nodes, NodeNetGroups, spines and superspines the plan
/// already occupies. Answers the feature-8 "minimum tier to any placed
/// pod" query in O(1) per candidate instead of the O(|placed|) scan
/// [`Fabric::min_tier_to`] performs — the difference between
/// O(pods²·candidates) and O(pods·candidates) per gang on the scoring
/// hot path.
///
/// Invariant (property-tested in `tests/prop_invariants.rs`): for every
/// node `n`, `footprint.tier_to(fabric, n)` equals
/// `fabric.min_tier_to(n, placed)` where `placed` is the exact set of
/// nodes recorded via [`GangFootprint::place`].
#[derive(Debug, Clone, Default)]
pub struct GangFootprint {
    nodes: HashSet<NodeId>,
    groups: HashSet<GroupId>,
    spines: HashSet<SpineId>,
    superspines: HashSet<SuperSpineId>,
}

/// Which topology layers a [`GangFootprint::place`] call newly entered.
/// Drives score-row invalidation: only candidates inside a newly-entered
/// layer can have had their minimum tier improved by the placement.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FootprintDelta {
    /// The footprint was empty before this placement (every candidate's
    /// tier changes from [`Tier::WORST`] to its true value).
    pub first_pod: bool,
    pub new_node: bool,
    pub new_group: bool,
    pub new_spine: bool,
    pub new_superspine: bool,
}

impl GangFootprint {
    pub fn new() -> GangFootprint {
        GangFootprint::default()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Record a pod placed on `n`; returns which layers were newly
    /// entered so callers can invalidate exactly the affected score rows.
    pub fn place(&mut self, fabric: &Fabric, n: NodeId) -> FootprintDelta {
        let first_pod = self.nodes.is_empty();
        FootprintDelta {
            first_pod,
            new_node: self.nodes.insert(n),
            new_group: self.groups.insert(fabric.group_of(n)),
            new_spine: self.spines.insert(fabric.spine_of(n)),
            new_superspine: self.superspines.insert(fabric.superspine_of(n)),
        }
    }

    /// O(1) minimum communication tier from `n` to the recorded
    /// placement ([`Tier::WORST`] while empty).
    pub fn tier_to(&self, fabric: &Fabric, n: NodeId) -> Tier {
        if self.nodes.contains(&n) {
            Tier::SameNode
        } else if self.groups.contains(&fabric.group_of(n)) {
            Tier::SameLeaf
        } else if self.spines.contains(&fabric.spine_of(n)) {
            Tier::SameSpine
        } else if self.superspines.contains(&fabric.superspine_of(n)) {
            Tier::SameSuperSpine
        } else {
            Tier::CrossSuperSpine
        }
    }

    pub fn nodes_spanned(&self) -> usize {
        self.nodes.len()
    }

    pub fn groups_spanned(&self) -> usize {
        self.groups.len()
    }

    pub fn spines_spanned(&self) -> usize {
        self.spines.len()
    }

    pub fn superspines_spanned(&self) -> usize {
        self.superspines.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 2 superspines × 2 spines × 2 groups × 2 nodes = 16 nodes.
    fn small_fabric() -> Fabric {
        let mut f = Fabric::default();
        let mut node = 0u32;
        for ss in 0..2u32 {
            for s in 0..2u32 {
                let spine_id = SpineId(ss * 2 + s);
                let mut spine = Spine {
                    id: spine_id,
                    superspine: SuperSpineId(ss),
                    groups: Vec::new(),
                };
                for g in 0..2u32 {
                    let gid = GroupId(spine_id.0 * 2 + g);
                    let nodes = vec![NodeId(node), NodeId(node + 1)];
                    node += 2;
                    spine.groups.push(gid);
                    f.groups.push(NetGroup {
                        id: gid,
                        spine: spine_id,
                        nodes,
                    });
                }
                f.spines.push(spine);
            }
        }
        f.num_superspines = 2;
        f.hbds.push(Hbd {
            id: HbdId(0),
            nodes: vec![NodeId(0), NodeId(1), NodeId(2), NodeId(3)],
        });
        f.finalize(16).unwrap();
        f
    }

    #[test]
    fn tier_orders_correctly() {
        let f = small_fabric();
        assert_eq!(f.tier(NodeId(0), NodeId(0)), Tier::SameNode);
        assert_eq!(f.tier(NodeId(0), NodeId(1)), Tier::SameLeaf);
        assert_eq!(f.tier(NodeId(0), NodeId(2)), Tier::SameSpine);
        assert_eq!(f.tier(NodeId(0), NodeId(4)), Tier::SameSuperSpine);
        // Nodes 8.. sit under superspine 1: a truthful CrossSuperSpine,
        // strictly worse than staying under superspine 0.
        assert_eq!(f.tier(NodeId(0), NodeId(8)), Tier::CrossSuperSpine);
        assert!(Tier::SameLeaf < Tier::SameSpine);
        assert!(Tier::SameSpine < Tier::SameSuperSpine);
        assert!(Tier::SameSuperSpine < Tier::CrossSuperSpine);
        assert_eq!(Tier::WORST, Tier::CrossSuperSpine);
    }

    #[test]
    fn min_tier_to_empty_is_worst() {
        let f = small_fabric();
        assert_eq!(f.min_tier_to(NodeId(0), &[]), Tier::CrossSuperSpine);
        assert_eq!(
            f.min_tier_to(NodeId(0), &[NodeId(4), NodeId(1)]),
            Tier::SameLeaf
        );
        assert_eq!(f.min_tier_to(NodeId(0), &[NodeId(8)]), Tier::CrossSuperSpine);
    }

    #[test]
    fn hbd_membership() {
        let f = small_fabric();
        assert_eq!(f.hbd_of(NodeId(2)), Some(HbdId(0)));
        assert_eq!(f.hbd_of(NodeId(8)), None);
    }

    #[test]
    fn groups_spanned_counts_distinct() {
        let f = small_fabric();
        assert_eq!(f.groups_spanned(&[NodeId(0), NodeId(1)]), 1);
        assert_eq!(f.groups_spanned(&[NodeId(0), NodeId(2), NodeId(3)]), 2);
        assert_eq!(f.groups_spanned(&[]), 0);
    }

    #[test]
    fn spine_and_superspine_spans_count_distinct() {
        let f = small_fabric();
        // Nodes 0 and 2: groups 0/1 under spine 0 — one spine, one superspine.
        assert_eq!(f.spines_spanned(&[NodeId(0), NodeId(2)]), 1);
        assert_eq!(f.superspines_spanned(&[NodeId(0), NodeId(2)]), 1);
        // Nodes 0 and 4: spines 0 and 1, still superspine 0.
        assert_eq!(f.spines_spanned(&[NodeId(0), NodeId(4)]), 2);
        assert_eq!(f.superspines_spanned(&[NodeId(0), NodeId(4)]), 1);
        // Nodes 0 and 8: the core-layer crossing.
        assert_eq!(f.superspines_spanned(&[NodeId(0), NodeId(8)]), 2);
        assert_eq!(f.spines_in_superspine(SuperSpineId(0)), 2);
    }

    #[test]
    fn lookup_tables_consistent() {
        let f = small_fabric();
        for g in &f.groups {
            for &n in &g.nodes {
                assert_eq!(f.group_of(n), g.id);
                assert_eq!(f.spine_of(n), g.spine);
            }
        }
    }

    #[test]
    fn finalize_rejects_orphan_nodes() {
        let mut f = small_fabric();
        // 17 nodes declared, only 16 assigned to groups: hard error, not
        // a debug-only assert.
        let err = f.finalize(17).unwrap_err();
        assert_eq!(err, OrphanNodeError(NodeId(16)));
        assert!(err.to_string().contains("NodeNetGroup"));
        // The valid shape still finalizes.
        assert!(f.finalize(16).is_ok());
    }

    #[test]
    fn footprint_tier_matches_min_tier_scan() {
        let f = small_fabric();
        let mut fp = GangFootprint::new();
        let mut placed: Vec<NodeId> = Vec::new();
        for &n in &[NodeId(5), NodeId(4), NodeId(0), NodeId(12)] {
            // Before and after each placement, the O(1) footprint query
            // must agree with the O(|placed|) oracle for every node.
            for probe in 0..16u32 {
                assert_eq!(
                    fp.tier_to(&f, NodeId(probe)),
                    f.min_tier_to(NodeId(probe), &placed),
                    "probe {probe} diverged with placed {placed:?}"
                );
            }
            fp.place(&f, n);
            placed.push(n);
        }
        assert_eq!(fp.nodes_spanned(), 4);
        assert_eq!(fp.groups_spanned(), 4);
        assert_eq!(fp.superspines_spanned(), 2);
        assert_eq!(fp.superspines_spanned(), f.superspines_spanned(&placed));
        assert_eq!(fp.spines_spanned(), f.spines_spanned(&placed));
    }

    #[test]
    fn footprint_delta_reports_new_layers() {
        let f = small_fabric();
        let mut fp = GangFootprint::new();
        let d = fp.place(&f, NodeId(0));
        assert!(d.first_pod && d.new_node && d.new_group && d.new_spine && d.new_superspine);
        // Same leaf: nothing above the group is new.
        let d = fp.place(&f, NodeId(1));
        assert!(!d.first_pod && d.new_node && !d.new_group && !d.new_spine);
        // Same spine, new group.
        let d = fp.place(&f, NodeId(2));
        assert!(d.new_group && !d.new_spine && !d.new_superspine);
        // New superspine.
        let d = fp.place(&f, NodeId(8));
        assert!(d.new_group && d.new_spine && d.new_superspine);
    }
}
