//! The scale-out RDMA fabric (leaf / spine / superspine) and scale-up HBD
//! domains (§3.3.5), plus the NodeNetGroup abstraction (§3.4.2).
//!
//! The fabric is a static tree built once by `cluster::builder`; distance
//! queries are O(1) from precomputed per-node group/spine/superspine ids.

use super::ids::{GroupId, HbdId, NodeId, SpineId, SuperSpineId};

/// Communication tier between two nodes — lower is better (§3.3.5 orders
/// preference: same leaf < same spine < same superspine).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Tier {
    SameNode = 0,
    SameLeaf = 1,
    SameSpine = 2,
    SameSuperSpine = 3,
}

impl Tier {
    pub fn as_f32(self) -> f32 {
        self as u8 as f32
    }
}

/// One NodeNetGroup = one LeafGroup: the basic scheduling management unit.
#[derive(Debug, Clone, PartialEq)]
pub struct NetGroup {
    pub id: GroupId,
    pub spine: SpineId,
    pub nodes: Vec<NodeId>,
}

/// One spine group (aggregation layer).
#[derive(Debug, Clone, PartialEq)]
pub struct Spine {
    pub id: SpineId,
    pub superspine: SuperSpineId,
    pub groups: Vec<GroupId>,
}

/// One HBD (Hyper Bandwidth Domain): a scale-up island whose member nodes'
/// GPUs are all interconnected at high speed (EP/TP patterns).
#[derive(Debug, Clone, PartialEq)]
pub struct Hbd {
    pub id: HbdId,
    pub nodes: Vec<NodeId>,
}

/// The whole fabric. Per-node lookups are precomputed dense arrays.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Fabric {
    pub groups: Vec<NetGroup>,
    pub spines: Vec<Spine>,
    pub num_superspines: u32,
    pub hbds: Vec<Hbd>,
    node_group: Vec<GroupId>,
    node_spine: Vec<SpineId>,
    node_superspine: Vec<SuperSpineId>,
    node_hbd: Vec<Option<HbdId>>,
}

impl Fabric {
    /// Build the per-node lookup tables; call once after groups/spines/hbds
    /// are populated. `num_nodes` must cover every node referenced.
    pub fn finalize(&mut self, num_nodes: usize) {
        self.node_group = vec![GroupId(u32::MAX); num_nodes];
        self.node_spine = vec![SpineId(u32::MAX); num_nodes];
        self.node_superspine = vec![SuperSpineId(u32::MAX); num_nodes];
        self.node_hbd = vec![None; num_nodes];
        for g in &self.groups {
            let spine = &self.spines[g.spine.index()];
            for &n in &g.nodes {
                self.node_group[n.index()] = g.id;
                self.node_spine[n.index()] = g.spine;
                self.node_superspine[n.index()] = spine.superspine;
            }
        }
        for h in &self.hbds {
            for &n in &h.nodes {
                self.node_hbd[n.index()] = Some(h.id);
            }
        }
        debug_assert!(
            self.node_group.iter().all(|g| g.0 != u32::MAX),
            "every node must belong to a NodeNetGroup"
        );
    }

    #[inline]
    pub fn group_of(&self, n: NodeId) -> GroupId {
        self.node_group[n.index()]
    }

    #[inline]
    pub fn spine_of(&self, n: NodeId) -> SpineId {
        self.node_spine[n.index()]
    }

    #[inline]
    pub fn superspine_of(&self, n: NodeId) -> SuperSpineId {
        self.node_superspine[n.index()]
    }

    #[inline]
    pub fn hbd_of(&self, n: NodeId) -> Option<HbdId> {
        self.node_hbd[n.index()]
    }

    /// Communication tier between two nodes.
    pub fn tier(&self, a: NodeId, b: NodeId) -> Tier {
        if a == b {
            Tier::SameNode
        } else if self.group_of(a) == self.group_of(b) {
            Tier::SameLeaf
        } else if self.spine_of(a) == self.spine_of(b) {
            Tier::SameSpine
        } else {
            Tier::SameSuperSpine
        }
    }

    /// Minimum tier from `n` to any node in `placed` (3 when `placed` empty) —
    /// feature 8 of the scoring contract.
    pub fn min_tier_to(&self, n: NodeId, placed: &[NodeId]) -> Tier {
        placed
            .iter()
            .map(|&p| self.tier(n, p))
            .min()
            .unwrap_or(Tier::SameSuperSpine)
    }

    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    /// Number of distinct NodeNetGroups spanned by a set of nodes — the
    /// numerator of JTTED's NodeNetGroupNum deviation ratio (§4.5).
    pub fn groups_spanned(&self, nodes: &[NodeId]) -> usize {
        let mut gs: Vec<GroupId> = nodes.iter().map(|&n| self.group_of(n)).collect();
        gs.sort_unstable();
        gs.dedup();
        gs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 2 superspines × 2 spines × 2 groups × 2 nodes = 16 nodes.
    fn small_fabric() -> Fabric {
        let mut f = Fabric::default();
        let mut node = 0u32;
        for ss in 0..2u32 {
            for s in 0..2u32 {
                let spine_id = SpineId(ss * 2 + s);
                let mut spine = Spine {
                    id: spine_id,
                    superspine: SuperSpineId(ss),
                    groups: Vec::new(),
                };
                for g in 0..2u32 {
                    let gid = GroupId(spine_id.0 * 2 + g);
                    let nodes = vec![NodeId(node), NodeId(node + 1)];
                    node += 2;
                    spine.groups.push(gid);
                    f.groups.push(NetGroup {
                        id: gid,
                        spine: spine_id,
                        nodes,
                    });
                }
                f.spines.push(spine);
            }
        }
        f.num_superspines = 2;
        f.hbds.push(Hbd {
            id: HbdId(0),
            nodes: vec![NodeId(0), NodeId(1), NodeId(2), NodeId(3)],
        });
        f.finalize(16);
        f
    }

    #[test]
    fn tier_orders_correctly() {
        let f = small_fabric();
        assert_eq!(f.tier(NodeId(0), NodeId(0)), Tier::SameNode);
        assert_eq!(f.tier(NodeId(0), NodeId(1)), Tier::SameLeaf);
        assert_eq!(f.tier(NodeId(0), NodeId(2)), Tier::SameSpine);
        assert_eq!(f.tier(NodeId(0), NodeId(4)), Tier::SameSuperSpine);
        assert_eq!(f.tier(NodeId(0), NodeId(8)), Tier::SameSuperSpine);
        assert!(Tier::SameLeaf < Tier::SameSpine);
    }

    #[test]
    fn min_tier_to_empty_is_worst() {
        let f = small_fabric();
        assert_eq!(f.min_tier_to(NodeId(0), &[]), Tier::SameSuperSpine);
        assert_eq!(
            f.min_tier_to(NodeId(0), &[NodeId(4), NodeId(1)]),
            Tier::SameLeaf
        );
    }

    #[test]
    fn hbd_membership() {
        let f = small_fabric();
        assert_eq!(f.hbd_of(NodeId(2)), Some(HbdId(0)));
        assert_eq!(f.hbd_of(NodeId(8)), None);
    }

    #[test]
    fn groups_spanned_counts_distinct() {
        let f = small_fabric();
        assert_eq!(f.groups_spanned(&[NodeId(0), NodeId(1)]), 1);
        assert_eq!(f.groups_spanned(&[NodeId(0), NodeId(2), NodeId(3)]), 2);
        assert_eq!(f.groups_spanned(&[]), 0);
    }

    #[test]
    fn lookup_tables_consistent() {
        let f = small_fabric();
        for g in &f.groups {
            for &n in &g.nodes {
                assert_eq!(f.group_of(n), g.id);
                assert_eq!(f.spine_of(n), g.spine);
            }
        }
    }
}
