//! Scheduling snapshots (§3.4.3).
//!
//! Before each cycle the scheduler works against a consistent copy of the
//! resource state. The naive approach deep-copies everything; Kant's
//! optimization maintains a persistent snapshot and applies only the delta
//! recorded in [`ClusterState`]'s mutation log since the last cycle —
//! "copies only the data portions modified since the last scheduling
//! cycle", which the paper reports cut RSCH CPU load by >50 % on a
//! 1,000-node cluster. Both modes are implemented; equivalence is
//! property-tested and the ablation bench measures the gap.

use super::ids::{GpuTypeId, GroupId, NodeId};
use super::index::NodeIndex;
use super::node::Zone;
use super::state::ClusterState;

/// Dense, scoring-ready record of one node. This is what feature extraction
/// reads — both the native Rust scorer and the XLA feature packer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeRecord {
    pub id: NodeId,
    pub gpu_type: GpuTypeId,
    pub group: GroupId,
    pub free: u32,
    pub total: u32,
    pub alloc: u32,
    pub healthy: bool,
    pub in_inference_zone: bool,
    pub hbd_free: u32,
    pub largest_free_island: u32,
}

/// Aggregated record of one NodeNetGroup.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct GroupRecord {
    pub free: u32,
    pub total: u32,
    /// Nodes with every GPU free (candidates for whole-node jobs).
    pub whole_free_nodes: u32,
    /// Fraction of member nodes in the inference dedicated zone.
    pub zone_frac: f32,
    /// Fraction of member nodes that are schedulable.
    pub healthy_frac: f32,
}

/// How the snapshot refreshes from state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SnapshotMode {
    /// Rebuild every record each cycle (the baseline the paper measures
    /// against).
    DeepCopy,
    /// Apply only the mutation-log delta since the previous refresh.
    Incremental,
}

/// A consistent scheduling-time view of the cluster.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub nodes: Vec<NodeRecord>,
    pub groups: Vec<GroupRecord>,
    mode: SnapshotMode,
    /// Mutation-log cursor (Incremental mode).
    cursor: u64,
    initialized: bool,
    /// Optional free-capacity index over the records (see
    /// [`crate::cluster::index`]), kept in lockstep by the same
    /// mutation-log delta that refreshes the records themselves.
    index: Option<NodeIndex>,
    /// Refresh-cost counters for the §3.4.3 ablation.
    pub stats: SnapshotStats,
}

/// Counters proving how much work each refresh does.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SnapshotStats {
    pub refreshes: u64,
    pub node_records_rebuilt: u64,
    pub full_rebuilds: u64,
}

impl Snapshot {
    pub fn new(mode: SnapshotMode) -> Snapshot {
        Snapshot {
            nodes: Vec::new(),
            groups: Vec::new(),
            mode,
            cursor: 0,
            initialized: false,
            index: None,
            stats: SnapshotStats::default(),
        }
    }

    /// Like [`Snapshot::new`], optionally carrying a [`NodeIndex`] that is
    /// maintained from the same mutation-log delta as the records.
    pub fn with_index(mode: SnapshotMode, indexed: bool) -> Snapshot {
        let mut s = Snapshot::new(mode);
        if indexed {
            s.index = Some(NodeIndex::default());
        }
        s
    }

    /// The free-capacity index, if this snapshot maintains one.
    pub fn index(&self) -> Option<&NodeIndex> {
        self.index.as_ref()
    }

    pub fn mode(&self) -> SnapshotMode {
        self.mode
    }

    /// Bring the snapshot up to date with `state`.
    pub fn refresh(&mut self, state: &ClusterState) {
        self.stats.refreshes += 1;
        match self.mode {
            SnapshotMode::DeepCopy => self.full_rebuild(state),
            SnapshotMode::Incremental => {
                if !self.initialized {
                    self.full_rebuild(state);
                } else {
                    match state.log_since(self.cursor) {
                        None => self.full_rebuild(state), // Log compacted past us.
                        Some(touched) => {
                            let touched: Vec<NodeId> = {
                                let mut t = touched.to_vec();
                                t.sort_unstable();
                                t.dedup();
                                t
                            };
                            for n in touched {
                                self.rebuild_node(state, n);
                                self.stats.node_records_rebuilt += 1;
                            }
                            self.cursor = state.log_head();
                        }
                    }
                }
            }
        }
    }

    fn full_rebuild(&mut self, state: &ClusterState) {
        self.stats.full_rebuilds += 1;
        self.stats.node_records_rebuilt += state.nodes.len() as u64;
        self.nodes = state
            .nodes
            .iter()
            .map(|n| {
                let gpu_type = state.gpu_type(n.gpu_type);
                NodeRecord {
                    id: n.id,
                    gpu_type: n.gpu_type,
                    group: n.group,
                    free: n.free_gpus(),
                    total: n.total_gpus(),
                    alloc: n.allocated_gpus(),
                    healthy: n.health.schedulable(),
                    in_inference_zone: n.zone == Zone::InferenceDedicated,
                    hbd_free: n.hbd.map(|h| state.hbd_free(h)).unwrap_or(0),
                    largest_free_island: n.largest_free_island(gpu_type),
                }
            })
            .collect();
        self.rebuild_all_groups(state);
        if let Some(ix) = &mut self.index {
            *ix = NodeIndex::from_records(&self.nodes, state.fabric.num_groups());
        }
        self.cursor = state.log_head();
        self.initialized = true;
    }

    fn rebuild_node(&mut self, state: &ClusterState, id: NodeId) {
        let n = state.node(id);
        let gpu_type = state.gpu_type(n.gpu_type);
        let rec = NodeRecord {
            id: n.id,
            gpu_type: n.gpu_type,
            group: n.group,
            free: n.free_gpus(),
            total: n.total_gpus(),
            alloc: n.allocated_gpus(),
            healthy: n.health.schedulable(),
            in_inference_zone: n.zone == Zone::InferenceDedicated,
            hbd_free: n.hbd.map(|h| state.hbd_free(h)).unwrap_or(0),
            largest_free_island: n.largest_free_island(gpu_type),
        };
        self.nodes[id.index()] = rec;
        if let Some(ix) = &mut self.index {
            ix.update_record(&rec);
        }
        self.rebuild_group(state, n.group);
        // HBD free counts are cluster aggregates: any member node's record
        // may be stale after a mutation elsewhere in the domain. Refresh
        // records of HBD siblings cheaply from the state aggregate.
        if let Some(h) = n.hbd {
            let free = state.hbd_free(h);
            for &sib in &state.fabric.hbds[h.index()].nodes {
                self.nodes[sib.index()].hbd_free = free;
            }
        }
    }

    fn rebuild_group(&mut self, state: &ClusterState, g: GroupId) {
        let members = &state.fabric.groups[g.index()].nodes;
        let mut rec = GroupRecord {
            free: state.group_free(g),
            total: state.group_total(g),
            ..Default::default()
        };
        let mut zone = 0u32;
        let mut healthy = 0u32;
        for &n in members {
            let node = state.node(n);
            if node.zone == Zone::InferenceDedicated {
                zone += 1;
            }
            if node.health.schedulable() {
                healthy += 1;
                if node.free_gpus() == node.total_gpus() {
                    rec.whole_free_nodes += 1;
                }
            }
        }
        let count = members.len().max(1) as f32;
        rec.zone_frac = zone as f32 / count;
        rec.healthy_frac = healthy as f32 / count;
        self.groups[g.index()] = rec;
    }

    fn rebuild_all_groups(&mut self, state: &ClusterState) {
        self.groups = vec![GroupRecord::default(); state.fabric.num_groups()];
        for g in 0..state.fabric.num_groups() {
            self.rebuild_group(state, GroupId(g as u32));
        }
    }

    /// Current mutation-log cursor (for `ClusterState::compact_log`).
    pub fn cursor(&self) -> u64 {
        self.cursor
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::builder::{ClusterBuilder, ClusterSpec};
    use crate::cluster::gpu::Health;
    use crate::cluster::ids::{JobId, PodId};
    use crate::cluster::state::PodPlacement;
    use crate::prop_assert;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    fn state() -> ClusterState {
        ClusterBuilder::build(&ClusterSpec::homogeneous("s", 2, 2, 4))
    }

    fn placement(job: u64, node: u32, devs: Vec<u8>) -> PodPlacement {
        PodPlacement {
            pod: PodId::new(JobId(job), 0),
            node: NodeId(node),
            devices: devs,
            nic: 0,
        }
    }

    #[test]
    fn deep_and_incremental_agree_after_mutations() {
        let mut s = state();
        let mut deep = Snapshot::new(SnapshotMode::DeepCopy);
        let mut inc = Snapshot::new(SnapshotMode::Incremental);
        deep.refresh(&s);
        inc.refresh(&s);
        assert_eq!(deep.nodes, inc.nodes);
        assert_eq!(deep.groups, inc.groups);

        s.commit_placements(JobId(1), vec![placement(1, 0, vec![0, 1, 2])])
            .unwrap();
        s.commit_placements(JobId(2), vec![placement(2, 5, vec![0])])
            .unwrap();
        s.set_node_health(NodeId(9), Health::Cordoned);
        s.release_job(JobId(2)).unwrap();

        deep.refresh(&s);
        inc.refresh(&s);
        assert_eq!(deep.nodes, inc.nodes);
        assert_eq!(deep.groups, inc.groups);
    }

    #[test]
    fn incremental_rebuilds_fewer_records() {
        let mut s = state();
        let mut inc = Snapshot::new(SnapshotMode::Incremental);
        inc.refresh(&s); // Full build: 16 nodes.
        s.commit_placements(JobId(1), vec![placement(1, 0, vec![0])])
            .unwrap();
        inc.refresh(&s);
        assert_eq!(inc.stats.full_rebuilds, 1);
        assert_eq!(inc.stats.node_records_rebuilt, 16 + 1);
    }

    #[test]
    fn compacted_log_triggers_full_rebuild() {
        let mut s = state();
        let mut inc = Snapshot::new(SnapshotMode::Incremental);
        inc.refresh(&s);
        s.commit_placements(JobId(1), vec![placement(1, 1, vec![0])])
            .unwrap();
        s.compact_log(s.log_head()); // Compact past the snapshot cursor... cursor == head0 < head.
        inc.refresh(&s);
        assert_eq!(inc.stats.full_rebuilds, 2);
        // And it is still correct.
        let mut deep = Snapshot::new(SnapshotMode::DeepCopy);
        deep.refresh(&s);
        assert_eq!(deep.nodes, inc.nodes);
    }

    #[test]
    fn group_records_track_whole_free_nodes() {
        let mut s = state();
        let mut snap = Snapshot::new(SnapshotMode::DeepCopy);
        snap.refresh(&s);
        assert_eq!(snap.groups[0].whole_free_nodes, 4);
        s.commit_placements(JobId(1), vec![placement(1, 0, vec![0])])
            .unwrap();
        snap.refresh(&s);
        assert_eq!(snap.groups[0].whole_free_nodes, 3);
        assert_eq!(snap.groups[0].free, 31);
    }

    #[test]
    fn property_incremental_equals_deep_after_random_ops() {
        prop::check(60, |rng: &mut Pcg32| {
            let mut s = state();
            let mut deep = Snapshot::new(SnapshotMode::DeepCopy);
            let mut inc = Snapshot::new(SnapshotMode::Incremental);
            let mut live_jobs: Vec<u64> = Vec::new();
            let mut next_job = 1u64;
            for step in 0..rng.range_inclusive(1, 40) {
                match rng.below(4) {
                    0 | 1 => {
                        // Try to place a random 1-4 GPU pod.
                        let node = NodeId(rng.below(16) as u32);
                        let want = rng.range_inclusive(1, 4) as usize;
                        let free = s.node(node).free_gpu_indices();
                        if free.len() >= want && s.node(node).health.schedulable() {
                            let devs = free[..want].to_vec();
                            s.commit_placements(
                                JobId(next_job),
                                vec![placement(next_job, node.0, devs)],
                            )
                            .unwrap();
                            live_jobs.push(next_job);
                            next_job += 1;
                        }
                    }
                    2 => {
                        if let Some(i) = (!live_jobs.is_empty())
                            .then(|| rng.below(live_jobs.len() as u64) as usize)
                        {
                            let j = live_jobs.swap_remove(i);
                            s.release_job(JobId(j)).unwrap();
                        }
                    }
                    _ => {
                        let node = NodeId(rng.below(16) as u32);
                        // Only flip health of nodes with no allocations, to
                        // keep the exercise simple and valid.
                        if s.node(node).allocated_gpus() == 0 {
                            let h = if s.node(node).health.schedulable() {
                                Health::Cordoned
                            } else {
                                Health::Healthy
                            };
                            s.set_node_health(node, h);
                        }
                    }
                }
                // Refresh at random points, not only at the end.
                if rng.chance(0.3) || step == 0 {
                    deep.refresh(&s);
                    inc.refresh(&s);
                    prop_assert!(
                        deep.nodes == inc.nodes,
                        "node records diverged at step {step}"
                    );
                    prop_assert!(
                        deep.groups == inc.groups,
                        "group records diverged at step {step}"
                    );
                }
            }
            deep.refresh(&s);
            inc.refresh(&s);
            prop_assert!(deep.nodes == inc.nodes, "final node records diverged");
            prop_assert!(deep.groups == inc.groups, "final group records diverged");
            Ok(())
        });
    }
}
