//! Strongly-typed identifiers used across the cluster model.
//!
//! Everything is index-based (ids are indices into `Vec`s on
//! [`crate::cluster::state::ClusterState`]) — the scheduler hot path never
//! chases pointers or hashes strings.

use std::fmt;

macro_rules! id_type {
    ($(#[$doc:meta])* $name:ident, $inner:ty) => {
        $(#[$doc])*
        #[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
        pub struct $name(pub $inner);

        impl $name {
            #[inline]
            pub fn index(self) -> usize {
                self.0 as usize
            }
        }

        impl fmt::Display for $name {
            fmt_impl!($name);
        }

        impl From<usize> for $name {
            fn from(i: usize) -> Self {
                $name(i as $inner)
            }
        }
    };
}

macro_rules! fmt_impl {
    ($name:ident) => {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "{}{}", stringify!($name), self.0)
        }
    };
}

id_type!(
    /// A physical node (server with a GPU board).
    NodeId, u32
);
id_type!(
    /// A NodeNetGroup — one LeafGroup of the scale-out fabric (§3.4.2).
    GroupId, u32
);
id_type!(
    /// An aggregation-layer (spine) switch group.
    SpineId, u32
);
id_type!(
    /// A core-layer (superspine) switch group.
    SuperSpineId, u32
);
id_type!(
    /// A Hyper Bandwidth Domain — scale-up interconnect island (§3.3.5).
    HbdId, u32
);
id_type!(
    /// A GPU model (Type-L, Type-A, ...). Indexes the GPU type table.
    GpuTypeId, u16
);
id_type!(
    /// A GPU-Type-based node pool (§3.4.1).
    PoolId, u16
);
id_type!(
    /// A tenant in the multi-tenant cluster.
    TenantId, u32
);
id_type!(
    /// A submitted job (workload).
    JobId, u64
);

/// A pod is addressed as (job, replica index); it never exists standalone.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PodId {
    pub job: JobId,
    pub replica: u32,
}

impl PodId {
    pub fn new(job: JobId, replica: u32) -> PodId {
        PodId { job, replica }
    }
}

impl fmt::Display for PodId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/pod{}", self.job, self.replica)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_roundtrip_index() {
        assert_eq!(NodeId::from(42usize).index(), 42);
        assert_eq!(GroupId(7).index(), 7);
    }

    #[test]
    fn display_formats() {
        assert_eq!(NodeId(3).to_string(), "NodeId3");
        assert_eq!(PodId::new(JobId(9), 2).to_string(), "JobId9/pod2");
    }

    #[test]
    fn pod_ids_order_by_job_then_replica() {
        let a = PodId::new(JobId(1), 5);
        let b = PodId::new(JobId(2), 0);
        assert!(a < b);
    }
}
