//! A cluster node: an 8-GPU (or 4-GPU) server with NVLink islands, RDMA
//! NICs, a position in the scale-out fabric (its NodeNetGroup) and
//! optionally a scale-up HBD domain.
//!
//! Nodes expose *primitive* allocation operations (allocate these exact
//! device indices to this pod); policy — which devices to pick — lives in
//! `rsch::device_alloc`.

use std::fmt;

use super::gpu::{GpuDevice, GpuType, Health, Nic};
use super::ids::{GpuTypeId, GroupId, HbdId, NodeId, PodId};

/// Placement zone for E-Spread (§3.3.4): a subset of nodes is designated an
/// inference dedicated zone; the rest is the general pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Zone {
    General,
    InferenceDedicated,
}

/// A physical node.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub id: NodeId,
    pub gpu_type: GpuTypeId,
    pub group: GroupId,
    pub hbd: Option<HbdId>,
    pub zone: Zone,
    pub health: Health,
    pub gpus: Vec<GpuDevice>,
    pub nics: Vec<Nic>,
}

impl Node {
    pub fn new(id: NodeId, gpu_type: &GpuType, group: GroupId) -> Node {
        Node {
            id,
            gpu_type: gpu_type.id,
            group,
            hbd: None,
            zone: Zone::General,
            health: Health::Healthy,
            gpus: (0..gpu_type.gpus_per_node).map(GpuDevice::new).collect(),
            nics: (0..gpu_type.nics_per_node).map(Nic::new).collect(),
        }
    }

    #[inline]
    pub fn total_gpus(&self) -> u32 {
        self.gpus.len() as u32
    }

    /// Free (unallocated, healthy) GPU count; zero when the node itself is
    /// unschedulable.
    pub fn free_gpus(&self) -> u32 {
        if !self.health.schedulable() {
            return 0;
        }
        self.gpus.iter().filter(|g| g.free()).count() as u32
    }

    pub fn allocated_gpus(&self) -> u32 {
        self.gpus.iter().filter(|g| g.allocated_to.is_some()).count() as u32
    }

    /// Indices of free, healthy devices.
    pub fn free_gpu_indices(&self) -> Vec<u8> {
        if !self.health.schedulable() {
            return Vec::new();
        }
        self.gpus
            .iter()
            .filter(|g| g.free())
            .map(|g| g.index)
            .collect()
    }

    /// Fragmentation classification per §4.3: a node is *non-fragmented*
    /// when fully idle or fully occupied, fragmented otherwise. Unhealthy
    /// nodes are excluded from the metric (not schedulable capacity).
    pub fn is_fragmented(&self) -> bool {
        if !self.health.schedulable() {
            return false;
        }
        let alloc = self.allocated_gpus();
        alloc > 0 && alloc < self.total_gpus()
    }

    /// Size of the largest NVLink island measured in *free* devices —
    /// feature 11 of the scoring contract and the device-alloc heuristic's
    /// first choice.
    pub fn largest_free_island(&self, gpu_type: &GpuType) -> u32 {
        debug_assert_eq!(gpu_type.id, self.gpu_type);
        if !self.health.schedulable() {
            return 0;
        }
        gpu_type
            .nvlink_islands
            .iter()
            .map(|island| {
                island
                    .iter()
                    .filter(|&&i| self.gpus.get(i as usize).is_some_and(|g| g.free()))
                    .count() as u32
            })
            .max()
            .unwrap_or(0)
    }

    /// Bind `devices` (exact indices) to `pod`. Fails without mutating if
    /// any device is missing, unhealthy or already bound — allocation is
    /// all-or-nothing at node granularity too.
    pub fn allocate(&mut self, pod: PodId, devices: &[u8]) -> Result<(), AllocError> {
        if !self.health.schedulable() {
            return Err(AllocError::NodeUnhealthy(self.id));
        }
        for &d in devices {
            match self.gpus.get(d as usize) {
                None => return Err(AllocError::NoSuchDevice(self.id, d)),
                Some(g) if !g.free() => return Err(AllocError::DeviceBusy(self.id, d)),
                Some(_) => {}
            }
        }
        for &d in devices {
            self.gpus[d as usize].allocated_to = Some(pod);
        }
        Ok(())
    }

    /// Release every device bound to `pod`; returns how many were freed.
    pub fn release_pod(&mut self, pod: PodId) -> u32 {
        let mut freed = 0;
        for g in &mut self.gpus {
            if g.allocated_to == Some(pod) {
                g.allocated_to = None;
                freed += 1;
            }
        }
        freed
    }

    /// Devices currently bound to `pod`.
    pub fn devices_of(&self, pod: PodId) -> Vec<u8> {
        self.gpus
            .iter()
            .filter(|g| g.allocated_to == Some(pod))
            .map(|g| g.index)
            .collect()
    }

    /// Distinct pods with at least one device on this node.
    pub fn resident_pods(&self) -> Vec<PodId> {
        let mut pods: Vec<PodId> = self
            .gpus
            .iter()
            .filter_map(|g| g.allocated_to)
            .collect();
        pods.sort_unstable();
        pods.dedup();
        pods
    }
}

/// Device-level allocation failures (distinct from scheduling failures —
/// these indicate races/bugs and abort the gang transaction).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AllocError {
    NodeUnhealthy(NodeId),
    NoSuchDevice(NodeId, u8),
    DeviceBusy(NodeId, u8),
}

impl fmt::Display for AllocError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AllocError::NodeUnhealthy(n) => write!(f, "node {n} is unhealthy"),
            AllocError::NoSuchDevice(n, d) => write!(f, "node {n} has no GPU device {d}"),
            AllocError::DeviceBusy(n, d) => write!(f, "node {n} GPU device {d} is busy"),
        }
    }
}

impl std::error::Error for AllocError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ids::JobId;

    fn node8() -> (Node, GpuType) {
        let t = GpuType::type_h(GpuTypeId(0));
        (Node::new(NodeId(0), &t, GroupId(0)), t)
    }

    fn pod(j: u64, r: u32) -> PodId {
        PodId::new(JobId(j), r)
    }

    #[test]
    fn fresh_node_is_all_free() {
        let (n, t) = node8();
        assert_eq!(n.free_gpus(), 8);
        assert_eq!(n.allocated_gpus(), 0);
        assert!(!n.is_fragmented());
        assert_eq!(n.largest_free_island(&t), 8);
    }

    #[test]
    fn allocate_and_release_roundtrip() {
        let (mut n, _) = node8();
        n.allocate(pod(1, 0), &[0, 1, 2, 3]).unwrap();
        assert_eq!(n.free_gpus(), 4);
        assert!(n.is_fragmented());
        assert_eq!(n.devices_of(pod(1, 0)), vec![0, 1, 2, 3]);
        assert_eq!(n.release_pod(pod(1, 0)), 4);
        assert_eq!(n.free_gpus(), 8);
        assert!(!n.is_fragmented());
    }

    #[test]
    fn allocate_is_all_or_nothing() {
        let (mut n, _) = node8();
        n.allocate(pod(1, 0), &[3]).unwrap();
        let err = n.allocate(pod(2, 0), &[2, 3]).unwrap_err();
        assert_eq!(err, AllocError::DeviceBusy(NodeId(0), 3));
        // Device 2 must NOT have been allocated by the failed call.
        assert!(n.gpus[2].free());
    }

    #[test]
    fn allocate_rejects_bad_device() {
        let (mut n, _) = node8();
        assert!(matches!(
            n.allocate(pod(1, 0), &[42]),
            Err(AllocError::NoSuchDevice(_, 42))
        ));
    }

    #[test]
    fn unhealthy_node_is_not_schedulable() {
        let (mut n, t) = node8();
        n.health = Health::Cordoned;
        assert_eq!(n.free_gpus(), 0);
        assert_eq!(n.largest_free_island(&t), 0);
        assert!(n.allocate(pod(1, 0), &[0]).is_err());
        assert!(!n.is_fragmented()); // Excluded from GFR.
    }

    #[test]
    fn faulty_device_shrinks_free_and_islands() {
        let (mut n, t) = node8();
        n.gpus[0].health = Health::Faulty;
        assert_eq!(n.free_gpus(), 7);
        assert_eq!(n.largest_free_island(&t), 7);
    }

    #[test]
    fn type_l_islands_track_quads() {
        let t = GpuType::type_l(GpuTypeId(1));
        let mut n = Node::new(NodeId(1), &t, GroupId(0));
        n.allocate(pod(1, 0), &[0, 1]).unwrap();
        // Quad 0 has 2 free, quad 1 has 4 free.
        assert_eq!(n.largest_free_island(&t), 4);
    }

    #[test]
    fn fully_allocated_node_not_fragmented() {
        let (mut n, _) = node8();
        n.allocate(pod(1, 0), &[0, 1, 2, 3, 4, 5, 6, 7]).unwrap();
        assert!(!n.is_fragmented());
    }

    #[test]
    fn resident_pods_dedups() {
        let (mut n, _) = node8();
        n.allocate(pod(1, 0), &[0, 1]).unwrap();
        n.allocate(pod(2, 1), &[2]).unwrap();
        assert_eq!(n.resident_pods(), vec![pod(1, 0), pod(2, 1)]);
    }
}
