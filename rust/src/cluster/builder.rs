//! Cluster construction from a declarative spec.
//!
//! Builds the leaf/spine/superspine fabric, optional HBD domains, nodes
//! with their GPU boards, and an optional E-Spread inference dedicated zone.

use super::gpu::GpuType;
use super::ids::{GpuTypeId, GroupId, HbdId, NodeId, SpineId, SuperSpineId};
use super::node::{Node, Zone};
use super::state::ClusterState;
use super::topology::{Fabric, Hbd, NetGroup, Spine};

/// How many nodes of which GPU model to place, fabric shape, zones.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub name: String,
    /// GPU model profiles (index = GpuTypeId).
    pub gpu_types: Vec<GpuTypeProfile>,
    /// Leaf groups per spine.
    pub groups_per_spine: u32,
    /// Spines per superspine.
    pub spines_per_superspine: u32,
    /// Nodes per leaf group.
    pub nodes_per_group: u32,
    /// Consecutive nodes per HBD domain (0 = no HBDs).
    pub hbd_size: u32,
    /// Fraction of nodes (from the tail) designated E-Spread inference zone.
    pub inference_zone_frac: f64,
}

/// One GPU model's share of the cluster.
#[derive(Debug, Clone)]
pub struct GpuTypeProfile {
    pub model: GpuModel,
    /// Number of *leaf groups* populated with this model (heterogeneous
    /// clusters split by model at group granularity — pools stay
    /// topology-aligned).
    pub groups: u32,
}

/// Built-in GPU models (see `gpu.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GpuModel {
    TypeH,
    TypeL,
    TypeA,
}

impl GpuModel {
    pub fn instantiate(self, id: GpuTypeId) -> GpuType {
        match self {
            GpuModel::TypeH => GpuType::type_h(id),
            GpuModel::TypeL => GpuType::type_l(id),
            GpuModel::TypeA => GpuType::type_a(id),
        }
    }
}

impl ClusterSpec {
    /// Homogeneous Type-H training cluster:
    /// `spines × groups_per_spine` groups of `nodes_per_group` 8-GPU nodes.
    pub fn homogeneous(
        name: impl Into<String>,
        spines: u32,
        groups_per_spine: u32,
        nodes_per_group: u32,
    ) -> ClusterSpec {
        ClusterSpec {
            name: name.into(),
            gpu_types: vec![GpuTypeProfile {
                model: GpuModel::TypeH,
                groups: spines * groups_per_spine,
            }],
            groups_per_spine,
            spines_per_superspine: 4,
            nodes_per_group,
            hbd_size: 0,
            inference_zone_frac: 0.0,
        }
    }

    /// The paper's §5.1 testbed: a homogeneous 8,000-GPU training cluster
    /// (1,000 × 8-GPU nodes; 32 nodes per leaf group).
    pub fn train8000() -> ClusterSpec {
        // 1000 nodes ≈ 32 groups of 32 nodes (1024 nodes); trim to 1000
        // would break group symmetry, so we build 31 groups of 32 + 1 of 8.
        // Simpler and faithful: 1000 nodes = 25 groups of 40? Keep 32/32 and
        // accept 1024 nodes (8192 GPUs) — the paper says "8,000-GPU scale".
        ClusterSpec::homogeneous("train8000", 8, 4, 32)
    }

    /// The "tens of thousands of GPUs" end of the paper's abstract claim:
    /// 1,250 × 8-GPU nodes = exactly 10,000 GPUs, in 50 LeafGroups of 25
    /// nodes. The scale the candidate-index ablation proves itself at.
    pub fn train10000() -> ClusterSpec {
        ClusterSpec::homogeneous("train10000", 10, 5, 25)
    }

    /// The 100,000-GPU frontier cluster: 12,500 × 8-GPU nodes in 500
    /// LeafGroups of 25, over 50 spines in 10 superspines — the scale the
    /// superspine-sharded scheduler core targets (one shard per
    /// superspine, ~10,000 GPUs each).
    pub fn train100000() -> ClusterSpec {
        let mut s = ClusterSpec::homogeneous("train100000", 50, 10, 25);
        s.spines_per_superspine = 5;
        s
    }

    pub fn total_groups(&self) -> u32 {
        self.gpu_types.iter().map(|p| p.groups).sum()
    }

    pub fn total_nodes(&self) -> u32 {
        self.total_groups() * self.nodes_per_group
    }
}

/// Builder entry point.
pub struct ClusterBuilder;

impl ClusterBuilder {
    pub fn build(spec: &ClusterSpec) -> ClusterState {
        let gpu_types: Vec<GpuType> = spec
            .gpu_types
            .iter()
            .enumerate()
            .map(|(i, p)| p.model.instantiate(GpuTypeId(i as u16)))
            .collect();

        let total_groups = spec.total_groups();
        let groups_per_spine = spec.groups_per_spine.max(1);
        let spines_per_ss = spec.spines_per_superspine.max(1);

        let mut fabric = Fabric::default();
        let mut nodes: Vec<Node> = Vec::new();

        // Assign a contiguous range of groups per GPU-type profile.
        let mut group_cursor = 0u32;
        let mut type_of_group: Vec<GpuTypeId> = Vec::with_capacity(total_groups as usize);
        for (ti, p) in spec.gpu_types.iter().enumerate() {
            for _ in 0..p.groups {
                type_of_group.push(GpuTypeId(ti as u16));
                group_cursor += 1;
            }
        }
        debug_assert_eq!(group_cursor, total_groups);

        let num_spines = total_groups.div_ceil(groups_per_spine);
        for s in 0..num_spines {
            fabric.spines.push(Spine {
                id: SpineId(s),
                superspine: SuperSpineId(s / spines_per_ss),
                groups: Vec::new(),
            });
        }
        fabric.num_superspines = num_spines.div_ceil(spines_per_ss);

        for g in 0..total_groups {
            let spine = SpineId(g / groups_per_spine);
            let gid = GroupId(g);
            let gpu_type = &gpu_types[type_of_group[g as usize].index()];
            let mut members = Vec::with_capacity(spec.nodes_per_group as usize);
            for _ in 0..spec.nodes_per_group {
                let id = NodeId(nodes.len() as u32);
                nodes.push(Node::new(id, gpu_type, gid));
                members.push(id);
            }
            fabric.spines[spine.index()].groups.push(gid);
            fabric.groups.push(NetGroup {
                id: gid,
                spine,
                nodes: members,
            });
        }

        // HBD domains: consecutive node runs of `hbd_size` within a group.
        if spec.hbd_size > 1 {
            let mut hbd_id = 0u32;
            for g in &fabric.groups {
                for chunk in g.nodes.chunks(spec.hbd_size as usize) {
                    if chunk.len() as u32 == spec.hbd_size {
                        let id = HbdId(hbd_id);
                        hbd_id += 1;
                        for &n in chunk {
                            nodes[n.index()].hbd = Some(id);
                        }
                        fabric.hbds.push(Hbd {
                            id,
                            nodes: chunk.to_vec(),
                        });
                    }
                }
            }
        }

        // Inference dedicated zone: the *last* fraction of each pool's
        // groups (keeps the zone topology-contiguous).
        if spec.inference_zone_frac > 0.0 {
            let zone_groups =
                (total_groups as f64 * spec.inference_zone_frac).round() as u32;
            for g in (total_groups - zone_groups.min(total_groups))..total_groups {
                for &n in &fabric.groups[g as usize].nodes {
                    nodes[n.index()].zone = Zone::InferenceDedicated;
                }
            }
        }

        // The builder assigns every node to a group above, so finalize
        // can only fail on a builder bug — surface it loudly.
        fabric
            .finalize(nodes.len())
            .expect("ClusterBuilder left a node outside every NodeNetGroup");
        ClusterState::new(gpu_types, nodes, fabric)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::node::Zone;

    #[test]
    fn homogeneous_shape() {
        let s = ClusterBuilder::build(&ClusterSpec::homogeneous("t", 2, 4, 8));
        assert_eq!(s.nodes.len(), 2 * 4 * 8);
        assert_eq!(s.total_gpus(), 64 * 8);
        assert_eq!(s.fabric.num_groups(), 8);
        assert_eq!(s.fabric.spines.len(), 2);
        assert_eq!(s.pools.len(), 1);
    }

    // The preset-scale builds allocate tens of thousands of nodes —
    // fine natively, minutes under Miri's interpreter, and free of the
    // pointer tricks Miri exists to catch. The CI miri arm skips them.
    #[test]
    #[cfg_attr(miri, ignore)]
    fn train8000_is_thousand_node_scale() {
        let spec = ClusterSpec::train8000();
        let s = ClusterBuilder::build(&spec);
        assert_eq!(s.nodes.len(), 1024);
        assert_eq!(s.total_gpus(), 8192);
        assert_eq!(s.fabric.num_groups(), 32);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn train10000_is_ten_thousand_gpu_scale() {
        let spec = ClusterSpec::train10000();
        let s = ClusterBuilder::build(&spec);
        assert_eq!(s.nodes.len(), 1250);
        assert_eq!(s.total_gpus(), 10_000);
        assert_eq!(s.fabric.num_groups(), 50);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn train100000_is_hundred_thousand_gpu_scale() {
        let spec = ClusterSpec::train100000();
        let s = ClusterBuilder::build(&spec);
        assert_eq!(s.nodes.len(), 12_500);
        assert_eq!(s.total_gpus(), 100_000);
        assert_eq!(s.fabric.num_groups(), 500);
        assert_eq!(s.fabric.spines.len(), 50);
        assert_eq!(s.fabric.num_superspines, 10);
    }

    #[test]
    fn heterogeneous_pools_split_by_type() {
        let spec = ClusterSpec {
            name: "het".into(),
            gpu_types: vec![
                GpuTypeProfile {
                    model: GpuModel::TypeL,
                    groups: 2,
                },
                GpuTypeProfile {
                    model: GpuModel::TypeA,
                    groups: 1,
                },
            ],
            groups_per_spine: 2,
            spines_per_superspine: 2,
            nodes_per_group: 4,
            hbd_size: 0,
            inference_zone_frac: 0.0,
        };
        let s = ClusterBuilder::build(&spec);
        assert_eq!(s.pools.len(), 2);
        // Type-L: 2 groups × 4 nodes × 8 GPUs; Type-A: 1 group × 4 × 4.
        assert_eq!(s.pool_free_for_type(GpuTypeId(0)), 64);
        assert_eq!(s.pool_free_for_type(GpuTypeId(1)), 16);
        assert_eq!(s.total_gpus(), 80);
    }

    #[test]
    fn hbd_domains_cover_whole_chunks() {
        let mut spec = ClusterSpec::homogeneous("h", 1, 2, 8);
        spec.hbd_size = 4;
        let s = ClusterBuilder::build(&spec);
        assert_eq!(s.fabric.hbds.len(), 4); // 16 nodes / 4.
        assert!(s.nodes.iter().all(|n| n.hbd.is_some()));
        // HBDs don't straddle groups.
        for h in &s.fabric.hbds {
            let g0 = s.fabric.group_of(h.nodes[0]);
            assert!(h.nodes.iter().all(|&n| s.fabric.group_of(n) == g0));
        }
    }

    #[test]
    fn inference_zone_marks_tail_groups() {
        let mut spec = ClusterSpec::homogeneous("z", 1, 4, 4);
        spec.inference_zone_frac = 0.25;
        let s = ClusterBuilder::build(&spec);
        let zoned: Vec<_> = s
            .nodes
            .iter()
            .filter(|n| n.zone == Zone::InferenceDedicated)
            .map(|n| n.group)
            .collect();
        assert_eq!(zoned.len(), 4); // One group of four nodes.
        assert!(zoned.iter().all(|&g| g == GroupId(3)));
    }
}
