//! The simulated cluster substrate: GPUs, nodes, fabric topology, GPU-type
//! node pools, tenants/quotas, the authoritative state, and scheduling
//! snapshots. This stands in for Kubernetes + real hardware (DESIGN.md §1).

pub mod builder;
pub mod gpu;
pub mod ids;
pub mod index;
pub mod node;
pub mod pool;
pub mod shard;
pub mod snapshot;
pub mod state;
pub mod tenant;
pub mod topology;

pub use builder::{ClusterBuilder, ClusterSpec, GpuModel, GpuTypeProfile};
pub use gpu::{GpuDevice, GpuType, Health, Nic};
pub use ids::{
    GpuTypeId, GroupId, HbdId, JobId, NodeId, PodId, PoolId, SpineId, SuperSpineId, TenantId,
};
pub use index::{NodeIndex, ZoneQuery};
pub use node::{AllocError, Node, Zone};
pub use pool::{NodePool, PoolSet};
pub use shard::ShardMap;
pub use snapshot::{GroupRecord, NodeRecord, Snapshot, SnapshotMode, SnapshotStats};
pub use state::{ClusterState, PodPlacement, StateError};
pub use tenant::{BorrowRecord, QuotaEntry, QuotaError, QuotaLedger, QuotaMode, Tenant};
pub use topology::{
    Fabric, FootprintDelta, GangFootprint, Hbd, NetGroup, OrphanNodeError, Spine, Tier,
};
